"""Engine correctness: vectorized executor vs brute-force oracle.

Covers e-graph homomorphism (RDF semantics), subgraph isomorphism mode,
predicate variables (M_e binding), cyclic queries (non-tree joins), bound
IDs, multi-label vertices, and both join strategies (+INT on/off).
"""

import numpy as np
import pytest

from conftest import random_labeled_graph, random_query_graph
from repro.core import ExecOpts, Executor, build_plan
from repro.core.reference import enumerate_matches


def _run_and_compare(g, q, opts: ExecOpts, estimate="sampled"):
    plan = build_plan(g, q, estimate=estimate,
                      use_nlf=opts.use_nlf, use_deg=opts.use_deg)
    ex = Executor(g, opts)
    res = ex.run(plan)
    ref = enumerate_matches(g, q, semantics=opts.semantics)
    got = sorted(
        (tuple(b), tuple(p[: len(q.pvars)]))
        for b, p in zip(res.bindings.tolist(), res.pvar_bindings.tolist())
    )
    want = sorted(ref)
    assert res.count == len(ref), f"count {res.count} != oracle {len(ref)}"
    assert got == want
    return res


@pytest.mark.parametrize("seed", range(12))
def test_random_hom(seed):
    rng = np.random.default_rng(seed)
    g = random_labeled_graph(rng, n_vertices=10 + seed % 4)
    q = random_query_graph(rng, g, n_qv=2 + seed % 3)
    _run_and_compare(g, q, ExecOpts())


@pytest.mark.parametrize("seed", range(8))
def test_random_iso(seed):
    rng = np.random.default_rng(100 + seed)
    g = random_labeled_graph(rng, n_vertices=9)
    q = random_query_graph(rng, g, n_qv=3)
    _run_and_compare(g, q, ExecOpts(semantics="iso"))


@pytest.mark.parametrize("seed", range(8))
def test_random_pvar(seed):
    rng = np.random.default_rng(200 + seed)
    g = random_labeled_graph(rng, n_vertices=8, n_elabels=2)
    q = random_query_graph(rng, g, n_qv=3, with_pvar=True, p_extra_edge=0.0)
    if not q.pvars:
        pytest.skip("no pvar generated")
    _run_and_compare(g, q, ExecOpts())


@pytest.mark.parametrize("use_int", [True, False])
@pytest.mark.parametrize("seed", range(4))
def test_join_strategies_agree(seed, use_int):
    """+INT (tile compare-all) and binary-search IsJoinable: identical."""
    rng = np.random.default_rng(300 + seed)
    g = random_labeled_graph(rng, n_vertices=12, p_edge=0.35)
    q = random_query_graph(rng, g, n_qv=4, p_extra_edge=1.2)
    _run_and_compare(g, q, ExecOpts(use_int=use_int))


@pytest.mark.parametrize("use_nlf,use_deg", [(True, False), (False, True),
                                             (True, True)])
def test_filters_preserve_results(use_nlf, use_deg):
    """-NLF/-DEG are performance toggles; results must not change."""
    rng = np.random.default_rng(42)
    g = random_labeled_graph(rng, n_vertices=14, p_edge=0.3)
    for seed in range(4):
        rngq = np.random.default_rng(400 + seed)
        q = random_query_graph(rngq, g, n_qv=3)
        _run_and_compare(g, q, ExecOpts(use_nlf=use_nlf, use_deg=use_deg))


def test_hom_vs_iso_differ_on_diamond():
    """Homomorphism can map two query vertices to one data vertex."""
    from repro.core.query import QEdge, QueryGraph, QVertex
    from repro.rdf.graph import LabeledGraph

    # data: v0 -a-> v1, v0 -a-> v2  (fan-out of 2)
    g = LabeledGraph.build(
        n_vertices=3, src=np.array([0, 0]), el=np.array([0, 0]),
        dst=np.array([1, 2]), n_elabels=1,
        vlabel_sets=[(), (), ()], n_vlabels=0)
    q = QueryGraph()
    q.vertices = [QVertex("a"), QVertex("b"), QVertex("c")]
    q.var_to_vertex = {"a": 0, "b": 1, "c": 2}
    q.edges = [QEdge(0, 1, 0), QEdge(0, 2, 0)]
    hom = Executor(g, ExecOpts()).run(build_plan(g, q))
    iso = Executor(g, ExecOpts(semantics="iso")).run(build_plan(g, q))
    assert hom.count == 4  # (1,1),(1,2),(2,1),(2,2)
    assert iso.count == 2  # (1,2),(2,1)


def test_paper_figure1_example():
    """Figure 1 of the paper: 1 subgraph isomorphism, 3 e-graph homomorphisms."""
    from repro.core.query import QEdge, QueryGraph, QVertex
    from repro.rdf.graph import LabeledGraph

    # g1 (reconstructed from the paper's stated solutions): labels A..D=0..3;
    # edges a,b,c = 0,1,2
    # v0:A v1:B v2:A v3:C v4:D v5:D
    # v0-a->v1, v0-b->v4, v2-a->v1, v2-a->v3, v3-c->v4, v3-c->v5, v2-b->v5
    g = LabeledGraph.build(
        n_vertices=6,
        src=np.array([0, 0, 2, 2, 3, 3, 2]),
        el=np.array([0, 1, 0, 0, 2, 2, 1]),
        dst=np.array([1, 4, 1, 3, 4, 5, 5]),
        n_elabels=3,
        vlabel_sets=[(0,), (1,), (0,), (2,), (3,), (3,)],
        n_vlabels=4)
    # q1: u0:A -a-> u1:_ ; u0 -b-> u4:_ ; u2:A -a-> u1 ; u2 -a-> u3:C ;
    #     u3 -c-> u4   (u1, u4 blank per Figure 1)
    q = QueryGraph()
    q.vertices = [QVertex("u0", labels=(0,)), QVertex("u1"),
                  QVertex("u2", labels=(0,)), QVertex("u3", labels=(2,)),
                  QVertex("u4")]
    q.var_to_vertex = {f"u{i}": i for i in range(5)}
    q.edges = [QEdge(0, 1, 0), QEdge(0, 4, 1), QEdge(2, 1, 0), QEdge(2, 3, 0),
               QEdge(3, 4, 2)]
    hom = Executor(g, ExecOpts()).run(build_plan(g, q))
    iso = Executor(g, ExecOpts(semantics="iso")).run(build_plan(g, q))
    assert iso.count == 1
    assert hom.count == 3
    want = {(0, 1, 2, 3, 4), (2, 3, 2, 3, 5), (2, 1, 2, 3, 5)}
    assert set(map(tuple, hom.bindings.tolist())) == want


def test_point_query(lubm_graph):
    """Point-shaped queries (paper Algorithm 1 lines 2-4): inverse label scan."""
    g, maps = lubm_graph
    from repro.core.query import QueryGraph, QVertex

    lbl = maps.vlabel_of("ub:Student")
    q = QueryGraph()
    q.vertices = [QVertex("x", labels=(lbl,))]
    q.var_to_vertex = {"x": 0}
    plan = build_plan(g, q)
    res = Executor(g, ExecOpts()).run(plan)
    assert res.count == g.freq([lbl])


def test_overflow_retry():
    """Tiny initial capacity must trigger geometric retry, same results."""
    rng = np.random.default_rng(7)
    g = random_labeled_graph(rng, n_vertices=14, p_edge=0.5)
    q = random_query_graph(rng, g, n_qv=3, with_labels=False, with_id=False)
    opts = ExecOpts(init_cap=8, chunk=4)
    plan = build_plan(g, q)
    plan.est_fanout = []  # defeat capacity presizing: force the retry path
    plan.est_expand = []
    ex = Executor(g, opts)
    res = ex.run(plan)
    ref = enumerate_matches(g, q)
    assert res.count == len(ref)
    assert res.chunks_retried > 0


def test_disconnected_query_cross_product():
    rng = np.random.default_rng(11)
    g = random_labeled_graph(rng, n_vertices=8, p_edge=0.4)
    from repro.core.query import QEdge, QueryGraph, QVertex

    q = QueryGraph()
    q.vertices = [QVertex("a"), QVertex("b"), QVertex("c"), QVertex("d")]
    q.var_to_vertex = {v.var: i for i, v in enumerate(q.vertices)}
    q.edges = [QEdge(0, 1, 0), QEdge(2, 3, 1)]  # two components
    _run_and_compare(g, q, ExecOpts())
