"""SPARQL layer tests: parser, full LUBM suite vs brute-force oracle,
OPTIONAL / FILTER / UNION semantics, predicate variables, both transforms."""

import numpy as np
import pytest

from repro.core import ExecOpts, SparqlEngine, build_query_graph
from repro.core.reference import enumerate_matches
from repro.rdf.sparql import (Comparison, Regex, SparqlError, Var,
                              parse_sparql)
from repro.rdf.workloads import BSBM_QUERIES, HETERO_QUERIES, LUBM_QUERIES


# --------------------------------------------------------------------------
# parser
# --------------------------------------------------------------------------


def test_parse_basic():
    q = parse_sparql("SELECT ?x WHERE { ?x rdf:type ub:Student . }")
    assert q.select == ["x"]
    assert len(q.where.triples) == 1


def test_parse_prefix_and_iri():
    q = parse_sparql(
        'PREFIX ub: <http://ex.org/ub#>\n'
        "SELECT ?x ?y WHERE { ?x ub:advisor ?y . "
        "?x <http://www.w3.org/1999/02/22-rdf-syntax-ns#type> ub:Student }"
    )
    assert q.prefixes["ub"] == "http://ex.org/ub#"
    assert q.where.triples[1].p.value == "rdf:type"


def test_parse_optional_filter_union():
    q = parse_sparql("""
        SELECT ?p ?r WHERE {
          { ?p b:f b:A . } UNION { ?p b:f b:B . }
          ?p b:price ?v .
          FILTER (?v < 100 && ?v > 10)
          OPTIONAL { ?p b:rating ?r . }
        }""")
    assert len(q.where.unions) == 1 and len(q.where.unions[0]) == 2
    assert len(q.where.filters) == 2  # && split
    assert len(q.where.optionals) == 1


def test_parse_regex_filter():
    q = parse_sparql(
        'SELECT ?x WHERE { ?x b:label ?l . FILTER regex(?l, "ab.c") }')
    f = q.where.filters[0]
    assert isinstance(f, Regex) and f.pattern == "ab.c"


def test_parse_predicate_variable():
    q = parse_sparql("SELECT ?p WHERE { b:X ?p ?o . }")
    assert isinstance(q.where.triples[0].p, Var)


def test_parse_a_keyword():
    q = parse_sparql("SELECT ?x WHERE { ?x a ub:Student . }")
    assert q.where.triples[0].p.value == "rdf:type"


def test_parse_errors():
    with pytest.raises(SparqlError):
        parse_sparql("SELECT ?x WHERE { ?x }")
    with pytest.raises(SparqlError):
        parse_sparql("SELECT ?x { ?x a b:C }")  # missing WHERE


# --------------------------------------------------------------------------
# LUBM suite vs oracle (type-aware transformation)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(LUBM_QUERIES))
def test_lubm_query_vs_oracle(lubm_graph, name):
    g, maps = lubm_graph
    engine = SparqlEngine(g, maps)
    ast = parse_sparql(LUBM_QUERIES[name])
    res = engine.query_ast(ast)
    q = build_query_graph(ast.where.triples, maps)
    ref = enumerate_matches(g, q)
    assert res.count == len(ref), f"{name}: {res.count} != oracle {len(ref)}"


def test_lubm_direct_vs_type_aware(lubm_graph, lubm_graph_direct):
    """Both transformations must yield identical solution counts (Q6/Q14:
    the type-aware count includes subclass closure; under the direct
    transformation the same closure exists only through materialized
    subClassOf edges, so restrict to queries without subsumption)."""
    g_t, m_t = lubm_graph
    g_d, m_d = lubm_graph_direct
    e_t = SparqlEngine(g_t, m_t)
    e_d = SparqlEngine(g_d, m_d)
    for name in ("Q1", "Q2", "Q3"):  # leaf-type queries: no subsumption needed
        c_t = e_t.count(LUBM_QUERIES[name])
        c_d = e_d.count(LUBM_QUERIES[name])
        assert c_t == c_d, f"{name}: type-aware {c_t} != direct {c_d}"


def test_q6_equals_inverse_label_freq(lubm_graph):
    g, maps = lubm_graph
    engine = SparqlEngine(g, maps)
    lbl = maps.vlabel_of("ub:Student")
    assert engine.count(LUBM_QUERIES["Q6"]) == g.freq([lbl])


def test_constant_queries_nonempty(lubm_graph):
    g, maps = lubm_graph
    engine = SparqlEngine(g, maps)
    for name in ("Q1", "Q4", "Q5", "Q8", "Q11", "Q12"):
        assert engine.count(LUBM_QUERIES[name]) > 0, name


# --------------------------------------------------------------------------
# hetero suite (pvar, triangles) vs oracle
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(HETERO_QUERIES))
def test_hetero_query_vs_oracle(hetero_graph, name):
    g, maps = hetero_graph
    engine = SparqlEngine(g, maps)
    ast = parse_sparql(HETERO_QUERIES[name])
    res = engine.query_ast(ast)
    q = build_query_graph(ast.where.triples, maps)
    ref = enumerate_matches(g, q)
    assert res.count == len(ref), f"{name}: {res.count} != {len(ref)}"


# --------------------------------------------------------------------------
# OPTIONAL / FILTER / UNION semantics on BSBM-like data
# --------------------------------------------------------------------------


def _oracle_filtered(g, maps, triples, pred):
    q = build_query_graph(triples, maps)
    out = []
    for b, p in enumerate_matches(g, q):
        if pred(q, b):
            out.append((b, p))
    return out


def test_filter_numeric(bsbm_graph):
    g, maps = bsbm_graph
    engine = SparqlEngine(g, maps)
    ast = parse_sparql(BSBM_QUERIES["B1"])
    res = engine.query_ast(ast)
    # oracle: count products with feature1 and value > 1200
    def pred(q, b):
        col = q.var_to_vertex["v"]
        return g.numeric_value[b[col]] > 1200

    ref = _oracle_filtered(g, maps, ast.where.triples, pred)
    assert res.count == len(ref)
    assert res.count > 0


def test_filter_var_var(bsbm_graph):
    g, maps = bsbm_graph
    engine = SparqlEngine(g, maps)
    ast = parse_sparql(BSBM_QUERIES["B5"])
    res = engine.query_ast(ast)

    def pred(q, b):
        v1 = g.numeric_value[b[q.var_to_vertex["v1"]]]
        v2 = g.numeric_value[b[q.var_to_vertex["v2"]]]
        return v1 < v2

    ref = _oracle_filtered(g, maps, ast.where.triples, pred)
    assert res.count == len(ref) and res.count > 0


def test_filter_regex(bsbm_graph):
    g, maps = bsbm_graph
    engine = SparqlEngine(g, maps)
    res = engine.query(BSBM_QUERIES["B6"])
    assert 0 < res.count
    for rec in res.decode(maps):
        assert "product 1" in rec["label"]


def test_union_keeps_duplicates_and_counts(bsbm_graph):
    g, maps = bsbm_graph
    engine = SparqlEngine(g, maps)
    ast = parse_sparql(BSBM_QUERIES["B4"])
    res = engine.query_ast(ast)
    c5 = engine.count("SELECT ?p WHERE { ?p rdf:type b:Product . "
                      "?p b:productFeature b:Feature5 . }")
    c6 = engine.count("SELECT ?p WHERE { ?p rdf:type b:Product . "
                      "?p b:productFeature b:Feature6 . }")
    assert res.count == c5 + c6  # SPARQL UNION: no dedup


def test_optional_left_join(bsbm_graph):
    g, maps = bsbm_graph
    engine = SparqlEngine(g, maps)
    res = engine.query(BSBM_QUERIES["B8"])
    base = engine.count("""
        SELECT ?r ?rating1 WHERE {
          ?r rdf:type b:Review .
          ?r b:reviewFor b:Product7 .
          ?r b:rating1 ?rating1 . }""")
    assert base > 0
    # every base row appears exactly once (rating2 is single-valued)
    assert res.count == base
    col = res.variables.index("rating2")
    matched = int((res.rows[:, col] >= 0).sum())
    with_r2 = engine.count("""
        SELECT ?r WHERE {
          ?r rdf:type b:Review .
          ?r b:reviewFor b:Product7 .
          ?r b:rating1 ?x .
          ?r b:rating2 ?y . }""")
    assert matched == with_r2
    assert matched < base  # generator leaves ~40% without rating2


def test_optional_unmatched_rows_are_null(bsbm_graph):
    g, maps = bsbm_graph
    engine = SparqlEngine(g, maps)
    res = engine.query(BSBM_QUERIES["B9"])
    col = res.variables.index("home")
    nulls = int((res.rows[:, col] < 0).sum())
    assert nulls > 0  # homepages are mostly missing
    for rec in res.decode(maps, limit=5):
        assert "r" in rec


@pytest.mark.parametrize("name", sorted(BSBM_QUERIES))
def test_bsbm_all_run(bsbm_graph, name):
    g, maps = bsbm_graph
    engine = SparqlEngine(g, maps)
    res = engine.query(BSBM_QUERIES[name])
    assert res.count >= 0
    if name not in ("B6",):  # regex may be empty on tiny data
        assert res.count > 0, name


def test_predicate_variable_bindings(bsbm_graph):
    g, maps = bsbm_graph
    engine = SparqlEngine(g, maps)
    res = engine.query(BSBM_QUERIES["B11"])
    assert res.count > 0
    pcol = res.variables.index("prop")
    preds = {maps.dict.predicate(int(maps.elabel_to_pred[e]))
             for e in res.rows[:, pcol] if e >= 0}
    assert "b:product" in preds and "b:price" in preds


def test_table2_constant_vs_increasing_queries():
    """Paper Table 2: constant-solution queries stay byte-constant across
    scale factors; increasing-solution queries grow (the paper's central
    LUBM phenomenology, reproduced by the generator's per-university RNG
    streams + fixed degree pool)."""
    from repro.rdf.generator import generate_lubm
    from repro.rdf.transform import type_aware_transform
    from repro.rdf.workloads import LUBM_CONSTANT, LUBM_INCREASING

    counts = {}
    for scale in (1, 3):
        st = generate_lubm(scale=scale, seed=0, density=0.4)
        st.finalize()
        g, m = type_aware_transform(st)
        engine = SparqlEngine(g, m)
        for name in LUBM_CONSTANT + LUBM_INCREASING:
            counts.setdefault(name, {})[scale] = engine.count(
                LUBM_QUERIES[name])
    for name in LUBM_CONSTANT:
        assert counts[name][1] == counts[name][3], (name, counts[name])
    for name in LUBM_INCREASING:
        assert counts[name][3] > counts[name][1], (name, counts[name])


def test_direct_with_inference_matches_type_aware():
    """Paper protocol: direct transformation over original + INFERRED
    triples answers subsumption queries identically to the type-aware
    transformation (which performs the closure natively)."""
    from repro.rdf.generator import generate_lubm
    from repro.rdf.transform import (direct_transform,
                                     materialize_inferred_types,
                                     type_aware_transform)

    st = generate_lubm(scale=1, seed=0, density=0.4)
    st.finalize()
    g_t, m_t = type_aware_transform(st)
    g_d, m_d = direct_transform(materialize_inferred_types(st))
    e_t = SparqlEngine(g_t, m_t)
    e_d = SparqlEngine(g_d, m_d)
    for name in ("Q2", "Q5", "Q6", "Q9", "Q13", "Q14"):
        assert e_t.count(LUBM_QUERIES[name]) == e_d.count(LUBM_QUERIES[name]), name


# --------------------------------------------------------------------------
# solution modifiers: DISTINCT / LIMIT / OFFSET
# --------------------------------------------------------------------------


def test_parse_modifiers():
    q = parse_sparql("SELECT DISTINCT ?x WHERE { ?x rdf:type ub:Student . } "
                     "LIMIT 7 OFFSET 3")
    assert q.distinct and q.limit == 7 and q.offset == 3
    assert q.has_modifiers
    q2 = parse_sparql("SELECT ?x WHERE { ?x rdf:type ub:Student . }")
    assert not q2.has_modifiers and q2.limit is None and q2.offset == 0
    with pytest.raises(SparqlError):
        parse_sparql("SELECT ?x WHERE { ?x a ub:S . } LIMIT ?x")


def test_limit_offset_applied(lubm_graph):
    g, maps = lubm_graph
    engine = SparqlEngine(g, maps)
    base = "SELECT ?x ?y WHERE { ?x ub:advisor ?y . }"
    full = engine.query(base)
    assert full.count > 3
    lim = engine.query(base + " LIMIT 3")
    assert lim.count == 3 and lim.rows.shape[0] == 3
    off = engine.query(base + f" OFFSET {full.count - 1}")
    assert off.count == 1
    past = engine.query(base + f" OFFSET {full.count + 5}")
    assert past.count == 0
    both = engine.query(base + " LIMIT 2 OFFSET 1")
    assert both.count == 2
    # count collection honors the modifiers (must materialize internally)
    assert engine.count(base + " LIMIT 3") == 3
    assert engine.count(base) == full.count


def test_distinct_dedupes(lubm_graph):
    g, maps = lubm_graph
    engine = SparqlEngine(g, maps)
    proj = "SELECT ?x WHERE { ?x ub:advisor ?y . }"
    full = engine.query(proj)
    dis = engine.query(proj.replace("SELECT ?x", "SELECT DISTINCT ?x"))
    uniq = np.unique(full.rows, axis=0)
    assert dis.count == uniq.shape[0] <= full.count
    np.testing.assert_array_equal(np.sort(dis.rows, axis=0),
                                  np.sort(uniq, axis=0))


def test_count_bypass_without_modifiers(lubm_graph):
    """collect='count' with no modifier present must keep the executor's
    no-materialization fast path (rows stay empty)."""
    g, maps = lubm_graph
    engine = SparqlEngine(g, maps)
    q = "SELECT ?x ?y WHERE { ?x ub:advisor ?y . }"
    res = engine.query(q, collect="count")
    assert res.count > 0 and res.rows.shape[0] == 0
    # with a modifier the same call materializes to get the answer right
    res_lim = engine.query(q + " LIMIT 1", collect="count")
    assert res_lim.count == 1


def test_modifiers_split_fingerprints(lubm_graph):
    from repro.serve.fingerprint import fingerprint_query

    q = "SELECT ?x WHERE { ?x rdf:type ub:Student . }"
    fps = {fingerprint_query(q), fingerprint_query(q + " LIMIT 5"),
           fingerprint_query(q + " LIMIT 6"),
           fingerprint_query(q + " OFFSET 5"),
           fingerprint_query(q.replace("SELECT", "SELECT DISTINCT"))}
    assert len(fps) == 5
