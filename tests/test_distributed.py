"""Multi-device behavior, via subprocesses with forced host device counts
(jax pins the device count at first init, so each scenario gets its own
interpreter).  Covers: sharded engine == host engine, DP+TP train step ==
single-device step, pipeline-parallel loss/grads == dense loss/grads,
elastic checkpoint restore across mesh shapes, and the GreedyChunker."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Both xfails below are deterministic jax 0.4.x lowering artifacts (see each
# marker's reason).  Conditioning on the exact version line + strict=True
# means they must fail on 0.4.x and must pass the moment the image moves to
# jax>=0.5 — a rotted marker shows up as XPASS-strict instead of hiding.
_JAX_04 = __import__("jax").__version__.startswith("0.4.")


def run_script(body: str, devices: int = 8, timeout: int = 420) -> str:
    script = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={devices}'\n"
        "import repro.utils.compat\n"  # jax.shard_map/set_mesh on old jax
        + textwrap.dedent(body)
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    return proc.stdout


def test_sharded_engine_matches_host():
    out = run_script("""
        import jax, numpy as np
        from repro.rdf.generator import generate_lubm
        from repro.rdf.transform import type_aware_transform
        from repro.rdf.sparql import parse_sparql
        from repro.rdf.workloads import LUBM_QUERIES
        from repro.core import ExecOpts, Executor, build_plan, build_query_graph
        from repro.core.distributed import run_sharded

        st = generate_lubm(scale=1, seed=0, density=0.3); st.finalize()
        g, maps = type_aware_transform(st)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        ex = Executor(g, ExecOpts())
        for name in ("Q2", "Q9", "Q6"):
            ast = parse_sparql(LUBM_QUERIES[name])
            q = build_query_graph(ast.where.triples, maps)
            plan = build_plan(g, q)
            host = ex.run(plan, collect="count").count
            if not plan.steps:
                print(f"{name} point {host}"); continue
            dist = run_sharded(ex, plan, mesh)
            print(f"{name} host={host} dist={dist}")
            assert host == dist, (name, host, dist)
        print("ENGINE_OK")
    """)
    assert "ENGINE_OK" in out


@pytest.mark.xfail(
    reason="jax 0.4.x GSPMD divergence in the DP+TP step: measured loss "
           "6.1623 (single-device) vs 6.1985 (2x4 mesh) on jax 0.4.37 — a "
           "0.59% relative gap, 36x the 1e-3 tolerance, with "
           "compute_dtype=float32, so this is a real lowering difference "
           "and not reduction-order noise; do NOT widen the tolerance to "
           "mask it.  Passes on jax>=0.5.",
    condition=_JAX_04, strict=True)
def test_dp_tp_train_step_matches_single_device():
    out = run_script("""
        import dataclasses, jax, numpy as np, jax.numpy as jnp
        from repro.configs import get_arch
        from repro.launch.cells import _named
        from repro.models import transformer
        from repro.sharding.specs import batch_specs, opt_state_specs, param_specs
        from repro.train.optimizer import OptConfig, adamw_init
        from repro.train.trainstep import make_train_step

        arch = get_arch("qwen3-8b")
        cfg, batch = arch.smoke()
        cfg = dataclasses.replace(cfg, compute_dtype="float32")
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        opt_cfg = OptConfig(lr=1e-3, warmup_steps=1)
        opt = adamw_init(params, opt_cfg)
        step = make_train_step(transformer.loss_fn, cfg, opt_cfg)
        p1, o1, m1 = jax.jit(step)(params, opt, batch)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        pspecs = param_specs(jax.eval_shape(lambda: params), "lm", mesh)
        psh = _named(mesh, pspecs)
        osh = _named(mesh, opt_state_specs(pspecs, opt))
        bsh = _named(mesh, batch_specs("lm", "train",
                                       jax.eval_shape(lambda: batch), mesh))
        with jax.set_mesh(mesh):
            sharded = jax.jit(step, in_shardings=(psh, osh, bsh),
                              out_shardings=(psh, osh, None))
            p2, o2, m2 = sharded(jax.device_put(params, psh),
                                 jax.device_put(opt, osh),
                                 jax.device_put(batch, bsh))
        print("loss", float(m1["loss"]), float(m2["loss"]))
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-3, atol=2e-3)
        print("DPTP_OK")
    """)
    assert "DPTP_OK" in out


@pytest.mark.xfail(
    reason="jax 0.4.x shard_map rep-check: pipelined_loss returns a "
           "replicated P() scalar that only check_vma=False (jax>=0.5) can "
           "express; the 0.4.x compat shim (utils/compat.py) must run "
           "checked, so _SpecError fires at trace time "
           "(ShapedArray(float32[]) fails rep inference).  No cheap 0.4.x "
           "workaround: it would need pipelined_loss to prove replication "
           "via an explicit collective on every output.  Needs jax>=0.5.",
    condition=_JAX_04, strict=True)
def test_pipeline_parallel_matches_dense():
    out = run_script("""
        import dataclasses, jax, numpy as np, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_arch
        from repro.models import transformer
        from repro.sharding.pipeline import pipelined_loss

        arch = get_arch("qwen3-8b")
        cfg, batch = arch.smoke()
        cfg = dataclasses.replace(cfg, compute_dtype="float32", n_layers=4,
                                  remat=False)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)

        dense_loss = transformer.loss_fn(params, batch, cfg)

        mesh = jax.make_mesh((4,), ("pod",))
        n_stages, n_mb = 4, 2
        pspec = jax.tree.map(lambda _: P(), params)
        pspec["dense_layers"] = jax.tree.map(lambda _: P("pod"),
                                             params["dense_layers"])
        bspec = {"tokens": P(), "labels": P()}

        def loss_fn(p, b):
            return pipelined_loss(p, b, cfg, n_stages=n_stages,
                                  n_microbatches=n_mb)

        with jax.set_mesh(mesh):
            sm = jax.shard_map(loss_fn, mesh=mesh, in_specs=(pspec, bspec),
                               out_specs=P(), check_vma=False)
            pl_loss = jax.jit(sm)(params, batch)
            g_dense = jax.grad(lambda p: transformer.loss_fn(p, batch, cfg))(params)
            g_pipe = jax.jit(jax.grad(lambda p: sm(p, batch)))(params)
        print("dense", float(dense_loss), "pipe", float(pl_loss))
        assert abs(float(dense_loss) - float(pl_loss)) < 2e-3
        for a, b in zip(jax.tree.leaves(g_dense), jax.tree.leaves(g_pipe)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=5e-2, atol=5e-3)
        print("PIPE_OK")
    """)
    assert "PIPE_OK" in out


def test_elastic_checkpoint_restore_new_mesh(tmp_path):
    out = run_script(f"""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train.checkpoint import Checkpointer

        params = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        mesh1 = jax.make_mesh((4, 2), ("data", "model"))
        sh1 = {{"w": NamedSharding(mesh1, P("data", "model"))}}
        p1 = jax.device_put(params, sh1)
        ck = Checkpointer(r"{tmp_path}", keep=2)
        ck.save(7, {{"params": p1}})

        mesh2 = jax.make_mesh((2, 2), ("data", "model"))  # topology changed
        sh2 = {{"w": NamedSharding(mesh2, P("model", "data"))}}
        step, trees, _ = ck.restore({{"params": params}},
                                    shardings={{"params": sh2}})
        assert step == 7
        np.testing.assert_array_equal(np.asarray(trees["params"]["w"]),
                                      np.asarray(params["w"]))
        assert trees["params"]["w"].sharding.mesh.shape["data"] == 2
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


def test_greedy_chunker_balance():
    from repro.core.distributed import GreedyChunker

    rng = np.random.default_rng(0)
    degree = rng.zipf(1.5, 1000).astype(np.int64)
    cands = np.arange(1000, dtype=np.int32)
    chunks, counts, loads = GreedyChunker(8).partition(cands, degree)
    assert chunks.shape[0] == 8
    assert counts.sum() == 1000
    # LPT guarantee: makespan ≤ max(heaviest single item, 4/3 × ideal)
    est = degree[cands].astype(np.float64) + 1.0
    ideal = est.sum() / 8
    assert loads.max() <= max(est.max(), ideal * 4 / 3) + 1e-9
    # every candidate appears exactly once
    got = np.sort(chunks[chunks >= 0])
    np.testing.assert_array_equal(got, cands)


def test_gnn_spmd_matches_single_device():
    """Explicit-SPMD GNN gradients (shard_map profile) == single-device
    gradients, for all four archs (sum/mean/max/min/std aggregators)."""
    out = run_script("""
        import dataclasses, jax, numpy as np, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_arch
        from repro.sharding.gnn_spmd import (SHARDED_FIELDS, pad_gnn_batch,
                                             n_shards_of, mesh_axes)
        from repro.models.gnn import dimenet, gcn, meshgraphnet, pna

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        mods = {"gcn-cora": gcn, "pna": pna, "meshgraphnet": meshgraphnet,
                "dimenet": dimenet}
        tols = {"gcn-cora": 1e-4, "meshgraphnet": 1e-4, "dimenet": 1e-4,
                "pna": 1e-3}
        for name, mod in mods.items():
            arch = get_arch(name)
            cfg, batch = arch.smoke()
            params = mod.init_params(jax.random.PRNGKey(0), cfg)
            g_true = jax.grad(lambda p: mod.loss_fn(p, batch, cfg))(params)
            n_seg = batch["edge_src"].shape[0] if name == "dimenet" \\
                else batch["x"].shape[0]
            ns = n_shards_of(mesh)
            pb = pad_gnn_batch(name, {k: np.asarray(v)
                                      for k, v in batch.items()}, ns, n_seg)
            pb = {k: jnp.asarray(v) for k, v in pb.items()}
            cfg2 = dataclasses.replace(cfg, spmd_axes=mesh_axes(mesh),
                                       spmd_shards=ns)
            def local(p, b, cfg2=cfg2):
                g = jax.grad(lambda pp: mod.loss_fn(pp, b, cfg2))(p)
                return jax.lax.pmean(g, mesh_axes(mesh))
            sharded = set(SHARDED_FIELDS[name])
            bspec = {k: P(mesh_axes(mesh)) if k in sharded else P()
                     for k in pb}
            sm = jax.shard_map(local, mesh=mesh,
                               in_specs=(jax.tree.map(lambda _: P(), params),
                                         bspec),
                               out_specs=jax.tree.map(lambda _: P(), params),
                               check_vma=False)
            with jax.set_mesh(mesh):
                g2 = jax.jit(sm)(params, pb)
            rel = max(float(jnp.linalg.norm(a - b))
                      / (float(jnp.linalg.norm(a)) + 1e-12)
                      for a, b in zip(jax.tree.leaves(g_true),
                                      jax.tree.leaves(g2)))
            print(name, "rel", rel)
            assert rel < tols[name], (name, rel)
        print("GNN_SPMD_OK")
    """, timeout=420)
    assert "GNN_SPMD_OK" in out


def test_dimenet_edge_sharded_matches_single_device():
    """DimeNet v2 (edge-sharded, §Perf 4.2 iter 2): loss and gradients match
    the single-device forward exactly (all_gather transposes to
    reduce-scatter, so AD through the exchange is exact)."""
    out = run_script("""
        import dataclasses, jax, numpy as np, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_arch
        from repro.models.gnn import dimenet

        NS = 4
        mesh = jax.make_mesh((NS,), ("data",))
        arch = get_arch("dimenet")
        cfg, batch = arch.smoke()
        params = dimenet.init_params(jax.random.PRNGKey(0), cfg)
        g_true = jax.grad(lambda p: dimenet.loss_fn(p, batch, cfg))(params)
        l_true = dimenet.loss_fn(params, batch, cfg)

        b = {k: np.asarray(v) for k, v in batch.items()}
        E = b["edge_src"].shape[0]
        E_pad = ((E + NS - 1) // NS) * NS
        n = b["pos"].shape[0]
        esrc = np.pad(b["edge_src"], (0, E_pad - E))
        edst = np.pad(b["edge_dst"], (0, E_pad - E), constant_values=n)
        e_l = E_pad // NS
        t_kj, t_ji = b["t_kj"], b["t_ji"]
        shard_of = t_ji // e_l
        T_pad = max(np.bincount(shard_of, minlength=NS).max(), 1)
        tkj_sh = np.zeros((NS, T_pad), np.int32)
        tji_sh = np.full((NS, T_pad), e_l, np.int32)
        for s in range(NS):
            sel = shard_of == s
            k = sel.sum()
            tkj_sh[s, :k] = t_kj[sel]
            tji_sh[s, :k] = t_ji[sel] - s * e_l
        sb = dict(b)
        sb["edge_src"], sb["edge_dst"] = esrc, edst
        sb["t_kj"], sb["t_ji"] = tkj_sh.reshape(-1), tji_sh.reshape(-1)
        sb = {k: jnp.asarray(v) for k, v in sb.items()}

        cfg2 = dataclasses.replace(cfg, spmd_axes=("data",), spmd_shards=NS,
                                   edge_sharded=True)
        def local(p, bb):
            l = dimenet.loss_fn(p, bb, cfg2)
            g = jax.grad(lambda pp: dimenet.loss_fn(pp, bb, cfg2))(p)
            return jax.lax.pmean(l, ("data",)), jax.lax.pmean(g, ("data",))
        bspec = {k: P("data") if k in ("edge_src", "edge_dst", "t_kj",
                                       "t_ji") else P() for k in sb}
        sm = jax.shard_map(local, mesh=mesh,
                           in_specs=(jax.tree.map(lambda _: P(), params),
                                     bspec),
                           out_specs=(P(), jax.tree.map(lambda _: P(),
                                                        params)),
                           check_vma=False)
        with jax.set_mesh(mesh):
            l2, g2 = jax.jit(sm)(params, sb)
        assert abs(float(l_true) - float(l2)) < 1e-5
        rel = max(float(jnp.linalg.norm(a - bb))
                  / (float(jnp.linalg.norm(a)) + 1e-12)
                  for a, bb in zip(jax.tree.leaves(g_true),
                                   jax.tree.leaves(g2)))
        assert rel < 1e-4, rel
        print("DIMENET_V2_OK")
    """)
    assert "DIMENET_V2_OK" in out
