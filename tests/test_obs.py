"""Observability tests: trace span trees (incl. a hypothesis nesting
property), Chrome export, slow-query log bounds/eviction, labeled metrics +
Prometheus exposition-format validity, roofline kernel cost models, the
benchmark regression gate, and end-to-end forced tracing through the
engine, the scheduler, and the HTTP debug endpoints."""

import json
import re
import threading
import urllib.error
import urllib.request
from urllib.parse import urlencode

import pytest

from benchmarks import check
from conftest import given, settings, st
from repro.analysis.roofline import (KERNEL_MODELS, estimate_step_ms,
                                     kernel_cost)
from repro.core import SparqlEngine
from repro.obs import SlowQueryLog, Trace, chrome_trace
from repro.rdf.workloads import LUBM_QUERIES
from repro.serve.cache import PlanCache, ResultCache
from repro.serve.metrics import (FINE_BUCKETS_S, LabeledGauge,
                                 LabeledHistogram, MetricsRegistry,
                                 ServeMetrics)
from repro.serve.scheduler import Scheduler
from repro.serve.server import (DatasetRegistry, make_server,
                                serve_in_thread)


# ------------------------------------------------------------------ traces
def test_trace_nesting_and_find():
    t = Trace("q", profile_steps=True)
    with t.span("execute"):
        with t.span("branch", index=0):
            t.add("step", 0.001, step=0, kernel="ragged_expand")
            t.add("step", 0.002, step=1, kernel="expand_filter")
        t.event("plan_cache", hit=True)
    t.finish()
    assert [c.name for c in t.root.children] == ["execute"]
    branch = t.find("branch")[0]
    assert [c.name for c in branch.children] == ["step", "step"]
    assert branch.meta["index"] == 0
    assert t.find("plan_cache")[0].meta["hit"] is True
    assert len(t.find("step")) == 2
    d = t.to_dict()
    assert d["profiled"] and not d["sampled"]
    assert d["dur_ms"] >= d["root"]["children"][0]["dur_ms"] > 0


def test_trace_finish_is_idempotent_and_closes_stack():
    t = Trace()
    cm = t.span("left_open")
    cm.__enter__()  # deliberately never exited
    t.finish()
    first = t.dur_ms
    assert len(t._stack) == 1  # stack tail cleared down to the root
    t.finish()
    assert t.dur_ms >= first


@given(st.recursive(st.just([]),
                    lambda ch: st.lists(ch, max_size=3), max_leaves=12))
@settings(max_examples=25, deadline=None)
def test_trace_span_tree_mirrors_nesting(shape):
    t = Trace("prop")

    def build(children):
        for sub in children:
            with t.span("s"):
                build(sub)

    build(shape)
    t.finish()

    def verify(span, children_shape):
        assert len(span.children) == len(children_shape)
        end = span.t0 + span.dur
        prev_t0 = span.t0
        for child, sub in zip(span.children, children_shape):
            # siblings open in order; children lie within the parent
            assert child.t0 >= prev_t0 - 1e-9
            assert child.t0 + child.dur <= end + 1e-6
            prev_t0 = child.t0
            verify(child, sub)

    verify(t.root, shape)
    # top-level spans are disjoint, so they can't sum past the wall time
    assert t.span_sum_ms() <= t.dur_ms + 1e-3


def test_chrome_trace_export():
    t = Trace("q")
    with t.span("execute", branches=1):
        t.add("step", 0.001, kernel="ragged_expand")
    t.finish()
    doc = chrome_trace(t)
    names = [e["name"] for e in doc["traceEvents"]]
    assert "thread_name" in names and "execute" in names and "step" in names
    ex = next(e for e in doc["traceEvents"] if e["name"] == "execute")
    assert ex["ph"] == "X" and ex["args"]["branches"] == 1
    step = next(e for e in doc["traceEvents"] if e["name"] == "step")
    assert step["dur"] == 1000  # 0.001s in microseconds
    text = chrome_trace([t], as_text=True)
    assert json.loads(text)["displayTimeUnit"] == "ms"


def _fake_trace(name="query"):
    t = Trace(name, profile_steps=True)
    with t.span("execute"):
        pass
    return t.finish()


# ---------------------------------------------------------- slow-query log
def test_slowlog_keeps_worst_per_fingerprint():
    log = SlowQueryLog(capacity=4)
    t1, t2 = _fake_trace(), _fake_trace()
    assert log.record("fpA", 10.0, t1)
    assert not log.record("fpA", 5.0, _fake_trace())   # faster: ignored
    assert log.record("fpA", 50.0, t2)                  # slower: replaces
    assert len(log) == 1
    assert log.entries()[0]["wall_ms"] == 50.0
    assert log.get(t2.trace_id) is not None
    assert log.get(t1.trace_id) is None  # replaced entry is gone


def test_slowlog_bounded_evicts_fastest():
    log = SlowQueryLog(capacity=3)
    for i, ms in enumerate([30.0, 10.0, 20.0]):
        assert log.record(f"fp{i}", ms, _fake_trace())
    assert not log.record("fp_new", 5.0, _fake_trace())  # faster than all
    assert log.record("fp_new", 25.0, _fake_trace())     # evicts the 10ms
    assert len(log) == 3
    walls = [e["wall_ms"] for e in log.entries()]
    assert walls == [30.0, 25.0, 20.0]  # slowest first, 10ms gone


def test_slowlog_disabled_and_render():
    assert not SlowQueryLog(capacity=0).record("fp", 99.0, _fake_trace())
    log = SlowQueryLog(capacity=2)
    t = _fake_trace()
    log.record("fp", 7.0, t, dataset="lubm", count=3,
               explain={"order": ["u0"]})
    (entry,) = log.entries()
    digest = log.summaries()[0]
    assert digest["count"] == 3 and "explain" not in digest
    full = SlowQueryLog.render_entry(entry)
    assert full["trace"]["id"] == t.trace_id
    assert full["explain"] == {"order": ["u0"]}
    chrome = SlowQueryLog.render_entry(entry, fmt="chrome")
    assert "traceEvents" in chrome


# ----------------------------------------------------------------- metrics
def test_labeled_histogram_and_gauge_render():
    h = LabeledHistogram("x_seconds", "spans", label="span",
                         buckets=FINE_BUCKETS_S)
    h.observe("compile", 0.5)
    h.observe("compile", 2e-6)
    h.observe("dispatch", 1e-3)
    lines = h.render()
    assert '# TYPE x_seconds histogram' in lines
    assert any('span="compile"' in ln and 'le="+Inf"' in ln and
               ln.endswith(" 2") for ln in lines)
    assert 'x_seconds_count{span="dispatch"} 1' in lines
    g = LabeledGauge("x_inflight", "per dataset", label="dataset")
    g.inc("lubm")
    g.inc("lubm")
    g.dec("lubm")
    g.set("bsbm", 5)
    assert g.value("lubm") == 1.0
    assert 'x_inflight{dataset="bsbm"} 5' in g.render()


def test_fine_buckets_ladder():
    assert list(FINE_BUCKETS_S) == sorted(FINE_BUCKETS_S)
    assert FINE_BUCKETS_S[0] == 1e-6
    assert FINE_BUCKETS_S[-1] == float("inf")
    assert 10.0 in FINE_BUCKETS_S


# grammar of the Prometheus text exposition format (v0.0.4, subset we emit)
_HELP_RE = re.compile(r"^# HELP [a-zA-Z_:][a-zA-Z0-9_:]* .*$")
_TYPE_RE = re.compile(
    r"^# TYPE ([a-zA-Z_:][a-zA-Z0-9_:]*) (counter|gauge|histogram)$")
_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"
    r'(\{[a-zA-Z_][a-zA-Z0-9_]*="[^"\\]*"'
    r'(,[a-zA-Z_][a-zA-Z0-9_]*="[^"\\]*")*\})?'
    r" (NaN|[-+]?Inf|[-+]?[0-9]*\.?[0-9]+([eE][-+]?[0-9]+)?)$")


def test_prometheus_exposition_validity():
    m = ServeMetrics(MetricsRegistry())
    m.record("lubm", "ok", 12.5)
    m.record_plan_search(3.0)
    m.record_cardinality(10.0, 12)
    m.compile_events.inc(2)
    m.span_seconds.observe("execute", 0.01)
    m.dataset_inflight.inc("lubm")
    m.record_trace(_fake_trace())
    m.attach_cache_gauges("lubm", PlanCache(4), ResultCache(4))
    text = m.registry.render()
    assert text.endswith("\n")
    typed = set()
    for ln in text.splitlines():
        if ln.startswith("# HELP"):
            assert _HELP_RE.match(ln), ln
        elif ln.startswith("# TYPE"):
            mt = _TYPE_RE.match(ln)
            assert mt, ln
            typed.add(mt.group(1))
        else:
            ms = _SAMPLE_RE.match(ln)
            assert ms, f"invalid sample line: {ln!r}"
            base = re.sub(r"_(bucket|sum|count)$", "", ms.group(1))
            assert base in typed or ms.group(1) in typed, ln
    # the new series exist alongside the original names
    for name in ("repro_requests_total", "repro_span_seconds_bucket",
                 "repro_compile_events_total", "repro_traces_total",
                 "repro_dataset_inflight_queries",
                 "repro_plan_cache_hit_ratio_lubm"):
        assert name in text, name


def test_histogram_buckets_cumulative_in_render():
    m = ServeMetrics(MetricsRegistry())
    for s in (1e-6, 1e-3, 1e-3, 0.2, 5.0):
        m.span_seconds.observe("execute", s)
    lines = [ln for ln in m.registry.render().splitlines()
             if ln.startswith("repro_span_seconds_bucket")]
    counts = [int(ln.rsplit(" ", 1)[1]) for ln in lines]
    assert counts == sorted(counts)  # cumulative
    assert counts[-1] == 5           # +Inf bucket sees everything


# ---------------------------------------------------------------- roofline
def test_kernel_cost_models_cover_all_kernels():
    for kernel in KERNEL_MODELS:
        cost = kernel_cost(kernel, expanded=1e4, rows=1e3, capacity=2048,
                           nq=4, bitmap_words=2, n_iters=16)
        assert cost["flops"] > 0 and cost["bytes"] > 0, kernel
    with pytest.raises(ValueError):
        kernel_cost("not_a_kernel", expanded=1.0)


def test_estimate_step_ms_is_roofline():
    est = estimate_step_ms("ragged_expand", backend="cpu",
                           expanded=1e6, rows=1e4, capacity=4096)
    assert est["model_ms"] > 0
    assert est["dominant"] in ("compute", "memory")
    # cpu peaks are far below tpu peaks: same work must cost more time
    tpu = estimate_step_ms("ragged_expand", backend="tpu",
                           expanded=1e6, rows=1e4, capacity=4096)
    assert est["model_ms"] > tpu["model_ms"]


# ---------------------------------------------------------- regression gate
_EXEC_BASE = {"lubm.Q2": {"count": 10, "speedup": 2.0,
                          "legacy_us": 100.0, "pipelined_us": 50.0}}


def test_check_exec_count_mismatch_is_regression():
    fresh = {"lubm.Q2": {**_EXEC_BASE["lubm.Q2"], "count": 11}}
    bad = check.compare("exec", _EXEC_BASE, fresh)
    assert bad and "correctness" in bad[0]


def test_check_exec_speedup_regression_and_tolerance():
    ok = {"lubm.Q2": {**_EXEC_BASE["lubm.Q2"], "speedup": 1.9}}
    assert check.compare("exec", _EXEC_BASE, ok) == []
    slow = {"lubm.Q2": {**_EXEC_BASE["lubm.Q2"], "speedup": 1.0}}
    assert check.compare("exec", _EXEC_BASE, slow)
    # faster-than-baseline never fails the gate
    fast = {"lubm.Q2": {**_EXEC_BASE["lubm.Q2"], "speedup": 4.0}}
    assert check.compare("exec", _EXEC_BASE, fast) == []


def test_check_exec_missing_query_is_regression():
    assert check.compare("exec", _EXEC_BASE, {})


def test_check_store_speedup_ratio():
    # store's floor is widened to 60% (quick-scale ingest ratios are
    # noisy) — a halved ratio passes, an order-of-magnitude loss gates
    base = {"speedup_ingest": 20.0, "speedup_wall": 1.1}
    assert check.compare("update", base,
                         {"speedup_ingest": 10.0, "speedup_wall": 1.1}) == []
    bad = check.compare("update", base,
                        {"speedup_ingest": 2.0, "speedup_wall": 1.1})
    assert bad and "speedup_ingest" in bad[0]


def test_check_planner_counts():
    base = {"lubm.dp.Q1": {"count": 4, "us_per_call": 10.0}}
    assert check.compare("planner", base,
                         {"lubm.dp.Q1": {"count": 4}}) == []
    assert check.compare("planner", base, {"lubm.dp.Q1": {"count": 5}})


def test_check_unknown_suite_passes():
    assert check.compare("kernels", {"a": 1}, {"a": 2}) == []


# ------------------------------------------------------------- end to end
def test_engine_forced_trace_spans_account_for_wall(lubm_graph):
    g, maps = lubm_graph
    engine = SparqlEngine(g, maps)
    plain = engine.query(LUBM_QUERIES["Q2"])
    res = engine.query(LUBM_QUERIES["Q2"], trace=True)
    assert res.count == plain.count  # tracing must not change answers
    t = res.stats["trace_obj"]
    d = res.stats["trace"]
    names = {s["name"] for s in _walk(d["root"])}
    assert {"parse", "fingerprint", "plan_cache", "execute",
            "branch", "step"} <= names
    # dispatch or compile depending on jit-cache state; one must exist
    assert names & {"compile", "dispatch"}
    steps = t.find("step")
    assert steps and all("kernel" in s.meta for s in steps)
    # the span tree accounts for the end-to-end wall time (20% tolerance)
    assert d["span_sum_ms"] >= 0.8 * d["dur_ms"]
    # second traced run: plan cache hit, no fresh compiles
    res2 = engine.query(LUBM_QUERIES["Q2"], trace=True)
    t2 = res2.stats["trace_obj"]
    assert t2.find("plan_cache")[0].meta["hit"] is True
    assert not t2.find("compile")
    assert t2.find("dispatch")


def _walk(span_dict):
    yield span_dict
    for c in span_dict.get("children", ()):
        yield from _walk(c)


def test_untraced_query_carries_no_trace(lubm_graph):
    g, maps = lubm_graph
    engine = SparqlEngine(g, maps)
    res = engine.query(LUBM_QUERIES["Q1"])
    assert "trace" not in res.stats


def test_scheduler_forced_trace_executes_and_logs(lubm_graph):
    g, maps = lubm_graph
    registry = DatasetRegistry(ServeMetrics(), result_cache_size=16)
    registry.register("lubm", g, maps)
    with Scheduler(registry, workers=2) as sched:
        r1 = sched.submit("lubm", LUBM_QUERIES["Q1"], trace=True)
        r2 = sched.submit("lubm", LUBM_QUERIES["Q1"], trace=True)
    t1, t2 = r1.stats["trace"], r2.stats["trace"]
    assert t1["id"] != t2["id"]  # no coalescing, each run observed
    assert r1.count == r2.count
    names = {s["name"] for s in _walk(t1["root"])}
    assert {"parse", "fingerprint", "execute"} <= names
    # worst Q1 execution is in the slow log, findable by trace id
    ds = registry.get("lubm")
    assert len(ds.slow_log) == 1
    entry = ds.slow_log.entries()[0]
    assert entry["id"] in (t1["id"], t2["id"])
    assert registry.find_trace(entry["id"]) is entry
    assert "order" in entry["explain"]["branches"][0]
    # traced runs bypass the result cache: nothing was stored
    assert ds.result_cache.stats.inserts == 0
    # span histograms + trace counter fed
    assert registry.metrics.traces.value(mode="forced") == 2
    assert registry.metrics.dataset_inflight.value("lubm") == 0


def test_registry_trace_sampling(lubm_graph):
    g, maps = lubm_graph
    registry = DatasetRegistry(ServeMetrics(), trace_sample=1.0)
    registry.register("lubm", g, maps)
    res = registry.execute("lubm", LUBM_QUERIES["Q1"])
    ds = registry.get("lubm")
    assert len(ds.slow_log) == 1
    assert registry.metrics.traces.value(mode="sampled") == 1
    # sampled traces keep the fast path: no per-step profiling
    assert ds.slow_log.entries()[0]["trace"].profile_steps is False
    assert res.count == registry.get("lubm").slow_log.entries()[0]["count"]


@pytest.fixture(scope="module")
def obs_http_service(lubm_graph):
    g, maps = lubm_graph
    registry = DatasetRegistry(ServeMetrics())
    registry.register("lubm", g, maps)
    server = make_server(registry, port=0, workers=2,
                         default_timeout_s=60.0)
    serve_in_thread(server)
    yield server
    server.shutdown()
    server.scheduler.stop()


def _get(server, path, **params):
    host, port = server.server_address[:2]
    url = f"http://{host}:{port}{path}"
    if params:
        url += "?" + urlencode(params)
    with urllib.request.urlopen(url, timeout=60) as r:
        return json.loads(r.read())


def test_http_trace_roundtrip_and_debug_endpoints(obs_http_service):
    server = obs_http_service
    plain = _get(server, "/sparql", query=LUBM_QUERIES["Q2"])
    out = _get(server, "/sparql", query=LUBM_QUERIES["Q2"], trace=1)
    assert out["stats"]["count"] == plain["stats"]["count"]
    tr = out["trace"]
    names = {s["name"] for s in _walk(tr["root"])}
    assert {"parse", "fingerprint", "execute", "branch", "step"} <= names
    assert tr["span_sum_ms"] >= 0.8 * tr["dur_ms"]

    slow = _get(server, "/debug/slow")["slow"]
    assert any(e["id"] == tr["id"] for e in slow["lubm"])

    full = _get(server, "/debug/trace", id=tr["id"])
    assert full["trace"]["id"] == tr["id"]
    assert "explain" in full and full["dataset"] == "lubm"

    chrome = _get(server, "/debug/trace", id=tr["id"], format="chrome")
    assert any(e["name"] == "step" for e in chrome["traceEvents"])

    # span histograms show up on /metrics
    host, port = server.server_address[:2]
    with urllib.request.urlopen(f"http://{host}:{port}/metrics",
                                timeout=60) as r:
        text = r.read().decode()
    assert 'repro_span_seconds_bucket{span="execute"' in text
    assert "repro_dataset_inflight_queries" in text


def test_http_debug_trace_unknown_id_404(obs_http_service):
    server = obs_http_service
    host, port = server.server_address[:2]
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(
            f"http://{host}:{port}/debug/trace?id=999999999", timeout=60)
    assert ei.value.code == 404
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(
            f"http://{host}:{port}/debug/trace", timeout=60)
    assert ei.value.code == 400


def test_http_concurrent_forced_traces_are_distinct(obs_http_service):
    server = obs_http_service
    ids, errors = [], []

    def client():
        try:
            out = _get(server, "/sparql", query=LUBM_QUERIES["Q1"], trace=1)
            ids.append(out["trace"]["id"])
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=client) for _ in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120.0)
    assert not errors
    assert len(set(ids)) == 3  # forced traces never coalesce
