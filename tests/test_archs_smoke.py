"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting finite loss, sane output shapes, and loss decrease
over a few steps for one arch per family."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_arch
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.trainstep import make_train_step


def _init_for(arch, cfg, key):
    if arch.family == "lm":
        from repro.models import transformer

        return transformer.init_params(key, cfg)
    if arch.family == "recsys":
        from repro.models.recsys import dlrm

        return dlrm.init_params(key, cfg)
    mod = _gnn_module(arch.name)
    return mod.init_params(key, cfg)


def _gnn_module(name):
    from repro.models.gnn import dimenet, gcn, meshgraphnet, pna

    return {"dimenet": dimenet, "gcn-cora": gcn, "meshgraphnet": meshgraphnet,
            "pna": pna}[name]


@pytest.mark.parametrize("name", ASSIGNED)
def test_arch_smoke_train_step(name):
    arch = get_arch(name)
    cfg, batch = arch.smoke()
    key = jax.random.PRNGKey(0)
    params = _init_for(arch, cfg, key)
    loss0 = arch.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss0)), f"{name}: non-finite initial loss"

    opt_cfg = OptConfig(lr=1e-3, warmup_steps=1, total_steps=100)
    step = jax.jit(make_train_step(arch.loss_fn, cfg, opt_cfg))
    opt_state = adamw_init(params, opt_cfg)
    params2, opt_state, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0.0


@pytest.mark.parametrize("name", ["qwen3-8b", "gcn-cora", "dlrm-rm2"])
def test_arch_loss_decreases(name):
    arch = get_arch(name)
    cfg, batch = arch.smoke()
    params = _init_for(arch, cfg, jax.random.PRNGKey(1))
    opt_cfg = OptConfig(lr=3e-3, warmup_steps=1, total_steps=1000,
                        schedule="const", weight_decay=0.0)
    step = jax.jit(make_train_step(arch.loss_fn, cfg, opt_cfg))
    opt_state = adamw_init(params, opt_cfg)
    first = None
    loss = None
    for _ in range(12):
        params, opt_state, metrics = step(params, opt_state, batch)
        loss = float(metrics["loss"])
        first = loss if first is None else first
    assert loss < first, f"{name}: loss did not decrease ({first} -> {loss})"


def test_lm_decode_matches_forward():
    """Prefill-then-decode must agree with full forward logits."""
    from repro.models import transformer

    arch = get_arch("qwen3-8b")
    cfg, batch = arch.smoke()
    params = transformer.init_params(jax.random.PRNGKey(2), cfg)
    tokens = batch["tokens"]  # [2, 16]
    logits_full, _ = transformer.forward(params, tokens, cfg)
    cache = transformer.init_cache(cfg, tokens.shape[0], 32)
    # decode token by token
    outs = []
    for t in range(tokens.shape[1]):
        logits, cache = transformer.decode_step(params, cache,
                                                tokens[:, t:t + 1], cfg)
        outs.append(logits[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_full, np.float32), np.asarray(logits_dec, np.float32),
        rtol=0.15, atol=0.15)  # bf16 accumulation-order tolerance


def test_mla_decode_matches_forward():
    """Absorbed MLA decode ≡ full MLA attention."""
    from repro.models import transformer

    arch = get_arch("deepseek-v2-236b")
    cfg, batch = arch.smoke()
    # capacity_factor high enough that no token is ever dropped: capacity
    # dropping legitimately differs between batched prefill and per-token
    # decode, which would mask the MLA-equivalence check
    cfg = dataclasses.replace(
        cfg, compute_dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = transformer.init_params(jax.random.PRNGKey(3), cfg)
    tokens = batch["tokens"][:, :8]
    logits_full, _ = transformer.forward(params, tokens, cfg)
    cache = transformer.init_cache(cfg, tokens.shape[0], 16)
    outs = []
    for t in range(tokens.shape[1]):
        logits, cache = transformer.decode_step(params, cache,
                                                tokens[:, t:t + 1], cfg)
        outs.append(logits[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_full),
                               np.asarray(logits_dec), rtol=2e-3, atol=2e-3)


def test_moe_capacity_and_aux():
    from repro.models.moe import MoEConfig, moe_apply, moe_init

    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16)
    params = moe_init(jax.random.PRNGKey(0), 8, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 8), jnp.float32)
    y, aux = moe_apply(params, x, cfg)
    assert y.shape == (32, 8)
    assert float(aux) > 0.0
    # capacity dropping: with capacity_factor tiny, output norm shrinks
    cfg_tiny = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16,
                         capacity_factor=0.05)
    y2, _ = moe_apply(params, x, cfg_tiny)
    assert float(jnp.linalg.norm(y2)) < float(jnp.linalg.norm(y))


def test_dlrm_retrieval_shape():
    from repro.models.recsys import dlrm

    arch = get_arch("dlrm-rm2")
    cfg, batch = arch.smoke()
    params = dlrm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    rb = {
        "dense": jnp.asarray(rng.normal(size=(1, cfg.n_dense)), jnp.float32),
        "sparse": jnp.asarray(rng.integers(0, 64, (1, cfg.n_sparse,
                                                   cfg.hotness)), jnp.int32),
        "cand": jnp.asarray(rng.normal(size=(1000, cfg.bot_mlp[-1])),
                            jnp.float32),
    }
    scores = dlrm.retrieval_score(params, rb, cfg)
    assert scores.shape == (1000,)
    assert np.isfinite(np.asarray(scores)).all()


def test_neighbor_sampler():
    from repro.models.gnn.sampler import pad_block, sample_blocks

    rng = np.random.default_rng(0)
    n = 200
    deg = rng.integers(0, 10, n)
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(deg, out=indptr[1:])
    nbr = rng.integers(0, n, int(indptr[-1])).astype(np.int32)
    seeds = rng.choice(n, 16, replace=False)
    blk = sample_blocks(indptr, nbr, seeds, [5, 3], rng)
    assert blk["seed_count"] == 16
    assert blk["edge_src"].shape == blk["edge_dst"].shape
    assert blk["edge_src"].shape[0] == 16 * 5 + 16 * 5 * 3
    # all edges reference valid local nodes
    assert blk["edge_src"].max() < len(blk["nodes"])
    padded = pad_block(blk, 1024, 512)
    assert padded["nodes"].shape == (1024,)
    assert padded["edge_src"].shape == (512,)
