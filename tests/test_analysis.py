"""Analysis-layer tests: the scan-count fact the roofline corrects for,
the HLO collective-bytes parser, model-flops sanity, and dry-run artifact
invariants (when present)."""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.model_flops import model_flops
from repro.analysis.roofline import (_combine, _sub, roofline_terms,
                                     to_markdown, xla_cost)
from repro.launch.dryrun import _shape_bytes, collective_bytes

REPO = Path(__file__).resolve().parent.parent


def test_xla_counts_scan_body_once():
    """The premise of the depth-differencing correction: scan trip count is
    invisible to HloCostAnalysis.  If XLA ever fixes this, the roofline
    should switch back to raw costs — this test is the tripwire."""

    def one(x, w):
        return x @ w

    def scanned(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, ws)
        return y

    d = 128
    x = jax.ShapeDtypeStruct((d, d), jnp.float32)
    w = jax.ShapeDtypeStruct((d, d), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, d, d), jnp.float32)
    c1 = xla_cost(jax.jit(one).lower(x, w).compile())["flops"]
    c4 = xla_cost(jax.jit(scanned).lower(x, ws).compile())["flops"]
    assert c4 == pytest.approx(c1, rel=0.01)


def test_shape_bytes():
    assert _shape_bytes("bf16[16,4096]") == 16 * 4096 * 2
    assert _shape_bytes("f32[8]") == 32
    assert _shape_bytes("(f32[4], s32[2])") == 24
    assert _shape_bytes("pred[10]") == 10
    assert _shape_bytes("token[]") == 0


def test_collective_bytes_parser():
    hlo = """
  ENTRY %main {
    %ag = bf16[32,128] all-gather(bf16[2,128] %x), dimensions={0}
    %ar.1 = f32[1024] all-reduce(f32[1024] %y), to_apply=%add
    %rs = f32[64] reduce-scatter(f32[512] %z), dimensions={0}
    %cp = u32[8,2] collective-permute(u32[8,2] %w)
    %norm = f32[4] add(f32[4] %a, f32[4] %b)
  }
    """
    out = collective_bytes(hlo)
    assert out["all-gather"] == 32 * 128 * 2
    assert out["all-reduce"] == 4096
    assert out["reduce-scatter"] == 256
    assert out["collective-permute"] == 64
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_cost_algebra():
    a = {"flops": 10.0, "bytes": 100.0, "coll": {"all-reduce": 8.0}}
    b = {"flops": 4.0, "bytes": 30.0, "coll": {"all-reduce": 2.0,
                                               "all-gather": 1.0}}
    per = _sub(a, b)
    total = _combine(b, per, 3)
    assert total["flops"] == 4 + 3 * 6
    assert total["coll"]["all-gather"] == 1 + 3 * -1  # algebra, not clamped


def test_roofline_terms_dominance():
    t = roofline_terms({"flops": 197e12, "bytes": 0.0, "coll": {}})
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["dominant"] == "compute"
    t = roofline_terms({"flops": 0.0, "bytes": 819e9, "coll": {}})
    assert t["dominant"] == "memory"
    t = roofline_terms({"flops": 0.0, "bytes": 0.0,
                        "coll": {"all-reduce": 50e9}})
    assert t["collective_s"] == pytest.approx(2.0)  # 2× wire factor
    assert t["dominant"] == "collective"


def test_model_flops_orders_of_magnitude():
    # qwen3-8b train_4k: 6 * ~8e9 * 1.05e6 tokens ≈ 5e16
    f = model_flops("qwen3-8b", "train_4k")
    assert 1e16 < f < 3e17
    # decode flops per step ≪ train
    assert model_flops("qwen3-8b", "decode_32k") < f / 1e3
    # MoE active ≪ total: deepseek active ~21B → 6·21e9·1.05e6 ≈ 1.3e17
    f_ds = model_flops("deepseek-v2-236b", "train_4k")
    assert 3e16 < f_ds < 1e18
    # gnn / recsys positive and plausible
    assert 1e9 < model_flops("gcn-cora", "ogb_products") < 1e14
    assert 1e9 < model_flops("dlrm-rm2", "train_batch") < 1e15


def test_markdown_table():
    rows = [{"arch": "a", "cell": "c", "compute_s": 1e-3, "memory_s": 2e-3,
             "collective_s": 0.0, "dominant": "memory", "model_flops": 1e12,
             "useful_ratio": 0.5, "roofline_frac": 0.25}]
    md = to_markdown(rows)
    assert "| a | c |" in md and "memory" in md


@pytest.mark.skipif(not (REPO / "runs/dryrun/single").exists(),
                    reason="dry-run artifacts not generated")
def test_dryrun_artifacts_complete():
    """Every assigned (arch × cell) must have an OK record on BOTH meshes."""
    from repro.configs import ASSIGNED, get_arch

    for mesh in ("single", "multi"):
        d = REPO / "runs/dryrun" / mesh
        for arch_name in ASSIGNED:
            arch = get_arch(arch_name)
            for cell in arch.cells:
                p = d / f"{arch_name}--{cell}.json"
                assert p.exists(), f"missing {mesh}/{arch_name}/{cell}"
                rec = json.loads(p.read_text())
                assert rec["status"] == "ok", \
                    f"{mesh}/{arch_name}/{cell}: {rec.get('error')}"
                assert rec["flops"] > 0
