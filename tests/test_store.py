"""Live-store subsystem tests: SPARQL UPDATE parsing, delta buffer
semantics, snapshot host interface, the core equivalence property
(snapshot == from-scratch rebuild, pre- and post-compaction, on LUBM and
BSBM query shapes), incremental GraphStats maintenance, and serving-layer
update integration."""

import numpy as np
import pytest

from conftest import given, settings, st

from repro.core import SparqlEngine
from repro.rdf.generator import generate_bsbm, generate_lubm
from repro.rdf.graph import LabeledGraph
from repro.rdf.transform import type_aware_transform
from repro.rdf.triples import TripleStore
from repro.rdf.workloads import BSBM_QUERIES, LUBM_QUERIES
from repro.stats import GraphStats, get_stats
from repro.store import (EdgeDelta, UpdateError, VersionedStore, parse_update)
from repro.store.delta import DeltaCOO, base_has_edge


# ---------------------------------------------------------- update parser
def test_parse_update_insert_delete():
    ops = parse_update("""
        PREFIX ub: <http://example.org/univ#>
        INSERT DATA { ub:s1 ub:knows ub:s2 . ub:s1 a ub:Student }
        DELETE DATA { ub:s1 ub:age "25" . }
    """)
    assert [op.action for op in ops] == ["insert", "delete"]
    assert ops[0].triples == [("ub:s1", "ub:knows", "ub:s2"),
                              ("ub:s1", "rdf:type", "ub:Student")]
    assert ops[1].triples == [("ub:s1", "ub:age", '"25"')]


def test_parse_update_iri_normalization_and_numbers():
    ops = parse_update("""INSERT DATA {
        <http://a> <http://www.w3.org/1999/02/22-rdf-syntax-ns#type> <http://C> .
        <http://a> <http://p> 42 . }""")
    assert ops[0].triples[0] == ("http://a", "rdf:type", "http://C")
    assert ops[0].triples[1] == ("http://a", "http://p", '"42"')


def test_parse_update_rejects_bad_input():
    with pytest.raises(UpdateError):
        parse_update("SELECT ?x WHERE { ?x ?p ?o }")
    with pytest.raises(UpdateError):
        parse_update("INSERT DATA { ?x ub:p ub:o }")  # variables are not data
    with pytest.raises(UpdateError):
        parse_update("INSERT { ub:a ub:p ub:o }")  # only INSERT DATA
    with pytest.raises(UpdateError):
        parse_update("")


# ----------------------------------------------------------- delta buffer
def _tiny_graph():
    # 0 --0--> 1, 0 --0--> 2, 1 --1--> 2
    return LabeledGraph.build(
        3, np.array([0, 0, 1]), np.array([0, 0, 1]), np.array([1, 2, 2]),
        2, [(0,), (), (1,)], 2)


def test_edge_delta_state_machine():
    g = _tiny_graph()
    d = EdgeDelta(g)
    assert base_has_edge(g, 0, 0, 1) and not base_has_edge(g, 0, 1, 1)
    assert not d.insert(0, 0, 1)          # already in base: no-op
    assert d.insert(2, 0, 0)              # genuinely new
    assert not d.insert(2, 0, 0)          # duplicate insert: no-op
    assert d.delete(2, 0, 0)              # delete of an insert: un-inserts
    assert not d.inserts and not d.tombs
    assert d.delete(0, 0, 1)              # base edge: tombstone
    assert not d.delete(0, 0, 1)          # already tombstoned
    assert d.insert(0, 0, 1)              # re-insert removes the tombstone
    assert not d.inserts and not d.tombs
    assert not d.delete(1, 0, 2)          # never existed (wrong label)


def test_delta_coo_rows_sorted():
    edges = {(2, 0, 1), (0, 0, 5), (0, 0, 2), (1, 1, 0)}
    coo = DeltaCOO.from_edges(edges, forward=True)
    iptr, nbr = coo.el_rows(0, 8)
    assert list(iptr[:4]) == [0, 2, 2, 3]
    assert list(nbr) == [2, 5, 1]  # per-source runs ascending
    assert coo.max_run() == 2
    iptr1, nbr1 = coo.el_rows(1, 8)
    assert list(nbr1) == [0]


# ------------------------------------------------- snapshot host interface
def test_snapshot_predicate_index_and_candidates():
    g = _tiny_graph()
    store = VersionedStore(g, auto_compact=False)
    v3 = store.add_vertex(labels=(0,))
    store.insert_edges([(v3, 0, 1), (2, 0, 0)])
    store.delete_edges([(0, 0, 1), (0, 0, 2)])  # vertex 0 loses all el-0 out
    snap = store.snapshot()
    subs, objs = snap.predicate_index(0)
    assert list(subs) == [2, 3]           # 0 dropped, 2 and 3 added
    assert list(objs) == [0, 1]           # 2 dropped (both its in-edges died)
    assert list(snap.candidates_with_labels([0])) == [0, 3]
    assert snap.freq([0]) == 2
    assert snap.out.degree[0] == 0 and snap.out.degree[3] == 1
    assert snap.n_edges == g.n_edges  # -2 +2


def test_snapshot_new_elabel():
    g = _tiny_graph()
    store = VersionedStore(g, auto_compact=False)
    store.insert_edges([(0, 5, 1)])  # label space grows to 6
    snap = store.snapshot()
    assert snap.n_elabels == 6
    subs, objs = snap.predicate_index(5)
    assert list(subs) == [0] and list(objs) == [1]


# ----------------------------------------------------- equivalence property
def _split_stream(triples, rng, frac_base=0.75, n_dels=40):
    onto = [t for t in triples if t[1] in ("rdf:type", "rdf:subClassOf")]
    plain = [t for t in triples if t[1] not in ("rdf:type", "rdf:subClassOf")]
    idx = rng.permutation(len(plain))
    n_base = int(len(plain) * frac_base)
    base = onto + [plain[i] for i in idx[:n_base]]
    ins = [plain[i] for i in idx[n_base:]]
    dels = [plain[idx[i]] for i in
            rng.choice(n_base, size=min(n_dels, n_base), replace=False)]
    return base, ins, dels


def _decoded(res, maps):
    return sorted(tuple(sorted((k, v or "") for k, v in r.items()))
                  for r in res.decode(maps))


def _check_equivalence(base, ins, dels, queries, compact):
    st_ = TripleStore()
    st_.add_many(base)
    g, maps = type_aware_transform(st_.finalize())
    store = VersionedStore(g, maps, auto_compact=False)
    get_stats(g)  # force base stats so compaction exercises patch_stats
    store.insert_triples(ins)
    store.delete_triples(dels)
    snap = store.compact() if compact else store.snapshot()
    eng = SparqlEngine(snap, maps)

    final = [t for t in base if t not in set(dels)] + ins
    st2 = TripleStore()
    st2.add_many(final)
    g2, maps2 = type_aware_transform(st2.finalize())
    ref = SparqlEngine(g2, maps2)
    for name, q in queries.items():
        r1, r2 = eng.query(q), ref.query(q)
        assert r1.count == r2.count, (name, r1.count, r2.count)
        assert _decoded(r1, maps) == _decoded(r2, maps2), name
        assert eng.count(q) == r2.count, name  # count path agrees too
    if compact:
        patched = snap.base._graph_stats
        built = GraphStats.build(snap.base)
        for f in ("pred_edges", "pred_subjects", "pred_objects",
                  "fanout_max_out", "fanout_max_in", "label_freq"):
            np.testing.assert_array_equal(getattr(patched, f),
                                          getattr(built, f), err_msg=f)
        np.testing.assert_allclose(patched.fanout_avg_out,
                                   built.fanout_avg_out)
        np.testing.assert_allclose(patched.fanout_avg_in,
                                   built.fanout_avg_in)
        if built.label_cooc is not None:
            np.testing.assert_array_equal(patched.label_cooc,
                                          built.label_cooc)
        assert (patched.n_edges, patched.n_vertices) == \
            (built.n_edges, built.n_vertices)


@pytest.mark.parametrize("seed,compact", [(1, False), (1, True), (7, False)])
def test_lubm_stream_equivalence(seed, compact):
    """Acceptance property: querying base+delta (and the compacted graph)
    is indistinguishable from rebuilding from the merged triple set."""
    full = generate_lubm(scale=1, seed=0, density=0.35).finalize()
    rng = np.random.default_rng(seed)
    base, ins, dels = _split_stream(list(full.iter_decoded()), rng)
    _check_equivalence(base, ins, dels, LUBM_QUERIES, compact)


@pytest.mark.parametrize("compact", [False, True])
def test_bsbm_stream_equivalence(compact):
    """Same property over BSBM shapes (FILTER / OPTIONAL / UNION)."""
    full = generate_bsbm(n_products=120, seed=3).finalize()
    rng = np.random.default_rng(11)
    base, ins, dels = _split_stream(list(full.iter_decoded()), rng,
                                    frac_base=0.8, n_dels=30)
    _check_equivalence(base, ins, dels, BSBM_QUERIES, compact)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=3, deadline=None)
def test_random_stream_equivalence_property(seed):
    full = generate_lubm(scale=1, seed=0, density=0.25).finalize()
    rng = np.random.default_rng(seed)
    base, ins, dels = _split_stream(list(full.iter_decoded()), rng,
                                    frac_base=float(rng.uniform(0.6, 0.9)),
                                    n_dels=int(rng.integers(0, 60)))
    queries = {k: LUBM_QUERIES[k] for k in ("Q1", "Q2", "Q6", "Q9", "Q14")}
    _check_equivalence(base, ins, dels, queries,
                       compact=bool(rng.integers(0, 2)))


# ----------------------------------------------------- store/update layers
def test_update_visibility_and_plan_cache_survival(lubm_graph):
    g, maps = lubm_graph
    store = VersionedStore(g, maps, auto_compact=False)
    eng = SparqlEngine(store.snapshot(), maps)
    q = ("SELECT ?x WHERE { ?x rdf:type ub:GraduateStudent . "
         "?x ub:takesCourse ?c . }")
    c0 = eng.query(q).count
    store.apply_update("""INSERT DATA {
        ub:Zed rdf:type ub:GraduateStudent .
        ub:Zed ub:takesCourse ub:CourseZ . }""")
    eng.set_graph(store.snapshot())
    assert eng.query(q).count == c0 + 1
    # same compiled plan object served both versions
    assert eng.plan_cache.stats.misses == 1 and eng.plan_cache.stats.hits >= 1
    # decode sees the interned terms
    res = eng.query("SELECT ?c WHERE { ub:Zed ub:takesCourse ?c . }")
    assert [r["c"] for r in res.decode(maps)] == ["ub:CourseZ"]
    store.apply_update("DELETE DATA { ub:Zed ub:takesCourse ub:CourseZ . }")
    eng.set_graph(store.snapshot())
    assert eng.query(q).count == c0


def test_type_insert_grows_labels_and_retraction_rejected(lubm_graph):
    g, maps = lubm_graph
    store = VersionedStore(g, maps, auto_compact=False)
    # GraduateStudent is a subclass of Student in the generator's ontology:
    # closure labels must appear on an existing, previously unlabeled vertex
    eng = SparqlEngine(store.snapshot(), maps)
    q_student = "SELECT ?x WHERE { ?x rdf:type ub:Student . }"
    c0 = eng.count(q_student)
    store.insert_triples([("ub:Brand-New", "rdf:type", "ub:GraduateStudent")])
    eng.set_graph(store.snapshot())
    assert eng.count(q_student) == c0 + 1
    with pytest.raises(UpdateError):
        store.delete_triples([("ub:Brand-New", "rdf:type",
                               "ub:GraduateStudent")])
    with pytest.raises(UpdateError):
        store.insert_triples([("ub:X", "rdf:type", "ub:NoSuchClass")])


def test_failed_batch_applies_nothing(lubm_graph):
    """Regression: a rejected batch/update must not leave a half-applied
    prefix in the delta (it would leak into the next successful update)."""
    g, maps = lubm_graph
    store = VersionedStore(g, maps, auto_compact=False)
    v0, d0 = store.version, store.delta_size()
    with pytest.raises(UpdateError):
        store.insert_triples([
            ("ub:LeakS", "ub:advisor", "ub:LeakO"),          # valid
            ("ub:LeakS", "rdf:type", "ub:NoSuchClass"),      # rejected
        ])
    assert store.version == v0 and store.delta_size() == d0
    # multi-op atomicity through apply_update: op 2 invalid -> op 1 unapplied
    with pytest.raises(UpdateError):
        store.apply_update("""
            INSERT DATA { ub:LeakS ub:advisor ub:LeakO . }
            DELETE DATA { ub:LeakS rdf:type ub:GraduateStudent . }
        """)
    assert store.version == v0 and store.delta_size() == d0
    eng = SparqlEngine(store.snapshot(), maps)
    assert eng.count("SELECT ?x WHERE { ub:LeakS ub:advisor ?x . }") == 0


def test_auto_compaction_threshold():
    g = _tiny_graph()
    store = VersionedStore(g, compact_threshold=0.5, compact_min=2)
    store.insert_edges([(0, 1, 1), (1, 0, 0)])
    assert store.should_compact()
    snap_before = store.snapshot()
    assert snap_before.has_delta
    snap = store.compact()
    assert store.epoch == 1 and store.delta_size() == 0
    assert not snap.has_delta
    assert snap.base.n_edges == g.n_edges + 2
    # ids survive compaction: the same edges are still present
    assert base_has_edge(snap.base, 0, 1, 1) and base_has_edge(snap.base,
                                                               1, 0, 0)


def test_version_bumps_and_snapshot_caching():
    g = _tiny_graph()
    store = VersionedStore(g, auto_compact=False)
    s0 = store.snapshot()
    assert store.snapshot() is s0  # cached until a write
    store.insert_edges([(0, 1, 2)])
    s1 = store.snapshot()
    assert s1 is not s0 and s1.version > s0.version
    assert not store.insert_edges([(0, 1, 2)])  # duplicate: no version bump
    assert store.snapshot() is s1


def test_pvar_query_sees_delta(lubm_graph):
    g, maps = lubm_graph
    store = VersionedStore(g, maps, auto_compact=False)
    eng = SparqlEngine(store.snapshot(), maps)
    q = "SELECT ?p WHERE { ub:PVarSubj ?p ub:PVarObj . }"
    assert eng.count(q) == 0
    store.insert_triples([("ub:PVarSubj", "ub:brandNewPred", "ub:PVarObj")])
    eng.set_graph(store.snapshot())
    res = eng.query(q)
    assert res.count == 1
    assert [r["p"] for r in res.decode(maps)] == ["ub:brandNewPred"]
    # deleting it again removes the binding (tombstone on the pvar path)
    store.insert_triples([("ub:PVarSubj", "ub:advisor", "ub:PVarObj")])
    store.delete_triples([("ub:PVarSubj", "ub:brandNewPred", "ub:PVarObj")])
    eng.set_graph(store.snapshot())
    res = eng.query(q)
    assert [r["p"] for r in res.decode(maps)] == ["ub:advisor"]
    # tombstone of a *base* edge must be masked on the pvar path too
    q_all = "SELECT ?x ?p ?y WHERE { ?x ?p ?y . }"
    total = eng.count(q_all)
    d = maps.dict
    s_id = int(np.flatnonzero(np.diff(g.out.indptr_all))[0])  # has an edge
    o_id = int(g.out.nbr_all[g.out.indptr_all[s_id]])
    el = int(g.out.lab_all[g.out.indptr_all[s_id]])
    triple = (d.term(int(maps.vertex_to_term[s_id])),
              d.predicate(int(maps.elabel_to_pred[el])),
              d.term(int(maps.vertex_to_term[o_id])))
    assert store.delete_triples([triple]) == 1
    eng.set_graph(store.snapshot())
    assert eng.count(q_all) == total - 1
