"""Adaptive-capacity pipelined executor tests.

Covers: overflow/suffix-resume exactness (forced tiny capacities must give
the same multiset as an overflow-free run), suffix-resume locality (retries
land on the overflowing step only, earlier steps are not re-executed —
asserted via the Result.stats step counters), count-only vs bindings
equivalence across every ExecOpts toggle, the int32 cumsum widening on a
high-degree star graph, async double-buffering, profiled stats, and the
engine-level OPTIONAL/analyze paths.
"""

import numpy as np
import pytest

from conftest import (given, random_labeled_graph, random_query_graph,
                      settings, st)

from repro.core import ExecOpts, Executor, build_plan
from repro.core.reference import enumerate_matches


def _tiny_plan(g, q):
    """Plan with presizing estimates stripped: tiny caps force resumes."""
    plan = build_plan(g, q)
    plan.est_fanout = []
    plan.est_expand = []
    return plan


def _multiset(res, n_pvars):
    return sorted(
        (tuple(b), tuple(p[:n_pvars]))
        for b, p in zip(res.bindings.tolist(), res.pvar_bindings.tolist()))


# ------------------------------------------------------- suffix resume
@given(st.integers(0, 10_000), st.integers(1, 5), st.booleans(),
       st.booleans())
@settings(max_examples=10, deadline=None)
def test_suffix_resume_exactness(seed, chunk, use_fused, count_mode):
    """Forced-overflow runs (init_cap=8, tiny chunks) return exactly the
    no-retry run's results, bindings and count alike."""
    rng = np.random.default_rng(seed)
    g = random_labeled_graph(rng, n_vertices=11, p_edge=0.4)
    q = random_query_graph(rng, g, n_qv=3)
    want = Executor(g, ExecOpts()).run(build_plan(g, q))
    ex = Executor(g, ExecOpts(init_cap=8, chunk=chunk, use_fused=use_fused))
    if count_mode:
        got = ex.run(_tiny_plan(g, q), collect="count")
        assert got.count == want.count
        assert got.bindings is None
    else:
        got = ex.run(_tiny_plan(g, q))
        assert _multiset(got, len(q.pvars)) == _multiset(want, len(q.pvars))


def test_suffix_resume_reexecutes_only_overflowing_step():
    """When step k overflows, steps < k must not run again: their expansion
    totals match the overflow-free run exactly (no double counting), and
    the retry counters sit on step k alone."""
    rng = np.random.default_rng(7)
    g = random_labeled_graph(rng, n_vertices=14, p_edge=0.6)
    q = random_query_graph(rng, g, n_qv=4, with_labels=False, with_id=False)
    want = Executor(g, ExecOpts()).run(build_plan(g, q))
    ex = Executor(g, ExecOpts(init_cap=8, chunk=4))
    got = ex.run(_tiny_plan(g, q))
    assert got.count == want.count
    st_ = got.stats
    assert st_["resumes"] > 0
    # exactness of the per-step totals proves no step was re-executed
    assert st_["step_rows"] == want.stats["step_rows"]
    assert st_["step_kept"] == want.stats["step_kept"]
    # every resume is attributed to exactly one overflowing step
    assert sum(st_["step_retries"]) == st_["resumes"]


def test_legacy_mode_still_exact():
    """cap_schedule=False + suffix_resume=False reproduces the old
    whole-chunk-retry executor, bit-for-bit results."""
    rng = np.random.default_rng(7)
    g = random_labeled_graph(rng, n_vertices=14, p_edge=0.6)
    q = random_query_graph(rng, g, n_qv=4, with_labels=False, with_id=False)
    want = Executor(g, ExecOpts()).run(build_plan(g, q))
    ex = Executor(g, ExecOpts(init_cap=8, chunk=4, cap_schedule=False,
                              suffix_resume=False, async_chunks=1,
                              use_fused=False))
    got = ex.run(_tiny_plan(g, q))
    assert _multiset(got, len(q.pvars)) == _multiset(want, len(q.pvars))
    assert got.chunks_retried > 0


# ------------------------------------------- count == bindings, all toggles
@pytest.mark.parametrize("toggles", [
    {},
    {"use_fused": False},
    {"cap_schedule": False},
    {"suffix_resume": False},
    {"async_chunks": 1},
    {"async_chunks": 3, "chunk": 3},
    {"semantics": "iso"},
    {"use_int": False},
    {"use_nlf": True, "use_deg": True},
    {"init_cap": 8, "chunk": 2},
])
def test_count_matches_bindings(toggles):
    rng = np.random.default_rng(99)
    g = random_labeled_graph(rng, n_vertices=12, p_edge=0.4)
    opts = ExecOpts(**toggles)
    for seed in range(3):
        rngq = np.random.default_rng(700 + seed)
        q = random_query_graph(rngq, g, n_qv=3, with_pvar=True)
        plan = build_plan(g, q, use_nlf=opts.use_nlf, use_deg=opts.use_deg)
        ex = Executor(g, opts)
        res_b = ex.run(plan, collect="bindings")
        res_c = ex.run(plan, collect="count")
        assert res_c.count == res_b.count
        assert res_c.bindings is None
        ref = enumerate_matches(g, q, semantics=opts.semantics)
        assert res_b.count == len(ref)


# --------------------------------------------------- int32 cumsum widening
def test_int32_cumsum_widening_star_graph():
    """A wide chunk expanding a 40k-degree star hub makes cap * max_degree
    exceed 2**31 — the widened total check must keep the count exact
    instead of wrapping into silent truncation."""
    from repro.rdf.graph import LabeledGraph

    n, hub_deg = 70_000, 40_000
    src = np.concatenate([np.arange(1, n, dtype=np.int64),
                          np.zeros(hub_deg, np.int64)])
    dst = np.concatenate([np.zeros(n - 1, np.int64),
                          np.arange(1, hub_deg + 1, dtype=np.int64)])
    el = np.zeros(src.shape[0], np.int64)
    g = LabeledGraph.build(n, src, el, dst, 1, [()] * n, 1)

    from repro.core.query import QEdge, QueryGraph, QVertex
    q = QueryGraph()
    q.vertices = [QVertex("x"), QVertex("y")]
    q.var_to_vertex = {"x": 0, "y": 1}
    q.edges = [QEdge(0, 1, 0)]

    opts = ExecOpts(chunk=1 << 16, init_cap=1 << 16)
    plan = build_plan(g, q, estimate="static")
    # the hazard condition the widening guards: chunk rows × max degree
    assert (1 << 16) * hub_deg >= 2**31
    res = Executor(g, opts).run(plan, collect="count")
    assert res.count == (n - 1) + hub_deg


# ----------------------------------------------------------- stats & async
def test_stats_populated_and_async_invariance():
    rng = np.random.default_rng(3)
    g = random_labeled_graph(rng, n_vertices=13, p_edge=0.45)
    q = random_query_graph(rng, g, n_qv=3, with_labels=False, with_id=False)
    plan = build_plan(g, q)
    n_src = plan.start_candidates.shape[0]
    assert n_src > 1  # label-free start: several candidates -> several chunks
    base = Executor(g, ExecOpts(chunk=1, async_chunks=1)).run(plan)
    deep = Executor(g, ExecOpts(chunk=1, async_chunks=4)).run(plan)
    assert _multiset(base, len(q.pvars)) == _multiset(deep, len(q.pvars))
    st_ = deep.stats
    n_steps = len(plan.steps)
    assert len(st_["step_rows"]) == n_steps
    assert len(st_["caps"]) == n_steps
    assert st_["chunks"] == n_src  # one dispatch per single-row chunk
    assert st_["wall_ms"] > 0
    assert st_["step_kept"][-1] == deep.count


def test_profile_mode_wall_times():
    rng = np.random.default_rng(5)
    g = random_labeled_graph(rng, n_vertices=12, p_edge=0.4)
    q = random_query_graph(rng, g, n_qv=3)
    plan = build_plan(g, q)
    want = Executor(g, ExecOpts()).run(plan)
    got = Executor(g, ExecOpts()).run(plan, profile=True)
    assert _multiset(got, len(q.pvars)) == _multiset(want, len(q.pvars))
    wall = got.stats["step_wall_ms"]
    assert wall is not None and len(wall) == len(plan.steps)
    assert all(w > 0 for w in wall)


def test_profile_mode_resumes_exact():
    """Profiled execution with forced overflow still returns exact rows."""
    rng = np.random.default_rng(7)
    g = random_labeled_graph(rng, n_vertices=14, p_edge=0.6)
    q = random_query_graph(rng, g, n_qv=4, with_labels=False, with_id=False)
    want = Executor(g, ExecOpts()).run(build_plan(g, q))
    got = Executor(g, ExecOpts(init_cap=8, chunk=4)).run(
        _tiny_plan(g, q), profile=True)
    assert _multiset(got, len(q.pvars)) == _multiset(want, len(q.pvars))
    assert got.stats["resumes"] > 0


# --------------------------------------------------- engine-level coverage
def test_engine_optional_under_tiny_caps(lubm_graph):
    g, maps = lubm_graph
    from repro.core import SparqlEngine

    q = """SELECT ?x ?e WHERE { ?x rdf:type ub:GraduateStudent .
           OPTIONAL { ?x ub:emailAddress ?e } }"""
    want = SparqlEngine(g, maps, ExecOpts()).query(q)
    got = SparqlEngine(g, maps, ExecOpts(init_cap=8, chunk=4)).query(q)
    assert sorted(map(tuple, want.rows.tolist())) == \
        sorted(map(tuple, got.rows.tolist()))


def test_engine_count_only_and_analyze(lubm_graph):
    g, maps = lubm_graph
    from repro.core import SparqlEngine

    eng = SparqlEngine(g, maps, ExecOpts())
    q = """SELECT ?x ?y WHERE { ?x rdf:type ub:GraduateStudent .
           ?x ub:memberOf ?y . }"""
    full = eng.query(q)
    cnt = eng.query(q, collect="count")
    assert cnt.count == full.count
    assert cnt.rows.shape[0] == 0
    assert full.stats["exec"]["branches"][0]["base"]["step_kept"][-1] \
        == full.count
    ex = eng.explain(q, analyze=True)
    assert ex["actual_rows"] == full.count
    steps = ex["branches"][0]["steps"]
    assert all("actual_rows" in s and "wall_ms" in s for s in steps)
    assert steps[-1]["actual_rows"] == full.count
