"""Property tests on executor invariants (hypothesis):

1. solution sets are invariant to chunk size / capacity / +INT / estimator;
2. homomorphism count ≥ isomorphism count, and equality on injective data;
3. adding a label filter can only shrink the solution set;
4. the SPMD engine_chunk_step used by the production dry-run agrees with
   the host executor on its triangle-plan shape.
"""

import numpy as np
import pytest

from conftest import given, settings, st

import jax.numpy as jnp

from conftest import random_labeled_graph, random_query_graph
from repro.core import ExecOpts, Executor, build_plan


def _solutions(g, q, opts, estimate="sampled"):
    plan = build_plan(g, q, estimate=estimate, use_nlf=opts.use_nlf,
                      use_deg=opts.use_deg)
    res = Executor(g, opts).run(plan)
    return sorted(map(tuple, res.bindings.tolist()))


@given(st.integers(0, 10_000), st.integers(1, 7), st.sampled_from([8, 64]),
       st.booleans())
@settings(max_examples=12, deadline=None)
def test_chunk_capacity_estimator_invariance(seed, chunk, cap, use_int):
    rng = np.random.default_rng(seed)
    g = random_labeled_graph(rng, n_vertices=10, p_edge=0.3)
    q = random_query_graph(rng, g, n_qv=3)
    base = _solutions(g, q, ExecOpts())
    varied = _solutions(
        g, q, ExecOpts(chunk=chunk, init_cap=cap, use_int=use_int),
        estimate="static")
    assert base == varied


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_hom_superset_of_iso(seed):
    rng = np.random.default_rng(seed)
    g = random_labeled_graph(rng, n_vertices=9, p_edge=0.35)
    q = random_query_graph(rng, g, n_qv=3, with_id=False)
    hom = set(_solutions(g, q, ExecOpts()))
    iso = set(_solutions(g, q, ExecOpts(semantics="iso")))
    assert iso <= hom
    # iso rows are exactly the injective hom rows
    assert iso == {s for s in hom if len(set(s)) == len(s)}


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_label_filter_monotone(seed):
    rng = np.random.default_rng(seed)
    g = random_labeled_graph(rng, n_vertices=10, p_edge=0.3, n_vlabels=3)
    q = random_query_graph(rng, g, n_qv=3, with_labels=False, with_id=False)
    broad = set(_solutions(g, q, ExecOpts()))
    q.vertices[0].labels = (0,)
    narrow = set(_solutions(g, q, ExecOpts()))
    assert narrow <= broad


def test_engine_chunk_step_matches_executor():
    """The production-dry-run SPMD step == the host executor on the same
    3-step tree + final join plan shape."""
    from repro.core.distributed import engine_chunk_step
    from repro.core.query import QEdge, QueryGraph, QVertex

    from repro.rdf.graph import LabeledGraph

    rng = np.random.default_rng(5)
    n = 30
    m = 200
    arr = np.stack([rng.integers(0, n, m), np.zeros(m, np.int64),
                    rng.integers(0, n, m)], axis=1)
    # every vertex gets label 0 so the representative label mask matches
    g = LabeledGraph.build(n, arr[:, 0], arr[:, 1], arr[:, 2], 1,
                           [(0,)] * n, 1)

    # host plan: path x0 -e0-> x1 -e0-> x2 -e0-> x3 with join x2 -e0-> x3?
    # engine_chunk_step checks edge (parent -> v_new) at the last step,
    # which duplicates the tree edge — i.e. its count equals the pure path
    # count.  Compare against the host path query.
    q = QueryGraph()
    for i in range(4):
        q.vertices.append(QVertex(f"v{i}", labels=(0,)))
        q.var_to_vertex[f"v{i}"] = i
    q.edges = [QEdge(0, 1, 0), QEdge(1, 2, 0), QEdge(2, 3, 0)]
    # pin the forward-path order: engine_chunk_step IS that shape, and the
    # cost model is free to pick another (equally correct) order otherwise
    plan = build_plan(g, q, estimate="static", force_order=[0, 1, 2, 3])
    host = Executor(g, ExecOpts()).run(plan, collect="count").count

    iptr = jnp.asarray(
        np.stack([g.out.indptr_el[0]] * 3).astype(np.int32))
    cands = plan.start_candidates
    chunk = jnp.asarray(np.pad(cands, (0, 64 - len(cands)),
                               constant_values=-1))
    count, ovf = engine_chunk_step(
        jnp.asarray(g.out.nbr_el), iptr,
        jnp.asarray(g.label_bitmap), chunk, jnp.int32(len(cands)),
        cap=1 << 15, n_steps=3)
    assert not bool(ovf)
    assert int(count) == host
