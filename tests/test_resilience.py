"""Resilience subsystem: cooperative cancellation, fault injection,
degraded-mode execution, scheduler shutdown, and serve-path error mapping.

The load-bearing guarantees under test:

- the degradation ladder returns *bit-identical* results under injected
  RESOURCE_EXHAUSTED at every query-path fault site;
- deadline expiry mid-query stops within one chunk boundary and surfaces
  partial stats (HTTP 504, not 500);
- scheduler shutdown fails every unfinished flight with SchedulerShutdown
  and no waiter blocks past it;
- a store_commit fault leaves the versioned store unmutated.
"""

import threading
import time
import urllib.error
import urllib.parse
import urllib.request

import json

import numpy as np
import pytest

from conftest import given, settings, st
from repro.core import ExecOpts, SparqlEngine
from repro.core.sparql_exec import QueryResult
from repro.resilience import faults
from repro.resilience.cancel import CancelToken, QueryCancelled
from repro.resilience.faults import FaultInjector, FaultSpec, InjectedFault, parse_fault_spec
from repro.resilience.policy import (MAX_LEVEL, DegradationBreaker, RetryPolicy,
                                     degrade_opts, is_transient_fault)
from repro.serve.scheduler import (DeadlineExceeded, Overloaded, Scheduler,
                                   SchedulerShutdown, SchedulerStopped)
from repro.serve.server import DatasetRegistry, make_server, serve_in_thread
from repro.store import VersionedStore

Q_ADVISOR = "SELECT ?x ?y WHERE { ?x <ub:advisor> ?y . }"
Q_COURSE = "SELECT ?x ?y WHERE { ?x <ub:takesCourse> ?y . }"


# ------------------------------------------------------------------ units
def test_cancel_token_deadline_and_extend():
    tok = CancelToken()
    assert not tok.expired and tok.remaining() is None
    tok.check()  # no deadline, not cancelled -> no-op

    tok = CancelToken(time.monotonic() + 60)
    assert not tok.expired and tok.remaining() > 50
    tok.extend(time.monotonic() + 120)
    assert tok.remaining() > 100
    tok.extend(time.monotonic() - 1)  # never moves earlier
    assert tok.remaining() > 100

    past = CancelToken(time.monotonic() - 0.001)
    assert past.expired and past.reason == "deadline exceeded"
    with pytest.raises(QueryCancelled):
        past.check({"chunks": 3})
    try:
        past.check({"chunks": 3})
    except QueryCancelled as e:
        assert e.partial_stats == {"chunks": 3}

    tok = CancelToken()
    tok.cancel("client went away")
    assert tok.expired and tok.reason == "client went away"


def test_fault_spec_parsing_and_validation():
    specs = parse_fault_spec("dispatch:oom:0.5;compile:latency:1.0:20")
    assert specs == (FaultSpec("dispatch", "oom", rate=0.5),
                     FaultSpec("compile", "latency", rate=1.0, latency_ms=20.0))
    # comma works as separator too, blanks ignored
    assert len(parse_fault_spec("dispatch:poison, store_commit:oom")) == 2
    with pytest.raises(ValueError):
        parse_fault_spec("nowhere:oom")
    with pytest.raises(ValueError):
        parse_fault_spec("dispatch:frobnicate")
    with pytest.raises(ValueError):
        parse_fault_spec("dispatch")
    with pytest.raises(ValueError):
        FaultSpec("dispatch", "oom", rate=1.5)


def test_injector_is_deterministic_and_bounded():
    def run(seed):
        inj = FaultInjector(
            [FaultSpec("dispatch", "poison", rate=0.5)], seed=seed)
        return [inj.fire("dispatch") for _ in range(64)]

    assert run(7) == run(7)          # same seed -> same firing sequence
    assert run(7) != run(8)          # different seed -> different sequence

    inj = FaultInjector([FaultSpec("dispatch", "oom", times=2)], seed=0)
    for _ in range(2):
        with pytest.raises(InjectedFault):
            inj.fire("dispatch")
    inj.fire("dispatch")             # exhausted: no-op
    assert inj.counters[("dispatch", "oom")] == 2
    assert inj.snapshot()["fired"] == {"dispatch:oom": 2}
    assert inj.fire("compile") is False  # unwired site: no-op


def test_transient_classification():
    assert is_transient_fault(InjectedFault("dispatch", "oom"))
    assert is_transient_fault(InjectedFault("compile", "compile_error"))
    assert not is_transient_fault(InjectedFault("dispatch", "poison"))
    assert is_transient_fault(MemoryError())
    assert is_transient_fault(RuntimeError("RESOURCE_EXHAUSTED: whatever"))
    assert not is_transient_fault(ValueError("bad query"))


def test_degrade_opts_ladder_shape():
    base = ExecOpts(chunk=4096, init_cap=1 << 16, async_chunks=2)
    assert degrade_opts(base, 0) is base
    l1 = degrade_opts(base, 1)
    assert l1.chunk == 2048 and l1.init_cap == (1 << 15)
    assert l1.async_chunks == 1 and l1.cap_slack == base.cap_slack * 0.5
    assert l1.use_fused == base.use_fused
    l2 = degrade_opts(base, 2)
    assert l2.use_fused is False and l2.chunk == 2048
    l3 = degrade_opts(base, MAX_LEVEL)
    assert l3.cap_schedule is False and l3.suffix_resume is False
    assert l3.use_fused is False and l3.async_chunks == 1
    # floors hold even from tiny configs
    tiny = degrade_opts(ExecOpts(chunk=64, init_cap=256), 1)
    assert tiny.chunk == 512 and tiny.init_cap == 1024


def test_breaker_escalates_and_reprobes():
    br = DegradationBreaker(cooldown_s=10.0)
    sig = "plan-a"
    assert br.level(sig, now=0.0) == 0
    assert br.record_failure(sig, 0, now=0.0) == 1
    assert br.level(sig, now=1.0) == 1        # inside cooldown: stay put
    assert br.level(sig, now=10.0) == 0       # cooldown over: probe lower
    assert br.record_failure(sig, 1, now=11.0) == 2
    br.record_success(sig, 2, now=12.0)
    assert br.level(sig, now=13.0) == 2       # success pins the level
    assert br.level(sig, now=22.0) == 1       # ...until the next re-probe
    br.record_success(sig, 0, now=23.0)       # success at 0 clears the entry
    assert br.snapshot()["degraded_plans"] == 0
    assert br.record_failure(sig, MAX_LEVEL, now=0.0) == MAX_LEVEL  # capped

    assert RetryPolicy(backoff_s=0.01, backoff_max_s=0.05).backoff(10) == 0.05


# --------------------------------------------- degradation ladder (engine)
def _rows_equal(a: QueryResult, b: QueryResult) -> bool:
    return (a.count == b.count and list(a.variables) == list(b.variables)
            and np.array_equal(np.asarray(a.rows), np.asarray(b.rows)))


@pytest.mark.parametrize("site", ["dispatch", "compile"])
def test_ladder_bit_identical_under_oom(lubm_graph, site):
    """RESOURCE_EXHAUSTED injected at a query-path site: the retry ladder
    must still produce bit-identical bindings for every query."""
    g, maps = lubm_graph
    expected = {q: SparqlEngine(g, maps, ExecOpts(chunk=64)).query(q)
                for q in (Q_ADVISOR, Q_COURSE)}
    for q, exp in expected.items():
        eng = SparqlEngine(g, maps, ExecOpts(chunk=64))
        with faults.inject(f"{site}:oom", times=4, seed=7) as inj:
            res = eng.query(q)
        assert inj.counters[(site, "oom")] >= 1
        assert _rows_equal(res, exp), f"results diverged under {site} oom"
        snap = eng.executor.resilience_snapshot()
        assert snap["fault_retries"] >= 1


def test_ladder_escalation_and_breaker_memory(lubm_graph):
    """Enough same-level failures escalate one ladder level; the breaker
    remembers, so the next run starts degraded without re-failing."""
    g, maps = lubm_graph
    exp = SparqlEngine(g, maps).query(Q_ADVISOR)
    eng = SparqlEngine(g, maps)
    # default policy: max_retries=2 -> 3 attempts at L0; 4 faults push the
    # 4th attempt to L1 where the injector is exhausted
    with faults.inject("dispatch:oom", times=4, seed=7):
        res = eng.query(Q_ADVISOR)
    assert _rows_equal(res, exp)
    snap = eng.executor.resilience_snapshot()
    assert snap["escalations"] >= 1 and snap["degraded_runs"] >= 1
    assert snap["degraded_plans"] == 1 and snap["max_level"] >= 1
    assert res.stats["exec"]["branches"][0]["base"]["degraded_level"] >= 1
    # breaker memory: the same plan now runs degraded and fault-free
    res2 = eng.query(Q_ADVISOR)
    assert _rows_equal(res2, exp)
    assert eng.executor.resilience_snapshot()["fault_retries"] == snap["fault_retries"]


def test_ladder_exhaustion_reraises(lubm_graph):
    """A fault that persists through every ladder level must surface, not
    loop forever."""
    g, maps = lubm_graph
    eng = SparqlEngine(g, maps)
    with faults.inject("dispatch:oom", seed=0):  # unlimited fires
        with pytest.raises(InjectedFault):
            eng.query(Q_ADVISOR)
    snap = eng.executor.resilience_snapshot()
    assert snap["max_level"] == MAX_LEVEL


def test_nontransient_errors_bypass_ladder(lubm_graph):
    g, maps = lubm_graph
    eng = SparqlEngine(g, maps)
    # unlimited poison: the executor's small-plan probe also visits the
    # dispatch site, so a one-shot spec can be consumed before the real run
    with faults.inject("dispatch:poison", seed=0):
        res = eng.query(Q_ADVISOR)
    # poison is a *silent* corruption, not a retryable fault: the run
    # completes, the chunk's counts are zeroed, and the stats say so
    assert res.count < SparqlEngine(g, maps).query(Q_ADVISOR).count
    parts = [br["base"] for br in res.stats["exec"]["branches"]]
    assert any(p.get("poisoned") for p in parts)
    assert eng.executor.resilience_snapshot()["fault_retries"] == 0


def test_delta_merge_fault_retries_to_identical_result(lubm_graph):
    g, maps = lubm_graph
    store = VersionedStore(g, maps, auto_compact=False)
    store.apply_update("INSERT DATA { ub:RZed ub:advisor ub:ROther . }")
    exp = SparqlEngine(store.snapshot(), maps).query(Q_ADVISOR)
    eng = SparqlEngine(store.snapshot(), maps)
    with faults.inject("delta_merge:oom", times=1, seed=0) as inj:
        res = eng.query(Q_ADVISOR)
    assert inj.counters[("delta_merge", "oom")] == 1
    assert _rows_equal(res, exp)
    assert eng.executor.resilience_snapshot()["fault_retries"] >= 1


def test_store_commit_fault_leaves_store_unmutated(lubm_graph):
    g, maps = lubm_graph
    store = VersionedStore(g, maps, auto_compact=False)
    v0, d0 = store.version, store.delta_size()
    upd = "INSERT DATA { ub:FaultS ub:advisor ub:FaultO . }"
    with faults.inject("store_commit:oom", seed=0):
        with pytest.raises(InjectedFault):
            store.apply_update(upd)
    assert store.version == v0 and store.delta_size() == d0
    eng = SparqlEngine(store.snapshot(), maps)
    assert eng.count("SELECT ?x WHERE { ub:FaultS ub:advisor ?x . }") == 0
    store.apply_update(upd)  # retried commit applies cleanly
    assert store.version > v0


# -------------------------------------------------- cancellation (engine)
def test_deadline_stops_within_one_chunk(lubm_graph):
    g, maps = lubm_graph
    eng = SparqlEngine(g, maps, ExecOpts(chunk=4))
    full = eng.query(Q_COURSE)  # warm compile so only dispatch costs count
    total_chunks = full.stats["exec"]["branches"][0]["base"]["chunks"]
    assert total_chunks >= 4, "fixture must yield a multi-chunk query"
    with faults.inject("dispatch:latency:1.0:25", seed=0):
        with pytest.raises(QueryCancelled) as ei:
            eng.query(Q_COURSE, timeout_ms=60)
    part = ei.value.partial_stats["exec"]["branches"][-1]["base"]
    # stopped at a chunk boundary: some progress, but nowhere near done —
    # 25ms injected per dispatch vs a 60ms budget bounds it to <=4 chunks
    assert 0 <= part["chunks"] < total_chunks
    assert part["wall_ms"] >= 0.0


def test_timeout_ms_zero_budget_cancels_before_dispatch(lubm_graph):
    g, maps = lubm_graph
    eng = SparqlEngine(g, maps, ExecOpts(chunk=4))
    eng.query(Q_COURSE)  # warm
    with pytest.raises(QueryCancelled):
        eng.query(Q_COURSE, timeout_ms=0)


def test_explicit_cancel_token(lubm_graph):
    g, maps = lubm_graph
    eng = SparqlEngine(g, maps, ExecOpts(chunk=4))
    eng.query(Q_COURSE)
    tok = CancelToken()
    tok.cancel("caller aborted")
    with pytest.raises(QueryCancelled) as ei:
        eng.query(Q_COURSE, cancel=tok)
    assert "caller aborted" in str(ei.value)


# ------------------------------------------------------- scheduler + HTTP
class _StubRegistry:
    """Duck-typed registry: version + execute_canonical only.  ``exec_s``
    simulates device occupancy; ``cooperative`` adds a cancel kwarg and
    polls it like the real executor does."""

    def __init__(self, exec_s: float = 0.2, cooperative: bool = False):
        self.exec_s = exec_s
        self.calls = 0
        if cooperative:
            self.execute_canonical = self._execute_cancellable

    def version(self, name: str) -> int:
        return 0

    def _result(self) -> QueryResult:
        return QueryResult(["v0"], np.empty((0, 1), np.int64), ["vertex"],
                           count=0, stats={})

    def execute_canonical(self, name, canon, version):
        self.calls += 1
        time.sleep(self.exec_s)
        return self._result()

    def _execute_cancellable(self, name, canon, version, cancel=None):
        self.calls += 1
        t_end = time.monotonic() + self.exec_s
        while time.monotonic() < t_end:
            if cancel is not None:
                cancel.check()
            time.sleep(0.005)
        return self._result()


def _submit_bg(sched, query, timeout_s, out, key):
    try:
        out[key] = sched.submit("ds", query, timeout_s=timeout_s)
    except Exception as e:  # noqa: BLE001 — the outcome *is* the assertion
        out[key] = e


def test_scheduler_shutdown_fails_unfinished_flights():
    reg = _StubRegistry(exec_s=1.0)
    sched = Scheduler(reg, workers=1, default_timeout_s=30.0).start()
    out: dict = {}
    t1 = threading.Thread(target=_submit_bg, args=(
        sched, "SELECT ?a WHERE { ?a <p:one> ?b . }", 30.0, out, 1))
    t2 = threading.Thread(target=_submit_bg, args=(
        sched, "SELECT ?a WHERE { ?a <p:two> ?b . }", 30.0, out, 2))
    t1.start()
    time.sleep(0.15)  # worker now busy on flight 1
    t2.start()
    time.sleep(0.15)  # flight 2 queued behind it
    t0 = time.monotonic()
    sched.stop()
    t1.join(8.0)
    t2.join(8.0)
    assert not t1.is_alive() and not t2.is_alive(), \
        "a waiter blocked past shutdown"
    assert time.monotonic() - t0 < 6.0
    # flight 1 may have finished inside the join window; flight 2 never
    # started and must carry the shutdown error
    assert isinstance(out[2], SchedulerShutdown)
    assert isinstance(out[1], (QueryResult, SchedulerShutdown, QueryCancelled))
    snap = sched.snapshot()
    assert snap["inflight"] == 0 and snap["running"] is False
    with pytest.raises(SchedulerStopped):
        sched.submit("ds", "SELECT ?a WHERE { ?a <p:one> ?b . }")


def test_scheduler_shutdown_cancels_cooperative_execution():
    """A cancel-aware registry exits at the next poll, so stop() returns
    well inside the join timeout instead of riding out the execution."""
    reg = _StubRegistry(exec_s=10.0, cooperative=True)
    sched = Scheduler(reg, workers=1, default_timeout_s=30.0).start()
    out: dict = {}
    t = threading.Thread(target=_submit_bg, args=(
        sched, "SELECT ?a WHERE { ?a <p:one> ?b . }", 30.0, out, 1))
    t.start()
    time.sleep(0.2)
    t0 = time.monotonic()
    sched.stop()
    assert time.monotonic() - t0 < 3.0
    t.join(3.0)
    assert not t.is_alive()
    assert isinstance(out[1], (QueryCancelled, SchedulerShutdown))


def test_waiter_abandonment_cancels_flight():
    """When the only waiter times out, the flight's token flips so the
    execution stops occupying the worker."""
    reg = _StubRegistry(exec_s=5.0, cooperative=True)
    sched = Scheduler(reg, workers=1, default_timeout_s=30.0).start()
    try:
        with pytest.raises(DeadlineExceeded) as ei:
            sched.submit("ds", "SELECT ?a WHERE { ?a <p:one> ?b . }",
                         timeout_s=0.2)
        assert ei.value.queue_wait_ms is not None
        # the cooperative stub polls every 5ms: the cancel lands long
        # before the 5s sleep would have finished
        t0 = time.monotonic()
        while sched.snapshot()["inflight"] and time.monotonic() - t0 < 2.0:
            time.sleep(0.01)
        assert sched.snapshot()["inflight"] == 0
        assert sched.metrics.cancelled.total() >= 1
    finally:
        sched.stop()


def test_http_resilience_status_codes(lubm_graph):
    g, maps = lubm_graph
    registry = DatasetRegistry()
    registry.register("lubm", g, maps, ExecOpts(chunk=4))
    server = make_server(registry, port=0, workers=1)
    serve_in_thread(server)
    host, port = server.server_address[:2]
    base = f"http://{host}:{port}"
    try:
        # warm the plan so injected latency dominates the timed run
        url = f"{base}/sparql?query=" + urllib.parse.quote(Q_COURSE)
        with urllib.request.urlopen(url, timeout=60) as r:
            assert json.load(r)["stats"]["count"] > 0

        # 504 with queue-wait/execution split, distinct from 500
        with faults.inject("dispatch:latency:1.0:30", seed=0):
            with pytest.raises(urllib.error.HTTPError) as ei:
                urllib.request.urlopen(f"{url}&timeout_ms=60", timeout=60)
        assert ei.value.code == 504
        body = json.load(ei.value)
        assert "queue_wait_ms" in body and "exec_ms" in body
        assert "error" in body

        # /healthz carries resilience + scheduler + fault state
        with urllib.request.urlopen(f"{base}/healthz", timeout=30) as r:
            h = json.load(r)
        assert "resilience" in h["datasets"]["lubm"]
        assert h["scheduler"]["workers_alive"] == 1
        assert "faults" in h

        # /metrics exposes the new counters
        with urllib.request.urlopen(f"{base}/metrics", timeout=30) as r:
            text = r.read().decode()
        assert "repro_cancelled_total" in text
        assert "repro_degraded_dispatch_total" in text
        assert "repro_degraded_plans_lubm" in text
    finally:
        server.shutdown()
        server.scheduler.stop()


def test_http_overload_sends_retry_after(lubm_graph):
    g, maps = lubm_graph
    registry = DatasetRegistry()
    registry.register("lubm", g, maps)
    # max_queue=0: every submission trips admission control, making the
    # 503 deterministic without racing worker threads
    server = make_server(registry, port=0, workers=1, max_queue=0)
    serve_in_thread(server)
    host, port = server.server_address[:2]
    try:
        url = (f"http://{host}:{port}/sparql?query="
               + urllib.parse.quote(Q_ADVISOR))
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(url, timeout=30)
        assert ei.value.code == 503
        assert int(ei.value.headers["Retry-After"]) >= 1
        body = json.load(ei.value)
        assert body["retry_after_s"] >= 0.5
    finally:
        server.shutdown()
        server.scheduler.stop()


def test_overloaded_retry_after_tracks_backlog():
    reg = _StubRegistry(exec_s=0.01, cooperative=True)
    sched = Scheduler(reg, workers=2, max_queue=4,
                      default_timeout_s=30.0)
    # empty queue: floor
    assert sched.retry_after_s() == 0.5
    sched._ema_exec_ms = 10_000.0
    sched._queue.put(object())
    try:
        assert 0.5 <= sched.retry_after_s() <= 30.0
    finally:
        sched._queue.get()


# -------------------------------------------------------- chaos (property)
@given(st.lists(st.sampled_from(["advisor", "course", "tight", "jitter"]),
                min_size=1, max_size=6),
       st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_chaos_interleavings_property(lubm_graph, ops, seed):
    """Random submit/fault/shutdown interleavings: every flight reaches
    exactly one terminal state, no waiter blocks past its deadline plus
    slack, and stop() leaves no inflight/pending residue."""
    g, maps = lubm_graph
    registry = DatasetRegistry()
    registry.register("ds", g, maps, ExecOpts(chunk=16))
    sched = Scheduler(registry, workers=2, max_queue=16,
                      default_timeout_s=10.0,
                      metrics=registry.metrics).start()
    out: dict = {}
    elapsed: dict = {}
    threads: list[threading.Thread] = []
    spec = ("dispatch:latency:0.3:3" if "jitter" in ops else None)
    injector = faults.install(
        FaultInjector(parse_fault_spec(spec), seed=seed)) if spec else None

    def run(i, query, timeout_s):
        t0 = time.monotonic()
        _submit_bg(sched, query, timeout_s, out, i)
        elapsed[i] = time.monotonic() - t0

    budgets = {}
    try:
        for i, op in enumerate(ops):
            if op == "jitter":
                continue
            q, timeout_s = {
                "advisor": (Q_ADVISOR, 10.0),
                "course": (Q_COURSE, 10.0),
                "tight": (Q_COURSE, 0.002),
            }[op]
            budgets[i] = timeout_s
            th = threading.Thread(target=run, args=(i, q, timeout_s))
            threads.append(th)
            th.start()
            time.sleep(0.002)
        time.sleep(0.01)
    finally:
        sched.stop()
        if spec:
            faults.install(injector)
    for th in threads:
        th.join(15.0)
        assert not th.is_alive(), "a waiter never reached a terminal state"
    for i, budget in budgets.items():
        assert i in out, f"flight {i} has no terminal state"
        assert elapsed[i] <= budget + 8.0, \
            f"flight {i} blocked {elapsed[i]:.1f}s past its deadline"
        assert isinstance(
            out[i], (QueryResult, DeadlineExceeded, QueryCancelled,
                     SchedulerShutdown, SchedulerStopped, Overloaded))
    assert sched._inflight == {} and sched._pending == {}
