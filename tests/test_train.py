"""Training substrate tests: optimizer, schedules, compression, checkpoint
atomicity/keep-k/elastic restore, fault-tolerant loop, resumable data."""

import json
import os
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import Checkpointer
from repro.train.data import RecsysStream, SampledGraphStream, TokenStream
from repro.train.loop import LoopConfig, Trainer
from repro.train.optimizer import (OptConfig, adamw_init, adamw_update,
                                   compress_int8, global_norm, lr_at)
from repro.train.straggler import ChunkRebalancer, StepTimeTracker
from repro.train.trainstep import make_train_step


# ------------------------------------------------------------- optimizer
def _quadratic_params():
    return {"w": jnp.asarray([3.0, -2.0, 1.0]), "b": jnp.asarray(4.0)}


def test_adamw_converges_quadratic():
    params = _quadratic_params()
    cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                    total_steps=500, schedule="const")
    state = adamw_init(params, cfg)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(300):
        grads = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(loss(params)) < 1e-3


def test_lr_schedule_shapes():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0  # warmup
    assert lrs[99] < lrs[50] < lrs[10]  # cosine decay
    assert all(l >= 0 for l in lrs)


def test_grad_clipping():
    params = {"w": jnp.ones(4)}
    cfg = OptConfig(lr=1e-9, clip_norm=1.0, weight_decay=0.0)
    state = adamw_init(params, cfg)
    big = {"w": jnp.full(4, 100.0)}
    _, _, gn = adamw_update(params, big, state, cfg)
    assert float(gn) == pytest.approx(200.0)


def test_int8_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=512).astype(np.float32))
    err = jnp.zeros_like(g)
    # single shot: quantization error bounded by scale/2
    deq, new_err = compress_int8(g, err)
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(deq - g))) <= scale * 0.5 + 1e-7
    # error feedback: accumulated dequantized sum converges to true sum
    total_true = jnp.zeros_like(g)
    total_deq = jnp.zeros_like(g)
    err = jnp.zeros_like(g)
    for i in range(50):
        gi = jnp.asarray(rng.normal(size=512).astype(np.float32))
        total_true += gi
        deq, err = compress_int8(gi, err)
        total_deq += deq
    # residual is carried, so the drift stays bounded by one quantum
    drift = float(jnp.max(jnp.abs(total_true - total_deq)))
    assert drift <= float(jnp.max(jnp.abs(err))) + 1e-5


def test_compressed_training_matches_uncompressed_roughly():
    def loss(p, batch, _cfg):
        return jnp.sum((p["w"] - 3.0) ** 2)

    for compress in (False, True):
        cfg = OptConfig(lr=0.05, weight_decay=0.0, schedule="const",
                        warmup_steps=1, grad_compress=compress)
        params = {"w": jnp.zeros(8)}
        state = adamw_init(params, cfg)
        step = make_train_step(loss, None, cfg)
        for _ in range(200):
            params, state, m = step(params, state, {})
        assert float(m["loss"]) < 1e-2, f"compress={compress}"


def test_microbatch_accumulation_equivalence():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))

    def loss(p, batch, _):
        pred = batch["x"] @ p["w"]
        return jnp.mean((pred - batch["y"]) ** 2)

    cfg = OptConfig(lr=0.1, weight_decay=0.0, schedule="const", warmup_steps=1)
    p0 = {"w": jnp.ones(4)}
    outs = []
    for m in (1, 4):
        step = make_train_step(loss, None, cfg, microbatches=m)
        p, s, metrics = step(p0, adamw_init(p0, cfg), {"x": x, "y": y})
        outs.append((np.asarray(p["w"]), float(metrics["loss"])))
    # microbatched grads are means of means over equal splits = same here
    np.testing.assert_allclose(outs[0][0], outs[1][0], rtol=2e-2, atol=2e-2)


# ------------------------------------------------------------ checkpoints
def test_checkpoint_roundtrip_and_keep(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    params = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
              "nested": {"b": jnp.ones(4, jnp.bfloat16)}}
    for s in (10, 20, 30):
        ck.save(s, {"params": jax.tree.map(lambda x: x * s, params)})
    assert ck.all_steps() == [20, 30]  # keep=2 pruned step 10
    step, trees, _ = ck.restore({"params": params})
    assert step == 30
    np.testing.assert_allclose(np.asarray(trees["params"]["a"], np.float32),
                               np.arange(6, dtype=np.float32).reshape(2, 3) * 30)
    assert trees["params"]["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_atomic_no_partial(tmp_path):
    ck = Checkpointer(tmp_path, keep=5)
    params = {"w": jnp.ones(3)}
    ck.save(1, {"params": params})
    # a stale staging dir must not be visible as a checkpoint
    (tmp_path / "step_000000000099.tmp.abc").mkdir()
    assert ck.all_steps() == [1]


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    ck.save(5, {"params": {"w": jnp.ones(1000)}}, blocking=False)
    ck.wait()
    assert ck.all_steps() == [5]


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, {"params": {"w": jnp.ones(3)}})
    with pytest.raises(ValueError, match="shape"):
        ck.restore({"params": {"w": jnp.ones(4)}})


# ------------------------------------------------------------------- loop
def _toy_setup(tmp_path, total=30, fail_at=None):
    cfg = OptConfig(lr=0.05, weight_decay=0.0, schedule="const",
                    warmup_steps=1)
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params, cfg)
    calls = {"n": 0}

    def loss(p, batch, _):
        return jnp.mean((p["w"] - batch["target"]) ** 2)

    raw = make_train_step(loss, None, cfg)

    def step_fn(p, s, b):
        calls["n"] += 1
        if fail_at is not None and calls["n"] == fail_at:
            raise RuntimeError("injected transient failure")
        return raw(p, s, b)

    class Stream:
        def batch_at(self, step):
            return {"target": jnp.full(4, 3.0)}

    loop_cfg = LoopConfig(total_steps=total, ckpt_every=10,
                          ckpt_dir=str(tmp_path), log_every=10)
    return Trainer(step_fn, Stream(), loop_cfg, params, opt), calls


def test_loop_runs_and_checkpoints(tmp_path):
    trainer, _ = _toy_setup(tmp_path)
    end = trainer.fit()
    assert end == 30
    assert trainer.ckpt.all_steps()[-1] == 30
    assert float(jnp.mean(trainer.params["w"])) > 1.0  # moved toward 3


def test_loop_retries_from_checkpoint(tmp_path):
    trainer, calls = _toy_setup(tmp_path, total=25, fail_at=17)
    end = trainer.fit()
    assert end == 25
    # one failure -> restored from step 10 and replayed
    assert calls["n"] > 25


def test_loop_resumes_after_restart(tmp_path):
    trainer, _ = _toy_setup(tmp_path, total=20)
    trainer.fit()
    # new trainer instance (fresh params) resumes from the checkpoint
    trainer2, _ = _toy_setup(tmp_path, total=40)
    end = trainer2.fit()
    assert end == 40
    assert trainer2.ckpt.latest_step() == 40


# ------------------------------------------------------------------- data
def test_streams_deterministic_and_resumable():
    s = TokenStream(vocab=128, batch=4, seq=16, seed=7)
    b1 = s.batch_at(42)
    b2 = s.batch_at(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(s.batch_at(43)["tokens"], b1["tokens"])
    # next-token alignment
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])

    r = RecsysStream(n_dense=4, n_sparse=3, hotness=2,
                     vocab_sizes=(50, 20, 10), batch=8, seed=1)
    rb = r.batch_at(5)
    assert rb["sparse"].max() < 50 and rb["sparse"].min() >= -1

    g = SampledGraphStream(n_nodes=500, avg_degree=5, d_feat=8, n_classes=3,
                           batch_nodes=16, fanout=[4, 3], seed=2)
    gb = g.batch_at(3)
    assert gb["x"].shape[0] == g.pad_n
    assert gb["edge_src"].shape == (g.pad_e,)
    np.testing.assert_array_equal(gb["x"], g.batch_at(3)["x"])


# -------------------------------------------------------------- straggler
def test_straggler_tracker_flags_outliers():
    t = StepTimeTracker(factor=2.0)
    for i in range(20):
        assert not t.record(i, 0.1)
    assert t.record(20, 0.5)
    assert t.flagged[0][0] == 20


def test_chunk_rebalancer_balances():
    rb = ChunkRebalancer(n_shards=4)
    for c in range(16):
        rb.observe(c, 1.0 + (10.0 if c == 0 else 0.0))
    assign = rb.assign(list(range(16)))
    # the heavy chunk is alone-ish: its shard gets fewest chunks
    heavy_shard = next(i for i, s in enumerate(assign) if 0 in s)
    assert len(assign[heavy_shard]) == min(len(s) for s in assign)
