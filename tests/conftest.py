"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device; only launch/dryrun.py forces 512 devices."""

import numpy as np
import pytest

from repro.rdf.generator import generate_bsbm, generate_hetero, generate_lubm
from repro.rdf.transform import direct_transform, type_aware_transform

# Optional hypothesis: property-test files do `from conftest import given,
# settings, st` — with hypothesis installed these are the real names, without
# it they are stand-ins that skip just the property tests (the rest of each
# module still runs).
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    def _hyp_missing(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    given = settings = _hyp_missing

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()


# Fallback per-test timeout when pytest-timeout is absent (CI installs it;
# the bare container may not).  SIGALRM-based, main-thread only, opt-in via
# the same `@pytest.mark.timeout(N)` / --timeout=N interface so tests don't
# care which implementation is active.
import importlib.util as _ilu  # noqa: E402

_HAVE_PYTEST_TIMEOUT = _ilu.find_spec("pytest_timeout") is not None

if not _HAVE_PYTEST_TIMEOUT:
    import signal
    import threading

    def pytest_addoption(parser):
        parser.addoption("--timeout", type=float, default=None,
                         help="per-test timeout in seconds (fallback shim; "
                              "install pytest-timeout for the real thing)")
        parser.addini("timeout", "per-test timeout in seconds (shim)",
                      default=None)

    def pytest_configure(config):
        config.addinivalue_line(
            "markers", "timeout(seconds): fail the test if it runs longer "
            "(SIGALRM fallback shim)")

    def _shim_timeout(item) -> float | None:
        marker = item.get_closest_marker("timeout")
        if marker is not None and marker.args:
            return float(marker.args[0])
        opt = item.config.getoption("--timeout")
        if opt:
            return float(opt)
        ini = item.config.getini("timeout")
        return float(ini) if ini else None

    @pytest.hookimpl(hookwrapper=True)
    def pytest_runtest_call(item):
        seconds = _shim_timeout(item)
        usable = (seconds and seconds > 0
                  and hasattr(signal, "SIGALRM")
                  and threading.current_thread() is threading.main_thread())
        if not usable:
            yield
            return

        def _on_alarm(signum, frame):
            pytest.fail(f"test exceeded {seconds:g}s timeout (shim)",
                        pytrace=False)

        prev = signal.signal(signal.SIGALRM, _on_alarm)
        signal.setitimer(signal.ITIMER_REAL, seconds)
        try:
            yield
        finally:
            signal.setitimer(signal.ITIMER_REAL, 0.0)
            signal.signal(signal.SIGALRM, prev)


@pytest.fixture(scope="session")
def lubm_store():
    st = generate_lubm(scale=1, seed=0, density=0.3)
    return st.finalize()


@pytest.fixture(scope="session")
def lubm_graph(lubm_store):
    return type_aware_transform(lubm_store)


@pytest.fixture(scope="session")
def lubm_graph_direct(lubm_store):
    return direct_transform(lubm_store)


@pytest.fixture(scope="session")
def bsbm_graph():
    st = generate_bsbm(n_products=150, seed=1)
    return type_aware_transform(st.finalize())


@pytest.fixture(scope="session")
def hetero_graph():
    st = generate_hetero(n_entities=400, n_types=12, n_predicates=8,
                         avg_degree=4.0, seed=2)
    return type_aware_transform(st.finalize())


def random_labeled_graph(rng: np.random.Generator, n_vertices=12, n_elabels=3,
                         n_vlabels=4, p_edge=0.18, multi_label=True):
    """Small random LabeledGraph for oracle-vs-engine property tests."""
    from repro.rdf.graph import LabeledGraph

    edges = []
    for u in range(n_vertices):
        for v in range(n_vertices):
            for el in range(n_elabels):
                if rng.random() < p_edge / n_elabels:
                    edges.append((u, el, v))
    if not edges:
        edges = [(0, 0, min(1, n_vertices - 1))]
    arr = np.array(edges, dtype=np.int64)
    labels = []
    for v in range(n_vertices):
        kmax = min(3 if multi_label else 2, n_vlabels + 1)
        k = int(rng.integers(0, kmax)) if kmax > 0 else 0
        labels.append(tuple(sorted(rng.choice(n_vlabels, size=k, replace=False)))
                      if k else ())
    return LabeledGraph.build(
        n_vertices=n_vertices, src=arr[:, 0], el=arr[:, 1], dst=arr[:, 2],
        n_elabels=n_elabels, vlabel_sets=labels, n_vlabels=n_vlabels)


def random_query_graph(rng: np.random.Generator, g, n_qv=3, p_extra_edge=0.4,
                       with_pvar=False, with_labels=True, with_id=True):
    """Random connected query graph over g's label/elabel spaces."""
    from repro.core.query import QEdge, QueryGraph, QVertex

    q = QueryGraph()
    for i in range(n_qv):
        labels = ()
        bound = -1
        if with_labels and rng.random() < 0.5 and g.n_vlabels:
            labels = (int(rng.integers(g.n_vlabels)),)
        if with_id and rng.random() < 0.15:
            bound = int(rng.integers(g.n_vertices))
        q.vertices.append(QVertex(var=f"v{i}", labels=labels, bound_id=bound))
        q.var_to_vertex[f"v{i}"] = i
    # spanning connectivity
    for i in range(1, n_qv):
        j = int(rng.integers(i))
        el = int(rng.integers(g.n_elabels))
        if with_pvar and rng.random() < 0.2:
            pv = f"p{len(q.pvars)}"
            q.pvars.append(pv)
            e = QEdge(j, i, -1, pvar=pv) if rng.random() < 0.5 else \
                QEdge(i, j, -1, pvar=pv)
        else:
            e = QEdge(j, i, el) if rng.random() < 0.5 else QEdge(i, j, el)
        q.edges.append(e)
    # extra (cycle-forming) edges
    for i in range(n_qv):
        for j in range(n_qv):
            if i != j and rng.random() < p_extra_edge / n_qv:
                el = int(rng.integers(g.n_elabels))
                q.edges.append(QEdge(i, j, el))
    return q
