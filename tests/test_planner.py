"""Planner subsystem tests.

Invariants: (1) every *legal* matching order yields the same result
multiset — the planner only affects speed, never answers (property test,
hypothesis-guarded per conftest); (2) base patterns and OPTIONAL extension
plans share one builder (``repro.core.planner.build_plan``) with real
cost-model fanouts instead of the old hardcoded 4.0; (3) GraphStats is
built once per graph and cached on it; (4) all estimate modes agree;
(5) ``explain()`` reports the order with the caller's variable names.
"""

import itertools

import numpy as np
import pytest

from conftest import (given, random_labeled_graph, random_query_graph,
                      settings, st)
from repro.core import (CostModel, ExecOpts, Executor, PlanError,
                        SparqlEngine, build_plan, build_query_graph)
from repro.core import sparql_exec as sparql_exec_mod
from repro.core.planner import DP_MAX_VERTICES, ESTIMATE_MODES
from repro.rdf.sparql import parse_sparql
from repro.rdf.workloads import BSBM_QUERIES, LUBM_QUERIES
from repro.stats import GraphStats, get_stats


def _multiset(g, q, opts=None, **plan_kw):
    plan = build_plan(g, q, **plan_kw)
    res = Executor(g, opts or ExecOpts()).run(plan)
    return sorted(map(tuple, res.bindings.tolist()))


# --------------------------------------------------------------- GraphStats


def test_stats_built_once_and_cached():
    rng = np.random.default_rng(0)
    g = random_labeled_graph(rng, n_vertices=12, p_edge=0.3)
    s = get_stats(g)
    assert isinstance(s, GraphStats)
    assert get_stats(g) is s  # cached on the graph object
    # tables are consistent with the graph
    assert int(s.pred_edges.sum()) == g.n_edges
    for lbl in range(g.n_vlabels):
        assert int(s.label_freq[lbl]) == g.freq([lbl])
    # cooccurrence diagonal == frequency; symmetric
    if s.label_cooc is not None:
        np.testing.assert_array_equal(np.diag(s.label_cooc), s.label_freq)
        np.testing.assert_array_equal(s.label_cooc, s.label_cooc.T)


def test_stats_sampled_fanout_matches_degrees():
    rng = np.random.default_rng(1)
    g = random_labeled_graph(rng, n_vertices=15, p_edge=0.4)
    s = get_stats(g)
    all_v = np.arange(g.n_vertices)
    for el in range(g.n_elabels):
        exact = np.diff(g.out.indptr_el[el]).mean()
        assert s.sampled_fanout(el, True, all_v) == pytest.approx(exact)


# ------------------------------------------------- order invariance (fixed)


@pytest.mark.parametrize("seed", [0, 3, 11, 42])
def test_estimate_modes_agree(seed):
    rng = np.random.default_rng(seed)
    g = random_labeled_graph(rng, n_vertices=10, p_edge=0.3)
    q = random_query_graph(rng, g, n_qv=3)
    results = {m: _multiset(g, q, estimate=m) for m in ESTIMATE_MODES}
    assert len({tuple(r) for r in results.values()}) == 1, results


@pytest.mark.parametrize("seed", [1, 7])
def test_every_legal_forced_order_same_multiset(seed):
    rng = np.random.default_rng(seed)
    g = random_labeled_graph(rng, n_vertices=9, p_edge=0.35)
    q = random_query_graph(rng, g, n_qv=3)
    reference = None
    legal = 0
    for perm in itertools.permutations(range(q.n_vertices)):
        try:
            got = _multiset(g, q, force_order=list(perm))
        except PlanError:
            continue  # order binds a vertex before any neighbor
        legal += 1
        if reference is None:
            reference = got
        assert got == reference, perm
    assert legal > 0


def test_force_order_validates():
    rng = np.random.default_rng(2)
    g = random_labeled_graph(rng, n_vertices=8, p_edge=0.4)
    q = random_query_graph(rng, g, n_qv=3)
    with pytest.raises(PlanError):
        build_plan(g, q, force_order=[0, 0, 1])  # not a permutation


# ---------------------------------------------- order invariance (property)


@given(st.integers(0, 10_000), st.integers(3, 4))
@settings(max_examples=15, deadline=None)
def test_property_matching_order_invariance(seed, n_qv):
    """Every legal matching order yields the same result multiset."""
    rng = np.random.default_rng(seed)
    g = random_labeled_graph(rng, n_vertices=10, p_edge=0.3)
    q = random_query_graph(rng, g, n_qv=n_qv, with_pvar=True)
    reference = None
    for perm in itertools.permutations(range(q.n_vertices)):
        try:
            got = _multiset(g, q, force_order=list(perm))
        except PlanError:
            continue
        if reference is None:
            reference = got
        assert got == reference, perm
    assert reference is not None


# ------------------------------------------------------------ DP order


def test_dp_search_used_and_correct(lubm_graph):
    g, maps = lubm_graph
    for name in ("Q2", "Q9", "Q4"):
        ast = parse_sparql(LUBM_QUERIES[name])
        q = build_query_graph(ast.where.triples, maps)
        dp_plan = build_plan(g, q, estimate="dp")
        if q.n_vertices <= DP_MAX_VERTICES:
            assert dp_plan.search == "dp", name
        ex = Executor(g, ExecOpts())
        assert ex.run(dp_plan, collect="count").count == \
            ex.run(build_plan(g, q, estimate="sampled"),
                   collect="count").count, name


# ------------------------------------------- sampled order with pvar edges


def test_sampled_survives_pvar_edges(bsbm_graph):
    """A predicate-variable edge no longer aborts sampling for the whole
    query (old behavior: any pvar edge -> static fallback)."""
    g, maps = bsbm_graph
    ast = parse_sparql("""
        SELECT ?r ?p WHERE {
          ?r rdf:type b:Review .
          ?r b:reviewFor ?prod .
          ?prod ?p ?o . }""")
    q = build_query_graph(ast.where.triples, maps)
    plan = build_plan(g, q, estimate="sampled")
    assert plan.search == "sampled"
    # and the result still matches the greedy ordering
    ex = Executor(g, ExecOpts())
    static = build_plan(g, q, estimate="static")
    assert ex.run(plan, collect="count").count == \
        ex.run(static, collect="count").count


def test_converging_pvar_edges_replan(hetero_graph):
    """Two predicate-variable edges meeting at one vertex: the estimate
    orders may leave one as an (unbindable) non-tree check; the builder
    must fall back to a pvar-first order instead of rejecting the query."""
    g, maps = hetero_graph
    ast = parse_sparql("SELECT ?a WHERE { ?a ?p ?b . ?b ?q ?c . "
                       "?a y:pred0 ?c . }")
    q = build_query_graph(ast.where.triples, maps)
    counts = set()
    for mode in ESTIMATE_MODES:
        plan = build_plan(g, q, estimate=mode)  # must not raise
        counts.add(Executor(g, ExecOpts()).run(plan, collect="count").count)
    assert len(counts) == 1


# ---------------------------------------------- one builder for base + OPT


def test_optional_and_base_share_one_builder(bsbm_graph, monkeypatch):
    """OPTIONAL extension plans go through the same planner entry point as
    base plans, flagged by ``prebound``."""
    g, maps = bsbm_graph
    calls = []
    real = sparql_exec_mod.build_plan

    def spy(*args, **kwargs):
        calls.append(kwargs.get("prebound", 0))
        return real(*args, **kwargs)

    monkeypatch.setattr(sparql_exec_mod, "build_plan", spy)
    engine = SparqlEngine(g, maps)
    res = engine.query(BSBM_QUERIES["B8"])
    assert res.count > 0
    assert 0 in calls  # base plan
    assert any(p > 0 for p in calls)  # extension plan, same builder
    # the old duplicated greedy loop is gone
    assert not hasattr(sparql_exec_mod, "_extension_plan")


def test_extension_fanout_is_cost_model_driven(bsbm_graph):
    """No hardcoded 4.0: extension-step fanouts come from the cost model
    (b:rating2 is single-valued, so the estimate must be ~1)."""
    g, maps = bsbm_graph
    engine = SparqlEngine(g, maps)
    compiled, _ = engine.compile(BSBM_QUERIES["B8"])
    (co,) = compiled.branches[0].optionals
    assert co.plan.est_fanout, "extension plan must carry estimates"
    assert all(f < 2.0 for f in co.plan.est_fanout), co.plan.est_fanout
    assert co.plan.order[: co.base_cols] == list(range(co.base_cols))


def test_extension_not_connected_raises(bsbm_graph):
    g, maps = bsbm_graph
    engine = SparqlEngine(g, maps)
    with pytest.raises(PlanError):
        engine.query("""
            SELECT ?r WHERE {
              ?r rdf:type b:Review .
              OPTIONAL { ?z b:price ?w . } }""")


# ------------------------------------------------------------------ explain


def test_explain_reports_order_and_estimates(lubm_graph):
    g, maps = lubm_graph
    engine = SparqlEngine(g, maps)
    ex = engine.explain(LUBM_QUERIES["Q2"])
    assert ex["branches"], ex
    br = ex["branches"][0]
    assert set(br["order"]) == {"?x", "?y", "?z"}  # caller's names restored
    assert len(br["steps"]) == len(br["order"]) - 1
    for step in br["steps"]:
        assert step["est_fanout"] is not None
        assert step["est_rows"] is not None
        assert "predicate" in step
    assert ex["plan_ms"] >= 0.0
    assert ex["est_total_rows"] >= 0.0


def test_explain_includes_optional_plans(bsbm_graph):
    g, maps = bsbm_graph
    engine = SparqlEngine(g, maps)
    ex = engine.explain(BSBM_QUERIES["B9"])
    opts = ex["branches"][0]["optionals"]
    assert len(opts) == 1
    assert opts[0]["steps"], opts
    assert ex["fingerprint"]


def test_query_result_carries_planner_stats(lubm_graph):
    g, maps = lubm_graph
    engine = SparqlEngine(g, maps)
    res = engine.query(LUBM_QUERIES["Q1"])
    assert "plan_ms" in res.stats and "est_rows" in res.stats


# ------------------------------------------------------- cost model basics


def test_cost_model_start_vertex_prefers_selective(lubm_graph):
    g, maps = lubm_graph
    cm = CostModel(g)
    ast = parse_sparql(LUBM_QUERIES["Q1"])
    q = build_query_graph(ast.where.triples, maps)
    comp = list(range(q.n_vertices))
    s = cm.choose_start_vertex(q, comp)
    freqs = [cm.vertex_freq(q, u) / max(1, len(q.adjacency()[u])) for u in comp]
    assert freqs[s] == min(freqs)
