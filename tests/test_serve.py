"""Serving subsystem tests: fingerprint equivalence, LRU/result caches,
engine plan-cache sharing, scheduler coalescing/deadlines/admission, and an
end-to-end HTTP round-trip with concurrent clients."""

import json
import threading
import time
import urllib.error
import urllib.request
from urllib.parse import urlencode

import numpy as np
import pytest

from repro.core import SparqlEngine
from repro.core.sparql_exec import QueryResult
from repro.rdf.sparql import parse_sparql
from repro.rdf.workloads import BSBM_QUERIES, LUBM_QUERIES
from repro.serve.cache import LRUCache, ResultCache
from repro.serve.fingerprint import canonicalize_query, fingerprint_query
from repro.serve.metrics import Histogram, MetricsRegistry, ServeMetrics
from repro.serve.scheduler import (DeadlineExceeded, Overloaded, Scheduler,
                                   SchedulerStopped)
from repro.serve.server import (DatasetRegistry, UnknownDataset, make_server,
                                serve_in_thread)

Q2_RENAMED_REORDERED = """
    SELECT ?a ?b ?c WHERE {
      ?a ub:undergraduateDegreeFrom ?b .
      ?c rdf:type ub:Department .
      ?a rdf:type ub:GraduateStudent .
      ?a ub:memberOf ?c .
      ?b rdf:type ub:University .
      ?c ub:subOrganizationOf ?b .
    }"""


# ------------------------------------------------------------- fingerprint
def test_fingerprint_alpha_renaming_and_reorder():
    assert fingerprint_query(LUBM_QUERIES["Q2"]) == \
        fingerprint_query(Q2_RENAMED_REORDERED)


def test_fingerprint_whitespace_and_prefix_invariance():
    a = "SELECT ?x WHERE { ?x rdf:type ub:Student . }"
    b = """PREFIX ub: <http://example.org/univ#>
           SELECT   ?y
           WHERE {
             ?y    rdf:type    ub:Student
           }"""
    assert fingerprint_query(a) == fingerprint_query(b)


def test_fingerprint_distinguishes_structure():
    fps = {name: fingerprint_query(q) for name, q in LUBM_QUERIES.items()}
    assert len(set(fps.values())) == len(fps)  # no two LUBM queries collide
    # same shape, different constant
    a = "SELECT ?x WHERE { ?x ub:takesCourse ub:CourseA . }"
    b = "SELECT ?x WHERE { ?x ub:takesCourse ub:CourseB . }"
    assert fingerprint_query(a) != fingerprint_query(b)
    # extra triple changes the fingerprint
    c = "SELECT ?x WHERE { ?x ub:takesCourse ub:CourseA . ?x rdf:type ub:Student . }"
    assert fingerprint_query(a) != fingerprint_query(c)


def test_fingerprint_select_order_matters():
    a = "SELECT ?x ?y WHERE { ?x ub:advisor ?y . }"
    b = "SELECT ?y ?x WHERE { ?x ub:advisor ?y . }"
    assert fingerprint_query(a) != fingerprint_query(b)


def test_fingerprint_symmetric_variables_correctness():
    # WL-symmetric star: any bijective renaming is correct even if sharing
    # is best-effort; canonicalization must stay deterministic
    q = "SELECT ?a ?b WHERE { ?c ub:knows ?a . ?c ub:knows ?b . }"
    assert fingerprint_query(q) == fingerprint_query(q)
    canon = canonicalize_query(parse_sparql(q))
    assert sorted(canon.rename) == ["a", "b", "c"]
    assert len(set(canon.rename.values())) == 3


def test_fingerprint_filter_optional_union():
    b3 = BSBM_QUERIES.get("B3")
    if b3 is not None:
        assert fingerprint_query(b3) == fingerprint_query(b3)
    a = """SELECT ?p WHERE {
        ?p rdf:type bsbm:Product .
        ?p bsbm:productPropertyNumeric1 ?v . FILTER (?v > 100)
        OPTIONAL { ?p bsbm:productPropertyTextual1 ?t . } }"""
    b = """SELECT ?q WHERE {
        OPTIONAL { ?q bsbm:productPropertyTextual1 ?u . }
        ?q bsbm:productPropertyNumeric1 ?w . FILTER (?w > 100)
        ?q rdf:type bsbm:Product . }"""
    assert fingerprint_query(a) == fingerprint_query(b)
    c = a.replace("> 100", "> 200")
    assert fingerprint_query(a) != fingerprint_query(c)


def test_fingerprint_optional_order_is_significant():
    # OPTIONAL left-joins chain: a later group may seed off variables bound
    # by an earlier one, so swapped OPTIONALs must NOT share a fingerprint
    a = """SELECT ?w WHERE { ?x rdf:type ub:A .
        OPTIONAL { ?x ub:p ?z . } OPTIONAL { ?z ub:q ?w . } }"""
    b = """SELECT ?w WHERE { ?x rdf:type ub:A .
        OPTIONAL { ?z ub:q ?w . } OPTIONAL { ?x ub:p ?z . } }"""
    assert fingerprint_query(a) != fingerprint_query(b)


def test_canonicalize_restores_caller_variables():
    canon = canonicalize_query(parse_sparql(Q2_RENAMED_REORDERED))
    restored = canon.restore([canon.rename[v] for v in ("a", "b", "c")])
    assert restored == ["a", "b", "c"]


# -------------------------------------------------------------------- LRU
def test_lru_eviction_order_and_stats():
    c = LRUCache(capacity=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1        # refresh a
    c.put("c", 3)                 # evicts b (least recent)
    assert c.get("b") is None
    assert c.get("a") == 1 and c.get("c") == 3
    assert c.stats.evictions == 1
    assert c.stats.hits == 3 and c.stats.misses == 1
    assert len(c) == 2
    snap = c.snapshot()
    assert snap["size"] == 2 and snap["capacity"] == 2
    assert 0.0 < snap["hit_rate"] < 1.0


def test_lru_disabled_at_zero_capacity():
    c = LRUCache(capacity=0)
    c.put("a", 1)
    assert not c.enabled and c.get("a") is None and len(c) == 0


def test_result_cache_version_invalidation():
    rc = ResultCache(capacity=8)
    r = QueryResult(["x"], np.zeros((1, 1), np.int32), ["vertex"], count=1)
    rc.put(("fp1", 0), r)
    rc.put(("fp2", 0), r)
    rc.put(("fp1", 1), r)
    assert rc.invalidate(0) == 2
    assert rc.peek(("fp1", 0)) is None
    assert rc.peek(("fp1", 1)) is r
    assert rc.stats.invalidations == 2


def test_result_cache_row_cap():
    rc = ResultCache(capacity=8, max_result_rows=10)
    big = QueryResult(["x"], np.zeros((11, 1), np.int32), ["vertex"], count=11)
    rc.put(("fp", 0), big)
    assert rc.peek(("fp", 0)) is None


# ---------------------------------------------------------------- metrics
def test_histogram_percentiles_and_render():
    h = Histogram("test_latency_ms")
    for v in range(1, 101):
        h.observe(float(v))
    assert h.percentile(50) == pytest.approx(50, abs=2)
    assert h.percentile(99) == pytest.approx(99, abs=2)
    text = "\n".join(h.render())
    assert 'test_latency_ms_bucket{le="+Inf"} 100' in text
    assert "test_latency_ms_count 100" in text


def test_metrics_registry_render():
    reg = MetricsRegistry()
    reg.counter("c_total", "a counter").inc(2, dataset="x")
    reg.gauge("g", "a gauge").set(1.5)
    out = reg.render()
    assert 'c_total{dataset="x"} 2' in out
    assert "# TYPE c_total counter" in out
    assert "g 1.5" in out


# -------------------------------------------------- scheduler (stub registry)
class _StubRegistry:
    """Registry double whose execution blocks until released."""

    def __init__(self):
        self.release = threading.Event()
        self.calls = []
        self.lock = threading.Lock()
        self.block = False

    def version(self, name):
        if name == "missing":
            raise UnknownDataset(name)
        return 0

    def execute_canonical(self, name, canon, version):
        with self.lock:
            self.calls.append(canon.fingerprint)
        if self.block and not self.release.wait(10.0):
            raise RuntimeError("stub never released")
        variables = canon.query.select or ["v0"]
        rows = np.arange(len(variables), dtype=np.int32)[None, :]
        return QueryResult(list(variables), rows,
                           ["vertex"] * len(variables), count=1)


def test_scheduler_coalesces_identical_fingerprints():
    reg = _StubRegistry()
    reg.block = True
    sched = Scheduler(reg, workers=2, metrics=ServeMetrics()).start()
    try:
        results, errors = [], []

        def client(q):
            try:
                results.append(sched.submit("d", q, timeout_s=10.0))
            except Exception as e:  # pragma: no cover
                errors.append(e)

        q1 = "SELECT ?x ?y WHERE { ?x ub:advisor ?y . }"
        q2 = "SELECT ?a ?b WHERE { ?a ub:advisor ?b . }"  # alpha-equivalent
        threads = [threading.Thread(target=client, args=(q,))
                   for q in (q1, q2, q1, q2)]
        for t in threads:
            t.start()
        deadline = time.time() + 5.0  # wait until all four are attached
        while sched.metrics.coalesced.total() < 3 and time.time() < deadline:
            time.sleep(0.01)
        reg.release.set()
        for t in threads:
            t.join(timeout=10.0)
        assert not errors
        assert len(results) == 4
        assert len(reg.calls) == 1  # one execution for four requests
        assert sched.metrics.coalesced.total() == 3
        # each caller got its own variable names back
        names = sorted(tuple(r.variables) for r in results)
        assert names == sorted([("x", "y"), ("a", "b"), ("x", "y"), ("a", "b")])
    finally:
        reg.release.set()
        sched.stop()


def test_scheduler_distinct_queries_do_not_coalesce():
    reg = _StubRegistry()
    sched = Scheduler(reg, workers=2, metrics=ServeMetrics()).start()
    try:
        sched.submit("d", "SELECT ?x WHERE { ?x rdf:type ub:Student . }")
        sched.submit("d", "SELECT ?x WHERE { ?x rdf:type ub:Course . }")
        assert len(set(reg.calls)) == 2
        assert sched.metrics.coalesced.total() == 0
    finally:
        sched.stop()


def test_scheduler_deadline_exceeded():
    reg = _StubRegistry()
    reg.block = True
    sched = Scheduler(reg, workers=1, metrics=ServeMetrics()).start()
    try:
        with pytest.raises(DeadlineExceeded):
            sched.submit("d", "SELECT ?x WHERE { ?x rdf:type ub:A . }",
                         timeout_s=0.15)
        assert sched.metrics.requests.value(dataset="d", status="timeout") == 1
    finally:
        reg.release.set()
        sched.stop()


def test_scheduler_admission_control_overload():
    reg = _StubRegistry()
    reg.block = True
    sched = Scheduler(reg, workers=1, max_queue=1,
                      metrics=ServeMetrics()).start()
    try:
        occupy = threading.Thread(
            target=lambda: sched.submit(
                "d", "SELECT ?x WHERE { ?x rdf:type ub:A . }", timeout_s=10.0))
        occupy.start()
        deadline = time.time() + 5.0
        while not reg.calls and time.time() < deadline:
            time.sleep(0.01)  # worker now blocked inside the stub
        queued = threading.Thread(
            target=lambda: sched.submit(
                "d", "SELECT ?x WHERE { ?x rdf:type ub:B . }", timeout_s=10.0))
        queued.start()
        deadline = time.time() + 5.0
        while sched._queue.qsize() < 1 and time.time() < deadline:
            time.sleep(0.01)
        with pytest.raises(Overloaded):
            sched.submit("d", "SELECT ?x WHERE { ?x rdf:type ub:C . }")
        reg.release.set()
        occupy.join(timeout=10.0)
        queued.join(timeout=10.0)
    finally:
        reg.release.set()
        sched.stop()


def test_scheduler_requires_start_and_propagates_unknown_dataset():
    reg = _StubRegistry()
    sched = Scheduler(reg, workers=1, metrics=ServeMetrics())
    with pytest.raises(SchedulerStopped):
        sched.submit("d", "SELECT ?x WHERE { ?x rdf:type ub:A . }")
    with sched:
        with pytest.raises(UnknownDataset):
            sched.submit("missing", "SELECT ?x WHERE { ?x rdf:type ub:A . }")


# ------------------------------------------------- engine plan-cache sharing
def test_engine_plan_cache_shares_alpha_equivalent_plans(lubm_graph):
    g, maps = lubm_graph
    engine = SparqlEngine(g, maps)
    r1 = engine.query(LUBM_QUERIES["Q2"])
    r2 = engine.query(Q2_RENAMED_REORDERED)
    stats = engine.plan_cache.stats
    assert stats.misses == 1 and stats.hits == 1  # exactly one plan compiled
    assert len(engine.plan_cache) == 1
    assert r1.count == r2.count
    assert r1.variables == ["x", "y", "z"]
    assert r2.variables == ["a", "b", "c"]
    assert np.array_equal(np.sort(r1.rows, axis=0), np.sort(r2.rows, axis=0))


def test_registry_result_cache_and_invalidation(lubm_graph):
    g, maps = lubm_graph
    registry = DatasetRegistry(result_cache_size=16)
    registry.register("lubm", g, maps)
    r1 = registry.execute("lubm", LUBM_QUERIES["Q1"])
    r2 = registry.execute("lubm", LUBM_QUERIES["Q1"])
    ds = registry.get("lubm")
    assert ds.result_cache.stats.hits == 1
    assert r1.count == r2.count
    # alpha-equivalent query hits the same cached result
    renamed = LUBM_QUERIES["Q1"].replace("?x", "?who")
    r3 = registry.execute("lubm", renamed)
    assert ds.result_cache.stats.hits == 2
    assert r3.variables == ["who"] and r3.count == r1.count
    # explicit invalidation: version bump retires the cached entry
    assert registry.invalidate("lubm") == 1
    registry.execute("lubm", LUBM_QUERIES["Q1"])
    assert ds.result_cache.stats.hits == 2  # miss after invalidation


# --------------------------------------------------------------- HTTP e2e
@pytest.fixture(scope="module")
def http_service(lubm_graph):
    g, maps = lubm_graph
    registry = DatasetRegistry(ServeMetrics())
    registry.register("lubm", g, maps)
    server = make_server(registry, port=0, workers=4, default_timeout_s=60.0)
    serve_in_thread(server)
    yield server
    server.shutdown()
    server.scheduler.stop()


def _http_get(server, query, **params):
    host, port = server.server_address[:2]
    url = f"http://{host}:{port}/sparql?" + urlencode(
        {"query": query, **params})
    with urllib.request.urlopen(url, timeout=60) as r:
        return json.loads(r.read())


def test_http_concurrent_clients_correct_bindings(http_service):
    server = http_service
    expected = {name: server.registry.execute("lubm", LUBM_QUERIES[name]).count
                for name in ("Q1", "Q2", "Q6", "Q9")}
    errors = []

    def client(tid):
        try:
            for name in ("Q1", "Q2", "Q6", "Q9"):
                out = _http_get(server, LUBM_QUERIES[name])
                assert out["stats"]["count"] == expected[name], name
                assert len(out["results"]["bindings"]) == expected[name]
                for b in out["results"]["bindings"]:
                    assert set(b) <= set(out["head"]["vars"])
        except Exception as e:  # pragma: no cover
            errors.append((tid, e))

    threads = [threading.Thread(target=client, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120.0)
    assert not errors


def test_http_post_json_and_limit(http_service):
    server = http_service
    host, port = server.server_address[:2]
    req = urllib.request.Request(
        f"http://{host}:{port}/sparql",
        data=json.dumps({"query": LUBM_QUERIES["Q6"], "dataset": "lubm",
                         "limit": 3}).encode(),
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=60) as r:
        out = json.loads(r.read())
    assert out["stats"]["returned"] == 3
    assert out["stats"]["count"] > 3


def test_http_post_raw_query_with_equals_filter(http_service):
    # raw bodies must not be mistaken for form encoding even when the
    # query itself contains '=' (e.g. an equality FILTER)
    server = http_service
    host, port = server.server_address[:2]
    q = ("SELECT ?x ?v WHERE { ?x rdf:type ub:Student . "
         "?x ub:age ?v . FILTER (?v >= 0) }")
    req = urllib.request.Request(
        f"http://{host}:{port}/sparql", data=q.encode(),
        headers={"Content-Type": "text/plain"})
    with urllib.request.urlopen(req, timeout=60) as r:
        out = json.loads(r.read())
    assert out["head"]["vars"] == ["x", "v"]


def test_http_healthz_and_metrics(http_service):
    server = http_service
    host, port = server.server_address[:2]
    _http_get(server, LUBM_QUERIES["Q1"])
    _http_get(server, LUBM_QUERIES["Q1"])  # plan-cache hit
    with urllib.request.urlopen(f"http://{host}:{port}/healthz",
                                timeout=30) as r:
        health = json.loads(r.read())
    assert health["status"] == "ok" and "lubm" in health["datasets"]
    with urllib.request.urlopen(f"http://{host}:{port}/metrics",
                                timeout=30) as r:
        text = r.read().decode()
    metrics = {line.split(" ")[0]: float(line.split(" ")[1])
               for line in text.splitlines()
               if line and not line.startswith("#")}
    assert metrics["repro_qps"] > 0
    assert metrics["repro_plan_cache_hits_lubm"] > 0
    assert any(k.startswith("repro_requests_total") and v > 0
               for k, v in metrics.items())


def test_http_explain(http_service):
    server = http_service
    out = _http_get(server, LUBM_QUERIES["Q2"], explain=1)
    assert out["dataset"] == "lubm"
    br = out["explain"]["branches"][0]
    assert set(br["order"]) == {"?x", "?y", "?z"}
    assert br["start_candidates"] >= 0
    for step in br["steps"]:
        assert step["est_fanout"] is not None
        assert step["est_rows"] is not None
    # explain never executes: no bindings key in the response
    assert "results" not in out
    # malformed query still yields a 400 through the explain path
    with pytest.raises(urllib.error.HTTPError) as ei:
        _http_get(server, "SELECT nonsense {{{", explain=1)
    assert ei.value.code == 400


def test_plan_search_and_cardinality_metrics(http_service):
    server = http_service
    _http_get(server, LUBM_QUERIES["Q9"])
    host, port = server.server_address[:2]
    with urllib.request.urlopen(f"http://{host}:{port}/metrics",
                                timeout=30) as r:
        text = r.read().decode()
    assert "repro_plan_search_ms" in text
    card = [line for line in text.splitlines()
            if line.startswith("repro_cardinality_error_log10_count")]
    assert card and float(card[0].split(" ")[1]) > 0


def test_http_error_codes(http_service):
    server = http_service
    with pytest.raises(urllib.error.HTTPError) as ei:
        _http_get(server, "SELECT nonsense {{{")
    assert ei.value.code == 400
    with pytest.raises(urllib.error.HTTPError) as ei:
        _http_get(server, LUBM_QUERIES["Q1"], dataset="nope")
    assert ei.value.code == 404
    host, port = server.server_address[:2]
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(f"http://{host}:{port}/bogus", timeout=30)
    assert ei.value.code == 404


# ------------------------------------------- live updates / result versions
def test_result_cache_watermark_blocks_late_stale_put():
    """Regression: a query that captured version v, finished after
    invalidate(v), used to re-insert its stale result under (fp, v) — a key
    no later invalidation visits.  The watermark refuses the late put."""
    rc = ResultCache(capacity=8)
    r = QueryResult(["x"], np.zeros((1, 1), np.int32), ["vertex"], count=1)
    assert rc.invalidate(0) == 0
    rc.put(("fp", 0), r)          # late insert for a retired generation
    assert rc.peek(("fp", 0)) is None
    rc.put(("fp", 1), r)          # current generation still caches
    assert rc.peek(("fp", 1)) is r
    # invalidate retires every generation <= v, not just == v
    rc.put(("fp2", 1), r)
    assert rc.invalidate(2) == 2
    assert rc.peek(("fp", 1)) is None and rc.peek(("fp2", 1)) is None


def test_registry_update_bumps_version_under_lock(lubm_graph):
    g, maps = lubm_graph
    registry = DatasetRegistry(result_cache_size=16)
    registry.register("live", g, maps, updatable=True)
    q = "SELECT ?x WHERE { ?x rdf:type ub:FullProfessor . }"
    c0 = registry.execute("live", q).count
    ds = registry.get("live")
    assert ds.result_cache.peek((fingerprint_query(q), 0)) is not None
    out = registry.update("live", """INSERT DATA {
        ub:NewProf rdf:type ub:FullProfessor . }""")
    assert out["inserted"] == 1 and out["version"] == ds.version >= 1
    assert out["invalidated"] >= 1
    # stale generation is gone; fresh execution sees the new data
    assert ds.result_cache.peek((fingerprint_query(q), 0)) is None
    assert registry.execute("live", q).count == c0 + 1
    # plan cache survived the update
    assert ds.engine.plan_cache.stats.misses >= 1
    assert len(ds.engine.plan_cache) >= 1
    with pytest.raises(ValueError):  # not updatable
        registry.register("frozen", g, maps)
        registry.update("frozen", "INSERT DATA { ub:a ub:p ub:b . }")


def test_registry_update_invalidates_after_manual_invalidate(lubm_graph):
    """Regression: a manual invalidate() bumps ds.version ahead of the
    store's counter; the next update must still move the version forward
    and retire cached results (it used to no-op the invalidation)."""
    g, maps = lubm_graph
    registry = DatasetRegistry(result_cache_size=16)
    registry.register("live2", g, maps, updatable=True)
    q = "SELECT ?x WHERE { ?x rdf:type ub:AssistantProfessor . }"
    registry.invalidate("live2")                    # ds.version -> 1
    c0 = registry.execute("live2", q).count
    ds = registry.get("live2")
    v1 = ds.version
    assert ds.result_cache.peek((fingerprint_query(q), v1)) is not None
    out = registry.update("live2", """INSERT DATA {
        ub:NewAsst rdf:type ub:AssistantProfessor . }""")
    assert out["version"] == ds.version > v1
    assert ds.result_cache.peek((fingerprint_query(q), v1)) is None
    assert registry.execute("live2", q).count == c0 + 1


# ------------------------------------------------------------- /update e2e
@pytest.fixture()
def updatable_service(lubm_graph):
    g, maps = lubm_graph
    registry = DatasetRegistry(ServeMetrics(), result_cache_size=16)
    registry.register("lubm", g, maps, updatable=True)
    server = make_server(registry, port=0, workers=2, default_timeout_s=60.0)
    serve_in_thread(server)
    yield server
    server.shutdown()
    server.scheduler.stop()


def _http_post(server, path, body, ctype="application/sparql-update"):
    host, port = server.server_address[:2]
    req = urllib.request.Request(f"http://{host}:{port}{path}",
                                 data=body.encode(),
                                 headers={"Content-Type": ctype})
    with urllib.request.urlopen(req, timeout=60) as r:
        return json.loads(r.read())


def test_http_update_endpoint(updatable_service):
    server = updatable_service
    q = ("SELECT ?x WHERE { ?x rdf:type ub:GraduateStudent . "
         "?x ub:takesCourse ub:HttpCourse . }")
    out0 = _http_get(server, q)
    assert out0["stats"]["count"] == 0
    res = _http_post(server, "/update", """INSERT DATA {
        ub:HttpStudent rdf:type ub:GraduateStudent .
        ub:HttpStudent ub:takesCourse ub:HttpCourse . }""")
    assert res["inserted"] == 2 and res["version"] >= 1
    out1 = _http_get(server, q)
    assert out1["stats"]["count"] == 1
    assert out1["results"]["bindings"][0]["x"]["value"] == "ub:HttpStudent"
    # JSON body form + delete
    res2 = _http_post(
        server, "/update",
        json.dumps({"update": "DELETE DATA { ub:HttpStudent "
                              "ub:takesCourse ub:HttpCourse . }"}),
        ctype="application/json")
    assert res2["deleted"] == 1
    assert _http_get(server, q)["stats"]["count"] == 0
    # health reflects the live store
    host, port = server.server_address[:2]
    with urllib.request.urlopen(f"http://{host}:{port}/healthz",
                                timeout=30) as r:
        health = json.loads(r.read())
    assert health["datasets"]["lubm"]["store"]["inserted"] >= 2
    with urllib.request.urlopen(f"http://{host}:{port}/metrics",
                                timeout=30) as r:
        text = r.read().decode()
    assert "repro_updates_total" in text
    assert 'repro_update_triples_total{dataset="lubm",op="insert"} 2' in text


def test_http_update_accepts_default_curl_content_type(updatable_service):
    # `curl --data-binary` sends x-www-form-urlencoded by default; a raw
    # SPARQL UPDATE body must still be accepted (README documents it)
    server = updatable_service
    res = _http_post(server, "/update",
                     "INSERT DATA { ub:CurlS ub:advisor ub:CurlO . }",
                     ctype="application/x-www-form-urlencoded")
    assert res["inserted"] == 1
    q = "SELECT ?x WHERE { ub:CurlS ub:advisor ?x . }"
    assert _http_get(server, q)["stats"]["count"] == 1


def test_http_update_errors(updatable_service):
    server = updatable_service
    with pytest.raises(urllib.error.HTTPError) as ei:
        _http_post(server, "/update", "DELETE WHERE { ?s ?p ?o }")
    assert ei.value.code == 400
    with pytest.raises(urllib.error.HTTPError) as ei:
        _http_post(server, "/update", "")
    assert ei.value.code == 400
    with pytest.raises(urllib.error.HTTPError) as ei:
        _http_post(server, "/update?dataset=nope",
                   "INSERT DATA { ub:a ub:p ub:b . }")
    assert ei.value.code == 404


def test_concurrent_queries_during_updates(updatable_service):
    """Queries racing a writer must always see a consistent snapshot —
    never crash, never a half-applied batch."""
    server = updatable_service
    q = ("SELECT ?x WHERE { ?x rdf:type ub:GraduateStudent . "
         "?x ub:takesCourse ub:RaceCourse . }")
    errors, counts = [], []
    stop = threading.Event()

    def reader():
        try:
            while not stop.is_set():
                counts.append(_http_get(server, q)["stats"]["count"])
        except Exception as e:  # pragma: no cover
            errors.append(e)

    readers = [threading.Thread(target=reader) for _ in range(3)]
    for t in readers:
        t.start()
    try:
        for i in range(8):
            _http_post(server, "/update", f"""INSERT DATA {{
                ub:Racer{i} rdf:type ub:GraduateStudent .
                ub:Racer{i} ub:takesCourse ub:RaceCourse . }}""")
    finally:
        stop.set()
        for t in readers:
            t.join(timeout=30.0)
    assert not errors
    assert _http_get(server, q)["stats"]["count"] == 8
    # every observed count is a whole batch (type+edge land atomically)
    assert set(counts) <= set(range(9))
