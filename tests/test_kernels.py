"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp oracle,
sweeping shapes and dtypes.  Plus hypothesis property tests on the ragged
expansion primitive."""

import numpy as np
import pytest

from conftest import given, settings, st

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bitmap_filter import bitmap_superset_pallas
from repro.kernels.edge_exists import edge_exists_pallas
from repro.kernels.expand_filter import expand_filter_compact_pallas
from repro.kernels.segment_gather import (segment_gather_fixed_pallas,
                                          segment_gather_sum_pallas)
from repro.kernels.signature_filter import signature_filter_pallas
from repro.kernels.sorted_intersect import tile_membership_pallas


# --------------------------------------------------------------------- +INT
@pytest.mark.parametrize("r,ta,tb", [(1, 1, 1), (4, 8, 16), (33, 7, 129),
                                     (256, 1, 64), (100, 128, 128)])
def test_tile_membership_shapes(r, ta, tb):
    rng = np.random.default_rng(r * 1000 + ta + tb)
    a = rng.integers(-1, 40, size=(r, ta)).astype(np.int32)
    b = rng.integers(-1, 40, size=(r, tb)).astype(np.int32)
    b = np.where(b < 0, -2, b).astype(np.int32)  # pad value
    got = np.asarray(tile_membership_pallas(jnp.asarray(a), jnp.asarray(b),
                                            interpret=True))
    want = np.asarray(ref.tile_membership_ref(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got, want)


@given(st.integers(1, 40), st.integers(1, 24), st.integers(1, 24),
       st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_tile_membership_property(r, ta, tb, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(-1, 20, size=(r, ta)).astype(np.int32)
    b = rng.integers(-1, 20, size=(r, tb)).astype(np.int32)
    got = np.asarray(tile_membership_pallas(jnp.asarray(a), jnp.asarray(b),
                                            interpret=True, row_tile=16))
    for i in range(r):
        bset = set(int(x) for x in b[i] if x >= 0)
        for j in range(ta):
            want = a[i, j] >= 0 and int(a[i, j]) in bset
            assert bool(got[i, j]) == want


# ------------------------------------------------------------- edge_exists
@pytest.mark.parametrize("m,b", [(1, 1), (17, 5), (1000, 64), (4096, 1024),
                                 (100, 2048)])
def test_edge_exists_shapes(m, b):
    rng = np.random.default_rng(m + b)
    nbr = np.sort(rng.integers(0, 500, size=m)).astype(np.int32)
    lo = rng.integers(0, m, size=b).astype(np.int32)
    hi = np.minimum(m, lo + rng.integers(0, 50, size=b)).astype(np.int32)
    tgt = rng.integers(0, 500, size=b).astype(np.int32)
    got = np.asarray(edge_exists_pallas(jnp.asarray(nbr), jnp.asarray(lo),
                                        jnp.asarray(hi), jnp.asarray(tgt),
                                        interpret=True, tile=256))
    want = np.asarray(ref.edge_exists_ref(jnp.asarray(nbr), jnp.asarray(lo),
                                          jnp.asarray(hi), jnp.asarray(tgt)))
    np.testing.assert_array_equal(got, want)
    # and against brute force
    brute = np.array([tgt[i] in nbr[lo[i]:hi[i]] for i in range(b)])
    np.testing.assert_array_equal(want, brute)


@given(st.integers(1, 200), st.integers(1, 64), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_edge_exists_property(m, b, seed):
    rng = np.random.default_rng(seed)
    nbr = np.sort(rng.integers(0, 60, size=m)).astype(np.int32)
    lo = rng.integers(0, m + 1, size=b).astype(np.int32)
    hi = np.clip(lo + rng.integers(-2, 30, size=b), 0, m).astype(np.int32)
    tgt = rng.integers(-1, 60, size=b).astype(np.int32)
    got = np.asarray(edge_exists_pallas(jnp.asarray(nbr), jnp.asarray(lo),
                                        jnp.asarray(hi), jnp.asarray(tgt),
                                        interpret=True, tile=32))
    brute = np.array([hi[i] > lo[i] and tgt[i] in nbr[lo[i]:hi[i]]
                      for i in range(b)])
    np.testing.assert_array_equal(got, brute)


# ------------------------------------------------------------ bitmap filter
@pytest.mark.parametrize("b,w", [(1, 1), (7, 2), (1000, 4), (2049, 1)])
def test_bitmap_superset_shapes(b, w):
    rng = np.random.default_rng(b * 7 + w)
    bm = rng.integers(0, 2**32, size=(b, w), dtype=np.uint64).astype(np.uint32)
    req = rng.integers(0, 2**10, size=(w,), dtype=np.uint64).astype(np.uint32)
    got = np.asarray(bitmap_superset_pallas(jnp.asarray(bm), jnp.asarray(req),
                                            interpret=True, tile=512))
    want = np.asarray(ref.bitmap_superset_ref(jnp.asarray(bm), jnp.asarray(req)))
    np.testing.assert_array_equal(got, want)
    brute = np.all((bm & req) == req, axis=-1)
    np.testing.assert_array_equal(want, brute)


# -------------------------------------------------------- signature filter
@pytest.mark.parametrize("v,w,b", [(1, 2, 1), (17, 2, 5), (100, 4, 257),
                                   (1024, 8, 2048)])
def test_signature_filter_shapes(v, w, b):
    rng = np.random.default_rng(v * 13 + w + b)
    sig = rng.integers(0, 2**32, size=(v, w), dtype=np.uint64) \
        .astype(np.uint32)
    cand = rng.integers(-1, v, size=b).astype(np.int32)
    req = (rng.integers(0, 2**32, size=w, dtype=np.uint64)
           & rng.integers(0, 2**32, size=w, dtype=np.uint64)).astype(np.uint32)
    got = np.asarray(signature_filter_pallas(jnp.asarray(sig),
                                             jnp.asarray(cand),
                                             jnp.asarray(req),
                                             interpret=True, tile=256))
    want = np.asarray(ref.signature_filter_ref(jnp.asarray(sig),
                                               jnp.asarray(cand),
                                               jnp.asarray(req)))
    np.testing.assert_array_equal(got, want)
    brute = np.all((sig[np.clip(cand, 0, v - 1)] & req) == req, axis=-1)
    np.testing.assert_array_equal(want, brute)


@given(st.integers(1, 50), st.integers(1, 6), st.integers(1, 100),
       st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_signature_filter_property(v, w, b, seed):
    rng = np.random.default_rng(seed)
    sig = rng.integers(0, 2**32, size=(v, w), dtype=np.uint64) \
        .astype(np.uint32)
    cand = rng.integers(0, v, size=b).astype(np.int32)
    req = (rng.integers(0, 2**32, size=w, dtype=np.uint64)
           & rng.integers(0, 2**32, size=w, dtype=np.uint64)).astype(np.uint32)
    got = np.asarray(signature_filter_pallas(jnp.asarray(sig),
                                             jnp.asarray(cand),
                                             jnp.asarray(req),
                                             interpret=True, tile=64))
    for i in range(b):
        want = bool(np.all((sig[cand[i]] & req) == req))
        assert bool(got[i]) == want


# ---------------------------------------------------------- segment gather
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("v,d,s,k", [(16, 8, 4, 3), (100, 64, 32, 8),
                                     (50, 200, 7, 1), (512, 128, 256, 16)])
def test_segment_gather_fixed(v, d, s, k, dtype):
    rng = np.random.default_rng(v + d + s + k)
    table = rng.normal(size=(v, d)).astype(np.float32)
    idx = rng.integers(-1, v, size=(s, k)).astype(np.int32)
    tj = jnp.asarray(table, dtype=dtype)
    got = segment_gather_fixed_pallas(tj, jnp.asarray(idx), interpret=True,
                                      seg_tile=64)
    # oracle via ragged form
    rows, segs = [], []
    for i in range(s):
        for x in idx[i]:
            if x >= 0:
                rows.append(int(x))
                segs.append(i)
    want = ref.segment_gather_sum_ref(
        tj, jnp.asarray(rows, dtype=jnp.int32),
        jnp.asarray(segs, dtype=jnp.int32), s)
    if dtype == np.float32:
        rtol, atol = 1e-6, 1e-6
    else:  # bf16: accumulation-order differences scale with sqrt(k)
        rtol, atol = 0.08, 0.08 * np.sqrt(k)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=rtol, atol=atol)


def test_segment_gather_weighted():
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 32, size=(8, 4)).astype(np.int32))
    w = jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))
    got = segment_gather_fixed_pallas(table, idx, w, interpret=True)
    want = np.zeros((8, 16), np.float32)
    for i in range(8):
        for j in range(4):
            want[i] += np.asarray(table)[int(idx[i, j])] * float(w[i, j])
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


def test_segment_gather_ragged_entry():
    rng = np.random.default_rng(3)
    table = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    e, s = 100, 16
    indices = jnp.asarray(rng.integers(0, 64, size=e).astype(np.int32))
    segments = jnp.asarray(rng.integers(0, s, size=e).astype(np.int32))
    got = segment_gather_sum_pallas(table, indices, segments, s, interpret=True)
    want = ref.segment_gather_sum_ref(table, indices, segments, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------- expand/filter/compact
def _efc_case(rng, r, v, w, tile, with_mask, with_bid):
    degs = rng.integers(0, 6, r).astype(np.int32)
    offs = np.concatenate([[0], np.cumsum(degs)[:-1]]).astype(np.int32)
    total = int(degs.sum())
    m = max(1, total + int(rng.integers(0, 8)))
    nbr = rng.integers(0, v, m).astype(np.int32)
    start = rng.integers(0, max(1, m - 6), r).astype(np.int32)
    bitmap = rng.integers(0, 2**32, (v, w), dtype=np.uint64).astype(np.uint32)
    mask = (rng.integers(0, 2**3, w, dtype=np.uint64).astype(np.uint32)
            if with_mask else np.zeros(w, np.uint32))
    bid = np.int32(rng.integers(0, v)) if with_bid else np.int32(-1)
    cap = tile * max(1, -(-max(1, total) // tile))  # multiple of tile ≥ total
    return (jnp.asarray(nbr), jnp.asarray(bitmap), jnp.asarray(start),
            jnp.asarray(degs), jnp.asarray(offs), jnp.asarray(mask),
            jnp.asarray(bid)), cap


@pytest.mark.parametrize("r,v,w,tile", [(1, 4, 1, 8), (17, 30, 2, 16),
                                        (40, 64, 1, 32), (5, 8, 4, 8)])
@pytest.mark.parametrize("with_mask,with_bid", [(False, False), (True, False),
                                                (True, True)])
def test_expand_filter_compact_shapes(r, v, w, tile, with_mask, with_bid):
    rng = np.random.default_rng(r * 100 + v + w + tile)
    args, cap = _efc_case(rng, r, v, w, tile, with_mask, with_bid)
    got = expand_filter_compact_pallas(*args, capacity=cap, interpret=True,
                                       tile=tile)
    want = ref.expand_filter_compact_ref(*args, cap)
    for g_, w_, name in zip(got, want, ("v_out", "row_out", "count")):
        np.testing.assert_array_equal(np.asarray(g_), np.asarray(w_),
                                      err_msg=name)


@given(st.integers(1, 40), st.integers(2, 40), st.integers(1, 2),
       st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_expand_filter_compact_property(r, v, w, seed):
    """Pallas (interpret) vs oracle vs brute force on random ragged CSR
    slices, label bitmaps, and bound-id probes."""
    rng = np.random.default_rng(seed)
    args, cap = _efc_case(rng, r, v, w, 16, with_mask=bool(seed % 2),
                          with_bid=seed % 3 == 0)
    nbr, bitmap, start, degs, offs, mask, bid = map(np.asarray, args)
    got_v, got_r, got_c = expand_filter_compact_pallas(
        *args, capacity=cap, interpret=True, tile=16)
    # brute-force the survivor stream
    stream = []
    for i in range(r):
        for j in range(degs[i]):
            k = int(start[i]) + j
            if k >= nbr.shape[0]:
                continue
            vv = int(nbr[k])
            if not all((bitmap[vv] & mask) == mask):
                continue
            if int(bid) >= 0 and vv != int(bid):
                continue
            stream.append((vv, i))
    assert int(got_c) == len(stream)
    for k, (vv, rr) in enumerate(stream):
        assert int(got_v[k]) == vv and int(got_r[k]) == rr
    assert all(int(x) == -1 for x in np.asarray(got_v)[len(stream):])


# ------------------------------------------------------------ ragged expand
@given(st.lists(st.integers(0, 9), min_size=1, max_size=30),
       st.integers(1, 128))
@settings(max_examples=40, deadline=None)
def test_ragged_expand_property(degs, extra_cap):
    degs_np = np.asarray(degs, dtype=np.int32)
    total = int(degs_np.sum())
    cap = total + extra_cap
    offs = np.concatenate([[0], np.cumsum(degs_np)[:-1]]).astype(np.int32)
    row, j, valid = ref.ragged_expand_ref(jnp.asarray(offs),
                                          jnp.asarray(degs_np), cap)
    row, j, valid = map(np.asarray, (row, j, valid))
    assert valid.sum() == total
    # every (row, j) pair with j < deg appears exactly once
    want = {(r, x) for r, d in enumerate(degs) for x in range(d)}
    got = {(int(row[k]), int(j[k])) for k in range(cap) if valid[k]}
    assert got == want


# ------------------------------------------------------------- delta_merge
def _brute_delta_merge(base, delta, tomb, bs, bd, ds, tlo, thi, j, valid):
    v = np.full(j.shape, -1, np.int32)
    ok = np.zeros(j.shape, bool)
    for k in range(j.shape[0]):
        if not valid[k]:
            continue
        if j[k] < bd[k]:
            cand = base[bs[k] + j[k]]
            dead = cand in set(tomb[tlo[k]:thi[k]])
        else:
            cand = delta[ds[k] + (j[k] - bd[k])]
            dead = False
        v[k] = cand
        ok[k] = not dead
    return v, ok


def _delta_merge_case(rng, k, mb, md, mt):
    base = np.sort(rng.integers(0, 60, size=mb)).astype(np.int32)
    delta = np.sort(rng.integers(0, 60, size=md)).astype(np.int32)
    # tombstones: sorted runs drawn from base values
    tomb = np.sort(rng.choice(base, size=min(mt, mb),
                              replace=False)).astype(np.int32)
    bd = rng.integers(0, 5, size=k).astype(np.int32)
    dd = rng.integers(0, 4, size=k).astype(np.int32)
    bs = rng.integers(0, max(1, mb - 5), size=k).astype(np.int32)
    ds = rng.integers(0, max(1, md - 4), size=k).astype(np.int32)
    tlo = rng.integers(0, tomb.shape[0] + 1, size=k).astype(np.int32)
    thi = np.minimum(tomb.shape[0],
                     tlo + rng.integers(0, 4, size=k)).astype(np.int32)
    j = rng.integers(0, 8, size=k).astype(np.int32)
    valid = (j < bd + dd) & (rng.random(k) > 0.1)
    return base, delta, tomb, bs, bd, ds, tlo, thi, j, valid


@pytest.mark.parametrize("k,mb,md,mt", [(1, 8, 4, 2), (64, 200, 30, 40),
                                        (1000, 4096, 257, 600)])
def test_delta_merge_oracle_vs_brute(k, mb, md, mt):
    rng = np.random.default_rng(k + mb)
    case = _delta_merge_case(rng, k, mb, md, mt)
    base, delta, tomb, bs, bd, ds, tlo, thi, j, valid = case
    got_v, got_ok = ref.delta_merge_ref(
        jnp.asarray(base), jnp.asarray(delta), jnp.asarray(tomb),
        jnp.asarray(bs), jnp.asarray(bd), jnp.asarray(ds),
        jnp.asarray(tlo), jnp.asarray(thi), jnp.asarray(j),
        jnp.asarray(valid))
    want_v, want_ok = _brute_delta_merge(base, delta, tomb, bs, bd, ds,
                                         tlo, thi, j, valid)
    np.testing.assert_array_equal(np.asarray(got_v), want_v)
    np.testing.assert_array_equal(np.asarray(got_ok) & np.asarray(valid),
                                  want_ok & valid)


@pytest.mark.parametrize("k,mb,md,mt", [(5, 16, 8, 4), (300, 1000, 64, 128)])
def test_delta_merge_pallas_matches_ref(k, mb, md, mt):
    from repro.kernels.delta_merge import delta_merge_pallas

    rng = np.random.default_rng(7 * k + mt)
    case = _delta_merge_case(rng, k, mb, md, mt)
    args = tuple(jnp.asarray(a) for a in case)
    ref_v, ref_ok = ref.delta_merge_ref(*args)
    got_v, got_ok = delta_merge_pallas(*args, interpret=True, tile=64)
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(ref_v))
    np.testing.assert_array_equal(np.asarray(got_ok), np.asarray(ref_ok))


def test_delta_merge_labeled_composite_masking():
    # base plain CSR of one source: neighbors (2, el 0), (2, el 1), (3, el 0)
    base_nbr = jnp.asarray(np.array([2, 2, 3], np.int32))
    base_lab = jnp.asarray(np.array([0, 1, 0], np.int32))
    delta_nbr = jnp.asarray(np.array([9], np.int32))
    delta_lab = jnp.asarray(np.array([1], np.int32))
    n_el = 2
    # tombstone exactly (nbr=2, el=1) -> key 5
    tomb_key = jnp.asarray(np.array([5], np.int32))
    k = 4
    z = lambda v: jnp.asarray(np.full(k, v, np.int32))  # noqa: E731
    j = jnp.asarray(np.arange(k, dtype=np.int32))
    v, el, ok = ref.delta_merge_labeled_ref(
        base_nbr, base_lab, delta_nbr, delta_lab, tomb_key,
        z(0), z(3), z(0), z(0), z(1), j,
        jnp.asarray(np.ones(k, bool)), n_el)
    np.testing.assert_array_equal(np.asarray(v), [2, 2, 3, 9])
    np.testing.assert_array_equal(np.asarray(el), [0, 1, 0, 1])
    # only the (2, el=1) candidate is tombstoned; delta slot never is
    np.testing.assert_array_equal(np.asarray(ok), [True, False, True, True])
