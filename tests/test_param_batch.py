"""Parameterized plan cache + vmapped same-shape batch dispatch.

The core contract under test: for any family of constants over one query
shape, ``execute_param_batch`` (one vmapped device launch) returns results
bit-identical to per-query ``execute_param``, which in turn matches the
unparameterized compile/execute path — including on ``VersionedStore``
snapshots and for shapes with DISTINCT/LIMIT modifiers.  Shapes that
cannot be parameterized (OPTIONAL/UNION) must cleanly fall back.
"""

import re
import threading

import numpy as np
import pytest

from conftest import given, settings, st

from repro.core.sparql_exec import SparqlEngine
from repro.serve.fingerprint import parameterize_query
from repro.serve.scheduler import Scheduler
from repro.serve.server import DatasetRegistry

TMPL_COURSE = """SELECT ?x WHERE {{
  ?x rdf:type ub:GraduateStudent .
  ?x ub:takesCourse {c} .
}}"""

TMPL_TWO_CONST = """SELECT ?x ?y WHERE {{
  ?x rdf:type ub:Student .
  ?x ub:memberOf {d} .
  ?x ub:takesCourse ?y .
  ?y rdf:type ub:Course .
  ?z ub:teacherOf ?y .
  ?z ub:worksFor {d2} .
}}"""


@pytest.fixture(scope="module")
def lubm_env(lubm_graph):
    g, maps = lubm_graph
    eng = SparqlEngine(g, maps)
    terms = maps.dict.terms.to_str
    courses = [t for t in terms if re.match(r"ub:GraduateCourse\d", t)]
    depts = [t for t in terms if re.match(r"ub:Dept\d", t)]
    assert len(courses) >= 3 and len(depts) >= 2
    return eng, courses, depts


def _rows_set(res):
    return sorted(map(tuple, res.rows.tolist()))


def _check_family(eng, queries):
    """Batch == sequential == unparameterized, for one shape family."""
    pqs = [parameterize_query(q) for q in queries]
    assert len({pq.shape for pq in pqs}) == 1
    fam = eng.compile_param(pqs[0])
    assert fam is not None
    seq = [eng.execute_param(fam, pq.consts) for pq in pqs]
    bat = eng.execute_param_batch(fam, [pq.consts for pq in pqs])
    for s, b in zip(seq, bat):
        assert s.count == b.count
        assert np.array_equal(s.rows, b.rows)  # bit-identical, order too
    for pq, s in zip(pqs, seq):
        ref = eng.query_ast(pq.canon.query)
        assert ref.count == s.count
        assert _rows_set(ref) == _rows_set(s)
    return seq


@given(st.lists(st.integers(0, 10_000), min_size=2, max_size=6))
@settings(max_examples=8, deadline=None)
def test_batch_matches_sequential_random_constants(lubm_env, idxs):
    eng, courses, _ = lubm_env
    picks = [courses[i % len(courses)] for i in idxs]
    _check_family(eng, [TMPL_COURSE.format(c=c) for c in picks])


@given(st.lists(st.integers(0, 10_000), min_size=2, max_size=4),
       st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_batch_matches_sequential_two_constants(lubm_env, idxs, seed2):
    eng, _, depts = lubm_env
    qs = [TMPL_TWO_CONST.format(d=depts[i % len(depts)],
                                d2=depts[(i + seed2) % len(depts)])
          for i in idxs]
    _check_family(eng, qs)


def test_batch_matches_sequential_seeded(lubm_env):
    # deterministic stand-in for the property test when hypothesis is absent
    import random

    eng, courses, _ = lubm_env
    rng = random.Random(7)
    for _ in range(4):
        picks = [rng.choice(courses) for _ in range(rng.randint(2, 6))]
        _check_family(eng, [TMPL_COURSE.format(c=c) for c in picks])


def test_missing_constant_lane_is_empty(lubm_env):
    eng, courses, _ = lubm_env
    qs = [TMPL_COURSE.format(c=courses[0]),
          TMPL_COURSE.format(c="ub:NoSuchCourse999"),
          TMPL_COURSE.format(c=courses[1])]
    seq = _check_family(eng, qs)
    assert seq[1].count == 0


def test_param_batch_on_versioned_snapshot(lubm_graph):
    from repro.store import VersionedStore

    g, maps = lubm_graph
    store = VersionedStore(g, maps, auto_compact=False)
    eng = SparqlEngine(store.snapshot(), maps)
    store.apply_update("""INSERT DATA {
        ub:NewGrad1 a ub:GraduateStudent .
        ub:NewGrad1 ub:takesCourse ub:GraduateCourse0.Dept0.Univ0 .
        ub:NewGrad2 a ub:GraduateStudent .
        ub:NewGrad2 ub:takesCourse ub:GraduateCourse1.Dept0.Univ0 .
    }""")
    eng.set_graph(store.snapshot())
    courses = [t for t in maps.dict.terms.to_str
               if re.match(r"ub:GraduateCourse\d", t)][:4]
    seq = _check_family(eng, [TMPL_COURSE.format(c=c) for c in courses])
    # the delta rows are visible through the parameterized path
    base = SparqlEngine(g, maps).query(TMPL_COURSE.format(c=courses[0]))
    assert seq[0].count == base.count + 1


def test_distinct_and_limit_shapes_parameterize(lubm_env):
    eng, _, depts = lubm_env
    tmpl = """SELECT DISTINCT ?y WHERE {{
      ?x rdf:type ub:Student .
      ?x ub:memberOf {d} .
      ?x ub:takesCourse ?y .
    }} LIMIT 3"""
    qs = [tmpl.format(d=d) for d in depts[:3]]
    pqs = [parameterize_query(q) for q in qs]
    fam = eng.compile_param(pqs[0])
    assert fam is not None and fam.distinct and fam.limit == 3
    seq = [eng.execute_param(fam, pq.consts) for pq in pqs]
    bat = eng.execute_param_batch(fam, [pq.consts for pq in pqs])
    for s, b, pq in zip(seq, bat, pqs):
        assert s.count == b.count and np.array_equal(s.rows, b.rows)
        ref = eng.query_ast(pq.canon.query)
        assert ref.count == s.count
        # DISTINCT sorts via np.unique in both paths — rows are identical
        assert np.array_equal(ref.rows, s.rows)


def test_optional_shape_falls_back(lubm_env):
    eng, courses, _ = lubm_env
    q = """SELECT ?x ?e WHERE {{
      ?x rdf:type ub:GraduateStudent .
      ?x ub:takesCourse {c} .
      OPTIONAL {{ ?x ub:emailAddress ?e . }}
    }}""".format(c=courses[0])
    pq = parameterize_query(q)
    assert eng.compile_param(pq) is None
    # the ineligible verdict is cached — second probe is a hit, still None
    assert eng.compile_param(pq) is None
    assert eng.param_stats.hits >= 1


def test_no_constant_shape_has_no_params(lubm_env):
    eng, _, _ = lubm_env
    pq = parameterize_query(
        "SELECT ?x ?y WHERE { ?x ub:advisor ?y . }")
    assert pq.consts == ()
    assert eng.compile_param(pq) is None


def test_alpha_equivalent_members_share_one_shape(lubm_env):
    _, courses, _ = lubm_env
    a = parameterize_query(TMPL_COURSE.format(c=courses[0]))
    b = parameterize_query("""SELECT ?s WHERE {{
      ?s ub:takesCourse {c} .
      ?s rdf:type ub:GraduateStudent .
    }}""".format(c=courses[1]))
    assert a.shape == b.shape
    assert a.consts != b.consts


def test_structural_predicates_never_hoist(lubm_env):
    _, courses, _ = lubm_env
    pq = parameterize_query(TMPL_COURSE.format(c=courses[0]))
    # the rdf:type object folds into vertex labels, not a parameter
    assert list(pq.consts) == [courses[0]]


def test_scheduler_batch_results_match_direct(lubm_graph):
    g, maps = lubm_graph
    reg = DatasetRegistry(result_cache_size=0)
    reg.register("lubm", g, maps)
    courses = [t for t in maps.dict.terms.to_str
               if re.match(r"ub:GraduateCourse\d", t)][:8]
    ref = {c: reg.execute("lubm", TMPL_COURSE.format(c=c)).count
           for c in courses}
    sched = Scheduler(reg, workers=2, batch_max=8, batch_window_ms=5.0)
    sched.start()
    try:
        results: dict[str, int] = {}

        def go(c):
            results[c] = sched.submit("lubm", TMPL_COURSE.format(c=c)).count

        threads = [threading.Thread(target=go, args=(c,)) for c in courses]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        sched.stop()
    assert results == ref
    m = reg.metrics
    assert m.batch_size.count >= 1
    # with a 5ms window and 8 concurrent same-shape queries, at least one
    # dispatch must have batched two or more
    assert m.coalesced_queries.total() >= 2


def test_scheduler_batch_disabled_still_serves(lubm_graph):
    g, maps = lubm_graph
    reg = DatasetRegistry(result_cache_size=0)
    reg.register("lubm", g, maps)
    courses = [t for t in maps.dict.terms.to_str
               if re.match(r"ub:GraduateCourse\d", t)][:3]
    sched = Scheduler(reg, workers=2, batch_max=1)
    sched.start()
    try:
        for c in courses:
            got = sched.submit("lubm", TMPL_COURSE.format(c=c)).count
            want = reg.execute("lubm", TMPL_COURSE.format(c=c)).count
            assert got == want
    finally:
        sched.stop()
    assert reg.metrics.coalesced_queries.total() == 0
