"""RDF substrate tests: N-Triples parser (round-trip + dirty input),
ontology closure, direct/type-aware transforms (Definition 3 invariants),
LabeledGraph structures, and hypothesis property tests."""

import numpy as np
import pytest

from conftest import given, settings, st

from repro.rdf.dictionary import RDF_TYPE, RDFS_SUBCLASSOF, Dictionary
from repro.rdf.graph import LabeledGraph, pack_bitmap
from repro.rdf.ontology import ClassHierarchy
from repro.rdf.parser import (ParseError, parse_line, parse_ntriples,
                              serialize_ntriples)
from repro.rdf.transform import direct_transform, type_aware_transform
from repro.rdf.triples import TripleStore


# ------------------------------------------------------------------ parser
def test_parse_basic_forms():
    assert parse_line("<http://a> <http://p> <http://b> .") == \
        ("http://a", "http://p", "http://b")
    assert parse_line('ub:x ub:name "hello world" .') == \
        ("ub:x", "ub:name", '"hello world"')
    assert parse_line('a:s a:p "v"^^<http://int> .') == \
        ("a:s", "a:p", '"v"^^<http://int>')
    assert parse_line('a:s a:p "v"@en .') == ("a:s", "a:p", '"v"@en')
    assert parse_line("# comment") is None
    assert parse_line("   ") is None


def test_parse_escaped_literal():
    s, p, o = parse_line(r'a:s a:p "say \"hi\" now" .')
    assert o == r'"say \"hi\" now"'


def test_parse_errors_strict_vs_lenient():
    with pytest.raises(ParseError):
        parse_line("<unterminated iri-less", 3)
    store, stats = parse_ntriples(
        ["a:s a:p a:o .", "<broken", "x:a x:b x:c ."], strict=False)
    assert stats.triples == 2 and stats.skipped == 1


def test_roundtrip():
    triples = [("ub:s", "ub:p", "ub:o"), ("http://a", "http://p", '"lit 1"')]
    lines = list(serialize_ntriples(triples))
    store, stats = parse_ntriples(lines)
    store.finalize()
    assert stats.triples == 2
    assert sorted(store.iter_decoded()) == sorted(triples)


def test_store_dedup():
    st_ = TripleStore()
    for _ in range(3):
        st_.add("a:x", "a:p", "a:y")
    st_.finalize()
    assert st_.n_triples == 1


# ---------------------------------------------------------------- ontology
def test_closure_diamond_and_cycle():
    h = ClassHierarchy()
    # diamond: 0 -> 1,2 -> 3 ; plus a cycle 4 <-> 5
    h.add_subclass(0, 1)
    h.add_subclass(0, 2)
    h.add_subclass(1, 3)
    h.add_subclass(2, 3)
    h.add_subclass(4, 5)
    h.add_subclass(5, 4)
    assert h.superclasses(0) == frozenset({0, 1, 2, 3})
    assert h.superclasses(4) == frozenset({4, 5})  # cycle-safe
    assert h.expand_types({0, 4}) == frozenset({0, 1, 2, 3, 4, 5})


# -------------------------------------------------------------- transforms
def _tiny_store():
    st_ = TripleStore()
    st_.add("ub:Grad", RDFS_SUBCLASSOF, "ub:Student")
    st_.add("ub:Student", RDFS_SUBCLASSOF, "ub:Person")
    st_.add("ub:s1", RDF_TYPE, "ub:Grad")
    st_.add("ub:s2", RDF_TYPE, "ub:Student")
    st_.add("ub:s1", "ub:knows", "ub:s2")
    st_.add("ub:s1", "ub:age", '"25"')
    return st_.finalize()


def test_type_aware_label_closure():
    g, maps = type_aware_transform(_tiny_store())
    v1 = maps.vertex_of("ub:s1")
    lbl_grad = maps.vlabel_of("ub:Grad")
    lbl_student = maps.vlabel_of("ub:Student")
    lbl_person = maps.vlabel_of("ub:Person")
    assert set(g.vlabel_sets[v1]) == {lbl_grad, lbl_student, lbl_person}
    v2 = maps.vertex_of("ub:s2")
    assert set(g.vlabel_sets[v2]) == {lbl_student, lbl_person}
    # class-only vertices are dropped; type/sc triples are not edges
    assert maps.vertex_of("ub:Grad") is None
    assert g.n_edges == 2  # knows + age


def test_type_aware_numeric_literals():
    g, maps = type_aware_transform(_tiny_store())
    v = maps.vertex_of('"25"')
    assert v is not None
    assert g.numeric_value[v] == 25.0


def test_direct_keeps_everything():
    st_ = _tiny_store()
    g, maps = direct_transform(st_)
    assert g.n_edges == st_.n_triples
    assert maps.vertex_of("ub:Grad") is not None  # classes are vertices


def test_table1_shrinkage(lubm_store):
    """Paper Table 1: type-aware graphs are strictly smaller."""
    gd, _ = direct_transform(lubm_store)
    gt, _ = type_aware_transform(lubm_store)
    assert gt.n_edges < gd.n_edges
    assert gt.n_vertices < gd.n_vertices


# ------------------------------------------------------------ graph struct
def test_csr_slices_match_edge_list():
    rng = np.random.default_rng(0)
    n, m, nel = 20, 60, 3
    src = rng.integers(0, n, m)
    el = rng.integers(0, nel, m)
    dst = rng.integers(0, n, m)
    g = LabeledGraph.build(n, src, el, dst, nel, [()] * n, 0)
    edges = {(int(s), int(e), int(d)) for s, e, d in zip(src, el, dst)}
    # out direction
    for v in range(n):
        for e in range(nel):
            sl = g.out.slice_el(e, v)
            assert all((v, e, int(w)) in edges for w in sl)
            assert list(sl) == sorted(sl)
        nbrs, labs = g.out.slice_all(v)
        assert {(v, int(l), int(w)) for w, l in zip(nbrs, labs)} == \
            {t for t in edges if t[0] == v}
    # in direction mirrors out
    assert g.inc.nbr_el.shape == g.out.nbr_el.shape
    for v in range(n):
        for e in range(nel):
            sl = g.inc.slice_el(e, v)
            assert all((int(w), e, v) in edges for w in sl)


def test_inverse_label_index_and_freq():
    labels = [(0,), (0, 1), (1,), (), (0,)]
    g = LabeledGraph.build(5, np.array([0]), np.array([0]), np.array([1]),
                           1, labels, 2)
    assert list(g.vertices_with_label(0)) == [0, 1, 4]
    assert list(g.vertices_with_label(1)) == [1, 2]
    assert g.freq([0]) == 3
    assert g.freq([0, 1]) == 1
    assert g.freq([]) == 5


def test_predicate_index():
    g = LabeledGraph.build(4, np.array([0, 1, 0]), np.array([0, 0, 1]),
                           np.array([2, 2, 3]), 2, [()] * 4, 0)
    subs, objs = g.predicate_index(0)
    assert list(subs) == [0, 1] and list(objs) == [2]


def test_bitmap_pack():
    bm = pack_bitmap([(0, 33), (31,)], 64)
    assert bm.shape == (2, 2)
    assert bm[0, 0] == 1 and bm[0, 1] == 2
    assert bm[1, 0] == np.uint32(1 << 31)


# ------------------------------------------------------- dictionary growth
def test_dictionary_growth_after_finalize():
    """The live store keeps interning after finalize(): existing ids must
    stay stable and new ids must round-trip."""
    st_ = TripleStore()
    st_.add("a:s", "a:p", '"1"')
    st_.add("a:s", "a:q", "a:o")
    st_.finalize()
    d = st_.dict
    before = {t: d.term_id(t) for t in ("a:s", "a:o", '"1"')}
    before_p = {p: d.predicate_id(p) for p in ("a:p", "a:q")}
    n_terms, n_preds = d.n_terms, d.n_predicates
    new_t = d.encode_term("a:later")
    new_lit = d.encode_term('"lit after finalize"')
    new_p = d.encode_predicate("a:newPred")
    assert new_t == n_terms and new_p == n_preds
    assert d.term(new_t) == "a:later"
    assert d.term(new_lit) == '"lit after finalize"'
    assert new_lit in d.literal_ids
    assert d.predicate(new_p) == "a:newPred"
    # pre-existing ids unchanged
    assert {t: d.term_id(t) for t in before} == before
    assert {p: d.predicate_id(p) for p in before_p} == before_p
    # re-interning is idempotent
    assert d.encode_term("a:later") == new_t
    assert d.encode_predicate("a:newPred") == new_p


@given(st.lists(st.text(alphabet="abcXYZ0:_\"", min_size=1, max_size=8),
                min_size=1, max_size=40),
       st.integers(1, 20))
@settings(max_examples=50, deadline=None)
def test_dictionary_growth_property(terms, split):
    """Interning any term stream in two phases (pre/post finalize-style
    cutover) yields stable ids and perfect round-trips."""
    d = Dictionary()
    ids_first = [d.encode_term(t) for t in terms[:split]]
    frozen = {t: d.term_id(t) for t in terms[:split]}
    ids_second = [d.encode_term(t) for t in terms[split:]]
    # phase 1 ids survived phase 2 interning
    assert [d.term_id(t) for t in terms[:split]] == ids_first
    assert {t: d.term_id(t) for t in terms[:split]} == frozen
    # every id round-trips to its term, vlabels/preds spaces untouched
    for t, tid in zip(terms, ids_first + ids_second):
        assert d.term(tid) == t
        assert d.term_id(t) == d.encode_term(t)
    assert d.n_terms == len(set(terms))
    # literal tracking is consistent with the quote convention
    for t in terms:
        if t.startswith('"'):
            assert d.term_id(t) in d.literal_ids


@given(st.integers(2, 25), st.integers(1, 60), st.integers(1, 4),
       st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_graph_build_property(n, m, nel, seed):
    """CSR invariants hold for arbitrary edge multisets."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    el = rng.integers(0, nel, m)
    dst = rng.integers(0, n, m)
    g = LabeledGraph.build(n, src, el, dst, nel, [()] * n, 0)
    uniq = {(int(s), int(e), int(d)) for s, e, d in zip(src, el, dst)}
    assert g.n_edges == len(uniq)  # set semantics
    assert int(g.out.degree.sum()) == len(uniq)
    assert int(g.inc.degree.sum()) == len(uniq)
    # per-el indptr rows are monotone and partition nbr_el
    for e in range(nel):
        row = g.out.indptr_el[e]
        assert (np.diff(row) >= 0).all()
