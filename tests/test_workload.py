"""Workload intelligence: q-error accounting, decision journal, and
observed-cardinality feedback into the planner.

The load-bearing contract: feedback is *purely an estimator override* —
re-planning with observed (or arbitrary clamped) fanouts may reorder the
matching order but must never change the result multiset.  The rest
covers the accounting plumbing: per-step q-errors consistent with
``ExecPlan.est_rows`` across solo and batched paths, the decision
journal, correlation query ids, the ``/debug/workload`` endpoints, and
the report CLI.
"""

import io
import json
import logging
import re
import urllib.request
from urllib.parse import urlencode

import numpy as np
import pytest

from conftest import given, settings, st

from repro.core.sparql_exec import SparqlEngine
from repro.obs import DecisionJournal, Trace, WorkloadProfile, \
    WorkloadProfiler, chrome_trace, qerror, qerror_log10
from repro.rdf.sparql import parse_sparql
from repro.rdf.workloads import BSBM_QUERIES, LUBM_QUERIES
from repro.serve.fingerprint import canonicalize_query, parameterize_query
from repro.serve.metrics import ServeMetrics
from repro.serve.server import DatasetRegistry, make_server, serve_in_thread
from repro.utils.logging import JsonFormatter, log_event


def _rows_set(res):
    return sorted(map(tuple, np.asarray(res.rows).tolist()))


# ------------------------------------------------------------ q-error math
def test_qerror_math():
    assert qerror(10, 10) == 1.0
    assert qerror(0, 0) == 1.0
    assert qerror(99, 9) == pytest.approx(10.0)
    assert qerror(9, 99) == pytest.approx(10.0)  # symmetric
    assert qerror(0, 9) == pytest.approx(10.0)  # +1 smoothing
    assert qerror(-5, 9) == pytest.approx(10.0)  # negative est clamped
    assert qerror_log10(99, 9) == pytest.approx(1.0)
    # log10(qerror) is exactly the abs-log10 ratio the card-error
    # histograms have always recorded
    import math
    for e, a in ((3, 700), (120, 5), (0, 0), (1, 1)):
        assert qerror_log10(e, a) == pytest.approx(
            abs(math.log10((e + 1) / (a + 1))))


# ------------------------------------------------------- decision journal
def test_decision_journal_bounds_and_filter():
    j = DecisionJournal(size=8)
    for i in range(20):
        j.record("plan_cache", hit=i % 2 == 0, i=i)
    j.record("replan", fingerprint="abc")
    assert len(j) == 8  # ring buffer bound
    assert j.counts["plan_cache"] == 20 and j.counts["replan"] == 1
    snap = j.snapshot()
    assert snap[0]["kind"] == "replan"  # newest first
    assert [e["seq"] for e in snap] == sorted(
        (e["seq"] for e in snap), reverse=True)
    only = j.snapshot(kind="plan_cache", limit=3)
    assert len(only) == 3 and all(e["kind"] == "plan_cache" for e in only)
    assert j.snapshot(kind="nope") == []


# ------------------------------------------------------- profile folding
class _FakeStep:
    def __init__(self, u, parent, elabel, forward=True):
        self.u, self.parent, self.elabel, self.forward = \
            u, parent, elabel, forward


class _FakePlan:
    search = "greedy"

    def __init__(self, est_rows, steps, n0=10):
        self.est_rows = est_rows
        self.steps = steps
        self.start_candidates = np.zeros(n0, dtype=np.int32)

    def signature(self):
        return (len(self.steps), tuple(self.est_rows))


def _fake_stats(kept, expanded=None, prune_in=None, prune_out=None, **kw):
    st_ = {"step_kept": kept,
           "step_rows": expanded or kept,
           "step_retries": [0] * len(kept),
           "step_prune_in": prune_in or [-1] * len(kept),
           "step_prune_out": prune_out or [-1] * len(kept)}
    st_.update(kw)
    return st_


def test_profile_fold_and_observed_fanouts():
    plan = _FakePlan([100.0, 50.0],
                     [_FakeStep(1, 0, 2), _FakeStep(2, 1, 3, forward=False)],
                     n0=10)
    p = WorkloadProfile("lubm", "k")
    p.fold(plan, _fake_stats([20, 40], expanded=[30, 80],
                             step_kernels=["expand_filter", "ragged_expand"]),
           count=40, wall_ms=5.0)
    p.fold(plan, _fake_stats([10, 20], expanded=[15, 40]),
           count=20, wall_ms=3.0)
    assert p.runs == 2 and p.rows_total == 60
    # ratio of sums: step0 in = 10+10 starts, kept = 30
    fan = p.observed_fanouts()
    assert fan[(1, 0, 2, True)][0] == pytest.approx(30 / 20)
    assert fan[(1, 0, 2, True)][1] == pytest.approx(45 / 20)  # raw
    # step1 inputs are step0's kept rows
    assert fan[(2, 1, 3, False)][0] == pytest.approx(60 / 30)
    assert p.kernels == {"expand_filter": 1, "ragged_expand": 1}
    # worst-step q-error per run: run1 step0 = 101/21
    assert p.run_qerrs[0] == pytest.approx(101 / 21)
    snap = p.snapshot()
    assert snap["runs"] == 2 and len(snap["steps"]) == 2
    assert snap["steps"][0]["obs_fanout"] == pytest.approx(1.5)
    # signature change resets step state but not run counters
    plan2 = _FakePlan([100.0], [_FakeStep(1, 0, 2)], n0=10)
    p.fold(plan2, _fake_stats([100]), count=100, wall_ms=1.0)
    assert p.runs == 3 and p.n_steps == 1


def test_profile_skips_restart_and_sentinel_steps():
    plan = _FakePlan([100.0, 50.0],
                     [_FakeStep(1, 0, 2), _FakeStep(2, -1, 0)], n0=10)
    p = WorkloadProfile("lubm", "k")
    p.fold(plan, _fake_stats([20, 40], prune_in=[100, -1],
                             prune_out=[60, -1]),
           count=40, wall_ms=1.0)
    fan = p.observed_fanouts()
    assert (1, 0, 2, True) in fan
    assert all(k[1] >= 0 for k in fan)  # restart step excluded
    snap = p.snapshot()
    assert snap["steps"][0]["prune_ratio"] == pytest.approx(0.4)
    assert "prune_ratio" not in snap["steps"][1]  # -1 sentinel skipped


def test_profiler_replan_trigger_and_bounds():
    prof = WorkloadProfiler(feedback=True, qerror_threshold=2.0, min_runs=2,
                            max_replans=1, journal=DecisionJournal())
    plan = _FakePlan([1000.0], [_FakeStep(1, 0, 2)], n0=10)
    bad = _fake_stats([5])  # est 1000 vs actual 5 => q-error huge
    assert prof.observe("d", "k", plan, bad, count=5, wall_ms=1.0,
                        fingerprint="fp1") is None  # below min_runs
    hint = prof.observe("d", "k", plan, bad, count=5, wall_ms=1.0,
                        fingerprint="fp1")
    assert hint is not None and hint["fingerprint"] == "fp1"
    assert hint["version"] == 1 and hint["q_error_median"] > 2.0
    assert (1, 0, 2, True) in hint["fanouts"]
    # run counter resets: no immediate re-trigger, and max_replans caps it
    for _ in range(5):
        assert prof.observe("d", "k", plan, bad, count=5, wall_ms=1.0,
                            fingerprint="fp1") is None
    # feedback off => never a hint
    off = WorkloadProfiler(feedback=False, qerror_threshold=2.0, min_runs=1)
    for _ in range(3):
        assert off.observe("d", "k", plan, bad, count=5, wall_ms=1.0,
                           fingerprint="fp1") is None


def test_profiler_lru_bound():
    prof = WorkloadProfiler(max_profiles=4)
    plan = _FakePlan([10.0], [_FakeStep(1, 0, 0)], n0=5)
    for i in range(10):
        prof.observe("d", f"k{i}", plan, _fake_stats([10]), count=10,
                     wall_ms=1.0)
    assert len(prof) == 4 and prof.evictions == 6
    keys = {p["plan_key"] for p in prof.snapshot()}
    assert keys == {"k6", "k7", "k8", "k9"}


# ------------------------------------------- engine q-error + feedback
@pytest.fixture(scope="module")
def lubm_engine(lubm_graph):
    g, maps = lubm_graph
    return SparqlEngine(g, maps)


def test_explain_analyze_qerror_columns(lubm_engine):
    out = lubm_engine.explain(LUBM_QUERIES["Q2"], analyze=True)
    assert out["q_error"] >= 1.0
    assert out["q_error"] == pytest.approx(
        qerror(out["est_total_rows"], out["actual_rows"]), abs=1e-3)
    steps = out["branches"][0]["steps"]
    assert any("q_error" in s for s in steps)
    for s in steps:
        if "q_error" in s:
            assert s["q_error"] == pytest.approx(
                qerror(s["est_rows"], s["actual_rows"]), abs=1e-3)


@given(qname=st.sampled_from(["Q1", "Q2", "Q4", "Q7"]))
@settings(max_examples=4, deadline=None)
def test_step_qerror_consistent_with_est_rows(lubm_engine, qname):
    """Property: per-step q-error derivable from Result.stats equals the
    explain(analyze) column, and both come from ExecPlan.est_rows."""
    canon = canonicalize_query(parse_sparql(LUBM_QUERIES[qname]))
    compiled = lubm_engine.compile_canonical(canon)
    res = lubm_engine.execute_compiled(compiled)
    plan = compiled.branches[0].plan
    base = res.stats["exec"]["branches"][0]["base"]
    kept = base["step_kept"]
    assert len(kept) == len(plan.steps)
    out = lubm_engine.describe_compiled(compiled, run_stats=res.stats)
    for i, s in enumerate(out["branches"][0]["steps"]):
        if "q_error" in s and i < len(kept):
            assert s["q_error"] == pytest.approx(
                qerror(plan.est_rows[i], kept[i]), abs=1e-3)


@given(qname=st.sampled_from(["Q2", "Q7"]),
       fans=st.lists(st.floats(min_value=1e-4, max_value=1e6,
                               allow_nan=False), min_size=1, max_size=8))
@settings(max_examples=8, deadline=None)
def test_feedback_arbitrary_fanouts_never_change_results(lubm_engine,
                                                         qname, fans):
    """Property: ANY clamped fanout override is purely an estimator
    change — the replanned order may differ, the result multiset not."""
    eng = lubm_engine
    eng.clear_feedback()
    canon = canonicalize_query(parse_sparql(LUBM_QUERIES[qname]))
    baseline = eng.execute_compiled(eng.compile_canonical(canon))
    plan = eng.compile_canonical(canon).branches[0].plan
    fanouts = {}
    for i, step in enumerate(plan.steps):
        if step.parent >= 0:
            f = fans[i % len(fans)]
            fanouts[(int(step.u), int(step.parent), int(step.elabel),
                     bool(step.forward))] = (f, f)
    try:
        eng.apply_feedback(canon.fingerprint, fanouts)
        compiled = eng.compile_canonical(canon)
        res = eng.execute_compiled(compiled)
        assert res.count == baseline.count
        assert _rows_set(res) == _rows_set(baseline)
        if fanouts:
            assert compiled.branches[0].plan.search.endswith("+fb1")
    finally:
        eng.clear_feedback()


def test_feedback_replan_e2e_preserves_results(lubm_graph):
    """The acceptance loop: with feedback enabled, misestimated shapes get
    re-planned with observed fanouts after min_runs, and every round's
    results stay bit-identical (as multisets) to the pre-replan round."""
    g, maps = lubm_graph
    registry = DatasetRegistry(ServeMetrics(), feedback=True,
                               qerror_threshold=1.5, feedback_min_runs=2)
    registry.register("lubm", g, maps)
    names = ["Q1", "Q2", "Q4", "Q7", "Q9"]
    rounds = []
    for _ in range(3):
        rounds.append({n: registry.execute("lubm", LUBM_QUERIES[n])
                       for n in names})
    # at least one shape crossed the q-error threshold and was re-planned
    fb = registry.get("lubm").engine.feedback_snapshot()
    assert fb, "no feedback replan triggered on deliberately misestimated " \
               "LUBM shapes"
    assert registry.metrics.feedback_replans.total() >= 1
    replanned = [p for p in registry.workload.snapshot() if p["replans"]]
    assert replanned and any("+fb" in (p["search"] or "")
                             for p in replanned)
    assert any(e["kind"] == "replan" for e in registry.journal.snapshot())
    # round 1 ran before any feedback could trigger (min_runs=2) — it is
    # the feedback-free baseline; round 3 ran on re-planned plans
    for n in names:
        assert rounds[2][n].count == rounds[0][n].count, n
        assert _rows_set(rounds[2][n]) == _rows_set(rounds[0][n]), n


def test_feedback_off_by_default(lubm_graph):
    g, maps = lubm_graph
    registry = DatasetRegistry(ServeMetrics())
    registry.register("lubm", g, maps)
    for _ in range(3):
        registry.execute("lubm", LUBM_QUERIES["Q2"])
    assert not registry.get("lubm").engine.feedback_snapshot()
    assert not registry.workload.feedback
    # profiles and the journal still accumulate
    assert len(registry.workload) >= 1
    assert registry.journal.counts["execute"] == 3


# --------------------------------------------------- batched-path stats
def test_param_batch_stats_carry_qerror(lubm_graph):
    g, maps = lubm_graph
    terms = maps.dict.terms.to_str
    courses = [t for t in terms if re.match(r"ub:GraduateCourse\d", t)][:3]
    assert len(courses) == 3
    registry = DatasetRegistry(ServeMetrics())
    registry.register("lubm", g, maps)
    tmpl = """SELECT ?x WHERE {{
      ?x rdf:type ub:GraduateStudent .
      ?x ub:takesCourse {c} .
    }}"""
    pqs = [parameterize_query(tmpl.format(c=c)) for c in courses]
    out = registry.execute_canonical_batch("lubm", pqs, 0)
    assert not any(isinstance(r, Exception) for r in out)
    for r in out:
        # satellite: cardinality metrics on the batch path too
        assert r.stats.get("est_rows") is not None
        card = r.stats.get("step_card")
        assert card and all(est >= 0 for est, _ in card)
        base = r.stats["exec"]["branches"][0]["base"]
        assert [a for _, a in card] == list(base["step_kept"])[:len(card)]
    assert registry.metrics.card_error._count > 0
    # the shape got a workload profile under its shape: key
    keys = [p["plan_key"] for p in registry.workload.snapshot()]
    assert any(k.startswith("shape:") for k in keys)
    assert registry.journal.counts["batch"] == 1


# ----------------------------------------------------------- HTTP surface
@pytest.fixture(scope="module")
def http_mixed(lubm_graph, bsbm_graph):
    g, maps = lubm_graph
    bg, bmaps = bsbm_graph
    registry = DatasetRegistry(ServeMetrics(), feedback=True,
                               qerror_threshold=1.5, feedback_min_runs=2)
    registry.register("lubm", g, maps)
    registry.register("bsbm", bg, bmaps)
    server = make_server(registry, port=0, workers=2,
                         default_timeout_s=120.0)
    serve_in_thread(server)
    yield server
    server.shutdown()
    server.scheduler.stop()


def _get(server, path):
    host, port = server.server_address[:2]
    with urllib.request.urlopen(f"http://{host}:{port}{path}",
                                timeout=120) as r:
        return json.loads(r.read()), dict(r.headers)


def test_http_workload_debug_endpoints(http_mixed):
    server = http_mixed
    bsbm_q = sorted(BSBM_QUERIES)[0]
    for _ in range(3):
        for ds, q in (("lubm", LUBM_QUERIES["Q2"]),
                      ("lubm", LUBM_QUERIES["Q4"]),
                      ("bsbm", BSBM_QUERIES[bsbm_q])):
            out, headers = _get(
                server, "/sparql?" + urlencode({"query": q, "dataset": ds}))
            # correlation id: response field + header agree
            assert re.fullmatch(r"[0-9a-f]{6}-\d{6}", out["query_id"])
            assert headers["X-Repro-Query-Id"] == out["query_id"]
    wl, _ = _get(server, "/debug/workload")
    assert wl["profiles"], "workload profiles empty after mixed run"
    assert {p["dataset"] for p in wl["profiles"]} == {"lubm", "bsbm"}
    assert all(p["runs"] >= 1 and p["q_error_median"] >= 1.0
               for p in wl["profiles"])
    assert wl["feedback_enabled"] is True
    dec, _ = _get(server, "/debug/decisions")
    assert dec["decisions"] and dec["counts"]["execute"] > 0
    kinds = {e["kind"] for e in dec["decisions"]}
    assert "plan_cache" in kinds and "execute" in kinds
    assert all(e["query_id"] for e in dec["decisions"]
               if e["kind"] == "execute")
    filt, _ = _get(server, "/debug/decisions?kind=plan_cache&limit=2")
    assert len(filt["decisions"]) <= 2
    assert all(e["kind"] == "plan_cache" for e in filt["decisions"])
    host, port = server.server_address[:2]
    with urllib.request.urlopen(f"http://{host}:{port}/metrics",
                                timeout=60) as r:
        text = r.read().decode()
    assert 'repro_qerror_log10_count{scope="query"}' in text
    assert "repro_decisions_total" in text


def test_query_id_threads_into_trace(http_mixed):
    res = http_mixed.scheduler.submit("lubm", LUBM_QUERIES["Q1"],
                                      trace=True, timeout_s=120.0)
    qid = res.stats["query_id"]
    assert re.fullmatch(r"[0-9a-f]{6}-\d{6}", qid)
    tr = res.stats["trace"]
    assert tr["query_id"] == qid
    assert tr["dataset"] == "lubm"
    assert tr["thread"].startswith("serve-worker-")
    # the slow-log keeps the same trace, findable by id
    entry = http_mixed.registry.find_trace(tr["id"])
    assert entry is not None and entry["trace"].query_id == qid


# ------------------------------------------------ chrome trace metadata
def test_chrome_trace_process_thread_metadata():
    t1 = Trace("query")
    with t1.span("execute"):
        pass
    t1.finish()
    t1.dataset, t1.query_id, t1.thread = "lubm", "abc123-000001", "worker-0"
    t2 = Trace("query")
    t2.finish()  # unlabeled: default process lane
    doc = chrome_trace([t1, t2])
    procs = {e["args"]["name"] for e in doc["traceEvents"]
             if e.get("name") == "process_name"}
    assert procs == {"dataset:lubm", "repro"}
    threads = {e["args"]["name"] for e in doc["traceEvents"]
               if e.get("name") == "thread_name"}
    assert "worker-0 abc123-000001" in threads
    # distinct pids per dataset lane
    pids = {e["pid"] for e in doc["traceEvents"]
            if e.get("name") == "process_name"}
    assert len(pids) == 2


# --------------------------------------------------------- JSON logging
def test_log_event_json_format():
    logger = logging.getLogger("repro.test.workload")
    logger.setLevel(logging.INFO)
    buf = io.StringIO()
    handler = logging.StreamHandler(buf)
    handler.setFormatter(JsonFormatter())
    logger.addHandler(handler)
    logger.propagate = False
    try:
        log_event(logger, "sparql", query_id="ab12cd-000007",
                  dataset="lubm", status="ok", ms=1.25, count=42)
    finally:
        logger.removeHandler(handler)
    rec = json.loads(buf.getvalue())
    assert rec["event"] == "sparql" and rec["query_id"] == "ab12cd-000007"
    assert rec["dataset"] == "lubm" and rec["count"] == 42
    assert rec["level"] == "info" and "ts" in rec


def test_log_event_text_format():
    logger = logging.getLogger("repro.test.workload2")
    logger.setLevel(logging.INFO)
    buf = io.StringIO()
    handler = logging.StreamHandler(buf)
    handler.setFormatter(logging.Formatter("%(message)s"))
    logger.addHandler(handler)
    logger.propagate = False
    try:
        log_event(logger, "sparql", query_id="x", status="ok")
    finally:
        logger.removeHandler(handler)
    assert buf.getvalue().strip() == "sparql query_id=x status=ok"


# ------------------------------------------------------------ report CLI
def test_report_builds_from_snapshots(lubm_graph, tmp_path):
    from repro.obs.report import build_report, main, render_markdown

    g, maps = lubm_graph
    registry = DatasetRegistry(ServeMetrics(), trace_sample=1.0)
    registry.register("lubm", g, maps)
    for _ in range(2):
        registry.execute("lubm", LUBM_QUERIES["Q2"])
    report = build_report(workload=registry.workload_snapshot(),
                          slow=registry.slow_summaries())
    assert report["workload"]["n_profiles"] >= 1
    md = render_markdown(report)
    assert "# Workload report" in md and "misestimated" in md
    # round-trip through files + the CLI entry point
    wl_path = tmp_path / "wl.json"
    wl_path.write_text(json.dumps(registry.workload_snapshot()))
    bench_path = tmp_path / "bench.csv"
    bench_path.write_text("name,us_per_call,derived\n"
                          "kernels.expand,12.5,\n"
                          "_meta.total_seconds,2000000,\n")
    out_path = tmp_path / "report.md"
    assert main(["--workload", str(wl_path), "--bench-csv", str(bench_path),
                 "--out", str(out_path)]) == 0
    text = out_path.read_text()
    assert "Bench summary" in text and "kernels.expand" in text
    assert main(["--workload", str(wl_path), "--format", "json",
                 "--out", str(out_path)]) == 0
    assert json.loads(out_path.read_text())["workload"]["n_profiles"] >= 1
