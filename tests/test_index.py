"""repro.index tests: signature/summary construction, pruning soundness
(prune-on == prune-off, including across live-store update streams and
compaction), and the incremental-maintenance exactness contract
(patched index/summary bit-identical to a from-scratch rebuild)."""

import numpy as np
import pytest

from conftest import given, random_labeled_graph, random_query_graph, settings, st

from repro.core import ExecOpts, Executor, SparqlEngine, build_plan
from repro.index import (SignatureIndex, get_index, get_summary, patch_index,
                         patch_summary, prune_candidates, required_signature,
                         signature_rows)
from repro.index.signature import sig_bits
from repro.index.summary import SummaryGraph, primary_classes
from repro.rdf.workloads import LUBM_QUERIES
from repro.store.versioned import VersionedStore


# --------------------------------------------------------------------------
# signature construction
# --------------------------------------------------------------------------


def _brute_sig(g, v, n_bits):
    w = (n_bits + 31) // 32
    row = np.zeros(2 * w, np.uint32)
    for d, off in ((g.out, 0), (g.inc, w)):
        for el in d.lab_all[d.indptr_all[v]:d.indptr_all[v + 1]]:
            t = int(el) % n_bits
            row[off + (t >> 5)] |= np.uint32(1 << (t & 31))
    return row


def _check_sig_build(seed):
    rng = np.random.default_rng(seed)
    g = random_labeled_graph(rng, n_vertices=12, n_elabels=5, p_edge=0.3)
    idx = get_index(g)
    assert idx.n_bits == sig_bits(g.n_elabels)
    for v in range(g.n_vertices):
        np.testing.assert_array_equal(idx.sig[v], _brute_sig(g, v, idx.n_bits))


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_signature_build_matches_brute_force(seed):
    _check_sig_build(seed * 7919 + 5)


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_signature_build_matches_brute_force_property(seed):
    _check_sig_build(seed)


def test_signature_fold_width_bounded():
    rng = np.random.default_rng(0)
    g = random_labeled_graph(rng, n_vertices=8, n_elabels=4, p_edge=0.4)
    idx = get_index(g)
    assert idx.sig.shape == (8, 2 * ((idx.n_bits + 31) // 32))
    assert get_index(g) is idx  # cached on the graph


def test_prune_candidates_sound_superset():
    """Every vertex that actually matches the query vertex survives."""
    rng = np.random.default_rng(7)
    g = random_labeled_graph(rng, n_vertices=15, n_elabels=4, p_edge=0.35)
    q = random_query_graph(rng, g, n_qv=3, with_id=False)
    from repro.core.reference import enumerate_matches

    matches = enumerate_matches(g, q)
    for u in range(q.n_vertices):
        valid = {m[0][u] for m in matches}
        cands = np.arange(g.n_vertices, dtype=np.int32)
        kept = set(prune_candidates(g, q, u, cands).tolist())
        assert valid <= kept


def test_required_signature_skips_other_optional_groups():
    """Edges into a different optional group are not required (left join)."""
    rng = np.random.default_rng(3)
    g = random_labeled_graph(rng, n_vertices=8, n_elabels=6, p_edge=0.3)
    q = random_query_graph(rng, g, n_qv=3, with_id=False, p_extra_edge=0.0)
    n_bits = sig_bits(g.n_elabels)
    full = required_signature(n_bits, q, 0)
    # push every other vertex into a foreign optional group: only
    # self-incident requirements may remain
    groups = {v: 1 for v in range(1, q.n_vertices)}
    relaxed = required_signature(n_bits, q, 0, groups)
    assert np.all((full & relaxed) == relaxed)  # relaxed ⊆ full
    # edges inside u's own group still count
    groups0 = dict(groups)
    groups0[0] = 1
    assert np.array_equal(required_signature(n_bits, q, 0, groups0), full)


# --------------------------------------------------------------------------
# pruning never drops a valid match (the core soundness property)
# --------------------------------------------------------------------------


def _solutions(g, opts, q):
    plan = build_plan(g, q, estimate="static", use_sig=opts.use_prune)
    res = Executor(g, opts).run(plan)
    return sorted(map(tuple, res.bindings.tolist()))


def _check_prune_equiv(seed):
    rng = np.random.default_rng(seed)
    g = random_labeled_graph(rng, n_vertices=11, n_elabels=4, p_edge=0.3)
    q = random_query_graph(rng, g, n_qv=4)
    on = _solutions(g, ExecOpts(), q)
    off = _solutions(g, ExecOpts(use_prune=False), q)
    assert on == off


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5])
def test_prune_on_equals_prune_off(seed):
    _check_prune_equiv(seed * 104729 + 13)


@given(st.integers(0, 100_000))
@settings(max_examples=20, deadline=None)
def test_prune_on_equals_prune_off_property(seed):
    _check_prune_equiv(seed)


def _check_prune_equiv_live(seed):
    """Random insert/delete stream through VersionedStore: prune-on and
    prune-off agree on every snapshot and after compaction."""
    rng = np.random.default_rng(seed)
    g = random_labeled_graph(rng, n_vertices=10, n_elabels=3, p_edge=0.25)
    q = random_query_graph(rng, g, n_qv=3, with_id=False)
    get_index(g)  # warm so compaction exercises patch_index
    get_summary(g)
    store = VersionedStore(g, auto_compact=False)
    for _ in range(3):
        n_ins = int(rng.integers(1, 8))
        store.insert_edges(
            [(int(rng.integers(g.n_vertices)), int(rng.integers(g.n_elabels)),
              int(rng.integers(g.n_vertices))) for _ in range(n_ins)])
        rows = np.repeat(np.arange(g.n_vertices), np.diff(g.out.indptr_all))
        if rows.size:
            k = int(rng.integers(0, min(4, rows.size) + 1))
            pick = rng.choice(rows.size, k, replace=False)
            store.delete_edges(
                [(int(rows[i]), int(g.out.lab_all[i]), int(g.out.nbr_all[i]))
                 for i in pick])
        snap = store.snapshot()
        on = _solutions(snap, ExecOpts(), q)
        off = _solutions(snap, ExecOpts(use_prune=False), q)
        assert on == off
    snap = store.compact()
    assert _solutions(snap, ExecOpts(), q) == \
        _solutions(snap, ExecOpts(use_prune=False), q)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_prune_equivalence_under_update_stream(seed):
    _check_prune_equiv_live(seed * 31337 + 7)


@given(st.integers(0, 100_000))
@settings(max_examples=8, deadline=None)
def test_prune_equivalence_under_update_stream_property(seed):
    _check_prune_equiv_live(seed)


def test_prune_equivalence_lubm_live(lubm_graph):
    """LUBM engine-level equivalence on a live store: fresh snapshot after
    updates, then after compaction."""
    g, maps = lubm_graph
    get_index(g)
    get_summary(g)
    store = VersionedStore(g, maps, auto_compact=False)
    rng = np.random.default_rng(11)
    rows = np.repeat(np.arange(g.n_vertices), np.diff(g.out.indptr_all))
    pick = rng.choice(rows.size, 40, replace=False)
    store.delete_edges(
        [(int(rows[i]), int(g.out.lab_all[i]), int(g.out.nbr_all[i]))
         for i in pick])
    store.insert_edges(
        [(int(rng.integers(g.n_vertices)), int(rng.integers(g.n_elabels)),
          int(rng.integers(g.n_vertices))) for _ in range(60)])
    for snap in (store.snapshot(), store.compact()):
        on = SparqlEngine(snap, maps, opts=ExecOpts())
        off = SparqlEngine(snap, maps, opts=ExecOpts(use_prune=False))
        for name in ("Q1", "Q2", "Q4", "Q8", "Q9", "Q12"):
            a = on.count(LUBM_QUERIES[name])
            b = off.count(LUBM_QUERIES[name])
            assert a == b, (name, a, b)


def test_snapshot_rows_conservative():
    """Snapshot signature rows over-approximate: every bit of the exact
    post-compaction index is set in the snapshot overlay (tombstones are
    ignored until compaction, inserts appear immediately)."""
    rng = np.random.default_rng(5)
    g = random_labeled_graph(rng, n_vertices=10, n_elabels=3, p_edge=0.3)
    get_index(g)
    store = VersionedStore(g, auto_compact=False)
    store.insert_edges([(0, 1, 2), (3, 2, 4)])
    rows = np.repeat(np.arange(g.n_vertices), np.diff(g.out.indptr_all))
    store.delete_edges([(int(rows[0]), int(g.out.lab_all[0]),
                         int(g.out.nbr_all[0]))])
    snap = store.snapshot()
    overlay = signature_rows(snap)
    exact = get_index(store.compact().base).sig
    assert np.all((overlay[:exact.shape[0]] & exact) == exact)


# --------------------------------------------------------------------------
# incremental maintenance == rebuild
# --------------------------------------------------------------------------


def _check_patch_equals_rebuild(seed):
    rng = np.random.default_rng(seed)
    g = random_labeled_graph(rng, n_vertices=12, n_elabels=4, p_edge=0.3)
    get_index(g)
    get_summary(g)
    store = VersionedStore(g, auto_compact=False)
    store.insert_edges(
        [(int(rng.integers(g.n_vertices)), int(rng.integers(g.n_elabels)),
          int(rng.integers(g.n_vertices))) for _ in range(6)])
    rows = np.repeat(np.arange(g.n_vertices), np.diff(g.out.indptr_all))
    if rows.size > 3:
        pick = rng.choice(rows.size, 3, replace=False)
        store.delete_edges(
            [(int(rows[i]), int(g.out.lab_all[i]), int(g.out.nbr_all[i]))
             for i in pick])
    # a label change and a fresh vertex stress the summary re-key pass
    if g.n_vlabels:
        store.set_vertex_labels(0, (g.n_vlabels - 1,))
    vid = store.add_vertex(labels=(0,) if g.n_vlabels else ())
    store.insert_edges([(vid, 0, 0)])
    ng = store.compact().base

    idx = ng._sig_index
    rebuilt = SignatureIndex.build(ng)
    assert idx.graph is ng and idx.n_bits == rebuilt.n_bits
    np.testing.assert_array_equal(idx.sig, rebuilt.sig)

    summ = ng._summary_graph
    fresh = SummaryGraph.build(ng)
    assert (summ is None) == (fresh is None)
    if summ is not None:
        assert summ.graph is ng
        np.testing.assert_array_equal(summ.classes, fresh.classes)
        np.testing.assert_array_equal(summ.counts, fresh.counts)
        np.testing.assert_array_equal(summ.class_count, fresh.class_count)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_compaction_patch_equals_rebuild(seed):
    _check_patch_equals_rebuild(seed * 65537 + 3)


@given(st.integers(0, 100_000))
@settings(max_examples=10, deadline=None)
def test_compaction_patch_equals_rebuild_property(seed):
    _check_patch_equals_rebuild(seed)


def test_patch_index_rebuilds_on_fold_width_change():
    """Growing the predicate vocabulary past the old modulus invalidates
    folded bits — patch_index must fall back to a full rebuild."""
    rng = np.random.default_rng(9)
    g = random_labeled_graph(rng, n_vertices=8, n_elabels=2, p_edge=0.3)
    old = get_index(g)
    store = VersionedStore(g, auto_compact=False)
    store.insert_edges([(0, 5, 1)])  # new edge label: n_elabels 2 -> 6
    ng = store.compact().base
    assert ng.n_elabels == 6
    idx = ng._sig_index
    assert idx.n_bits == sig_bits(6) != old.n_bits
    np.testing.assert_array_equal(idx.sig, SignatureIndex.build(ng).sig)


# --------------------------------------------------------------------------
# summary graph
# --------------------------------------------------------------------------


def test_summary_counts_partition_edges():
    rng = np.random.default_rng(2)
    g = random_labeled_graph(rng, n_vertices=14, n_elabels=4, p_edge=0.35)
    s = get_summary(g)
    assert s is not None
    assert int(s.counts.sum()) == int(np.diff(g.out.indptr_all).sum())
    assert int(s.class_count.sum()) == g.n_vertices
    classes = primary_classes(g)
    for v in range(g.n_vertices):
        ls = g.vlabel_sets[v] if g.vlabel_sets else ()
        assert classes[v] == (min(ls) if ls else g.n_vlabels)


def test_summary_est_fanout_exact_on_single_label_classes():
    """When every vertex has exactly its primary class, est_fanout is the
    exact average fanout parent-class -> child-class."""
    from repro.rdf.graph import LabeledGraph

    # two A vertices, three B vertices; A --0--> B complete bipartite
    src = np.repeat([0, 1], 3)
    dst = np.tile([2, 3, 4], 2)
    g = LabeledGraph.build(n_vertices=5, src=src, el=np.zeros(6, np.int64),
                           dst=dst, n_elabels=1,
                           vlabel_sets=[(0,), (0,), (1,), (1,), (1,)],
                           n_vlabels=2)
    s = get_summary(g)
    assert s.est_fanout(0, True, (0,), (1,)) == pytest.approx(3.0)
    assert s.est_fanout(0, False, (1,), (0,)) == pytest.approx(2.0)
    assert s.est_fanout(0, True, (1,), (0,)) == pytest.approx(0.0)
    assert s.est_fanout(0, True, (), (1,)) is None  # label-free side


def test_cost_model_uses_summary(lubm_graph):
    g, _ = lubm_graph
    from repro.core.planner.cost import CostModel

    cm = CostModel(g)
    assert cm.summary is not None
    assert cm.summary is get_summary(g)


# --------------------------------------------------------------------------
# executor surfaces
# --------------------------------------------------------------------------


def test_prune_counters_in_result_stats(lubm_graph):
    g, maps = lubm_graph
    eng = SparqlEngine(g, maps, opts=ExecOpts())
    res = eng.query(LUBM_QUERIES["Q8"])
    parts = [part
             for br in res.stats["exec"]["branches"]
             for part in [br.get("base") or {}] + list(br.get("optionals") or [])]
    assert any("step_prune_in" in p for p in parts)
    for p in parts:
        for pi, po in zip(p.get("step_prune_in", []),
                          p.get("step_prune_out", [])):
            assert po <= pi


def test_explain_analyze_reports_prune_ratio(lubm_graph):
    g, maps = lubm_graph
    eng = SparqlEngine(g, maps, opts=ExecOpts())
    out = eng.explain(LUBM_QUERIES["Q2"], analyze=True)
    steps = out["branches"][0]["steps"]
    probed = [s for s in steps if s.get("sig_probe")]
    assert probed, "Q2 should carry at least one signature probe"
    for s in probed:
        if s.get("prune_in"):
            assert 0.0 <= s["prune_ratio"] <= 1.0
