"""Paper Figure 16: parallel scaling on Q2 / Q9.

One physical core here, so wall-clock multi-thread speedup is not
measurable.  Instead, the LPT work partition is *executed shard by shard*
and the parallel time is simulated as max_i(shard_i time) — exactly the
quantity a synchronous SPMD execution realizes.  Reported: per-shard-count
predicted speedup (sum/max) and balance, for 1/2/4/8/16 shards.
"""

from __future__ import annotations

import numpy as np

from repro.core import ExecOpts, Executor, build_plan, build_query_graph
from repro.core.distributed import GreedyChunker
from repro.rdf.sparql import parse_sparql
from repro.rdf.workloads import LUBM_QUERIES
from repro.utils.timing import timed

from benchmarks.common import emit, lubm_typeaware

SCALE, DENSITY = 24, 1.0
SHARDS = [1, 2, 4, 8, 16]


def run(quick: bool = False) -> dict:
    scale = 2 if quick else SCALE
    g, maps = lubm_typeaware(scale, DENSITY)
    out = {}
    for qname in ("Q2", "Q9"):
        ast = parse_sparql(LUBM_QUERIES[qname])
        q = build_query_graph(ast.where.triples, maps)
        plan = build_plan(g, q)
        ex = Executor(g, ExecOpts())
        cands = plan.start_candidates
        t1 = None
        for n_shards in (SHARDS[:3] if quick else SHARDS):
            chunks, counts, _ = GreedyChunker(n_shards).partition(
                cands, g.out.degree)
            times = []
            total = 0
            for s in range(n_shards):
                sub = np.sort(chunks[s][: counts[s]])
                plan_s = build_plan(g, q)
                plan_s.start_candidates = sub
                if counts[s] == 0:
                    times.append(0.0)
                    continue
                res, secs = timed(lambda p=plan_s: ex.run(p, collect="count"),
                                  repeats=3, warmup=1)
                times.append(secs)
                total += res.count
            par_time = max(times)
            seq_time = sum(times)
            t1 = seq_time if t1 is None else t1
            speedup = t1 / max(par_time, 1e-9)
            out[(qname, n_shards)] = speedup
            emit(f"parallel.fig16.{qname}.shards{n_shards}", par_time,
                 f"speedup={speedup:.2f};count={total};"
                 f"balance={seq_time / max(n_shards * par_time, 1e-9):.2f}")
    return out


if __name__ == "__main__":
    run()
