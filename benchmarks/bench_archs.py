"""Roofline summary per (arch × shape): reads the dry-run + roofline
artifacts (produced by `python -m repro.launch.dryrun` and
`python -m repro.analysis.roofline`) and emits one row per cell."""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit


def run(quick: bool = False) -> None:
    path = Path("runs/roofline/roofline.json")
    if not path.exists():
        emit("archs.roofline.missing", 0,
             "run `python -m repro.launch.dryrun` then "
             "`python -m repro.analysis.roofline`")
        return
    rows = json.loads(path.read_text())
    for r in rows:
        step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        emit(f"archs.roofline.{r['arch']}.{r['cell']}", step_s,
             f"dom={r['dominant']};compute={r['compute_s']:.3e};"
             f"memory={r['memory_s']:.3e};coll={r['collective_s']:.3e};"
             f"useful_ratio={r.get('useful_ratio', 0):.3f}")


if __name__ == "__main__":
    run()
