"""Shared benchmark plumbing: dataset cache, the paper's timing protocol
(5 runs, drop best/worst, average — see utils.timing.timed), CSV output."""

from __future__ import annotations

import functools
import sys

from repro.rdf.generator import generate_bsbm, generate_hetero, generate_lubm
from repro.rdf.transform import (direct_transform, materialize_inferred_types,
                                 type_aware_transform)
from repro.utils.timing import timed


@functools.lru_cache(maxsize=8)
def lubm(scale: int, density: float = 1.0, seed: int = 0):
    st = generate_lubm(scale=scale, seed=seed, density=density)
    return st.finalize()


@functools.lru_cache(maxsize=4)
def lubm_typeaware(scale: int, density: float = 1.0):
    return type_aware_transform(lubm(scale, density))


@functools.lru_cache(maxsize=4)
def lubm_direct(scale: int, density: float = 1.0):
    # the paper loads original + INFERRED triples for non-reasoning engines
    return direct_transform(materialize_inferred_types(lubm(scale, density)))


@functools.lru_cache(maxsize=2)
def hetero(n_entities: int = 30000):
    st = generate_hetero(n_entities=n_entities, seed=2)
    return type_aware_transform(st.finalize())


@functools.lru_cache(maxsize=2)
def bsbm(n_products: int = 1500):
    st = generate_bsbm(n_products=n_products, seed=1)
    return type_aware_transform(st.finalize())


def emit(name: str, seconds: float, derived: str = "") -> None:
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def bench_query(engine, sparql: str, repeats: int = 5):
    # warm: compile + caches; timed runs measure pure matching (the paper
    # excludes dictionary lookups and result decoding, as do we)
    res, secs = timed(engine.query_ast, engine_parse(engine, sparql),
                      repeats=repeats, warmup=1)
    return res, secs


@functools.lru_cache(maxsize=512)
def _parse_cached(sparql: str):
    from repro.rdf.sparql import parse_sparql

    return parse_sparql(sparql)


def engine_parse(_engine, sparql: str):
    return _parse_cached(sparql)
