"""Kernel micro-benchmarks (CPU execution path = the jnp oracles; Pallas
kernels are TPU-target and validated in interpret mode by the test suite).

Measures the engine's two join primitives head to head — the +INT decision
the executor takes per step (tile compare-all vs binary search) — plus the
filter and aggregation primitives.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.utils.timing import timed

from benchmarks.common import emit


def run(quick: bool = False) -> None:
    rng = np.random.default_rng(0)
    b = 1 << (12 if quick else 14)
    m = 1 << 18

    nbr = jnp.asarray(np.sort(rng.integers(0, 1 << 20, m)).astype(np.int32))
    lo = jnp.asarray(rng.integers(0, m - 256, b).astype(np.int32))
    hi = lo + jnp.asarray(rng.integers(1, 256, b).astype(np.int32))
    tgt = jnp.asarray(rng.integers(0, 1 << 20, b).astype(np.int32))
    f = jax.jit(lambda: ref.edge_exists_ref(nbr, lo, hi, tgt, n_iters=20))
    _, secs = timed(f, repeats=5)
    emit("kernels.edge_exists.binary_search", secs,
         f"b={b};probe_per_s={b / secs:.3e}")

    for tb in (32, 128):
        a = jnp.asarray(rng.integers(0, 1 << 20, (b, 1)).astype(np.int32))
        bt = jnp.asarray(rng.integers(0, 1 << 20, (b, tb)).astype(np.int32))
        f = jax.jit(lambda a=a, bt=bt: ref.tile_membership_ref(a, bt))
        _, secs = timed(f, repeats=5)
        emit(f"kernels.tile_membership.tb{tb}", secs,
             f"b={b};probe_per_s={b / secs:.3e}")

    bm = jnp.asarray(rng.integers(0, 2**32, (b, 4), dtype=np.uint64)
                     .astype(np.uint32))
    req = jnp.asarray(np.array([3, 0, 1, 0], dtype=np.uint32))
    f = jax.jit(lambda: ref.bitmap_superset_ref(bm, req))
    _, secs = timed(f, repeats=5)
    emit("kernels.bitmap_superset", secs, f"b={b}")

    v, d, e, s = 1 << 14, 64, 1 << (14 if quick else 16), 1 << 12
    table = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, v, e).astype(np.int32))
    seg = jnp.asarray(np.sort(rng.integers(0, s, e)).astype(np.int32))
    f = jax.jit(lambda: ref.segment_gather_sum_ref(table, idx, seg, s))
    _, secs = timed(f, repeats=5)
    emit("kernels.segment_gather_sum", secs,
         f"rows_per_s={e / secs:.3e}")


if __name__ == "__main__":
    run()
