"""Paper Figure 15: effect of each optimization (+INT, -NLF, -DEG, +REUSE)
applied separately to the no-optimization baseline, on the two triangle
queries Q2 and Q9.

Baseline (paper's "no optimization"): binary-search IsJoinable, NLF filter
ON, degree filter ON, per-region matching order.  Each variant toggles ONE
optimization; `all` is the TurboHOM++ configuration.
"""

from __future__ import annotations

import numpy as np

from repro.core import ExecOpts, Executor, SparqlEngine, build_plan, \
    build_query_graph
from repro.rdf.sparql import parse_sparql
from repro.rdf.workloads import LUBM_QUERIES
from repro.utils.timing import timed

from benchmarks.common import emit, lubm_typeaware

SCALE, DENSITY = 4, 0.6

VARIANTS = {
    "baseline": ExecOpts(use_int=False, use_nlf=True, use_deg=True),
    "+INT": ExecOpts(use_int=True, use_nlf=True, use_deg=True),
    "-NLF": ExecOpts(use_int=False, use_nlf=False, use_deg=True),
    "-DEG": ExecOpts(use_int=False, use_nlf=True, use_deg=False),
    "all(TurboHOM++)": ExecOpts(use_int=True, use_nlf=False, use_deg=False),
}


def _run_query(g, maps, sparql, opts, estimate="sampled"):
    ast = parse_sparql(sparql)
    q = build_query_graph(ast.where.triples, maps)
    plan = build_plan(g, q, estimate=estimate, use_nlf=opts.use_nlf,
                      use_deg=opts.use_deg)
    ex = Executor(g, opts)
    res, secs = timed(lambda: ex.run(plan, collect="count"), repeats=5,
                      warmup=1)
    return res.count, secs


def _run_query_no_reuse(g, maps, sparql, opts, chunk=128):
    """-REUSE emulation: re-plan (re-derive the matching order) per chunk of
    candidate regions, as TurboISO does per region.  Execution time only —
    the recompilations a per-region order forces on TPU are reported
    separately as derived info."""
    ast = parse_sparql(sparql)
    q = build_query_graph(ast.where.triples, maps)
    base_plan = build_plan(g, q, use_nlf=opts.use_nlf, use_deg=opts.use_deg)
    cands = base_plan.start_candidates
    ex = Executor(g, opts)
    import numpy as np

    def run_all():
        total = 0
        for off in range(0, len(cands), chunk):
            sub = cands[off:off + chunk]
            plan = build_plan(g, q, use_nlf=opts.use_nlf,
                              use_deg=opts.use_deg)
            plan.start_candidates = np.sort(sub)
            total += ex.run(plan, collect="count").count
        return total

    count, secs = timed(run_all, repeats=3, warmup=1)
    return count, secs, len(ex._compiled)


def run(quick: bool = False) -> dict:
    scale = 2 if quick else SCALE
    g, maps = lubm_typeaware(scale, DENSITY)
    out = {}
    for qname in ("Q2", "Q9"):
        base_count = None
        for vname, opts in VARIANTS.items():
            count, secs = _run_query(g, maps, LUBM_QUERIES[qname], opts)
            base_count = base_count if base_count is not None else count
            assert count == base_count, (qname, vname, count, base_count)
            out[(qname, vname)] = secs
            emit(f"opts.fig15.{qname}.{vname}", secs, f"count={count}")
        count, secs, n_compiled = _run_query_no_reuse(
            g, maps, LUBM_QUERIES[qname], VARIANTS["baseline"])
        assert count == base_count
        out[(qname, "-REUSE")] = secs
        emit(f"opts.fig15.{qname}.-REUSE(per-region-order)", secs,
             f"count={count};compiled_variants={n_compiled}")
    return out


if __name__ == "__main__":
    run()
