"""Signature/summary pruning benchmark: prune-off vs prune-on.

Head-to-head on LUBM and BSBM queries with the neighborhood-signature
index disabled (``use_prune=False`` + ``use_sig=False`` plans — the
pre-index executor) and enabled (defaults).  Counts must agree exactly
(pruning is sound, never lossy); the headline metrics are

  speedup         — prune-off wall / prune-on wall,
  cand_reduction  — surviving candidate rows carried between plan steps
                    (sum of per-step ``step_kept``, the binding-table
                    rows feeding each subsequent join) without the index
                    vs with it — the paper's "candidate region shrink".

Beyond the stock workload queries, the ``I*`` queries below are
signature-stress stars: they require several *independently-irregular*
predicates on one vertex (undergrads have no ``emailAddress``, only
chairs carry ``headOf``, ~25% of grad students TA, ``rating2`` /
``reviewerHomepage`` are probabilistic in the BSBM generator), which is
exactly the structure vertex labels cannot prune but neighborhood
signatures can.

The returned dict is persisted as ``BENCH_index.json`` by run.py and
gated by ``benchmarks.check`` (counts exact, per-query candidate
reduction and geomean speedup within tolerance).
"""

from __future__ import annotations

from repro.core import ExecOpts, SparqlEngine
from repro.rdf.workloads import BSBM_QUERIES, LUBM_QUERIES

from benchmarks.common import bench_query, bsbm, emit, lubm_typeaware

INDEX_LUBM = {
    # students with an email AND an advisor: every undergraduate fails the
    # emailAddress bit, 80% also fail advisor — the signature kills them
    # before the 3-spoke star is expanded
    "I1": """SELECT ?x ?e ?a WHERE {
        ?x rdf:type ub:Student .
        ?x ub:memberOf ?d .
        ?x ub:emailAddress ?e .
        ?x ub:advisor ?a .
        ?x ub:takesCourse ?c .
    }""",
    # faculty who head a department: ~1 chair per ~15 faculty carries the
    # headOf out-bit
    "I2": """SELECT ?x ?d WHERE {
        ?x rdf:type ub:Faculty .
        ?x ub:worksFor ?d .
        ?x ub:headOf ?d2 .
        ?x ub:doctoralDegreeFrom ?u .
    }""",
    # teaching assistants: ~25% of graduate students, 0% of undergraduates
    "I3": """SELECT ?x ?c WHERE {
        ?x rdf:type ub:Student .
        ?x ub:memberOf ?d .
        ?x ub:teachingAssistantOf ?c .
        ?x ub:advisor ?a .
        ?x ub:takesCourse ?c2 .
    }""",
}

INDEX_BSBM = {
    # reviews with BOTH optional predicates (rating2 ~60%, homepage ~30%)
    "I4": """SELECT ?r ?p WHERE {
        ?r rdf:type b:Review .
        ?r b:reviewFor ?p .
        ?r b:rating2 ?v .
        ?r b:reviewerHomepage ?h .
    }""",
}

LUBM_SET = ("Q2", "Q8", "Q9")
BSBM_SET = ("B1", "B3", "B12")


def _sum_stat(res, key: str) -> int:
    total = 0
    for br in res.stats.get("exec", {}).get("branches", []):
        parts = [br.get("base") or {}] + list(br.get("optionals") or [])
        for part in parts:
            total += sum(x for x in part.get(key, ()) if x > 0)
    return total


def run(quick: bool = False) -> dict:
    repeats = 3 if quick else 11
    datasets = [
        ("lubm", lubm_typeaware(1 if quick else 8, 0.6),
         {**{n: LUBM_QUERIES[n] for n in LUBM_SET}, **INDEX_LUBM}),
        ("bsbm", bsbm(400 if quick else 3000),
         {**{n: BSBM_QUERIES[n] for n in BSBM_SET}, **INDEX_BSBM}),
    ]
    out: dict[str, dict] = {}
    for ds_name, (g, maps), queries in datasets:
        eng_off = SparqlEngine(g, maps, ExecOpts(use_prune=False))
        eng_on = SparqlEngine(g, maps, ExecOpts())
        for name, q in queries.items():
            res_off, secs_off = bench_query(eng_off, q, repeats=repeats)
            res_on, secs_on = bench_query(eng_on, q, repeats=repeats)
            if res_off.count != res_on.count:
                raise AssertionError(
                    f"{ds_name}.{name}: prune-off count {res_off.count} != "
                    f"prune-on count {res_on.count} (pruning must be sound)")
            # candidate region = surviving rows per step (the binding
            # table carried into each subsequent join); expansion rows
            # entering a step's own filter are unavoidable work the probe
            # runs inside of, so they don't count as candidates
            cand_off = _sum_stat(res_off, "step_kept")
            cand_on = _sum_stat(res_on, "step_kept")
            pr_in = _sum_stat(res_on, "step_prune_in")
            pr_out = _sum_stat(res_on, "step_prune_out")
            reduction = cand_off / max(cand_on, 1)
            speedup = secs_off / max(secs_on, 1e-12)
            emit(f"index.{ds_name}.{name}.prune_off", secs_off,
                 f"count={res_off.count};cands={cand_off}")
            emit(f"index.{ds_name}.{name}.prune_on", secs_on,
                 f"count={res_on.count};cands={cand_on};"
                 f"reduction={reduction:.2f}x;speedup={speedup:.2f}x")
            out[f"{ds_name}.{name}"] = {
                "count": int(res_on.count),
                "off_us": round(secs_off * 1e6, 1),
                "on_us": round(secs_on * 1e6, 1),
                "speedup": round(speedup, 3),
                "cands_off": int(cand_off),
                "cands_on": int(cand_on),
                "cand_reduction": round(reduction, 3),
                "probe_in": int(pr_in),
                "probe_out": int(pr_out),
            }
    return out


if __name__ == "__main__":
    run()
