"""Planner ablation: matching-order quality across order-search strategies.

Runs the LUBM and BSBM workloads under each estimate mode — ``static``
(cost-model greedy), ``sampled`` (paper §4.2 candidate-region estimation),
``dp`` (exact subset DP for ≤ 8 free vertices) — and reports per-ordering
end-to-end latency, planner time, and the cardinality-estimate error.

``benchmarks/run.py`` persists this suite's return value as
``BENCH_planner.json`` so successive PRs have a perf trajectory.
"""

from __future__ import annotations

from repro.core import ExecOpts, SparqlEngine
from repro.rdf.workloads import BSBM_QUERIES, LUBM_QUERIES

from benchmarks.common import bench_query, bsbm, emit, lubm_typeaware

MODES = ("static", "sampled", "dp")


def run(quick: bool = False) -> dict:
    datasets = [
        ("lubm", lubm_typeaware(1 if quick else 2, 0.6), LUBM_QUERIES),
        ("bsbm", bsbm(400 if quick else 1500), BSBM_QUERIES),
    ]
    snapshot: dict[str, dict] = {}
    for ds, (g, maps), queries in datasets:
        mode_total = {}
        for mode in MODES:
            engine = SparqlEngine(g, maps, ExecOpts(), estimate=mode)
            total_us = 0.0
            for name, q in sorted(queries.items()):
                res, secs = bench_query(engine, q, repeats=3 if quick else 5)
                total_us += secs * 1e6
                plan_ms = float(res.stats.get("plan_ms", 0.0))
                est = float(res.stats.get("est_rows", 0.0))
                emit(f"planner.{ds}.{mode}.{name}", secs,
                     f"count={res.count};plan_ms={plan_ms:.2f};est={est:.0f}")
                snapshot[f"{ds}.{mode}.{name}"] = {
                    "us_per_call": round(secs * 1e6, 1),
                    "count": res.count,
                    "plan_ms": round(plan_ms, 3),
                    "est_rows": round(est, 1),
                }
            mode_total[mode] = total_us
            emit(f"planner.{ds}.{mode}.TOTAL", total_us / 1e6, "")
        snapshot[f"{ds}.TOTAL"] = {m: round(v, 1) for m, v in mode_total.items()}
    return snapshot


if __name__ == "__main__":
    run(quick=True)
