"""Paper Tables 1/2/3: LUBM suite across scale factors.

Emits graph-size stats (Table 1), solution counts (Table 2 sanity: constant
queries stay constant, increasing queries grow), and per-query elapsed time
(Table 3) for the optimized TurboHOM++ configuration.
"""

from __future__ import annotations

from repro.core import ExecOpts, SparqlEngine
from repro.rdf.workloads import LUBM_CONSTANT, LUBM_INCREASING, LUBM_QUERIES

from benchmarks.common import bench_query, emit, lubm_direct, lubm_typeaware

SCALES = [(1, 0.6), (2, 0.6), (4, 0.6)]


def run(quick: bool = False) -> dict:
    scales = SCALES[:2] if quick else SCALES
    counts: dict[str, dict[int, int]] = {}
    for scale, density in scales:
        g, maps = lubm_typeaware(scale, density)
        gd, _ = lubm_direct(scale, density)
        emit(f"lubm.table1.scale{scale}.type_aware.vertices", 0,
             str(g.n_vertices))
        emit(f"lubm.table1.scale{scale}.type_aware.edges", 0, str(g.n_edges))
        emit(f"lubm.table1.scale{scale}.direct.vertices", 0,
             str(gd.n_vertices))
        emit(f"lubm.table1.scale{scale}.direct.edges", 0, str(gd.n_edges))
        engine = SparqlEngine(g, maps, ExecOpts())
        for name, q in sorted(LUBM_QUERIES.items()):
            res, secs = bench_query(engine, q, repeats=3 if quick else 5)
            counts.setdefault(name, {})[scale] = res.count
            emit(f"lubm.table3.scale{scale}.{name}", secs,
                 f"count={res.count}")
    # Table 2 sanity
    if len(scales) >= 2:
        s0, s1 = scales[0][0], scales[-1][0]
        for name in LUBM_CONSTANT:
            ok = counts[name][s0] == counts[name][s1]
            emit(f"lubm.table2.constant.{name}", 0,
                 f"{'OK' if ok else 'VIOLATION'}:{counts[name]}")
        for name in LUBM_INCREASING:
            ok = counts[name][s1] > counts[name][s0]
            emit(f"lubm.table2.increasing.{name}", 0,
                 f"{'OK' if ok else 'VIOLATION'}:{counts[name]}")
    return counts


if __name__ == "__main__":
    run()
