"""Paper Tables 4/5 stand-in: heterogeneous (YAGO/BTC-like) query suite."""

from __future__ import annotations

from repro.core import ExecOpts, SparqlEngine
from repro.rdf.workloads import HETERO_QUERIES

from benchmarks.common import bench_query, emit, hetero


def run(quick: bool = False) -> dict:
    g, maps = hetero(8000 if quick else 30000)
    engine = SparqlEngine(g, maps, ExecOpts())
    out = {}
    for name, q in sorted(HETERO_QUERIES.items()):
        res, secs = bench_query(engine, q, repeats=3 if quick else 5)
        out[name] = (res.count, secs)
        emit(f"hetero.table45.{name}", secs, f"count={res.count}")
    return out


if __name__ == "__main__":
    run()
