"""Executor pipeline benchmark: legacy vs adaptive-capacity pipelined.

Head-to-head on the heavy (increasing-solution / join-dense) LUBM and BSBM
queries, the workloads dominated by the binding-table inner loop:

  legacy     — one static capacity for every plan step (whole-plan fanout
               product), overflow redoes the chunk from step 0, synchronous
               dispatch, no fused kernel (``cap_schedule=False,
               suffix_resume=False, async_chunks=1, use_fused=False`` — the
               pre-pipeline executor),
  pipelined  — per-step capacity schedule from the planner's cardinality
               estimates, suffix-resume on overflow, double-buffered chunk
               dispatch, fused expand/filter/compact steps (defaults).

Also times the pipelined engine's count-only path (no binding-table
materialization / transfer).  The returned dict is persisted as
``BENCH_exec.json`` by run.py — the executor's perf trajectory baseline.
"""

from __future__ import annotations

from repro.core import ExecOpts, SparqlEngine
from repro.rdf.workloads import BSBM_QUERIES, LUBM_QUERIES
from repro.utils.timing import timed

from benchmarks.common import bench_query, bsbm, emit, engine_parse, lubm_typeaware

LUBM_HEAVY = ("Q2", "Q8", "Q9", "Q13")
BSBM_HEAVY = ("B1", "B3", "B5", "B8")

LEGACY = dict(cap_schedule=False, suffix_resume=False, async_chunks=1,
              use_fused=False)


def run(quick: bool = False) -> dict:
    # 11 repeats (drop best/worst, average 9) — the legacy-vs-pipelined
    # ratio is the committed trajectory baseline, so keep the noise down
    repeats = 3 if quick else 11
    datasets = [
        ("lubm", lubm_typeaware(1 if quick else 8, 0.6),
         {n: LUBM_QUERIES[n] for n in LUBM_HEAVY}),
        ("bsbm", bsbm(400 if quick else 3000),
         {n: BSBM_QUERIES[n] for n in BSBM_HEAVY}),
    ]
    out: dict[str, dict] = {}
    for ds_name, (g, maps), queries in datasets:
        eng_old = SparqlEngine(g, maps, ExecOpts(**LEGACY))
        eng_new = SparqlEngine(g, maps, ExecOpts())
        for name, q in queries.items():
            res_o, secs_o = bench_query(eng_old, q, repeats=repeats)
            res_n, secs_n = bench_query(eng_new, q, repeats=repeats)
            if res_o.count != res_n.count:
                raise AssertionError(
                    f"{ds_name}.{name}: legacy count {res_o.count} != "
                    f"pipelined count {res_n.count}")
            ast = engine_parse(eng_new, q)
            res_c, secs_c = timed(
                lambda a=ast: eng_new.query_ast(a, collect="count"),
                repeats=repeats, warmup=1)
            speedup = secs_o / max(secs_n, 1e-12)
            emit(f"exec.{ds_name}.{name}.legacy", secs_o,
                 f"count={res_o.count}")
            emit(f"exec.{ds_name}.{name}.pipelined", secs_n,
                 f"count={res_n.count};speedup={speedup:.2f}x")
            emit(f"exec.{ds_name}.{name}.count_only", secs_c,
                 f"speedup_vs_legacy={secs_o / max(secs_c, 1e-12):.2f}x")
            out[f"{ds_name}.{name}"] = {
                "count": int(res_n.count),
                "legacy_us": round(secs_o * 1e6, 1),
                "pipelined_us": round(secs_n * 1e6, 1),
                "count_only_us": round(secs_c * 1e6, 1),
                "speedup": round(speedup, 3),
            }
    return out


if __name__ == "__main__":
    run()
