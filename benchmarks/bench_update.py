"""Live-store benchmark: streaming ingest + query-under-update.

Scenario: a LUBM dataset goes live with a fraction of its triples held
back; the holdout arrives as a stream of insert batches (plus a trickle of
deletes) while a fixed query mix keeps executing.  Two strategies answer
the same workload:

- ``delta``    — ``repro.store.VersionedStore``: each batch lands in the
  delta overlay, queries run against cheap snapshots (base CSR + merged
  delta, no rebuild); compaction is left to its threshold.
- ``rebuild``  — the pre-store architecture: every batch triggers a full
  ``type_aware_transform`` + engine rebuild (plan recompiles included,
  because plans bake candidate sets of the dead graph).

Reported per strategy: ingest throughput (triples/s of making a batch
*queryable*), mean query latency during the stream, and end-to-end wall
time.  The committed ``BENCH_update.json`` tracks the full-size run.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import SparqlEngine
from repro.rdf.generator import generate_lubm
from repro.rdf.transform import type_aware_transform
from repro.rdf.triples import TripleStore
from repro.rdf.workloads import LUBM_QUERIES

QUERY_MIX = ("Q1", "Q2", "Q6", "Q9", "Q14")


def _dataset(scale: int, density: float, holdout: float, seed: int):
    full = generate_lubm(scale=scale, seed=0, density=density).finalize()
    triples = list(full.iter_decoded())
    onto = [t for t in triples if t[1] in ("rdf:type", "rdf:subClassOf")]
    plain = [t for t in triples if t[1] not in ("rdf:type", "rdf:subClassOf")]
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(plain))
    n_base = int(len(plain) * (1.0 - holdout))
    base = onto + [plain[i] for i in idx[:n_base]]
    stream = [plain[i] for i in idx[n_base:]]
    dels = [plain[idx[i]] for i in
            rng.choice(n_base, size=max(1, len(stream) // 10),
                       replace=False)]
    return base, stream, dels


def _batches(stream, dels, n_batches):
    ins_sz = max(1, len(stream) // n_batches)
    del_sz = max(1, len(dels) // n_batches)
    out = []
    for i in range(n_batches):
        out.append((stream[i * ins_sz: (i + 1) * ins_sz],
                    dels[i * del_sz: (i + 1) * del_sz]))
    return out


def _run_queries(engine) -> float:
    t0 = time.perf_counter()
    for name in QUERY_MIX:
        engine.query(LUBM_QUERIES[name])
    return (time.perf_counter() - t0) / len(QUERY_MIX)


def _delta_strategy(base, batches):
    from repro.store import VersionedStore

    st = TripleStore()
    st.add_many(base)
    g, maps = type_aware_transform(st.finalize())
    store = VersionedStore(g, maps)
    engine = SparqlEngine(store.snapshot(), maps)
    _run_queries(engine)  # warm compile on the base snapshot
    ingest_s = 0.0
    q_lat = []
    n_triples = 0
    t_all = time.perf_counter()
    for ins, dels in batches:
        t0 = time.perf_counter()
        store.insert_triples(ins)
        store.delete_triples(dels)
        engine.set_graph(store.snapshot())
        ingest_s += time.perf_counter() - t0
        n_triples += len(ins) + len(dels)
        q_lat.append(_run_queries(engine))
    wall = time.perf_counter() - t_all
    return {"ingest_tps": n_triples / max(ingest_s, 1e-9),
            "query_ms": float(np.mean(q_lat) * 1e3),
            "wall_s": wall,
            "compactions": store.counters["compactions"]}


def _rebuild_strategy(base, batches):
    current = list(base)
    st = TripleStore()
    st.add_many(current)
    g, maps = type_aware_transform(st.finalize())
    engine = SparqlEngine(g, maps)
    _run_queries(engine)
    ingest_s = 0.0
    q_lat = []
    n_triples = 0
    t_all = time.perf_counter()
    for ins, dels in batches:
        t0 = time.perf_counter()
        drop = set(dels)
        current = [t for t in current if t not in drop] + ins
        st = TripleStore()
        st.add_many(current)
        g, maps = type_aware_transform(st.finalize())
        engine = SparqlEngine(g, maps)
        ingest_s += time.perf_counter() - t0
        n_triples += len(ins) + len(dels)
        q_lat.append(_run_queries(engine))
    wall = time.perf_counter() - t_all
    return {"ingest_tps": n_triples / max(ingest_s, 1e-9),
            "query_ms": float(np.mean(q_lat) * 1e3),
            "wall_s": wall}


def run(quick: bool = False) -> dict:
    scale, density, holdout = (1, 0.3, 0.2) if quick else (2, 0.6, 0.25)
    n_batches = 4 if quick else 8
    base, stream, dels = _dataset(scale, density, holdout, seed=5)
    batches = _batches(stream, dels, n_batches)
    n_stream = sum(len(i) + len(d) for i, d in batches)

    out: dict = {"scenario": {"base_triples": len(base),
                              "stream_triples": n_stream,
                              "batches": n_batches}}
    for name, fn in (("delta", _delta_strategy),
                     ("rebuild", _rebuild_strategy)):
        res = fn(base, batches)
        out[name] = res
        emit(f"update.{name}.ingest", 1.0 / max(res['ingest_tps'], 1e-9),
             f"{res['ingest_tps']:.0f} triples/s")
        emit(f"update.{name}.query", res["query_ms"] / 1e3,
             f"{res['query_ms']:.1f} ms mean under churn")
        emit(f"update.{name}.wall", res["wall_s"],
             f"{res['wall_s']:.2f} s end-to-end")
    speedup = out["rebuild"]["wall_s"] / max(out["delta"]["wall_s"], 1e-9)
    ingest_x = out["delta"]["ingest_tps"] / max(out["rebuild"]["ingest_tps"],
                                                1e-9)
    out["speedup_wall"] = round(speedup, 2)
    out["speedup_ingest"] = round(ingest_x, 2)
    emit("update.speedup", 0.0,
         f"delta vs rebuild: {ingest_x:.1f}x ingest, {speedup:.2f}x wall")
    return out


if __name__ == "__main__":
    import sys

    run(quick="--quick" in sys.argv)
