"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--quick`` shrinks datasets
(CI-sized); default sizes match EXPERIMENTS.md.  Select suites with
``--only lubm,opts``.

``--check`` turns the run into a regression gate: each snapshot suite's
fresh results are diffed against its committed ``BENCH_*.json`` baseline
(see :mod:`benchmarks.check` — counts exact, internal speedup ratios within
tolerance) and a regression exits non-zero.  ``--trace-out FILE`` wraps
every suite in a :class:`repro.obs.Trace` span and writes Chrome
``trace_event`` JSON for chrome://tracing / Perfetto.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


SUITES = ["lubm", "typeaware", "opts", "parallel", "hetero", "bsbm",
          "kernels", "exec", "archs", "serve", "planner", "store", "index"]

# suites whose module name differs from the suite name
SUITE_MODULES = {"store": "bench_update"}

# suites whose run() return value is persisted as BENCH_<name>.json next to
# this file (named after the module), giving future PRs a perf trajectory
# to compare against
SNAPSHOT_SUITES = {"planner", "exec", "store", "index", "typeaware", "serve"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help=f"comma list from {SUITES}")
    ap.add_argument("--check", action="store_true",
                    help="diff snapshot suites against committed BENCH_*"
                         " baselines; exit 1 on regression")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="write a Chrome trace_event JSON of the run")
    args = ap.parse_args()
    chosen = args.only.split(",") if args.only else SUITES

    trace = None
    if args.trace_out:
        from repro.obs import Trace, chrome_trace
        trace = Trace("bench")

    print("name,us_per_call,derived", flush=True)
    t0 = time.time()
    regressions: list[str] = []
    for suite in chosen:
        modname = SUITE_MODULES.get(suite, f"bench_{suite}")
        mod = __import__(f"benchmarks.{modname}", fromlist=["run"])
        t1 = time.time()
        try:
            if trace is not None:
                with trace.span(suite):
                    out = mod.run(quick=args.quick)
            else:
                out = mod.run(quick=args.quick)
            if suite in SNAPSHOT_SUITES and isinstance(out, dict):
                # quick runs land in a sibling file so smoke tests never
                # clobber the committed full-scale trajectory baseline
                base = modname.removeprefix("bench_")
                name = (f"BENCH_{base}.quick.json" if args.quick
                        else f"BENCH_{base}.json")
                path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                    name)
                if args.check:
                    # gate BEFORE overwriting the committed snapshot
                    from benchmarks import check

                    found = check.check_suite(base, out, quick=args.quick)
                    regressions.extend(found)
                    status = "regressed" if found else "ok"
                    print(f"_meta.{suite}.check,0,{status}", flush=True)
                with open(path, "w") as f:
                    json.dump({"quick": args.quick, "results": out}, f,
                              indent=1, sort_keys=True)
                print(f"_meta.{suite}.snapshot,0,{path}", flush=True)
        except Exception as e:  # keep the harness going; report the failure
            print(f"{suite}.SUITE_FAILED,0,{type(e).__name__}:{e}",
                  flush=True)
            import traceback

            traceback.print_exc(file=sys.stderr)
        print(f"_meta.{suite}.suite_seconds,{(time.time() - t1) * 1e6:.0f},",
              flush=True)
    print(f"_meta.total_seconds,{(time.time() - t0) * 1e6:.0f},", flush=True)

    if trace is not None:
        trace.finish()
        with open(args.trace_out, "w") as f:
            f.write(chrome_trace(trace, as_text=True))
        print(f"_meta.trace,0,{args.trace_out}", flush=True)

    if args.check and regressions:
        for r in regressions:
            print(f"REGRESSION: {r}", file=sys.stderr, flush=True)
        sys.exit(1)


if __name__ == "__main__":
    main()
