"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--quick`` shrinks datasets
(CI-sized); default sizes match EXPERIMENTS.md.  Select suites with
``--only lubm,opts``.
"""

from __future__ import annotations

import argparse
import sys
import time


SUITES = ["lubm", "typeaware", "opts", "parallel", "hetero", "bsbm",
          "kernels", "archs", "serve"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help=f"comma list from {SUITES}")
    args = ap.parse_args()
    chosen = args.only.split(",") if args.only else SUITES
    print("name,us_per_call,derived", flush=True)
    t0 = time.time()
    for suite in chosen:
        mod = __import__(f"benchmarks.bench_{suite}", fromlist=["run"])
        t1 = time.time()
        try:
            mod.run(quick=args.quick)
        except Exception as e:  # keep the harness going; report the failure
            print(f"{suite}.SUITE_FAILED,0,{type(e).__name__}:{e}",
                  flush=True)
            import traceback

            traceback.print_exc(file=sys.stderr)
        print(f"_meta.{suite}.suite_seconds,{(time.time() - t1) * 1e6:.0f},",
              flush=True)
    print(f"_meta.total_seconds,{(time.time() - t0) * 1e6:.0f},", flush=True)


if __name__ == "__main__":
    main()
