"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--quick`` shrinks datasets
(CI-sized); default sizes match EXPERIMENTS.md.  Select suites with
``--only lubm,opts``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


SUITES = ["lubm", "typeaware", "opts", "parallel", "hetero", "bsbm",
          "kernels", "exec", "archs", "serve", "planner", "store"]

# suites whose module name differs from the suite name
SUITE_MODULES = {"store": "bench_update"}

# suites whose run() return value is persisted as BENCH_<name>.json next to
# this file (named after the module), giving future PRs a perf trajectory
# to compare against
SNAPSHOT_SUITES = {"planner", "exec", "store"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help=f"comma list from {SUITES}")
    args = ap.parse_args()
    chosen = args.only.split(",") if args.only else SUITES
    print("name,us_per_call,derived", flush=True)
    t0 = time.time()
    for suite in chosen:
        modname = SUITE_MODULES.get(suite, f"bench_{suite}")
        mod = __import__(f"benchmarks.{modname}", fromlist=["run"])
        t1 = time.time()
        try:
            out = mod.run(quick=args.quick)
            if suite in SNAPSHOT_SUITES and isinstance(out, dict):
                # quick runs land in a sibling file so smoke tests never
                # clobber the committed full-scale trajectory baseline
                base = modname.removeprefix("bench_")
                name = (f"BENCH_{base}.quick.json" if args.quick
                        else f"BENCH_{base}.json")
                path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                    name)
                with open(path, "w") as f:
                    json.dump({"quick": args.quick, "results": out}, f,
                              indent=1, sort_keys=True)
                print(f"_meta.{suite}.snapshot,0,{path}", flush=True)
        except Exception as e:  # keep the harness going; report the failure
            print(f"{suite}.SUITE_FAILED,0,{type(e).__name__}:{e}",
                  flush=True)
            import traceback

            traceback.print_exc(file=sys.stderr)
        print(f"_meta.{suite}.suite_seconds,{(time.time() - t1) * 1e6:.0f},",
              flush=True)
    print(f"_meta.total_seconds,{(time.time() - t0) * 1e6:.0f},", flush=True)


if __name__ == "__main__":
    main()
