"""Benchmark regression gate: diff a fresh quick-suite snapshot against the
committed ``BENCH_<suite>.quick.json`` baseline.

Comparisons use machine-independent signals only — result counts must match
exactly (a count change is a correctness bug, not noise) and *internal
ratios* (pipelined-vs-legacy speedup, delta-vs-rebuild ingest speedup) must
stay within a tolerance band.  Absolute microseconds are never compared:
they vary with the host, but a ratio of two timings taken on the same host
in the same run does not.
"""

from __future__ import annotations

import json
import math
import os

TOLERANCE = 0.25  # fractional ratio drift allowed before we call regression

_DIR = os.path.dirname(os.path.abspath(__file__))


def baseline_path(suite: str, quick: bool = True) -> str:
    tag = ".quick" if quick else ""
    return os.path.join(_DIR, f"BENCH_{suite}{tag}.json")


def load_baseline(suite: str, quick: bool = True) -> dict | None:
    path = baseline_path(suite, quick)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)["results"]


def _geomean(xs: list[float]) -> float:
    xs = [x for x in xs if x > 0]
    if not xs:
        return float("nan")
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def _ratio_drift(old: float, new: float) -> float:
    """Fractional change of ``new`` relative to ``old`` (0.0 = unchanged)."""
    if old <= 0 or new <= 0 or not (math.isfinite(old) and math.isfinite(new)):
        return float("inf")
    return abs(new / old - 1.0)


def _check_exec(base: dict, fresh: dict, tol: float) -> list[str]:
    """Per-query counts exact; geomean pipelined-vs-legacy speedup within
    tolerance (per-query speedups are noisy at quick scale; the geomean is
    the suite's headline number)."""
    bad = []
    missing = sorted(set(base) - set(fresh))
    if missing:
        bad.append(f"exec: queries missing from fresh run: {missing}")
    for q in sorted(set(base) & set(fresh)):
        if base[q]["count"] != fresh[q]["count"]:
            bad.append(f"exec: {q} count {fresh[q]['count']} != baseline "
                       f"{base[q]['count']} (correctness regression)")
    shared = sorted(set(base) & set(fresh))
    g_old = _geomean([base[q]["speedup"] for q in shared])
    g_new = _geomean([fresh[q]["speedup"] for q in shared])
    if _ratio_drift(g_old, g_new) > tol and g_new < g_old:
        bad.append(f"exec: geomean pipelined speedup {g_new:.3f} regressed "
                   f">{tol:.0%} vs baseline {g_old:.3f}")
    return bad


def _check_planner(base: dict, fresh: dict, tol: float) -> list[str]:
    """Counts are the planner suite's correctness signal: every strategy
    must still produce the same answers."""
    bad = []
    missing = sorted(set(base) - set(fresh))
    if missing:
        bad.append(f"planner: entries missing from fresh run: {missing}")
    for q in sorted(set(base) & set(fresh)):
        b, f = base[q], fresh[q]
        if "count" in b and "count" in f and b["count"] != f["count"]:
            bad.append(f"planner: {q} count {f['count']} != baseline "
                       f"{b['count']} (correctness regression)")
    return bad


def _check_store(base: dict, fresh: dict, tol: float) -> list[str]:
    """Delta-vs-rebuild speedups are internal ratios — compare directly.
    Quick-scale delta ingest is ~15µs/call, so run-to-run drift of the
    ratio is routinely ±40% on an idle host; the gate exists to catch
    order-of-magnitude regressions (a lost fast path), not timing noise,
    hence the widened floor."""
    tol = max(tol, 0.6)
    bad = []
    for key in ("speedup_ingest", "speedup_wall"):
        if key not in base or key not in fresh:
            continue
        old, new = float(base[key]), float(fresh[key])
        if _ratio_drift(old, new) > tol and new < old:
            bad.append(f"store: {key} {new:.2f} regressed >{tol:.0%} "
                       f"vs baseline {old:.2f}")
    return bad


def _check_index(base: dict, fresh: dict, tol: float) -> list[str]:
    """Counts exact (pruning must stay sound); the candidate-reduction
    ratio (rows examined without vs with the signature index) is a pure
    counter ratio — deterministic on a fixed dataset, so compare per query
    with the regular tolerance; the geomean prune-off/prune-on speedup is
    timing-based and compared like exec's."""
    bad = []
    missing = sorted(set(base) - set(fresh))
    if missing:
        bad.append(f"index: queries missing from fresh run: {missing}")
    shared = sorted(set(base) & set(fresh))
    for q in shared:
        if base[q]["count"] != fresh[q]["count"]:
            bad.append(f"index: {q} count {fresh[q]['count']} != baseline "
                       f"{base[q]['count']} (correctness regression)")
        old_r = float(base[q]["cand_reduction"])
        new_r = float(fresh[q]["cand_reduction"])
        if _ratio_drift(old_r, new_r) > tol and new_r < old_r:
            bad.append(f"index: {q} candidate reduction {new_r:.2f} "
                       f"regressed >{tol:.0%} vs baseline {old_r:.2f}")
    g_old = _geomean([base[q]["speedup"] for q in shared])
    g_new = _geomean([fresh[q]["speedup"] for q in shared])
    if _ratio_drift(g_old, g_new) > tol and g_new < g_old:
        bad.append(f"index: geomean prune speedup {g_new:.3f} regressed "
                   f">{tol:.0%} vs baseline {g_old:.3f}")
    return bad


def _check_typeaware(base: dict, fresh: dict, tol: float) -> list[str]:
    """Both transforms' counts exact per query; geomean type-aware gain
    (an internal direct/type-aware ratio) within tolerance."""
    bad = []
    missing = sorted(set(base) - set(fresh))
    if missing:
        bad.append(f"typeaware: queries missing from fresh run: {missing}")
    shared = sorted(set(base) & set(fresh))
    for q in shared:
        for key in ("count_direct", "count_typeaware"):
            if base[q][key] != fresh[q][key]:
                bad.append(f"typeaware: {q} {key} {fresh[q][key]} != "
                           f"baseline {base[q][key]} (correctness "
                           f"regression)")
    g_old = _geomean([base[q]["gain"] for q in shared])
    g_new = _geomean([fresh[q]["gain"] for q in shared])
    if _ratio_drift(g_old, g_new) > tol and g_new < g_old:
        bad.append(f"typeaware: geomean gain {g_new:.3f} regressed "
                   f">{tol:.0%} vs baseline {g_old:.3f}")
    return bad


def _check_serve(base: dict, fresh: dict, tol: float) -> list[str]:
    """The coalesce mix's correctness bit must hold outright (per-query
    counts validated against a direct engine reference), and the
    batched-vs-unbatched throughput ratio — an internal same-host ratio —
    must not collapse.  Scheduler throughput under threaded load is the
    noisiest signal in the repo, so the floor is widened like store's."""
    tol = max(tol, 0.6)
    bad = []
    b, f = base.get("coalesce"), fresh.get("coalesce")
    if b is None or f is None:
        return ["serve: coalesce mix missing from "
                + ("baseline" if b is None else "fresh run")]
    if not f.get("counts_ok", False):
        bad.append("serve: batched results diverged from the direct-engine "
                   "reference (correctness regression)")
    old, new = float(b.get("speedup", 0)), float(f.get("speedup", 0))
    if new < 1.0:
        bad.append(f"serve: coalescing slower than unbatched "
                   f"(speedup {new:.2f} < 1.0)")
    elif _ratio_drift(old, new) > tol and new < old:
        bad.append(f"serve: coalesce speedup {new:.2f} regressed "
                   f">{tol:.0%} vs baseline {old:.2f}")
    return bad


_CHECKERS = {"exec": _check_exec, "planner": _check_planner,
             "update": _check_store, "index": _check_index,
             "typeaware": _check_typeaware, "serve": _check_serve}


def compare(suite: str, base: dict, fresh: dict,
            tol: float = TOLERANCE) -> list[str]:
    """Return a list of regression descriptions (empty == pass)."""
    checker = _CHECKERS.get(suite)
    if checker is None:
        return []
    return checker(base, fresh, tol)


def check_suite(suite: str, fresh: dict, quick: bool = True,
                tol: float = TOLERANCE) -> list[str]:
    """Gate one suite's fresh results against its committed baseline.
    A missing baseline is reported (the gate is only meaningful when the
    baseline is committed) but phrased so the fix is obvious."""
    base = load_baseline(suite, quick)
    if base is None:
        return [f"{suite}: no committed baseline "
                f"{os.path.basename(baseline_path(suite, quick))} — run "
                f"`python -m benchmarks.run --quick --only ...` and commit it"]
    return compare(suite, base, fresh, tol)
