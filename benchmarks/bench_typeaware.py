"""Paper Table 7: direct vs type-aware transformation, per LUBM query.

The paper reports 1.01× (Q1) to 27.22× (Q6) gains on LUBM8000; shapes here
are smaller but the *structure* (point-shaped queries gain most; anchored
constant queries gain least) must reproduce.
"""

from __future__ import annotations

from repro.core import ExecOpts, SparqlEngine
from repro.rdf.workloads import LUBM_QUERIES

from benchmarks.common import bench_query, emit, lubm_direct, lubm_typeaware

SCALE, DENSITY = 4, 0.6


def run(quick: bool = False) -> dict:
    scale = 2 if quick else SCALE
    g_t, m_t = lubm_typeaware(scale, DENSITY)
    g_d, m_d = lubm_direct(scale, DENSITY)
    e_t = SparqlEngine(g_t, m_t, ExecOpts())
    e_d = SparqlEngine(g_d, m_d, ExecOpts())
    out: dict[str, dict] = {}
    for name, q in sorted(LUBM_QUERIES.items()):
        res_d, sec_d = bench_query(e_d, q, repeats=3)
        res_t, sec_t = bench_query(e_t, q, repeats=3)
        gain = sec_d / max(sec_t, 1e-9)
        # counts must agree for leaf-type queries; subsumption queries (Q5,
        # Q6, Q9, Q13, Q14 use superclasses) count MORE under type-aware
        # unless direct data materializes the closure — flag only shrinkage
        flag = "" if res_t.count >= res_d.count else "COUNT_SHRANK"
        emit(f"typeaware.table7.{name}.direct", sec_d, f"count={res_d.count}")
        emit(f"typeaware.table7.{name}.type_aware", sec_t,
             f"count={res_t.count};gain={gain:.2f}{flag}")
        out[name] = {
            "count_direct": int(res_d.count),
            "count_typeaware": int(res_t.count),
            "gain": round(gain, 3),
        }
    return out


if __name__ == "__main__":
    run()
