"""Serving-layer throughput: closed-loop and open-loop load against the
LUBM mix through the repro.serve scheduler (coalescing + plan cache).

Closed loop: N client threads issue queries back-to-back for a fixed
number of rounds — measures saturated throughput and latency under
self-clocked load.  Open loop: a dispatcher injects requests at a target
arrival rate regardless of completions — measures behavior when load is
*offered*, not negotiated (queueing delay shows up in the percentiles).

Emits ``serve.*`` CSV rows via benchmarks.common.emit.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor

from repro.rdf.workloads import LUBM_QUERIES
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import Scheduler
from repro.serve.server import DatasetRegistry

from benchmarks.common import emit, lubm_typeaware


def _registry(scale: int, density: float = 0.6):
    g, maps = lubm_typeaware(scale, density)
    metrics = ServeMetrics()
    registry = DatasetRegistry(metrics)
    registry.register("lubm", g, maps)
    return registry


def _warm(scheduler: Scheduler, queries: list[str]) -> None:
    for q in queries:
        scheduler.submit("lubm", q)


def closed_loop(scale: int, clients: int, rounds: int) -> None:
    registry = _registry(scale)
    queries = [LUBM_QUERIES[k] for k in sorted(LUBM_QUERIES)]
    with Scheduler(registry, workers=clients, max_queue=4 * clients,
                   metrics=registry.metrics) as scheduler:
        _warm(scheduler, queries)
        latencies: list[float] = []
        lock = threading.Lock()

        def client(tid: int) -> None:
            local = []
            for r in range(rounds):
                # stagger starting offsets so clients collide on queries
                for i in range(len(queries)):
                    q = queries[(tid + i) % len(queries)]
                    t0 = time.perf_counter()
                    scheduler.submit("lubm", q)
                    local.append(time.perf_counter() - t0)
            with lock:
                latencies.extend(local)

        t0 = time.perf_counter()
        with ThreadPoolExecutor(max_workers=clients) as pool:
            list(pool.map(client, range(clients)))
        wall = time.perf_counter() - t0
    n = len(latencies)
    lat = registry.metrics.latency
    pc = registry.get("lubm").engine.plan_cache.snapshot()
    emit(f"serve.closed.c{clients}.scale{scale}.throughput",
         wall / max(n, 1), f"qps={n / wall:.1f}")
    emit(f"serve.closed.c{clients}.scale{scale}.p50", lat.percentile(50) / 1e3)
    emit(f"serve.closed.c{clients}.scale{scale}.p99", lat.percentile(99) / 1e3)
    emit(f"serve.closed.c{clients}.scale{scale}.coalesced", 0,
         f"{registry.metrics.coalesced.total():.0f}/{n}")
    emit(f"serve.closed.c{clients}.scale{scale}.plan_cache_hit_rate", 0,
         f"{pc['hit_rate']:.3f}")


def open_loop(scale: int, target_qps: float, duration_s: float,
              workers: int = 8) -> None:
    registry = _registry(scale)
    queries = [LUBM_QUERIES[k] for k in sorted(LUBM_QUERIES)]
    with Scheduler(registry, workers=workers, max_queue=256,
                   default_timeout_s=duration_s,
                   metrics=registry.metrics) as scheduler:
        _warm(scheduler, queries)
        done: list[float] = []
        errors = [0]
        lock = threading.Lock()

        def fire(q: str) -> None:
            t0 = time.perf_counter()
            try:
                scheduler.submit("lubm", q)
            except Exception:
                with lock:
                    errors[0] += 1
                return
            with lock:
                done.append(time.perf_counter() - t0)

        period = 1.0 / target_qps
        t0 = time.perf_counter()
        i = 0
        with ThreadPoolExecutor(max_workers=workers * 4) as pool:
            # fixed-rate arrivals: sleep to the schedule, not the completions
            while (now := time.perf_counter()) - t0 < duration_s:
                pool.submit(fire, queries[i % len(queries)])
                i += 1
                next_t = t0 + i * period
                if (delay := next_t - time.perf_counter()) > 0:
                    time.sleep(delay)
        wall = time.perf_counter() - t0
    n = len(done)
    lat = registry.metrics.latency
    emit(f"serve.open.q{target_qps:g}.scale{scale}.achieved",
         wall / max(n, 1), f"qps={n / wall:.1f} offered={i / wall:.1f} "
                           f"errors={errors[0]}")
    emit(f"serve.open.q{target_qps:g}.scale{scale}.p50",
         lat.percentile(50) / 1e3)
    emit(f"serve.open.q{target_qps:g}.scale{scale}.p99",
         lat.percentile(99) / 1e3)
    emit(f"serve.open.q{target_qps:g}.scale{scale}.coalesced", 0,
         f"{registry.metrics.coalesced.total():.0f}/{n}")


def run(quick: bool = False) -> None:
    scale = 1 if quick else 2
    rounds = 2 if quick else 5
    for clients in ([2, 4] if quick else [1, 4, 8]):
        closed_loop(scale, clients, rounds)
    for qps in ([20] if quick else [20, 50]):
        open_loop(scale, qps, duration_s=3.0 if quick else 10.0)


if __name__ == "__main__":
    print("name,us_per_call,derived", flush=True)
    run(quick=True)
