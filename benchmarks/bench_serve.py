"""Serving-layer throughput: closed-loop and open-loop load against the
LUBM mix through the repro.serve scheduler (coalescing + plan cache).

Closed loop: N client threads issue queries back-to-back for a fixed
number of rounds — measures saturated throughput and latency under
self-clocked load.  Open loop: a dispatcher injects requests at a target
arrival rate regardless of completions — measures behavior when load is
*offered*, not negotiated (queueing delay shows up in the percentiles).

The coalesce mix is the suite's snapshot headline: an open-loop burst of
same-*shape* queries whose constants follow a skewed (zipf-ish) draw from
the course population, run twice — batching enabled vs disabled — with
per-query counts validated against a direct engine reference.  The
speedup ratio (batched qps / unbatched qps) is machine-independent and
gated by ``benchmarks.check``.

Emits ``serve.*`` CSV rows via benchmarks.common.emit; ``run()`` returns
the snapshot dict persisted as ``BENCH_serve.json``.
"""

from __future__ import annotations

import random
import re
import threading
import time
from concurrent.futures import ThreadPoolExecutor

from repro.rdf.workloads import LUBM_QUERIES
from repro.serve.fingerprint import parameterize_query
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import Scheduler
from repro.serve.server import DatasetRegistry

from benchmarks.common import emit, lubm_typeaware


def _registry(scale: int, density: float = 0.6):
    g, maps = lubm_typeaware(scale, density)
    metrics = ServeMetrics()
    registry = DatasetRegistry(metrics)
    registry.register("lubm", g, maps)
    return registry


def _warm(scheduler: Scheduler, queries: list[str]) -> None:
    for q in queries:
        scheduler.submit("lubm", q)


def closed_loop(scale: int, clients: int, rounds: int) -> None:
    registry = _registry(scale)
    queries = [LUBM_QUERIES[k] for k in sorted(LUBM_QUERIES)]
    with Scheduler(registry, workers=clients, max_queue=4 * clients,
                   metrics=registry.metrics) as scheduler:
        _warm(scheduler, queries)
        latencies: list[float] = []
        lock = threading.Lock()

        def client(tid: int) -> None:
            local = []
            for r in range(rounds):
                # stagger starting offsets so clients collide on queries
                for i in range(len(queries)):
                    q = queries[(tid + i) % len(queries)]
                    t0 = time.perf_counter()
                    scheduler.submit("lubm", q)
                    local.append(time.perf_counter() - t0)
            with lock:
                latencies.extend(local)

        t0 = time.perf_counter()
        with ThreadPoolExecutor(max_workers=clients) as pool:
            list(pool.map(client, range(clients)))
        wall = time.perf_counter() - t0
    n = len(latencies)
    lat = registry.metrics.latency
    pc = registry.get("lubm").engine.plan_cache.snapshot()
    emit(f"serve.closed.c{clients}.scale{scale}.throughput",
         wall / max(n, 1), f"qps={n / wall:.1f}")
    emit(f"serve.closed.c{clients}.scale{scale}.p50", lat.percentile(50) / 1e3)
    emit(f"serve.closed.c{clients}.scale{scale}.p99", lat.percentile(99) / 1e3)
    emit(f"serve.closed.c{clients}.scale{scale}.coalesced", 0,
         f"{registry.metrics.coalesced.total():.0f}/{n}")
    emit(f"serve.closed.c{clients}.scale{scale}.plan_cache_hit_rate", 0,
         f"{pc['hit_rate']:.3f}")


def open_loop(scale: int, target_qps: float, duration_s: float,
              workers: int = 8) -> None:
    registry = _registry(scale)
    queries = [LUBM_QUERIES[k] for k in sorted(LUBM_QUERIES)]
    with Scheduler(registry, workers=workers, max_queue=256,
                   default_timeout_s=duration_s,
                   metrics=registry.metrics) as scheduler:
        _warm(scheduler, queries)
        done: list[float] = []
        errors = [0]
        lock = threading.Lock()

        def fire(q: str) -> None:
            t0 = time.perf_counter()
            try:
                scheduler.submit("lubm", q)
            except Exception:
                with lock:
                    errors[0] += 1
                return
            with lock:
                done.append(time.perf_counter() - t0)

        period = 1.0 / target_qps
        t0 = time.perf_counter()
        i = 0
        with ThreadPoolExecutor(max_workers=workers * 4) as pool:
            # fixed-rate arrivals: sleep to the schedule, not the completions
            while (now := time.perf_counter()) - t0 < duration_s:
                pool.submit(fire, queries[i % len(queries)])
                i += 1
                next_t = t0 + i * period
                if (delay := next_t - time.perf_counter()) > 0:
                    time.sleep(delay)
        wall = time.perf_counter() - t0
    n = len(done)
    lat = registry.metrics.latency
    emit(f"serve.open.q{target_qps:g}.scale{scale}.achieved",
         wall / max(n, 1), f"qps={n / wall:.1f} offered={i / wall:.1f} "
                           f"errors={errors[0]}")
    emit(f"serve.open.q{target_qps:g}.scale{scale}.p50",
         lat.percentile(50) / 1e3)
    emit(f"serve.open.q{target_qps:g}.scale{scale}.p99",
         lat.percentile(99) / 1e3)
    emit(f"serve.open.q{target_qps:g}.scale{scale}.coalesced", 0,
         f"{registry.metrics.coalesced.total():.0f}/{n}")


SAME_SHAPE_TMPL = """SELECT ?c ?t WHERE {{
  {c} ub:takesCourse ?c .
  ?t ub:teacherOf ?c .
  ?t ub:worksFor ?d .
}}"""


def _skewed_constants(maps, n: int, pool_size: int = 512,
                      seed: int = 0) -> list[str]:
    """Zipf-ish draw over student instances: a hot head (whose exact
    duplicates the scheduler's fingerprint coalescing already dedupes)
    plus a long tail of *distinct* constants that only same-shape
    batching can amortize — the arrival pattern the parameterized plan
    cache is built for."""
    terms = maps.dict.terms.to_str
    pool = [t for t in terms
            if re.match(r"ub:(Undergraduate|Graduate)Student\d", t)]
    pool = pool[:pool_size]
    weights = [1.0 / (i + 1) ** 0.7 for i in range(len(pool))]
    return random.Random(seed).choices(pool, weights=weights, k=n)


def _coalesce_run(scale: int, consts: list[str], ref: dict[str, int],
                  batch_max: int, window_ms: float,
                  workers: int, client_threads: int) -> dict:
    """One open-loop burst through the scheduler; returns achieved qps and
    the count-mismatch tally (must be zero)."""
    g, maps = lubm_typeaware(scale, 0.6)
    metrics = ServeMetrics()
    registry = DatasetRegistry(metrics)
    registry.register("lubm", g, maps)
    mismatches = [0]
    lock = threading.Lock()
    with Scheduler(registry, workers=workers,
                   max_queue=2 * len(consts) + client_threads,
                   default_timeout_s=300.0, metrics=metrics,
                   batch_max=batch_max, batch_window_ms=window_ms) as sched:
        # warm outside the clock: per-constant plans for the unbatched path,
        # and every pow2 vmap lane count the batched path can see
        for c in ref:
            registry.execute("lubm", SAME_SHAPE_TMPL.format(c=c))
        if batch_max > 1:
            pqs = [parameterize_query(SAME_SHAPE_TMPL.format(c=c))
                   for c in consts[:batch_max]]
            version = registry.version("lubm")
            sz = 1
            while sz <= min(batch_max, len(pqs)):
                registry.execute_canonical_batch("lubm", pqs[:sz], version)
                sz *= 2
        # warm-up dispatches count too — measure deltas from here
        coal0 = metrics.coalesced_queries.total()
        disp0 = metrics.batch_size.count

        def fire(c: str) -> None:
            try:
                res = sched.submit("lubm", SAME_SHAPE_TMPL.format(c=c))
                ok = res.count == ref[c]
            except Exception:
                ok = False
            if not ok:
                with lock:
                    mismatches[0] += 1

        t0 = time.perf_counter()
        with ThreadPoolExecutor(max_workers=client_threads) as pool:
            for c in consts:
                pool.submit(fire, c)
        wall = time.perf_counter() - t0
    return {
        "qps": len(consts) / wall,
        "mismatches": mismatches[0],
        "coalesced": int(metrics.coalesced_queries.total() - coal0),
        "dispatches": int(metrics.batch_size.count - disp0),
    }


def coalesce_mix(scale: int, quick: bool) -> dict:
    """The snapshot headline: same burst, coalescing on vs off."""
    n = 256 if quick else 1024
    consts = _skewed_constants(lubm_typeaware(scale, 0.6)[1], n)
    g, maps = lubm_typeaware(scale, 0.6)
    ref_reg = DatasetRegistry()
    ref_reg.register("lubm", g, maps)
    ref = {c: ref_reg.execute("lubm", SAME_SHAPE_TMPL.format(c=c)).count
           for c in dict.fromkeys(consts)}
    # each side runs its best reasonable config: unbatched wants worker
    # parallelism, batched wants few deep dispatches (workers beyond 2
    # only fragment the batches)
    on = _coalesce_run(scale, consts, ref, batch_max=64, window_ms=3.0,
                       workers=2, client_threads=128)
    off = _coalesce_run(scale, consts, ref, batch_max=1, window_ms=0.0,
                        workers=4, client_threads=64)
    speedup = on["qps"] / max(off["qps"], 1e-9)
    emit(f"serve.coalesce.scale{scale}.on", 1.0 / max(on["qps"], 1e-9),
         f"qps={on['qps']:.1f} coalesced={on['coalesced']}/{n} "
         f"dispatches={on['dispatches']}")
    emit(f"serve.coalesce.scale{scale}.off", 1.0 / max(off["qps"], 1e-9),
         f"qps={off['qps']:.1f}")
    emit(f"serve.coalesce.scale{scale}.speedup", 0, f"{speedup:.2f}x")
    return {
        "n_queries": n,
        "distinct_constants": len(ref),
        "counts_ok": on["mismatches"] == 0 and off["mismatches"] == 0,
        "qps_on": round(on["qps"], 1),
        "qps_off": round(off["qps"], 1),
        "speedup": round(speedup, 3),
        "coalesced_on": on["coalesced"],
        "dispatches_on": on["dispatches"],
    }


def run(quick: bool = False) -> dict:
    scale = 1 if quick else 2
    rounds = 2 if quick else 5
    for clients in ([2, 4] if quick else [1, 4, 8]):
        closed_loop(scale, clients, rounds)
    for qps in ([20] if quick else [20, 50]):
        open_loop(scale, qps, duration_s=3.0 if quick else 10.0)
    return {"coalesce": coalesce_mix(scale, quick)}


if __name__ == "__main__":
    print("name,us_per_call,derived", flush=True)
    run(quick=True)
