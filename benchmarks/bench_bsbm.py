"""Paper Table 6: BSBM-like explore use case (OPTIONAL/FILTER/UNION)."""

from __future__ import annotations

from repro.core import ExecOpts, SparqlEngine
from repro.rdf.workloads import BSBM_QUERIES

from benchmarks.common import bench_query, bsbm, emit


def run(quick: bool = False) -> dict:
    g, maps = bsbm(400 if quick else 1500)
    engine = SparqlEngine(g, maps, ExecOpts())
    out = {}
    for name, q in sorted(BSBM_QUERIES.items()):
        res, secs = bench_query(engine, q, repeats=3 if quick else 5)
        out[name] = (res.count, secs)
        emit(f"bsbm.table6.{name}", secs, f"count={res.count}")
    return out


if __name__ == "__main__":
    run()
