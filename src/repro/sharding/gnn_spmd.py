"""Explicit-SPMD GNN training step (the "shard_map" profile).

The baseline GSPMD auto-partitioning of edge-sharded scatter-adds falls back
to involuntary full rematerialization — every chip redoes the whole
aggregation (the ~0.005 useful ratios in the baseline roofline table).
This builder runs the model inside shard_map with:

  - edge (or triplet) arrays sharded across ALL mesh axes,
  - node arrays and parameters replicated,
  - local segment reductions + psum/pmax (models' ``spmd_axes`` path),
  - pmean(grads) with the _scale_grad correction for exactness,

which is the standard production layout for full-graph GNN training.

Edge padding: shard_map needs the sharded axis divisible by the shard
count; pads use out-of-range segment ids (dropped by segment_sum) so they
are mathematically invisible.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.train.optimizer import OptConfig, adamw_update

SHARDED_FIELDS = {
    "gcn-cora": ("edge_src", "edge_dst"),
    "pna": ("edge_src", "edge_dst"),
    "meshgraphnet": ("edge_src", "edge_dst", "edge_attr"),
    "dimenet": ("t_kj", "t_ji"),
}
# pad value per field kind: segment targets pad out-of-range; gather sources
# pad 0 (their messages land in dropped segments)
_PAD_SEGMENT = {"edge_dst", "t_ji"}


def mesh_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)


def n_shards_of(mesh) -> int:
    out = 1
    for a in mesh_axes(mesh):
        out *= mesh.shape[a]
    return out


def pad_gnn_batch_abstract(arch_name: str, batch_abs: dict, n_shards: int,
                           n_drop_segment: int) -> dict:
    """Pad the sharded edge/triplet axes up to a multiple of n_shards."""
    out = dict(batch_abs)
    for f in SHARDED_FIELDS[arch_name]:
        x = out[f]
        e = x.shape[0]
        pad = (-e) % n_shards
        if pad:
            out[f] = jax.ShapeDtypeStruct((e + pad,) + tuple(x.shape[1:]),
                                          x.dtype)
    return out


def pad_gnn_batch(arch_name: str, batch: dict, n_shards: int,
                  n_drop_segment: int) -> dict:
    out = dict(batch)
    for f in SHARDED_FIELDS[arch_name]:
        x = np.asarray(out[f])
        pad = (-x.shape[0]) % n_shards
        if pad:
            fill = n_drop_segment if f in _PAD_SEGMENT else 0
            pads = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
            out[f] = np.pad(x, pads, constant_values=fill)
    return out


def make_spmd_train_step(arch_name: str, mod, cfg, opt_cfg: OptConfig, mesh,
                         edge_sharded: bool = False):
    axes = mesh_axes(mesh)
    ns = n_shards_of(mesh)
    kw = {"edge_sharded": True} if edge_sharded else {}
    cfg = dataclasses.replace(cfg, spmd_axes=axes, spmd_shards=ns, **kw)

    def local_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: mod.loss_fn(p, batch, cfg))(params)
        grads = jax.lax.pmean(grads, axes)
        loss = jax.lax.pmean(loss, axes)
        params, opt_state, gn = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, "grad_norm": gn,
                                   "step": opt_state.step}

    def batch_specs(batch_abs):
        sharded = set(SHARDED_FIELDS[arch_name])
        if edge_sharded:  # dimenet v2: edge arrays sharded too
            sharded |= {"edge_src", "edge_dst"}
        return {k: P(axes) if k in sharded else P()
                for k in batch_abs}

    def wrap(params_abs, opt_abs, batch_abs):
        pspec = jax.tree.map(lambda _: P(), params_abs)
        ospec = jax.tree.map(lambda _: P(), opt_abs)
        bspec = batch_specs(batch_abs)
        sm = jax.shard_map(
            local_step, mesh=mesh,
            in_specs=(pspec, ospec, bspec),
            out_specs=(pspec, ospec, {"loss": P(), "grad_norm": P(),
                                      "step": P()}),
            check_vma=False)
        return jax.jit(sm, donate_argnums=(0, 1))

    return wrap, cfg
