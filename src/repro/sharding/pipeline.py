"""GPipe-style pipeline parallelism over the ``pod`` axis.

The layer stack is split into S = pod-axis-size stages (layer-stacked params
sharded P("pod", ...) on the layer dim).  Microbatches stream through the
stages; activations move stage→stage with ``collective_permute`` each tick
(M + S − 1 ticks total, the classic GPipe bubble).  Because ppermute has a
transpose rule, ``jax.grad`` differentiates straight through the schedule —
backward runs the reverse pipeline automatically.

This is the dense-LM path (MoE layers keep EP over ``model`` instead of PP;
combining both is out of scope and noted in DESIGN.md).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.layers import cross_entropy_loss, rmsnorm, rope_angles
from repro.models.transformer import LMConfig, _layer_fwd


def _stage_layers_fwd(x, stage_params, cfg: LMConfig, sin, cos):
    """Run this stage's layer slice (scan over local layers)."""

    def body(carry, layer_p):
        y, _ = _layer_fwd(carry, layer_p, cfg, sin, cos, use_moe=False)
        return y, None

    x, _ = jax.lax.scan(body, x, stage_params)
    return x


def pipelined_loss(params, batch, cfg: LMConfig, *, n_stages: int,
                   n_microbatches: int, axis: str = "pod"):
    """SPMD GPipe loss, to be wrapped in shard_map over the ``pod`` axis.

    params: this stage's slice — {"embed","final_ln","lm_head",
    "dense_layers"(L/S leading)}; embed/head replicated (stage 0 embeds,
    last stage computes loss; the dead weights elsewhere are GSPMD-pruned).
    batch: full per-pod batch {"tokens","labels"} [B, T]; B split into
    microbatches here.
    """
    stage = jax.lax.axis_index(axis)
    tokens, labels = batch["tokens"], batch["labels"]
    b, t = tokens.shape
    m = n_microbatches
    mb = b // m
    tok_mb = tokens.reshape(m, mb, t)
    lab_mb = labels.reshape(m, mb, t)

    positions = jnp.arange(t, dtype=jnp.int32)
    dr = cfg.d_head
    sin, cos = rope_angles(positions, dr, cfg.rope_theta)
    sin, cos = sin[None, :, None, :], cos[None, :, None, :]

    n_ticks = m + n_stages - 1
    buf = jnp.zeros((mb, t, cfg.d_model), cfg.dtype)  # inter-stage activation
    loss_acc = jnp.zeros((), jnp.float32)
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def tick(carry, i):
        buf, loss_acc = carry
        mb_in = jnp.clip(i, 0, m - 1)
        mb_out = jnp.clip(i - (n_stages - 1), 0, m - 1)
        # stage 0 ingests microbatch i (if in range); others use buf
        x_in = params["embed"].astype(cfg.dtype)[tok_mb[mb_in]]
        x = jnp.where(stage == 0, x_in, buf)
        y = _stage_layers_fwd(x, params["dense_layers"], cfg, sin, cos)
        # last stage: compute loss for the microbatch that just completed
        h = rmsnorm(y, params["final_ln"])
        logits = h @ params["lm_head"].astype(y.dtype)
        mb_loss = cross_entropy_loss(logits, lab_mb[mb_out])
        active = (stage == n_stages - 1) & (i >= n_stages - 1) & (i < n_ticks)
        loss_acc = loss_acc + jnp.where(active, mb_loss, 0.0)
        # ship activations to the next stage
        buf = jax.lax.ppermute(y, axis, perm)
        return (buf, loss_acc), None

    (buf, loss_acc), _ = jax.lax.scan(tick, (buf, loss_acc),
                                      jnp.arange(n_ticks))
    # all stages must return the same loss: broadcast from the last stage
    total = jax.lax.psum(loss_acc, axis)  # only last stage contributed
    return total / m


def make_pipeline_train_step(cfg: LMConfig, opt_cfg, mesh,
                             n_microbatches: int = 4, axis: str = "pod"):
    """shard_map-wrapped pipelined train step (pods = stages)."""
    from jax.sharding import PartitionSpec as P

    from repro.train.optimizer import adamw_update

    n_stages = mesh.shape[axis]

    param_pspec = {
        "embed": P(),
        "final_ln": P(),
        "lm_head": P(),
        "dense_layers": jax.tree.map(lambda _: P(axis), {"any": 0}),
    }

    def local_step(params, opt_state, batch):
        def loss_fn(p):
            return pipelined_loss(p, batch, cfg, n_stages=n_stages,
                                  n_microbatches=n_microbatches, axis=axis)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        # DP mean over data axis happens in adamw_update(axis_name="data")
        params, opt_state, gn = adamw_update(
            params, grads, opt_state, opt_cfg,
            axis_name="data" if "data" in mesh.axis_names else None)
        return params, opt_state, {"loss": loss, "grad_norm": gn}

    return local_step, param_pspec
