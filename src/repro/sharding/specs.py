"""Sharding rules: parameter / optimizer / batch PartitionSpecs per family.

LM rules (Megatron-style TP + ZeRO-/FSDP-style data sharding):
  - column-parallel projections (wq/wk/wv/w_gate/w_up/w_uq/w_uk/w_uv):
    output dim → ``model``, input dim → ``data`` (ZeRO)
  - row-parallel projections (wo/w_down): input dim → ``model``, output →
    ``data``
  - MoE expert stacks: expert dim → ``model`` (expert parallelism), token
    dims ZeRO-sharded over ``data``
  - embed: vocab → ``model``;  lm_head: d → ``data``, vocab → ``model``
  - norms / small biases: replicated
Optimizer moments inherit the parameter spec (fully-sharded optimizer).

GNN rules: parameters replicated (they are tiny); edge arrays sharded over
every mesh axis; node tensors replicated (small graphs) or feature-sharded.

DLRM rules: embedding tables row-sharded over ``model`` when the vocab is
large & divisible (small tales replicated — the standard mixed placement);
MLPs replicated; batch over data axes.

All rules degrade to replication when a dimension is not divisible by the
assigned axis size — the fallback keeps every (arch × mesh) cell lowerable.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _fits(shape, spec, mesh) -> bool:
    for dim, axis in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axis is None:
            continue
        if dim % _axis_size(mesh, axis):
            return False
    return True


def _guard(shape, spec, mesh) -> P:
    """Use spec if divisible, else progressively drop axes (replicate)."""
    if _fits(shape, spec, mesh):
        return spec
    # drop axes one by one from the rightmost constrained dim
    axes = list(tuple(spec))
    for i in reversed(range(len(axes))):
        if axes[i] is not None:
            trial = P(*axes[:i], None, *axes[i + 1:])
            if _fits(shape, trial, mesh):
                return trial
            axes[i] = None
    return P()


# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------

_REPLICATED_NAMES = {"ln1", "ln2", "final_ln", "q_ln", "kv_ln", "q_norm",
                     "k_norm", "bq", "bk", "bv", "ln_g", "ln_b"}
_COL_NAMES = {"wq", "wk", "wv", "w_gate", "w_up", "w_uq", "w_uk", "w_uv"}
_ROW_NAMES = {"wo", "w_down"}


def _lm_leaf_spec(path: tuple[str, ...], shape, mesh, dp, zero: bool) -> P:
    name = path[-1]
    stacked = len(path) > 1 and path[0] in ("dense_layers", "moe_layers")
    in_moe = "moe" in path and "shared" not in path
    zdp = dp if zero else None

    if name in _REPLICATED_NAMES or len(shape) <= 1 + (1 if stacked else 0):
        return P()
    if name == "embed":
        return _guard(shape, P("model", None), mesh)
    if name == "lm_head":
        return _guard(shape, P(zdp, "model"), mesh)
    if name == "router":
        return _guard(shape, P(*(None, zdp, None)[: len(shape)]), mesh)

    lead = (None,) if stacked else ()
    if in_moe and name in _COL_NAMES:  # [L, E, d, ff]
        return _guard(shape, P(*lead, "model", zdp, None), mesh)
    if in_moe and name in _ROW_NAMES:  # [L, E, ff, d]
        return _guard(shape, P(*lead, "model", None, zdp), mesh)
    if name in _COL_NAMES:  # [L, d_in, d_out]
        return _guard(shape, P(*lead, zdp, "model"), mesh)
    if name in _ROW_NAMES:  # [L, d_in, d_out] row-parallel
        return _guard(shape, P(*lead, "model", zdp), mesh)
    if name in ("w_dq", "w_dkv", "w_kr"):  # small down-projections
        return _guard(shape, P(*lead, zdp, None), mesh)
    return P()


def _path_names(kp) -> tuple[str, ...]:
    names = []
    for entry in kp:
        if hasattr(entry, "key"):
            names.append(str(entry.key))
        elif hasattr(entry, "idx"):
            names.append(str(entry.idx))
        elif hasattr(entry, "name"):
            names.append(str(entry.name))
    return tuple(names)


def param_specs(abstract_params: Any, family: str, mesh, *,
                zero: bool = True) -> Any:
    """Pytree of PartitionSpec matching ``abstract_params``."""
    dp = "data"  # ZeRO axis; pod stays pure DP (gradients all-reduced)

    def leaf(kp, x):
        path = _path_names(kp)
        if family == "lm":
            return _lm_leaf_spec(path, x.shape, mesh, dp, zero)
        if family == "recsys":
            if "tables" in path and len(x.shape) == 2 and x.shape[0] >= 4096:
                return _guard(x.shape, P("model", None), mesh)
            return P()
        return P()  # gnn & default: replicate

    return jax.tree_util.tree_map_with_path(leaf, abstract_params)


def opt_state_specs(param_spec_tree: Any, opt_state_abstract: Any) -> Any:
    """AdamW moments inherit their parameter's spec; step scalar replicated."""
    from repro.train.optimizer import AdamWState

    def like(tree):
        return param_spec_tree

    return AdamWState(
        step=P(),
        mu=param_spec_tree,
        nu=param_spec_tree,
        err=param_spec_tree if opt_state_abstract.err is not None else None,
    )


# ---------------------------------------------------------------------------
# batches
# ---------------------------------------------------------------------------


def batch_specs(arch_family: str, cell_kind: str, batch_abstract, mesh,
                seq_shard: bool = False):
    """in_shardings for the batch pytree of one cell."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    every = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)

    def leaf(kp, x):
        path = _path_names(kp)
        name = path[-1] if path else ""
        shape = x.shape
        if arch_family == "lm":
            if name in ("tokens", "labels"):
                spec = P(dp, "model") if (seq_shard and len(shape) == 2
                                          and shape[1] > 1) else P(dp)
                return _guard(shape, spec, mesh)
            if name in ("k", "v"):  # [L, B, T, H, Dh]
                if shape[1] == 1:  # batch-1 long-context: sequence-shard the
                    return _guard(shape, P(None, None, every, None, None), mesh)
                return _guard(shape, P(None, dp, "model", None, None), mesh)
            if name in ("ckv", "krope"):  # [L, B, T, C]
                if shape[1] == 1:
                    return _guard(shape, P(None, None, every, None), mesh)
                return _guard(shape, P(None, dp, "model", None), mesh)
            if name == "pos":
                return P()
            return P()
        if arch_family == "gnn":
            if name in ("edge_src", "edge_dst", "t_kj", "t_ji"):
                return _guard(shape, P(every), mesh)
            if name == "edge_attr":
                return _guard(shape, P(every, None), mesh)
            if name in ("x",) and len(shape) == 2:
                return _guard(shape, P(None, "model"), mesh)
            return P()
        if arch_family == "recsys":
            if name == "cand":
                return _guard(shape, P(every, None), mesh)
            if name in ("dense", "sparse", "labels"):
                return _guard(shape, P(dp), mesh)
            return P()
        return P()

    return jax.tree_util.tree_map_with_path(leaf, batch_abstract)
