from repro.sharding.specs import (batch_specs, opt_state_specs, param_specs)

__all__ = ["param_specs", "batch_specs", "opt_state_specs"]
