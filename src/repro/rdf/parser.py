"""Minimal N-Triples parser (subset sufficient for the benchmarks and tests).

Grammar per line:  ``subject predicate object .``
  subject   := <IRI> | prefixed:name | _:blank
  predicate := <IRI> | prefixed:name
  object    := subject-forms | "literal" | "literal"^^<type> | "literal"@lang

Comments (``# ...``) and blank lines are skipped.  Malformed lines raise
``ParseError`` with a line number (strict mode) or are counted and skipped
(lenient mode — the BTC2012 dataset in the paper is famously dirty).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Iterator, TextIO

from repro.rdf.triples import TripleStore


class ParseError(ValueError):
    pass


@dataclass
class ParseStats:
    lines: int = 0
    triples: int = 0
    skipped: int = 0


def _scan_term(line: str, pos: int, lineno: int) -> tuple[str, int]:
    """Return (term, new_pos) starting at first non-space char at/after pos."""
    n = len(line)
    while pos < n and line[pos] in " \t":
        pos += 1
    if pos >= n:
        raise ParseError(f"line {lineno}: unexpected end of line")
    c = line[pos]
    if c == "<":  # IRI
        end = line.find(">", pos + 1)
        if end < 0:
            raise ParseError(f"line {lineno}: unterminated IRI")
        return line[pos + 1 : end], end + 1
    if c == '"':  # literal (with escapes), optional ^^type / @lang suffix
        i = pos + 1
        while i < n:
            if line[i] == "\\":
                i += 2
                continue
            if line[i] == '"':
                break
            i += 1
        if i >= n:
            raise ParseError(f"line {lineno}: unterminated literal")
        end = i + 1
        # consume datatype / language tag into the lexical form
        if end < n and line[end] == "@":
            while end < n and line[end] not in " \t":
                end += 1
        elif end + 1 < n and line[end : end + 2] == "^^":
            end += 2
            if end < n and line[end] == "<":
                close = line.find(">", end)
                if close < 0:
                    raise ParseError(f"line {lineno}: unterminated datatype IRI")
                end = close + 1
        return line[pos:end], end
    # prefixed name or blank node: read to whitespace
    end = pos
    while end < n and line[end] not in " \t":
        end += 1
    term = line[pos:end]
    if term.endswith("."):  # allow `obj .` glued to the dot
        term = term[:-1]
        end -= 1
    if not term:
        raise ParseError(f"line {lineno}: empty term")
    return term, end


def parse_line(line: str, lineno: int = 0) -> tuple[str, str, str] | None:
    stripped = line.strip()
    if not stripped or stripped.startswith("#"):
        return None
    s, pos = _scan_term(line, 0, lineno)
    p, pos = _scan_term(line, pos, lineno)
    o, pos = _scan_term(line, pos, lineno)
    rest = line[pos:].strip()
    if rest not in (".", ""):
        raise ParseError(f"line {lineno}: trailing garbage {rest!r}")
    return s, p, o


def parse_ntriples(
    lines: Iterable[str] | TextIO,
    store: TripleStore | None = None,
    strict: bool = True,
) -> tuple[TripleStore, ParseStats]:
    store = store if store is not None else TripleStore()
    stats = ParseStats()
    for lineno, line in enumerate(lines, start=1):
        stats.lines += 1
        try:
            t = parse_line(line, lineno)
        except ParseError:
            if strict:
                raise
            stats.skipped += 1
            continue
        if t is None:
            continue
        store.add(*t)
        stats.triples += 1
    return store, stats


def serialize_ntriples(triples: Iterable[tuple[str, str, str]]) -> Iterator[str]:
    """Inverse of the parser for round-trip tests: IRIs <>-wrapped unless literal/prefixed."""
    for s, p, o in triples:
        yield f"{_wrap(s)} {_wrap(p)} {_wrap(o)} ."


def _wrap(term: str) -> str:
    if term.startswith('"') or term.startswith("_:"):
        return term
    if ":" in term and not term.startswith("http"):
        return term  # prefixed name
    return f"<{term}>"
