"""Synthetic RDF dataset generators shaped like the paper's benchmarks.

- ``generate_lubm(scale)``  — LUBM-like university data (paper Tables 2/3/7):
  regular schema, deep-ish class hierarchy, constant- and increasing-solution
  query behavior reproduced by construction (per-university subtree sizes are
  scale-invariant; the number of universities grows with scale).
- ``generate_hetero(...)``  — YAGO/BTC-like: many types, power-law degrees,
  irregular predicates (paper Tables 4/5).
- ``generate_bsbm(...)``    — BSBM-like e-commerce data with numeric literals
  and optional attributes, exercising FILTER / OPTIONAL / UNION (Table 6).

All generators are deterministic given ``seed``.
"""

from __future__ import annotations

import numpy as np

from repro.rdf.dictionary import RDF_TYPE, RDFS_SUBCLASSOF
from repro.rdf.triples import TripleStore

# ---------------------------------------------------------------------------
# LUBM-like
# ---------------------------------------------------------------------------

LUBM_HIERARCHY: list[tuple[str, str]] = [
    ("ub:FullProfessor", "ub:Professor"),
    ("ub:AssociateProfessor", "ub:Professor"),
    ("ub:AssistantProfessor", "ub:Professor"),
    ("ub:Professor", "ub:Faculty"),
    ("ub:Lecturer", "ub:Faculty"),
    ("ub:Faculty", "ub:Employee"),
    ("ub:Employee", "ub:Person"),
    ("ub:UndergraduateStudent", "ub:Student"),
    ("ub:GraduateStudent", "ub:Student"),
    ("ub:Student", "ub:Person"),
    ("ub:Chair", "ub:Professor"),
    ("ub:TeachingAssistant", "ub:Person"),
    ("ub:GraduateCourse", "ub:Course"),
    ("ub:ResearchGroup", "ub:Organization"),
    ("ub:Department", "ub:Organization"),
    ("ub:University", "ub:Organization"),
]


def generate_lubm(
    scale: int = 1,
    seed: int = 0,
    density: float = 1.0,
) -> TripleStore:
    """LUBM-like generator.  ``scale`` = number of universities.

    ``density`` scales per-department entity counts (1.0 ≈ a few thousand
    triples per department, like LUBM's shape at reduced magnitude so CPU
    benchmarks stay tractable).
    """
    st = TripleStore()
    add = st.add

    for sub, sup in LUBM_HIERARCHY:
        add(sub, RDFS_SUBCLASSOF, sup)

    # degrees point into a FIXED-size university pool (like LUBM, where
    # anchored per-university content is scale-invariant and unanchored
    # query answers grow linearly with scale — paper Table 2)
    DEGREE_POOL = 5

    for u in range(scale):
        # per-university RNG stream: Univ{u}'s subtree is byte-identical at
        # every scale factor (constant-solution queries stay constant)
        rng = np.random.default_rng((seed, u))

        def d(lo: int, hi: int) -> int:
            return max(1, int(round(rng.integers(lo, hi + 1) * density)))

        def rand_univ() -> str:
            # fixed-bound draws keep the stream aligned across scale factors
            # (np's integers() uses rejection sampling, so a scale-dependent
            # bound would desynchronize Univ{u}'s content between scales);
            # 30% of degrees are from one's own university so unanchored
            # triangle/alumni queries (Q2/Q13) grow with scale while
            # anchored per-university content stays byte-identical.
            own = rng.random() < 0.3
            r = int(rng.integers(DEGREE_POOL))
            if own:
                return f"ub:Univ{u}"
            return f"ub:Univ{r % max(1, min(scale, DEGREE_POOL))}"

        univ = f"ub:Univ{u}"
        add(univ, RDF_TYPE, "ub:University")
        n_depts = d(12, 18)
        for dep in range(n_depts):
            dept = f"ub:Dept{dep}.Univ{u}"
            add(dept, RDF_TYPE, "ub:Department")
            add(dept, "ub:subOrganizationOf", univ)

            n_full = d(3, 5)
            n_assoc = d(4, 6)
            n_asst = d(3, 5)
            n_lect = d(2, 4)
            faculty: list[str] = []
            for kind, count in (
                ("FullProfessor", n_full),
                ("AssociateProfessor", n_assoc),
                ("AssistantProfessor", n_asst),
                ("Lecturer", n_lect),
            ):
                for i in range(count):
                    f = f"ub:{kind}{i}.{dept[3:]}"
                    add(f, RDF_TYPE, f"ub:{kind}")
                    add(f, "ub:worksFor", dept)
                    add(f, "ub:name", f'"{kind}{i} of {dept[3:]}"')
                    add(f, "ub:emailAddress", f'"{kind}{i}@{dept[3:]}.edu"')
                    add(f, "ub:telephone", f'"555-{u:03d}-{dep:03d}-{i:03d}"')
                    if kind != "Lecturer":
                        # degrees from random universities (within generated range)
                        add(f, "ub:undergraduateDegreeFrom", rand_univ())
                        add(f, "ub:mastersDegreeFrom", rand_univ())
                        add(f, "ub:doctoralDegreeFrom", rand_univ())
                        add(f, "ub:researchInterest", f'"Research{int(rng.integers(30))}"')
                    faculty.append(f)
            # chair: the first full professor also heads the department
            chair = faculty[0]
            add(chair, RDF_TYPE, "ub:Chair")
            add(chair, "ub:headOf", dept)

            n_courses = d(8, 12)
            n_gcourses = d(5, 8)
            courses = []
            gcourses = []
            for c in range(n_courses):
                crs = f"ub:Course{c}.{dept[3:]}"
                add(crs, RDF_TYPE, "ub:Course")
                courses.append(crs)
            for c in range(n_gcourses):
                crs = f"ub:GraduateCourse{c}.{dept[3:]}"
                add(crs, RDF_TYPE, "ub:GraduateCourse")
                gcourses.append(crs)
            for crs in courses + gcourses:
                add(rng.choice(faculty), "ub:teacherOf", crs)

            n_ugrad = d(25, 40)
            n_grad = d(8, 14)
            for i in range(n_ugrad):
                s = f"ub:UndergraduateStudent{i}.{dept[3:]}"
                add(s, RDF_TYPE, "ub:UndergraduateStudent")
                add(s, "ub:memberOf", dept)
                add(s, "ub:name", f'"UGStudent{i} of {dept[3:]}"')
                for crs in rng.choice(courses, size=min(len(courses), 3), replace=False):
                    add(s, "ub:takesCourse", str(crs))
                if rng.random() < 0.2:
                    add(s, "ub:advisor", str(rng.choice(faculty)))
            for i in range(n_grad):
                s = f"ub:GraduateStudent{i}.{dept[3:]}"
                add(s, RDF_TYPE, "ub:GraduateStudent")
                add(s, "ub:memberOf", dept)
                add(s, "ub:emailAddress", f'"gs{i}@{dept[3:]}.edu"')
                add(s, "ub:undergraduateDegreeFrom", rand_univ())
                for crs in rng.choice(gcourses, size=min(len(gcourses), 2), replace=False):
                    add(s, "ub:takesCourse", str(crs))
                adv = str(rng.choice(faculty))
                add(s, "ub:advisor", adv)
                if rng.random() < 0.25:
                    ta_course = str(rng.choice(courses))
                    add(s, RDF_TYPE, "ub:TeachingAssistant")
                    add(s, "ub:teachingAssistantOf", ta_course)

            n_groups = d(3, 6)
            for gidx in range(n_groups):
                grp = f"ub:ResearchGroup{gidx}.{dept[3:]}"
                add(grp, RDF_TYPE, "ub:ResearchGroup")
                add(grp, "ub:subOrganizationOf", dept)

            n_pubs = d(10, 20)
            for pidx in range(n_pubs):
                pub = f"ub:Publication{pidx}.{dept[3:]}"
                add(pub, RDF_TYPE, "ub:Publication")
                add(pub, "ub:publicationAuthor", str(rng.choice(faculty)))
                if rng.random() < 0.4:
                    gs = f"ub:GraduateStudent{int(rng.integers(n_grad))}.{dept[3:]}"
                    add(pub, "ub:publicationAuthor", gs)
    return st


# ---------------------------------------------------------------------------
# Heterogeneous (YAGO / BTC2012-like)
# ---------------------------------------------------------------------------


def generate_hetero(
    n_entities: int = 20000,
    n_types: int = 40,
    n_predicates: int = 25,
    avg_degree: float = 6.0,
    seed: int = 0,
    subclass_pairs: int = 15,
) -> TripleStore:
    """Irregular, power-law graph with many types — YAGO/BTC-style."""
    rng = np.random.default_rng(seed)
    st = TripleStore()
    types = [f"y:Type{t}" for t in range(n_types)]
    preds = [f"y:pred{p}" for p in range(n_predicates)]
    # shallow random class DAG
    for _ in range(subclass_pairs):
        a, b = rng.integers(n_types, size=2)
        if a != b:
            st.add(types[int(a)], RDFS_SUBCLASSOF, types[int(min(a, b))])
    # type assignment: 1–3 types, zipf-ish popularity
    type_pop = rng.zipf(1.6, size=n_entities) % n_types
    for e in range(n_entities):
        ent = f"y:e{e}"
        st.add(ent, RDF_TYPE, types[int(type_pop[e])])
        if rng.random() < 0.35:
            st.add(ent, RDF_TYPE, types[int(rng.integers(n_types))])
    # power-law out-degrees, preferential-attachment-ish targets
    n_edges = int(n_entities * avg_degree)
    src = rng.zipf(1.3, size=n_edges) % n_entities
    dst = rng.zipf(1.2, size=n_edges) % n_entities
    pe = rng.integers(n_predicates, size=n_edges)
    for i in range(n_edges):
        st.add(f"y:e{int(src[i])}", preds[int(pe[i])], f"y:e{int(dst[i])}")
    # sprinkle literals
    for e in range(0, n_entities, 7):
        st.add(f"y:e{e}", "y:label", f'"entity {e}"')
    return st


# ---------------------------------------------------------------------------
# BSBM-like (FILTER / OPTIONAL / UNION workloads)
# ---------------------------------------------------------------------------


def generate_bsbm(
    n_products: int = 2000,
    n_producers: int = 40,
    n_features: int = 60,
    n_vendors: int = 20,
    reviews_per_product: float = 3.0,
    seed: int = 0,
) -> TripleStore:
    rng = np.random.default_rng(seed)
    st = TripleStore()
    st.add("b:Product", RDFS_SUBCLASSOF, "b:Thing")
    st.add("b:Review", RDFS_SUBCLASSOF, "b:Thing")
    for pr in range(n_producers):
        st.add(f"b:Producer{pr}", RDF_TYPE, "b:Producer")
    for f in range(n_features):
        st.add(f"b:Feature{f}", RDF_TYPE, "b:ProductFeature")
    for v in range(n_vendors):
        st.add(f"b:Vendor{v}", RDF_TYPE, "b:Vendor")
        st.add(f"b:Vendor{v}", "b:country", f'"{ "US" if v % 2 else "DE" }"')
    for p in range(n_products):
        prod = f"b:Product{p}"
        st.add(prod, RDF_TYPE, "b:Product")
        st.add(prod, "b:producer", f"b:Producer{int(rng.integers(n_producers))}")
        st.add(prod, "b:label", f'"product {p}"')
        st.add(prod, "b:propertyNumeric1", f'"{int(rng.integers(1, 2000))}"')
        st.add(prod, "b:propertyNumeric2", f'"{int(rng.integers(1, 2000))}"')
        for f in rng.choice(n_features, size=int(rng.integers(2, 6)), replace=False):
            st.add(prod, "b:productFeature", f"b:Feature{int(f)}")
        # offers
        for _ in range(int(rng.integers(1, 4))):
            off = f"b:Offer{p}.{int(rng.integers(10**6))}"
            st.add(off, RDF_TYPE, "b:Offer")
            st.add(off, "b:product", prod)
            st.add(off, "b:vendor", f"b:Vendor{int(rng.integers(n_vendors))}")
            st.add(off, "b:price", f'"{float(rng.uniform(5, 500)):.2f}"')
        # reviews; rating2/homepage optional (for OPTIONAL queries)
        for r in range(int(rng.poisson(reviews_per_product))):
            rev = f"b:Review{p}.{r}"
            st.add(rev, RDF_TYPE, "b:Review")
            st.add(rev, "b:reviewFor", prod)
            st.add(rev, "b:rating1", f'"{int(rng.integers(1, 11))}"')
            if rng.random() < 0.6:
                st.add(rev, "b:rating2", f'"{int(rng.integers(1, 11))}"')
            if rng.random() < 0.3:
                st.add(rev, "b:reviewerHomepage", f'"http://rev/{p}/{r}"')
    return st
