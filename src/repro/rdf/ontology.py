"""Class-hierarchy handling: transitive ``rdf:subClassOf`` closure.

The type-aware transformation (Definition 3.7) labels a vertex with every
class reachable from its ``rdf:type`` objects through ``rdf:subClassOf``
chains — i.e. L(v) = types(v) expanded by the transitive closure of the
subclass DAG.  The closure is computed once per dataset with a memoized DFS
(cycle-safe: malformed data like BTC2012 can contain subclass cycles).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class ClassHierarchy:
    """Superclass closure over class ids (vertex-label id space)."""

    parents: dict[int, set[int]] = field(default_factory=dict)  # direct superclasses
    _closure: dict[int, frozenset[int]] = field(default_factory=dict)

    def add_subclass(self, sub: int, sup: int) -> None:
        self.parents.setdefault(sub, set()).add(sup)
        self._closure.clear()

    def superclasses(self, cls: int) -> frozenset[int]:
        """All classes reachable from ``cls`` (including itself)."""
        hit = self._closure.get(cls)
        if hit is not None:
            return hit
        # iterative DFS with a visiting set for cycle safety
        result: set[int] = {cls}
        stack = [cls]
        seen = {cls}
        while stack:
            cur = stack.pop()
            for sup in self.parents.get(cur, ()):
                if sup not in seen:
                    seen.add(sup)
                    result.add(sup)
                    stack.append(sup)
        fs = frozenset(result)
        self._closure[cls] = fs
        return fs

    def expand_types(self, types: set[int]) -> frozenset[int]:
        out: set[int] = set()
        for t in types:
            out |= self.superclasses(t)
        return frozenset(out)


def closure_matrix(h: ClassHierarchy, n_classes: int) -> np.ndarray:
    """Dense bool [n, n] reachability matrix (for tests / small ontologies)."""
    mat = np.zeros((n_classes, n_classes), dtype=bool)
    for c in range(n_classes):
        for s in h.superclasses(c):
            if s < n_classes:
                mat[c, s] = True
    return mat
