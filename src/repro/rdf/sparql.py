"""SPARQL subset parser (BGP + OPTIONAL + FILTER + UNION + PREFIX).

Grammar (recursive descent):

    query     := prologue SELECT varlist WHERE group
    prologue  := (PREFIX name: <iri>)*
    varlist   := '*' | var+
    group     := '{' item* '}'
    item      := triple '.'?                      (BGP triple pattern)
               | OPTIONAL group
               | FILTER expr
               | group (UNION group)+             (alternative groups)
    triple    := term term term
    term      := var | <iri> | prefixed | literal | number
    expr      := '(' cmp ')' | REGEX '(' var ',' literal ')'
    cmp       := operand op operand ( '&&' cmp )*
    op        := < <= > >= = !=

This is the fragment the paper evaluates (basic graph patterns for
LUBM/YAGO/BTC + the explore-use-case keywords for BSBM).  Modifiers the
paper strips (DISTINCT/ORDER BY) are accepted and ignored with a warning.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Union

from repro.utils import get_logger

log = get_logger("rdf.sparql")


# --------------------------------------------------------------------- AST
@dataclass(frozen=True)
class Var:
    name: str


@dataclass(frozen=True)
class Iri:
    value: str  # normalized (prefix-expanded if prefix known, else as written)


@dataclass(frozen=True)
class Literal:
    value: str  # lexical form WITHOUT quotes
    numeric: float | None = None


Term = Union[Var, Iri, Literal]


@dataclass(frozen=True)
class TriplePattern:
    s: Term
    p: Term
    o: Term


@dataclass(frozen=True)
class Comparison:
    lhs: Term
    op: str  # < <= > >= = !=
    rhs: Term


@dataclass(frozen=True)
class Regex:
    var: Var
    pattern: str


FilterExpr = Union[Comparison, Regex]


@dataclass
class GroupPattern:
    triples: list[TriplePattern] = field(default_factory=list)
    filters: list[FilterExpr] = field(default_factory=list)
    optionals: list["GroupPattern"] = field(default_factory=list)
    unions: list[list["GroupPattern"]] = field(default_factory=list)  # each: ≥2 branches


@dataclass
class SelectQuery:
    select: list[str]  # variable names, empty = '*'
    where: GroupPattern
    prefixes: dict[str, str] = field(default_factory=dict)
    # solution modifiers (applied post-matching; ORDER BY is still ignored)
    distinct: bool = False
    limit: int | None = None
    offset: int = 0

    @property
    def has_modifiers(self) -> bool:
        return self.distinct or self.limit is not None or self.offset > 0


# ------------------------------------------------------------------ lexer
_TOKEN_RE = re.compile(
    r"""
    (?P<WS>\s+)
  | (?P<COMMENT>\#[^\n]*)
  | (?P<IRI><[^>\s]*>)
  | (?P<LITERAL>"(?:[^"\\]|\\.)*"(?:@\w+|\^\^<[^>]*>|\^\^\w+:\w+)?)
  | (?P<VAR>[?$][A-Za-z_][A-Za-z0-9_]*)
  | (?P<NUMBER>[+-]?\d+(?:\.\d+)?)
  | (?P<LBRACE>\{) | (?P<RBRACE>\})
  | (?P<LPAREN>\() | (?P<RPAREN>\))
  | (?P<DOT>\.(?!\w))
  | (?P<COMMA>,)
  | (?P<OP><=|>=|!=|=|<|>|&&|\|\|)
  | (?P<STAR>\*)
  | (?P<NAME>[A-Za-z_][\w.\-]*(?::[\w.\-]*)*)
""",
    re.VERBOSE,
)

_KEYWORDS = {"SELECT", "WHERE", "OPTIONAL", "FILTER", "UNION", "PREFIX", "REGEX",
             "DISTINCT", "ORDER", "BY", "LIMIT", "OFFSET", "ASC", "DESC", "A"}


@dataclass
class _Tok:
    kind: str
    text: str
    pos: int


class SparqlError(ValueError):
    pass


def normalize_iri(iri: str) -> str:
    """Canonical short forms for the well-known vocabulary.  Shared by the
    query parser and the SPARQL UPDATE parser — both sides MUST intern the
    same term string or updates become unfindable by queries."""
    if iri.endswith("#type") or iri.endswith("/type"):
        return "rdf:type"
    if iri.endswith("#subClassOf"):
        return "rdf:subClassOf"
    return iri


def normalize_prefixed(name: str) -> str:
    if name in ("rdf:type", "rdfs:subClassOf", "rdf:subClassOf"):
        return "rdf:type" if name == "rdf:type" else "rdf:subClassOf"
    # datasets in this repo use prefixed names directly as dictionary terms
    return name


def _lex(src: str) -> list[_Tok]:
    toks: list[_Tok] = []
    pos = 0
    while pos < len(src):
        m = _TOKEN_RE.match(src, pos)
        if not m:
            raise SparqlError(f"lex error at {pos}: {src[pos:pos + 20]!r}")
        kind = m.lastgroup or ""
        text = m.group()
        pos = m.end()
        if kind in ("WS", "COMMENT"):
            continue
        if kind == "NAME" and text.upper() in _KEYWORDS:
            kind = text.upper() if text.upper() != "A" else "A"
        toks.append(_Tok(kind, text, m.start()))
    toks.append(_Tok("EOF", "", len(src)))
    return toks


# ----------------------------------------------------------------- parser
class _Parser:
    def __init__(self, src: str):
        self.toks = _lex(src)
        self.i = 0
        self.prefixes: dict[str, str] = {}

    def peek(self) -> _Tok:
        return self.toks[self.i]

    def next(self) -> _Tok:
        t = self.toks[self.i]
        self.i += 1
        return t

    def expect(self, kind: str) -> _Tok:
        t = self.next()
        if t.kind != kind:
            raise SparqlError(f"expected {kind}, got {t.kind} {t.text!r} at {t.pos}")
        return t

    # ---- entry
    def parse(self) -> SelectQuery:
        while self.peek().kind == "PREFIX":
            self.next()
            name = self.expect("NAME").text
            iri = self.expect("IRI").text[1:-1]
            self.prefixes[name.rstrip(":")] = iri
        self.expect("SELECT")
        distinct = False
        if self.peek().kind == "DISTINCT":
            distinct = True
            self.next()
        select: list[str] = []
        if self.peek().kind == "STAR":
            self.next()
        else:
            while self.peek().kind == "VAR":
                select.append(self.next().text[1:])
        self.expect("WHERE")
        where = self.group()
        # solution modifiers: LIMIT/OFFSET are honored, ORDER BY is parsed
        # and ignored (the engine returns unordered bindings)
        limit: int | None = None
        offset = 0
        while self.peek().kind != "EOF":
            t = self.next()
            if t.kind in ("LIMIT", "OFFSET"):
                n = self.expect("NUMBER")
                try:
                    val = int(n.text)
                except ValueError:
                    raise SparqlError(
                        f"{t.kind} needs an integer, got {n.text!r} at {n.pos}"
                    ) from None
                if val < 0:
                    raise SparqlError(f"{t.kind} must be >= 0 (at {n.pos})")
                if t.kind == "LIMIT":
                    limit = val
                else:
                    offset = val
            elif t.kind == "ORDER":
                log.debug("ignoring ORDER BY (engine returns unordered rows)")
            elif t.kind in ("BY", "ASC", "DESC", "NUMBER", "VAR", "LPAREN",
                            "RPAREN"):
                continue
            else:
                raise SparqlError(
                    f"unexpected trailing token {t.text!r} at {t.pos}")
        return SelectQuery(select=select, where=where, prefixes=self.prefixes,
                           distinct=distinct, limit=limit, offset=offset)

    # ---- group
    def group(self) -> GroupPattern:
        self.expect("LBRACE")
        g = GroupPattern()
        while True:
            t = self.peek()
            if t.kind == "RBRACE":
                self.next()
                return g
            if t.kind == "OPTIONAL":
                self.next()
                g.optionals.append(self.group())
            elif t.kind == "FILTER":
                self.next()
                g.filters.append(self.filter_expr())
            elif t.kind == "LBRACE":
                branches = [self.group()]
                while self.peek().kind == "UNION":
                    self.next()
                    branches.append(self.group())
                if len(branches) < 2:
                    # plain nested group: merge into parent
                    sub = branches[0]
                    g.triples += sub.triples
                    g.filters += sub.filters
                    g.optionals += sub.optionals
                    g.unions += sub.unions
                else:
                    g.unions.append(branches)
            elif t.kind == "EOF":
                raise SparqlError("unexpected EOF inside group")
            else:
                g.triples.append(self.triple())
                if self.peek().kind == "DOT":
                    self.next()
        # unreachable

    def triple(self) -> TriplePattern:
        s = self.term()
        p = self.term(pred=True)
        o = self.term()
        return TriplePattern(s, p, o)

    def term(self, pred: bool = False) -> Term:
        t = self.next()
        if t.kind == "VAR":
            return Var(t.text[1:])
        if t.kind == "IRI":
            return Iri(self._expand_iri(t.text[1:-1]))
        if t.kind == "NAME":
            return Iri(self._expand_prefixed(t.text))
        if t.kind == "A" and pred:
            return Iri("rdf:type")
        if t.kind == "LITERAL":
            lex = _literal_lexical(t.text)
            return Literal(lex, _try_float(lex))
        if t.kind == "NUMBER":
            return Literal(t.text, float(t.text))
        raise SparqlError(f"bad term {t.text!r} at {t.pos}")

    def _expand_iri(self, iri: str) -> str:
        return normalize_iri(iri)

    def _expand_prefixed(self, name: str) -> str:
        return normalize_prefixed(name)

    # ---- filters
    def filter_expr(self) -> FilterExpr:
        t = self.peek()
        if t.kind == "REGEX":
            self.next()
            self.expect("LPAREN")
            var = self.term()
            if not isinstance(var, Var):
                raise SparqlError("regex() first arg must be a variable")
            self.expect("COMMA")
            lit = self.next()
            if lit.kind != "LITERAL":
                raise SparqlError("regex() second arg must be a literal")
            self.expect("RPAREN")
            return Regex(var, _literal_lexical(lit.text))
        self.expect("LPAREN")
        cmp = self._comparison()
        # only single comparisons (optionally &&-chained comparisons are split
        # into multiple filters by the caller; reject || at parse level)
        exprs = [cmp]
        while self.peek().kind == "OP" and self.peek().text == "&&":
            self.next()
            exprs.append(self._comparison())
        self.expect("RPAREN")
        if len(exprs) == 1:
            return exprs[0]
        # represent && as a chain by returning the list through a wrapper
        return _AndChain(exprs)  # type: ignore[return-value]

    def _comparison(self) -> Comparison:
        lhs = self.term()
        op = self.expect("OP").text
        if op in ("&&", "||"):
            raise SparqlError(f"unexpected {op}")
        rhs = self.term()
        return Comparison(lhs, op, rhs)


@dataclass(frozen=True)
class _AndChain:
    exprs: list[Comparison]


def _literal_lexical(tok: str) -> str:
    end = tok.rfind('"')
    return tok[1:end]


def _try_float(s: str) -> float | None:
    try:
        return float(s)
    except ValueError:
        return None


def parse_sparql(src: str) -> SelectQuery:
    q = _Parser(src).parse()
    # flatten &&-chains into separate filters
    def _flatten(g: GroupPattern) -> None:
        flat: list[FilterExpr] = []
        for f in g.filters:
            if isinstance(f, _AndChain):
                flat.extend(f.exprs)
            else:
                flat.append(f)
        g.filters = flat
        for o in g.optionals:
            _flatten(o)
        for branches in g.unions:
            for b in branches:
                _flatten(b)

    _flatten(q.where)
    return q
