"""Dictionary encoding for RDF terms.

The paper (§3.2, §4.1) maps subjects/objects to a vertex-ID space and
predicates to an edge-label space; the type-aware transformation additionally
maps ``rdf:type`` / ``rdf:subClassOf`` objects to a vertex-*label* space.
This module owns the string <-> id bijections (``F_V``/``F_ID``, ``F_EL``,
``F_VL`` in Definition 3).  Benchmark timings exclude dictionary lookups,
matching the paper's protocol.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable

# Canonical IRIs for the two predicates the type-aware transformation folds away.
RDF_TYPE = "rdf:type"
RDFS_SUBCLASSOF = "rdf:subClassOf"


@dataclass
class _Interner:
    """Append-only string interner with O(1) lookup both ways.

    Append-only is a load-bearing property: the live store
    (:mod:`repro.store`) keeps interning new terms *after* the triple
    store is finalized, and every id handed out earlier must stay stable
    across those insertions (and across compactions)."""

    to_id: dict[str, int] = field(default_factory=dict)
    to_str: list[str] = field(default_factory=list)

    def intern(self, term: str) -> int:
        tid = self.to_id.get(term)
        if tid is None:
            tid = len(self.to_str)
            self.to_id[term] = tid
            self.to_str.append(term)
        return tid

    def get(self, term: str) -> int | None:
        return self.to_id.get(term)

    def __len__(self) -> int:
        return len(self.to_str)


@dataclass
class Dictionary:
    """Three independent id spaces: terms (vertices), predicates, vertex labels."""

    terms: _Interner = field(default_factory=_Interner)
    predicates: _Interner = field(default_factory=_Interner)
    vlabels: _Interner = field(default_factory=_Interner)
    # literal ids (subset of term ids) — literals can never be subjects.
    literal_ids: set[int] = field(default_factory=set)

    # -- encoding -------------------------------------------------------------
    def encode_term(self, term: str) -> int:
        tid = self.terms.intern(term)
        if term.startswith('"'):
            self.literal_ids.add(tid)
        return tid

    def encode_predicate(self, pred: str) -> int:
        return self.predicates.intern(pred)

    def encode_vlabel(self, label: str) -> int:
        return self.vlabels.intern(label)

    # -- decoding / lookup ----------------------------------------------------
    def term(self, tid: int) -> str:
        return self.terms.to_str[tid]

    def predicate(self, pid: int) -> str:
        return self.predicates.to_str[pid]

    def vlabel(self, lid: int) -> str:
        return self.vlabels.to_str[lid]

    def term_id(self, term: str) -> int | None:
        return self.terms.get(term)

    def predicate_id(self, pred: str) -> int | None:
        return self.predicates.get(pred)

    def vlabel_id(self, label: str) -> int | None:
        return self.vlabels.get(label)

    @property
    def n_terms(self) -> int:
        return len(self.terms)

    @property
    def n_predicates(self) -> int:
        return len(self.predicates)

    @property
    def n_vlabels(self) -> int:
        return len(self.vlabels)

    def encode_terms(self, terms: Iterable[str]) -> list[int]:
        return [self.encode_term(t) for t in terms]
