"""In-memory labeled graph — the paper's §4.2 data structures, array-native.

The paper keeps (1) an *inverse vertex-label list* and (2) *adjacency lists
grouped by neighbor type* (edge label, vertex label), both as offset+array
pairs, one copy per direction.  We materialize the same information as flat
numpy/JAX arrays so the vectorized executor can gather slices with tensor ops:

- ``out_indptr_el[el, v] : out_indptr_el[el, v+1]`` slices ``out_nbr_el``
  (dst vertices sorted by (el, src, dst)) — the per-edge-label CSR used by
  tree-edge expansion and the +INT / edge-exists join primitives.  The
  ``[n_elabels, n_vertices+1]`` offset table lives on the *host*; compiled
  plans receive only the rows for edge labels the query mentions.
- a plain CSR (``out_indptr_all`` / ``out_nbr_all`` / ``out_lab_all``,
  sorted by (src, dst)) used when a query edge has a *predicate variable*
  (blank edge label) and for e-hom edge-label binding.
- the same two structures for the incoming direction.
- ``label_bitmap``: packed uint32 vertex-label sets (the two-attribute vertex
  model's label attribute) — O(words) superset tests replace the paper's
  sorted-set containment.
- inverse vertex-label index ``vl_indptr``/``vl_vertices`` (sorted ids) for
  ``freq(g, L(u))`` and start-candidate enumeration.
- predicate index: per edge label, sorted unique subjects and objects — used
  by ChooseStartQueryVertex when a query vertex has neither label nor ID.
- optional NLF bitmaps over neighbor types t = el * n_vlabels + vl (the
  homomorphism-weakened NLF filter: "at least one neighbor of each required
  neighbor type").
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np


def pack_bitmap(sets: Sequence[Sequence[int]], n_bits: int) -> np.ndarray:
    """Pack per-row integer sets into a uint32 bitmap [n_rows, ceil(n_bits/32)]."""
    n_words = max(1, (n_bits + 31) // 32)
    out = np.zeros((len(sets), n_words), dtype=np.uint32)
    for i, items in enumerate(sets):
        for b in items:
            out[i, b >> 5] |= np.uint32(1 << (b & 31))
    return out


def _csr_from_sorted(keys: np.ndarray, n_keys: int) -> np.ndarray:
    """indptr[n_keys+1] for an ascending-sorted key column."""
    counts = np.bincount(keys, minlength=n_keys)
    indptr = np.zeros(n_keys + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr


@dataclass
class _Direction:
    """One direction (outgoing or incoming) of the adjacency structures."""

    indptr_el: np.ndarray  # int64 [n_elabels, n_vertices+1] (host only)
    nbr_el: np.ndarray  # int32 [n_edges]  sorted by (el, v, nbr)
    indptr_all: np.ndarray  # int64 [n_vertices+1]
    nbr_all: np.ndarray  # int32 [n_edges]  sorted by (v, nbr, el)
    lab_all: np.ndarray  # int32 [n_edges]  edge label aligned with nbr_all
    degree: np.ndarray  # int32 [n_vertices]

    def slice_el(self, el: int, v: int) -> np.ndarray:
        s, e = self.indptr_el[el, v], self.indptr_el[el, v + 1]
        return self.nbr_el[s:e]

    def slice_all(self, v: int) -> tuple[np.ndarray, np.ndarray]:
        s, e = self.indptr_all[v], self.indptr_all[v + 1]
        return self.nbr_all[s:e], self.lab_all[s:e]


def _build_direction(
    src: np.ndarray, el: np.ndarray, dst: np.ndarray, n_vertices: int, n_elabels: int
) -> _Direction:
    m = src.shape[0]
    # (el, src, dst) sort for the per-label CSR.
    order = np.lexsort((dst, src, el))
    s1, e1, d1 = src[order], el[order], dst[order]
    # indptr_el[el, v]: start of run (el, v).  Composite key = el * n + v.
    comp = e1.astype(np.int64) * n_vertices + s1.astype(np.int64)
    counts = np.bincount(comp, minlength=n_elabels * n_vertices)
    indptr_el = np.zeros(n_elabels * n_vertices + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr_el[1:])
    # reshape to [n_elabels, n_vertices+1]: row el must cover [el*n .. el*n + n]
    full = np.empty((n_elabels, n_vertices + 1), dtype=np.int64)
    for lbl in range(n_elabels):
        full[lbl, :] = indptr_el[lbl * n_vertices : lbl * n_vertices + n_vertices + 1]
    # (src, dst, el) sort for the plain CSR.
    order2 = np.lexsort((el, dst, src))
    s2, e2, d2 = src[order2], el[order2], dst[order2]
    indptr_all = _csr_from_sorted(s2, n_vertices)
    degree = np.diff(indptr_all).astype(np.int32)
    return _Direction(
        indptr_el=full,
        nbr_el=d1.astype(np.int32),
        indptr_all=indptr_all,
        nbr_all=d2.astype(np.int32),
        lab_all=e2.astype(np.int32),
        degree=degree,
    )


@dataclass
class LabeledGraph:
    n_vertices: int
    n_elabels: int
    n_vlabels: int
    out: _Direction
    inc: _Direction
    label_bitmap: np.ndarray  # uint32 [n_vertices, n_label_words]
    vl_indptr: np.ndarray  # int64 [n_vlabels+1]
    vl_vertices: np.ndarray  # int32 [sum |V_l|], sorted per label
    vlabel_sets: list[tuple[int, ...]] = field(repr=False, default_factory=list)
    # FILTER support: numeric value per vertex (NaN if not a numeric literal).
    numeric_value: np.ndarray | None = None
    # Lazily built structures.
    _pred_index: dict[int, tuple[np.ndarray, np.ndarray]] = field(default_factory=dict)
    _nlf_out: np.ndarray | None = None
    _nlf_in: np.ndarray | None = None

    # ------------------------------------------------------------------ build
    @staticmethod
    def build(
        n_vertices: int,
        src: np.ndarray,
        el: np.ndarray,
        dst: np.ndarray,
        n_elabels: int,
        vlabel_sets: Sequence[Sequence[int]],
        n_vlabels: int,
        numeric_value: np.ndarray | None = None,
    ) -> "LabeledGraph":
        src = np.asarray(src, dtype=np.int64)
        el = np.asarray(el, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        # RDF set semantics: duplicate (s, p, o) edges would duplicate
        # expansion rows in the executor and corrupt solution counts
        sed = np.unique(np.stack([src, el, dst], axis=1), axis=0)
        src, el, dst = sed[:, 0], sed[:, 1], sed[:, 2]
        assert len(vlabel_sets) == n_vertices
        out = _build_direction(src, el, dst, n_vertices, n_elabels)
        inc = _build_direction(dst, el, src, n_vertices, n_elabels)
        label_bitmap = pack_bitmap(vlabel_sets, max(1, n_vlabels))
        # inverse vertex-label index
        pairs_l: list[np.ndarray] = []
        pairs_v: list[np.ndarray] = []
        for v, labels in enumerate(vlabel_sets):
            if labels:
                arr = np.fromiter(labels, dtype=np.int64)
                pairs_l.append(arr)
                pairs_v.append(np.full(arr.shape, v, dtype=np.int64))
        if pairs_l:
            ls = np.concatenate(pairs_l)
            vs = np.concatenate(pairs_v)
            order = np.lexsort((vs, ls))
            ls, vs = ls[order], vs[order]
        else:
            ls = np.zeros(0, dtype=np.int64)
            vs = np.zeros(0, dtype=np.int64)
        vl_indptr = _csr_from_sorted(ls, max(1, n_vlabels)) if ls.size else np.zeros(
            max(1, n_vlabels) + 1, dtype=np.int64
        )
        return LabeledGraph(
            n_vertices=n_vertices,
            n_elabels=n_elabels,
            n_vlabels=n_vlabels,
            out=out,
            inc=inc,
            label_bitmap=label_bitmap,
            vl_indptr=vl_indptr,
            vl_vertices=vs.astype(np.int32),
            vlabel_sets=[tuple(sorted(s)) for s in vlabel_sets],
            numeric_value=numeric_value,
        )

    # ------------------------------------------------------------- properties
    @property
    def n_edges(self) -> int:
        return int(self.out.nbr_el.shape[0])

    @property
    def n_label_words(self) -> int:
        return int(self.label_bitmap.shape[1])

    def vertices_with_label(self, lbl: int) -> np.ndarray:
        """Sorted vertex ids carrying vertex label ``lbl`` (inverse label list)."""
        return self.vl_vertices[self.vl_indptr[lbl] : self.vl_indptr[lbl + 1]]

    def freq(self, labels: Sequence[int]) -> int:
        """``freq(g, L(u))`` — |∩_l V(g)_l| (paper, ChooseStartQueryVertex)."""
        if not labels:
            return self.n_vertices
        cur = self.vertices_with_label(labels[0])
        for lbl in labels[1:]:
            cur = np.intersect1d(cur, self.vertices_with_label(lbl), assume_unique=True)
        return int(cur.shape[0])

    def candidates_with_labels(self, labels: Sequence[int]) -> np.ndarray:
        if not labels:
            return np.arange(self.n_vertices, dtype=np.int32)
        cur = self.vertices_with_label(labels[0])
        for lbl in labels[1:]:
            cur = np.intersect1d(cur, self.vertices_with_label(lbl), assume_unique=True)
        return cur.astype(np.int32)

    # -------------------------------------------------------- predicate index
    def predicate_index(self, el: int) -> tuple[np.ndarray, np.ndarray]:
        """(sorted unique subjects, sorted unique objects) for edge label el."""
        cached = self._pred_index.get(el)
        if cached is None:
            subs = np.flatnonzero(np.diff(self.out.indptr_el[el]) > 0).astype(np.int32)
            objs = np.flatnonzero(np.diff(self.inc.indptr_el[el]) > 0).astype(np.int32)
            cached = (subs, objs)
            self._pred_index[el] = cached
        return cached

    # -------------------------------------------------------------- NLF build
    def nlf_bitmaps(self) -> tuple[np.ndarray, np.ndarray]:
        """Per-vertex neighbor-type bitmaps (out, in); type t = el*n_vlabels + vl.

        A vertex with an unlabeled neighbor via edge label el sets only the
        el-presence summary bit (t = el*n_vlabels + 0 would collide with a real
        label) — instead we reserve one extra pseudo-label slot per edge label:
        type space is el * (n_vlabels + 1) + (1 + vl), with slot el*(n+1)
        meaning "any neighbor via el".
        """
        if self._nlf_out is not None:
            return self._nlf_out, self._nlf_in
        stride = self.n_vlabels + 1
        n_types = self.n_elabels * stride
        self._nlf_out = self._nlf_direction(self.out, n_types, stride)
        self._nlf_in = self._nlf_direction(self.inc, n_types, stride)
        return self._nlf_out, self._nlf_in

    def _nlf_direction(self, d: _Direction, n_types: int, stride: int) -> np.ndarray:
        n_words = (n_types + 31) // 32
        bm = np.zeros((self.n_vertices, n_words), dtype=np.uint32)
        # iterate edges in plain CSR order: vertex v, neighbor w, label el
        v_of_edge = np.repeat(
            np.arange(self.n_vertices, dtype=np.int64), np.diff(d.indptr_all)
        )
        w = d.nbr_all.astype(np.int64)
        el = d.lab_all.astype(np.int64)
        # "any neighbor via el" pseudo-type
        t_any = el * stride
        np.bitwise_or.at(
            bm, (v_of_edge, t_any >> 5), (np.uint32(1) << (t_any & 31).astype(np.uint32))
        )
        # typed neighbor types for every label the neighbor carries
        for li in range(self.n_vlabels):
            has = (self.label_bitmap[w, li >> 5] >> np.uint32(li & 31)) & np.uint32(1)
            sel = has.astype(bool)
            if not sel.any():
                continue
            t = el[sel] * stride + (1 + li)
            np.bitwise_or.at(
                bm, (v_of_edge[sel], t >> 5), (np.uint32(1) << (t & 31).astype(np.uint32))
            )
        return bm

    # ------------------------------------------------------------------ stats
    def stats(self) -> dict:
        return {
            "n_vertices": self.n_vertices,
            "n_edges": self.n_edges,
            "n_elabels": self.n_elabels,
            "n_vlabels": self.n_vlabels,
            "avg_out_degree": float(self.out.degree.mean()) if self.n_vertices else 0.0,
            "max_out_degree": int(self.out.degree.max()) if self.n_vertices else 0,
        }
