"""Encoded triple store: the pre-transformation representation of an RDF dataset.

Triples arrive as python string 3-tuples (from the N-Triples parser or a
generator) and are dictionary-encoded into three parallel int32 arrays.
Duplicate triples are dropped (RDF set semantics) at ``finalize`` time.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Iterator

import numpy as np

from repro.rdf.dictionary import Dictionary


@dataclass
class TripleStore:
    dict: Dictionary = field(default_factory=Dictionary)
    _s: list[int] = field(default_factory=list)
    _p: list[int] = field(default_factory=list)
    _o: list[int] = field(default_factory=list)
    _finalized: bool = False
    s: np.ndarray | None = None
    p: np.ndarray | None = None
    o: np.ndarray | None = None

    def add(self, subj: str, pred: str, obj: str) -> None:
        assert not self._finalized, "store already finalized"
        self._s.append(self.dict.encode_term(subj))
        self._p.append(self.dict.encode_predicate(pred))
        self._o.append(self.dict.encode_term(obj))

    def add_many(self, triples: Iterable[tuple[str, str, str]]) -> None:
        for s, p, o in triples:
            self.add(s, p, o)

    def finalize(self) -> "TripleStore":
        """Deduplicate and freeze into numpy arrays."""
        if self._finalized:
            return self
        s = np.asarray(self._s, dtype=np.int64)
        p = np.asarray(self._p, dtype=np.int64)
        o = np.asarray(self._o, dtype=np.int64)
        # Dedup via a single composite key (ids are < 2**21 at our scales, but
        # use a safe composite on (s,p,o) rows instead of bit packing).
        spo = np.stack([s, p, o], axis=1)
        spo = np.unique(spo, axis=0)
        self.s = spo[:, 0].astype(np.int32)
        self.p = spo[:, 1].astype(np.int32)
        self.o = spo[:, 2].astype(np.int32)
        self._s, self._p, self._o = [], [], []
        self._finalized = True
        return self

    @property
    def n_triples(self) -> int:
        if self._finalized:
            return int(self.s.shape[0])
        return len(self._s)

    def iter_decoded(self) -> Iterator[tuple[str, str, str]]:
        assert self._finalized
        d = self.dict
        for i in range(self.n_triples):
            yield d.term(int(self.s[i])), d.predicate(int(self.p[i])), d.term(int(self.o[i]))
