from repro.rdf.dictionary import Dictionary, RDF_TYPE, RDFS_SUBCLASSOF
from repro.rdf.graph import LabeledGraph
from repro.rdf.transform import direct_transform, type_aware_transform
from repro.rdf.triples import TripleStore

__all__ = [
    "Dictionary",
    "LabeledGraph",
    "TripleStore",
    "direct_transform",
    "type_aware_transform",
    "RDF_TYPE",
    "RDFS_SUBCLASSOF",
]
