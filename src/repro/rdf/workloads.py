"""Benchmark query workloads, mirroring the paper's suites.

LUBM Q1–Q14 (paper Tables 2/3): adapted to the generator's ontology, keeping
each query's *shape class* — constant-solution queries (Q1, Q3–Q5, Q7, Q8,
Q10–Q12: a bound entity anchors one candidate region), increasing-solution
queries (Q2, Q6, Q9, Q13, Q14), triangles (Q2, Q9), and point-shaped queries
after type-aware transformation (Q6, Q14).

BSBM-like B1–B12 (paper Table 6): FILTER / OPTIONAL / UNION explore-use-case
analogues.  HETERO H1–H6 (paper Tables 4/5 stand-ins for YAGO/BTC).
"""

from __future__ import annotations

LUBM_QUERIES: dict[str, str] = {
    # Q1: constant — grad students taking a specific graduate course
    "Q1": """
        SELECT ?x WHERE {
          ?x rdf:type ub:GraduateStudent .
          ?x ub:takesCourse ub:GraduateCourse0.Dept0.Univ0 .
        }""",
    # Q2: triangle — grad student, university, department
    "Q2": """
        SELECT ?x ?y ?z WHERE {
          ?x rdf:type ub:GraduateStudent .
          ?y rdf:type ub:University .
          ?z rdf:type ub:Department .
          ?x ub:memberOf ?z .
          ?z ub:subOrganizationOf ?y .
          ?x ub:undergraduateDegreeFrom ?y .
        }""",
    # Q3: constant — publications of a specific assistant professor
    "Q3": """
        SELECT ?x WHERE {
          ?x rdf:type ub:Publication .
          ?x ub:publicationAuthor ub:AssistantProfessor0.Dept0.Univ0 .
        }""",
    # Q4: constant star — professors of a department with contact info
    "Q4": """
        SELECT ?x ?y1 ?y2 ?y3 WHERE {
          ?x rdf:type ub:Professor .
          ?x ub:worksFor ub:Dept0.Univ0 .
          ?x ub:name ?y1 .
          ?x ub:emailAddress ?y2 .
          ?x ub:telephone ?y3 .
        }""",
    # Q5: constant — members of a department (subsumption: Person)
    "Q5": """
        SELECT ?x WHERE {
          ?x rdf:type ub:Person .
          ?x ub:memberOf ub:Dept0.Univ0 .
        }""",
    # Q6: point-shaped — all students
    "Q6": """
        SELECT ?x WHERE { ?x rdf:type ub:Student . }""",
    # Q7: constant — students taking courses of a specific professor
    "Q7": """
        SELECT ?x ?y WHERE {
          ?x rdf:type ub:Student .
          ?y rdf:type ub:Course .
          ?x ub:takesCourse ?y .
          ub:AssociateProfessor0.Dept0.Univ0 ub:teacherOf ?y .
        }""",
    # Q8: constant 2-hop — students of departments of a university
    "Q8": """
        SELECT ?x ?y ?z WHERE {
          ?x rdf:type ub:Student .
          ?y rdf:type ub:Department .
          ?x ub:memberOf ?y .
          ?y ub:subOrganizationOf ub:Univ0 .
          ?x ub:emailAddress ?z .
        }""",
    # Q9: triangle — student, faculty advisor, course
    "Q9": """
        SELECT ?x ?y ?z WHERE {
          ?x rdf:type ub:Student .
          ?y rdf:type ub:Faculty .
          ?z rdf:type ub:Course .
          ?x ub:advisor ?y .
          ?y ub:teacherOf ?z .
          ?x ub:takesCourse ?z .
        }""",
    # Q10: constant — students taking a specific graduate course
    "Q10": """
        SELECT ?x WHERE {
          ?x rdf:type ub:Student .
          ?x ub:takesCourse ub:GraduateCourse0.Dept0.Univ0 .
        }""",
    # Q11: constant — research groups of a university (via department)
    "Q11": """
        SELECT ?x ?y WHERE {
          ?x rdf:type ub:ResearchGroup .
          ?x ub:subOrganizationOf ?y .
          ?y ub:subOrganizationOf ub:Univ0 .
        }""",
    # Q12: constant — chairs working for departments of a university
    "Q12": """
        SELECT ?x ?y WHERE {
          ?x rdf:type ub:Chair .
          ?y rdf:type ub:Department .
          ?x ub:worksFor ?y .
          ?y ub:subOrganizationOf ub:Univ0 .
        }""",
    # Q13: alumni of a specific university
    "Q13": """
        SELECT ?x WHERE {
          ?x rdf:type ub:Person .
          ?x ub:undergraduateDegreeFrom ub:Univ0 .
        }""",
    # Q14: point-shaped — all undergraduate students
    "Q14": """
        SELECT ?x WHERE { ?x rdf:type ub:UndergraduateStudent . }""",
}

# queries that keep a constant number of solutions as scale grows
LUBM_CONSTANT = ("Q1", "Q3", "Q4", "Q5", "Q7", "Q8", "Q10", "Q11", "Q12")
LUBM_INCREASING = ("Q2", "Q6", "Q9", "Q13", "Q14")


BSBM_QUERIES: dict[str, str] = {
    # B1: feature + numeric range FILTER
    "B1": """
        SELECT ?p WHERE {
          ?p rdf:type b:Product .
          ?p b:productFeature b:Feature1 .
          ?p b:propertyNumeric1 ?v .
          FILTER (?v > 1200)
        }""",
    # B2: product details star
    "B2": """
        SELECT ?p ?label ?producer WHERE {
          ?p rdf:type b:Product .
          ?p b:label ?label .
          ?p b:producer ?producer .
          ?p b:productFeature b:Feature3 .
        }""",
    # B3: two-range FILTER
    "B3": """
        SELECT ?p WHERE {
          ?p rdf:type b:Product .
          ?p b:propertyNumeric1 ?v1 .
          ?p b:propertyNumeric2 ?v2 .
          FILTER (?v1 > 600)
          FILTER (?v2 < 900)
        }""",
    # B4: UNION of two features
    "B4": """
        SELECT ?p WHERE {
          { ?p rdf:type b:Product . ?p b:productFeature b:Feature5 . }
          UNION
          { ?p rdf:type b:Product . ?p b:productFeature b:Feature6 . }
        }""",
    # B5: join FILTER (var-var comparison)
    "B5": """
        SELECT ?p ?v1 ?v2 WHERE {
          ?p rdf:type b:Product .
          ?p b:propertyNumeric1 ?v1 .
          ?p b:propertyNumeric2 ?v2 .
          FILTER (?v1 < ?v2)
        }""",
    # B6: regex FILTER on label
    "B6": """
        SELECT ?p ?label WHERE {
          ?p rdf:type b:Product .
          ?p b:label ?label .
          FILTER regex(?label, "product 1[0-3]")
        }""",
    # B7: review/offer star with vendor country
    "B7": """
        SELECT ?p ?offer ?vendor WHERE {
          ?p rdf:type b:Product .
          ?offer b:product ?p .
          ?offer b:vendor ?vendor .
          ?vendor b:country "US" .
        }""",
    # B8: reviews with optional second rating
    "B8": """
        SELECT ?r ?rating1 ?rating2 WHERE {
          ?r rdf:type b:Review .
          ?r b:reviewFor b:Product7 .
          ?r b:rating1 ?rating1 .
          OPTIONAL { ?r b:rating2 ?rating2 . }
        }""",
    # B9: optional homepage (mostly missing)
    "B9": """
        SELECT ?r ?home WHERE {
          ?r rdf:type b:Review .
          ?r b:reviewFor b:Product3 .
          OPTIONAL { ?r b:reviewerHomepage ?home . }
        }""",
    # B10: offers of a product below a price
    "B10": """
        SELECT ?offer ?price WHERE {
          ?offer rdf:type b:Offer .
          ?offer b:product b:Product5 .
          ?offer b:price ?price .
          FILTER (?price < 250.0)
        }""",
    # B11: predicate variable probe of one offer
    "B11": """
        SELECT ?prop ?val WHERE {
          ?o rdf:type b:Offer .
          ?o b:product b:Product11 .
          ?o ?prop ?val .
        }""",
    # B12: union + optional + filter combined
    "B12": """
        SELECT ?p ?v ?home WHERE {
          { ?p rdf:type b:Product . ?p b:productFeature b:Feature2 . }
          UNION
          { ?p rdf:type b:Product . ?p b:productFeature b:Feature4 . }
          ?p b:propertyNumeric1 ?v .
          FILTER (?v >= 100)
          OPTIONAL { ?r b:reviewFor ?p . ?r b:reviewerHomepage ?home . }
        }""",
}


HETERO_QUERIES: dict[str, str] = {
    # H1: typed 1-hop
    "H1": """
        SELECT ?x ?y WHERE {
          ?x rdf:type y:Type1 .
          ?x y:pred0 ?y .
        }""",
    # H2: typed 2-hop path
    "H2": """
        SELECT ?x ?y ?z WHERE {
          ?x rdf:type y:Type2 .
          ?x y:pred1 ?y .
          ?y y:pred2 ?z .
        }""",
    # H3: triangle
    "H3": """
        SELECT ?x ?y ?z WHERE {
          ?x y:pred0 ?y .
          ?y y:pred1 ?z .
          ?x y:pred2 ?z .
        }""",
    # H4: star with two typed leaves
    "H4": """
        SELECT ?x ?a ?b WHERE {
          ?x y:pred3 ?a .
          ?x y:pred4 ?b .
          ?a rdf:type y:Type3 .
          ?b rdf:type y:Type0 .
        }""",
    # H5: predicate variable
    "H5": """
        SELECT ?x ?p ?y WHERE {
          ?x rdf:type y:Type4 .
          ?x ?p ?y .
          ?y rdf:type y:Type1 .
        }""",
    # H6: 3-hop chain
    "H6": """
        SELECT ?a ?b ?c ?d WHERE {
          ?a y:pred0 ?b .
          ?b y:pred0 ?c .
          ?c y:pred0 ?d .
          ?a rdf:type y:Type5 .
        }""",
}
