"""RDF → labeled-graph transformations (paper §3.2 and §4.1).

``direct_transform``
    Subjects/objects → vertices; predicates → edge labels; every triple —
    including ``rdf:type`` / ``rdf:subClassOf`` — becomes an edge.  Vertices
    carry no label sets (the paper's L(v) = {v} identity labeling is realized
    by the executor's *ID-attribute* check instead, which is equivalent and
    avoids a label space the size of the vertex set).

``type_aware_transform``
    Definition 3: split T into T' / T'_t (rdf:type) / T'_sc (rdf:subClassOf);
    only T' becomes edges; objects of T'_t ∪ T'_sc become *vertex labels*;
    L(v) = type closure of v through rdf:type then transitive rdf:subClassOf.
    Class-only vertices (objects of type/subClassOf triples that never occur
    in T') are dropped from the vertex set — that is the size reduction in
    the paper's Table 1.

Both return (LabeledGraph, TransformMaps) where TransformMaps carries the
term↔vertex / predicate↔edge-label / class↔vertex-label id mappings needed to
transform SPARQL queries consistently (F_ID = F'_ID etc. in the paper).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.rdf.dictionary import RDF_TYPE, RDFS_SUBCLASSOF, Dictionary
from repro.rdf.graph import LabeledGraph
from repro.rdf.ontology import ClassHierarchy
from repro.rdf.triples import TripleStore
from repro.utils import get_logger

log = get_logger("rdf.transform")


@dataclass
class TransformMaps:
    """Id mappings shared between data-graph and query-graph transformation."""

    dict: Dictionary
    term_to_vertex: dict[int, int]
    vertex_to_term: np.ndarray  # int64 [n_vertices]
    pred_to_elabel: dict[int, int]
    elabel_to_pred: np.ndarray
    class_term_to_vlabel: dict[int, int] = field(default_factory=dict)
    hierarchy: ClassHierarchy | None = None
    kind: str = "type_aware"  # or "direct"

    # convenience: string-level lookups (queries arrive as strings)
    def vertex_of(self, term: str) -> int | None:
        tid = self.dict.term_id(term)
        if tid is None:
            return None
        return self.term_to_vertex.get(tid)

    def elabel_of(self, pred: str) -> int | None:
        pid = self.dict.predicate_id(pred)
        if pid is None:
            return None
        return self.pred_to_elabel.get(pid)

    def vlabel_of(self, cls: str) -> int | None:
        tid = self.dict.term_id(cls)
        if tid is None:
            return None
        return self.class_term_to_vlabel.get(tid)


def _numeric_values(dic: Dictionary, vertex_to_term: np.ndarray) -> np.ndarray:
    """Parse numeric literals ("42", "3.5"^^xsd:double …) into a value column."""
    vals = np.full(vertex_to_term.shape[0], np.nan, dtype=np.float64)
    for v, tid in enumerate(vertex_to_term):
        term = dic.term(int(tid))
        if term.startswith('"'):
            end = term.find('"', 1)
            lex = term[1:end] if end > 0 else term.strip('"')
            try:
                vals[v] = float(lex)
            except ValueError:
                pass
    return vals


def materialize_inferred_types(store: TripleStore) -> TripleStore:
    """Add the inferred ``rdf:type`` triples (transitive subClassOf closure).

    The paper loads LUBM as *original + inferred* triples ("the standard way
    to perform the LUBM benchmark") so subsumption queries (e.g. Q5/Q6's
    ``?x rdf:type ub:Student``) work on engines without reasoning — exactly
    what the direct transformation needs.  The type-aware transformation
    performs this closure natively (Definition 3.7), so it does NOT need the
    materialized triples.  Returns a new finalized store.
    """
    store.finalize()
    d = store.dict
    pid_type = d.predicate_id(RDF_TYPE)
    pid_sc = d.predicate_id(RDFS_SUBCLASSOF)
    out = TripleStore()
    for s, p, o in store.iter_decoded():
        out.add(s, p, o)
    if pid_type is None or pid_sc is None:
        return out.finalize()
    # class hierarchy over class terms
    hierarchy: dict[str, set[str]] = {}
    is_sc = store.p == pid_sc
    for sterm, oterm in zip(store.s[is_sc], store.o[is_sc]):
        hierarchy.setdefault(d.term(int(sterm)), set()).add(d.term(int(oterm)))

    def supers(cls: str) -> set[str]:
        seen: set[str] = set()
        stack = [cls]
        while stack:
            for sup in hierarchy.get(stack.pop(), ()):
                if sup not in seen:
                    seen.add(sup)
                    stack.append(sup)
        return seen

    is_type = store.p == pid_type
    for sterm, oterm in zip(store.s[is_type], store.o[is_type]):
        subj = d.term(int(sterm))
        for sup in supers(d.term(int(oterm))):
            out.add(subj, RDF_TYPE, sup)
    return out.finalize()


def direct_transform(store: TripleStore) -> tuple[LabeledGraph, TransformMaps]:
    store.finalize()
    d = store.dict
    terms = np.unique(np.concatenate([store.s, store.o]))
    term_to_vertex = {int(t): i for i, t in enumerate(terms)}
    remap = np.full(d.n_terms, -1, dtype=np.int64)
    remap[terms] = np.arange(terms.shape[0])
    src = remap[store.s]
    dst = remap[store.o]
    el = store.p.astype(np.int64)  # predicate ids ARE edge labels (bijective)
    n_el = d.n_predicates
    maps = TransformMaps(
        dict=d,
        term_to_vertex=term_to_vertex,
        vertex_to_term=terms.astype(np.int64),
        pred_to_elabel={i: i for i in range(n_el)},
        elabel_to_pred=np.arange(n_el, dtype=np.int64),
        kind="direct",
    )
    g = LabeledGraph.build(
        n_vertices=terms.shape[0],
        src=src,
        el=el,
        dst=dst,
        n_elabels=n_el,
        vlabel_sets=[()] * terms.shape[0],
        n_vlabels=0,
        numeric_value=_numeric_values(d, maps.vertex_to_term),
    )
    log.info("direct transform: %d vertices, %d edges", g.n_vertices, g.n_edges)
    return g, maps


def type_aware_transform(store: TripleStore) -> tuple[LabeledGraph, TransformMaps]:
    store.finalize()
    d = store.dict
    pid_type = d.predicate_id(RDF_TYPE)
    pid_sc = d.predicate_id(RDFS_SUBCLASSOF)
    is_type = store.p == pid_type if pid_type is not None else np.zeros(store.n_triples, bool)
    is_sc = store.p == pid_sc if pid_sc is not None else np.zeros(store.n_triples, bool)
    plain = ~(is_type | is_sc)

    # --- vertex label space: objects of type/subClassOf triples (+ their subjects
    # for subClassOf, since classes are labels on both sides of the hierarchy).
    class_terms = np.unique(
        np.concatenate(
            [store.o[is_type], store.o[is_sc], store.s[is_sc]]
        )
    ) if (is_type.any() or is_sc.any()) else np.zeros(0, dtype=store.o.dtype)
    class_term_to_vlabel = {int(t): i for i, t in enumerate(class_terms)}
    n_vlabels = class_terms.shape[0]

    # --- class hierarchy from subClassOf triples
    hierarchy = ClassHierarchy()
    for sterm, oterm in zip(store.s[is_sc], store.o[is_sc]):
        hierarchy.add_subclass(class_term_to_vlabel[int(sterm)], class_term_to_vlabel[int(oterm)])

    # --- vertex set: subjects/objects of T' plus subjects of T'_t (Def. 3.1).
    vertex_terms = np.unique(
        np.concatenate([store.s[plain], store.o[plain], store.s[is_type]])
    )
    term_to_vertex = {int(t): i for i, t in enumerate(vertex_terms)}
    remap = np.full(d.n_terms, -1, dtype=np.int64)
    remap[vertex_terms] = np.arange(vertex_terms.shape[0])

    # --- per-vertex label sets: direct types expanded through the closure
    direct_types: list[set[int]] = [set() for _ in range(vertex_terms.shape[0])]
    for sterm, oterm in zip(store.s[is_type], store.o[is_type]):
        v = remap[int(sterm)]
        if v >= 0:
            direct_types[v].add(class_term_to_vlabel[int(oterm)])
    vlabel_sets = [tuple(hierarchy.expand_types(ts)) if ts else () for ts in direct_types]

    # --- edge label space: predicates of T' only (F_EL domain is P')
    plain_preds = np.unique(store.p[plain])
    pred_to_elabel = {int(p): i for i, p in enumerate(plain_preds)}
    el_remap = np.full(d.n_predicates, -1, dtype=np.int64)
    el_remap[plain_preds] = np.arange(plain_preds.shape[0])

    src = remap[store.s[plain]]
    dst = remap[store.o[plain]]
    el = el_remap[store.p[plain]]
    maps = TransformMaps(
        dict=d,
        term_to_vertex=term_to_vertex,
        vertex_to_term=vertex_terms.astype(np.int64),
        pred_to_elabel=pred_to_elabel,
        elabel_to_pred=plain_preds.astype(np.int64),
        class_term_to_vlabel=class_term_to_vlabel,
        hierarchy=hierarchy,
        kind="type_aware",
    )
    g = LabeledGraph.build(
        n_vertices=vertex_terms.shape[0],
        src=src,
        el=el,
        dst=dst,
        n_elabels=plain_preds.shape[0],
        vlabel_sets=vlabel_sets,
        n_vlabels=n_vlabels,
        numeric_value=_numeric_values(d, maps.vertex_to_term),
    )
    log.info(
        "type-aware transform: %d vertices, %d edges, %d vertex labels",
        g.n_vertices,
        g.n_edges,
        n_vlabels,
    )
    return g, maps
