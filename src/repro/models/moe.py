"""Mixture-of-Experts block: top-k routing with capacity-bounded dispatch.

Dispatch is gather/scatter based (sort by expert, per-expert capacity
C = ceil(T·k/E · capacity_factor)), so compiled FLOPs reflect the *active*
expert compute (6·N_active·D roofline accounting), not an all-experts dense
einsum.  Tokens overflowing an expert's capacity are dropped (standard
Switch/GShard semantics); the auxiliary load-balance loss keeps the router
near-uniform so drops are rare.

Expert layout: stacked weights [E, d, ff] / [E, ff, d] sharded over the
`model` axis (expert parallelism) by the sharding rules.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    first_dense_layers: int = 0


def moe_init(key, d_model: int, mcfg: MoEConfig):
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)
    e, ff = mcfg.n_experts, mcfg.d_ff_expert
    params = {
        "router": dense_init(k1, d_model, e, scale=0.02),
        "w_gate": jax.random.normal(k2, (e, d_model, ff), jnp.float32)
        * (1.0 / math.sqrt(d_model)),
        "w_up": jax.random.normal(k3, (e, d_model, ff), jnp.float32)
        * (1.0 / math.sqrt(d_model)),
        "w_down": jax.random.normal(k4, (e, ff, d_model), jnp.float32)
        * (1.0 / math.sqrt(ff)),
    }
    if mcfg.n_shared:
        sff = mcfg.n_shared * ff
        params["shared"] = {
            "w_gate": dense_init(k5, d_model, sff),
            "w_up": dense_init(k6, d_model, sff),
            "w_down": dense_init(k7, sff, d_model),
        }
    return params


def moe_apply(params, x: jax.Array, mcfg: MoEConfig):
    """x [T, d] -> (y [T, d], aux_loss scalar)."""
    t, d = x.shape
    e, k = mcfg.n_experts, mcfg.top_k
    cap = max(1, int(math.ceil(t * k / e * mcfg.capacity_factor)))

    logits = (x @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # [T, E]
    top_p, top_i = jax.lax.top_k(probs, k)  # [T, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # --- aux load-balance loss (Switch): E * Σ_e f_e · p_e
    me = jnp.mean(probs, axis=0)
    one_hot_top = jax.nn.one_hot(top_i, e, dtype=jnp.float32).sum(1)  # [T, E]
    fe = jnp.mean(one_hot_top, axis=0)
    aux = mcfg.router_aux_weight * e * jnp.sum(fe * me)

    # --- dispatch: sort assignments by expert, bound by capacity
    flat_e = top_i.reshape(-1)  # [T*k]
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_w = top_p.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    estart = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype))
    rank = jnp.arange(t * k, dtype=jnp.int32) - estart[se].astype(jnp.int32)
    keep = rank < cap
    rank_c = jnp.clip(rank, 0, cap - 1)

    buf = jnp.zeros((e, cap, d), dtype=x.dtype)
    buf = buf.at[se, rank_c].set(jnp.where(keep[:, None], x[st], 0.0))

    h = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(x.dtype))
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf,
                                    params["w_up"].astype(x.dtype))
    h = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(x.dtype))

    # --- combine
    gathered = h[se, rank_c] * sw[:, None].astype(x.dtype)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    y = jnp.zeros((t, d), dtype=x.dtype).at[st].add(gathered)

    if mcfg.n_shared:
        sp = params["shared"]
        sh = jax.nn.silu(x @ sp["w_gate"].astype(x.dtype)) * (
            x @ sp["w_up"].astype(x.dtype))
        y = y + sh @ sp["w_down"].astype(x.dtype)
    return y, aux
