from repro.models.recsys import dlrm

__all__ = ["dlrm"]
