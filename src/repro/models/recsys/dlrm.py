"""DLRM (arXiv:1906.00091) — RM-2 shape: 26 sparse + 13 dense features.

JAX has no native EmbeddingBag: lookups are ``jnp.take`` + masked sum over a
fixed-hotness index layout (the Pallas ``segment_gather`` kernel provides
the fused path), which IS the system's hot loop at serving time.  Dot-product
feature interaction (upper triangle) + bottom/top MLPs, BCE loss.

``retrieval_score`` implements the retrieval_cand shape: one user query
scored against N candidate item embeddings as a single batched GEMV —
not a loop.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.models.layers import mlp_apply, mlp_init


@dataclass(frozen=True)
class DLRMConfig:
    name: str
    n_dense: int
    n_sparse: int
    embed_dim: int
    bot_mlp: tuple[int, ...]
    top_mlp: tuple[int, ...]
    vocab_sizes: tuple[int, ...]  # one per sparse field
    hotness: int = 8  # multi-hot lookups per field (RM-2 style)
    compute_dtype: str = "float32"

    @property
    def dtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def n_interact(self) -> int:
        f = self.n_sparse + 1
        return f * (f - 1) // 2


def init_params(key, cfg: DLRMConfig):
    keys = jax.random.split(key, cfg.n_sparse + 2)
    tables = [
        jax.random.normal(keys[i], (v, cfg.embed_dim), jnp.float32)
        * (1.0 / jnp.sqrt(v).astype(jnp.float32))
        for i, v in enumerate(cfg.vocab_sizes)
    ]
    bot = mlp_init(keys[-2], [cfg.n_dense, *cfg.bot_mlp])
    d_top_in = cfg.n_interact + cfg.bot_mlp[-1]
    top = mlp_init(keys[-1], [d_top_in, *cfg.top_mlp])
    return {"tables": tables, "bot": bot, "top": top}


def embed_bags(tables, sparse_idx: jax.Array, dtype) -> jax.Array:
    """sparse_idx int32 [B, F, K] (−1 padded) -> [B, F, D] summed bags."""
    outs = []
    for f, table in enumerate(tables):
        idx = sparse_idx[:, f, :]  # [B, K]
        rows = jnp.take(table.astype(dtype), jnp.clip(idx, 0, table.shape[0] - 1),
                        axis=0)  # [B, K, D]
        mask = (idx >= 0).astype(dtype)[:, :, None]
        outs.append(jnp.sum(rows * mask, axis=1))
    return jnp.stack(outs, axis=1)  # [B, F, D]


def forward(params, batch, cfg: DLRMConfig):
    dense = batch["dense"].astype(cfg.dtype)  # [B, n_dense]
    sparse = batch["sparse"]  # int32 [B, F, K]
    b = dense.shape[0]
    z_bot = mlp_apply(params["bot"], dense, final_act=True)  # [B, D]
    emb = embed_bags(params["tables"], sparse, cfg.dtype)  # [B, F, D]
    feats = jnp.concatenate([z_bot[:, None, :], emb], axis=1)  # [B, F+1, D]
    inter = jnp.einsum("bfd,bgd->bfg", feats, feats)  # [B, F+1, F+1]
    f = feats.shape[1]
    iu, ju = jnp.triu_indices(f, k=1)
    z_int = inter[:, iu, ju]  # [B, n_interact]
    top_in = jnp.concatenate([z_bot, z_int], axis=-1)
    logit = mlp_apply(params["top"], top_in)  # [B, 1]
    return logit[:, 0]


def loss_fn(params, batch, cfg: DLRMConfig):
    logit = forward(params, batch, cfg).astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    # numerically stable BCE-with-logits
    loss = jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit)))
    return jnp.mean(loss)


def retrieval_score(params, batch, cfg: DLRMConfig):
    """Score 1 query against N candidates: [N] logits via one GEMV.

    batch: dense [1, n_dense], sparse [1, F, K], cand [N, D] (item tower
    embeddings).  Two-tower style: user vector = bottom-MLP output combined
    with the mean sparse embedding, scored by dot product.
    """
    dense = batch["dense"].astype(cfg.dtype)
    sparse = batch["sparse"]
    z_bot = mlp_apply(params["bot"], dense, final_act=True)  # [1, D]
    emb = embed_bags(params["tables"], sparse, cfg.dtype)  # [1, F, D]
    user = z_bot + jnp.mean(emb, axis=1)  # [1, D]
    cand = batch["cand"].astype(cfg.dtype)  # [N, D]
    return (cand @ user[0])  # [N]
