"""Neighbor sampler for sampled-training GNN shapes (minibatch_lg).

Real layered fanout sampling (GraphSAGE-style) over a CSR graph:
``sample_blocks`` draws, for each seed, up to fanout[0] neighbors, then for
each of those up to fanout[1], etc., emitting a padded subgraph in the
models' common batch layout (edge_src/edge_dst into a compact local id
space).  Deterministic given the numpy Generator.

Padding: missing neighbors repeat the source node with a self-edge, keeping
shapes static for jit while preserving aggregation semantics under mean/sum
with self-loops — the standard padded-sampler trick.
"""

from __future__ import annotations

import numpy as np


def sample_blocks(
    indptr: np.ndarray,
    nbr: np.ndarray,
    seeds: np.ndarray,
    fanouts: list[int],
    rng: np.random.Generator,
):
    """Returns dict(nodes, edge_src, edge_dst, seed_count) with LOCAL ids.

    nodes[0:len(seeds)] are the seeds; edges point child -> parent
    (aggregation flows toward the seeds).
    """
    nodes = list(map(int, seeds))
    local: dict[int, int] = {int(v): i for i, v in enumerate(seeds)}
    frontier = list(range(len(seeds)))
    src_l: list[int] = []
    dst_l: list[int] = []
    for fan in fanouts:
        next_frontier: list[int] = []
        for li in frontier:
            v = nodes[li]
            s, e = int(indptr[v]), int(indptr[v + 1])
            deg = e - s
            if deg == 0:
                chosen = np.full(fan, v)  # self-padding
            elif deg <= fan:
                chosen = np.concatenate(
                    [nbr[s:e], np.full(fan - deg, v)])
            else:
                chosen = nbr[s + rng.choice(deg, size=fan, replace=False)]
            for w in chosen:
                w = int(w)
                wi = local.get(w)
                if wi is None:
                    wi = len(nodes)
                    local[w] = wi
                    nodes.append(w)
                src_l.append(wi)
                dst_l.append(li)
                next_frontier.append(wi)
        frontier = next_frontier
    return {
        "nodes": np.asarray(nodes, dtype=np.int64),
        "edge_src": np.asarray(src_l, dtype=np.int32),
        "edge_dst": np.asarray(dst_l, dtype=np.int32),
        "seed_count": len(seeds),
    }


def pad_block(block: dict, n_nodes: int, n_edges: int) -> dict:
    """Pad a sampled block to static (n_nodes, n_edges) for jit."""
    nodes = block["nodes"]
    src, dst = block["edge_src"], block["edge_dst"]
    out_nodes = np.zeros(n_nodes, dtype=np.int64)
    out_nodes[: len(nodes)] = nodes[:n_nodes]
    out_src = np.zeros(n_edges, dtype=np.int32)
    out_dst = np.zeros(n_edges, dtype=np.int32)
    m = min(len(src), n_edges)
    out_src[:m] = src[:m]
    out_dst[:m] = dst[:m]
    # padded edges become self-loops on node 0 (harmless under masking)
    return {"nodes": out_nodes, "edge_src": out_src, "edge_dst": out_dst,
            "seed_count": block["seed_count"], "n_real_nodes": len(nodes),
            "n_real_edges": len(src)}
