"""DimeNet (arXiv:2003.03123) — directional message passing over triplets.

The kernel regime is *triplet gather* (not SpMM): messages live on edges and
are updated from all incoming edges k→j of each edge j→i, modulated by an
angular basis of the angle ∠(kj, ji) and a radial basis of the distances.

Faithful geometry: radial basis = spherical-Bessel-like sin(nπ d/c)/d
envelope (DimeNet's RBF); angular basis simplified to a Chebyshev cos(lθ)
family of the same rank (n_spherical × n_radial outer product) — the exact
spherical-harmonic normalization constants change coefficients, not compute
shape or sparsity (noted in DESIGN.md §Arch-applicability).  Bilinear
interaction W[n_bilinear] mirrors the paper's einsum.

Batch layout (precomputed by the data pipeline / input_specs):
  z [N] atom types, pos [N, 3], edge_src/dst [E], t_kj/t_ji [T] (edge ids),
  batch_seg [N] molecule id, targets [B].  Output: per-molecule energy (MSE).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.gnn.common import segment_sum, segment_sum_spmd
from repro.models.layers import dense_init, mlp_apply, mlp_init


@dataclass(frozen=True)
class DimeNetConfig:
    name: str
    n_blocks: int
    d_hidden: int
    n_bilinear: int
    n_spherical: int
    n_radial: int
    n_atom_types: int = 16
    cutoff: float = 5.0
    compute_dtype: str = "float32"
    # triplet arrays sharded across these axes (edge/node arrays replicated)
    spmd_axes: tuple = ()
    spmd_shards: int = 1
    # v2 (§Perf 4.2 iter 2): edge arrays sharded too — edge-message MLPs run
    # on the local shard and messages are exchanged with one all_gather per
    # block instead of every chip recomputing the full [E, H] update
    edge_sharded: bool = False

    @property
    def dtype(self):
        return jnp.dtype(self.compute_dtype)


def init_params(key, cfg: DimeNetConfig):
    h = cfg.d_hidden
    ks = jax.random.split(key, 8 + cfg.n_blocks * 2)
    params = {
        "embed_z": jax.random.normal(ks[0], (cfg.n_atom_types, h)) * 0.1,
        "rbf_w": dense_init(ks[1], cfg.n_radial, h),
        "edge_embed": mlp_init(ks[2], [3 * h, h]),
        "out_blocks": [],
        "blocks": [],
    }
    for b in range(cfg.n_blocks):
        kb, ko = ks[3 + 2 * b], ks[4 + 2 * b]
        k1, k2, k3, k4 = jax.random.split(kb, 4)
        params["blocks"].append({
            # source-message projection and bilinear angular interaction
            "w_src": dense_init(k1, h, h),
            "w_sbf": dense_init(k2, cfg.n_spherical * cfg.n_radial,
                                cfg.n_bilinear),
            "w_bil": jax.random.normal(
                k3, (cfg.n_bilinear, h, h), jnp.float32) * (1.0 / h ** 0.5),
            "update": mlp_init(k4, [h, h, h]),
        })
        params["out_blocks"].append(mlp_init(ko, [h, h, 1]))
    return params


def _rbf(d, cfg: DimeNetConfig):
    """Spherical-Bessel-flavored radial basis with smooth cutoff envelope."""
    n = jnp.arange(1, cfg.n_radial + 1, dtype=jnp.float32)
    dn = jnp.maximum(d[:, None], 1e-6)
    u = dn / cfg.cutoff
    env = jnp.where(u < 1.0, (1.0 - u) ** 2 * (1.0 + 2.0 * u), 0.0)
    return env * jnp.sin(n[None, :] * jnp.pi * u) / dn


def _sbf(angle, d_kj, cfg: DimeNetConfig):
    """Angular × radial basis on triplets: cos(lθ) ⊗ rbf(d_kj)."""
    l = jnp.arange(cfg.n_spherical, dtype=jnp.float32)
    ang = jnp.cos(l[None, :] * angle[:, None])  # [T, S]
    rad = _rbf(d_kj, cfg)  # [T, R]
    return (ang[:, :, None] * rad[:, None, :]).reshape(
        angle.shape[0], cfg.n_spherical * cfg.n_radial)


def forward_edge_sharded(params, batch, cfg: DimeNetConfig):
    """Explicit-SPMD v2: local edge shard + local triplets.

    Batch (per shard, inside shard_map): edge_src/edge_dst [E_l] the local
    edge range; t_kj [T_l] GLOBAL edge ids (sources may be remote);
    t_ji [T_l] LOCAL edge ids (triplets co-partitioned with their target
    edge — a data-pipeline guarantee); z/pos/batch_seg replicated.

    Per block: edge-message MLP on [E_l, H] (was [E, H] replicated in v1);
    one tiled all_gather rebuilds [E, H] for the t_kj gathers; node/graph
    reductions psum.  The all_gather is differentiable (transpose =
    reduce-scatter), so gradients stay exact.
    """
    axes = cfg.spmd_axes
    z, pos = batch["z"], batch["pos"].astype(cfg.dtype)
    src, dst = batch["edge_src"], batch["edge_dst"]
    t_kj, t_ji = batch["t_kj"], batch["t_ji"]
    n = pos.shape[0]
    e_l = src.shape[0]

    vec_l = pos[dst] - pos[src]  # [E_l, 3]
    d_l = jnp.sqrt(jnp.maximum(jnp.sum(vec_l * vec_l, -1), 1e-12))
    rbf_l = _rbf(d_l, cfg).astype(cfg.dtype)

    # one gather of edge geometry for the triplet angle computation
    vec_full = jax.lax.all_gather(vec_l, axes, tiled=True)  # [E, 3]
    d_full = jax.lax.all_gather(d_l, axes, tiled=True)
    v1 = -vec_full[t_kj]
    v2 = vec_l[t_ji]
    cosang = jnp.sum(v1 * v2, -1) / jnp.maximum(
        jnp.linalg.norm(v1, axis=-1) * jnp.linalg.norm(v2, axis=-1), 1e-9)
    angle = jnp.arccos(jnp.clip(cosang, -1.0, 1.0))
    sbf = _sbf(angle, d_full[t_kj], cfg).astype(cfg.dtype)  # [T_l, S*R]

    hz = params["embed_z"].astype(cfg.dtype)[z]
    rbf_h = rbf_l @ params["rbf_w"].astype(cfg.dtype)
    m = mlp_apply(params["edge_embed"],
                  jnp.concatenate([hz[src], hz[dst], rbf_h], -1))
    m = jax.nn.silu(m)  # [E_l, H]

    n_graphs = batch["targets"].shape[0]
    per_graph = jnp.zeros((n_graphs,), cfg.dtype)
    seg = batch.get("batch_seg", jnp.zeros((n,), jnp.int32))

    for blk, out in zip(params["blocks"], params["out_blocks"]):
        msrc_l = jax.nn.silu(m @ blk["w_src"].astype(cfg.dtype))  # [E_l, H]
        msrc_full = jax.lax.all_gather(msrc_l, axes, tiled=True)  # [E, H]
        a = sbf @ blk["w_sbf"].astype(cfg.dtype)  # [T_l, B]
        inter = jnp.einsum("tb,bhg,th->tg", a.astype(cfg.dtype),
                           blk["w_bil"].astype(cfg.dtype), msrc_full[t_kj])
        agg = segment_sum(inter, t_ji, e_l)  # purely local (co-partitioned)
        m = m + jax.nn.silu(mlp_apply(blk["update"], m + agg))
        node_e = jax.lax.psum(segment_sum(m, dst, n), axes)
        per_graph = per_graph + segment_sum(
            mlp_apply(out, node_e)[:, 0], seg, n_graphs)
    return per_graph


def forward(params, batch, cfg: DimeNetConfig):
    if cfg.spmd_axes and cfg.edge_sharded:
        return forward_edge_sharded(params, batch, cfg)
    z, pos = batch["z"], batch["pos"].astype(cfg.dtype)
    src, dst = batch["edge_src"], batch["edge_dst"]
    t_kj, t_ji = batch["t_kj"], batch["t_ji"]
    n = pos.shape[0]
    e = src.shape[0]
    vec = pos[dst] - pos[src]
    d = jnp.sqrt(jnp.maximum(jnp.sum(vec * vec, -1), 1e-12))
    rbf = _rbf(d, cfg).astype(cfg.dtype)  # [E, R]

    # angle at shared vertex j between edges (k->j) and (j->i)
    v1 = -vec[t_kj]
    v2 = vec[t_ji]
    cosang = jnp.sum(v1 * v2, -1) / jnp.maximum(
        jnp.linalg.norm(v1, axis=-1) * jnp.linalg.norm(v2, axis=-1), 1e-9)
    angle = jnp.arccos(jnp.clip(cosang, -1.0, 1.0))
    sbf = _sbf(angle, d[t_kj], cfg).astype(cfg.dtype)  # [T, S*R]

    hz = params["embed_z"].astype(cfg.dtype)[z]
    rbf_h = rbf @ params["rbf_w"].astype(cfg.dtype)
    m = mlp_apply(params["edge_embed"],
                  jnp.concatenate([hz[src], hz[dst], rbf_h], -1))
    m = jax.nn.silu(m)  # [E, H]

    n_graphs = batch["targets"].shape[0]  # static
    per_graph = jnp.zeros((n_graphs,), cfg.dtype)
    seg = batch.get("batch_seg", jnp.zeros((n,), jnp.int32))

    for blk, out in zip(params["blocks"], params["out_blocks"]):
        # directional message: for each triplet, source message m[t_kj]
        msrc = jax.nn.silu(m @ blk["w_src"].astype(cfg.dtype))[t_kj]  # [T, H]
        a = sbf @ blk["w_sbf"].astype(cfg.dtype)  # [T, B]
        inter = jnp.einsum("tb,bhg,th->tg", a.astype(cfg.dtype),
                           blk["w_bil"].astype(cfg.dtype), msrc)
        if cfg.spmd_axes:
            agg = segment_sum_spmd(inter, t_ji, e, cfg.spmd_axes,
                                   cfg.spmd_shards)
        else:
            agg = segment_sum(inter, t_ji, e)  # sum over incoming triplets
        m = m + jax.nn.silu(mlp_apply(blk["update"], m + agg))
        # output block: per-node then per-molecule energy contribution
        node_e = segment_sum(m, dst, n)
        per_graph = per_graph + segment_sum(
            mlp_apply(out, node_e)[:, 0], seg, n_graphs)
    return per_graph


def loss_fn(params, batch, cfg: DimeNetConfig):
    pred = forward(params, batch, cfg)
    tgt = batch["targets"].astype(pred.dtype)
    return jnp.mean((pred - tgt) ** 2)
