"""MeshGraphNet (arXiv:2010.03409) — encode-process-decode mesh simulator.

15 message-passing layers; per layer an edge MLP m_e = MLP([h_u, h_v, e])
updates edge features (residual) and a node MLP over [h_v, Σ_e m_e] updates
node features (residual); sum aggregation; 2-layer MLPs with LayerNorm.
Output: per-node dynamics regression (MSE).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.gnn.common import segment_sum, segment_sum_spmd
from repro.models.layers import layernorm, mlp_apply, mlp_init


@dataclass(frozen=True)
class MGNConfig:
    name: str
    n_layers: int
    d_hidden: int
    d_node_in: int
    d_edge_in: int
    d_out: int
    mlp_layers: int = 2
    compute_dtype: str = "float32"
    spmd_axes: tuple = ()
    spmd_shards: int = 1

    @property
    def dtype(self):
        return jnp.dtype(self.compute_dtype)


def _mlp_ln_init(key, dims):
    k1, _ = jax.random.split(key)
    return {"mlp": mlp_init(k1, dims),
            "ln_g": jnp.ones((dims[-1],), jnp.float32),
            "ln_b": jnp.zeros((dims[-1],), jnp.float32)}


def _mlp_ln(p, x):
    h = mlp_apply(p["mlp"], x, act=jax.nn.relu)
    return layernorm(h, p["ln_g"], p["ln_b"])


def init_params(key, cfg: MGNConfig):
    h = cfg.d_hidden
    hid = [h] * cfg.mlp_layers
    key, k1, k2, k3 = jax.random.split(key, 4)
    params = {
        "node_enc": _mlp_ln_init(k1, [cfg.d_node_in] + hid),
        "edge_enc": _mlp_ln_init(k2, [cfg.d_edge_in] + hid),
        "decoder": mlp_init(k3, hid + [cfg.d_out]),
        "blocks": [],
    }
    for _ in range(cfg.n_layers):
        key, ke, kn = jax.random.split(key, 3)
        params["blocks"].append({
            "edge": _mlp_ln_init(ke, [3 * h] + hid),
            "node": _mlp_ln_init(kn, [2 * h] + hid),
        })
    return params


def forward(params, batch, cfg: MGNConfig):
    x = batch["x"].astype(cfg.dtype)
    e = batch["edge_attr"].astype(cfg.dtype)
    src, dst = batch["edge_src"], batch["edge_dst"]
    n = x.shape[0]
    h = _mlp_ln(params["node_enc"], x)
    he = _mlp_ln(params["edge_enc"], e)
    for blk in params["blocks"]:
        m = _mlp_ln(blk["edge"], jnp.concatenate([h[src], h[dst], he], -1))
        he = he + m
        if cfg.spmd_axes:
            agg = segment_sum_spmd(he, dst, n, cfg.spmd_axes, cfg.spmd_shards)
        else:
            agg = segment_sum(he, dst, n)
        h = h + _mlp_ln(blk["node"], jnp.concatenate([h, agg], -1))
    return mlp_apply(params["decoder"], h)


def loss_fn(params, batch, cfg: MGNConfig):
    pred = forward(params, batch, cfg)
    tgt = batch["targets"].astype(pred.dtype)
    return jnp.mean((pred - tgt) ** 2)
