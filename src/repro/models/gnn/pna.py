"""PNA — Principal Neighbourhood Aggregation (arXiv:2004.05718).

4 parallel aggregators (mean/max/min/std) × 3 degree scalers (identity /
amplification / attenuation) → 12-fold concatenated message, post-MLP per
layer.  Config pna: 4 layers, hidden 75.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.gnn.common import (degrees, degrees_spmd,
                                     segment_max, segment_max_spmd,
                                     segment_mean, segment_mean_spmd,
                                     segment_min, segment_min_spmd,
                                     segment_std, segment_std_spmd)
from repro.models.layers import cross_entropy_loss, mlp_apply, mlp_init


@dataclass(frozen=True)
class PNAConfig:
    name: str
    n_layers: int
    d_hidden: int
    d_feat: int
    n_classes: int
    delta: float = 2.5  # mean log-degree normalizer (dataset statistic)
    compute_dtype: str = "float32"
    spmd_axes: tuple = ()
    spmd_shards: int = 1

    @property
    def dtype(self):
        return jnp.dtype(self.compute_dtype)


N_AGG = 4
N_SCALE = 3


def init_params(key, cfg: PNAConfig):
    layers = []
    d_in = cfg.d_feat
    for _ in range(cfg.n_layers):
        key, k1, k2 = jax.random.split(key, 3)
        layers.append({
            "pre": mlp_init(k1, [2 * d_in, cfg.d_hidden]),
            "post": mlp_init(k2, [d_in + N_AGG * N_SCALE * cfg.d_hidden,
                                  cfg.d_hidden, cfg.d_hidden]),
        })
        d_in = cfg.d_hidden
    key, kf = jax.random.split(key)
    return {"layers": layers, "head": mlp_init(kf, [cfg.d_hidden,
                                                    cfg.n_classes])}


def forward(params, batch, cfg: PNAConfig):
    x = batch["x"].astype(cfg.dtype)
    src, dst = batch["edge_src"], batch["edge_dst"]
    n = x.shape[0]
    if cfg.spmd_axes:
        deg = degrees_spmd(dst, n, cfg.spmd_axes, cfg.spmd_shards)
    else:
        deg = degrees(dst, n)
    logd = jnp.log(deg + 1.0)
    amp = (logd / cfg.delta)[:, None].astype(cfg.dtype)
    att = (cfg.delta / jnp.maximum(logd, 1e-2))[:, None].astype(cfg.dtype)
    for lp in params["layers"]:
        msg_in = jnp.concatenate([x[src], x[dst]], axis=-1)
        m = jax.nn.relu(mlp_apply(lp["pre"], msg_in))
        if cfg.spmd_axes:
            ax, ns = cfg.spmd_axes, cfg.spmd_shards
            aggs = [segment_mean_spmd(m, dst, n, ax, ns),
                    segment_max_spmd(m, dst, n, ax, ns),
                    segment_min_spmd(m, dst, n, ax, ns),
                    segment_std_spmd(m, dst, n, ax, ns)]
        else:
            aggs = [segment_mean(m, dst, n), segment_max(m, dst, n),
                    segment_min(m, dst, n), segment_std(m, dst, n)]
        scaled = []
        for a in aggs:
            a = jnp.nan_to_num(a, neginf=0.0, posinf=0.0)
            scaled += [a, a * amp, a * att]
        h = jnp.concatenate([x] + scaled, axis=-1)
        x = jax.nn.relu(mlp_apply(lp["post"], h))
    return mlp_apply(params["head"], x)


def loss_fn(params, batch, cfg: PNAConfig):
    logits = forward(params, batch, cfg)
    labels = batch["labels"]
    mask = batch.get("train_mask")
    if mask is not None:
        labels = jnp.where(mask, labels, -1)
    return cross_entropy_loss(logits, labels)
