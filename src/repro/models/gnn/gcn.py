"""GCN (Kipf & Welling, arXiv:1609.02907) — symmetric-normalized SpMM stack.

Ã·X·W realized as edge-gather → weighted segment-sum with per-edge
1/sqrt(d_i d_j) coefficients (self-loops included).  gcn-cora config:
2 layers, hidden 16, node classification.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.gnn.common import (degrees, degrees_spmd,
                                     segment_sum, segment_sum_spmd)
from repro.models.layers import cross_entropy_loss, dense_init


@dataclass(frozen=True)
class GCNConfig:
    name: str
    n_layers: int
    d_hidden: int
    d_feat: int
    n_classes: int
    compute_dtype: str = "float32"
    # explicit-SPMD aggregation (edges sharded across these mesh axes)
    spmd_axes: tuple = ()
    spmd_shards: int = 1

    @property
    def dtype(self):
        return jnp.dtype(self.compute_dtype)


def init_params(key, cfg: GCNConfig):
    dims = [cfg.d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    ws = []
    for i in range(cfg.n_layers):
        key, k = jax.random.split(key)
        ws.append(dense_init(k, dims[i], dims[i + 1]))
    return {"w": ws}


def forward(params, batch, cfg: GCNConfig):
    x = batch["x"].astype(cfg.dtype)
    src, dst = batch["edge_src"], batch["edge_dst"]
    n = x.shape[0]
    # symmetric norm with implicit self loops
    if cfg.spmd_axes:
        deg = degrees_spmd(dst, n, cfg.spmd_axes, cfg.spmd_shards) + 1.0
    else:
        deg = degrees(dst, n) + 1.0
    inv = jax.lax.rsqrt(deg)
    coef = (inv[src] * inv[dst])[:, None].astype(cfg.dtype)
    for i, w in enumerate(params["w"]):
        h = x @ w.astype(cfg.dtype)
        msg = h[src] * coef
        if cfg.spmd_axes:
            nbr = segment_sum_spmd(msg, dst, n, cfg.spmd_axes, cfg.spmd_shards)
        else:
            nbr = segment_sum(msg, dst, n)
        agg = nbr + h * (inv * inv)[:, None].astype(cfg.dtype)
        x = jax.nn.relu(agg) if i < len(params["w"]) - 1 else agg
    return x


def loss_fn(params, batch, cfg: GCNConfig):
    logits = forward(params, batch, cfg)
    labels = batch["labels"]
    mask = batch.get("train_mask")
    if mask is not None:
        labels = jnp.where(mask, labels, -1)
    return cross_entropy_loss(logits, labels)
