from repro.models.gnn import dimenet, gcn, meshgraphnet, pna
from repro.models.gnn.common import segment_mean, segment_softmax_norm

__all__ = ["dimenet", "gcn", "meshgraphnet", "pna", "segment_mean",
           "segment_softmax_norm"]
