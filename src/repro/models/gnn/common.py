"""Message-passing primitives shared by the GNN zoo.

JAX has no native sparse message passing (BCOO only) — per the assignment,
aggregation is built on ``jax.ops.segment_sum`` / ``segment_max`` over an
edge-index scatter.  These wrappers add degree normalization, mean/std
aggregators, and a numerically safe segment softmax; the engine's
``kernels/segment_gather`` provides the fused Pallas path where applicable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum(x, seg, n):
    return jax.ops.segment_sum(x, seg, num_segments=n)


# --------------------------------------------------------------------------
# Explicit-SPMD variants (the "shard_map" GNN profile).
#
# GSPMD's auto-partitioning of scatter-adds over sharded edge arrays falls
# back to full rematerialization (replicating the whole aggregation on every
# device — the warnings the baseline dry-run logs, and the ~0.005 useful
# ratios in the baseline roofline).  These variants run the aggregation
# LOCALLY on each shard's edges and combine with psum/pmax across the mesh.
#
# Gradient correctness: inside shard_map, the transpose of ``psum`` is
# ``psum`` (the pmap convention), so backward cotangents crossing these
# aggregations are automatically all-reduced; taking ``pmean`` of the
# per-shard parameter gradients then reconstructs the exact global gradient
# (verified to ~1e-7 against the single-device gradient in
# tests/test_distributed.py::test_gnn_spmd_matches_single_device).
# --------------------------------------------------------------------------


def segment_sum_spmd(x, seg, n, axes, n_shards):
    """Local scatter-add over this shard's edges + cross-shard psum."""
    if not axes:
        return segment_sum(x, seg, n)
    local = jax.ops.segment_sum(x, seg, num_segments=n)
    return jax.lax.psum(local, axes)


def segment_max_spmd(x, seg, n, axes, n_shards, grad_scale: float = 1.0):
    """Cross-shard segment max, expressed through a masked psum so the
    backward pass uses the same collective transpose as the sum aggregators
    (a straight-through pmax composes incorrectly with deeper layers whose
    cotangents are shard-varying).  Empty segments: local counts guard the
    -inf identity (-inf − -inf = NaN otherwise); globally-empty segments
    restore -inf so downstream nan_to_num treats both paths identically.
    Cross-shard value ties share the gradient equally."""
    if not axes:
        return jax.ops.segment_max(x, seg, num_segments=n)
    local = jax.ops.segment_max(x, seg, num_segments=n)
    cnt_l = jax.ops.segment_sum(jnp.ones(seg.shape[0], local.dtype), seg,
                                num_segments=n)
    while cnt_l.ndim < local.ndim:
        cnt_l = cnt_l[..., None]
    sentinel = jnp.asarray(-3.0e38, local.dtype)
    local_f = jnp.where(cnt_l > 0, local, sentinel)
    m = jax.lax.pmax(jax.lax.stop_gradient(local_f), axes)
    mask = ((jax.lax.stop_gradient(local_f) == m) & (cnt_l > 0)).astype(
        local.dtype)
    ties = jax.lax.psum(mask, axes)
    out = jax.lax.psum(local_f * mask, axes) / jnp.maximum(ties, 1.0)
    cnt_g = jax.lax.psum(jnp.minimum(cnt_l, 1.0), axes)
    return jnp.where(cnt_g > 0, out, -jnp.inf)


def segment_min_spmd(x, seg, n, axes, n_shards, grad_scale: float = 1.0):
    if not axes:
        return jax.ops.segment_min(x, seg, num_segments=n)
    return -segment_max_spmd(-x, seg, n, axes, n_shards,
                             grad_scale=grad_scale)


def segment_mean_spmd(x, seg, n, axes, n_shards):
    s = segment_sum_spmd(x, seg, n, axes, n_shards)
    cnt = segment_sum_spmd(jnp.ones((x.shape[0], 1), x.dtype), seg, n, axes,
                           n_shards)
    return s / jnp.maximum(cnt, 1.0)


def segment_std_spmd(x, seg, n, axes, n_shards, eps: float = 1e-5):
    mu = segment_mean_spmd(x, seg, n, axes, n_shards)
    mu2 = segment_mean_spmd(x * x, seg, n, axes, n_shards)
    return jnp.sqrt(jnp.maximum(mu2 - mu * mu, 0.0) + eps)


def degrees_spmd(seg, n, axes, n_shards, dtype=jnp.float32):
    local = jax.ops.segment_sum(jnp.ones(seg.shape[0], dtype), seg,
                                num_segments=n)
    return jax.lax.psum(local, axes) if axes else local


def segment_mean(x, seg, n):
    s = jax.ops.segment_sum(x, seg, num_segments=n)
    cnt = jax.ops.segment_sum(jnp.ones((x.shape[0], 1), x.dtype), seg,
                              num_segments=n)
    return s / jnp.maximum(cnt, 1.0)


def segment_max(x, seg, n):
    return jax.ops.segment_max(x, seg, num_segments=n)


def segment_min(x, seg, n):
    return jax.ops.segment_min(x, seg, num_segments=n)


def segment_std(x, seg, n, eps: float = 1e-5):
    mu = segment_mean(x, seg, n)
    var = segment_mean(x * x, seg, n) - mu * mu
    return jnp.sqrt(jnp.maximum(var, 0.0) + eps)


def segment_softmax_norm(scores, seg, n):
    """Edge-softmax: normalize scores within each destination segment."""
    smax = jax.ops.segment_max(scores, seg, num_segments=n)
    ex = jnp.exp(scores - smax[seg])
    denom = jax.ops.segment_sum(ex, seg, num_segments=n)
    return ex / jnp.maximum(denom[seg], 1e-9)


def degrees(seg, n, dtype=jnp.float32):
    return jax.ops.segment_sum(jnp.ones(seg.shape[0], dtype), seg,
                               num_segments=n)
