"""Model zoo for the assigned architectures.

Families:
  - LM transformers (dense GQA, MLA, MoE) — transformer.py / moe.py
  - GNNs (gcn, pna, meshgraphnet, dimenet) — gnn/
  - RecSys (dlrm) — recsys/

Each family exposes ``init_params(key, cfg)``, ``loss_fn(params, batch, cfg)``
and (for LMs) ``decode_step(params, cache, batch, cfg)``; the launch layer
wraps them into train/serve steps with optimizer and sharding.
"""
