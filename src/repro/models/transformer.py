"""Decoder-only LM stack: dense GQA, MLA (DeepSeek-V2), and MoE variants.

Layers are parameter-stacked and iterated with ``lax.scan`` so the HLO stays
one-layer-sized regardless of depth (dry-run compile cost, and the layout
production frameworks use).  Both a training forward (full attention) and a
KV-cache decode step are provided; MLA decode uses the *absorbed* form
(cache = compressed c_kv + shared RoPE key — the memory win that defines
MLA), matching DeepSeek-V2 practice.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import (apply_rope, cross_entropy_loss, dense_init,
                                 embed_init, rmsnorm, rope_angles, shard_hint)
from repro.models.moe import MoEConfig, moe_apply, moe_init


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    qk_norm: bool = False
    qkv_bias: bool = False
    attn: str = "gqa"  # "gqa" | "mla"
    # MLA geometry (DeepSeek-V2)
    q_lora: int = 0
    kv_lora: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128
    rope_theta: float = 1e4
    moe: MoEConfig | None = None
    remat: bool = True
    # remat policy: "full" (recompute everything), "dots" (save matmul
    # outputs, recompute elementwise — Megatron-style selective remat)
    remat_policy: str = "full"
    # keep attention logits in fp32 (stable softmax) or bf16 (halves the
    # S×T HBM traffic; max-subtraction still in fp32) — §Perf knob
    attn_fp32_logits: bool = True
    compute_dtype: str = "bfloat16"
    # python-loop the layer stack instead of lax.scan: used by the roofline
    # analyzer's small-depth variants (XLA cost analysis counts a scan body
    # once regardless of trip count, so unrolled variants are differenced
    # to recover true per-layer cost)
    unroll_layers: bool = False
    # activation sharding hints (logical): filled by the sharding rules
    act_spec: Any = None  # P over [batch, seq, model_dim]
    logits_spec: Any = None

    @property
    def dtype(self):
        return jnp.dtype(self.compute_dtype)

    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline accounting)."""
        d, v = self.d_model, self.vocab
        if self.attn == "mla":
            qk = self.nope_head_dim + self.rope_head_dim
            attn = (d * self.q_lora + self.q_lora * self.n_heads * qk
                    + d * self.kv_lora + d * self.rope_head_dim
                    + self.kv_lora * self.n_heads * self.nope_head_dim
                    + self.kv_lora * self.n_heads * self.v_head_dim
                    + self.n_heads * self.v_head_dim * d)
        else:
            attn = d * self.n_heads * self.d_head * 2 \
                + d * self.n_kv_heads * self.d_head * 2
        if self.moe is not None:
            ff_active = 3 * d * self.moe.d_ff_expert * (
                self.moe.top_k + self.moe.n_shared)
            ff_total = 3 * d * self.moe.d_ff_expert * (
                self.moe.n_experts + self.moe.n_shared) + d * self.moe.n_experts
            dense_ff = 3 * d * self.d_ff
            nd = self.moe.first_dense_layers
            total = self.n_layers * attn + nd * dense_ff \
                + (self.n_layers - nd) * ff_total + 2 * v * d
            object.__setattr__(self, "_active",
                               self.n_layers * attn + nd * dense_ff
                               + (self.n_layers - nd) * ff_active + 2 * v * d)
            return total
        return self.n_layers * (attn + 3 * d * self.d_ff) + 2 * v * d

    def active_param_count(self) -> int:
        self.param_count()
        return getattr(self, "_active", self.param_count())


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def _init_attn(key, cfg: LMConfig):
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    if cfg.attn == "mla":
        qk = cfg.nope_head_dim + cfg.rope_head_dim
        p = {
            "w_dq": dense_init(ks[0], d, cfg.q_lora),
            "q_ln": jnp.ones((cfg.q_lora,), jnp.float32),
            "w_uq": dense_init(ks[1], cfg.q_lora, cfg.n_heads * qk),
            "w_dkv": dense_init(ks[2], d, cfg.kv_lora),
            "kv_ln": jnp.ones((cfg.kv_lora,), jnp.float32),
            "w_uk": dense_init(ks[3], cfg.kv_lora,
                               cfg.n_heads * cfg.nope_head_dim),
            "w_uv": dense_init(ks[4], cfg.kv_lora,
                               cfg.n_heads * cfg.v_head_dim),
            "w_kr": dense_init(ks[5], d, cfg.rope_head_dim),
            "wo": dense_init(ks[6], cfg.n_heads * cfg.v_head_dim, d),
        }
        return p
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * cfg.d_head),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * cfg.d_head),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * cfg.d_head),
        "wo": dense_init(ks[3], cfg.n_heads * cfg.d_head, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * cfg.d_head,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * cfg.d_head,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * cfg.d_head,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.d_head,), jnp.float32)
        p["k_norm"] = jnp.ones((cfg.d_head,), jnp.float32)
    return p


def _init_layer(key, cfg: LMConfig, use_moe: bool):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": _init_attn(k1, cfg),
    }
    if use_moe:
        p["moe"] = moe_init(k2, cfg.d_model, cfg.moe)
    else:
        p["mlp"] = {
            "w_gate": dense_init(k2, cfg.d_model, cfg.d_ff),
            "w_up": dense_init(jax.random.fold_in(k2, 1), cfg.d_model, cfg.d_ff),
            "w_down": dense_init(k3, cfg.d_ff, cfg.d_model),
        }
    return p


def init_params(key, cfg: LMConfig):
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    n_dense = cfg.moe.first_dense_layers if cfg.moe else cfg.n_layers
    n_moe = cfg.n_layers - n_dense if cfg.moe else 0
    params = {"embed": embed_init(k_embed, cfg.vocab, cfg.d_model),
              "final_ln": jnp.ones((cfg.d_model,), jnp.float32),
              "lm_head": dense_init(k_head, cfg.d_model, cfg.vocab)}
    if n_dense:
        keys = jax.random.split(jax.random.fold_in(k_layers, 0), n_dense)
        params["dense_layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, use_moe=False))(keys)
    if n_moe:
        keys = jax.random.split(jax.random.fold_in(k_layers, 1), n_moe)
        params["moe_layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, use_moe=True))(keys)
    return params


# --------------------------------------------------------------------------
# attention blocks (training / prefill path)
# --------------------------------------------------------------------------


def _attention_full(x, p, cfg: LMConfig, sin, cos):
    b, s, d = x.shape
    if cfg.attn == "mla":
        return _mla_full(x, p, cfg, sin, cos)
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, s, hq, dh)
    k = k.reshape(b, s, hkv, dh)
    v = v.reshape(b, s, hkv, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    out = _gqa(q, k, v, causal=True, fp32_logits=cfg.attn_fp32_logits)
    return out.reshape(b, s, hq * dh) @ p["wo"].astype(x.dtype)


def _gqa(q, k, v, causal=True, q_offset=0, kv_len=None, fp32_logits=True):
    """GQA with possibly different v head dim."""
    b, s, hq, dqk = q.shape
    t, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, dqk)
    acc_dtype = jnp.float32 if fp32_logits else q.dtype
    logits = jnp.einsum("bshgd,bthd->bhgst", qg, k,
                        preferred_element_type=jnp.float32).astype(acc_dtype)
    logits = logits * jnp.asarray(1.0 / math.sqrt(dqk), acc_dtype)
    neg = jnp.asarray(jnp.finfo(acc_dtype).min, acc_dtype)
    if causal:
        qpos = jnp.arange(s)[:, None] + q_offset
        kpos = jnp.arange(t)[None, :]
        logits = jnp.where((kpos <= qpos)[None, None, None], logits, neg)
    if kv_len is not None:
        valid = jnp.arange(t) < kv_len  # [t]
        logits = jnp.where(valid[None, None, None, None, :], logits, neg)
    # stable softmax: max/sum reductions in fp32 even on the bf16 path
    m = jnp.max(logits.astype(jnp.float32), axis=-1, keepdims=True)
    ex = jnp.exp(logits - m.astype(acc_dtype))
    denom = jnp.sum(ex.astype(jnp.float32), axis=-1, keepdims=True)
    probs = (ex / denom.astype(acc_dtype)).astype(v.dtype)
    out = jnp.einsum("bhgst,bthe->bshge", probs, v)
    return out.reshape(b, s, hq, dv)


def _mla_full(x, p, cfg: LMConfig, sin, cos):
    b, s, d = x.shape
    h, dn, dr, dv = (cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim,
                     cfg.v_head_dim)
    cq = rmsnorm(x @ p["w_dq"].astype(x.dtype), p["q_ln"])
    q = (cq @ p["w_uq"].astype(x.dtype)).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, sin, cos)
    ckv = rmsnorm(x @ p["w_dkv"].astype(x.dtype), p["kv_ln"])
    k_nope = (ckv @ p["w_uk"].astype(x.dtype)).reshape(b, s, h, dn)
    v = (ckv @ p["w_uv"].astype(x.dtype)).reshape(b, s, h, dv)
    k_rope = (x @ p["w_kr"].astype(x.dtype)).reshape(b, s, 1, dr)
    k_rope = apply_rope(k_rope, sin, cos)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))],
                             axis=-1)
    out = _gqa(q_full, k_full, v, causal=True, fp32_logits=cfg.attn_fp32_logits)
    return out.reshape(b, s, h * dv) @ p["wo"].astype(x.dtype)


# --------------------------------------------------------------------------
# forward / loss
# --------------------------------------------------------------------------


def _layer_fwd(x, p, cfg: LMConfig, sin, cos, use_moe: bool):
    h = rmsnorm(x, p["ln1"])
    x = x + shard_hint(_attention_full(h, p["attn"], cfg, sin, cos),
                       cfg.act_spec)
    h2 = rmsnorm(x, p["ln2"])
    if use_moe:
        b, s, d = h2.shape
        y, aux = moe_apply(p["moe"], h2.reshape(b * s, d), cfg.moe)
        y = y.reshape(b, s, d)
    else:
        m = p["mlp"]
        y = jax.nn.silu(h2 @ m["w_gate"].astype(x.dtype)) * (
            h2 @ m["w_up"].astype(x.dtype))
        y = y @ m["w_down"].astype(x.dtype)
        aux = jnp.zeros((), jnp.float32)
    x = x + shard_hint(y, cfg.act_spec)
    return x, aux


def forward(params, tokens: jax.Array, cfg: LMConfig) -> tuple[jax.Array, jax.Array]:
    """tokens int32 [B, S] -> (logits [B, S, V] fp32-safe, aux loss)."""
    b, s = tokens.shape
    x = params["embed"].astype(cfg.dtype)[tokens]
    x = shard_hint(x, cfg.act_spec)
    positions = jnp.arange(s, dtype=jnp.int32)
    dr = cfg.rope_head_dim if cfg.attn == "mla" else cfg.d_head
    sin, cos = rope_angles(positions, dr, cfg.rope_theta)
    sin, cos = sin[None, :, None, :], cos[None, :, None, :]
    aux_total = jnp.zeros((), jnp.float32)

    def run_stack(x, stack, use_moe):
        fwd = lambda xx, pp: _layer_fwd(xx, pp, cfg, sin, cos, use_moe)
        if cfg.remat:
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat_policy == "dots" else None)
            fwd = jax.checkpoint(fwd, policy=policy)
        if cfg.unroll_layers:
            aux = jnp.zeros((), jnp.float32)
            n = jax.tree_util.tree_leaves(stack)[0].shape[0]
            for i in range(n):
                layer_p = jax.tree.map(lambda l: l[i], stack)
                x, a = fwd(x, layer_p)
                aux = aux + a
            return x, aux

        def body(carry, layer_p):
            xc, aux = carry
            xn, a = fwd(xc, layer_p)
            return (xn, aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stack)
        return x, aux

    if "dense_layers" in params:
        x, a = run_stack(x, params["dense_layers"], use_moe=False)
        aux_total += a
    if "moe_layers" in params:
        x, a = run_stack(x, params["moe_layers"], use_moe=True)
        aux_total += a
    x = rmsnorm(x, params["final_ln"])
    logits = x @ params["lm_head"].astype(x.dtype)
    logits = shard_hint(logits, cfg.logits_spec)
    return logits, aux_total


def loss_fn(params, batch: dict, cfg: LMConfig) -> jax.Array:
    logits, aux = forward(params, batch["tokens"], cfg)
    return cross_entropy_loss(logits, batch["labels"]) + aux


# --------------------------------------------------------------------------
# decode (serving) path
# --------------------------------------------------------------------------


def init_cache(cfg: LMConfig, batch: int, max_len: int):
    """Preallocated KV cache, layer-stacked for scan."""
    lt = cfg.n_layers
    if cfg.attn == "mla":
        return {
            "ckv": jnp.zeros((lt, batch, max_len, cfg.kv_lora), cfg.dtype),
            "krope": jnp.zeros((lt, batch, max_len, cfg.rope_head_dim),
                               cfg.dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros((lt, batch, max_len, cfg.n_kv_heads, cfg.d_head),
                       cfg.dtype),
        "v": jnp.zeros((lt, batch, max_len, cfg.n_kv_heads, cfg.d_head),
                       cfg.dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def _gqa_decode(x, p, cfg, cache_k, cache_v, pos, sin, cos):
    b, s, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q, k, v = (q + p["bq"].astype(x.dtype), k + p["bk"].astype(x.dtype),
                   v + p["bv"].astype(x.dtype))
    q = q.reshape(b, s, hq, dh)
    k = k.reshape(b, s, hkv, dh)
    v = v.reshape(b, s, hkv, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    ck = jax.lax.dynamic_update_slice(cache_k, k, (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, v, (0, pos, 0, 0))
    out = _gqa(q, ck, cv, causal=False, kv_len=pos + s,
               fp32_logits=cfg.attn_fp32_logits)
    return out.reshape(b, s, hq * dh) @ p["wo"].astype(x.dtype), ck, cv


def _mla_decode(x, p, cfg, cache_ckv, cache_kr, pos, sin, cos):
    """Absorbed MLA decode: attention runs in the compressed c_kv space."""
    b, s, d = x.shape
    h, dn, dr, dv = (cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim,
                     cfg.v_head_dim)
    c = cfg.kv_lora
    cq = rmsnorm(x @ p["w_dq"].astype(x.dtype), p["q_ln"])
    q = (cq @ p["w_uq"].astype(x.dtype)).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, sin, cos)
    ckv_new = rmsnorm(x @ p["w_dkv"].astype(x.dtype), p["kv_ln"])  # [b,s,c]
    kr_new = apply_rope((x @ p["w_kr"].astype(x.dtype)).reshape(b, s, 1, dr),
                        sin, cos).reshape(b, s, dr)
    cache_ckv = jax.lax.dynamic_update_slice(cache_ckv, ckv_new, (0, pos, 0))
    cache_kr = jax.lax.dynamic_update_slice(cache_kr, kr_new, (0, pos, 0))
    # absorb W_uk into q:  q_abs[b,s,h,c] = q_nope · W_uk[c,h,dn]
    w_uk3 = p["w_uk"].astype(x.dtype).reshape(c, h, dn)
    q_abs = jnp.einsum("bshn,chn->bshc", q_nope, w_uk3)
    logits = (jnp.einsum("bshc,btc->bhst", q_abs, cache_ckv)
              + jnp.einsum("bshr,btr->bhst", q_rope, cache_kr))
    logits = logits.astype(jnp.float32) / math.sqrt(dn + dr)
    t = cache_ckv.shape[1]
    valid = jnp.arange(t)[None, :] < (pos + s)
    logits = jnp.where(valid[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    ctx_c = jnp.einsum("bhst,btc->bshc", probs, cache_ckv)
    w_uv3 = p["w_uv"].astype(x.dtype).reshape(c, h, dv)
    ctx_v = jnp.einsum("bshc,chv->bshv", ctx_c, w_uv3)
    out = ctx_v.reshape(b, s, h * dv) @ p["wo"].astype(x.dtype)
    return out, cache_ckv, cache_kr


def decode_step(params, cache: dict, tokens: jax.Array, cfg: LMConfig):
    """One decode step: tokens [B, S_new] -> (logits [B, S_new, V], cache)."""
    b, s = tokens.shape
    pos = cache["pos"]
    x = params["embed"].astype(cfg.dtype)[tokens]
    positions = pos + jnp.arange(s, dtype=jnp.int32)
    dr = cfg.rope_head_dim if cfg.attn == "mla" else cfg.d_head
    sin, cos = rope_angles(positions, dr, cfg.rope_theta)
    sin, cos = sin[None, :, None, :], cos[None, :, None, :]

    n_dense = cfg.moe.first_dense_layers if cfg.moe else cfg.n_layers
    mla = cfg.attn == "mla"

    def body(x, scanned):
        layer_p, cache_sl, use_moe = scanned
        h = rmsnorm(x, layer_p["ln1"])
        if mla:
            out, c1, c2 = _mla_decode(h, layer_p["attn"], cfg, cache_sl[0],
                                      cache_sl[1], pos, sin, cos)
        else:
            out, c1, c2 = _gqa_decode(h, layer_p["attn"], cfg, cache_sl[0],
                                      cache_sl[1], pos, sin, cos)
        x = x + out
        h2 = rmsnorm(x, layer_p["ln2"])
        if use_moe:
            y, _ = moe_apply(layer_p["moe"], h2.reshape(b * s, -1), cfg.moe)
            y = y.reshape(b, s, -1)
        else:
            m = layer_p["mlp"]
            y = jax.nn.silu(h2 @ m["w_gate"].astype(x.dtype)) * (
                h2 @ m["w_up"].astype(x.dtype))
            y = y @ m["w_down"].astype(x.dtype)
        return x + y, (c1, c2)

    ck_name, cv_name = ("ckv", "krope") if mla else ("k", "v")
    new_c1 = []
    new_c2 = []
    li = 0

    def run_cache_stack(x, stack, c1_sl, c2_sl, use_moe):
        if cfg.unroll_layers:
            n = jax.tree_util.tree_leaves(stack)[0].shape[0]
            c1_out, c2_out = [], []
            for i in range(n):
                layer_p = jax.tree.map(lambda l: l[i], stack)
                x, (c1n, c2n) = body(x, (layer_p, (c1_sl[i], c2_sl[i]),
                                         use_moe))
                c1_out.append(c1n)
                c2_out.append(c2n)
            return x, (jnp.stack(c1_out), jnp.stack(c2_out))

        def scan_body(carry, xs):
            layer_p, c1, c2 = xs
            xn, (c1n, c2n) = body(carry, (layer_p, (c1, c2), use_moe))
            return xn, (c1n, c2n)

        return jax.lax.scan(scan_body, x, (stack, c1_sl, c2_sl))

    if "dense_layers" in params:
        stack = params["dense_layers"]
        nd = jax.tree_util.tree_leaves(stack)[0].shape[0]
        x, (c1s, c2s) = run_cache_stack(
            x, stack, cache[ck_name][li:li + nd], cache[cv_name][li:li + nd],
            use_moe=False)
        new_c1.append(c1s)
        new_c2.append(c2s)
        li += nd
    if "moe_layers" in params:
        stack = params["moe_layers"]
        nm = jax.tree_util.tree_leaves(stack)[0].shape[0]
        x, (c1s, c2s) = run_cache_stack(
            x, stack, cache[ck_name][li:li + nm], cache[cv_name][li:li + nm],
            use_moe=True)
        new_c1.append(c1s)
        new_c2.append(c2s)
        li += nm
    x = rmsnorm(x, params["final_ln"])
    logits = x @ params["lm_head"].astype(x.dtype)
    new_cache = {
        ck_name: jnp.concatenate(new_c1, axis=0),
        cv_name: jnp.concatenate(new_c2, axis=0),
        "pos": pos + s,
    }
    return logits, new_cache
