"""Shared neural-net layers (pure functions over param dicts).

Conventions:
  - params are nested dicts of jnp arrays; master dtype fp32, compute bf16;
  - every layer takes an explicit ``compute_dtype``;
  - initializers take an explicit PRNG key (splittable, deterministic);
  - activations may carry logical sharding annotations via ``shard_hint``.
"""

from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ------------------------------------------------------------------ helpers
def shard_hint(x: jax.Array, spec: P | None) -> jax.Array:
    """Attach a sharding constraint when tracing under a mesh; no-op outside."""
    if spec is None:
        return x
    try:
        from jax.sharding import NamedSharding
        import jax.core

        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.shape:
            return x
        # only constrain if all named axes exist on the mesh
        for axis in jax.tree_util.tree_leaves(tuple(spec)):
            if axis is not None and axis not in mesh.shape:
                return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except Exception:
        return x


def dense_init(key, in_dim: int, out_dim: int, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return jax.random.normal(key, (in_dim, out_dim), dtype=jnp.float32) * scale


def embed_init(key, vocab: int, dim: int):
    return jax.random.normal(key, (vocab, dim), dtype=jnp.float32) * 0.02


# ------------------------------------------------------------------- norms
def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * gamma.astype(dt)


def layernorm(x, gamma, beta, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * gamma.astype(dt) + beta.astype(dt)


# -------------------------------------------------------------------- RoPE
def rope_angles(positions: jax.Array, head_dim: int, theta: float = 1e4):
    """positions int32 [...]: returns (sin, cos) with trailing dim head_dim/2."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x [..., H, D]; sin/cos broadcastable [..., 1, D/2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin.astype(x.dtype)
    cos = cos.astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# --------------------------------------------------------------- attention
def gqa_attention(
    q: jax.Array,  # [B, S, Hq, D]
    k: jax.Array,  # [B, T, Hkv, D]
    v: jax.Array,  # [B, T, Hkv, D]
    causal: bool = True,
    q_offset: jax.Array | int = 0,
    kv_len: jax.Array | None = None,
) -> jax.Array:
    """Grouped-query attention with stable fp32 softmax.

    ``q_offset``: absolute position of q[0] (decode: cache length).
    ``kv_len``: number of valid KV entries (decode with preallocated cache).
    """
    b, s, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    q = q.reshape(b, s, hkv, g, d)
    logits = jnp.einsum("bshgd,bthd->bhgst", q, k).astype(jnp.float32)
    logits *= 1.0 / math.sqrt(d)
    if causal:
        qpos = jnp.arange(s)[:, None] + q_offset
        kpos = jnp.arange(t)[None, :]
        mask = kpos <= qpos  # [s, t]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    if kv_len is not None:
        valid = jnp.arange(t) < kv_len  # [t]
        logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v)
    return out.reshape(b, s, hq, d)


# ------------------------------------------------------------------- MLPs
def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array):
    h = jax.nn.silu(x @ w_gate.astype(x.dtype)) * (x @ w_up.astype(x.dtype))
    return h @ w_down.astype(x.dtype)


def mlp(x: jax.Array, weights: Sequence[jax.Array],
        biases: Sequence[jax.Array] | None = None,
        act=jax.nn.relu, final_act: bool = False) -> jax.Array:
    n = len(weights)
    for i, w in enumerate(weights):
        x = x @ w.astype(x.dtype)
        if biases is not None:
            x = x + biases[i].astype(x.dtype)
        if i < n - 1 or final_act:
            x = act(x)
    return x


def mlp_init(key, dims: Sequence[int], with_bias: bool = True):
    ws, bs = [], []
    for i in range(len(dims) - 1):
        key, k1 = jax.random.split(key)
        ws.append(dense_init(k1, dims[i], dims[i + 1]))
        if with_bias:
            bs.append(jnp.zeros((dims[i + 1],), jnp.float32))
    params = {"w": ws}
    if with_bias:
        params["b"] = bs
    return params


def mlp_apply(params, x, act=jax.nn.relu, final_act: bool = False):
    return mlp(x, params["w"], params.get("b"), act=act, final_act=final_act)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       ignore: int = -1) -> jax.Array:
    """Mean CE over non-ignored positions; logits [..., V], labels int [...]"""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].clip(0), axis=-1)[..., 0]
    nll = lse - ll
    mask = (labels != ignore).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
