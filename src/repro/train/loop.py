"""Fault-tolerant training loop.

- checkpoint every ``ckpt_every`` steps (async, atomic, keep-k);
- SIGTERM/SIGINT → flush a final checkpoint before exiting (preemption
  handling, the behavior a borg/k8s eviction needs);
- step-level retry: a transient step failure (device OOM, io hiccup)
  restores the last checkpoint and replays — data streams are stateless in
  ``step`` so replay is exact;
- straggler tracking feeds metrics.
"""

from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax

from repro.train.checkpoint import Checkpointer
from repro.train.straggler import StepTimeTracker
from repro.utils import get_logger

log = get_logger("train.loop")


@dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 100
    ckpt_dir: str = "runs/ckpt"
    keep: int = 3
    max_retries: int = 3
    log_every: int = 10


@dataclass
class Trainer:
    step_fn: Callable  # (params, opt_state, batch) -> (params, opt, metrics)
    stream: Any  # .batch_at(step) -> batch
    cfg: LoopConfig
    params: Any
    opt_state: Any
    metrics_log: list = field(default_factory=list)

    def __post_init__(self):
        self.ckpt = Checkpointer(self.cfg.ckpt_dir, keep=self.cfg.keep)
        self.tracker = StepTimeTracker()
        self._preempted = False

    # -- preemption ---------------------------------------------------------
    def _install_handlers(self):
        def handler(signum, frame):
            log.warning("signal %s: will checkpoint and stop", signum)
            self._preempted = True

        try:
            signal.signal(signal.SIGTERM, handler)
            signal.signal(signal.SIGINT, handler)
        except ValueError:
            pass  # not main thread (tests)

    # -- main ---------------------------------------------------------------
    def fit(self, start_step: int | None = None) -> int:
        self._install_handlers()
        step = self._maybe_restore() if start_step is None else start_step
        retries = 0
        while step < self.cfg.total_steps and not self._preempted:
            batch = self.stream.batch_at(step)
            t0 = time.perf_counter()
            try:
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch)
                jax.block_until_ready(metrics["loss"])
            except Exception as e:  # transient failure path
                retries += 1
                log.error("step %d failed (%s); retry %d/%d from last "
                          "checkpoint", step, e, retries,
                          self.cfg.max_retries)
                if retries > self.cfg.max_retries:
                    self._flush(step)
                    raise
                step = self._maybe_restore()
                continue
            retries = 0
            dt = time.perf_counter() - t0
            self.tracker.record(step, dt)
            step += 1
            if step % self.cfg.log_every == 0 or step == self.cfg.total_steps:
                rec = {"step": step, "loss": float(metrics["loss"]),
                       "grad_norm": float(metrics["grad_norm"]),
                       "sec_per_step": dt}
                self.metrics_log.append(rec)
                log.info("step %(step)d loss=%(loss).4f "
                         "gnorm=%(grad_norm).3f %(sec_per_step).3fs", rec)
            if step % self.cfg.ckpt_every == 0:
                self._flush(step, blocking=False)
        self._flush(step)
        return step

    # -- checkpoint plumbing --------------------------------------------------
    def _flush(self, step: int, blocking: bool = True) -> None:
        self.ckpt.save(step, {"params": self.params, "opt": self.opt_state},
                       extra={"metrics": self.metrics_log[-5:]},
                       blocking=blocking)

    def _maybe_restore(self) -> int:
        got = self.ckpt.restore({"params": self.params,
                                 "opt": self.opt_state})
        if got is None:
            return 0
        step, trees, _ = got
        self.params = trees["params"]
        self.opt_state = trees["opt"]
        log.info("restored checkpoint at step %d", step)
        return step
