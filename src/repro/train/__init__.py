from repro.train.optimizer import AdamWState, adamw_init, adamw_update
from repro.train.trainstep import make_train_step

__all__ = ["AdamWState", "adamw_init", "adamw_update", "make_train_step"]
