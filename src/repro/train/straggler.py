"""Straggler detection & mitigation hooks.

Training: ``StepTimeTracker`` keeps a rolling window of per-step wall times;
steps slower than ``factor`` × rolling-median are flagged.  On a real
multi-host fleet the flags feed the controller that evicts/replaces slow
hosts; on this container they surface in metrics and tests.

Engine serving: ``ChunkRebalancer`` consumes per-chunk execution times and
re-deals the heaviest chunks the next round (the paper's dynamic chunk
distribution, closed-loop version).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.utils import get_logger

log = get_logger("train.straggler")


@dataclass
class StepTimeTracker:
    window: int = 50
    factor: float = 2.0
    times: deque = field(default_factory=lambda: deque(maxlen=256))
    flagged: list = field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        self.times.append(seconds)
        if len(self.times) < 8:
            return False
        med = float(np.median(list(self.times)[-self.window:]))
        is_straggler = seconds > self.factor * med
        if is_straggler:
            self.flagged.append((step, seconds, med))
            log.warning("straggler step %d: %.3fs vs median %.3fs",
                        step, seconds, med)
        return is_straggler

    @property
    def median(self) -> float:
        return float(np.median(self.times)) if self.times else 0.0


@dataclass
class ChunkRebalancer:
    """Re-deal engine work chunks based on observed chunk times."""

    n_shards: int
    history: dict = field(default_factory=dict)  # chunk_id -> ema seconds
    alpha: float = 0.5

    def observe(self, chunk_id: int, seconds: float) -> None:
        prev = self.history.get(chunk_id)
        self.history[chunk_id] = (seconds if prev is None
                                  else self.alpha * seconds
                                  + (1 - self.alpha) * prev)

    def assign(self, chunk_ids: list[int]) -> list[list[int]]:
        """LPT re-assignment using observed times (unknown chunks = median)."""
        default = (float(np.median(list(self.history.values())))
                   if self.history else 1.0)
        est = {c: self.history.get(c, default) for c in chunk_ids}
        order = sorted(chunk_ids, key=lambda c: -est[c])
        loads = np.zeros(self.n_shards)
        out: list[list[int]] = [[] for _ in range(self.n_shards)]
        for c in order:
            s = int(np.argmin(loads))
            out[s].append(c)
            loads[s] += est[c]
        return out
