"""Hand-rolled AdamW + LR schedules + gradient clipping + int8 gradient
compression with error feedback (no external optimizer dependency).

Compression: before the cross-replica mean, gradients can be quantized to
int8 with a per-leaf scale and an error-feedback residual carried in the
optimizer state (1-bit-Adam-family trick, arXiv:2102.02888 flavor).  This
cuts all-reduce bytes 4× at ~zero quality cost for well-conditioned leaves;
enabled per-config (``grad_compress=True``) and exercised by tests.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # int32
    mu: Any  # first moment (pytree like params)
    nu: Any  # second moment
    err: Any | None  # error-feedback residual (grad compression) or None


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"  # "cosine" | "linear" | "const"
    grad_compress: bool = False


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / max(1, cfg.warmup_steps))
    if cfg.schedule == "const":
        decay = 1.0
    elif cfg.schedule == "linear":
        frac = jnp.clip((s - cfg.warmup_steps)
                        / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
        decay = 1.0 - frac
    else:  # cosine
        frac = jnp.clip((s - cfg.warmup_steps)
                        / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * decay


def adamw_init(params, cfg: OptConfig) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    err = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                       params) if cfg.grad_compress else None
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros), err=err)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def compress_int8(g: jax.Array, err: jax.Array):
    """Quantize g+err to int8 with per-leaf absmax scale; return
    (quantized float value, new residual)."""
    t = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(t)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(t / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, t - deq


def adamw_update(params, grads, state: AdamWState, cfg: OptConfig,
                 axis_name: str | None = None):
    """One AdamW step.  If ``axis_name`` is given (inside shard_map/pmap),
    the cross-replica mean runs here — after optional int8 compression."""
    new_err = state.err
    if cfg.grad_compress:
        pairs = jax.tree.map(compress_int8, grads, state.err)
        grads = jax.tree.map(lambda pr: pr[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda pr: pr[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
    if axis_name is not None:
        grads = jax.lax.pmean(grads, axis_name)
    # clip by global norm
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    b1, b2 = cfg.betas
    lr = lr_at(cfg, state.step)
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_mu, new_nu, new_err), gn
