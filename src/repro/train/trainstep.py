"""Train/serve step builders: value_and_grad + AdamW (+ microbatch gradient
accumulation via lax.scan) around a family loss function."""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.train.optimizer import OptConfig, adamw_update


def make_train_step(
    loss_fn: Callable,  # (params, batch, model_cfg) -> scalar
    model_cfg,
    opt_cfg: OptConfig,
    microbatches: int = 1,
    axis_name: str | None = None,
):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``microbatches > 1``: the batch's leading dim is split and gradients are
    accumulated with a lax.scan — the standard memory/overlap lever (each
    microbatch's backward overlaps the next microbatch's gradient psum when
    compiled with the latency-hiding scheduler).
    """

    def loss_wrapped(params, batch):
        return loss_fn(params, batch, model_cfg)

    def step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_wrapped)(params, batch)
        else:
            def resh(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])

            mb = jax.tree.map(resh, batch)

            def body(carry, one):
                acc, loss_acc = carry
                l, g = jax.value_and_grad(loss_wrapped)(params, one)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_acc + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
        params, opt_state, gnorm = adamw_update(params, grads, opt_state,
                                                opt_cfg, axis_name=axis_name)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm,
                   "step": opt_state.step}
        return params, opt_state, metrics

    return step


def make_eval_step(loss_fn, model_cfg):
    def step(params, batch):
        return loss_fn(params, batch, model_cfg)

    return step
