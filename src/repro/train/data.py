"""Deterministic, resumable synthetic data pipelines per family.

Every stream is *stateless in step*: ``batch_at(step)`` derives the batch
from (seed, step) alone, so resuming after preemption is exact — restore the
step counter and the stream continues byte-identically (no iterator state
in checkpoints).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.models.gnn.sampler import pad_block, sample_blocks


@dataclass(frozen=True)
class TokenStream:
    """Synthetic LM token stream with a Zipf unigram + local structure
    (repeated n-grams) so the loss has learnable signal."""

    vocab: int
    batch: int
    seq: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        base = rng.zipf(1.3, size=(self.batch, self.seq + 1)) % self.vocab
        # inject copy structure: second half repeats the first half shifted
        half = (self.seq + 1) // 2
        base[:, half:half * 2] = base[:, :half]
        tokens = base[:, :-1].astype(np.int32)
        labels = base[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}


@dataclass(frozen=True)
class RecsysStream:
    n_dense: int
    n_sparse: int
    hotness: int
    vocab_sizes: tuple[int, ...]
    batch: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        dense = rng.normal(size=(self.batch, self.n_dense)).astype(np.float32)
        sparse = np.zeros((self.batch, self.n_sparse, self.hotness), np.int32)
        for f, v in enumerate(self.vocab_sizes):
            sparse[:, f, :] = rng.zipf(1.2, size=(self.batch,
                                                  self.hotness)) % v
        # some pad slots
        pad = rng.random((self.batch, self.n_sparse, self.hotness)) < 0.1
        sparse[pad] = -1
        # clickable signal: label correlates with dense[0]
        labels = (dense[:, 0] + 0.3 * rng.normal(size=self.batch) > 0)
        return {"dense": dense, "sparse": sparse,
                "labels": labels.astype(np.float32)}


class SampledGraphStream:
    """Layered-fanout neighbor sampling over a synthetic power-law graph."""

    def __init__(self, n_nodes: int, avg_degree: int, d_feat: int,
                 n_classes: int, batch_nodes: int, fanout, seed: int = 0):
        rng = np.random.default_rng(seed)
        m = n_nodes * avg_degree
        src = rng.zipf(1.4, size=m) % n_nodes
        dst = rng.integers(0, n_nodes, m)
        order = np.argsort(src, kind="stable")
        src, dst = src[order], dst[order]
        self.indptr = np.zeros(n_nodes + 1, np.int64)
        np.cumsum(np.bincount(src, minlength=n_nodes), out=self.indptr[1:])
        self.nbr = dst.astype(np.int32)
        self.features = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
        self.labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
        self.n_nodes = n_nodes
        self.batch_nodes = batch_nodes
        self.fanout = list(fanout)
        self.seed = seed
        from repro.configs.common import sampled_block_dims

        self.pad_n, self.pad_e = sampled_block_dims(batch_nodes, fanout)
        self.pad_n += batch_nodes  # slack for duplicate-free local ids

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        seeds = rng.choice(self.n_nodes, self.batch_nodes, replace=False)
        blk = sample_blocks(self.indptr, self.nbr, seeds, self.fanout, rng)
        p = pad_block(blk, self.pad_n, self.pad_e)
        feats = self.features[p["nodes"]]
        labels = self.labels[p["nodes"]]
        mask = np.zeros(self.pad_n, bool)
        mask[: blk["seed_count"]] = True
        return {"x": feats, "edge_src": p["edge_src"],
                "edge_dst": p["edge_dst"], "labels": labels,
                "train_mask": mask}
