"""Checkpointing: atomic, keep-last-k, async, elastic on restore.

Layout:  <dir>/step_<n>/   arrays.npz  (leaf path -> array)
                           meta.json   (step, tree structure, extra)
         <dir>/step_<n>.tmp.*          (staging; atomic rename commits)

- *Atomic*: a checkpoint directory appears only via os.replace of a fully
  written staging dir — a crash mid-write never leaves a half checkpoint
  visible.
- *Keep-k*: older step dirs are pruned after a successful commit.
- *Async*: ``save(..., blocking=False)`` snapshots to host memory
  synchronously (cheap) and writes in a daemon thread, overlapping I/O with
  the next training steps.
- *Elastic*: ``restore`` returns host numpy trees; the caller re-shards via
  ``jax.device_put`` with whatever mesh is alive (topology changes between
  save and restore are fine — arrays are saved unsharded).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np

from repro.utils import get_logger

log = get_logger("train.checkpoint")

_SEP = "|"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_part(p) for p in kp)
        arr = np.asarray(leaf)
        # npz cannot round-trip ml_dtypes (bf16/fp8): store a bit-view
        if arr.dtype.kind == "V" or "bfloat16" in str(arr.dtype) \
                or "float8" in str(arr.dtype):
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        flat[key] = arr
    return flat


def _part(entry) -> str:
    if hasattr(entry, "key"):
        return f"k:{entry.key}"
    if hasattr(entry, "idx"):
        return f"i:{entry.idx}"
    if hasattr(entry, "name"):
        return f"n:{entry.name}"
    return f"?:{entry}"


class Checkpointer:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = Path(directory)
        self.keep = keep
        self.dir.mkdir(parents=True, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, trees: dict[str, Any], extra: dict | None = None,
             blocking: bool = True) -> None:
        # snapshot to host NOW (device buffers may be donated next step)
        host = {name: _flatten(tree) for name, tree in trees.items()}
        meta = {"step": int(step), "names": sorted(host),
                "extra": extra or {}}
        self.wait()
        if blocking:
            self._write(step, host, meta)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, meta), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: dict, meta: dict) -> None:
        final = self.dir / f"step_{step:012d}"
        staging = Path(tempfile.mkdtemp(prefix=f"step_{step:012d}.tmp.",
                                        dir=self.dir))
        try:
            for name, flat in host.items():
                np.savez(staging / f"{name}.npz", **flat)
            (staging / "meta.json").write_text(json.dumps(meta))
            if final.exists():
                shutil.rmtree(final)
            os.replace(staging, final)
            log.info("checkpoint step %d committed (%s)", step, final)
            self._prune()
        except Exception:
            shutil.rmtree(staging, ignore_errors=True)
            raise

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.dir / f"step_{s:012d}", ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") and "tmp" not in p.name:
                if (p / "meta.json").exists():  # committed only
                    out.append(int(p.name[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, templates: dict[str, Any], step: int | None = None,
                shardings: dict[str, Any] | None = None):
        """Restore trees shaped like ``templates``; optionally re-shard each
        tree with a matching sharding pytree (elastic restore)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None
        d = self.dir / f"step_{step:012d}"
        meta = json.loads((d / "meta.json").read_text())
        out: dict[str, Any] = {}
        for name, template in templates.items():
            flat = np.load(d / f"{name}.npz")
            kps, treedef = jax.tree_util.tree_flatten_with_path(template)
            leaves = []
            shd_leaves = None
            if shardings is not None and name in shardings:
                shd_leaves = jax.tree_util.tree_leaves(
                    shardings[name],
                    is_leaf=lambda x: hasattr(x, "spec"))
            for i, (kp, tmpl) in enumerate(kps):
                key = _SEP.join(_part(p) for p in kp)
                arr = flat[key]
                if tuple(arr.shape) != tuple(tmpl.shape):
                    raise ValueError(
                        f"checkpoint leaf {key}: shape {arr.shape} != "
                        f"template {tmpl.shape}")
                tdt = np.dtype(tmpl.dtype)
                if arr.dtype != tdt and arr.dtype.itemsize == tdt.itemsize \
                        and arr.dtype.kind in "uV" and tdt.kind not in "iuf":
                    arr = arr.view(tdt)  # bit-view restore (bf16/fp8)
                elif arr.dtype != tdt and arr.dtype == np.uint16 \
                        and "bfloat16" in str(tdt):
                    arr = arr.view(tdt)
                else:
                    arr = arr.astype(tdt)
                if shd_leaves is not None:
                    arr = jax.device_put(arr, shd_leaves[i])
                leaves.append(arr)
            out[name] = jax.tree_util.tree_unflatten(treedef, leaves)
        return int(meta["step"]), out, meta.get("extra", {})
