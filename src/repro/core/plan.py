"""Query planning: start-vertex choice, query tree, matching order (§2.2, §4).

Mirrors TurboISO's pipeline, adapted for the vectorized executor:

- ``choose_start_vertex``  — rank(u) = freq(g, L(u)) / deg(u) (paper's score),
  freq from the inverse vertex-label index / predicate index / ID attribute.
- ``write_query_tree``     — BFS tree from the start vertex; non-tree edges
  recorded and attached to the later endpoint in the matching order.
- ``matching order``       — greedy minimum-estimated-fanout ordering.  Two
  estimators: ``static`` (schema statistics: per-label average fanout ×
  label selectivity) and ``sampled`` (the paper's candidate-region-based
  estimation: walk the tree over the *actual* start candidates with host
  numpy and count candidates per path).  With +REUSE (default) the sampled
  order is computed once, on the first chunk of candidate regions, and
  reused for all regions — on TPU this is structural: one compiled XLA
  executable serves every region.  The -REUSE ablation replans per chunk.

The output ``ExecPlan`` is a static list of expansion steps the executor
compiles into a single jitted program.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.query import QueryGraph
from repro.rdf.graph import LabeledGraph
from repro.utils import get_logger

log = get_logger("core.plan")


class PlanError(ValueError):
    pass


@dataclass
class NTCheck:
    """Non-tree edge check executed when query vertex ``u`` is bound.

    The query edge is (other --elabel--> u) if ``forward`` else
    (u --elabel--> other); ``other`` is bound earlier in the order.
    """

    other: int
    elabel: int
    forward: bool
    pvar_idx: int = -1  # >= 0: edge label is that predicate variable's binding
    self_loop: bool = False  # query self-loop checked against u itself


@dataclass
class Step:
    u: int
    parent: int  # -1 for a cross-component restart step
    elabel: int  # -1 = predicate variable
    forward: bool  # parent --el--> u (out CSR) vs u --el--> parent (in CSR)
    pvar_idx: int = -1
    labels: tuple[int, ...] = ()
    bound_id: int = -1
    nontree: tuple[NTCheck, ...] = ()
    min_out_ntypes: int = 0  # hom-weakened degree filter constants
    min_in_ntypes: int = 0
    nlf_out_mask: np.ndarray | None = None  # uint32 words over neighbor types
    nlf_in_mask: np.ndarray | None = None
    num_filters: tuple[tuple[str, float], ...] = ()
    optional_group: int = -1  # -1 = required pattern
    # restart steps expand the table by this component's start candidates
    restart_candidates: np.ndarray | None = None


@dataclass
class ExecPlan:
    query: QueryGraph
    start_vertex: int
    start_candidates: np.ndarray  # int32, sorted
    steps: list[Step]
    order: list[int]  # query vertex order (including start)
    n_pvars: int
    unsat: bool = False
    # estimated fanout per step (for capacity presizing)
    est_fanout: list[float] = field(default_factory=list)

    def signature(self) -> tuple:
        """Hashable identity for the compiled-executable cache."""
        return (
            self.start_vertex,
            tuple(
                (
                    s.u, s.parent, s.elabel, s.forward, s.pvar_idx, s.labels,
                    s.bound_id, s.min_out_ntypes, s.min_in_ntypes,
                    tuple((c.other, c.elabel, c.forward, c.pvar_idx, c.self_loop)
                          for c in s.nontree),
                    s.num_filters, s.optional_group,
                    None if s.restart_candidates is None
                    else len(s.restart_candidates),
                )
                for s in self.steps
            ),
            self.n_pvars,
        )


# --------------------------------------------------------------------------
# ChooseStartQueryVertex
# --------------------------------------------------------------------------


def _vertex_freq(g: LabeledGraph, q: QueryGraph, u: int) -> float:
    qv = q.vertices[u]
    if qv.bound_id >= 0:
        return 1.0
    if qv.bound_id == -2:  # constant missing from data
        return 0.0
    if qv.labels:
        return float(g.freq(list(qv.labels)))
    # label-free: use the predicate index over incident edges
    best = float(g.n_vertices)
    for e in q.edges:
        if e.elabel < 0:
            continue
        subs, objs = g.predicate_index(e.elabel)
        if e.u == u:
            best = min(best, float(subs.shape[0]))
        if e.v == u:
            best = min(best, float(objs.shape[0]))
    return best


def _candidates(g: LabeledGraph, q: QueryGraph, u: int) -> np.ndarray:
    qv = q.vertices[u]
    if qv.bound_id >= 0:
        cand = np.array([qv.bound_id], dtype=np.int32)
        if qv.labels:  # ID + labels: verify label containment
            bm = g.label_bitmap[qv.bound_id]
            for lbl in qv.labels:
                if not (bm[lbl >> 5] >> np.uint32(lbl & 31)) & np.uint32(1):
                    return np.zeros(0, dtype=np.int32)
        return cand
    if qv.bound_id == -2:
        return np.zeros(0, dtype=np.int32)
    if qv.labels:
        return g.candidates_with_labels(list(qv.labels))
    # label-free: smallest predicate-index side among incident edges
    best: np.ndarray | None = None
    for e in q.edges:
        if e.elabel < 0:
            continue
        subs, objs = g.predicate_index(e.elabel)
        side = subs if e.u == u else (objs if e.v == u else None)
        if side is not None and (best is None or side.shape[0] < best.shape[0]):
            best = side
    if best is not None:
        return best.astype(np.int32)
    return np.arange(g.n_vertices, dtype=np.int32)


def choose_start_vertex(g: LabeledGraph, q: QueryGraph, component: list[int]) -> int:
    adj = q.adjacency()
    best_u, best_score = component[0], float("inf")
    for u in component:
        deg = max(1, len(adj[u]))
        score = _vertex_freq(g, q, u) / deg
        if score < best_score:
            best_score = score
            best_u = u
    return best_u


# --------------------------------------------------------------------------
# WriteQueryTree + matching order
# --------------------------------------------------------------------------


def _avg_fanout(g: LabeledGraph, el: int, forward: bool) -> float:
    if el < 0:
        return float(g.out.degree.mean() + 1.0)
    subs, objs = g.predicate_index(el)
    m_el = int(g.out.indptr_el[el, -1] - g.out.indptr_el[el, 0])
    srcs = subs.shape[0] if forward else objs.shape[0]
    return m_el / max(1, srcs)


def _label_selectivity(g: LabeledGraph, labels: tuple[int, ...]) -> float:
    if not labels:
        return 1.0
    return max(1.0, float(g.freq(list(labels)))) / max(1, g.n_vertices)


def _static_edge_cost(g: LabeledGraph, q: QueryGraph, ei: int, parent: int) -> float:
    e = q.edges[ei]
    forward = e.u == parent
    child = e.v if forward else e.u
    qv = q.vertices[child]
    est = _avg_fanout(g, e.elabel, forward)
    if qv.bound_id >= 0:
        est = min(est, 0.05)
    elif qv.labels:
        est *= max(0.01, _label_selectivity(g, qv.labels) * 4.0)
    return est


def _sampled_order(
    g: LabeledGraph,
    q: QueryGraph,
    start: int,
    candidates: np.ndarray,
    optional_rank: dict[int, int],
) -> list[int] | None:
    """Paper-style candidate-region estimation: walk tree edges over the real
    start candidates (first chunk) with host numpy, greedily choosing the
    child with the fewest total candidates.  Returns None on any pvar edge
    (falls back to static)."""
    sample = candidates[: min(256, candidates.shape[0])].astype(np.int64)
    placed = {start}
    cand_of: dict[int, np.ndarray] = {start: sample}
    order = [start]
    adj = q.adjacency()
    remaining = {v for v in range(q.n_vertices)} - placed
    # restrict to this component
    comp = set()
    stack = [start]
    comp.add(start)
    while stack:
        cur = stack.pop()
        for _, w in adj[cur]:
            if w not in comp:
                comp.add(w)
                stack.append(w)
    remaining &= comp
    while remaining:
        frontier: list[tuple[float, int, int, np.ndarray]] = []
        for p in list(placed):
            for ei, w in adj[p]:
                if w in placed or w not in remaining:
                    continue
                e = q.edges[ei]
                if e.elabel < 0:
                    return None
                forward = e.u == p
                d = g.out if forward else g.inc
                vp = cand_of[p]
                starts = d.indptr_el[e.elabel, vp]
                ends = d.indptr_el[e.elabel, vp + 1]
                degs = ends - starts
                total = int(degs.sum())
                # gather up to a bounded number of children for the next level
                child = _gather_bounded(d.nbr_el, starts, degs, bound=4096)
                child = _filter_by_labels(g, child, q.vertices[w].labels)
                if q.vertices[w].bound_id >= 0:
                    child = child[child == q.vertices[w].bound_id]
                cost = float(total) + 1e3 * optional_rank.get(w, 0)
                frontier.append((cost, w, ei, np.unique(child)))
        if not frontier:
            break
        frontier.sort(key=lambda t: t[0])
        _, w, _, child = frontier[0]
        placed.add(w)
        remaining.discard(w)
        cand_of[w] = child if child.size else np.zeros(1, dtype=np.int64)
        order.append(w)
    return order if len(order) == len(comp) else None


def _gather_bounded(nbr: np.ndarray, starts: np.ndarray, degs: np.ndarray, bound: int):
    take = np.minimum(degs, np.maximum(0, bound // max(1, len(starts))) + 1)
    parts = [nbr[s : s + t] for s, t in zip(starts, take) if t > 0]
    return np.concatenate(parts).astype(np.int64) if parts else np.zeros(0, np.int64)


def _filter_by_labels(g: LabeledGraph, verts: np.ndarray, labels) -> np.ndarray:
    if not len(labels) or verts.size == 0:
        return verts
    keep = np.ones(verts.shape[0], dtype=bool)
    for lbl in labels:
        keep &= ((g.label_bitmap[verts, lbl >> 5] >> np.uint32(lbl & 31)) & 1).astype(bool)
    return verts[keep]


# --------------------------------------------------------------------------
# Plan construction
# --------------------------------------------------------------------------


def _nlf_masks(
    g: LabeledGraph, q: QueryGraph, u: int
) -> tuple[np.ndarray, np.ndarray, int, int]:
    """Query-side NLF masks + hom-weakened degree minimums for vertex u."""
    stride = g.n_vlabels + 1
    n_types = g.n_elabels * stride
    n_words = (n_types + 31) // 32
    masks = {True: np.zeros(n_words, np.uint32), False: np.zeros(n_words, np.uint32)}
    ntypes = {True: set(), False: set()}
    for e in q.edges:
        if e.elabel < 0:
            continue
        if e.u == u:
            other, out_dir = e.v, True
        elif e.v == u:
            other, out_dir = e.u, False
        else:
            continue
        labels = q.vertices[other].labels
        ts = [e.elabel * stride] if not labels else [
            e.elabel * stride + 1 + l for l in labels
        ]
        for t in ts:
            masks[out_dir][t >> 5] |= np.uint32(1 << (t & 31))
        ntypes[out_dir].add((e.elabel, labels))
    return masks[True], masks[False], len(ntypes[True]), len(ntypes[False])


def build_plan(
    g: LabeledGraph,
    q: QueryGraph,
    *,
    estimate: str = "sampled",
    num_filters: dict[str, list[tuple[str, float]]] | None = None,
    optional_groups: dict[int, int] | None = None,
    use_nlf: bool = False,
    use_deg: bool = False,
) -> ExecPlan:
    """Build an execution plan for a (sub-)query.

    ``optional_groups`` maps query-vertex index -> optional group id (used by
    the OPTIONAL orchestration, which plans extension steps separately).
    ``use_nlf`` / ``use_deg`` correspond to the paper's -NLF / -DEG toggles
    (both disabled by default, the paper's recommended configuration).
    """
    num_filters = num_filters or {}
    optional_groups = optional_groups or {}
    if q.unsat:
        return ExecPlan(q, 0, np.zeros(0, np.int32), [], [0] if q.n_vertices else [],
                        len(q.pvars), unsat=True)
    if q.n_vertices == 0:
        raise PlanError("empty query")

    comps = q.connected_components()
    # order components: the one containing the best start vertex first
    comp_starts = [choose_start_vertex(g, q, c) for c in comps]
    comp_rank = sorted(
        range(len(comps)), key=lambda i: _vertex_freq(g, q, comp_starts[i])
    )
    adj = q.adjacency()
    steps: list[Step] = []
    global_order: list[int] = []
    placed: set[int] = set()
    edge_used = [False] * len(q.edges)
    start_vertex = comp_starts[comp_rank[0]]
    start_candidates = _candidates(g, q, start_vertex)
    est_fanout: list[float] = []

    for rank_pos, ci in enumerate(comp_rank):
        comp = comps[ci]
        s = comp_starts[ci]
        cands = start_candidates if rank_pos == 0 else _candidates(g, q, s)
        if use_deg and cands.size:
            _, _, mo, mi = _nlf_masks(g, q, s)
            keep = (g.out.degree[cands] >= mo) & (g.inc.degree[cands] >= mi)
            cands = cands[keep]
        if rank_pos == 0:
            start_candidates = cands
        else:
            steps.append(Step(u=s, parent=-1, elabel=-1, forward=True,
                              labels=q.vertices[s].labels,
                              bound_id=max(q.vertices[s].bound_id, -1),
                              optional_group=optional_groups.get(s, -1),
                              restart_candidates=cands))
            est_fanout.append(float(max(1, cands.shape[0])))
        placed.add(s)
        global_order.append(s)

        # matching order within the component
        order = None
        if estimate == "sampled":
            order = _sampled_order(g, q, s, cands, optional_groups)
        if order is None:
            order = _static_greedy_order(g, q, s, comp, adj, optional_groups)
        # emit steps following `order`
        for w in order[1:]:
            # tree edge: cheapest edge from placed to w
            best_ei, best_cost = -1, float("inf")
            for ei, other in adj[w]:
                if edge_used[ei] or other not in placed:
                    continue
                cost = _static_edge_cost(g, q, ei, other)
                if q.edges[ei].elabel < 0:
                    cost *= 0.5  # prefer pvar edges as tree edges (they must expand)
                if cost < best_cost:
                    best_cost, best_ei = cost, ei
            if best_ei < 0:
                raise PlanError(f"vertex {w} not connected to placed set")
            e = q.edges[best_ei]
            edge_used[best_ei] = True
            forward = e.u != w  # parent --> w when parent is subject
            parent = e.u if forward else e.v
            # non-tree edges resolvable now (both endpoints placed after adding w)
            nts: list[NTCheck] = []
            for ei2, other2 in adj[w]:
                if edge_used[ei2]:
                    continue
                e2 = q.edges[ei2]
                if e2.u == e2.v == w:  # self loop
                    edge_used[ei2] = True
                    nts.append(NTCheck(other=w, elabel=e2.elabel, forward=True,
                                       pvar_idx=_pvar_idx(q, e2), self_loop=True))
                    continue
                if other2 in placed:
                    edge_used[ei2] = True
                    fwd = e2.u == other2  # (other --el--> w)?
                    if e2.elabel < 0 and _pvar_idx(q, e2) < 0:
                        raise PlanError("unbound predicate variable on non-tree edge")
                    nts.append(NTCheck(other=other2, elabel=e2.elabel, forward=fwd,
                                       pvar_idx=_pvar_idx(q, e2)))
            om, im, mo, mi = _nlf_masks(g, q, w)
            qv = q.vertices[w]
            steps.append(
                Step(
                    u=w,
                    parent=parent,
                    elabel=e.elabel,
                    forward=forward,
                    pvar_idx=_pvar_idx(q, e),
                    labels=qv.labels,
                    bound_id=max(qv.bound_id, -1),
                    nontree=tuple(nts),
                    min_out_ntypes=mo if use_deg else 0,
                    min_in_ntypes=mi if use_deg else 0,
                    nlf_out_mask=om if use_nlf else None,
                    nlf_in_mask=im if use_nlf else None,
                    num_filters=tuple(num_filters.get(qv.var or "", ())),
                    optional_group=optional_groups.get(w, -1),
                )
            )
            est_fanout.append(_static_edge_cost(g, q, best_ei, parent))
            placed.add(w)
            global_order.append(w)

    # leftover edges (cycles whose both endpoints were placed in other comps):
    if not all(edge_used):
        for ei, used in enumerate(edge_used):
            if used:
                continue
            e = q.edges[ei]
            # attach as a non-tree check to the step of the later endpoint
            later = max(global_order.index(e.u), global_order.index(e.v))
            w = global_order[later]
            for st in steps:
                if st.u == w:
                    other = e.u if e.v == w else e.v
                    fwd = e.u == other
                    st.nontree = (*st.nontree, NTCheck(other, e.elabel, fwd,
                                                       _pvar_idx(q, e)))
                    edge_used[ei] = True
                    break
    if not all(edge_used):
        raise PlanError("internal: unassigned query edges remain")

    # start-vertex cheap numeric filters applied on host
    sv = q.vertices[start_vertex]
    if sv.var and num_filters.get(sv.var) and g.numeric_value is not None:
        vals = g.numeric_value[start_candidates]
        keep = np.ones(start_candidates.shape[0], bool)
        for op, c in num_filters[sv.var]:
            keep &= _np_cmp(vals, op, c)
        start_candidates = start_candidates[keep]

    return ExecPlan(
        query=q,
        start_vertex=start_vertex,
        start_candidates=np.sort(start_candidates).astype(np.int32),
        steps=steps,
        order=global_order,
        n_pvars=len(q.pvars),
        est_fanout=est_fanout,
    )


def _pvar_idx(q: QueryGraph, e) -> int:
    return q.pvars.index(e.pvar) if e.pvar is not None else -1


def _static_greedy_order(g, q, s, comp, adj, optional_groups) -> list[int]:
    placed = {s}
    order = [s]
    remaining = set(comp) - placed
    while remaining:
        best_w, best_cost = None, float("inf")
        for p in placed:
            for ei, w in adj[p]:
                if w not in remaining:
                    continue
                cost = _static_edge_cost(g, q, ei, p)
                cost += 1e6 * optional_groups.get(w, 0)  # optionals last
                if cost < best_cost:
                    best_cost, best_w = cost, w
        if best_w is None:
            break
        placed.add(best_w)
        remaining.discard(best_w)
        order.append(best_w)
    return order


def _np_cmp(vals: np.ndarray, op: str, c: float) -> np.ndarray:
    if op == "<":
        return vals < c
    if op == "<=":
        return vals <= c
    if op == ">":
        return vals > c
    if op == ">=":
        return vals >= c
    if op == "=":
        return vals == c
    if op == "!=":
        return vals != c
    raise ValueError(op)
