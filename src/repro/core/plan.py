"""Compatibility shim — the planner moved to :mod:`repro.core.planner`.

The ad-hoc estimators that used to live here (``_vertex_freq`` /
``_avg_fanout`` / ``_label_selectivity`` / ``_sampled_order``) became a
real cost-based optimizer layer: graph statistics in :mod:`repro.stats`
(built once per graph and cached on it), a ``CostModel`` + order search +
unified base/extension plan builder in :mod:`repro.core.planner`.  This
module re-exports the public names so existing imports keep working.
"""

from __future__ import annotations

from repro.core.planner import (ExecPlan, NTCheck, PlanError, Step,
                                build_plan, choose_start_vertex, np_cmp)
from repro.core.planner.builder import _nlf_masks  # noqa: F401 (compat)

# legacy private alias (pre-planner callers imported this name)
_np_cmp = np_cmp

__all__ = [
    "ExecPlan",
    "NTCheck",
    "PlanError",
    "Step",
    "build_plan",
    "choose_start_vertex",
    "np_cmp",
]
