"""Vectorized e-graph-homomorphism executor (the tamed TurboHOM++ core).

The paper's recursive ExploreCandidateRegion + SubgraphSearch become a
breadth-first *binding table* pipeline: a table of partial embeddings
``B int32[capacity, |V(q)|]`` is expanded one query vertex at a time along
the matching order.  Each step is a capacity-bounded ragged expansion over
CSR adjacency slices followed by vectorized filters:

  - vertex-label containment (packed-bitmap superset test),
  - ID-attribute equality (Definition 3's ID check),
  - optional NLF / degree filters (the paper's -NLF / -DEG toggles),
  - non-tree edge joins — either per-candidate binary search (the paper's
    original IsJoinable) or the bulk tile-compare path (+INT),
  - injectivity masks when running in subgraph-*isomorphism* mode
    (``semantics="iso"``) — the executor implements both semantics; e-hom
    is the RDF semantics and simply skips those masks (§2.2),
  - predicate-variable (M_e) binding and consistency for e-graph
    homomorphism (Definition 2).

Capacity management (the adaptive pipeline): each step runs at its own
power-of-two capacity from the planner's ``capacity_schedule`` (derived
from per-step cardinality estimates), so early low-cardinality steps stop
paying full-table compaction scatters.  A step whose ragged expansion
exceeds its capacity *freezes* the chunk — the surviving table is carried
through the remaining (inert) steps unchanged and the program reports the
overflowing step index — and the host re-enters the plan from exactly that
step with only that step's capacity doubled (*suffix-resume*), instead of
redoing the whole chunk.  Learned capacities persist per plan, so later
chunks start right-sized.  Results are exact — overflow never truncates.

The host loop keeps ``ExecOpts.async_chunks`` chunk programs in flight and
only reads back a chunk's ``(count, overflow_step)`` scalars after the
next chunk has been dispatched, hiding dispatch latency; with
``collect="count"`` the final step skips binding-table materialization and
nothing but scalars crosses the device→host boundary.  Steps with no
non-tree checks run through the fused Pallas expand/filter/compact kernel
(:mod:`repro.kernels.expand_filter`) where the backend supports it.

Non-tree join directions (uniform rule): for a check attached to query
vertex u with candidate v_new and earlier vertex `other` bound to other_v,
  forward  (other --el--> u):  v_new ∈ out_adj(other_v, el)
  reverse  (u --el--> other):  v_new ∈ in_adj(other_v, el)
  self-loop (u --el--> u):     v_new ∈ out_adj(v_new, el)
i.e. the probe vertex is other_v (v_new for self-loops), the search target
is always v_new, and the direction picks the out/in CSR.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field, replace
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.planner import ExecPlan, Step
from repro.core.planner.ir import _next_pow2
from repro.kernels import ops as kops
from repro.rdf.graph import LabeledGraph
from repro.resilience import faults as _faults
from repro.resilience.cancel import CancelToken, QueryCancelled
from repro.resilience.policy import (
    MAX_LEVEL,
    DegradationBreaker,
    RetryPolicy,
    degrade_opts,
    is_transient_fault,
)
from repro.utils import get_logger

log = get_logger("core.exec")

_NULL = jnp.int32(-1)


# --------------------------------------------------------------------------
# Device-resident graph
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class DeviceGraph:
    n_vertices: int
    n_elabels: int
    n_vlabels: int
    max_log_deg: int
    arrays: dict[str, jax.Array]
    host: LabeledGraph
    # per-edge-label max degree (host, for the +INT tile decision)
    max_deg_out_el: np.ndarray = field(default=None)  # type: ignore[assignment]
    max_deg_in_el: np.ndarray = field(default=None)  # type: ignore[assignment]
    # --- live-store (snapshot) mode ---------------------------------------
    # delta_mode=True: ``arrays`` holds only the *base* graph; the merged
    # label bitmap / numeric column and all delta CSRs flow in per call via
    # the step-arrays pytree, so compiled chunk programs are reused across
    # snapshots of the same base.  ``pad_vertices`` is the pow2-padded
    # vertex bound every per-vertex gather is sized/clipped to (stable
    # across snapshots until the vertex count crosses the bucket);
    # ``base_vertices``/``base_elabels`` bound the base-CSR id spaces.
    delta_mode: bool = False
    base_vertices: int = 0
    base_elabels: int = 0
    pad_vertices: int = 0

    def key(self) -> tuple:
        """Trace-relevant identity for the compiled-chunk cache.  The
        *logical* vertex count is deliberately absent in snapshot mode —
        traces only depend on the pow2-padded bound, so growing the vertex
        set inside one pad bucket keeps every compiled program."""
        return (self.delta_mode, self.pad_vertices,
                self.base_vertices, self.n_elabels, self.max_log_deg)

    @staticmethod
    def from_snapshot(snap, with_nlf: bool = False,
                      with_prune: bool = False) -> "DeviceGraph":
        """Device view of a live-store snapshot: the base graph's arrays
        (cached on the base, shared by successive snapshots) plus
        snapshot-mode metadata.  Delta arrays are NOT uploaded here — they
        are per-plan step inputs (see ``Executor._snapshot_arrays``)."""
        import dataclasses

        want = (bool(with_nlf), bool(with_prune))
        cache = getattr(snap.base, "_device_graph", None)
        if cache is None or cache[0] != want:
            base_dg = DeviceGraph.from_graph(snap.base, with_nlf=with_nlf,
                                             with_prune=with_prune)
            snap.base._device_graph = (want, base_dg)
        else:
            base_dg = cache[1]
        n_pad = _next_pow2(max(snap.n_vertices, 8))
        return dataclasses.replace(
            base_dg,
            n_vertices=snap.n_vertices,
            n_elabels=snap.n_elabels,
            max_log_deg=32,  # safe bound: merged degrees are unbounded
            delta_mode=True,
            base_vertices=snap.base.n_vertices,
            base_elabels=snap.base.n_elabels,
            pad_vertices=n_pad,
        )

    @staticmethod
    def from_graph(g: LabeledGraph, with_nlf: bool = False,
                   with_prune: bool = False) -> "DeviceGraph":
        def dev(x, dtype):
            x = np.asarray(x, dtype=dtype)
            if x.size == 0:
                x = np.zeros((1,) + x.shape[1:], dtype=dtype)
            return jnp.asarray(x)

        arrays = {
            "out_nbr_el": dev(g.out.nbr_el, np.int32),
            "in_nbr_el": dev(g.inc.nbr_el, np.int32),
            "out_indptr_all": dev(g.out.indptr_all, np.int32),
            "in_indptr_all": dev(g.inc.indptr_all, np.int32),
            "out_nbr_all": dev(g.out.nbr_all, np.int32),
            "in_nbr_all": dev(g.inc.nbr_all, np.int32),
            "out_lab_all": dev(g.out.lab_all, np.int32),
            "in_lab_all": dev(g.inc.lab_all, np.int32),
            "label_bitmap": dev(g.label_bitmap, np.uint32),
            "out_degree": dev(g.out.degree, np.int32),
            "in_degree": dev(g.inc.degree, np.int32),
        }
        if g.numeric_value is not None:
            arrays["numeric_value"] = dev(g.numeric_value, np.float32)
        if with_nlf:
            nlf_o, nlf_i = g.nlf_bitmaps()
            arrays["nlf_out"] = dev(nlf_o, np.uint32)
            arrays["nlf_in"] = dev(nlf_i, np.uint32)
        if with_prune:
            from repro.index import get_index

            sig = get_index(g).sig
            arrays["sig"] = dev(sig, np.uint32)
            # the fused expand/filter/compact kernel is width-generic in the
            # bitmap, so composing the signature probe with the label filter
            # is just a wider bitmap (labels ++ signature) and a combined mask
            arrays["filter_bitmap"] = dev(
                np.hstack([g.label_bitmap, sig]), np.uint32)
        max_deg = int(max(g.out.degree.max(initial=1), g.inc.degree.max(initial=1)))
        # one vectorized diff+reduce over the stacked [n_elabels, V+1] indptr
        mdo = (np.max(np.diff(g.out.indptr_el, axis=1), axis=1, initial=0)
               if g.n_elabels else np.zeros(0, np.int64))
        mdi = (np.max(np.diff(g.inc.indptr_el, axis=1), axis=1, initial=0)
               if g.n_elabels else np.zeros(0, np.int64))
        return DeviceGraph(
            n_vertices=g.n_vertices,
            n_elabels=g.n_elabels,
            n_vlabels=g.n_vlabels,
            max_log_deg=max(2, int(np.ceil(np.log2(max(2, max_deg)))) + 1),
            arrays=arrays,
            host=g,
            max_deg_out_el=mdo,
            max_deg_in_el=mdi,
            base_vertices=g.n_vertices,
            base_elabels=g.n_elabels,
            pad_vertices=g.n_vertices,
        )


# --------------------------------------------------------------------------
# Options / results
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ExecOpts:
    semantics: str = "hom"  # "hom" (RDF) or "iso" (classical subgraph iso)
    use_int: bool = True  # +INT: bulk tile-compare joins where tiles fit
    use_nlf: bool = False  # paper default: disabled (-NLF)
    use_deg: bool = False  # paper default: disabled (-DEG)
    reuse_order: bool = True  # +REUSE
    int_tile: int = 128  # adjacency tile bound for the +INT path
    chunk: int = 8192  # starting vertices per chunk (§Perf: 2-3.7× over 1k on heavy queries)
    init_cap: int = 4096
    max_cap: int = 1 << 22
    # --- adaptive pipeline toggles (all False/1 ≈ the legacy executor) ---
    cap_schedule: bool = True  # per-step capacity schedule from the planner
    suffix_resume: bool = True  # overflow resumes from the overflowing step
    async_chunks: int = 2  # chunk programs kept in flight before readback
    use_fused: bool = True  # fused expand/filter/compact kernel fast path
    cap_slack: float = 1.0  # schedule headroom (pow2 rounding adds ~1.5x already)
    use_prune: bool = True  # neighborhood-signature pruning (repro.index)
    profile: bool = False  # per-step wall-time stats (adds host syncs)
    # absolute time.monotonic() deadline; checked between chunk dispatches
    # and suffix-resume re-entries (None = no deadline).  Deliberately
    # excluded from key(): deadlines never affect compiled programs.
    deadline: float | None = None

    def key(self) -> tuple:
        return (self.semantics, self.use_int, self.use_nlf, self.use_deg,
                self.int_tile, self.use_fused, self.use_prune)


@dataclass
class Result:
    count: int
    bindings: np.ndarray | None  # int32 [count, |V(q)|] (None if count-only)
    pvar_bindings: np.ndarray | None  # int32 [count, n_pvars]
    origins: np.ndarray | None = None  # source-row ids (for extension runs)
    chunks_retried: int = 0
    stats: dict[str, Any] = field(default_factory=dict)


# --------------------------------------------------------------------------
# Step arrays: per-plan device constants
# --------------------------------------------------------------------------


def _label_mask(g: LabeledGraph, labels: tuple[int, ...]) -> np.ndarray:
    n_words = g.label_bitmap.shape[1]
    mask = np.zeros(n_words, dtype=np.uint32)
    for lbl in labels:
        mask[lbl >> 5] |= np.uint32(1 << (lbl & 31))
    return mask


def _plan_arrays(g: LabeledGraph, plan: ExecPlan,
                 use_prune: bool = False) -> list[dict[str, jax.Array]]:
    """Per-step device constants: CSR indptr rows, label masks, etc."""
    out: list[dict[str, jax.Array]] = []
    flat_out = flat_in = None
    if any(c.pvar_idx >= 0 for s in plan.steps for c in s.nontree):
        flat_out = jnp.asarray(g.out.indptr_el.reshape(-1), dtype=jnp.int32)
        flat_in = jnp.asarray(g.inc.indptr_el.reshape(-1), dtype=jnp.int32)
    for s in plan.steps:
        d: dict[str, jax.Array] = {}
        if s.restart_candidates is not None:
            cands = s.restart_candidates.astype(np.int32)
            d["restart"] = jnp.asarray(cands if cands.size else np.zeros(1, np.int32))
            d["restart_n"] = jnp.int32(cands.size)
        elif s.elabel >= 0:
            dirn = g.out if s.forward else g.inc
            d["iptr"] = jnp.asarray(dirn.indptr_el[s.elabel], dtype=jnp.int32)
        if s.labels:
            d["label_mask"] = jnp.asarray(_label_mask(g, s.labels))
        if use_prune and s.sig_mask is not None \
                and s.restart_candidates is None:
            # restart steps carry pre-pruned candidate arrays; tree steps
            # probe on device.  ``fmask`` = labels ++ signature drives the
            # fused kernel's single combined superset test.
            d["sig_mask"] = jnp.asarray(s.sig_mask)
            lm = _label_mask(g, s.labels) if s.labels else \
                np.zeros(g.label_bitmap.shape[1], np.uint32)
            d["fmask"] = jnp.asarray(np.concatenate([lm, s.sig_mask]))
        if s.nlf_out_mask is not None:
            d["nlf_out_mask"] = jnp.asarray(s.nlf_out_mask)
            d["nlf_in_mask"] = jnp.asarray(s.nlf_in_mask)
        for ci, c in enumerate(s.nontree):
            use_out = c.forward or c.self_loop
            if c.pvar_idx >= 0:
                d[f"nt{ci}_flat"] = flat_out if use_out else flat_in
            else:
                dirn = g.out if use_out else g.inc
                d[f"nt{ci}_iptr"] = jnp.asarray(dirn.indptr_el[c.elabel],
                                                dtype=jnp.int32)
        out.append(d)
    return out


# --------------------------------------------------------------------------
# The compiled chunk program
# --------------------------------------------------------------------------


def _pad_rows(x: jax.Array, rows: int) -> jax.Array:
    """Pad a table/vector along axis 0 with nulls up to ``rows``."""
    pad = rows - x.shape[0]
    if pad <= 0:
        return x
    width = ((0, pad),) + ((0, 0),) * (x.ndim - 1)
    return jnp.pad(x, width, constant_values=-1)


def _nontree_mask(dg: DeviceGraph, step: Step, sarr, b_rows, p_rows, v_new,
                  opts: ExecOpts) -> jax.Array:
    n = dg.pad_vertices if dg.delta_mode else dg.n_vertices
    ok = jnp.ones(v_new.shape[0], dtype=bool)
    for ci, c in enumerate(step.nontree):
        use_out = c.forward or c.self_loop
        nbr = dg.arrays["out_nbr_el" if use_out else "in_nbr_el"]
        probe = v_new if c.self_loop else b_rows[:, c.other]
        psafe = jnp.clip(probe, 0, n - 1)
        if c.pvar_idx >= 0:
            el_raw = p_rows[:, c.pvar_idx]
            bound_ok = el_raw >= 0
            if dg.delta_mode:
                # base flat tables cover the base id spaces only; probes or
                # labels born in the delta have no base edges by definition
                in_base = (probe < jnp.int32(dg.base_vertices)) & \
                    (el_raw < jnp.int32(dg.base_elabels))
                pb = jnp.clip(probe, 0, dg.base_vertices - 1)
                el_b = jnp.clip(el_raw, 0, dg.base_elabels - 1)
                flat = sarr[f"nt{ci}_flat"]
                bi = el_b * jnp.int32(dg.base_vertices + 1) + pb
                found = kops.edge_exists(nbr, flat[bi], flat[bi + 1], v_new,
                                         n_iters=dg.max_log_deg) & in_base
                el_m = jnp.clip(el_raw, 0, dg.n_elabels - 1)
                fi = el_m * jnp.int32(n + 1) + psafe
                tf = sarr.get(f"nt{ci}_t_flat_iptr")
                if tf is not None:
                    dead = kops.edge_exists(
                        sarr[f"nt{ci}_t_flat_nbr"], tf[fi], tf[fi + 1],
                        v_new, n_iters=dg.max_log_deg)
                    found &= ~dead
                df = sarr.get(f"nt{ci}_d_flat_iptr")
                if df is not None:
                    found |= kops.edge_exists(
                        sarr[f"nt{ci}_d_flat_nbr"], df[fi], df[fi + 1],
                        v_new, n_iters=dg.max_log_deg)
            else:
                flat = sarr[f"nt{ci}_flat"]
                el_dyn = jnp.clip(el_raw, 0, dg.n_elabels - 1)
                base = el_dyn * jnp.int32(n + 1)
                lo = flat[base + psafe]
                hi = flat[base + psafe + 1]
                found = kops.edge_exists(nbr, lo, hi, v_new,
                                         n_iters=dg.max_log_deg)
            ok &= found & bound_ok
            continue
        iptr = sarr[f"nt{ci}_iptr"]
        lo = iptr[psafe]
        hi = iptr[psafe + 1]
        if dg.delta_mode:
            # base membership (padded rows: zero-degree past the base id
            # spaces), minus tombstones, plus delta inserts — +INT tiles
            # only cover the base CSR, so dirty labels use the search path
            found = kops.edge_exists(nbr, lo, hi, v_new,
                                     n_iters=dg.max_log_deg)
            ti = sarr.get(f"nt{ci}_t_iptr")
            if ti is not None:
                dead = kops.edge_exists(sarr[f"nt{ci}_t_nbr"], ti[psafe],
                                        ti[psafe + 1], v_new,
                                        n_iters=dg.max_log_deg)
                found &= ~dead
            di = sarr.get(f"nt{ci}_d_iptr")
            if di is not None:
                found |= kops.edge_exists(sarr[f"nt{ci}_d_nbr"], di[psafe],
                                          di[psafe + 1], v_new,
                                          n_iters=dg.max_log_deg)
            ok &= found
            continue
        max_deg = int(
            (dg.max_deg_out_el if use_out else dg.max_deg_in_el)[c.elabel]
        )
        if opts.use_int and 0 < max_deg <= opts.int_tile:
            # +INT: bulk membership via tiled compare-all in VMEM.  Gather the
            # probe side's full adjacency tile (bounded by int_tile) and test
            # all candidates of this step against it at once.
            tb = _next_pow2(max(8, max_deg))
            pos = lo[:, None] + jnp.arange(tb, dtype=jnp.int32)[None, :]
            in_range = pos < hi[:, None]
            adj_tile = jnp.where(
                in_range, nbr[jnp.clip(pos, 0, nbr.shape[0] - 1)], -2
            )
            found = kops.tile_membership(v_new[:, None], adj_tile)[:, 0]
        else:
            found = kops.edge_exists(nbr, lo, hi, v_new, n_iters=dg.max_log_deg)
        ok &= found
    return ok


def _fused_eligible(step: Step, opts: ExecOpts) -> bool:
    """Steps the fused expand/filter/compact kernel covers: a tree edge (or
    restart) whose only filters are the label bitmap and a bound ID."""
    return (opts.use_fused and not step.nontree and opts.semantics == "hom"
            and step.pvar_idx < 0 and not step.num_filters
            and not step.min_out_ntypes and not step.min_in_ntypes
            and step.nlf_out_mask is None)


def build_chunk_fn(dg: DeviceGraph, plan: ExecPlan, caps: tuple[int, ...],
                   n_in: int, opts: ExecOpts, table_input: bool,
                   collect: str = "bindings", start_step: int = 0,
                   stop_step: int | None = None):
    """Build the jittable chunk program for plan steps ``[start_step,
    stop_step)`` with the per-step capacity schedule ``caps``.

    ``table_input=False``: the input is a vector of start-vertex candidates
    (``n_in`` wide) and the program seeds the binding table from it.
    ``table_input=True``: the input is ``(B0, count, P0, origins)`` rows of
    capacity ``n_in`` — OPTIONAL left-join extensions and suffix-resume
    re-entries both use this form.

    Overflow semantics: the first step whose ragged expansion total exceeds
    its capacity *freezes* the table — every later step passes it through
    unchanged — and the returned ``ovf_step`` names that step (``len(steps)``
    = completed).  The frozen table is exactly the input the overflowing
    step needs on re-entry, so the host resumes from there with only that
    step's capacity doubled.  ``caps`` must be monotone non-decreasing from
    ``n_in`` so the freeze carry is lossless.

    With ``collect="count"`` the final step only tallies survivors: no
    compacted binding table is materialized for it and only scalars need to
    cross back to the host.

    Returns ``(b, p, org, count, ovf_step, totals, kepts, pins, pouts)``
    where ``totals``/``kepts`` hold each executed step's expansion total and
    surviving-row count (``-1`` once frozen / not executed) and
    ``pins``/``pouts`` the signature-prune probe's candidates in/out
    (``-1`` when the step has no probe).

    ``params`` (int32 ``[plan.n_params]``, empty for fully baked plans) is a
    traced input: steps with ``param_slot >= 0`` check the new binding
    against ``params[slot]`` instead of the baked ``bound_id``, so one
    compiled program serves every constant instantiation of the shape — and
    ``jax.vmap`` over the params axis answers a whole batch per launch.
    """
    nq = plan.query.n_vertices
    npv = max(1, plan.n_pvars)
    steps = plan.steps
    n_steps = len(steps)
    stop = n_steps if stop_step is None else stop_step
    dmode = dg.delta_mode
    has_numeric = "numeric_value" in dg.arrays
    n = dg.pad_vertices if dmode else dg.n_vertices
    for si in range(start_step, stop):
        prev = n_in if si == start_step else caps[si - 1]
        if caps[si] < prev:
            raise ValueError("capacity schedule must be monotone "
                             f"non-decreasing (step {si}: {caps[si]} < {prev})")

    def fn(chunk, chunk_count, p_init, org_init, params, sarrs):
        if not table_input:
            b = jnp.full((n_in, nq), _NULL, dtype=jnp.int32)
            b = b.at[:, plan.start_vertex].set(chunk)
            p = jnp.full((n_in, npv), _NULL, dtype=jnp.int32)
            org = jnp.arange(n_in, dtype=jnp.int32)
            count = jnp.minimum(chunk_count, n_in).astype(jnp.int32)
        else:
            b, p, org = chunk, p_init, org_init
            count = chunk_count.astype(jnp.int32)

        ovf_step = jnp.int32(n_steps)  # sentinel: completed
        totals: list[jax.Array] = []
        kepts: list[jax.Array] = []
        pins: list[jax.Array] = []
        pouts: list[jax.Array] = []
        cap_prev = n_in
        for si in range(start_step, stop):
            step = steps[si]
            sarr = sarrs[si]
            cap = caps[si]
            active = ovf_step == jnp.int32(n_steps)
            alive = jnp.arange(cap_prev, dtype=jnp.int32) < count

            # delta overlay per-step inputs (snapshot mode only; the step
            # arrays pytree carries them so jit retraces exactly when a
            # label's delta appears or vanishes)
            d_iptr = sarr.get("d_iptr") if dmode else None
            t_iptr = sarr.get("t_iptr") if dmode else None
            start_d = deg_b = t_lo = t_hi = None
            if step.restart_candidates is not None:
                k_cands = int(sarr["restart"].shape[0])
                deg = jnp.where(alive, sarr["restart_n"], 0)
                nbr_src = sarr["restart"]
                start = jnp.zeros(cap_prev, dtype=jnp.int32)
                deg_bound = k_cands
                d_iptr = t_iptr = None
            elif step.elabel >= 0:
                iptr = sarr["iptr"]
                vp = jnp.clip(b[:, step.parent], 0, n - 1)
                start = iptr[vp]
                deg_b = iptr[vp + 1] - start
                deg = deg_b
                if d_iptr is not None:
                    start_d = d_iptr[vp]
                    deg = deg + (d_iptr[vp + 1] - start_d)
                if t_iptr is not None:
                    t_lo, t_hi = t_iptr[vp], t_iptr[vp + 1]
                deg = jnp.where(alive, deg, 0)
                nbr_src = dg.arrays["out_nbr_el" if step.forward else "in_nbr_el"]
                deg_bound = int(
                    (dg.max_deg_out_el if step.forward
                     else dg.max_deg_in_el)[step.elabel]) \
                    if step.elabel < dg.base_elabels else 0
            else:  # predicate variable: plain CSR
                iptr = sarr["all_iptr"] if dmode else \
                    dg.arrays["out_indptr_all" if step.forward
                              else "in_indptr_all"]
                vp = jnp.clip(b[:, step.parent], 0, n - 1)
                start = iptr[vp]
                deg_b = iptr[vp + 1] - start
                deg = deg_b
                if d_iptr is not None:
                    start_d = d_iptr[vp]
                    deg = deg + (d_iptr[vp + 1] - start_d)
                if t_iptr is not None:
                    t_lo, t_hi = t_iptr[vp], t_iptr[vp + 1]
                deg = jnp.where(alive, deg, 0)
                nbr_src = dg.arrays["out_nbr_all" if step.forward
                                    else "in_nbr_all"]
                deg_bound = 1 << dg.max_log_deg

            merged = d_iptr is not None or t_iptr is not None
            coffs = jnp.cumsum(deg.astype(jnp.int32))
            total = coffs[-1]
            offs = (coffs - deg).astype(jnp.int32)
            ovf_here = total > cap
            if dmode or cap_prev * max(1, deg_bound) >= 2**31:
                # the int32 prefix sums can wrap; redo the *total* in a wide
                # dtype (int64 with x64 enabled, else float32 — exact enough
                # for a compare against cap <= 2**22) so a wrapped cumsum is
                # still reported as overflow instead of silent truncation.
                wide = jnp.int64 if jax.config.jax_enable_x64 else jnp.float32
                total_w = jnp.sum(deg.astype(wide))
                ovf_here = ovf_here | (total < 0) | (total_w > cap)
            ovf_here = active & ovf_here
            keep_new = active & ~ovf_here
            ovf_step = jnp.where(ovf_here, jnp.int32(si), ovf_step)
            count_only = collect == "count" and si == n_steps - 1

            bitmap_src = (sarr.get("bitmap") if dmode
                          else dg.arrays["label_bitmap"])
            p_in = p_out = None
            if _fused_eligible(step, opts) and not count_only and not merged:
                fmask = sarr.get("fmask")
                fb_src = (sarr.get("filter_bitmap") if dmode
                          else dg.arrays.get("filter_bitmap")) \
                    if fmask is not None else None
                if fmask is not None and fb_src is not None:
                    # composed label + signature probe: one superset test
                    # over the widened (labels ++ signature) bitmap
                    filt_bitmap, filt_mask = fb_src, fmask
                    p_in, p_out = total, None  # p_out = kept, set below
                else:
                    filt_bitmap = bitmap_src
                    filt_mask = sarr.get("label_mask")
                    if filt_mask is None:
                        filt_mask = jnp.zeros(
                            (bitmap_src.shape[1],), jnp.uint32)
                bid = (params[step.param_slot] if step.param_slot >= 0
                       else jnp.int32(step.bound_id))
                v_out, row_sel, kept = kops.expand_filter_compact(
                    nbr_src, filt_bitmap, start, deg, offs,
                    filt_mask, bid, cap)
                if p_in is not None:
                    p_out = kept
                # gather-based table build: when frozen, the identity index
                # carries the old table through at zero extra cost
                idg = jnp.where(
                    keep_new,
                    jnp.clip(row_sel, 0, cap_prev - 1),
                    jnp.minimum(jnp.arange(cap, dtype=jnp.int32), cap_prev - 1))
                nb = b[idg]
                nb = nb.at[:, step.u].set(
                    jnp.where(keep_new, v_out, nb[:, step.u]))
                b, p, org = nb, p[idg], org[idg]
                count = jnp.where(keep_new, kept, count)
            else:
                row, j, valid = kops.ragged_expand(offs, deg, cap)
                el_new = None
                if merged:
                    # live store: position j < deg_b reads the base CSR
                    # (minus tombstones), later positions read the delta
                    sb = start[row]
                    db = deg_b[row]
                    sd = start_d[row] if start_d is not None else \
                        jnp.zeros_like(row)
                    tl = t_lo[row] if t_lo is not None else \
                        jnp.zeros_like(row)
                    th = t_hi[row] if t_hi is not None else \
                        jnp.zeros_like(row)
                    dummy = jnp.full(1, -1, jnp.int32)
                    d_nbr = sarr.get("d_nbr", dummy)
                    if step.elabel >= 0:
                        v_new, ok = kops.delta_merge(
                            nbr_src, d_nbr, sarr.get("t_nbr", dummy),
                            sb, db, sd, tl, th, j, valid,
                            n_iters=dg.max_log_deg)
                    else:
                        lab_src = dg.arrays["out_lab_all" if step.forward
                                            else "in_lab_all"]
                        v_new, el_new, ok = kops.delta_merge_labeled(
                            nbr_src, lab_src, d_nbr,
                            sarr.get("d_lab", dummy),
                            sarr.get("t_key", dummy),
                            sb, db, sd, tl, th, j, valid,
                            n_elabels=dg.n_elabels,
                            n_iters=dg.max_log_deg)
                else:
                    idx = jnp.clip(start[row] + j, 0, nbr_src.shape[0] - 1)
                    v_new = jnp.where(valid, nbr_src[idx], _NULL)
                    ok = valid

                b_rows = b[row]
                p_rows = p[row]
                org_rows = org[row]
                b_rows = b_rows.at[:, step.u].set(v_new)

                if step.pvar_idx >= 0:  # tree-edge M_e binding
                    if el_new is None:
                        lab_src = dg.arrays["out_lab_all" if step.forward
                                            else "in_lab_all"]
                        el_new = jnp.where(valid, lab_src[idx], _NULL)
                    prev = p_rows[:, step.pvar_idx]
                    ok &= (prev < 0) | (prev == el_new)
                    p_rows = p_rows.at[:, step.pvar_idx].set(
                        jnp.where(prev < 0, el_new, prev))
                if step.param_slot >= 0:
                    ok &= v_new == params[step.param_slot]
                elif step.bound_id >= 0:
                    ok &= v_new == jnp.int32(step.bound_id)
                if "label_mask" in sarr:
                    bm = bitmap_src[jnp.clip(v_new, 0, n - 1)]
                    ok &= kops.bitmap_superset(bm, sarr["label_mask"])
                sig_mask = sarr.get("sig_mask")
                sig_src = (sarr.get("sig") if dmode
                           else dg.arrays.get("sig")) \
                    if sig_mask is not None else None
                if sig_src is not None:
                    p_in = jnp.sum(ok.astype(jnp.int32))
                    ok &= kops.signature_filter(
                        sig_src, jnp.clip(v_new, 0, n - 1), sig_mask)
                    p_out = jnp.sum(ok.astype(jnp.int32))
                if (step.min_out_ntypes or step.min_in_ntypes) and not dmode:
                    # degree/NLF prunes use base-build summaries; they are
                    # not maintained across deltas, so snapshot execution
                    # skips them (they are pure optimizations)
                    safe = jnp.clip(v_new, 0, n - 1)
                    ok &= dg.arrays["out_degree"][safe] >= jnp.int32(
                        step.min_out_ntypes)
                    ok &= dg.arrays["in_degree"][safe] >= jnp.int32(
                        step.min_in_ntypes)
                if "nlf_out_mask" in sarr and "nlf_out" in dg.arrays \
                        and not dmode:
                    safe = jnp.clip(v_new, 0, n - 1)
                    ok &= kops.bitmap_superset(dg.arrays["nlf_out"][safe],
                                               sarr["nlf_out_mask"])
                    ok &= kops.bitmap_superset(dg.arrays["nlf_in"][safe],
                                               sarr["nlf_in_mask"])
                if step.num_filters:
                    num_src = sarr.get("numeric") if dmode else (
                        dg.arrays["numeric_value"] if has_numeric else None)
                    if num_src is not None:
                        vals = num_src[jnp.clip(v_new, 0, n - 1)]
                        for op, cval in step.num_filters:
                            ok &= _jnp_cmp(vals, op, cval)
                if opts.semantics == "iso":
                    for w in plan.order:
                        if w == step.u:
                            break
                        ok &= b_rows[:, w] != v_new
                if step.nontree:
                    ok &= _nontree_mask(dg, step, sarr, b_rows, p_rows, v_new,
                                        opts)

                kept = jnp.sum(ok.astype(jnp.int32))
                if count_only:
                    # final tally only: carry the (possibly frozen) table —
                    # no compacted binding table is materialized
                    b = _pad_rows(b, cap)
                    p = _pad_rows(p, cap)
                    org = _pad_rows(org, cap)
                    count = jnp.where(keep_new, kept, count)
                else:
                    pos = jnp.where(ok, jnp.cumsum(ok.astype(jnp.int32)) - 1,
                                    cap)
                    pos = jnp.where(keep_new, pos, cap)  # frozen: drop all
                    # scatter into the padded previous table: rows the
                    # scatter misses keep stale values, but those sit beyond
                    # ``count`` and every consumer masks on it — and when
                    # frozen the untouched pad IS the carried table
                    b = _pad_rows(b, cap + 1).at[pos].set(b_rows)[:cap]
                    p = _pad_rows(p, cap + 1).at[pos].set(p_rows)[:cap]
                    org = _pad_rows(org, cap + 1).at[pos].set(org_rows)[:cap]
                    count = jnp.where(keep_new, kept, count)

            totals.append(jnp.where(active, total, jnp.int32(-1)))
            kepts.append(jnp.where(keep_new, count, jnp.int32(-1)))
            if p_in is None:
                pins.append(jnp.int32(-1))
                pouts.append(jnp.int32(-1))
            else:
                pins.append(jnp.where(active, p_in, jnp.int32(-1)))
                pouts.append(jnp.where(keep_new, p_out, jnp.int32(-1)))
            cap_prev = cap

        z = jnp.zeros(0, jnp.int32)
        return (b, p, org, count, ovf_step,
                jnp.stack(totals) if totals else z,
                jnp.stack(kepts) if kepts else z,
                jnp.stack(pins) if pins else z,
                jnp.stack(pouts) if pouts else z)

    return fn


def _jnp_cmp(vals, op: str, c: float):
    c = jnp.float32(c)
    if op == "<":
        return vals < c
    if op == "<=":
        return vals <= c
    if op == ">":
        return vals > c
    if op == ">=":
        return vals >= c
    if op == "=":
        return vals == c
    if op == "!=":
        return vals != c
    raise ValueError(op)


# --------------------------------------------------------------------------
# Host-level executor
# --------------------------------------------------------------------------


def _grow_caps(caps: list[int], si: int, max_cap: int) -> list[int]:
    """Double step ``si``'s capacity after an overflow (raising once it is
    already at ``max_cap``) and restore monotonicity for later steps.
    Mutates and returns ``caps`` — the single overflow-retry policy shared
    by the async drain and the profiled per-step path."""
    if caps[si] >= max_cap:
        raise RuntimeError(
            f"binding-table overflow at max capacity {max_cap};"
            " raise ExecOpts.max_cap")
    caps[si] = min(max_cap, caps[si] * 2)
    for j in range(si + 1, len(caps)):
        caps[j] = max(caps[j], caps[si])
    return caps


_SMALL_PLAN_ROWS = 512.0
_SMALL_PLAN_STEPS = 6


def _small_plan(plan: ExecPlan, opts: ExecOpts) -> bool:
    """Is this plan a *candidate* for skipping the pipelined machinery?
    For B1-class point lookups the per-step capacity schedule,
    fused-kernel setup and async bookkeeping cost more than they save —
    the legacy single-shot configuration is faster.  Planner estimates
    alone cannot make the call (B1 and B8 are estimate-twins but land on
    opposite sides), so this gate only shortlists: a tiny expected result,
    few steps, no estimated intermediate blow-up, and a start set that
    fits one chunk.  The executor settles shortlisted plans with a
    one-time timed probe of both configurations (``_small_mode``)."""
    if not (opts.cap_schedule or opts.use_fused or opts.suffix_resume):
        return False  # already running the legacy configuration
    if not plan.steps or len(plan.steps) > _SMALL_PLAN_STEPS:
        return False
    if plan.start_candidates.shape[0] > opts.chunk:
        return False
    peak = max(plan.est_rows, default=plan.estimated_rows())
    return (plan.estimated_rows() <= _SMALL_PLAN_ROWS
            and peak <= 4 * _SMALL_PLAN_ROWS)


def _empty_stats(n_steps: int) -> dict[str, Any]:
    return {
        "step_rows": [0] * n_steps,
        "step_kept": [0] * n_steps,
        "step_retries": [0] * n_steps,
        "step_prune_in": [0] * n_steps,
        "step_prune_out": [0] * n_steps,
        "step_wall_ms": None,
        "caps": [],
        "chunks": 0,
        "resumes": 0,
        "compiles": 0,
        "wall_ms": 0.0,
    }


def _step_kernel_name(dg: DeviceGraph, step: Step, sarr: dict,
                      opts: ExecOpts, count_only: bool) -> str:
    """Which kernel a step actually runs through — mirrors the dispatch
    logic in ``build_chunk_fn`` (fused fast path vs. legacy ragged expand
    vs. live-store delta merge)."""
    merged = dg.delta_mode and ("d_iptr" in sarr or "t_iptr" in sarr)
    if merged:
        return "delta_merge" if step.elabel >= 0 else "delta_merge_labeled"
    if _fused_eligible(step, opts) and not count_only:
        return "expand_filter"
    return "ragged_expand"


def _annotate_step_spans(trace, plan: ExecPlan, dg: DeviceGraph, sarrs,
                         opts: ExecOpts, stats: dict, collect: str,
                         n_src: int) -> None:
    """Attach one summary span per plan step: executed-counter meta
    (rows/kept/retries/capacity), the kernel that ran, and a roofline
    estimate next to the measured wall time (profiled runs only have real
    per-step durations; sampled traces report zero-duration spans)."""
    try:
        from repro.analysis.roofline import estimate_step_ms
    except Exception:  # pragma: no cover - annotation must never fail a run
        estimate_step_ms = None
    backend = jax.default_backend()
    nq = plan.query.n_vertices
    bitmap_words = int(dg.arrays["label_bitmap"].shape[1])
    wall = stats.get("step_wall_ms")
    caps = stats.get("caps") or []
    rows_in = float(n_src)
    for si, step in enumerate(plan.steps):
        count_only = collect == "count" and si == len(plan.steps) - 1
        kernel = _step_kernel_name(dg, step, sarrs[si], opts, count_only)
        expanded = stats["step_rows"][si]
        kept = stats["step_kept"][si]
        cap = int(caps[si]) if si < len(caps) else 0
        meta: dict[str, Any] = {
            "step": si, "kernel": kernel, "rows": expanded, "kept": kept,
            "retries": stats["step_retries"][si], "capacity": cap,
        }
        if step.sig_mask is not None:
            p_in = stats["step_prune_in"][si]
            meta["prune_in"] = p_in
            meta["prune_out"] = stats["step_prune_out"][si]
            if p_in:
                meta["prune_ratio"] = round(
                    stats["step_prune_out"][si] / p_in, 4)
        if step.nontree:
            meta["nontree_checks"] = len(step.nontree)
        if estimate_step_ms is not None:
            est = estimate_step_ms(
                kernel, backend=backend, expanded=expanded, rows=rows_in,
                capacity=cap, nq=nq, bitmap_words=bitmap_words,
                n_iters=dg.max_log_deg)
            model_ms = est["model_ms"]
            for _ in step.nontree:
                model_ms += estimate_step_ms(
                    "edge_exists", backend=backend, expanded=expanded,
                    n_iters=dg.max_log_deg)["model_ms"]
            meta["model_ms"] = round(model_ms, 6)
            meta["model_dominant"] = est["dominant"]
        dur_s = (wall[si] / 1e3) if wall is not None else 0.0
        trace.add("step", dur_s, **meta)
        rows_in = float(kept)


class Executor:
    """Chunked plan executor: per-step capacity schedule, suffix-resume on
    overflow, double-buffered async chunk dispatch, compile cache.

    ``g`` may be a plain :class:`LabeledGraph` or a live-store
    :class:`~repro.store.versioned.Snapshot`.  In snapshot mode the base
    graph's device arrays are shared across snapshots, delta CSRs flow in
    per call through the step-arrays pytree (so compiled chunk programs
    survive updates), and start / restart candidate sets are re-resolved
    against the current snapshot — which also makes *cached plans* built
    against an older version execute correctly."""

    def __init__(self, g, opts: ExecOpts | None = None, *,
                 policy: RetryPolicy | None = None,
                 breaker: DegradationBreaker | None = None):
        self.opts = opts or ExecOpts()
        # transient-fault policy + per-plan-signature degradation breaker;
        # callers rebuilding an executor (e.g. engine compaction) pass the
        # old instances through so learned degradations survive
        self._policy = policy or RetryPolicy.from_env()
        self._breaker = breaker or DegradationBreaker(
            cooldown_s=self._policy.cooldown_s)
        self._res_counters = {"degraded_runs": 0, "fault_retries": 0,
                              "escalations": 0}
        if getattr(g, "is_snapshot", False):
            view = g
            self.graph = g.base
            dg = DeviceGraph.from_snapshot(g, with_nlf=self.opts.use_nlf,
                                           with_prune=self.opts.use_prune)
        else:
            view = None
            self.graph = g
            dg = DeviceGraph.from_graph(g, with_nlf=self.opts.use_nlf,
                                        with_prune=self.opts.use_prune)
        # (view, dg) swap together atomically (single tuple assignment), so
        # a query that pinned the pair mid-update stays internally
        # consistent; ``view``/``dg`` attributes mirror the latest state
        self._state: tuple[Any, DeviceGraph] = (view, dg)
        self._compiled: dict[tuple, Any] = {}
        self._plan_arrays_cache: dict[int, list[dict[str, jax.Array]]] = {}
        # learned per-plan capacity schedules (overflow doublings persist,
        # so later chunks / queries start right-sized)
        self._caps_cache: dict[tuple, list[int]] = {}
        # learned pipelined-vs-legacy choice for small plans (see
        # _small_plan): True = legacy single-shot config wins for this
        # plan signature
        self._small_mode: dict[tuple, bool] = {}

    @property
    def view(self):
        return self._state[0]

    @property
    def dg(self) -> DeviceGraph:
        return self._state[1]

    def pin(self) -> tuple[Any, DeviceGraph]:
        """Capture the current (view, dg) pair.  Callers composing several
        ``run`` calls into one logical query pass it to each so concurrent
        ``set_snapshot`` swaps cannot tear the query across versions."""
        return self._state

    def set_snapshot(self, snap) -> None:
        """Swap to a newer snapshot of the *same* base graph (post-update).
        Compiled chunk programs are reused: only the pytree of delta/step
        arrays changes, and jit retraces exactly when shapes/structure do.
        In-flight queries keep executing against the state they pinned."""
        if self.view is None or snap.base is not self.graph:
            raise ValueError("snapshot has a different base graph; "
                             "build a new Executor")
        self._state = (snap,
                       DeviceGraph.from_snapshot(
                           snap, with_nlf=self.opts.use_nlf,
                           with_prune=self.opts.use_prune))

    @property
    def policy(self) -> RetryPolicy:
        return self._policy

    @property
    def breaker(self) -> DegradationBreaker:
        return self._breaker

    def resilience_snapshot(self) -> dict:
        """Breaker state + fault counters, for /healthz and gauges."""
        d = self._breaker.snapshot()
        d.update(self._res_counters)
        return d

    def _get_fn(self, plan: ExecPlan, caps: tuple[int, ...], n_in: int,
                table_input: bool, collect: str, start: int, stop: int,
                dg: DeviceGraph | None = None, opts: ExecOpts | None = None):
        dg = self.dg if dg is None else dg
        opts = self.opts if opts is None else opts
        # key on the [start, stop) capacity window only: suffix programs
        # that differ in capacities of steps they never execute are
        # byte-identical and must share one compile
        key = (plan.signature(), caps[start:stop], n_in, table_input,
               collect, start, stop, opts.key(), dg.key())
        fn = self._compiled.get(key)
        fresh = fn is None
        if fresh:
            _faults.fire("compile")
            raw = build_chunk_fn(dg, plan, caps, n_in, opts,
                                 table_input, collect, start, stop)
            out_cap = caps[stop - 1] if stop > start else n_in
            donate = ()
            if (table_input and start > 0 and out_cap == n_in
                    and jax.default_backend() in ("tpu", "gpu")):
                # steady-state resume dispatches reuse the binding-table
                # buffers in place (donation is a no-op on CPU).  Initial
                # whole-chunk dispatches are excluded: legacy retry re-feeds
                # the same host args, which donation would invalidate.
                donate = (0, 2, 3)
            fn = jax.jit(raw, donate_argnums=donate)
            self._compiled[key] = fn
        # freshness is returned (not kept on self) so concurrent runs on a
        # shared executor each see their own compile events
        return fn, fresh

    def _arrays(self, plan: ExecPlan,
                state: tuple | None = None) -> list[dict[str, jax.Array]]:
        view, dg = state if state is not None else self._state
        if view is not None:
            return self._snapshot_arrays(plan, view, dg)
        # cache on the plan object itself (an id()-keyed dict can collide
        # when a dead plan's id is recycled by the allocator)
        use_prune = self.opts.use_prune
        cached = getattr(plan, "_dev_arrays", None)
        if cached is not None and cached[0] is self.graph \
                and cached[1] == use_prune:
            return cached[2]
        arrs = _plan_arrays(self.graph, plan, use_prune)
        plan._dev_arrays = (self.graph, use_prune, arrs)  # type: ignore[attr-defined]
        return arrs

    def _snapshot_arrays(self, plan: ExecPlan, snap,
                         dg: DeviceGraph) -> list[dict[str, jax.Array]]:
        """Per-step device constants for snapshot execution: padded base
        CSR rows, the snapshot's delta/tombstone CSRs, merged label bitmap
        and numeric column, and freshly resolved restart candidates."""
        from repro.core.planner.cost import CostModel

        use_prune = self.opts.use_prune
        token = (snap.token(), use_prune)
        cached = getattr(plan, "_dev_arrays_snap", None)
        if cached is not None and cached[0] == token:
            return cached[1]
        _faults.fire("delta_merge")
        n_pad = dg.pad_vertices
        cm = CostModel(snap)
        flat_cache: dict[bool, jax.Array] = {}

        def base_flat(fwd: bool) -> jax.Array:
            if fwd not in flat_cache:
                dirn = self.graph.out if fwd else self.graph.inc
                flat_cache[fwd] = jnp.asarray(dirn.indptr_el.reshape(-1),
                                              dtype=jnp.int32)
            return flat_cache[fwd]

        out: list[dict[str, jax.Array]] = []
        for s in plan.steps:
            d: dict[str, jax.Array] = {}
            if s.restart_candidates is not None:
                cands = np.sort(cm.candidates(plan.query, s.u)) \
                    .astype(np.int32)
                if use_prune and s.sig_mask is not None and cands.size:
                    # re-apply the plan's baked candidate prune to the
                    # freshly resolved set (conservative snapshot rows)
                    from repro.index import signature_rows

                    rows = signature_rows(snap)
                    keep = np.all((rows[cands] & s.sig_mask) == s.sig_mask,
                                  axis=-1)
                    cands = cands[keep]
                n_real = cands.size
                # pow2 padding keeps the trace stable across snapshots
                target = _next_pow2(max(1, n_real))
                if n_real < target:
                    cands = np.concatenate(
                        [cands, np.full(target - n_real, -1, np.int32)])
                d["restart"] = jnp.asarray(cands)
                d["restart_n"] = jnp.int32(n_real)
            elif s.elabel >= 0:
                d["iptr"] = snap.base_el_row_padded(s.elabel, s.forward,
                                                    n_pad)
                d.update(snap.dev_el_step(s.elabel, s.forward, n_pad))
            else:
                d["all_iptr"] = snap.base_plain_padded(s.forward, n_pad)
                d.update(snap.dev_plain(s.forward, n_pad))
            if s.labels:
                d["label_mask"] = jnp.asarray(_label_mask(self.graph,
                                                          s.labels))
            if s.labels or _fused_eligible(s, self.opts):
                d["bitmap"] = snap.dev_bitmap(n_pad)
            if use_prune and s.sig_mask is not None \
                    and s.restart_candidates is None:
                d["sig_mask"] = jnp.asarray(s.sig_mask)
                d["sig"] = snap.dev_sig(n_pad)
                if _fused_eligible(s, self.opts):
                    lm = _label_mask(self.graph, s.labels) if s.labels else \
                        np.zeros(self.graph.label_bitmap.shape[1], np.uint32)
                    d["fmask"] = jnp.asarray(
                        np.concatenate([lm, s.sig_mask]))
                    d["filter_bitmap"] = snap.dev_filter_bitmap(n_pad)
            if s.num_filters:
                nv = snap.dev_numeric(n_pad)
                if nv is not None:
                    d["numeric"] = nv
            for ci, c in enumerate(s.nontree):
                use_out = c.forward or c.self_loop
                if c.pvar_idx >= 0:
                    d[f"nt{ci}_flat"] = base_flat(use_out)
                    for k, v in snap.dev_flat(use_out, n_pad).items():
                        d[f"nt{ci}_{k}"] = v
                else:
                    d[f"nt{ci}_iptr"] = snap.base_el_row_padded(
                        c.elabel, use_out, n_pad)
                    for k, v in snap.dev_el_step(c.elabel, use_out,
                                                 n_pad).items():
                        d[f"nt{ci}_{k}"] = v
            out.append(d)
        plan._dev_arrays_snap = (token, out)  # type: ignore[attr-defined]
        return out

    def _start_candidates(self, plan: ExecPlan,
                          view=None) -> np.ndarray:
        """The plan's start-candidate set, re-resolved against the current
        snapshot when executing a live store (plans are cached across
        versions; their baked candidate arrays go stale, the spec —
        labels / bound id / cheap numeric filters — does not)."""
        if view is None:
            view = self.view
        if view is None:
            return plan.start_candidates
        from repro.core.planner.cost import CostModel
        from repro.core.planner.ir import np_cmp

        token = (view.token(), self.opts.use_prune)
        cached = getattr(plan, "_snap_start", None)
        if cached is not None and cached[0] == token:
            return cached[1]
        cands = CostModel(view).candidates(plan.query, plan.start_vertex)
        nf = getattr(plan, "start_num_filters", ())
        if nf and view.numeric_value is not None:
            vals = view.numeric_value[cands]
            keep = np.ones(cands.shape[0], bool)
            for op, c in nf:
                keep &= np_cmp(vals, op, c)
            cands = cands[keep]
        sig = getattr(plan, "start_sig", None)
        if self.opts.use_prune and sig is not None and cands.size:
            from repro.index import signature_rows

            rows = signature_rows(view)
            cands = cands[np.all((rows[cands] & sig) == sig, axis=-1)]
        cands = np.sort(cands).astype(np.int32)
        plan._snap_start = (token, cands)  # type: ignore[attr-defined]
        return cands

    def _param_start_candidates(self, plan: ExecPlan, params: np.ndarray,
                                view=None) -> np.ndarray:
        """Start-candidate resolution for a parameterized start vertex: the
        set is exactly the parameter's vertex id, subject to the same
        label-containment check the cost model applies to baked bound
        vertices.  Signature pruning is skipped (it is a pure optimization
        on a one-element set).  Never cached on the plan — it varies with
        ``params`` — and valid against both the base graph and snapshots
        (ids are stable across versions)."""
        g = view if view is not None else self.graph
        cid = int(params[plan.start_param_slot])
        if cid < 0 or cid >= int(g.n_vertices):
            return np.zeros(0, np.int32)
        qv = plan.query.vertices[plan.start_vertex]
        if qv.labels:
            bm = np.asarray(g.label_bitmap[cid])
            for lbl in qv.labels:
                if not (int(bm[lbl >> 5]) >> (lbl & 31)) & 1:
                    return np.zeros(0, np.int32)
        return np.array([cid], np.int32)

    def _schedule(self, plan: ExecPlan, chunk_size: int,
                  opts: ExecOpts | None = None) -> tuple[tuple, list[int]]:
        """The (learned) per-step capacity schedule for this plan+chunk."""
        opts = self.opts if opts is None else opts
        # cap_slack/init_cap are in the key so degraded-ladder runs learn
        # their own schedules instead of polluting the normal path's
        key = (plan.signature(), chunk_size, bool(opts.cap_schedule),
               opts.cap_slack, opts.init_cap)
        caps = self._caps_cache.get(key)
        if caps is None:
            if opts.cap_schedule:
                caps = list(plan.capacity_schedule(
                    chunk_size, opts.init_cap, opts.max_cap, opts.cap_slack))
            else:
                # legacy presizing: one global capacity from the whole-plan
                # fanout product, identical for every step
                est = 1.0
                for f in plan.est_fanout:
                    est *= max(1.0, min(f, 64.0))
                cap0 = int(min(opts.max_cap,
                               max(opts.init_cap,
                                   _next_pow2(int(chunk_size * min(est, 512.0))))))
                cap0 = max(cap0, _next_pow2(chunk_size))
                caps = [cap0] * len(plan.steps)
            self._caps_cache[key] = caps
        return key, caps

    def run(
        self,
        plan: ExecPlan,
        collect: str = "bindings",
        initial: tuple[np.ndarray, np.ndarray, np.ndarray] | None = None,
        profile: bool | None = None,
        state: tuple | None = None,
        trace=None,
        params: np.ndarray | None = None,
        cancel: CancelToken | None = None,
        _opts_override: ExecOpts | None = None,
    ) -> Result:
        """Execute a plan.  ``initial=(B0, P0, origins)`` runs the plan's
        steps as an *extension* of existing rows (OPTIONAL left joins).
        ``profile=True`` (or ``ExecOpts.profile``) executes step-by-step
        with host syncs to fill per-step wall times in ``Result.stats``.
        ``state`` pins a ``pin()``-captured (view, device-graph) pair so a
        multi-run query stays on one snapshot under concurrent updates.
        ``trace`` (a :class:`repro.obs.Trace`) records compile / dispatch /
        device-wait / per-step spans under the caller's current span; a
        trace with ``profile_steps=True`` forces profiled execution so the
        step spans carry real device wall times.  ``params`` supplies a
        parameterized plan's constant vector (int32 ``[plan.n_params]``);
        a negative entry means the constant is absent from the dictionary
        and short-circuits to an empty result.  ``cancel`` (a
        :class:`repro.resilience.CancelToken`) is polled between chunk
        dispatches and suffix-resume re-entries; an expired or cancelled
        token raises :class:`QueryCancelled` with partial stats.

        Transient faults (RESOURCE_EXHAUSTED-shaped) are absorbed by a
        retry/degradation ladder: bounded backoff retries at the current
        config, then progressively degraded configs down to the legacy
        executor, with the working level remembered per plan signature
        (see :mod:`repro.resilience.policy`).  Runs are pure with respect
        to their host inputs, so a ladder re-run is exact."""
        if cancel is None and self.opts.deadline is not None:
            cancel = CancelToken(self.opts.deadline)
        if _opts_override is not None:
            # explicit config (small-plan probes, degraded re-runs):
            # bypass the ladder so probe timings stay undistorted
            return self._run_impl(plan, collect, initial, profile, state,
                                  trace, params, cancel, _opts_override)
        sig = plan.signature()
        policy = self._policy
        level = self._breaker.level(sig)
        attempt = 0
        while True:
            try:
                res = self._run_impl(
                    plan, collect, initial, profile, state, trace, params,
                    cancel, degrade_opts(self.opts, level) if level else None)
            except QueryCancelled:
                raise
            except Exception as e:  # noqa: BLE001 - filtered just below
                if not is_transient_fault(e):
                    raise
                self._res_counters["fault_retries"] += 1
                if attempt < policy.max_retries:
                    delay = policy.backoff(attempt)
                    attempt += 1
                    if cancel is not None:
                        if cancel.expired:
                            raise QueryCancelled(
                                f"query cancelled: "
                                f"{cancel.reason or 'cancelled'}") from e
                        rem = cancel.remaining()
                        if rem is not None:
                            delay = min(delay, max(0.0, rem))
                    time.sleep(delay)
                    continue
                if level >= MAX_LEVEL:
                    raise
                prev = level
                level = self._breaker.record_failure(sig, level)
                self._res_counters["escalations"] += 1
                attempt = 0
                log.warning(
                    "transient fault at degradation level %d; "
                    "escalating to level %d: %s", prev, level, e)
                continue
            self._breaker.record_success(sig, level)
            if level:
                self._res_counters["degraded_runs"] += 1
                res.stats["degraded_level"] = level
            return res

    def _run_impl(
        self,
        plan: ExecPlan,
        collect: str = "bindings",
        initial: tuple[np.ndarray, np.ndarray, np.ndarray] | None = None,
        profile: bool | None = None,
        state: tuple | None = None,
        trace=None,
        params: np.ndarray | None = None,
        cancel: CancelToken | None = None,
        _opts_override: ExecOpts | None = None,
    ) -> Result:
        state = self.pin() if state is None else state
        view, dg = state
        if plan.unsat:
            return Result(0, _empty(plan), _empty_p(plan), np.zeros(0, np.int32))
        if plan.n_params:
            if params is None:
                raise ValueError(
                    f"plan expects {plan.n_params} parameters; none given")
            params = np.asarray(params, np.int32).reshape(-1)
            if params.shape[0] != plan.n_params:
                raise ValueError(f"expected {plan.n_params} parameters, "
                                 f"got {params.shape[0]}")
            if (params < 0).any():
                # a hoisted constant missing from the dictionary: provably
                # zero solutions (same contract as an unsat baked plan)
                return Result(0,
                              _empty(plan) if collect == "bindings" else None,
                              _empty_p(plan), np.zeros(0, np.int32))
        opts = self.opts if _opts_override is None else _opts_override
        small_legacy = False  # remembered small-probe verdict applied?
        if (_opts_override is None and initial is None and trace is None
                and not profile and _small_plan(plan, opts)):
            # B1-class small queries: the pipelined machinery's fixed
            # overhead (per-step capacity schedule, fused-kernel setup,
            # async bookkeeping) can exceed the work saved.  Estimates
            # can't settle which side a plan lands on, so probe once per
            # plan signature: run each configuration twice (first to warm
            # its compile cache, second timed) and remember the winner.
            # Both configurations return identical results, so the probe
            # is invisible to callers beyond one-time latency.
            sig = plan.signature()
            mode = self._small_mode.get(sig)
            if mode is None:
                legacy = replace(opts, cap_schedule=False,
                                 suffix_resume=False, async_chunks=1,
                                 use_fused=False)
                kw = dict(collect=collect, state=state, params=params,
                          cancel=cancel)
                res = self.run(plan, _opts_override=opts, **kw)
                t0 = time.perf_counter()
                res = self.run(plan, _opts_override=opts, **kw)
                t_pipe = time.perf_counter() - t0
                self.run(plan, _opts_override=legacy, **kw)
                t0 = time.perf_counter()
                res_l = self.run(plan, _opts_override=legacy, **kw)
                t_leg = time.perf_counter() - t0
                # require a clear win before abandoning the pipeline: the
                # probe is a single sample and ties should keep defaults
                mode = t_leg < 0.9 * t_pipe
                self._small_mode[sig] = mode
                win = res_l if mode else res
                win.stats["small_probe"] = {
                    "t_pipelined_ms": round(t_pipe * 1e3, 3),
                    "t_legacy_ms": round(t_leg * 1e3, 3),
                    "legacy_wins": bool(mode)}
                return win
            if mode:
                opts = replace(opts, cap_schedule=False, suffix_resume=False,
                               async_chunks=1, use_fused=False)
                small_legacy = True
        profile = opts.profile if profile is None else profile
        if trace is not None and trace.profile_steps:
            profile = True
        nq = plan.query.n_vertices
        params_dev = jnp.asarray(params) if plan.n_params \
            else jnp.zeros(0, jnp.int32)

        if initial is None and not plan.steps:
            # point-shaped query (paper Algorithm 1 lines 2–4)
            if plan.start_param_slot >= 0 and params is not None:
                cands = self._param_start_candidates(plan, params, view)
            else:
                cands = self._start_candidates(plan, view)
            b = np.full((cands.shape[0], nq), -1, dtype=np.int32)
            b[:, plan.start_vertex] = cands
            return Result(
                int(cands.shape[0]),
                b if collect == "bindings" else None,
                np.full((cands.shape[0], max(1, plan.n_pvars)), -1, np.int32),
                np.arange(cands.shape[0], dtype=np.int32),
            )

        sarrs = self._arrays(plan, state)
        extension = initial is not None
        if extension:
            b0, p0, org0 = initial
            n_src = b0.shape[0]
        else:
            if plan.start_param_slot >= 0 and params is not None:
                start_cands = self._param_start_candidates(plan, params, view)
            else:
                start_cands = self._start_candidates(plan, view)
            n_src = start_cands.shape[0]
        if n_src == 0 or (not extension and not plan.steps):
            # honor the collect contract even on the empty fast path —
            # count-collect promises bindings=None (start pruning can make
            # this reachable for plans that would otherwise produce rows)
            return Result(0, _empty(plan) if collect == "bindings" else None,
                          _empty_p(plan), np.zeros(0, np.int32))

        t_run0 = time.perf_counter()
        n_steps = len(plan.steps)
        npv = max(1, plan.n_pvars)
        stats = _empty_stats(n_steps)
        if small_legacy:
            stats["small_mode"] = True

        def check_cancel() -> None:
            if cancel is not None and cancel.expired:
                stats["wall_ms"] = (time.perf_counter() - t_run0) * 1e3
                raise QueryCancelled(
                    f"query cancelled: {cancel.reason or 'cancelled'}",
                    partial_stats=dict(stats))

        if profile:
            stats["step_wall_ms"] = [0.0] * n_steps
        total = 0
        out_b: list[np.ndarray] = []
        out_p: list[np.ndarray] = []
        out_o: list[np.ndarray] = []
        chunk_size = min(opts.chunk, max(1, n_src))
        caps_key, caps = self._schedule(plan, chunk_size, opts)

        def host_args(offset: int, hi: int):
            n_real = hi - offset
            if not extension:
                chunk = np.full(chunk_size, -1, dtype=np.int32)
                chunk[:n_real] = start_cands[offset:hi]
                return (jnp.asarray(chunk), jnp.int32(n_real),
                        jnp.zeros((chunk_size, npv), jnp.int32),
                        jnp.zeros((chunk_size,), jnp.int32))
            bpad = np.full((chunk_size, nq), -1, dtype=np.int32)
            bpad[:n_real] = b0[offset:hi]
            ppad = np.full((chunk_size, npv), -1, np.int32)
            ppad[:n_real, : p0.shape[1]] = p0[offset:hi]
            opad = np.full(chunk_size, -1, dtype=np.int32)
            opad[:n_real] = org0[offset:hi]
            return (jnp.asarray(bpad), jnp.int32(n_real),
                    jnp.asarray(ppad), jnp.asarray(opad))

        def call_fn(fn, fresh, args, **meta):
            """One chunk-program invocation; with tracing on, the span is
            named ``compile`` when this call triggers the first-dispatch
            XLA compile (jit compiles synchronously inside the call) and
            ``dispatch`` when it only enqueues the async chunk."""
            poison = _faults.fire("dispatch")
            if fresh:
                stats["compiles"] += 1
            if trace is None:
                out = fn(*args)
            else:
                with trace.span("compile" if fresh else "dispatch", **meta):
                    out = fn(*args)
            if poison:
                # injected silent corruption: zero this chunk's count so
                # end-to-end checks can detect a poisoned dispatch
                stats["poisoned"] = stats.get("poisoned", 0) + 1
                out = (*out[:3], out[3] * 0, *out[4:])
            return out

        def dispatch(offset: int, hi: int) -> dict:
            args = host_args(offset, hi)
            used = tuple(caps)
            fn, fresh = self._get_fn(plan, used, chunk_size, extension,
                                     collect, 0, n_steps, dg, opts)
            ci = stats["chunks"]
            stats["chunks"] += 1
            return {"out": call_fn(fn, fresh, (*args, params_dev, sarrs),
                                   chunk=ci),
                    "args": args, "caps": used, "offset": offset}

        def accumulate(start: int, upto: int, acc_from: int, totals, kepts,
                       pins, pouts):
            """Fold one window's step counters into the run stats."""
            if upto <= acc_from:
                return
            t_np = np.asarray(totals)
            k_np = np.asarray(kepts)
            pi_np = np.asarray(pins)
            po_np = np.asarray(pouts)
            for si in range(max(start, acc_from), min(upto, n_steps)):
                ii = si - start
                if t_np[ii] >= 0:
                    stats["step_rows"][si] += int(t_np[ii])
                if k_np[ii] >= 0:
                    stats["step_kept"][si] += int(k_np[ii])
                if pi_np[ii] >= 0:
                    stats["step_prune_in"][si] += int(pi_np[ii])
                if po_np[ii] >= 0:
                    stats["step_prune_out"][si] += int(po_np[ii])

        def drain(rec: dict) -> None:
            nonlocal total
            b, p, org, count, ovf_step, totals, kepts, pins, pouts = rec["out"]
            used = list(rec["caps"])
            start = 0
            acc_from = 0
            while True:
                # device sync for this chunk's scalars — with tracing on,
                # the host's wait for buffer-ready shows up as device_wait
                if trace is None:
                    ovf = int(ovf_step)
                else:
                    with trace.span("device_wait"):
                        ovf = int(ovf_step)
                accumulate(start, ovf, acc_from, totals, kepts, pins, pouts)
                acc_from = max(acc_from, min(ovf, n_steps))
                if ovf >= n_steps:
                    break
                # overflow retry is a fresh dispatch: honor an expired
                # deadline before re-entering the plan
                check_cancel()
                stats["step_retries"][ovf] += 1
                if opts.suffix_resume:
                    # re-enter from the overflowing step only: the frozen
                    # table returned by the chunk program is exactly that
                    # step's input
                    new_caps = _grow_caps(list(used), ovf, opts.max_cap)
                    n_in = used[ovf - 1] if ovf > 0 else chunk_size
                    fn, fresh = self._get_fn(plan, tuple(new_caps), n_in,
                                             True, collect, ovf, n_steps, dg,
                                             opts)
                    (b, p, org, count, ovf_step, totals, kepts, pins,
                     pouts) = call_fn(
                        fn, fresh,
                        (b[:n_in], count, p[:n_in], org[:n_in], params_dev,
                         sarrs),
                        resume_step=ovf)
                    start = ovf
                    acc_from = ovf
                    stats["resumes"] += 1
                else:
                    # legacy: double every capacity, redo the whole chunk
                    if used[ovf] >= opts.max_cap:
                        raise RuntimeError(
                            f"binding-table overflow at max capacity "
                            f"{opts.max_cap}; raise ExecOpts.max_cap")
                    new_caps = [min(opts.max_cap, c * 2) for c in used]
                    fn, fresh = self._get_fn(plan, tuple(new_caps),
                                             chunk_size, extension, collect,
                                             0, n_steps, dg, opts)
                    (b, p, org, count, ovf_step, totals, kepts, pins,
                     pouts) = call_fn(
                        fn, fresh, (*rec["args"], params_dev, sarrs),
                        retry=True)
                    start = 0
                used = new_caps
                # persist the learned schedule for subsequent chunks
                shared = self._caps_cache[caps_key]
                for si in range(n_steps):
                    shared[si] = max(shared[si], used[si])
            c = int(count)
            total += c
            if collect == "bindings" and c:
                out_b.append(np.asarray(b[:c]))
                out_p.append(np.asarray(p[:c]))
                o = np.asarray(org[:c])
                if not extension:
                    o = o + rec["offset"]  # chunk-local start index -> global
                out_o.append(o)

        pending: deque[dict] = deque()
        max_inflight = max(1, int(opts.async_chunks))
        offset = 0
        while offset < n_src:
            check_cancel()
            hi = min(offset + chunk_size, n_src)
            if profile and n_steps:
                self._run_profiled_chunk(plan, sarrs, offset, hi, chunk_size,
                                         extension, collect, caps_key, stats,
                                         host_args, drain, dg, trace,
                                         params_dev, opts, check_cancel)
            else:
                pending.append(dispatch(offset, hi))
                if len(pending) >= max_inflight:
                    drain(pending.popleft())
            offset = hi
        while pending:
            drain(pending.popleft())

        stats["caps"] = list(self._caps_cache[caps_key])
        stats["wall_ms"] = (time.perf_counter() - t_run0) * 1e3
        # which kernel each step ran through — cheap host-side lookup,
        # consumed by the workload profiler's kernel-mix accounting
        stats["step_kernels"] = [
            _step_kernel_name(dg, st, sarrs[si], opts,
                              collect == "count" and si == n_steps - 1)
            for si, st in enumerate(plan.steps)]
        if trace is not None and n_steps:
            _annotate_step_spans(trace, plan, dg, sarrs, opts, stats,
                                 collect, n_src)
        bindings = (np.concatenate(out_b) if out_b else _empty(plan)) \
            if collect == "bindings" else None
        pb = (np.concatenate(out_p) if out_p else _empty_p(plan)) \
            if collect == "bindings" else None
        origins = np.concatenate(out_o) if out_o else np.zeros(0, np.int32)
        # one overflow event == one step retry, in every execution mode
        return Result(total, bindings, pb, origins,
                      chunks_retried=sum(stats["step_retries"]), stats=stats)

    def run_batch(self, plan: ExecPlan, params_mat: np.ndarray,
                  collect: str = "bindings",
                  state: tuple | None = None,
                  cancel: CancelToken | None = None) -> list[Result]:
        """Answer ``B`` same-shape queries in one device launch.

        ``params_mat`` (int32 ``[B, plan.n_params]``) stacks one constant
        vector per query; the chunk program is ``jax.vmap``-ed over the
        params axis (and, when the start vertex itself is parameterized,
        over per-lane start chunks), so a whole batch costs one dispatch.
        Per-lane capacity overflow is handled by masking: an overflowing
        lane freezes exactly like a single-query chunk, and only those
        lanes are re-run individually through :meth:`run` (suffix-resume) —
        results are bit-identical to per-query execution either way.

        Lanes whose constants are missing from the dictionary (negative
        ids) or whose parameterized start fails its label check return
        empty results without touching the device.  Falls back to
        sequential :meth:`run` calls when the plan's start set does not fit
        one chunk.  The fused Pallas kernel is disabled under vmap — the
        ref/jnp path is batchable on every backend."""
        state = self.pin() if state is None else state
        view, dg = state
        params_mat = np.asarray(params_mat, np.int32)
        if params_mat.ndim != 2 or params_mat.shape[1] != plan.n_params:
            raise ValueError(
                f"expected params [B, {plan.n_params}], got "
                f"{params_mat.shape}")
        B = params_mat.shape[0]
        n_steps = len(plan.steps)

        def empty() -> Result:
            return Result(0,
                          _empty(plan) if collect == "bindings" else None,
                          _empty_p(plan), np.zeros(0, np.int32))

        results: list[Result | None] = [None] * B
        if plan.unsat:
            return [empty() for _ in range(B)]
        if not plan.steps or plan.n_params == 0 or B == 1:
            # degenerate shapes: nothing to amortize, reuse the single path
            return [self.run(plan, collect=collect, state=state,
                             params=params_mat[i], cancel=cancel)
                    for i in range(B)]

        opts = replace(self.opts, use_fused=False, async_chunks=1)
        per_lane_start = plan.start_param_slot >= 0
        if per_lane_start:
            chunk_size = 1
            lane_chunks = np.full((B, 1), -1, np.int32)
            lane_counts = np.zeros(B, np.int32)
            for i in range(B):
                if (params_mat[i] < 0).any():
                    results[i] = empty()
                    continue
                cands = self._param_start_candidates(plan, params_mat[i],
                                                     view)
                if cands.size == 0:
                    results[i] = empty()
                else:
                    lane_chunks[i, 0] = cands[0]
                    lane_counts[i] = 1
        else:
            start_cands = self._start_candidates(plan, view)
            n_src = start_cands.shape[0]
            if n_src == 0:
                return [empty() for _ in range(B)]
            if n_src > opts.chunk:
                # multi-chunk start sets: per-lane accumulation across
                # chunks loses the one-launch win anyway — run sequentially
                return [self.run(plan, collect=collect, state=state,
                                 params=params_mat[i], cancel=cancel)
                        for i in range(B)]
            chunk_size = n_src
            for i in range(B):
                if (params_mat[i] < 0).any():
                    results[i] = empty()

        live = [i for i in range(B) if results[i] is None]
        if not live:
            return results  # type: ignore[return-value]

        # pow2-pad the lane axis (bounds recompiles to log-many shapes);
        # pad lanes duplicate the first live lane and are discarded
        L = len(live)
        L_pad = 1 << max(0, (L - 1).bit_length())
        rows = live + [live[0]] * (L_pad - L)
        pmat = jnp.asarray(params_mat[rows])
        sarrs = self._arrays(plan, state)
        if per_lane_start:
            # one start row per lane: the single-query capacity floor
            # (init_cap) would make every lane pay for the whole batch's
            # worth of slots — vmapped compute is per-lane, so size caps to
            # the estimate with a small floor.  Undersized lanes freeze and
            # rerun solo, which keeps results bit-identical.
            caps = list(plan.capacity_schedule(
                chunk_size, min(opts.init_cap, 64), opts.max_cap,
                opts.cap_slack))
        else:
            _, caps = self._schedule(plan, chunk_size, opts)
        npv = max(1, plan.n_pvars)
        used = tuple(caps)

        key = ("batch", plan.signature(), used, chunk_size, L_pad,
               per_lane_start, collect, opts.key(), dg.key())
        fn = self._compiled.get(key)
        if fn is None:
            raw = build_chunk_fn(dg, plan, used, chunk_size, opts,
                                 table_input=False, collect=collect,
                                 start_step=0, stop_step=n_steps)
            lane_ax = 0 if per_lane_start else None
            fn = jax.jit(jax.vmap(raw,
                                  in_axes=(lane_ax, lane_ax, None, None, 0,
                                           None)))
            self._compiled[key] = fn
        p0 = jnp.zeros((chunk_size, npv), jnp.int32)
        o0 = jnp.zeros((chunk_size,), jnp.int32)
        if per_lane_start:
            chunk_in = jnp.asarray(lane_chunks[rows])
            count_in = jnp.asarray(lane_counts[rows])
        else:
            chunk_in = jnp.asarray(start_cands)
            count_in = jnp.int32(n_src)
        if cancel is not None and cancel.expired:
            raise QueryCancelled(
                f"query cancelled: {cancel.reason or 'cancelled'}")
        try:
            poison = _faults.fire("dispatch")
            (b, p, org, count, ovf_step, totals, kepts, pins,
             pouts) = fn(chunk_in, count_in, p0, o0, pmat, sarrs)
        except Exception as e:  # noqa: BLE001 - filtered just below
            if not is_transient_fault(e):
                raise
            # batched dispatch hit memory pressure: fall back to the
            # sequential path, whose per-run ladder absorbs the fault
            return [results[i] if results[i] is not None
                    else self.run(plan, collect=collect, state=state,
                                  params=params_mat[i], cancel=cancel)
                    for i in range(B)]
        count_h = np.asarray(count)
        if poison:
            count_h = np.zeros_like(count_h)
        ovf_h = np.asarray(ovf_step)
        b_h = np.asarray(b) if collect == "bindings" else None
        p_h = np.asarray(p) if collect == "bindings" else None
        org_h = np.asarray(org) if collect == "bindings" else None
        # per-lane step counters ([L_pad, n_steps]; -1 = frozen/no-probe,
        # same sentinel contract as the single-query chunk program)
        tot_h, kep_h = np.asarray(totals), np.asarray(kepts)
        pin_h, pout_h = np.asarray(pins), np.asarray(pouts)
        kernels = [_step_kernel_name(dg, st, sarrs[si], opts,
                                     collect == "count" and si == n_steps - 1)
                   for si, st in enumerate(plan.steps)]
        for li, qi in enumerate(live):
            if int(ovf_h[li]) < n_steps:
                # overflowing lane: redo it alone — run()'s suffix-resume
                # doubling is deterministic, so the answer is identical to
                # a lane that had fit
                results[qi] = self.run(plan, collect=collect, state=state,
                                       params=params_mat[qi], cancel=cancel)
                continue
            c = int(count_h[li])
            stats = _empty_stats(n_steps)
            stats["chunks"] = 1
            stats["batched"] = True
            stats["batch_lanes"] = L_pad
            stats["batch_fill"] = L / L_pad
            stats["step_kernels"] = kernels
            for si in range(n_steps):
                if tot_h[li, si] >= 0:
                    stats["step_rows"][si] = int(tot_h[li, si])
                if kep_h[li, si] >= 0:
                    stats["step_kept"][si] = int(kep_h[li, si])
                if pin_h[li, si] >= 0:
                    stats["step_prune_in"][si] = int(pin_h[li, si])
                if pout_h[li, si] >= 0:
                    stats["step_prune_out"][si] = int(pout_h[li, si])
            if collect == "bindings":
                results[qi] = Result(c, b_h[li, :c].copy(),
                                     p_h[li, :c].copy(),
                                     org_h[li, :c].copy(), stats=stats)
            else:
                results[qi] = Result(c, None, _empty_p(plan),
                                     np.zeros(0, np.int32), stats=stats)
        return results  # type: ignore[return-value]

    def _run_profiled_chunk(self, plan, sarrs, offset, hi, chunk_size,
                            extension, collect, caps_key, stats, host_args,
                            drain, dg: DeviceGraph | None = None,
                            trace=None, params_dev=None,
                            opts: ExecOpts | None = None,
                            check_cancel=None) -> None:
        """Step-at-a-time execution of one chunk with host syncs, filling
        per-step wall times; overflow handling is inherently suffix-resume
        (each window re-runs alone with a doubled capacity)."""
        opts = self.opts if opts is None else opts
        if params_dev is None:
            params_dev = jnp.zeros(0, jnp.int32)
        n_steps = len(plan.steps)
        caps = self._caps_cache[caps_key]
        args = host_args(offset, hi)
        state = None
        ci = stats["chunks"]
        stats["chunks"] += 1
        for si in range(n_steps):
            while True:
                if check_cancel is not None:
                    check_cancel()
                used = tuple(caps)
                n_in = chunk_size if si == 0 else used[si - 1]
                fn, fresh = self._get_fn(plan, used, n_in,
                                         extension or si > 0,
                                         collect, si, si + 1, dg, opts)
                if fresh:
                    stats["compiles"] += 1
                span_cm = (trace.span("compile" if fresh else "dispatch",
                                      chunk=ci, step=si)
                           if trace is not None else None)
                if span_cm is not None:
                    span_cm.__enter__()
                poison = _faults.fire("dispatch")
                t0 = time.perf_counter()
                if si == 0:
                    out = fn(*args, params_dev, sarrs)
                else:
                    b, p, org, count = state
                    out = fn(b[:n_in], count, p[:n_in], org[:n_in],
                             params_dev, sarrs)
                if poison:
                    stats["poisoned"] = stats.get("poisoned", 0) + 1
                    out = (*out[:3], out[3] * 0, *out[4:])
                jax.block_until_ready(out)
                if span_cm is not None:
                    span_cm.__exit__(None, None, None)
                stats["step_wall_ms"][si] += (time.perf_counter() - t0) * 1e3
                b, p, org, count, ovf_step, totals, kepts, pins, pouts = out
                if int(ovf_step) >= n_steps:
                    if int(totals[0]) >= 0:
                        stats["step_rows"][si] += int(totals[0])
                    if int(kepts[0]) >= 0:
                        stats["step_kept"][si] += int(kepts[0])
                    if int(pins[0]) >= 0:
                        stats["step_prune_in"][si] += int(pins[0])
                    if int(pouts[0]) >= 0:
                        stats["step_prune_out"][si] += int(pouts[0])
                    state = (b, p, org, count)
                    break
                stats["step_retries"][si] += 1
                stats["resumes"] += 1
                _grow_caps(caps, si, opts.max_cap)
        # hand the finished table to the shared collection path (the -1
        # counter vectors mean "already accumulated above")
        b, p, org, count = state
        rec = {"out": (b, p, org, count, jnp.int32(n_steps),
                       jnp.full(n_steps, -1, jnp.int32),
                       jnp.full(n_steps, -1, jnp.int32),
                       jnp.full(n_steps, -1, jnp.int32),
                       jnp.full(n_steps, -1, jnp.int32)),
               "args": args, "caps": tuple(caps), "offset": offset}
        drain(rec)


def _empty(plan: ExecPlan) -> np.ndarray:
    return np.zeros((0, plan.query.n_vertices), dtype=np.int32)


def _empty_p(plan: ExecPlan) -> np.ndarray:
    return np.zeros((0, max(1, plan.n_pvars)), dtype=np.int32)

