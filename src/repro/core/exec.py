"""Vectorized e-graph-homomorphism executor (the tamed TurboHOM++ core).

The paper's recursive ExploreCandidateRegion + SubgraphSearch become a
breadth-first *binding table* pipeline: a table of partial embeddings
``B int32[capacity, |V(q)|]`` is expanded one query vertex at a time along
the matching order.  Each step is a capacity-bounded ragged expansion over
CSR adjacency slices followed by vectorized filters:

  - vertex-label containment (packed-bitmap superset test),
  - ID-attribute equality (Definition 3's ID check),
  - optional NLF / degree filters (the paper's -NLF / -DEG toggles),
  - non-tree edge joins — either per-candidate binary search (the paper's
    original IsJoinable) or the bulk tile-compare path (+INT),
  - injectivity masks when running in subgraph-*isomorphism* mode
    (``semantics="iso"``) — the executor implements both semantics; e-hom
    is the RDF semantics and simply skips those masks (§2.2),
  - predicate-variable (M_e) binding and consistency for e-graph
    homomorphism (Definition 2).

Capacity management: every step reports ``total`` rows required; if any step
overflows its static capacity the chunk is retried with doubled capacity
(geometric, recompile-cached).  Results are exact — overflow never truncates.

Non-tree join directions (uniform rule): for a check attached to query
vertex u with candidate v_new and earlier vertex `other` bound to other_v,
  forward  (other --el--> u):  v_new ∈ out_adj(other_v, el)
  reverse  (u --el--> other):  v_new ∈ in_adj(other_v, el)
  self-loop (u --el--> u):     v_new ∈ out_adj(v_new, el)
i.e. the probe vertex is other_v (v_new for self-loops), the search target
is always v_new, and the direction picks the out/in CSR.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.planner import ExecPlan, Step
from repro.kernels import ops as kops
from repro.rdf.graph import LabeledGraph
from repro.utils import get_logger

log = get_logger("core.exec")

_NULL = jnp.int32(-1)


# --------------------------------------------------------------------------
# Device-resident graph
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class DeviceGraph:
    n_vertices: int
    n_elabels: int
    n_vlabels: int
    max_log_deg: int
    arrays: dict[str, jax.Array]
    host: LabeledGraph
    # per-edge-label max degree (host, for the +INT tile decision)
    max_deg_out_el: np.ndarray = field(default=None)  # type: ignore[assignment]
    max_deg_in_el: np.ndarray = field(default=None)  # type: ignore[assignment]

    @staticmethod
    def from_graph(g: LabeledGraph, with_nlf: bool = False) -> "DeviceGraph":
        def dev(x, dtype):
            x = np.asarray(x, dtype=dtype)
            if x.size == 0:
                x = np.zeros((1,) + x.shape[1:], dtype=dtype)
            return jnp.asarray(x)

        arrays = {
            "out_nbr_el": dev(g.out.nbr_el, np.int32),
            "in_nbr_el": dev(g.inc.nbr_el, np.int32),
            "out_indptr_all": dev(g.out.indptr_all, np.int32),
            "in_indptr_all": dev(g.inc.indptr_all, np.int32),
            "out_nbr_all": dev(g.out.nbr_all, np.int32),
            "in_nbr_all": dev(g.inc.nbr_all, np.int32),
            "out_lab_all": dev(g.out.lab_all, np.int32),
            "in_lab_all": dev(g.inc.lab_all, np.int32),
            "label_bitmap": dev(g.label_bitmap, np.uint32),
            "out_degree": dev(g.out.degree, np.int32),
            "in_degree": dev(g.inc.degree, np.int32),
        }
        if g.numeric_value is not None:
            arrays["numeric_value"] = dev(g.numeric_value, np.float32)
        if with_nlf:
            nlf_o, nlf_i = g.nlf_bitmaps()
            arrays["nlf_out"] = dev(nlf_o, np.uint32)
            arrays["nlf_in"] = dev(nlf_i, np.uint32)
        max_deg = int(max(g.out.degree.max(initial=1), g.inc.degree.max(initial=1)))
        mdo = np.asarray(
            [int(np.diff(g.out.indptr_el[e]).max(initial=0)) for e in range(g.n_elabels)]
        ) if g.n_elabels else np.zeros(0, np.int64)
        mdi = np.asarray(
            [int(np.diff(g.inc.indptr_el[e]).max(initial=0)) for e in range(g.n_elabels)]
        ) if g.n_elabels else np.zeros(0, np.int64)
        return DeviceGraph(
            n_vertices=g.n_vertices,
            n_elabels=g.n_elabels,
            n_vlabels=g.n_vlabels,
            max_log_deg=max(2, int(np.ceil(np.log2(max(2, max_deg)))) + 1),
            arrays=arrays,
            host=g,
            max_deg_out_el=mdo,
            max_deg_in_el=mdi,
        )


# --------------------------------------------------------------------------
# Options / results
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ExecOpts:
    semantics: str = "hom"  # "hom" (RDF) or "iso" (classical subgraph iso)
    use_int: bool = True  # +INT: bulk tile-compare joins where tiles fit
    use_nlf: bool = False  # paper default: disabled (-NLF)
    use_deg: bool = False  # paper default: disabled (-DEG)
    reuse_order: bool = True  # +REUSE
    int_tile: int = 128  # adjacency tile bound for the +INT path
    chunk: int = 8192  # starting vertices per chunk (§Perf: 2-3.7× over 1k on heavy queries)
    init_cap: int = 4096
    max_cap: int = 1 << 22

    def key(self) -> tuple:
        return (self.semantics, self.use_int, self.use_nlf, self.use_deg,
                self.int_tile)


@dataclass
class Result:
    count: int
    bindings: np.ndarray | None  # int32 [count, |V(q)|] (None if count-only)
    pvar_bindings: np.ndarray | None  # int32 [count, n_pvars]
    origins: np.ndarray | None = None  # source-row ids (for extension runs)
    chunks_retried: int = 0
    stats: dict[str, Any] = field(default_factory=dict)


# --------------------------------------------------------------------------
# Step arrays: per-plan device constants
# --------------------------------------------------------------------------


def _label_mask(g: LabeledGraph, labels: tuple[int, ...]) -> np.ndarray:
    n_words = g.label_bitmap.shape[1]
    mask = np.zeros(n_words, dtype=np.uint32)
    for lbl in labels:
        mask[lbl >> 5] |= np.uint32(1 << (lbl & 31))
    return mask


def _plan_arrays(g: LabeledGraph, plan: ExecPlan) -> list[dict[str, jax.Array]]:
    """Per-step device constants: CSR indptr rows, label masks, etc."""
    out: list[dict[str, jax.Array]] = []
    flat_out = flat_in = None
    if any(c.pvar_idx >= 0 for s in plan.steps for c in s.nontree):
        flat_out = jnp.asarray(g.out.indptr_el.reshape(-1), dtype=jnp.int32)
        flat_in = jnp.asarray(g.inc.indptr_el.reshape(-1), dtype=jnp.int32)
    for s in plan.steps:
        d: dict[str, jax.Array] = {}
        if s.restart_candidates is not None:
            cands = s.restart_candidates.astype(np.int32)
            d["restart"] = jnp.asarray(cands if cands.size else np.zeros(1, np.int32))
        elif s.elabel >= 0:
            dirn = g.out if s.forward else g.inc
            d["iptr"] = jnp.asarray(dirn.indptr_el[s.elabel], dtype=jnp.int32)
        if s.labels:
            d["label_mask"] = jnp.asarray(_label_mask(g, s.labels))
        if s.nlf_out_mask is not None:
            d["nlf_out_mask"] = jnp.asarray(s.nlf_out_mask)
            d["nlf_in_mask"] = jnp.asarray(s.nlf_in_mask)
        for ci, c in enumerate(s.nontree):
            use_out = c.forward or c.self_loop
            if c.pvar_idx >= 0:
                d[f"nt{ci}_flat"] = flat_out if use_out else flat_in
            else:
                dirn = g.out if use_out else g.inc
                d[f"nt{ci}_iptr"] = jnp.asarray(dirn.indptr_el[c.elabel],
                                                dtype=jnp.int32)
        out.append(d)
    return out


# --------------------------------------------------------------------------
# The compiled chunk program
# --------------------------------------------------------------------------


def _compact(b, p, org, valid, cap: int):
    """Scatter valid rows to a prefix; invalid rows land in a dropped slot."""
    count = jnp.sum(valid.astype(jnp.int32))
    pos = jnp.where(valid, jnp.cumsum(valid.astype(jnp.int32)) - 1, cap)
    b2 = jnp.full((cap + 1, b.shape[1]), _NULL, dtype=jnp.int32).at[pos].set(b)[:cap]
    p2 = jnp.full((cap + 1, p.shape[1]), _NULL, dtype=jnp.int32).at[pos].set(p)[:cap]
    o2 = jnp.full((cap + 1,), _NULL, dtype=jnp.int32).at[pos].set(org)[:cap]
    return b2, p2, o2, count


def _nontree_mask(dg: DeviceGraph, step: Step, sarr, b_rows, p_rows, v_new,
                  opts: ExecOpts) -> jax.Array:
    n = dg.n_vertices
    ok = jnp.ones(v_new.shape[0], dtype=bool)
    for ci, c in enumerate(step.nontree):
        use_out = c.forward or c.self_loop
        nbr = dg.arrays["out_nbr_el" if use_out else "in_nbr_el"]
        probe = v_new if c.self_loop else b_rows[:, c.other]
        psafe = jnp.clip(probe, 0, n - 1)
        if c.pvar_idx >= 0:
            flat = sarr[f"nt{ci}_flat"]
            el_dyn = jnp.clip(p_rows[:, c.pvar_idx], 0, dg.n_elabels - 1)
            base = el_dyn * jnp.int32(n + 1)
            lo = flat[base + psafe]
            hi = flat[base + psafe + 1]
            bound_ok = p_rows[:, c.pvar_idx] >= 0
            found = kops.edge_exists(nbr, lo, hi, v_new, n_iters=dg.max_log_deg)
            ok &= found & bound_ok
            continue
        iptr = sarr[f"nt{ci}_iptr"]
        lo = iptr[psafe]
        hi = iptr[psafe + 1]
        max_deg = int(
            (dg.max_deg_out_el if use_out else dg.max_deg_in_el)[c.elabel]
        )
        if opts.use_int and 0 < max_deg <= opts.int_tile:
            # +INT: bulk membership via tiled compare-all in VMEM.  Gather the
            # probe side's full adjacency tile (bounded by int_tile) and test
            # all candidates of this step against it at once.
            tb = _next_pow2(max(8, max_deg))
            pos = lo[:, None] + jnp.arange(tb, dtype=jnp.int32)[None, :]
            in_range = pos < hi[:, None]
            adj_tile = jnp.where(
                in_range, nbr[jnp.clip(pos, 0, nbr.shape[0] - 1)], -2
            )
            found = kops.tile_membership(v_new[:, None], adj_tile)[:, 0]
        else:
            found = kops.edge_exists(nbr, lo, hi, v_new, n_iters=dg.max_log_deg)
        ok &= found
    return ok


def build_chunk_fn(dg: DeviceGraph, plan: ExecPlan, cap: int, n_chunk: int,
                   opts: ExecOpts, extension: bool):
    """Build the jittable whole-plan chunk program.

    ``extension=False``: the chunk is a vector of start-vertex candidates.
    ``extension=True``: the chunk is (B0 rows, P0 rows, origin ids) and the
    plan's steps extend those rows (OPTIONAL left joins, cross products).
    """
    nq = plan.query.n_vertices
    npv = max(1, plan.n_pvars)
    steps = plan.steps
    has_numeric = "numeric_value" in dg.arrays

    def fn(chunk, chunk_count, p_init, org_init, sarrs):
        overflow = jnp.zeros((), dtype=bool)
        if not extension:
            b = jnp.full((cap, nq), _NULL, dtype=jnp.int32)
            col = jnp.pad(chunk, (0, cap - n_chunk), constant_values=-1)
            b = b.at[:, plan.start_vertex].set(col)
            p = jnp.full((cap, npv), _NULL, dtype=jnp.int32)
            org = jnp.arange(cap, dtype=jnp.int32)
            count = jnp.minimum(chunk_count, cap).astype(jnp.int32)
        else:
            pad = cap - n_chunk
            b = jnp.pad(chunk, ((0, pad), (0, 0)), constant_values=-1)
            p = jnp.pad(p_init, ((0, pad), (0, 0)), constant_values=-1)
            org = jnp.pad(org_init, (0, pad), constant_values=-1)
            count = chunk_count.astype(jnp.int32)

        for si, step in enumerate(steps):
            sarr = sarrs[si]
            alive = jnp.arange(cap, dtype=jnp.int32) < count
            if step.restart_candidates is not None:
                k_cands = int(step.restart_candidates.shape[0])
                deg = jnp.where(alive, jnp.int32(k_cands), 0)
                nbr_src = sarr["restart"]
                start = jnp.zeros(cap, dtype=jnp.int32)
            elif step.elabel >= 0:
                iptr = sarr["iptr"]
                vp = jnp.clip(b[:, step.parent], 0, dg.n_vertices - 1)
                start = iptr[vp]
                deg = jnp.where(alive, iptr[vp + 1] - start, 0)
                nbr_src = dg.arrays["out_nbr_el" if step.forward else "in_nbr_el"]
            else:  # predicate variable: plain CSR
                iptr = dg.arrays["out_indptr_all" if step.forward else "in_indptr_all"]
                vp = jnp.clip(b[:, step.parent], 0, dg.n_vertices - 1)
                start = iptr[vp]
                deg = jnp.where(alive, iptr[vp + 1] - start, 0)
                nbr_src = dg.arrays["out_nbr_all" if step.forward else "in_nbr_all"]

            # int32 cumsum: safe while chunk_rows × max_degree < 2**31 —
            # true at every scale this container can hold in RAM.
            coffs = jnp.cumsum(deg.astype(jnp.int32))
            total = coffs[-1]
            offs = (coffs - deg).astype(jnp.int32)
            overflow = overflow | (total > cap)
            row, j, valid = kops.ragged_expand(offs, deg.astype(jnp.int32), cap)
            idx = jnp.clip(start[row] + j, 0, nbr_src.shape[0] - 1)
            v_new = jnp.where(valid, nbr_src[idx], _NULL)

            b_rows = b[row]
            p_rows = p[row]
            org_rows = org[row]
            b_rows = b_rows.at[:, step.u].set(v_new)

            ok = valid
            if step.pvar_idx >= 0:  # tree-edge M_e binding
                lab_src = dg.arrays["out_lab_all" if step.forward else "in_lab_all"]
                el_new = jnp.where(valid, lab_src[idx], _NULL)
                prev = p_rows[:, step.pvar_idx]
                ok &= (prev < 0) | (prev == el_new)
                p_rows = p_rows.at[:, step.pvar_idx].set(
                    jnp.where(prev < 0, el_new, prev))
            if step.bound_id >= 0:
                ok &= v_new == jnp.int32(step.bound_id)
            if "label_mask" in sarr:
                bm = dg.arrays["label_bitmap"][jnp.clip(v_new, 0, dg.n_vertices - 1)]
                ok &= kops.bitmap_superset(bm, sarr["label_mask"])
            if step.min_out_ntypes or step.min_in_ntypes:
                safe = jnp.clip(v_new, 0, dg.n_vertices - 1)
                ok &= dg.arrays["out_degree"][safe] >= jnp.int32(step.min_out_ntypes)
                ok &= dg.arrays["in_degree"][safe] >= jnp.int32(step.min_in_ntypes)
            if "nlf_out_mask" in sarr and "nlf_out" in dg.arrays:
                safe = jnp.clip(v_new, 0, dg.n_vertices - 1)
                ok &= kops.bitmap_superset(dg.arrays["nlf_out"][safe],
                                           sarr["nlf_out_mask"])
                ok &= kops.bitmap_superset(dg.arrays["nlf_in"][safe],
                                           sarr["nlf_in_mask"])
            if step.num_filters and has_numeric:
                vals = dg.arrays["numeric_value"][jnp.clip(v_new, 0, dg.n_vertices - 1)]
                for op, cval in step.num_filters:
                    ok &= _jnp_cmp(vals, op, cval)
            if opts.semantics == "iso":
                for w in plan.order:
                    if w == step.u:
                        break
                    ok &= b_rows[:, w] != v_new
            if step.nontree:
                ok &= _nontree_mask(dg, step, sarr, b_rows, p_rows, v_new, opts)

            b, p, org, count = _compact(b_rows, p_rows, org_rows, ok, cap)
        return b, p, org, count, overflow

    return fn


def _jnp_cmp(vals, op: str, c: float):
    c = jnp.float32(c)
    if op == "<":
        return vals < c
    if op == "<=":
        return vals <= c
    if op == ">":
        return vals > c
    if op == ">=":
        return vals >= c
    if op == "=":
        return vals == c
    if op == "!=":
        return vals != c
    raise ValueError(op)


# --------------------------------------------------------------------------
# Host-level executor
# --------------------------------------------------------------------------


class Executor:
    """Chunked, retry-on-overflow plan executor with a compile cache."""

    def __init__(self, g: LabeledGraph, opts: ExecOpts | None = None):
        self.opts = opts or ExecOpts()
        self.graph = g
        self.dg = DeviceGraph.from_graph(g, with_nlf=self.opts.use_nlf)
        self._compiled: dict[tuple, Any] = {}
        self._plan_arrays_cache: dict[int, list[dict[str, jax.Array]]] = {}

    def _get_fn(self, plan: ExecPlan, cap: int, n_chunk: int, extension: bool):
        key = (plan.signature(), cap, n_chunk, extension, self.opts.key())
        fn = self._compiled.get(key)
        if fn is None:
            raw = build_chunk_fn(self.dg, plan, cap, n_chunk, self.opts, extension)
            fn = jax.jit(raw)
            self._compiled[key] = fn
        return fn

    def _arrays(self, plan: ExecPlan) -> list[dict[str, jax.Array]]:
        # cache on the plan object itself (an id()-keyed dict can collide
        # when a dead plan's id is recycled by the allocator)
        cached = getattr(plan, "_dev_arrays", None)
        if cached is not None and cached[0] is self.graph:
            return cached[1]
        arrs = _plan_arrays(self.graph, plan)
        plan._dev_arrays = (self.graph, arrs)  # type: ignore[attr-defined]
        return arrs

    def run(
        self,
        plan: ExecPlan,
        collect: str = "bindings",
        initial: tuple[np.ndarray, np.ndarray, np.ndarray] | None = None,
    ) -> Result:
        """Execute a plan.  ``initial=(B0, P0, origins)`` runs the plan's
        steps as an *extension* of existing rows (OPTIONAL left joins)."""
        if plan.unsat:
            return Result(0, _empty(plan), _empty_p(plan), np.zeros(0, np.int32))
        opts = self.opts
        nq = plan.query.n_vertices

        if initial is None and not plan.steps:
            # point-shaped query (paper Algorithm 1 lines 2–4)
            cands = plan.start_candidates
            b = np.full((cands.shape[0], nq), -1, dtype=np.int32)
            b[:, plan.start_vertex] = cands
            return Result(
                int(cands.shape[0]),
                b if collect == "bindings" else None,
                np.full((cands.shape[0], max(1, plan.n_pvars)), -1, np.int32),
                np.arange(cands.shape[0], dtype=np.int32),
            )

        sarrs = self._arrays(plan)
        extension = initial is not None
        if extension:
            b0, p0, org0 = initial
            n_src = b0.shape[0]
        else:
            n_src = plan.start_candidates.shape[0]
        if n_src == 0 or (not extension and not plan.steps):
            return Result(0, _empty(plan), _empty_p(plan), np.zeros(0, np.int32))

        total = 0
        retried = 0
        out_b: list[np.ndarray] = []
        out_p: list[np.ndarray] = []
        out_o: list[np.ndarray] = []
        chunk_size = min(opts.chunk, max(1, n_src))
        est = 1.0
        for f in plan.est_fanout:
            est *= max(1.0, min(f, 64.0))
        cap0 = int(min(opts.max_cap,
                       max(opts.init_cap,
                           _next_pow2(int(chunk_size * min(est, 512.0))))))
        cap0 = max(cap0, _next_pow2(chunk_size))

        offset = 0
        cap = cap0
        while offset < n_src:
            hi = min(offset + chunk_size, n_src)
            n_real = hi - offset
            while True:
                if not extension:
                    chunk = np.full(chunk_size, -1, dtype=np.int32)
                    chunk[:n_real] = plan.start_candidates[offset:hi]
                    args = (jnp.asarray(chunk), jnp.int32(n_real),
                            jnp.zeros((chunk_size, max(1, plan.n_pvars)), jnp.int32),
                            jnp.zeros((chunk_size,), jnp.int32))
                else:
                    bpad = np.full((chunk_size, nq), -1, dtype=np.int32)
                    bpad[:n_real] = b0[offset:hi]
                    ppad = np.full((chunk_size, max(1, plan.n_pvars)), -1, np.int32)
                    ppad[:n_real, : p0.shape[1]] = p0[offset:hi]
                    opad = np.full(chunk_size, -1, dtype=np.int32)
                    opad[:n_real] = org0[offset:hi]
                    args = (jnp.asarray(bpad), jnp.int32(n_real),
                            jnp.asarray(ppad), jnp.asarray(opad))
                fn = self._get_fn(plan, cap, chunk_size, extension)
                b, p, org, count, overflow = fn(*args, sarrs)
                if bool(overflow):
                    if cap >= opts.max_cap:
                        raise RuntimeError(
                            f"binding-table overflow at max capacity {opts.max_cap};"
                            " raise ExecOpts.max_cap")
                    cap = min(opts.max_cap, cap * 2)
                    retried += 1
                    continue
                c = int(count)
                total += c
                if collect == "bindings" and c:
                    out_b.append(np.asarray(b[:c]))
                    out_p.append(np.asarray(p[:c]))
                    o = np.asarray(org[:c])
                    if not extension:
                        o = o + offset  # chunk-local start index -> global
                    out_o.append(o)
                break
            offset = hi

        bindings = (np.concatenate(out_b) if out_b else _empty(plan)) \
            if collect == "bindings" else None
        pb = (np.concatenate(out_p) if out_p else _empty_p(plan)) \
            if collect == "bindings" else None
        origins = np.concatenate(out_o) if out_o else np.zeros(0, np.int32)
        return Result(total, bindings, pb, origins, chunks_retried=retried)


def _empty(plan: ExecPlan) -> np.ndarray:
    return np.zeros((0, plan.query.n_vertices), dtype=np.int32)


def _empty_p(plan: ExecPlan) -> np.ndarray:
    return np.zeros((0, max(1, plan.n_pvars)), dtype=np.int32)


def _next_pow2(x: int) -> int:
    return 1 << max(3, (max(1, x) - 1).bit_length())
