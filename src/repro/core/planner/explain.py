"""Human/JSON-facing plan rendering for ``SparqlEngine.explain()`` and the
``/sparql?explain=1`` endpoint: matching order, chosen start vertex, and
per-step fanout / cumulative-cardinality estimates."""

from __future__ import annotations

from repro.core.planner.ir import ExecPlan


def _vertex_name(q, u: int) -> str:
    qv = q.vertices[u]
    if qv.var is not None:
        return "?" + qv.var
    return qv.term or f"_v{u}"


def _predicate_name(maps, elabel: int) -> str | None:
    if maps is None or elabel < 0:
        return None
    try:
        return maps.dict.predicate(int(maps.elabel_to_pred[elabel]))
    except Exception:  # noqa: BLE001 — explain must never fail the query
        return None


def explain_plan(plan: ExecPlan, maps=None) -> dict:
    """JSON-able description of one compiled plan."""
    q = plan.query
    if plan.unsat:
        return {"unsat": True, "order": [], "steps": []}
    steps = []
    for i, s in enumerate(plan.steps):
        rec: dict = {
            "var": _vertex_name(q, s.u),
            "kind": "restart" if s.restart_candidates is not None else "expand",
            "est_fanout": (round(float(plan.est_fanout[i]), 3)
                           if i < len(plan.est_fanout) else None),
            "est_rows": (round(float(plan.est_rows[i]), 1)
                         if i < len(plan.est_rows) else None),
        }
        if s.parent >= 0:
            rec["parent"] = _vertex_name(q, s.parent)
            rec["forward"] = s.forward
        if s.elabel >= 0:
            pred = _predicate_name(maps, s.elabel)
            rec["predicate"] = pred if pred is not None else int(s.elabel)
        elif s.pvar_idx >= 0:
            rec["predicate"] = "?" + q.pvars[s.pvar_idx]
        if s.param_slot >= 0:
            # hoisted constant: the equality check reads params[k] at run
            # time instead of a baked vertex id
            rec["param"] = f"param[{s.param_slot}]"
        elif s.bound_id >= 0:
            rec["bound"] = True
        if s.nontree:
            rec["nontree_checks"] = len(s.nontree)
        if s.sig_mask is not None:
            rec["sig_probe"] = True
        if s.optional_group >= 0:
            rec["optional_group"] = s.optional_group
        if s.restart_candidates is not None:
            rec["restart_candidates"] = int(s.restart_candidates.shape[0])
        steps.append(rec)
    out = {
        "start_vertex": _vertex_name(q, plan.start_vertex),
        "start_candidates": int(plan.start_candidates.shape[0]),
        "order": [_vertex_name(q, u) for u in plan.order],
        "search": plan.search,
        "est_total_rows": round(float(plan.estimated_rows()), 1),
        "build_ms": round(plan.build_ms, 3),
        "steps": steps,
    }
    if plan.n_params:
        out["n_params"] = plan.n_params
        if plan.start_param_slot >= 0:
            out["start_param"] = f"param[{plan.start_param_slot}]"
    return out
