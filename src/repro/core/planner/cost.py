"""Cost model: per-edge fanout and per-vertex frequency estimates.

All estimates come from the graph's cached :class:`~repro.stats.GraphStats`
(built once per graph) instead of the ad-hoc inline recomputation the old
``core.plan`` helpers did on every ``build_plan`` call.  The unit of cost
is *expected rows produced per input row* when expanding a query edge —
exactly what the executor's capacity presizing consumes as ``est_fanout``.
"""

from __future__ import annotations

import numpy as np

from repro.core.query import QueryGraph
from repro.index import get_summary
from repro.rdf.graph import LabeledGraph
from repro.stats import GraphStats, get_stats

# a bound vertex keeps at most one row per input row; model it as strongly
# selective rather than zero so plans still prefer genuinely cheap edges
_BOUND_SELECTIVITY = 0.05
_LABEL_SELECTIVITY_FLOOR = 0.01

_UNSET = object()


class CostModel:
    """Fanout / frequency / candidate estimates for one (graph, stats) pair.

    ``observed`` optionally carries workload feedback from
    :mod:`repro.obs.workload`: per-edge observed ``(surviving, raw)``
    fanouts keyed ``(child, parent, elabel, forward)`` over query-vertex
    indices.  When an expansion matches a key, the observed surviving
    fanout replaces the static estimate in :meth:`edge_cost`, so an
    order-search re-run ranks edges by what actually happened instead of
    what the graph statistics predicted.  Purely an estimator override —
    it never changes which rows a plan produces.
    """

    def __init__(self, g: LabeledGraph, stats: GraphStats | None = None,
                 observed: dict[tuple[int, int, int, bool],
                                tuple[float, float]] | None = None):
        self.g = g
        self.stats = stats if stats is not None else get_stats(g)
        self.observed = observed or {}
        self._summary = _UNSET

    def observed_fanout(self, q: QueryGraph, ei: int,
                        parent: int) -> tuple[float, float] | None:
        """Workload-observed (surviving, raw) fanout for expanding edge
        ``ei`` away from ``parent``, or ``None`` when unobserved."""
        if not self.observed:
            return None
        e = q.edges[ei]
        forward = e.u == parent
        child = e.v if forward else e.u
        return self.observed.get((child, parent, e.elabel, forward))

    @property
    def summary(self):
        """The graph's (class, predicate, class) summary — lazily resolved
        because most CostModel uses never reach edge_cost."""
        if self._summary is _UNSET:
            self._summary = get_summary(self.g)
        return self._summary

    # ---------------------------------------------------------- vertex side
    def vertex_freq(self, q: QueryGraph, u: int) -> float:
        """Candidate-set size estimate for query vertex ``u`` (paper's
        freq(g, L(u)); predicate-index sizes for label-free vertices)."""
        qv = q.vertices[u]
        if qv.bound_id >= 0:
            return 1.0
        if qv.bound_id == -2:  # constant missing from data
            return 0.0
        if qv.labels:
            return float(self.stats.freq(qv.labels))
        # label-free: smallest predicate-index side among incident edges
        best = float(self.g.n_vertices)
        for e in q.edges:
            if e.elabel < 0:
                continue
            if e.u == u:
                best = min(best, float(self.stats.pred_sources(e.elabel, True)))
            if e.v == u:
                best = min(best, float(self.stats.pred_sources(e.elabel, False)))
        return best

    def candidates(self, q: QueryGraph, u: int) -> np.ndarray:
        """Materialized start-candidate set for query vertex ``u``."""
        g = self.g
        qv = q.vertices[u]
        if qv.bound_id >= 0:
            cand = np.array([qv.bound_id], dtype=np.int32)
            if qv.labels:  # ID + labels: verify label containment
                bm = g.label_bitmap[qv.bound_id]
                for lbl in qv.labels:
                    if not (bm[lbl >> 5] >> np.uint32(lbl & 31)) & np.uint32(1):
                        return np.zeros(0, dtype=np.int32)
            return cand
        if qv.bound_id == -2:
            return np.zeros(0, dtype=np.int32)
        if qv.labels:
            return g.candidates_with_labels(list(qv.labels))
        # label-free: smallest predicate-index side among incident edges
        best: np.ndarray | None = None
        for e in q.edges:
            if e.elabel < 0:
                continue
            subs, objs = g.predicate_index(e.elabel)
            side = subs if e.u == u else (objs if e.v == u else None)
            if side is not None and (best is None or side.shape[0] < best.shape[0]):
                best = side
        if best is not None:
            return best.astype(np.int32)
        return np.arange(g.n_vertices, dtype=np.int32)

    # ------------------------------------------------------------ edge side
    def edge_cost(self, q: QueryGraph, ei: int, parent: int) -> float:
        """Expected rows per input row when expanding edge ``ei`` away from
        ``parent``.  When both endpoints carry labels and the graph has a
        summary (:mod:`repro.index.summary`), the per-(class, predicate,
        class) edge count over the parent class's population is the
        estimate — real join selectivity instead of the global
        label-frequency discount; otherwise the average (predicate,
        direction) fanout discounted by the child's label selectivity."""
        e = q.edges[ei]
        forward = e.u == parent
        child = e.v if forward else e.u
        obs = self.observed.get((child, parent, e.elabel, forward))
        if obs is not None:
            return obs[0]
        qv = q.vertices[child]
        est = self.stats.avg_fanout(e.elabel, forward)
        if qv.bound_id >= 0:
            est = min(est, _BOUND_SELECTIVITY)
        elif qv.labels:
            sel = None
            if self.summary is not None:
                sel = self.summary.est_fanout(
                    e.elabel, forward, q.vertices[parent].labels, qv.labels)
            if sel is not None:
                est = max(sel, 1e-4)
            else:
                est *= max(_LABEL_SELECTIVITY_FLOOR,
                           self.stats.label_selectivity(qv.labels) * 4.0)
        return est

    def choose_start_vertex(self, q: QueryGraph, component: list[int]) -> int:
        """rank(u) = freq(g, L(u)) / deg(u) — the paper's start-vertex score."""
        adj = q.adjacency()
        best_u, best_score = component[0], float("inf")
        for u in component:
            deg = max(1, len(adj[u]))
            score = self.vertex_freq(q, u) / deg
            if score < best_score:
                best_score = score
                best_u = u
        return best_u
