"""The one plan builder: base patterns and OPTIONAL extensions alike.

``build_plan`` turns a :class:`~repro.core.query.QueryGraph` into an
:class:`~repro.core.planner.ir.ExecPlan`:

- **base mode** (``prebound=0``): per connected component, choose a start
  vertex (paper's rank), search a matching order (greedy / sampled / DP per
  ``estimate``), and emit expansion steps; secondary components enter
  through restart steps.
- **extension mode** (``prebound=k``): query vertices ``0..k-1`` are
  pre-bound table columns (OPTIONAL left joins); only the remaining
  vertices get steps, ordered by the same cost model — there is no second
  greedy loop anywhere, and no hardcoded fanout.

Per-step cost-model (or sampled, when available) fanout estimates land in
``est_fanout`` so the executor's capacity presizing runs on real numbers;
cumulative cardinality estimates land in ``est_rows`` for ``explain()``
and the serving-layer estimate-vs-actual metrics.

``force_order`` pins the matching order (tests and the planner benchmark
use it to compare orderings); an illegal order — one that binds a vertex
before any neighbor, or checks a predicate variable before binding it —
raises :class:`PlanError`.  On a multi-component query the forced order is
regrouped per connected component (components enter in order of first
appearance), since cross-component restarts are emitted per component.

When the estimate-driven order would leave two unbound-predicate-variable
edges converging on one vertex (no single step can bind both), the builder
retries once with :func:`~repro.core.planner.order.pvar_first_order`,
which binds pvar edges as tree edges eagerly; only if that also fails is
the query rejected.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.planner.cost import CostModel
from repro.core.planner.ir import (ExecPlan, NTCheck, OrderNotExecutable,
                                   PlanError, Step, np_cmp)
from repro.core.planner.order import (DP_MAX_VERTICES, dp_order, greedy_order,
                                      pvar_first_order, sampled_order)
from repro.core.query import QueryGraph
from repro.index import get_index, prune_candidates, required_signature
from repro.rdf.graph import LabeledGraph
from repro.utils import get_logger

log = get_logger("core.planner")

ESTIMATE_MODES = ("static", "sampled", "dp", "exhaustive")


def build_plan(
    g: LabeledGraph,
    q: QueryGraph,
    *,
    estimate: str = "sampled",
    num_filters: dict[str, list[tuple[str, float]]] | None = None,
    optional_groups: dict[int, int] | None = None,
    use_nlf: bool = False,
    use_deg: bool = False,
    use_sig: bool = True,
    prebound: int = 0,
    prebound_pvars: int = 0,
    force_order: list[int] | None = None,
    observed_fanout: dict[tuple[int, int, int, bool],
                          tuple[float, float]] | None = None,
) -> ExecPlan:
    """Build an execution plan for a (sub-)query.

    ``estimate`` selects the order search: ``static`` (cost-model greedy),
    ``sampled`` (paper's candidate-region estimation, greedy fallback), or
    ``dp`` / ``exhaustive`` (optimal order by subset DP for components with
    ≤ 8 free vertices, greedy fallback).  ``prebound`` > 0 switches to
    extension mode: vertices below it are pre-bound base columns and the
    plan only binds the rest (OPTIONAL left joins).  ``use_nlf`` /
    ``use_deg`` correspond to the paper's -NLF / -DEG toggles; ``use_sig``
    enables neighborhood-signature pruning (:mod:`repro.index`) of start
    and restart candidates plus per-step ``sig_mask`` probes.

    ``observed_fanout`` injects workload feedback (see
    :mod:`repro.obs.workload`): per-edge observed (surviving, raw)
    fanouts keyed ``(child, parent, elabel, forward)`` replace the
    static estimates in the cost model, so the order search and the
    executor's capacity presizing both run on observed numbers.  The
    sampled-order shortcut is skipped when feedback is present (its
    sampled fanouts would mask the observed ones).
    """
    if estimate not in ESTIMATE_MODES:
        raise PlanError(f"unknown estimate mode {estimate!r}; "
                        f"expected one of {ESTIMATE_MODES}")
    t0 = time.perf_counter()
    num_filters = num_filters or {}
    optional_groups = optional_groups or {}
    if q.unsat:
        return ExecPlan(q, 0, np.zeros(0, np.int32), [], [0] if q.n_vertices else [],
                        len(q.pvars), unsat=True)
    if q.n_vertices == 0:
        raise PlanError("empty query")
    cm = CostModel(g, observed=observed_fanout)

    sig_bits = get_index(g).n_bits if use_sig else None

    def attempt(pvar_first: bool) -> ExecPlan:
        if prebound:
            return _build_extension(g, cm, q, prebound, prebound_pvars,
                                    estimate, num_filters, optional_groups,
                                    use_nlf, use_deg, sig_bits, force_order,
                                    pvar_first)
        return _build_base(g, cm, q, estimate, num_filters, optional_groups,
                           use_nlf, use_deg, sig_bits, force_order,
                           pvar_first)

    try:
        plan = attempt(pvar_first=False)
    except OrderNotExecutable:
        if force_order is not None:
            raise  # the caller pinned the order; report it as-is
        # the estimate-driven order left an unbound-pvar edge as a non-tree
        # check; retry with an order that binds pvar edges as tree edges
        plan = attempt(pvar_first=True)
    plan.build_ms = (time.perf_counter() - t0) * 1e3
    return plan


# --------------------------------------------------------------------------
# base mode
# --------------------------------------------------------------------------


def _build_base(g, cm: CostModel, q: QueryGraph, estimate, num_filters,
                optional_groups, use_nlf, use_deg, sig_bits, force_order,
                pvar_first: bool = False) -> ExecPlan:
    comps = q.connected_components()
    adj = q.adjacency()
    if force_order is not None:
        if sorted(force_order) != list(range(q.n_vertices)):
            raise PlanError("force_order must be a permutation of the query "
                            "vertices")
        comp_of = {v: i for i, c in enumerate(comps) for v in c}
        comp_rank: list[int] = []
        comp_starts = [0] * len(comps)
        comp_order: list[list[int]] = [[] for _ in comps]
        for v in force_order:
            ci = comp_of[v]
            if ci not in comp_rank:
                comp_rank.append(ci)
                comp_starts[ci] = v
            comp_order[ci].append(v)
        search = "forced"
    else:
        comp_starts = [cm.choose_start_vertex(q, c) for c in comps]
        comp_rank = sorted(
            range(len(comps)), key=lambda i: cm.vertex_freq(q, comp_starts[i])
        )
        comp_order = [[] for _ in comps]  # filled per component below
        search = "greedy" if estimate == "static" else estimate

    steps: list[Step] = []
    global_order: list[int] = []
    placed: set[int] = set()
    edge_used = [False] * len(q.edges)
    start_vertex = comp_starts[comp_rank[0]]
    start_candidates = cm.candidates(q, start_vertex)
    est_fanout: list[float] = []
    est_expand: list[float] = []
    est_rows: list[float] = []
    rows = 1.0
    start_sig = None
    bound_pvars: dict[int, int] = {}  # pvar idx -> order position bound

    for rank_pos, ci in enumerate(comp_rank):
        comp = comps[ci]
        s = comp_starts[ci]
        cands = start_candidates if rank_pos == 0 else cm.candidates(q, s)
        if use_deg and cands.size:
            _, _, mo, mi = _nlf_masks(g, q, s)
            keep = (g.out.degree[cands] >= mo) & (g.inc.degree[cands] >= mi)
            cands = cands[keep]
        s_sig = None
        if sig_bits is not None:
            s_sig = required_signature(sig_bits, q, s, optional_groups)
            if s_sig.any():
                cands = prune_candidates(g, q, s, cands, optional_groups)
            else:
                s_sig = None
        if rank_pos == 0:
            start_candidates = cands
            start_sig = s_sig
            rows = float(max(1, cands.shape[0]))
        else:
            steps.append(Step(u=s, parent=-1, elabel=-1, forward=True,
                              labels=q.vertices[s].labels,
                              bound_id=max(q.vertices[s].bound_id, -1),
                              param_slot=q.vertices[s].param_slot,
                              optional_group=optional_groups.get(s, -1),
                              restart_candidates=cands,
                              sig_mask=s_sig))
            est_fanout.append(float(max(1, cands.shape[0])))
            est_expand.append(float(max(1, cands.shape[0])))
            rows *= float(max(1, cands.shape[0]))
            est_rows.append(rows)
        placed.add(s)
        global_order.append(s)

        # matching order within the component
        sampled_fanout: dict[int, float] = {}
        if force_order is not None:
            order = comp_order[ci]
        elif pvar_first:
            targets = set(comp) - {s}
            order = [s] + pvar_first_order(cm, q, adj, {s}, targets,
                                           optional_groups,
                                           bound0=set(bound_pvars))
            search = "pvar-first"
        else:
            order = None
            targets = set(comp) - {s}
            if estimate == "sampled":
                # live-store snapshots expose no raw CSR to sample from;
                # the cost-model greedy order stands in (estimates only —
                # snapshot answers used for candidates stay exact).  When
                # workload feedback is active, sampling is skipped too so
                # the observed fanouts in the cost model drive the order.
                hit = sampled_order(g, q, s, cands, optional_groups) \
                    if (getattr(g, "supports_sampled_order", True)
                        and not cm.observed) else None
                if hit is not None:
                    order, sampled_fanout = hit
                else:
                    search = "greedy"
            elif estimate in ("dp", "exhaustive"):
                tail = dp_order(cm, q, adj, {s}, sorted(targets), rows,
                                optional_groups)
                if tail is not None and len(tail) == len(targets):
                    order = [s] + tail
                else:
                    search = "greedy"
            if order is None:
                order = [s] + greedy_order(cm, q, adj, {s}, targets,
                                           optional_groups)
        # emit steps following `order`
        for w in order[1:]:
            step, f_card, f_raw = _emit_vertex_step(
                g, cm, q, w, placed, adj, edge_used, num_filters,
                optional_groups, use_nlf, use_deg, sig_bits, bound_pvars,
                pos=len(global_order))
            steps.append(step)
            f_presize = sampled_fanout.get(w)
            if (step.u, step.parent, step.elabel,
                    step.forward) in cm.observed:
                f_presize = None  # f_card/f_raw already carry observed data
            elif f_presize is None and step.parent == s and cands.size:
                # first hop off the start vertex: probe the *actual*
                # candidates (bounded sample) instead of the graph average
                f_presize = cm.stats.sampled_fanout(step.elabel, step.forward,
                                                    cands)
            est_fanout.append(f_card if f_presize is None else f_presize)
            est_expand.append(f_raw if f_presize is None
                              else max(f_raw, f_presize))
            rows *= max(f_card, 1e-3)
            est_rows.append(rows)
            placed.add(w)
            global_order.append(w)

    _attach_leftover_edges(q, steps, global_order, edge_used, bound_pvars)

    # start-vertex cheap numeric filters applied on host
    sv = q.vertices[start_vertex]
    start_nf: tuple = ()
    if sv.var and num_filters.get(sv.var) and g.numeric_value is not None:
        start_nf = tuple(num_filters[sv.var])
        vals = g.numeric_value[start_candidates]
        keep = np.ones(start_candidates.shape[0], bool)
        for op, c in start_nf:
            keep &= np_cmp(vals, op, c)
        start_candidates = start_candidates[keep]

    return ExecPlan(
        query=q,
        start_vertex=start_vertex,
        start_candidates=np.sort(start_candidates).astype(np.int32),
        steps=steps,
        order=global_order,
        n_pvars=len(q.pvars),
        n_params=1 + max((v.param_slot for v in q.vertices), default=-1),
        start_param_slot=q.vertices[start_vertex].param_slot,
        start_num_filters=start_nf,
        start_sig=start_sig,
        est_fanout=est_fanout,
        est_expand=est_expand,
        est_rows=est_rows,
        search=search,
    )


# --------------------------------------------------------------------------
# extension mode (OPTIONAL left joins)
# --------------------------------------------------------------------------


def _build_extension(g, cm: CostModel, q: QueryGraph, prebound: int,
                     prebound_pvars: int, estimate, num_filters,
                     optional_groups, use_nlf, use_deg, sig_bits,
                     force_order, pvar_first: bool = False) -> ExecPlan:
    adj = q.adjacency()
    seeds = set(range(prebound))
    targets = [v for v in range(q.n_vertices) if v >= prebound]
    if force_order is not None:
        if sorted(force_order) != targets:
            raise PlanError("force_order must be a permutation of the "
                            "extension vertices")
        order = list(force_order)
        search = "forced"
    elif pvar_first:
        order = pvar_first_order(cm, q, adj, seeds, set(targets),
                                 optional_groups,
                                 bound0=set(range(prebound_pvars)))
        search = "pvar-first"
    else:
        order = None
        search = "greedy"
        if estimate in ("dp", "exhaustive") and len(targets) <= DP_MAX_VERTICES:
            order = dp_order(cm, q, adj, seeds, targets, 1.0, optional_groups)
            if order is not None and len(order) == len(targets):
                search = "dp"
            else:
                order = None
        if order is None:
            order = greedy_order(cm, q, adj, seeds, set(targets),
                                 optional_groups)
    if len(order) != len(targets):
        raise PlanError("OPTIONAL pattern not connected to the base pattern")

    steps: list[Step] = []
    placed = set(seeds)
    edge_used = [False] * len(q.edges)
    global_order = list(range(prebound))
    est_fanout: list[float] = []
    est_expand: list[float] = []
    est_rows: list[float] = []
    rows = 1.0  # per-base-row multiplier: base table size is a runtime input
    # pvars of the base pattern are bound before any extension step runs
    bound_pvars: dict[int, int] = {i: -1 for i in range(prebound_pvars)}
    for w in order:
        step, f_card, f_raw = _emit_vertex_step(
            g, cm, q, w, placed, adj, edge_used, num_filters,
            optional_groups, use_nlf, use_deg, sig_bits, bound_pvars,
            pos=len(global_order))
        steps.append(step)
        est_fanout.append(f_card)
        est_expand.append(f_raw)
        rows *= max(f_card, 1e-3)
        est_rows.append(rows)
        placed.add(w)
        global_order.append(w)

    _attach_leftover_edges(q, steps, global_order, edge_used, bound_pvars,
                           extension=True)

    return ExecPlan(
        query=q,
        start_vertex=0,
        start_candidates=np.zeros(0, np.int32),
        steps=steps,
        order=global_order,
        n_pvars=len(q.pvars),
        est_fanout=est_fanout,
        est_expand=est_expand,
        est_rows=est_rows,
        search=search,
    )


# --------------------------------------------------------------------------
# shared step emission
# --------------------------------------------------------------------------


def _emit_vertex_step(g, cm: CostModel, q: QueryGraph, w: int, placed: set[int],
                      adj, edge_used: list[bool], num_filters,
                      optional_groups, use_nlf, use_deg, sig_bits,
                      bound_pvars: dict[int, int],
                      pos: int) -> tuple[Step, float, float]:
    """Emit the expansion step binding ``w`` from the placed set: cheapest
    tree edge plus every now-resolvable non-tree check.  Returns the step,
    its cost-model cardinality fanout (rows surviving the step's filters
    per input row), and the raw expansion factor (candidates produced per
    input row before filtering — the executor's capacity requirement).

    An edge whose predicate variable is not yet bound MUST be the tree edge
    (the executor's non-tree check rejects rows with unbound M_e), so such
    edges win tree-edge selection outright; if two of them with *different*
    predicate variables converge on ``w``, no single step can bind both and
    the order is rejected rather than silently dropping every row.
    """
    best_ei, best_cost = -1, float("inf")
    best_mandatory = False
    for ei, other in adj[w]:
        if edge_used[ei] or other not in placed:
            continue
        e = q.edges[ei]
        mandatory = e.elabel < 0 and _pvar_idx(q, e) not in bound_pvars
        if mandatory and not best_mandatory:
            best_cost = float("inf")  # unbound-pvar edges preempt the rest
            best_mandatory = True
        elif best_mandatory and not mandatory:
            continue
        cost = cm.edge_cost(q, ei, other)
        if cost < best_cost:
            best_cost, best_ei = cost, ei
    if best_ei < 0:
        raise PlanError(f"vertex {w} not connected to placed set")
    e = q.edges[best_ei]
    edge_used[best_ei] = True
    forward = e.u != w  # parent --> w when parent is subject
    parent = e.u if forward else e.v
    f_card = cm.edge_cost(q, best_ei, parent)
    f_raw = cm.stats.avg_fanout(e.elabel, forward)
    obs = cm.observed_fanout(q, best_ei, parent)
    if obs is not None:
        f_card, f_raw = obs[0], max(obs[0], obs[1])
    if e.pvar is not None:
        bound_pvars.setdefault(_pvar_idx(q, e), pos)
    # non-tree edges resolvable now (both endpoints placed after adding w)
    nts: list[NTCheck] = []
    for ei2, other2 in adj[w]:
        if edge_used[ei2]:
            continue
        e2 = q.edges[ei2]
        if e2.u == e2.v == w:  # self loop
            edge_used[ei2] = True
            _require_bound_pvar(q, e2, bound_pvars, pos)
            nts.append(NTCheck(other=w, elabel=e2.elabel, forward=True,
                               pvar_idx=_pvar_idx(q, e2), self_loop=True))
            continue
        if other2 in placed:
            edge_used[ei2] = True
            _require_bound_pvar(q, e2, bound_pvars, pos)
            fwd = e2.u == other2  # (other --el--> w)?
            nts.append(NTCheck(other=other2, elabel=e2.elabel, forward=fwd,
                               pvar_idx=_pvar_idx(q, e2)))
    om, im, mo, mi = _nlf_masks(g, q, w)
    qv = q.vertices[w]
    sig_mask = None
    if sig_bits is not None and qv.bound_id < 0:
        req = required_signature(sig_bits, q, w, optional_groups)
        if req.any() and e.elabel >= 0:
            # the tree edge itself already guarantees one bit of the
            # required signature (forward expansion: w has an incoming
            # e.elabel edge; backward: an outgoing one) — a probe whose
            # mask holds nothing *beyond* that bit is pure overhead
            probe = req.copy()
            t = e.elabel % sig_bits
            off = ((sig_bits + 31) // 32) if forward else 0
            probe[off + (t >> 5)] &= ~np.uint32(1 << (t & 31))
            if not probe.any():
                req = None
        sig_mask = req if req is not None and req.any() else None
    step = Step(
        u=w,
        parent=parent,
        elabel=e.elabel,
        forward=forward,
        pvar_idx=_pvar_idx(q, e),
        labels=qv.labels,
        bound_id=max(qv.bound_id, -1),
        param_slot=qv.param_slot,
        nontree=tuple(nts),
        min_out_ntypes=mo if use_deg else 0,
        min_in_ntypes=mi if use_deg else 0,
        nlf_out_mask=om if use_nlf else None,
        nlf_in_mask=im if use_nlf else None,
        num_filters=tuple(num_filters.get(qv.var or "", ())),
        optional_group=optional_groups.get(w, -1),
        sig_mask=sig_mask,
    )
    return step, f_card, f_raw


def _require_bound_pvar(q: QueryGraph, e, bound_pvars: dict[int, int],
                        limit: int) -> None:
    """A non-tree check on a predicate variable needs that variable bound by
    a tree edge no later than the checking step (position ``limit``) —
    otherwise the executor would reject every row.  Reject the order
    instead of producing silently-empty results."""
    if e.elabel < 0 and bound_pvars.get(_pvar_idx(q, e), 1 << 30) > limit:
        raise OrderNotExecutable(
            f"matching order checks predicate variable ?{e.pvar} before any "
            "tree edge binds it; this order is not executable")


def _attach_leftover_edges(q: QueryGraph, steps: list[Step],
                           global_order: list[int], edge_used: list[bool],
                           bound_pvars: dict[int, int],
                           extension: bool = False) -> None:
    """Edges whose endpoints were both placed without a connecting step
    become non-tree checks on the later endpoint's step."""
    if all(edge_used):
        return
    for ei, used in enumerate(edge_used):
        if used:
            continue
        e = q.edges[ei]
        later = max(global_order.index(e.u), global_order.index(e.v))
        w = global_order[later]
        for st in steps:
            if st.u == w:
                _require_bound_pvar(q, e, bound_pvars, later)
                other = e.u if e.v == w else e.v
                fwd = e.u == other
                st.nontree = (*st.nontree, NTCheck(other, e.elabel, fwd,
                                                   _pvar_idx(q, e)))
                edge_used[ei] = True
                break
    if not all(edge_used):
        if extension:
            raise PlanError("optional edge between two pre-bound vertices "
                            "unsupported; move it into the base pattern")
        raise PlanError("internal: unassigned query edges remain")


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------


def _pvar_idx(q: QueryGraph, e) -> int:
    return q.pvars.index(e.pvar) if e.pvar is not None else -1


def _nlf_masks(
    g: LabeledGraph, q: QueryGraph, u: int
) -> tuple[np.ndarray, np.ndarray, int, int]:
    """Query-side NLF masks + hom-weakened degree minimums for vertex u."""
    stride = g.n_vlabels + 1
    n_types = g.n_elabels * stride
    n_words = (n_types + 31) // 32
    masks = {True: np.zeros(n_words, np.uint32), False: np.zeros(n_words, np.uint32)}
    ntypes = {True: set(), False: set()}
    for e in q.edges:
        if e.elabel < 0:
            continue
        if e.u == u:
            other, out_dir = e.v, True
        elif e.v == u:
            other, out_dir = e.u, False
        else:
            continue
        labels = q.vertices[other].labels
        ts = [e.elabel * stride] if not labels else [
            e.elabel * stride + 1 + l for l in labels
        ]
        for t in ts:
            masks[out_dir][t >> 5] |= np.uint32(1 << (t & 31))
        ntypes[out_dir].add((e.elabel, labels))
    return masks[True], masks[False], len(ntypes[True]), len(ntypes[False])
