"""repro.core.planner — the cost-based planner subsystem.

Layers (bottom-up):

- :mod:`repro.core.planner.ir` — ``ExecPlan`` / ``Step`` / ``NTCheck``,
  the executor's input contract (unchanged from the original ``core.plan``);
- :mod:`repro.core.planner.cost` — ``CostModel`` over the graph's cached
  :class:`~repro.stats.GraphStats`: edge fanout, vertex frequency,
  candidate sets, start-vertex choice;
- :mod:`repro.core.planner.order` — matching-order search: greedy,
  sampled (paper §4.2 candidate-region estimation), and exact subset DP
  for small queries;
- :mod:`repro.core.planner.builder` — ``build_plan``, the single entry
  point for base patterns (``prebound=0``) and OPTIONAL extension plans
  (``prebound=k``: vertices below ``k`` are pre-bound table columns);
- :mod:`repro.core.planner.explain` — plan rendering for
  ``SparqlEngine.explain()`` / ``/sparql?explain=1``.

``repro.core.plan`` remains as a thin compatibility shim re-exporting this
package's names.
"""

from repro.core.planner.builder import ESTIMATE_MODES, build_plan
from repro.core.planner.cost import CostModel
from repro.core.planner.explain import explain_plan
from repro.core.planner.ir import (ExecPlan, NTCheck, OrderNotExecutable,
                                   PlanError, Step, np_cmp)
from repro.core.planner.order import (DP_MAX_VERTICES, dp_order, greedy_order,
                                      pvar_first_order, sampled_order)

__all__ = [
    "ESTIMATE_MODES",
    "DP_MAX_VERTICES",
    "CostModel",
    "ExecPlan",
    "NTCheck",
    "OrderNotExecutable",
    "PlanError",
    "Step",
    "build_plan",
    "dp_order",
    "explain_plan",
    "greedy_order",
    "np_cmp",
    "pvar_first_order",
    "sampled_order",
]


def choose_start_vertex(g, q, component):
    """Compatibility wrapper: paper's rank(u) start-vertex choice."""
    return CostModel(g).choose_start_vertex(q, component)
