"""Plan intermediate representation: the executor's input contract.

``ExecPlan`` / ``Step`` / ``NTCheck`` are exactly what
:mod:`repro.core.exec` compiles into a jitted chunk program; the planner
packages (:mod:`~repro.core.planner.cost`, ``order``, ``builder``) only
ever *produce* these.  The executor-facing fields are unchanged from the
original ``core.plan`` module; ``est_rows`` / ``search`` / ``build_ms``
are planner diagnostics consumed by ``SparqlEngine.explain()`` and the
serving metrics, and do not participate in ``signature()``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.query import QueryGraph


class PlanError(ValueError):
    pass


class OrderNotExecutable(PlanError):
    """The chosen matching order cannot run (e.g. it would check a
    predicate variable before any tree edge binds it).  ``build_plan``
    retries these once with a pvar-first order before giving up."""


@dataclass
class NTCheck:
    """Non-tree edge check executed when query vertex ``u`` is bound.

    The query edge is (other --elabel--> u) if ``forward`` else
    (u --elabel--> other); ``other`` is bound earlier in the order.
    """

    other: int
    elabel: int
    forward: bool
    pvar_idx: int = -1  # >= 0: edge label is that predicate variable's binding
    self_loop: bool = False  # query self-loop checked against u itself


@dataclass
class Step:
    u: int
    parent: int  # -1 for a cross-component restart step
    elabel: int  # -1 = predicate variable
    forward: bool  # parent --el--> u (out CSR) vs u --el--> parent (in CSR)
    pvar_idx: int = -1
    labels: tuple[int, ...] = ()
    bound_id: int = -1
    nontree: tuple[NTCheck, ...] = ()
    min_out_ntypes: int = 0  # hom-weakened degree filter constants
    min_in_ntypes: int = 0
    nlf_out_mask: np.ndarray | None = None  # uint32 words over neighbor types
    nlf_in_mask: np.ndarray | None = None
    num_filters: tuple[tuple[str, float], ...] = ()
    optional_group: int = -1  # -1 = required pattern
    # >= 0: the bound-id equality check reads params[param_slot] (a traced
    # scalar input of the chunk program) instead of the baked ``bound_id``
    param_slot: int = -1
    # restart steps expand the table by this component's start candidates
    restart_candidates: np.ndarray | None = None
    # required neighborhood signature (repro.index; uint32 [2W]) — tree
    # steps probe it in the executor step loop, restart steps re-apply it
    # when snapshot execution re-resolves their candidates.  Derived from
    # plan structure + graph, so (like the NLF masks) not in signature().
    sig_mask: np.ndarray | None = None


@dataclass
class ExecPlan:
    query: QueryGraph
    start_vertex: int
    start_candidates: np.ndarray  # int32, sorted
    steps: list[Step]
    order: list[int]  # query vertex order (including start)
    n_pvars: int
    unsat: bool = False
    # cheap numeric filters applied to the start candidates on the host —
    # kept as a *spec* so snapshot execution (live store) can re-resolve
    # the candidate set against a newer graph version than the plan's
    start_num_filters: tuple = ()
    # start-vertex required signature — the snapshot re-resolution spec,
    # exactly like ``start_num_filters`` (the baked candidate array already
    # has it applied)
    start_sig: np.ndarray | None = None
    # parameterized plans: number of constant slots (0 = fully baked) and
    # the start vertex's slot when the start itself is parameterized (the
    # executor then resolves start candidates from params at run time)
    n_params: int = 0
    start_param_slot: int = -1
    # estimated fanout per step (for capacity presizing)
    est_fanout: list[float] = field(default_factory=list)
    # raw per-step expansion factor (candidates produced per input row
    # BEFORE filtering) — what the executor's per-step capacity schedule
    # must hold, as opposed to ``est_fanout`` (rows surviving the filters)
    est_expand: list[float] = field(default_factory=list)
    # planner diagnostics (explain() / metrics; not part of the signature)
    est_rows: list[float] = field(default_factory=list)  # cumulative, per step
    search: str = "greedy"  # which order search produced this plan
    build_ms: float = 0.0  # wall time spent planning

    def signature(self) -> tuple:
        """Hashable identity for the compiled-executable cache."""
        return (
            self.start_vertex,
            tuple(
                (
                    s.u, s.parent, s.elabel, s.forward, s.pvar_idx, s.labels,
                    s.bound_id, s.min_out_ntypes, s.min_in_ntypes,
                    tuple((c.other, c.elabel, c.forward, c.pvar_idx, c.self_loop)
                          for c in s.nontree),
                    s.num_filters, s.optional_group, s.param_slot,
                    None if s.restart_candidates is None
                    else len(s.restart_candidates),
                )
                for s in self.steps
            ),
            self.n_pvars,
            self.n_params,
            self.start_param_slot,
        )

    def capacity_schedule(self, chunk: int, init_cap: int, max_cap: int,
                          slack: float = 1.0) -> tuple[int, ...]:
        """Per-step binding-table capacities for a chunk of ``chunk`` rows.

        ``caps[i]`` bounds the candidates step ``i`` may expand to; it is
        derived from the cumulative row estimate times the step's raw
        expansion factor (``est_expand``), widened by ``slack``, rounded up
        to a power of two (bounding executor recompiles to pow2 buckets),
        floored at ``min(init_cap, max_cap)``, and made monotone
        non-decreasing so an overflow-frozen table can always be carried
        forward losslessly.  Estimation errors are corrected at run time by
        the executor's suffix-resume doubling, so these are starting
        points, not guarantees.
        """
        cap_in = _next_pow2(chunk)
        floor = max(cap_in, min(_next_pow2(init_cap), max_cap))
        caps: list[int] = []
        # the planner's cumulative row estimates are for the full start set;
        # scale them down to one chunk (extension plans have no start set —
        # their est_rows are per-input-row multipliers, i.e. n0 == 1)
        n0 = max(1, self.start_candidates.shape[0])
        scale = chunk / n0 if self.start_candidates.shape[0] else float(chunk)
        rows = float(chunk)
        prev = floor
        for i in range(len(self.steps)):
            raw = self.est_expand[i] if i < len(self.est_expand) else 1.0
            need = rows * max(raw, 1.0) * slack
            c = _next_pow2(int(min(need, float(max_cap))))
            c = min(max_cap, max(prev, c))
            caps.append(c)
            prev = c
            if i < len(self.est_rows):
                rows = max(1.0, self.est_rows[i] * scale)
            else:
                f = self.est_fanout[i] if i < len(self.est_fanout) else 1.0
                rows = max(1.0, rows * min(max(f, 1e-3), 256.0))
        return tuple(caps)

    def estimated_rows(self) -> float:
        """Final estimated result cardinality.  A plan with no steps (point
        query / pure extension) is exactly its start-candidate count."""
        if self.unsat:
            return 0.0
        if self.est_rows:
            return self.est_rows[-1]
        return float(max(1, self.start_candidates.shape[0]))


def _next_pow2(x: int) -> int:
    return 1 << max(3, (max(1, x) - 1).bit_length())


def np_cmp(vals: np.ndarray, op: str, c: float) -> np.ndarray:
    if op == "<":
        return vals < c
    if op == "<=":
        return vals <= c
    if op == ">":
        return vals > c
    if op == ">=":
        return vals >= c
    if op == "=":
        return vals == c
    if op == "!=":
        return vals != c
    raise ValueError(op)
