"""Matching-order search: greedy, sampled (paper §4.2), and subset-DP.

All searches produce an ordering of the *new* vertices to bind, given a set
of already-placed seed vertices (the start vertex for base plans; every
pre-bound base column for OPTIONAL extension plans):

- ``greedy_order`` — repeatedly bind the vertex reachable from the placed
  set through the cheapest edge (cost-model average fanout × selectivity);
- ``sampled_order`` — the paper's candidate-region-size estimation: walk
  tree edges over the *actual* start candidates with host numpy and pick
  the child with the fewest total candidates.  Predicate-variable edges are
  sampled through the plain (all-predicate) CSR instead of aborting the
  whole query, so one ``?p`` edge no longer forfeits sampling for every
  labeled edge around it;
- ``dp_order`` — exact dynamic program over placed-subsets (Held-Karp
  style) minimizing the estimated sum of intermediate table sizes; only
  attempted when the number of new vertices is ≤ ``DP_MAX_VERTICES``.

The greedy and DP searches rank edges through ``cm.edge_cost``, so
workload-observed fanout overrides (``CostModel.observed``, fed by
:mod:`repro.obs.workload` q-error feedback) flow into order selection
automatically; ``sampled_order`` bypasses the cost model and is skipped
by the builder when feedback is active.
"""

from __future__ import annotations

import numpy as np

from repro.core.planner.cost import CostModel
from repro.core.query import QueryGraph
from repro.rdf.graph import LabeledGraph

DP_MAX_VERTICES = 8
_SAMPLE_START = 256  # start candidates sampled for region estimation
_SAMPLE_CHILD = 4096  # bounded child gather per level


# --------------------------------------------------------------------------
# greedy
# --------------------------------------------------------------------------


def greedy_order(cm: CostModel, q: QueryGraph, adj, seeds: set[int],
                 targets: set[int], optional_rank: dict[int, int]) -> list[int]:
    """Order ``targets`` by repeated cheapest-frontier-edge selection."""
    placed = set(seeds)
    remaining = set(targets)
    order: list[int] = []
    while remaining:
        best_w, best_cost = None, float("inf")
        for p in placed:
            for ei, w in adj[p]:
                if w not in remaining:
                    continue
                cost = cm.edge_cost(q, ei, p)
                cost += 1e6 * optional_rank.get(w, 0)  # optionals last
                if cost < best_cost:
                    best_cost, best_w = cost, w
        if best_w is None:
            break
        placed.add(best_w)
        remaining.discard(best_w)
        order.append(best_w)
    return order


def pvar_first_order(cm: CostModel, q: QueryGraph, adj, seeds: set[int],
                     targets: set[int],
                     optional_rank: dict[int, int],
                     bound0: set[int] | None = None) -> list[int]:
    """Greedy order that walks unbound-predicate-variable edges as tree
    edges as early as possible.  Fallback when the estimate-driven order
    would leave two unbound-pvar edges converging on one vertex (which no
    single step can bind — the builder rejects such orders)."""
    placed = set(seeds)
    remaining = set(targets)
    bound: set[int] = set(bound0 or ())  # pvar indices bound so far
    order: list[int] = []
    while remaining:
        best = None  # (cost, w, pvar_idx)
        for p in placed:
            for ei, w in adj[p]:
                if w not in remaining:
                    continue
                e = q.edges[ei]
                pv = q.pvars.index(e.pvar) if e.pvar is not None else -1
                cost = cm.edge_cost(q, ei, p)
                if pv >= 0 and pv not in bound:
                    cost *= 1e-6  # bind fresh pvars via tree edges first
                cost += 1e6 * optional_rank.get(w, 0)
                if best is None or cost < best[0]:
                    best = (cost, w, pv)
        if best is None:
            break
        _, w, pv = best
        if pv >= 0:
            bound.add(pv)
        placed.add(w)
        remaining.discard(w)
        order.append(w)
    return order


# --------------------------------------------------------------------------
# sampled (candidate-region estimation)
# --------------------------------------------------------------------------


def sampled_order(
    g: LabeledGraph,
    q: QueryGraph,
    start: int,
    candidates: np.ndarray,
    optional_rank: dict[int, int],
) -> tuple[list[int], dict[int, float]] | None:
    """Candidate-region estimation over the first chunk of real candidates.

    Returns ``(order, fanout)`` where ``order`` includes ``start`` and
    ``fanout[w]`` is the observed expansion fanout (rows produced per input
    row, pre-filter) for the step that binds ``w`` — the real number the
    executor's capacity presizing wants.  Returns ``None`` only when the
    walk cannot cover the component (e.g. every sampled region dies out).
    """
    sample = candidates[: min(_SAMPLE_START, candidates.shape[0])].astype(np.int64)
    if sample.size == 0:
        return None
    placed = {start}
    cand_of: dict[int, np.ndarray] = {start: sample}
    order = [start]
    fanout: dict[int, float] = {}
    adj = q.adjacency()
    remaining = {v for v in range(q.n_vertices)} - placed
    # restrict to this component
    comp = set()
    stack = [start]
    comp.add(start)
    while stack:
        cur = stack.pop()
        for _, w in adj[cur]:
            if w not in comp:
                comp.add(w)
                stack.append(w)
    remaining &= comp
    while remaining:
        frontier: list[tuple[float, int, float, np.ndarray]] = []
        for p in list(placed):
            for ei, w in adj[p]:
                if w in placed or w not in remaining:
                    continue
                e = q.edges[ei]
                forward = e.u == p
                d = g.out if forward else g.inc
                vp = cand_of[p]
                if e.elabel < 0:
                    # predicate-variable edge: sample through the plain CSR
                    # (any predicate matches), instead of bailing out
                    starts = d.indptr_all[vp]
                    ends = d.indptr_all[vp + 1]
                    nbr = d.nbr_all
                else:
                    starts = d.indptr_el[e.elabel, vp]
                    ends = d.indptr_el[e.elabel, vp + 1]
                    nbr = d.nbr_el
                degs = ends - starts
                total = int(degs.sum())
                # gather up to a bounded number of children for the next level
                child = _gather_bounded(nbr, starts, degs, bound=_SAMPLE_CHILD)
                child = _filter_by_labels(g, child, q.vertices[w].labels)
                if q.vertices[w].bound_id >= 0:
                    child = child[child == q.vertices[w].bound_id]
                cost = float(total) + 1e3 * optional_rank.get(w, 0)
                raw_fanout = total / max(1, vp.shape[0])
                frontier.append((cost, w, raw_fanout, np.unique(child)))
        if not frontier:
            break
        frontier.sort(key=lambda t: t[:2])
        _, w, raw_fanout, child = frontier[0]
        placed.add(w)
        remaining.discard(w)
        cand_of[w] = child if child.size else np.zeros(1, dtype=np.int64)
        order.append(w)
        fanout[w] = raw_fanout
    if len(order) != len(comp):
        return None
    return order, fanout


def _gather_bounded(nbr: np.ndarray, starts: np.ndarray, degs: np.ndarray, bound: int):
    take = np.minimum(degs, np.maximum(0, bound // max(1, len(starts))) + 1)
    parts = [nbr[s : s + t] for s, t in zip(starts, take) if t > 0]
    return np.concatenate(parts).astype(np.int64) if parts else np.zeros(0, np.int64)


def _filter_by_labels(g: LabeledGraph, verts: np.ndarray, labels) -> np.ndarray:
    if not len(labels) or verts.size == 0:
        return verts
    keep = np.ones(verts.shape[0], dtype=bool)
    for lbl in labels:
        keep &= ((g.label_bitmap[verts, lbl >> 5] >> np.uint32(lbl & 31)) & 1).astype(bool)
    return verts[keep]


# --------------------------------------------------------------------------
# exact subset DP
# --------------------------------------------------------------------------


def dp_order(cm: CostModel, q: QueryGraph, adj, seeds: set[int],
             targets: list[int], start_rows: float,
             optional_rank: dict[int, int]) -> list[int] | None:
    """Minimum estimated total intermediate rows over all legal orders.

    Held-Karp over subsets of ``targets`` (≤ ``DP_MAX_VERTICES``): a state
    is the set of already-bound targets; the transition binds one more
    vertex adjacent to seeds ∪ state, multiplying the running row estimate
    by the cheapest connecting edge's fanout.  Objective is the classic
    C_out sum of intermediate cardinalities.  Because the running row count
    is path-dependent (the cheapest edge into a vertex depends on *when* it
    is bound), each subset keeps the full Pareto frontier over
    (total_cost, rows) — a state dominated on cost alone may still own the
    optimal completion — capped at ``_DP_PARETO_CAP`` entries.
    Optional-group vertices may only be bound once every lower-ranked
    vertex is bound.
    """
    k = len(targets)
    if k == 0:
        return []
    if k > DP_MAX_VERTICES:
        return None
    t_index = {t: i for i, t in enumerate(targets)}
    rank = [optional_rank.get(t, 0) for t in targets]

    def fanout_into(mask: int, wi: int) -> float:
        """Cheapest edge from seeds ∪ mask into targets[wi]; inf if none."""
        w = targets[wi]
        best = float("inf")
        for ei, other in adj[w]:
            oi = t_index.get(other)
            if oi is None:
                if other in seeds:
                    best = min(best, cm.edge_cost(q, ei, other))
            elif mask >> oi & 1:
                best = min(best, cm.edge_cost(q, ei, other))
        return best

    full = (1 << k) - 1
    INF = float("inf")
    # dp[mask] = Pareto set of (total_cost, rows, order) — ascending cost,
    # descending rows
    dp: list[list[tuple[float, float, tuple[int, ...]]]] = \
        [[] for _ in range(full + 1)]
    dp[0] = [(0.0, max(1.0, start_rows), ())]
    for mask in range(full + 1):
        for total, rows, order in dp[mask]:
            for wi in range(k):
                if mask >> wi & 1:
                    continue
                # optional ordering constraint: lower ranks first
                if any(not (mask >> oi & 1) for oi in range(k)
                       if rank[oi] < rank[wi]):
                    continue
                f = fanout_into(mask, wi)
                if f == INF:
                    continue
                nrows = rows * max(f, 1e-3)
                state = (total + nrows, nrows, order + (wi,))
                _pareto_insert(dp[mask | (1 << wi)], state)
    if not dp[full]:
        return None
    best = min(dp[full])  # lowest total cost wins at the full set
    return [targets[wi] for wi in best[2]]


_DP_PARETO_CAP = 32


def _pareto_insert(states: list[tuple[float, float, tuple[int, ...]]],
                   new: tuple[float, float, tuple[int, ...]]) -> None:
    """Keep ``states`` a (cost, rows)-Pareto frontier sorted by cost."""
    nc, nr, _ = new
    for c, r, _o in states:
        if c <= nc and r <= nr:
            return  # dominated
    states[:] = [s for s in states if not (nc <= s[0] and nr <= s[1])]
    states.append(new)
    states.sort()
    if len(states) > _DP_PARETO_CAP:
        del states[_DP_PARETO_CAP:]
