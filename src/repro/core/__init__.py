from repro.core.exec import DeviceGraph, ExecOpts, Executor, Result
from repro.core.planner import (CostModel, ExecPlan, PlanError, build_plan,
                                choose_start_vertex)
from repro.core.query import QueryGraph, build_query_graph
from repro.core.sparql_exec import (CompiledBranch, CompiledOptional,
                                    CompiledQuery, QueryResult, SparqlEngine)

__all__ = [
    "DeviceGraph",
    "ExecOpts",
    "Executor",
    "Result",
    "CostModel",
    "ExecPlan",
    "PlanError",
    "build_plan",
    "choose_start_vertex",
    "QueryGraph",
    "build_query_graph",
    "QueryResult",
    "SparqlEngine",
    "CompiledQuery",
    "CompiledBranch",
    "CompiledOptional",
]
