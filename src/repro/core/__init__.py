from repro.core.exec import DeviceGraph, ExecOpts, Executor, Result
from repro.core.plan import ExecPlan, build_plan, choose_start_vertex
from repro.core.query import QueryGraph, build_query_graph
from repro.core.sparql_exec import (CompiledBranch, CompiledOptional,
                                    CompiledQuery, QueryResult, SparqlEngine)

__all__ = [
    "DeviceGraph",
    "ExecOpts",
    "Executor",
    "Result",
    "ExecPlan",
    "build_plan",
    "choose_start_vertex",
    "QueryGraph",
    "build_query_graph",
    "QueryResult",
    "SparqlEngine",
    "CompiledQuery",
    "CompiledBranch",
    "CompiledOptional",
]
