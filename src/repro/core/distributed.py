"""Distributed engine execution (paper §5.2, NUMA → mesh).

The paper parallelizes over *starting data vertices* with dynamic chunking
across NUMA sockets.  Here:

- ``run_sharded``: host-level scatter of starting-vertex chunks across the
  data-parallel axes via a shard_map'd chunk program (graph replicated —
  the analogue of the paper's per-socket round-robin page interleave),
  counts combined with ``psum``.  Used on real multi-device runs and tested
  with forced host devices.
- ``engine_chunk_step``: the SPMD query step the multi-pod dry-run lowers —
  the same expansion/filter/join pipeline as core.exec.build_chunk_fn, but
  expressed over explicit graph-array *arguments* so it can be lowered with
  ShapeDtypeStructs at production scale (billion-edge arrays, 512 devices).
  A unit test checks it against the host Executor on a real graph.
- dynamic chunk scheduling: ``GreedyChunker`` orders candidate chunks by
  estimated region size (degree sum) and deals them round-robin so every
  device gets a balanced workload — the paper's dynamic distribution,
  precomputed (SPMD programs cannot work-steal at runtime; imbalance shows
  up as stragglers, which the tracker in train/straggler.py surfaces).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.exec import ExecOpts, Executor, build_chunk_fn
from repro.core.planner import ExecPlan
from repro.kernels import ops as kops
from repro.utils import get_logger

log = get_logger("core.distributed")


# ---------------------------------------------------------------------------
# work partitioning (the paper's dynamic chunking, precomputed)
# ---------------------------------------------------------------------------


@dataclass
class GreedyChunker:
    """Deal starting vertices to D shards, balancing estimated region size."""

    n_shards: int

    def partition(self, candidates: np.ndarray, degree: np.ndarray):
        est = degree[candidates].astype(np.float64) + 1.0
        order = np.argsort(-est)  # heaviest first
        loads = np.zeros(self.n_shards)
        shard_of = np.zeros(candidates.shape[0], dtype=np.int32)
        for idx in order:
            s = int(np.argmin(loads))
            shard_of[idx] = s
            loads[s] += est[idx]
        shards = [candidates[shard_of == s] for s in range(self.n_shards)]
        width = max(1, max(s.shape[0] for s in shards))
        out = np.full((self.n_shards, width), -1, dtype=np.int32)
        counts = np.zeros(self.n_shards, dtype=np.int32)
        for s, arr in enumerate(shards):
            out[s, : arr.shape[0]] = arr
            counts[s] = arr.shape[0]
        return out, counts, loads


# ---------------------------------------------------------------------------
# host-level sharded execution over real devices
# ---------------------------------------------------------------------------


def run_sharded(executor: Executor, plan: ExecPlan, mesh,
                collect: str = "count"):
    """Execute a plan with starting chunks scattered over the mesh's data
    axes.  Single-program path: shard_map over ("data",) [+ "pod"]."""
    if getattr(executor, "view", None) is not None:
        # live-store snapshots re-resolve candidates per version and ship
        # delta arrays per call; the shard_map path below bakes both, so
        # route snapshot execution through the (correct) host loop
        return executor.run(plan, collect="count").count
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_shards = 1
    for a in dp:
        n_shards *= mesh.shape[a]
    cands = plan.start_candidates
    if cands.shape[0] == 0 or plan.unsat:
        return 0
    chunker = GreedyChunker(n_shards)
    chunks, counts, loads = chunker.partition(cands, executor.graph.out.degree)
    width = chunks.shape[1]
    cap = max(executor.opts.init_cap, 1 << max(6, (width - 1).bit_length()))
    # widen capacity by the plan's fanout estimate, like the host loop
    est = 1.0
    for f in plan.est_fanout:
        est *= max(1.0, min(f, 64.0))
    cap = min(executor.opts.max_cap,
              max(cap, 1 << int(np.ceil(np.log2(max(2.0, width * min(est, 512.0)))))))

    n_steps = len(plan.steps)
    fn = build_chunk_fn(executor.dg, plan, (cap,) * n_steps, width,
                        executor.opts, table_input=False, collect="count")
    sarrs = executor._arrays(plan)

    def local(chunk_row, count_row):
        _, _, _, count, ovf_step, _, _, _, _ = fn(
            chunk_row[0], count_row[0],
            jnp.zeros((width, max(1, plan.n_pvars)), jnp.int32),
            jnp.zeros((width,), jnp.int32), jnp.zeros(0, jnp.int32), sarrs)
        total = jax.lax.psum(count, dp)
        ovf = (ovf_step < jnp.int32(n_steps)).astype(jnp.int32)
        any_ovf = jax.lax.pmax(ovf, dp)
        return total, any_ovf

    spec_in = P(dp, None)
    mapped = jax.shard_map(
        local, mesh=mesh,
        in_specs=(spec_in, P(dp)),
        out_specs=(P(), P()),
        check_vma=False)
    total, ovf = jax.jit(mapped)(jnp.asarray(chunks), jnp.asarray(counts))
    if int(ovf) > 0:
        log.warning("sharded run overflowed capacity %d; falling back to host "
                    "loop with retry", cap)
        return executor.run(plan, collect="count").count
    return int(total)


# ---------------------------------------------------------------------------
# SPMD dry-run step (production-scale lowering)
# ---------------------------------------------------------------------------


def engine_chunk_step(nbr_el, iptr_rows, label_bitmap, chunk, chunk_count,
                      *, cap: int, n_steps: int, max_log_deg: int = 32):
    """One fused query-chunk step at production scale.

    Semantically the executor's plan program for an n_steps-deep tree query
    with a label filter per step and one non-tree join check at the last
    step (the Q2/Q9 triangle shape):

      nbr_el       int32 [n_edges]           (el,src,dst)-sorted adjacency
      iptr_rows    int32 [n_steps, n_v + 1]  per-step CSR indptr rows
      label_bitmap uint32 [n_v, W]           vertex label words
      chunk        int32 [chunk_width]       starting vertices (-1 padded)
      chunk_count  int32 []

    Returns (count, overflow).  shard over: chunk → (pod, data); graph
    arrays replicated; candidate axis work is local (psum at the end).
    """
    n_v = label_bitmap.shape[0]
    w = label_bitmap.shape[1]
    required = jnp.full((w,), jnp.uint32(1))  # representative label mask

    b = jnp.full((cap,), -1, jnp.int32).at[: chunk.shape[0]].set(chunk)
    count = jnp.minimum(chunk_count, cap)
    prev = b  # previous-step bindings (for the final join check)
    overflow = jnp.zeros((), bool)

    for step in range(n_steps):
        iptr = iptr_rows[step]
        alive = jnp.arange(cap, dtype=jnp.int32) < count
        vp = jnp.clip(b, 0, n_v - 1)
        start = iptr[vp]
        deg = jnp.where(alive, iptr[vp + 1] - start, 0)
        coffs = jnp.cumsum(deg)
        total = coffs[-1]
        offs = (coffs - deg).astype(jnp.int32)
        overflow |= total > cap
        row, j, valid = kops.ragged_expand(offs, deg, cap)
        idx = jnp.clip(start[row] + j, 0, nbr_el.shape[0] - 1)
        v_new = jnp.where(valid, nbr_el[idx], -1)
        ok = valid
        bm = label_bitmap[jnp.clip(v_new, 0, n_v - 1)]
        ok &= kops.bitmap_superset(bm, required)
        if step == n_steps - 1:
            # non-tree join: edge (prev_binding -> v_new) must exist
            pv = jnp.clip(b[row], 0, n_v - 1)
            lo = iptr_rows[0][pv]
            hi = iptr_rows[0][pv + 1]
            ok &= kops.edge_exists(nbr_el, lo, hi, v_new, n_iters=max_log_deg)
        prev = b
        # compact
        cnt = jnp.sum(ok.astype(jnp.int32))
        pos = jnp.where(ok, jnp.cumsum(ok.astype(jnp.int32)) - 1, cap)
        b = jnp.full((cap + 1,), -1, jnp.int32).at[pos].set(v_new)[:cap]
        count = cnt
    return count, overflow


def lower_engine_cell(mesh, cfg, cell_meta, multi_pod: bool):
    """Lower the SPMD engine step over the production mesh (dry-run)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    cap = cell_meta["cap"]
    chunk = cell_meta["chunk"]
    n_steps = cell_meta.get("n_steps", cfg.n_steps)
    w = (cfg.n_vlabels + 31) // 32

    def step(nbr_el, iptr_rows, label_bitmap, chunks, counts):
        local = partial(engine_chunk_step, cap=cap, n_steps=n_steps)

        def shard_fn(nbr, iptr, bm, ch, cnt):
            c, ovf = local(nbr, iptr, bm, ch[0], cnt[0])
            return jax.lax.psum(c, dp), jax.lax.pmax(ovf.astype(jnp.int32), dp)

        return jax.shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(), P(), P(), P(dp, None), P(dp)),
            out_specs=(P(), P()), check_vma=False,
        )(nbr_el, iptr_rows, label_bitmap, chunks, counts)

    n_shards = 1
    for a in dp:
        n_shards *= mesh.shape[a]
    sds = jax.ShapeDtypeStruct
    args = (
        sds((cfg.n_edges,), jnp.int32),
        sds((n_steps, cfg.n_vertices + 1), jnp.int32),
        sds((cfg.n_vertices, w), jnp.uint32),
        sds((n_shards, chunk), jnp.int32),
        sds((n_shards,), jnp.int32),
    )
    return jax.jit(step).lower(*args)
