"""Brute-force reference matcher — the correctness oracle for the executor.

Pure-python recursive backtracking over the same LabeledGraph + QueryGraph
representations, implementing Definition 1 (subgraph isomorphism) and
Definition 2 (e-graph homomorphism) directly.  O(n^|V(q)|) — test-sized
graphs only.
"""

from __future__ import annotations

import numpy as np

from repro.core.query import QueryGraph
from repro.rdf.graph import LabeledGraph


def _has_labels(g: LabeledGraph, v: int, labels) -> bool:
    for lbl in labels:
        if not (g.label_bitmap[v, lbl >> 5] >> np.uint32(lbl & 31)) & np.uint32(1):
            return False
    return True


def _edge_labels(g: LabeledGraph, u: int, v: int) -> list[int]:
    nbrs, labs = g.out.slice_all(u)
    return [int(l) for w, l in zip(nbrs, labs) if int(w) == v]


def enumerate_matches(
    g: LabeledGraph,
    q: QueryGraph,
    semantics: str = "hom",
) -> list[tuple[tuple[int, ...], tuple[int, ...]]]:
    """All solutions as (vertex bindings, pvar bindings) tuples, sorted."""
    if q.unsat:
        return []
    nq = q.n_vertices
    sols: list[tuple[tuple[int, ...], tuple[int, ...]]] = []
    binding = [-1] * nq
    pbind: dict[str, int] = {}

    def vertex_ok(qi: int, v: int) -> bool:
        qv = q.vertices[qi]
        if qv.bound_id >= 0 and v != qv.bound_id:
            return False
        if qv.bound_id == -2:
            return False
        if not _has_labels(g, v, qv.labels):
            return False
        if semantics == "iso":
            for other_qi, other_v in enumerate(binding):
                if other_qi != qi and other_v == v:
                    return False
        return True

    def edges_ok() -> bool:
        # full check over completely bound edges with current partial binding
        for e in q.edges:
            bu, bv = binding[e.u], binding[e.v]
            if bu < 0 or bv < 0:
                continue
            labels = _edge_labels(g, bu, bv)
            if e.elabel >= 0:
                if e.elabel not in labels:
                    return False
            elif e.pvar is not None:
                want = pbind.get(e.pvar)
                if want is not None:
                    if want not in labels:
                        return False
        return True

    def rec(qi: int):
        if qi == nq:
            # assign predicate variables (may branch over multiple labels)
            free_edges = [e for e in q.edges if e.pvar is not None]

            def assign(idx: int, cur: dict[str, int]):
                if idx == len(free_edges):
                    sols.append(
                        (tuple(binding),
                         tuple(cur.get(pv, -1) for pv in q.pvars))
                    )
                    return
                e = free_edges[idx]
                labels = _edge_labels(g, binding[e.u], binding[e.v])
                want = cur.get(e.pvar)
                for lbl in sorted(set(labels)):
                    if want is not None and lbl != want:
                        continue
                    nxt = dict(cur)
                    nxt[e.pvar] = lbl
                    assign(idx + 1, nxt)

            assign(0, {})
            return
        for v in range(g.n_vertices):
            if not vertex_ok(qi, v):
                continue
            binding[qi] = v
            if edges_ok():
                rec(qi + 1)
            binding[qi] = -1

    rec(0)
    return sorted(set(sols))
