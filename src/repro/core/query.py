"""Query graphs: SPARQL basic graph patterns transformed per §3.2 / §4.1.

``build_query_graph(triples, maps)`` applies the SAME transformation to the
query that was applied to the data (Definition 3's requirement that
F_ID = F'_ID, F_VL = F'_VL, F_EL = F'_EL):

- type-aware maps: ``?x rdf:type C`` triples vanish into ``L(?x) ∋ F_VL(C)``;
  everything else becomes a query edge.  A constant subject/object becomes a
  query vertex with a bound ID attribute; a variable predicate becomes a
  blank edge label with a named predicate variable (e-graph homomorphism's
  M_e binding).
- direct maps: type triples stay ordinary edges; class IRIs are plain bound
  vertices.

``unsat`` is set when a constant term does not exist in the data at all (the
query provably has zero solutions — the executor short-circuits).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.rdf.dictionary import RDF_TYPE, RDFS_SUBCLASSOF
from repro.rdf.sparql import Iri, Literal, TriplePattern, Var
from repro.rdf.transform import TransformMaps


@dataclass
class QVertex:
    var: str | None  # variable name, None for constants
    labels: tuple[int, ...] = ()  # required vertex labels (type-aware)
    bound_id: int = -1  # data vertex id (ID attribute), -1 if free
    # original term string for diagnostics
    term: str | None = None
    # parameter slot when the bound id is a plan parameter (-1 = literal);
    # the executor reads the actual id from params[param_slot] at run time
    param_slot: int = -1


@dataclass
class QEdge:
    u: int  # subject query-vertex index
    v: int  # object query-vertex index
    elabel: int  # edge label id, -1 = blank (predicate variable)
    pvar: str | None = None  # predicate variable name when elabel == -1


@dataclass
class QueryGraph:
    vertices: list[QVertex] = field(default_factory=list)
    edges: list[QEdge] = field(default_factory=list)
    var_to_vertex: dict[str, int] = field(default_factory=dict)
    pvars: list[str] = field(default_factory=list)
    unsat: bool = False
    # a parameterized constant was missing from the dictionary — the family
    # representative cannot anchor cost estimation (callers treat the shape
    # as ineligible for parameterized compilation rather than unsat)
    param_missing: bool = False

    @property
    def n_vertices(self) -> int:
        return len(self.vertices)

    def vertex_for_var(self, name: str) -> int | None:
        return self.var_to_vertex.get(name)

    def adjacency(self) -> list[list[tuple[int, int]]]:
        """Undirected incidence: vertex -> [(edge_idx, other_vertex)]."""
        adj: list[list[tuple[int, int]]] = [[] for _ in self.vertices]
        for ei, e in enumerate(self.edges):
            adj[e.u].append((ei, e.v))
            adj[e.v].append((ei, e.u))
        return adj

    def connected_components(self) -> list[list[int]]:
        seen = [False] * self.n_vertices
        adj = self.adjacency()
        comps = []
        for s in range(self.n_vertices):
            if seen[s]:
                continue
            comp = [s]
            seen[s] = True
            stack = [s]
            while stack:
                cur = stack.pop()
                for _, w in adj[cur]:
                    if not seen[w]:
                        seen[w] = True
                        comp.append(w)
                        stack.append(w)
            comps.append(comp)
        return comps


class QueryBuildError(ValueError):
    pass


def build_query_graph(triples: list[TriplePattern], maps: TransformMaps,
                      param_ids: dict[int, int] | None = None) -> QueryGraph:
    """``param_ids`` maps ``id(term)`` of hoisted constant occurrences to
    their parameter slot (the parser builds a fresh term object per
    occurrence, so object identity distinguishes occurrences of equal
    constants).  A parameterized occurrence still resolves its bound id (the
    representative's constant anchors cost estimation) but a miss sets
    ``param_missing`` instead of ``unsat`` — other family members may well
    resolve."""
    q = QueryGraph()

    def vertex_of(term) -> int:
        if isinstance(term, Var):
            idx = q.var_to_vertex.get(term.name)
            if idx is None:
                idx = len(q.vertices)
                q.vertices.append(QVertex(var=term.name, term="?" + term.name))
                q.var_to_vertex[term.name] = idx
            return idx
        # constant: IRI or literal — bound vertex (the ID attribute)
        text = term.value if isinstance(term, Iri) else f'"{term.value}"'
        vid = maps.vertex_of(text)
        idx = len(q.vertices)
        slot = -1 if param_ids is None else param_ids.get(id(term), -1)
        q.vertices.append(
            QVertex(var=None, bound_id=vid if vid is not None else -2,
                    term=text, param_slot=slot)
        )
        if vid is None:
            if slot >= 0:
                q.param_missing = True
            else:
                q.unsat = True
        return idx

    type_aware = maps.kind == "type_aware"
    for tp in triples:
        pred = tp.p
        if isinstance(pred, Iri) and pred.value == RDF_TYPE and type_aware:
            if isinstance(tp.o, Var):
                raise QueryBuildError(
                    "variable rdf:type objects need the direct transformation "
                    "(type edges are folded away under type-aware transform)"
                )
            if not isinstance(tp.s, (Var,)):
                # constant subject with type assertion: fold into its labels too
                sv = vertex_of(tp.s)
                lbl = maps.vlabel_of(tp.o.value)
                if lbl is None:
                    q.unsat = True
                else:
                    q.vertices[sv].labels = tuple(sorted({*q.vertices[sv].labels, lbl}))
                continue
            sv = vertex_of(tp.s)
            lbl = maps.vlabel_of(tp.o.value)
            if lbl is None:
                q.unsat = True
            else:
                q.vertices[sv].labels = tuple(sorted({*q.vertices[sv].labels, lbl}))
            continue
        if isinstance(pred, Iri) and pred.value == RDFS_SUBCLASSOF and type_aware:
            raise QueryBuildError(
                "rdf:subClassOf query edges are not representable after the "
                "type-aware transformation; use the direct transformation"
            )
        sv = vertex_of(tp.s)
        ov = vertex_of(tp.o)
        if isinstance(pred, Var):
            if pred.name not in q.pvars:
                q.pvars.append(pred.name)
            q.edges.append(QEdge(sv, ov, -1, pvar=pred.name))
        else:
            if not isinstance(pred, Iri):
                raise QueryBuildError("literal in predicate position")
            el = maps.elabel_of(pred.value)
            if el is None:
                q.unsat = True
                el = -2  # sentinel: known-missing predicate
            q.edges.append(QEdge(sv, ov, el if el is not None else -2))
    return q
