"""Full SPARQL evaluation: BGP + OPTIONAL + FILTER + UNION (paper §5.1).

Orchestrates the vectorized executor:

- the required basic graph pattern runs first (one ExecPlan);
- each OPTIONAL group becomes an *extension plan* left-joined onto the base
  table: rows with ≥1 optional match take the matched rows, rows with none
  keep the base bindings with nulls — the paper's all-or-nothing OPTIONAL
  semantics realized as a group-level outer join (the nullify-and-keep-
  searching + qualify-and-exclude-duplicate pair collapses into this join,
  so no duplicate-exclusion pass is needed);
- FILTERs: cheap single-variable numeric comparisons are pushed into the
  expansion steps (inline), expensive ones (regex, var-var comparisons)
  are applied to the final table (the paper's strategy);
- UNION branches are evaluated independently and concatenated (SPARQL UNION
  keeps duplicates, as the paper notes).
"""

from __future__ import annotations

import re as _re
from dataclasses import dataclass, field

import numpy as np

from repro.core.exec import ExecOpts, Executor, Result
from repro.core.plan import ExecPlan, build_plan
from repro.core.query import QueryGraph, build_query_graph
from repro.rdf.sparql import (Comparison, GroupPattern, Literal, Regex,
                              SelectQuery, Var, parse_sparql)
from repro.rdf.transform import TransformMaps
from repro.utils import get_logger

log = get_logger("core.sparql")


@dataclass
class QueryResult:
    variables: list[str]  # projected variable names (vertex vars + pvars)
    rows: np.ndarray  # int32 [n, n_vars] vertex ids / edge-label ids / -1=null
    kinds: list[str]  # per column: "vertex" | "predicate"
    count: int = 0
    stats: dict = field(default_factory=dict)

    def decode(self, maps: TransformMaps, limit: int | None = None) -> list[dict]:
        out = []
        n = self.rows.shape[0] if limit is None else min(limit, self.rows.shape[0])
        for i in range(n):
            rec = {}
            for c, var in enumerate(self.variables):
                vid = int(self.rows[i, c])
                if vid < 0:
                    rec[var] = None
                elif self.kinds[c] == "vertex":
                    rec[var] = maps.dict.term(int(maps.vertex_to_term[vid]))
                else:
                    rec[var] = maps.dict.predicate(int(maps.elabel_to_pred[vid]))
            out.append(rec)
        return out


class SparqlEngine:
    """End-to-end SPARQL evaluation against one transformed graph."""

    def __init__(self, graph, maps: TransformMaps, opts: ExecOpts | None = None,
                 estimate: str = "sampled"):
        self.graph = graph
        self.maps = maps
        self.opts = opts or ExecOpts()
        self.estimate = estimate
        self.executor = Executor(graph, self.opts)
        self._plan_cache: dict[str, list] = {}

    # ------------------------------------------------------------------ API
    def query(self, sparql: str, collect: str = "bindings") -> QueryResult:
        ast = parse_sparql(sparql)
        return self.query_ast(ast, collect=collect)

    def query_ast(self, ast: SelectQuery, collect: str = "bindings") -> QueryResult:
        branches = self._expand_unions(ast.where)
        all_rows: list[np.ndarray] = []
        variables: list[str] | None = None
        kinds: list[str] | None = None
        total = 0
        for branch in branches:
            res, q, vrs, knd = self._eval_group(branch, ast.select)
            if variables is None:
                variables, kinds = vrs, knd
            total += res.shape[0]
            # align columns across branches (UNION branches may differ)
            if vrs != variables:
                res = _align_columns(res, vrs, variables)
            all_rows.append(res)
        rows = np.concatenate(all_rows) if all_rows else np.zeros((0, 0), np.int32)
        return QueryResult(variables or [], rows, kinds or [], count=int(rows.shape[0]))

    def count(self, sparql: str) -> int:
        return self.query(sparql).count

    # ----------------------------------------------------------- internals
    def _expand_unions(self, g: GroupPattern) -> list[GroupPattern]:
        """Cartesian expansion of UNION blocks into flat branch groups."""
        branches = [GroupPattern(list(g.triples), list(g.filters),
                                 list(g.optionals), [])]
        for union in g.unions:
            new: list[GroupPattern] = []
            for b in branches:
                for alt in union:
                    for alt_flat in self._expand_unions(alt):
                        nb = GroupPattern(
                            b.triples + alt_flat.triples,
                            b.filters + alt_flat.filters,
                            b.optionals + alt_flat.optionals,
                            [],
                        )
                        new.append(nb)
            branches = new
        return branches

    def _eval_group(self, g: GroupPattern, select: list[str]):
        q = build_query_graph(g.triples, self.maps)
        cheap, expensive = _split_filters(g.filters, q)
        plan = build_plan(self.graph, q, estimate=self.estimate,
                          num_filters=cheap,
                          use_nlf=self.opts.use_nlf, use_deg=self.opts.use_deg)
        res = self.executor.run(plan)
        table = res.bindings
        ptable = res.pvar_bindings
        # expensive filters on the base table
        table, ptable = self._apply_expensive(table, ptable, q, expensive)

        # OPTIONAL groups: group-level left join
        col_offset: dict[str, int] = {}
        q_all = q
        for og in g.optionals:
            table, ptable, q_all = self._left_join(table, ptable, q_all, og)

        # projection
        variables: list[str] = []
        kinds: list[str] = []
        cols: list[np.ndarray] = []
        want = select or [v for v in q_all.var_to_vertex] + q_all.pvars
        for var in want:
            if var in q_all.var_to_vertex:
                variables.append(var)
                kinds.append("vertex")
                cols.append(table[:, q_all.var_to_vertex[var]])
            elif var in q_all.pvars:
                variables.append(var)
                kinds.append("predicate")
                cols.append(ptable[:, q_all.pvars.index(var)])
            else:
                variables.append(var)
                kinds.append("vertex")
                cols.append(np.full(table.shape[0], -1, np.int32))
        rows = np.stack(cols, axis=1) if cols else np.zeros((table.shape[0], 0),
                                                            np.int32)
        return rows, q_all, variables, kinds

    def _left_join(self, table: np.ndarray, ptable: np.ndarray,
                   q_base: QueryGraph, og: GroupPattern):
        """Left-outer join an OPTIONAL group onto the current table."""
        # Build a combined query graph: base vars are *seeds* (shared vars
        # join on them), new vars extend.
        combined = _merge_query(q_base, og.triples, self.maps)
        q_ext, new_vertex_map, base_cols = combined
        cheap, expensive = _split_filters(og.filters, q_ext)
        # extension plan: steps that bind the new vertices starting from rows
        plan = _extension_plan(self.graph, q_ext, base_cols, cheap, self.opts,
                               self.estimate)
        nq_ext = q_ext.n_vertices
        b0 = np.full((table.shape[0], nq_ext), -1, dtype=np.int32)
        b0[:, : table.shape[1]] = table
        p0 = np.full((table.shape[0], max(1, len(q_ext.pvars))), -1, np.int32)
        p0[:, : ptable.shape[1]] = ptable
        org0 = np.arange(table.shape[0], dtype=np.int32)
        if plan.unsat or table.shape[0] == 0:
            matched = Result(0, np.zeros((0, nq_ext), np.int32),
                             np.zeros((0, max(1, len(q_ext.pvars))), np.int32),
                             np.zeros(0, np.int32))
        else:
            matched = self.executor.run(plan, initial=(b0, p0, org0))
        mt, mp = self._apply_expensive(matched.bindings, matched.pvar_bindings,
                                       q_ext, expensive,
                                       origins=matched.origins)
        morg = mt[1]
        mt, mp = mt[0], mp
        # rows with no optional match: keep base + nulls
        has_match = np.zeros(table.shape[0], dtype=bool)
        if morg.shape[0]:
            has_match[morg] = True
        unmatched = np.flatnonzero(~has_match)
        un_b = np.full((unmatched.shape[0], nq_ext), -1, dtype=np.int32)
        un_b[:, : table.shape[1]] = table[unmatched]
        un_p = np.full((unmatched.shape[0], mp.shape[1]), -1, np.int32)
        un_p[:, : ptable.shape[1]] = ptable[unmatched]
        new_table = np.concatenate([mt, un_b], axis=0)
        new_ptable = np.concatenate([mp, un_p], axis=0)
        return new_table, new_ptable, q_ext

    def _apply_expensive(self, table, ptable, q: QueryGraph, filters,
                         origins=None):
        keep = np.ones(table.shape[0], dtype=bool)
        g = self.graph
        for f in filters:
            if isinstance(f, Regex):
                col = q.var_to_vertex.get(f.var.name)
                if col is None:
                    continue
                pat = _re.compile(f.pattern)
                vals = table[:, col]
                km = np.zeros(table.shape[0], dtype=bool)
                for i, v in enumerate(vals):
                    if v >= 0:
                        term = self.maps.dict.term(int(self.maps.vertex_to_term[v]))
                        km[i] = bool(pat.search(term.strip('"')))
                keep &= km
            elif isinstance(f, Comparison):
                lv = _col_values(f.lhs, table, q, g)
                rv = _col_values(f.rhs, table, q, g)
                if lv is None or rv is None:
                    continue
                from repro.core.plan import _np_cmp

                with np.errstate(invalid="ignore"):
                    keep &= _np_cmp(lv - rv + 0.0, f.op, 0.0) if np.ndim(rv) else \
                        _np_cmp(lv, f.op, float(rv))
        table = table[keep]
        ptable = ptable[keep]
        if origins is not None:
            return (table, origins[keep]), ptable
        return table, ptable


# --------------------------------------------------------------------------


def _col_values(term, table, q: QueryGraph, g):
    if isinstance(term, Var):
        col = q.var_to_vertex.get(term.name)
        if col is None or g.numeric_value is None:
            return None
        ids = np.clip(table[:, col], 0, g.n_vertices - 1)
        vals = g.numeric_value[ids].copy()
        vals[table[:, col] < 0] = np.nan
        return vals
    if isinstance(term, Literal) and term.numeric is not None:
        return term.numeric
    return None


def _split_filters(filters, q: QueryGraph):
    """cheap: {var: [(op, const)]} pushed inline; expensive: post-hoc list."""
    cheap: dict[str, list[tuple[str, float]]] = {}
    expensive = []
    for f in filters:
        if (isinstance(f, Comparison) and isinstance(f.lhs, Var)
                and isinstance(f.rhs, Literal) and f.rhs.numeric is not None):
            cheap.setdefault(f.lhs.name, []).append((f.op, f.rhs.numeric))
        elif (isinstance(f, Comparison) and isinstance(f.rhs, Var)
              and isinstance(f.lhs, Literal) and f.lhs.numeric is not None):
            flipped = {"<": ">", "<=": ">=", ">": "<", ">=": "<=",
                       "=": "=", "!=": "!="}[f.op]
            cheap.setdefault(f.rhs.name, []).append((flipped, f.lhs.numeric))
        else:
            expensive.append(f)
    return cheap, expensive


def _merge_query(q_base: QueryGraph, opt_triples, maps):
    """Extend a base query graph with OPTIONAL triples; base vertices keep
    their column indices, new vertices append."""
    from repro.core.query import build_query_graph as _bqg

    # Build combined graph over base + optional triples by rebuilding with
    # the base's variable order fixed first.
    q_ext = QueryGraph()
    q_ext.vertices = [  # copy base vertices
        type(v)(var=v.var, labels=v.labels, bound_id=v.bound_id, term=v.term)
        for v in q_base.vertices
    ]
    q_ext.var_to_vertex = dict(q_base.var_to_vertex)
    q_ext.pvars = list(q_base.pvars)
    q_ext.unsat = q_base.unsat
    # note: base edges already satisfied; extension plan only needs new edges
    tmp = _bqg(opt_triples, maps)
    # remap tmp vertices into q_ext
    remap: dict[int, int] = {}
    for ti, tv in enumerate(tmp.vertices):
        if tv.var is not None and tv.var in q_ext.var_to_vertex:
            idx = q_ext.var_to_vertex[tv.var]
            # merge labels onto the existing vertex (type triples in OPTIONAL)
            merged = tuple(sorted({*q_ext.vertices[idx].labels, *tv.labels}))
            q_ext.vertices[idx].labels = merged
        else:
            idx = len(q_ext.vertices)
            q_ext.vertices.append(
                type(tv)(var=tv.var, labels=tv.labels, bound_id=tv.bound_id,
                         term=tv.term))
            if tv.var is not None:
                q_ext.var_to_vertex[tv.var] = idx
        remap[ti] = idx
    new_edges = []
    for e in tmp.edges:
        pv = e.pvar
        if pv is not None and pv not in q_ext.pvars:
            q_ext.pvars.append(pv)
        new_edges.append(type(e)(remap[e.u], remap[e.v], e.elabel, pv))
    q_ext.edges = new_edges  # ONLY the optional edges (extension steps)
    q_ext.unsat = q_ext.unsat or tmp.unsat
    base_cols = q_base.n_vertices
    return q_ext, remap, base_cols


def _extension_plan(graph, q_ext: QueryGraph, base_cols: int, cheap, opts,
                    estimate) -> ExecPlan:
    """Plan binding the new vertices of q_ext, starting from bound base rows.

    Builds a standard plan but marks base vertices as pre-bound: expansion
    steps are emitted only for vertices >= base_cols (or base vertices that
    gained labels are re-checked via a filter step).
    """
    from repro.core.plan import ExecPlan, NTCheck, PlanError, Step, _nlf_masks

    placed = set(range(base_cols))
    steps: list[Step] = []
    order = list(range(base_cols))
    edges = list(q_ext.edges)
    edge_used = [False] * len(edges)
    remaining = {i for i in range(len(q_ext.vertices)) if i >= base_cols}
    est_fanout: list[float] = []
    # greedy: repeatedly bind a new vertex adjacent to placed set
    guard = 0
    while remaining and guard < 1000:
        guard += 1
        progress = False
        for ei, e in enumerate(edges):
            if edge_used[ei]:
                continue
            u_in, v_in = e.u in placed, e.v in placed
            if u_in and v_in:
                continue  # becomes a non-tree check later
            if not (u_in or v_in):
                continue
            w = e.v if u_in else e.u
            parent = e.u if u_in else e.v
            forward = e.u == parent
            edge_used[ei] = True
            nts: list[NTCheck] = []
            for ei2, e2 in enumerate(edges):
                if edge_used[ei2]:
                    continue
                if e2.u == e2.v == w:
                    edge_used[ei2] = True
                    nts.append(NTCheck(w, e2.elabel, True,
                                       _pvar(q_ext, e2), self_loop=True))
                elif {e2.u, e2.v} <= placed | {w} and w in (e2.u, e2.v):
                    edge_used[ei2] = True
                    other = e2.u if e2.v == w else e2.v
                    nts.append(NTCheck(other, e2.elabel, e2.u == other,
                                       _pvar(q_ext, e2)))
            qv = q_ext.vertices[w]
            steps.append(Step(
                u=w, parent=parent, elabel=e.elabel, forward=forward,
                pvar_idx=_pvar(q_ext, e), labels=qv.labels,
                bound_id=max(qv.bound_id, -1), nontree=tuple(nts),
                num_filters=tuple(cheap.get(qv.var or "", ()))))
            est_fanout.append(4.0)
            placed.add(w)
            order.append(w)
            remaining.discard(w)
            progress = True
            break
        if not progress:
            break
    if remaining:
        raise PlanError("OPTIONAL pattern not connected to the base pattern")
    # leftover edges between placed vertices -> non-tree checks on last step
    for ei, e in enumerate(edges):
        if edge_used[ei]:
            continue
        later = max(order.index(e.u), order.index(e.v))
        w = order[later]
        attached = False
        for st in steps:
            if st.u == w:
                other = e.u if e.v == w else e.v
                st.nontree = (*st.nontree,
                              NTCheck(other, e.elabel, e.u == other,
                                      _pvar(q_ext, e)))
                attached = True
                break
        if not attached:
            raise PlanError("optional edge between two pre-bound vertices "
                            "unsupported; move it into the base pattern")
        edge_used[ei] = True
    plan = ExecPlan(
        query=q_ext, start_vertex=0,
        start_candidates=np.zeros(0, np.int32), steps=steps,
        order=order, n_pvars=len(q_ext.pvars), est_fanout=est_fanout)
    return plan


def _pvar(q: QueryGraph, e) -> int:
    return q.pvars.index(e.pvar) if e.pvar is not None else -1


def _align_columns(rows: np.ndarray, have: list[str], want: list[str]):
    out = np.full((rows.shape[0], len(want)), -1, dtype=np.int32)
    for i, var in enumerate(want):
        if var in have:
            out[:, i] = rows[:, have.index(var)]
    return out
