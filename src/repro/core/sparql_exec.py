"""Full SPARQL evaluation: BGP + OPTIONAL + FILTER + UNION (paper §5.1).

Orchestrates the vectorized executor:

- the required basic graph pattern runs first (one ExecPlan);
- each OPTIONAL group becomes an *extension plan* left-joined onto the base
  table: rows with ≥1 optional match take the matched rows, rows with none
  keep the base bindings with nulls — the paper's all-or-nothing OPTIONAL
  semantics realized as a group-level outer join (the nullify-and-keep-
  searching + qualify-and-exclude-duplicate pair collapses into this join,
  so no duplicate-exclusion pass is needed);
- FILTERs: cheap single-variable numeric comparisons are pushed into the
  expansion steps (inline), expensive ones (regex, var-var comparisons)
  are applied to the final table (the paper's strategy);
- UNION branches are evaluated independently and concatenated (SPARQL UNION
  keeps duplicates, as the paper notes).

Compilation and execution are split so the serving layer can share work:
``compile()`` canonicalizes the query (``repro.serve.fingerprint``), keys a
bounded LRU plan cache (``repro.serve.cache.PlanCache``) on the structural
fingerprint, and returns a ``CompiledQuery`` of branch plans + projections;
``execute_compiled()`` runs one.  Alpha-equivalent queries — same shape,
different variable names / triple order — therefore compile exactly once
per engine, and results are renamed back to the caller's variables.
"""

from __future__ import annotations

import contextlib
import re as _re
import threading
from dataclasses import dataclass, field

import numpy as np

from repro.core.exec import ExecOpts, Executor, Result
from repro.core.planner import ExecPlan, build_plan, explain_plan, np_cmp
from repro.obs.workload import qerror
from repro.core.query import QueryGraph, build_query_graph
from repro.resilience.cancel import CancelToken, QueryCancelled
from repro.rdf.sparql import (Comparison, GroupPattern, Literal, Regex,
                              SelectQuery, Var, parse_sparql)
from repro.rdf.transform import TransformMaps
from repro.utils import get_logger

log = get_logger("core.sparql")

_NULL_CM = contextlib.nullcontext()


def _maybe_span(trace, name: str, **meta):
    """A trace span when tracing is on, else a shared no-op context."""
    return trace.span(name, **meta) if trace is not None else _NULL_CM


def _as_trace(trace):
    """Normalize the public ``trace`` argument: False/None → off, True →
    a fresh forced trace (profiled steps), a Trace instance → itself."""
    if trace is None or trace is False:
        return None
    if trace is True:
        from repro.obs import Trace

        return Trace(profile_steps=True)
    return trace


@dataclass
class QueryResult:
    variables: list[str]  # projected variable names (vertex vars + pvars)
    rows: np.ndarray  # int32 [n, n_vars] vertex ids / edge-label ids / -1=null
    kinds: list[str]  # per column: "vertex" | "predicate"
    count: int = 0
    stats: dict = field(default_factory=dict)

    def decode(self, maps: TransformMaps, limit: int | None = None) -> list[dict]:
        out = []
        n = self.rows.shape[0] if limit is None else min(limit, self.rows.shape[0])
        for i in range(n):
            rec = {}
            for c, var in enumerate(self.variables):
                vid = int(self.rows[i, c])
                if vid < 0:
                    rec[var] = None
                elif self.kinds[c] == "vertex":
                    rec[var] = maps.dict.term(int(maps.vertex_to_term[vid]))
                else:
                    rec[var] = maps.dict.predicate(int(maps.elabel_to_pred[vid]))
            out.append(rec)
        return out


@dataclass
class CompiledOptional:
    """One OPTIONAL group compiled as an extension (left-join) plan."""

    q_ext: QueryGraph       # base vertices + the optional's new vertices
    base_cols: int          # number of pre-bound base columns
    plan: ExecPlan          # extension steps only
    expensive: list         # post-hoc filters on the joined table


@dataclass
class CompiledBranch:
    """One UNION branch: base plan + optional extensions + projection."""

    q: QueryGraph
    plan: ExecPlan
    expensive: list
    optionals: list[CompiledOptional]
    q_all: QueryGraph       # after all optional merges
    variables: list[str]
    kinds: list[str]


@dataclass
class CompiledQuery:
    """A fully compiled query: what the plan cache stores and the executor
    runs.  Variables are canonical names when built via ``compile()``."""

    fingerprint: str
    select: list[str]
    branches: list[CompiledBranch]
    variables: list[str]    # result columns (first branch's projection)
    kinds: list[str]
    plan_ms: float = 0.0    # total planner time (base + extension plans)
    # solution modifiers (post-processing; part of the fingerprint)
    distinct: bool = False
    limit: int | None = None
    offset: int = 0

    @property
    def has_modifiers(self) -> bool:
        return self.distinct or self.limit is not None or self.offset > 0

    @property
    def any_unsat(self) -> bool:
        """Some branch was compiled against a constant/predicate that did
        not exist in the data.  On an immutable graph that verdict is
        final; on a live store the term may be interned by a later update,
        so unsat compilations must not enter the plan cache."""
        return not self.branches or any(
            br.plan.unsat or any(co.plan.unsat for co in br.optionals)
            for br in self.branches)

    def estimated_rows(self) -> float:
        """Planner cardinality estimate for the full query (sum of branch
        base-plan estimates scaled by OPTIONAL extension multipliers ≥ 1:
        a left join never drops base rows)."""
        total = 0.0
        for br in self.branches:
            est = br.plan.estimated_rows()
            for co in br.optionals:
                est *= max(1.0, co.plan.estimated_rows())
            total += est
        return total


@dataclass
class ParamFamily:
    """One parameterized plan shared by every query of a *shape*.

    Built by :meth:`SparqlEngine.compile_param` from a
    :class:`~repro.serve.fingerprint.ParamQuery`: non-structural constants
    are hoisted into ``plan`` parameter slots (traced scalar inputs of the
    chunk program), so one compiled executable answers any member of the
    family — and :meth:`SparqlEngine.execute_param_batch` answers many
    members in a single vmapped launch.  ``variables`` / ``kinds`` use
    shape-canonical names; the serving layer renames per caller."""

    shape: str
    query: SelectQuery      # the blinded-canonical shape AST
    q: QueryGraph
    plan: ExecPlan
    expensive: list         # post-hoc filters (shared by all members)
    variables: list[str]
    kinds: list[str]
    n_params: int
    # solution modifiers are part of the shape (serialized un-blinded)
    distinct: bool = False
    limit: int | None = None
    offset: int = 0
    plan_ms: float = 0.0

    @property
    def has_modifiers(self) -> bool:
        return self.distinct or self.limit is not None or self.offset > 0


# cached plan-cache verdict: this shape cannot be parameterized (structural
# reasons only — data-dependent misses are never cached)
_PARAM_INELIGIBLE = object()


class SparqlEngine:
    """End-to-end SPARQL evaluation against one transformed graph.

    ``plan_cache`` (a :class:`repro.serve.cache.PlanCache`) is keyed by the
    query's structural fingerprint, so alpha-equivalent queries share one
    compiled plan.  Pass ``plan_cache=None`` for the default bounded LRU, or
    a pre-sized cache to share stats with a serving registry.
    """

    def __init__(self, graph, maps: TransformMaps, opts: ExecOpts | None = None,
                 estimate: str = "sampled", plan_cache=None):
        from repro.serve.cache import CacheStats, PlanCache

        self.graph = graph
        self.maps = maps
        self.opts = opts or ExecOpts()
        self.estimate = estimate
        self.executor = Executor(graph, self.opts)
        if plan_cache is None:
            plan_cache = PlanCache(capacity=256)
        self._plan_cache = plan_cache
        # parameterized-family compilation accounting (a hit = a query
        # answered by an already-compiled shape plan)
        self.param_stats = CacheStats()
        # workload feedback: fingerprint -> {"fanouts", "version"} —
        # observed per-edge fanouts injected into the next compile of
        # that fingerprint (see apply_feedback / repro.obs.workload)
        self._feedback: dict[str, dict] = {}
        self._feedback_lock = threading.Lock()

    # ------------------------------------------------------------------ API
    @property
    def plan_cache(self):
        return self._plan_cache

    def set_graph(self, g) -> None:
        """Point the engine at a new graph state (live-store updates).

        A newer :class:`~repro.store.versioned.Snapshot` of the *same* base
        swaps into the existing executor — compiled chunk programs and the
        plan cache survive; only the delta arrays change.  A different base
        (post-compaction, or a plain graph) rebuilds the executor; the plan
        cache still survives, since plans are structural and snapshot
        execution re-resolves their candidate sets per version."""
        self.graph = g
        if (getattr(g, "is_snapshot", False) and self.executor.view is not None
                and g.base is self.executor.graph):
            self.executor.set_snapshot(g)
        else:
            # carry the retry policy and learned degradation levels across
            # the rebuild (plan signatures are structural, so they remain
            # valid keys against the new graph state)
            prev = self.executor
            self.executor = Executor(g, self.opts, policy=prev.policy,
                                     breaker=prev.breaker)

    def apply_feedback(self, fingerprint: str, fanouts: dict) -> int:
        """Install workload-observed per-edge fanouts for a fingerprint
        and mark its cached plan stale.

        ``fanouts`` maps ``(child, parent, elabel, forward)`` query-vertex
        keys (stable across recompiles of the same canonical query) to
        observed ``(surviving, raw)`` expansion factors — the shape
        :meth:`repro.obs.workload.WorkloadProfile.observed_fanouts`
        produces.  The next :meth:`compile_canonical` of this fingerprint
        re-runs order search with those numbers injected into the cost
        model (plan ``search`` gains a ``+fb<version>`` tag).  Bounded
        (oldest fingerprints evicted) and versioned; results are
        unchanged as multisets — only order search and capacity presizing
        see the feedback.  Returns the new feedback version."""
        clamp = lambda v: float(min(1e6, max(1e-4, v)))  # noqa: E731
        clean = {k: (clamp(c), clamp(r)) for k, (c, r) in fanouts.items()}
        with self._feedback_lock:
            prev = self._feedback.pop(fingerprint, None)
            version = (prev["version"] if prev else 0) + 1
            self._feedback[fingerprint] = {"fanouts": clean,
                                           "version": version}
            while len(self._feedback) > 64:
                self._feedback.pop(next(iter(self._feedback)))
        self._plan_cache.pop(fingerprint)
        return version

    def clear_feedback(self) -> None:
        """Drop all workload feedback (plans recompile without overrides
        on their next cache miss)."""
        with self._feedback_lock:
            self._feedback.clear()

    def feedback_snapshot(self) -> dict[str, int]:
        """fingerprint -> feedback version, for debug endpoints."""
        with self._feedback_lock:
            return {fp: e["version"] for fp, e in self._feedback.items()}

    def compile(self, source: str | SelectQuery, trace=None):
        """Canonicalize + compile through the plan cache.

        Returns ``(compiled, canon)`` where ``compiled`` is a (possibly
        shared) :class:`CompiledQuery` over canonical variable names and
        ``canon`` is the :class:`~repro.serve.fingerprint.CanonicalQuery`
        carrying this caller's variable renaming.
        """
        from repro.serve.fingerprint import canonicalize_query

        if isinstance(source, str):
            with _maybe_span(trace, "parse"):
                ast = parse_sparql(source)
        else:
            ast = source
        with _maybe_span(trace, "fingerprint"):
            canon = canonicalize_query(ast)
        return self.compile_canonical(canon, trace=trace), canon

    def compile_canonical(self, canon, *, with_fresh: bool = False,
                          trace=None):
        """Compile a pre-canonicalized query through the plan cache.

        With ``with_fresh=True`` returns ``(compiled, fresh)`` where
        ``fresh`` tells whether *this call* built the plan (vs. a cache
        hit) — callers recording plan-search metrics need that rather than
        inferring it from shared cache counters, which races under
        concurrent compilation."""
        compiled = self._plan_cache.get(canon.fingerprint)
        fresh = compiled is None
        if trace is not None:
            trace.event("plan_cache", hit=not fresh)
        if fresh:
            with _maybe_span(trace, "plan_search") as sp:
                compiled = self._compile_ast(canon.query, canon.fingerprint)
                if trace is not None:
                    sp.meta.update(
                        plan_ms=round(compiled.plan_ms, 3),
                        est_rows=round(compiled.estimated_rows(), 1),
                        branches=[
                            {"order": explain_plan(br.plan).get("order", []),
                             "search": br.plan.search,
                             "est_rows": round(br.plan.estimated_rows(), 1)}
                            for br in compiled.branches])
            # live store: an unsat verdict is only as old as this snapshot
            # (a later update may intern the missing term) — recompile such
            # queries instead of caching the verdict
            if not (getattr(self.graph, "is_snapshot", False)
                    and compiled.any_unsat):
                self._plan_cache.put(canon.fingerprint, compiled)
        return (compiled, fresh) if with_fresh else compiled

    def compile_param(self, pq, trace=None) -> ParamFamily | None:
        """Compile (through the plan cache) the parameterized plan for a
        :class:`~repro.serve.fingerprint.ParamQuery`'s shape.

        Returns a :class:`ParamFamily`, or ``None`` when the shape cannot
        be parameterized: OPTIONAL/UNION shapes, shapes with no hoistable
        constants, plans whose cross-component restart step would need a
        re-baked candidate set per constant vector — all structural, so the
        verdict is cached — or (data-dependent, never cached) a family
        representative whose constant is missing from the dictionary.
        Callers fall back to :meth:`compile` / :meth:`execute_compiled`.
        Families are cached under the tuple key ``("shape", hash)``, which
        cannot collide with plain fingerprint-string keys."""
        key = ("shape", pq.shape)
        cached = self._plan_cache.get(key)
        if cached is not None:
            self.param_stats.hits += 1
            if trace is not None:
                trace.event("param_cache", hit=True,
                            eligible=cached is not _PARAM_INELIGIBLE)
            return None if cached is _PARAM_INELIGIBLE else cached
        self.param_stats.misses += 1
        if trace is not None:
            trace.event("param_cache", hit=False)
        ast = pq.shape_query
        g = ast.where
        if not pq.consts or g.optionals or g.unions:
            self._plan_cache.put(key, _PARAM_INELIGIBLE)
            return None
        from repro.serve.fingerprint import iter_param_occurrences

        param_ids = {id(t): k
                     for k, t in enumerate(iter_param_occurrences(g))}
        with _maybe_span(trace, "plan_search"):
            q = build_query_graph(g.triples, self.maps, param_ids=param_ids)
            if q.param_missing:
                # the representative's constant is missing — other members
                # may resolve, so no verdict is cached
                return None
            cheap, expensive = _split_filters(g.filters, q)
            if q.unsat:
                # unsat independently of the hoisted constants (missing
                # predicate / class) — final only on an immutable graph
                if not getattr(self.graph, "is_snapshot", False):
                    self._plan_cache.put(key, _PARAM_INELIGIBLE)
                return None
            plan = build_plan(self.graph, q, estimate=self.estimate,
                              num_filters=cheap,
                              use_nlf=self.opts.use_nlf,
                              use_deg=self.opts.use_deg,
                              use_sig=self.opts.use_prune)
        if (plan.n_params != len(pq.consts)
                or any(s.restart_candidates is not None and s.param_slot >= 0
                       for s in plan.steps)):
            # a parameterized constant anchors its own component: its baked
            # restart-candidate set would vary per constant vector
            self._plan_cache.put(key, _PARAM_INELIGIBLE)
            return None
        variables: list[str] = []
        kinds: list[str] = []
        want = ast.select or [v for v in q.var_to_vertex] + q.pvars
        for var in want:
            variables.append(var)
            kinds.append("vertex" if var in q.var_to_vertex
                         else "predicate" if var in q.pvars else "vertex")
        family = ParamFamily(shape=pq.shape, query=ast, q=q, plan=plan,
                             expensive=expensive, variables=variables,
                             kinds=kinds, n_params=len(pq.consts),
                             distinct=ast.distinct, limit=ast.limit,
                             offset=ast.offset, plan_ms=plan.build_ms)
        self._plan_cache.put(key, family)
        return family

    def resolve_params(self, consts) -> np.ndarray:
        """Constant keys (dictionary text form, as produced by
        ``fingerprint.const_key``) → vertex-id vector; a term missing from
        the dictionary maps to ``-1``, the executor's provably-empty
        sentinel."""
        out = np.empty(len(consts), np.int32)
        for i, c in enumerate(consts):
            vid = self.maps.vertex_of(c)
            out[i] = -1 if vid is None else vid
        return out

    def execute_param(self, family: ParamFamily, consts,
                      collect: str = "bindings", trace=None,
                      cancel: CancelToken | None = None) -> QueryResult:
        """Run one family member: resolve its constant vector and execute
        the shared parameterized plan.  Result columns carry the shape's
        canonical variable names (callers rename back)."""
        params = self.resolve_params(consts)
        executor = self.executor
        state = executor.pin()
        count_only = (collect == "count" and not family.expensive
                      and not family.has_modifiers)
        with _maybe_span(trace, "execute", branches=1):
            res = executor.run(
                family.plan, collect="count" if count_only else "bindings",
                state=state, trace=trace, params=params, cancel=cancel)
        if count_only:
            return QueryResult(
                list(family.variables),
                np.zeros((0, len(family.variables)), np.int32),
                list(family.kinds), count=res.count,
                stats={"plan_ms": family.plan_ms,
                       "exec": {"branches": [{"base": res.stats}]}})
        return self._finish_param(family, res)

    def execute_param_batch(self, family: ParamFamily, const_rows,
                            collect: str = "bindings",
                            cancel: CancelToken | None = None,
                            ) -> list[QueryResult]:
        """Answer ``B`` members of one family in a single vmapped device
        launch (:meth:`Executor.run_batch`); each result is bit-identical
        to what per-member :meth:`execute_param` would return."""
        if not const_rows:
            return []
        if len(const_rows) == 1:
            return [self.execute_param(family, const_rows[0], collect,
                                       cancel=cancel)]
        executor = self.executor
        state = executor.pin()
        mat = np.stack([self.resolve_params(c) for c in const_rows])
        count_only = (collect == "count" and not family.expensive
                      and not family.has_modifiers)
        results = executor.run_batch(
            family.plan, mat, collect="count" if count_only else "bindings",
            state=state, cancel=cancel)
        out: list[QueryResult] = []
        for res in results:
            if count_only:
                out.append(QueryResult(
                    list(family.variables),
                    np.zeros((0, len(family.variables)), np.int32),
                    list(family.kinds), count=res.count,
                    stats={"plan_ms": family.plan_ms,
                           "exec": {"branches": [{"base": res.stats}]}}))
            else:
                out.append(self._finish_param(family, res))
        return out

    def _finish_param(self, family: ParamFamily, res: Result) -> QueryResult:
        """Post-executor finish for one family member: post-hoc filters,
        projection, and DISTINCT/OFFSET/LIMIT — the single-branch subset of
        :meth:`execute_compiled`, applied in the same order so results are
        identical to the unparameterized path."""
        table, ptable, _ = self._apply_expensive(res.bindings,
                                                 res.pvar_bindings,
                                                 family.q, family.expensive)
        q = family.q
        cols: list[np.ndarray] = []
        for var in family.variables:
            if var in q.var_to_vertex:
                cols.append(table[:, q.var_to_vertex[var]])
            elif var in q.pvars:
                cols.append(ptable[:, q.pvars.index(var)])
            else:
                cols.append(np.full(table.shape[0], -1, np.int32))
        rows = np.stack(cols, axis=1) if cols else np.zeros(
            (table.shape[0], 0), np.int32)
        if family.distinct:
            rows = np.unique(rows, axis=0)
        if family.offset:
            rows = rows[family.offset:]
        if family.limit is not None:
            rows = rows[: family.limit]
        # est_rows / step_card mirror execute_compiled so the serving
        # layer's cardinality metrics + workload profiles cover the
        # parameterized path too (estimates are per-shape, shared by
        # every member of the family)
        step_card = [(float(est), int(actual))
                     for est, actual in zip(family.plan.est_rows,
                                            res.stats.get("step_kept") or [])]
        return QueryResult(list(family.variables), rows, list(family.kinds),
                           count=int(rows.shape[0]),
                           stats={"plan_ms": family.plan_ms,
                                  "est_rows": family.plan.estimated_rows(),
                                  "exec": {"branches": [{"base": res.stats}]},
                                  "step_card": step_card})

    def execute_compiled(self, compiled: CompiledQuery,
                         collect: str = "bindings",
                         profile: bool = False, trace=None,
                         cancel: CancelToken | None = None) -> QueryResult:
        """Run a compiled query; result columns keep its variable names.

        ``collect="count"`` lets branches without OPTIONALs, post-hoc
        filters or solution modifiers run the executor's count-only path
        (no binding-table materialization or device→host transfer); the
        result then has an exact ``count`` but empty ``rows``.  DISTINCT /
        OFFSET / LIMIT force materialization even for counts — they are
        applied to the assembled table here, after UNION concatenation.
        ``profile=True`` executes with per-step host syncs to fill
        per-step wall times in the stats.  ``trace`` records an
        ``execute`` span with per-branch / per-chunk / per-step children;
        a forced trace (``profile_steps=True``) implies ``profile``.
        ``cancel`` (a :class:`repro.resilience.CancelToken`) is threaded
        into every executor run and checked between branches; on expiry a
        :class:`QueryCancelled` carries the stats accumulated so far."""
        if trace is not None and trace.profile_steps:
            profile = True
        all_rows: list[np.ndarray] = []
        total = 0
        exec_stats: list[dict] = []
        step_card: list[tuple[float, int]] = []
        variables, kinds = compiled.variables, compiled.kinds
        modifiers = compiled.has_modifiers
        # pin one executor AND its state (snapshot + device graph) for the
        # whole query: concurrent live-store updates must not tear a UNION
        # branch or an OPTIONAL join across data versions — and a
        # compaction-triggered set_graph REPLACES self.executor, so the
        # object itself must be captured too, not re-read per branch
        executor = self.executor
        state = executor.pin()
        with _maybe_span(trace, "execute", branches=len(compiled.branches)):
            for bi, br in enumerate(compiled.branches):
                if cancel is not None:
                    cancel.check({"exec": {"branches": exec_stats}})
                try:
                    with _maybe_span(trace, "branch", index=bi):
                        rows, count, info = self._exec_branch(
                            br, collect if not modifiers else "bindings",
                            profile, executor, state, trace, cancel)
                except QueryCancelled as e:
                    # enrich with the completed branches' stats so the 504
                    # body can report partial progress
                    e.partial_stats = {
                        "exec": {"branches": exec_stats
                                 + [{"base": e.partial_stats}]}}
                    raise
                total += count
                exec_stats.append(info)
                base = info.get("base") or {}
                for est, actual in zip(br.plan.est_rows,
                                       base.get("step_kept") or []):
                    step_card.append((float(est), int(actual)))
                if rows is not None:
                    if br.variables != variables:
                        rows = _align_columns(rows, br.variables, variables)
                    all_rows.append(rows)
            rows = (np.concatenate(all_rows) if all_rows
                    else np.zeros((0, 0), np.int32))
            if modifiers:
                if compiled.distinct:
                    rows = np.unique(rows, axis=0)
                if compiled.offset:
                    rows = rows[compiled.offset:]
                if compiled.limit is not None:
                    rows = rows[: compiled.limit]
                total = int(rows.shape[0])
            elif collect == "bindings":
                total = int(rows.shape[0])
        return QueryResult(list(variables), rows, list(kinds),
                           count=total,
                           stats={"plan_ms": compiled.plan_ms,
                                  "est_rows": compiled.estimated_rows(),
                                  "exec": {"branches": exec_stats},
                                  "step_card": step_card})

    def query(self, sparql: str, collect: str = "bindings",
              trace=False, timeout_ms: float | None = None,
              cancel: CancelToken | None = None) -> QueryResult:
        """Evaluate a SPARQL string.  ``trace=True`` forces a full trace
        (profiled steps) and attaches the finished span tree as
        ``result.stats["trace"]``; a :class:`repro.obs.Trace` instance may
        also be passed to record into an existing trace.  ``timeout_ms``
        sets a deadline for this call (raising
        :class:`repro.resilience.QueryCancelled` on expiry); ``cancel``
        passes an externally owned token instead."""
        t = _as_trace(trace)
        if t is None:
            return self.query_ast(parse_sparql(sparql), collect=collect,
                                  timeout_ms=timeout_ms, cancel=cancel)
        with t.span("parse"):
            ast = parse_sparql(sparql)
        return self.query_ast(ast, collect=collect, trace=t,
                              timeout_ms=timeout_ms, cancel=cancel)

    def query_ast(self, ast: SelectQuery, collect: str = "bindings",
                  trace=False, timeout_ms: float | None = None,
                  cancel: CancelToken | None = None) -> QueryResult:
        import time as _time

        if cancel is None and timeout_ms is not None:
            cancel = CancelToken(_time.monotonic() + timeout_ms / 1e3)
        t = _as_trace(trace)
        compiled, canon = self.compile(ast, trace=t)
        if cancel is not None:
            cancel.check()  # deadline may have expired during plan search
        res = self.execute_compiled(compiled, collect=collect, trace=t,
                                    cancel=cancel)
        res.variables = canon.restore(res.variables)
        if t is not None:
            t.finish()
            res.stats["trace"] = t.to_dict()
            res.stats["trace_obj"] = t
        return res

    def count(self, sparql: str) -> int:
        return self.query(sparql, collect="count").count

    def explain(self, source: str | SelectQuery,
                analyze: bool = False) -> dict:
        """Describe the (possibly cached) plan for a query without running
        it: matching order, chosen start vertex, and per-step fanout /
        cardinality estimates, with the caller's variable names.

        ``analyze=True`` additionally *executes* the query in profiled mode
        and annotates every step with its measured expansion total,
        surviving rows, overflow retries, and wall time — the
        estimate-vs-actual view (SQL's EXPLAIN ANALYZE)."""
        compiled, canon = self.compile(source)
        run_stats = None
        if analyze:
            res = self.execute_compiled(compiled, profile=True)
            run_stats = res.stats
        out = self.describe_compiled(compiled, run_stats=run_stats,
                                     inverse=canon.inverse)
        if run_stats is not None:
            out["actual_rows"] = res.count
            out["q_error"] = round(qerror(out["est_total_rows"], res.count), 3)
        return out

    def explain_param(self, source: str | SelectQuery) -> dict:
        """Describe a query's *parameterized family* plan: the shape hash,
        the hoisted constants with their parameter slots, and the plan with
        ``param[k]`` markers where the executor reads traced inputs instead
        of baked ids.  Returns ``{"parameterized": False, ...}`` with the
        structural reason when the shape cannot be parameterized."""
        from repro.serve.fingerprint import parameterize_query

        pq = parameterize_query(source)
        family = self.compile_param(pq)
        if family is None:
            return {"parameterized": False, "shape": pq.shape,
                    "constants": list(pq.consts),
                    "explain": self.explain(source)}
        desc = explain_plan(family.plan, self.maps)
        inv = pq.inverse
        return {
            "parameterized": True,
            "shape": family.shape,
            "params": [{"slot": k, "constant": c}
                       for k, c in enumerate(pq.consts)],
            "variables": [inv.get(v, v) for v in family.variables],
            "plan": desc,
        }

    def describe_compiled(self, compiled: CompiledQuery,
                          run_stats: dict | None = None,
                          inverse: dict | None = None) -> dict:
        """EXPLAIN-style JSON for an already-compiled query.  With
        ``run_stats`` (a ``QueryResult.stats`` from any execution) the
        steps carry measured counters — the EXPLAIN ANALYZE view without
        re-running; the slow-query log uses exactly this to file the
        annotated plan next to each recorded trace.  ``inverse`` maps
        canonical variable names back to the caller's."""
        inverse = inverse or {}

        def restore_names(obj):
            if isinstance(obj, str) and obj.startswith("?"):
                return "?" + inverse.get(obj[1:], obj[1:])
            if isinstance(obj, list):
                return [restore_names(x) for x in obj]
            if isinstance(obj, dict):
                return {k: restore_names(v) for k, v in obj.items()}
            return obj

        branches = []
        for bi, br in enumerate(compiled.branches):
            b = explain_plan(br.plan, self.maps)
            b["optionals"] = [explain_plan(co.plan, self.maps)
                              for co in br.optionals]
            if run_stats is not None:
                binfo = run_stats["exec"]["branches"][bi]
                _annotate_steps(b, binfo.get("base"))
                for oi, od in enumerate(b["optionals"]):
                    opts_info = binfo.get("optionals") or []
                    if oi < len(opts_info):
                        _annotate_steps(od, opts_info[oi])
            branches.append(restore_names(b))
        return {
            "fingerprint": compiled.fingerprint,
            "estimate": self.estimate,
            "plan_ms": round(compiled.plan_ms, 3),
            "est_total_rows": round(compiled.estimated_rows(), 1),
            "branches": branches,
        }

    # --------------------------------------------------------- compilation
    def _compile_ast(self, ast: SelectQuery, fingerprint: str) -> CompiledQuery:
        with self._feedback_lock:
            fb = self._feedback.get(fingerprint)
        # feedback fanouts are keyed by branch-0 query-vertex indices
        # (profiles fold branch-0 base stats), so only that branch's base
        # plan sees them; UNION siblings keep static estimates
        branches = [self._compile_group(
                        g, ast.select,
                        observed=fb["fanouts"] if fb and i == 0 else None)
                    for i, g in enumerate(self._expand_unions(ast.where))]
        if fb and branches:
            p = branches[0].plan
            p.search = f"{p.search}+fb{fb['version']}"
        first = branches[0] if branches else None
        plan_ms = sum(br.plan.build_ms
                      + sum(co.plan.build_ms for co in br.optionals)
                      for br in branches)
        return CompiledQuery(
            fingerprint=fingerprint, select=list(ast.select),
            branches=branches,
            variables=list(first.variables) if first else [],
            kinds=list(first.kinds) if first else [],
            plan_ms=plan_ms,
            distinct=ast.distinct, limit=ast.limit, offset=ast.offset)

    def _compile_group(self, g: GroupPattern, select: list[str],
                       observed: dict | None = None) -> CompiledBranch:
        q = build_query_graph(g.triples, self.maps)
        cheap, expensive = _split_filters(g.filters, q)
        plan = build_plan(self.graph, q, estimate=self.estimate,
                          num_filters=cheap,
                          use_nlf=self.opts.use_nlf, use_deg=self.opts.use_deg,
                          use_sig=self.opts.use_prune,
                          observed_fanout=observed)
        q_all = q
        optionals: list[CompiledOptional] = []
        for og in g.optionals:
            n_base_pvars = len(q_all.pvars)
            q_ext, _, base_cols = _merge_query(q_all, og.triples, self.maps)
            cheap_o, exp_o = _split_filters(og.filters, q_ext)
            # the same planner entry point as the base pattern: vertices
            # below base_cols are pre-bound table columns, pvars below
            # n_base_pvars are bound by the base execution
            ext_plan = build_plan(self.graph, q_ext, estimate=self.estimate,
                                  num_filters=cheap_o,
                                  use_nlf=self.opts.use_nlf,
                                  use_deg=self.opts.use_deg,
                                  use_sig=self.opts.use_prune,
                                  prebound=base_cols,
                                  prebound_pvars=n_base_pvars)
            optionals.append(CompiledOptional(q_ext, base_cols, ext_plan, exp_o))
            q_all = q_ext
        variables: list[str] = []
        kinds: list[str] = []
        want = select or [v for v in q_all.var_to_vertex] + q_all.pvars
        for var in want:
            variables.append(var)
            kinds.append("vertex" if var in q_all.var_to_vertex
                         else "predicate" if var in q_all.pvars else "vertex")
        return CompiledBranch(q=q, plan=plan, expensive=expensive,
                              optionals=optionals, q_all=q_all,
                              variables=variables, kinds=kinds)

    # ------------------------------------------------------------ execution
    def _exec_branch(self, br: CompiledBranch, collect: str = "bindings",
                     profile: bool = False, executor=None,
                     state: tuple | None = None, trace=None,
                     cancel: CancelToken | None = None):
        """Run one branch; returns ``(rows | None, count, exec_stats)``."""
        executor = self.executor if executor is None else executor
        count_only = (collect == "count" and not br.optionals
                      and not br.expensive)
        res = executor.run(
            br.plan, collect="count" if count_only else "bindings",
            profile=profile, state=state, trace=trace, cancel=cancel)
        info: dict = {"base": res.stats}
        if count_only:
            return None, res.count, info
        table, ptable, _ = self._apply_expensive(res.bindings,
                                                 res.pvar_bindings,
                                                 br.q, br.expensive)
        opt_stats: list[dict] = []
        for oi, co in enumerate(br.optionals):
            with _maybe_span(trace, "optional", index=oi):
                table, ptable, ost = self._exec_left_join(table, ptable, co,
                                                          profile, executor,
                                                          state, trace,
                                                          cancel)
            opt_stats.append(ost)
        if opt_stats:
            info["optionals"] = opt_stats
        q_all = br.q_all
        cols: list[np.ndarray] = []
        for var in br.variables:
            if var in q_all.var_to_vertex:
                cols.append(table[:, q_all.var_to_vertex[var]])
            elif var in q_all.pvars:
                cols.append(ptable[:, q_all.pvars.index(var)])
            else:
                cols.append(np.full(table.shape[0], -1, np.int32))
        rows = np.stack(cols, axis=1) if cols else np.zeros(
            (table.shape[0], 0), np.int32)
        return rows, int(rows.shape[0]), info

    # ----------------------------------------------------------- internals
    def _expand_unions(self, g: GroupPattern) -> list[GroupPattern]:
        """Cartesian expansion of UNION blocks into flat branch groups."""
        branches = [GroupPattern(list(g.triples), list(g.filters),
                                 list(g.optionals), [])]
        for union in g.unions:
            new: list[GroupPattern] = []
            for b in branches:
                for alt in union:
                    for alt_flat in self._expand_unions(alt):
                        nb = GroupPattern(
                            b.triples + alt_flat.triples,
                            b.filters + alt_flat.filters,
                            b.optionals + alt_flat.optionals,
                            [],
                        )
                        new.append(nb)
            branches = new
        return branches

    def _exec_left_join(self, table: np.ndarray, ptable: np.ndarray,
                        co: CompiledOptional, profile: bool = False,
                        executor=None, state: tuple | None = None,
                        trace=None, cancel: CancelToken | None = None):
        """Left-outer join a compiled OPTIONAL extension onto the table."""
        q_ext, plan, expensive = co.q_ext, co.plan, co.expensive
        nq_ext = q_ext.n_vertices
        b0 = np.full((table.shape[0], nq_ext), -1, dtype=np.int32)
        b0[:, : table.shape[1]] = table
        p0 = np.full((table.shape[0], max(1, len(q_ext.pvars))), -1, np.int32)
        p0[:, : ptable.shape[1]] = ptable
        org0 = np.arange(table.shape[0], dtype=np.int32)
        if plan.unsat or table.shape[0] == 0:
            matched = Result(0, np.zeros((0, nq_ext), np.int32),
                             np.zeros((0, max(1, len(q_ext.pvars))), np.int32),
                             np.zeros(0, np.int32))
        else:
            executor = self.executor if executor is None else executor
            matched = executor.run(plan, initial=(b0, p0, org0),
                                   profile=profile, state=state, trace=trace,
                                   cancel=cancel)
        mt, mp, morg = self._apply_expensive(matched.bindings,
                                             matched.pvar_bindings,
                                             q_ext, expensive,
                                             origins=matched.origins)
        # rows with no optional match: keep base + nulls
        has_match = np.zeros(table.shape[0], dtype=bool)
        if morg.shape[0]:
            has_match[morg] = True
        unmatched = np.flatnonzero(~has_match)
        un_b = np.full((unmatched.shape[0], nq_ext), -1, dtype=np.int32)
        un_b[:, : table.shape[1]] = table[unmatched]
        un_p = np.full((unmatched.shape[0], mp.shape[1]), -1, np.int32)
        un_p[:, : ptable.shape[1]] = ptable[unmatched]
        new_table = np.concatenate([mt, un_b], axis=0)
        new_ptable = np.concatenate([mp, un_p], axis=0)
        return new_table, new_ptable, matched.stats

    def _apply_expensive(self, table, ptable, q: QueryGraph, filters,
                         origins=None):
        """Post-hoc (regex / var-var) filters; returns a plain
        ``(table, ptable, origins)`` — ``origins`` stays ``None`` when the
        caller did not pass source-row ids."""
        keep = np.ones(table.shape[0], dtype=bool)
        g = self.graph
        for f in filters:
            if isinstance(f, Regex):
                col = q.var_to_vertex.get(f.var.name)
                if col is None:
                    continue
                pat = _re.compile(f.pattern)
                vals = table[:, col]
                km = np.zeros(table.shape[0], dtype=bool)
                for i, v in enumerate(vals):
                    if v >= 0:
                        term = self.maps.dict.term(int(self.maps.vertex_to_term[v]))
                        km[i] = bool(pat.search(term.strip('"')))
                keep &= km
            elif isinstance(f, Comparison):
                lv = _col_values(f.lhs, table, q, g)
                rv = _col_values(f.rhs, table, q, g)
                if lv is None or rv is None:
                    continue
                with np.errstate(invalid="ignore"):
                    keep &= np_cmp(lv - rv + 0.0, f.op, 0.0) if np.ndim(rv) else \
                        np_cmp(lv, f.op, float(rv))
        table = table[keep]
        ptable = ptable[keep]
        return table, ptable, origins[keep] if origins is not None else None


# --------------------------------------------------------------------------


def _annotate_steps(plan_desc: dict, exec_stats: dict | None) -> None:
    """Merge one executor run's per-step counters into an explain_plan
    description (in place) — the EXPLAIN ANALYZE view."""
    if not exec_stats:
        return
    for i, rec in enumerate(plan_desc.get("steps", [])):
        for src, dst in (("step_rows", "actual_expanded"),
                         ("step_kept", "actual_rows"),
                         ("step_retries", "retries"),
                         ("step_prune_in", "prune_in"),
                         ("step_prune_out", "prune_out")):
            vals = exec_stats.get(src)
            if vals is not None and i < len(vals):
                rec[dst] = int(vals[i])
        if rec.get("prune_in"):
            rec["prune_ratio"] = round(rec["prune_out"] / rec["prune_in"], 4)
        if "actual_rows" in rec and rec.get("est_rows") is not None:
            rec["q_error"] = round(qerror(rec["est_rows"],
                                          rec["actual_rows"]), 3)
        wall = exec_stats.get("step_wall_ms")
        if wall is not None and i < len(wall):
            rec["wall_ms"] = round(float(wall[i]), 3)
        caps = exec_stats.get("caps")
        if caps and i < len(caps):
            rec["capacity"] = int(caps[i])
    plan_desc["exec"] = {
        "chunks": exec_stats.get("chunks", 0),
        "resumes": exec_stats.get("resumes", 0),
        "compiles": exec_stats.get("compiles", 0),
        "wall_ms": round(float(exec_stats.get("wall_ms", 0.0)), 3),
    }


def _col_values(term, table, q: QueryGraph, g):
    if isinstance(term, Var):
        col = q.var_to_vertex.get(term.name)
        if col is None or g.numeric_value is None:
            return None
        ids = np.clip(table[:, col], 0, g.n_vertices - 1)
        vals = g.numeric_value[ids].copy()
        vals[table[:, col] < 0] = np.nan
        return vals
    if isinstance(term, Literal) and term.numeric is not None:
        return term.numeric
    return None


def _split_filters(filters, q: QueryGraph):
    """cheap: {var: [(op, const)]} pushed inline; expensive: post-hoc list."""
    cheap: dict[str, list[tuple[str, float]]] = {}
    expensive = []
    for f in filters:
        if (isinstance(f, Comparison) and isinstance(f.lhs, Var)
                and isinstance(f.rhs, Literal) and f.rhs.numeric is not None):
            cheap.setdefault(f.lhs.name, []).append((f.op, f.rhs.numeric))
        elif (isinstance(f, Comparison) and isinstance(f.rhs, Var)
              and isinstance(f.lhs, Literal) and f.lhs.numeric is not None):
            flipped = {"<": ">", "<=": ">=", ">": "<", ">=": "<=",
                       "=": "=", "!=": "!="}[f.op]
            cheap.setdefault(f.rhs.name, []).append((flipped, f.lhs.numeric))
        else:
            expensive.append(f)
    return cheap, expensive


def _merge_query(q_base: QueryGraph, opt_triples, maps):
    """Extend a base query graph with OPTIONAL triples; base vertices keep
    their column indices, new vertices append."""
    from repro.core.query import build_query_graph as _bqg

    # Build combined graph over base + optional triples by rebuilding with
    # the base's variable order fixed first.
    q_ext = QueryGraph()
    q_ext.vertices = [  # copy base vertices
        type(v)(var=v.var, labels=v.labels, bound_id=v.bound_id, term=v.term)
        for v in q_base.vertices
    ]
    q_ext.var_to_vertex = dict(q_base.var_to_vertex)
    q_ext.pvars = list(q_base.pvars)
    q_ext.unsat = q_base.unsat
    # note: base edges already satisfied; extension plan only needs new edges
    tmp = _bqg(opt_triples, maps)
    # remap tmp vertices into q_ext
    remap: dict[int, int] = {}
    for ti, tv in enumerate(tmp.vertices):
        if tv.var is not None and tv.var in q_ext.var_to_vertex:
            idx = q_ext.var_to_vertex[tv.var]
            # merge labels onto the existing vertex (type triples in OPTIONAL)
            merged = tuple(sorted({*q_ext.vertices[idx].labels, *tv.labels}))
            q_ext.vertices[idx].labels = merged
        else:
            idx = len(q_ext.vertices)
            q_ext.vertices.append(
                type(tv)(var=tv.var, labels=tv.labels, bound_id=tv.bound_id,
                         term=tv.term))
            if tv.var is not None:
                q_ext.var_to_vertex[tv.var] = idx
        remap[ti] = idx
    new_edges = []
    for e in tmp.edges:
        pv = e.pvar
        if pv is not None and pv not in q_ext.pvars:
            q_ext.pvars.append(pv)
        new_edges.append(type(e)(remap[e.u], remap[e.v], e.elabel, pv))
    q_ext.edges = new_edges  # ONLY the optional edges (extension steps)
    q_ext.unsat = q_ext.unsat or tmp.unsat
    base_cols = q_base.n_vertices
    return q_ext, remap, base_cols


def _align_columns(rows: np.ndarray, have: list[str], want: list[str]):
    out = np.full((rows.shape[0], len(want)), -1, dtype=np.int32)
    for i, var in enumerate(want):
        if var in have:
            out[:, i] = rows[:, have.index(var)]
    return out
