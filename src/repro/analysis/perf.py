import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512")

"""Perf-iteration harness: compile a (arch × cell × profile) variant at small
unrolled depth, difference against depth-1, and report the corrected
three-term roofline — one hypothesis→measure cycle per invocation.

  PYTHONPATH=src python -m repro.analysis.perf --arch qwen3-8b \
      --cell train_4k --profile act_replicated

Results append to runs/perf/log.json so EXPERIMENTS.md §Perf can cite the
whole iteration history.
"""

import argparse
import json
import time
from pathlib import Path

import jax

from repro.analysis.model_flops import model_flops
from repro.analysis.roofline import (CHIPS_SINGLE, PEAK_FLOPS, _combine,
                                     _sub, roofline_terms, xla_cost)
from repro.configs import get_arch
from repro.launch.dryrun import collective_bytes
from repro.launch.mesh import make_production_mesh


def _compile_cost(arch_name, cell, depth, profile):
    from repro.launch.cells import build_cell

    mesh = make_production_mesh(multi_pod=False)
    built = build_cell(arch_name, cell, mesh, lm_depth=depth, profile=profile)
    t0 = time.time()
    with jax.set_mesh(mesh):
        if built.get("family") == "engine":
            compiled = built["lower"]().compile()
        else:
            compiled = built["step"].lower(*built["args"]).compile()
    cost = xla_cost(compiled)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": {k: v for k, v in
                 collective_bytes(compiled.as_text()).items() if k != "total"},
        "compile_s": time.time() - t0,
    }


def measure(arch_name: str, cell: str, profile: str) -> dict:
    arch = get_arch(arch_name)
    if arch.family == "lm":
        cfg = arch.config
        if cfg.moe is None:
            c1 = _compile_cost(arch_name, cell, (1, 0), profile)
            c2 = _compile_cost(arch_name, cell, (2, 0), profile)
            per = _sub(c2, c1)
            total = _combine(_sub(c1, per), per, cfg.n_layers)
        else:
            nd = cfg.moe.first_dense_layers
            c11 = _compile_cost(arch_name, cell, (min(1, nd), 1), profile)
            c12 = _compile_cost(arch_name, cell, (min(1, nd), 2), profile)
            per = _sub(c12, c11)
            base = _combine(_sub(c11, per), per, cfg.n_layers - nd)
            total = base  # dense prefix folded into fixed for nd<=1
    else:
        total = _compile_cost(arch_name, cell, None, profile)
    terms = roofline_terms(total)
    rec = {"arch": arch_name, "cell": cell, "profile": profile, **terms,
           "flops_per_chip": total["flops"], "bytes_per_chip": total["bytes"],
           "coll_per_chip": total["coll"], "ts": time.time()}
    if arch.family != "engine":
        mf = model_flops(arch_name, cell)
        step_s = max(terms["compute_s"], terms["memory_s"],
                     terms["collective_s"])
        rec["model_flops"] = mf
        rec["useful_ratio"] = mf / max(total["flops"] * CHIPS_SINGLE, 1.0)
        rec["roofline_frac"] = (mf / CHIPS_SINGLE / PEAK_FLOPS) / step_s \
            if step_s else 0.0
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", required=True)
    ap.add_argument("--profile", default="baseline")
    ap.add_argument("--out", default="runs/perf/log.json")
    args = ap.parse_args()
    rec = measure(args.arch, args.cell, args.profile)
    print(json.dumps(rec, indent=1))
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    log = json.loads(out.read_text()) if out.exists() else []
    log.append(rec)
    out.write_text(json.dumps(log, indent=1))


if __name__ == "__main__":
    main()
