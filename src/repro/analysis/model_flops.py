"""Analytic MODEL_FLOPS per (arch × cell) — the 'useful work' numerator for
the roofline's MODEL_FLOPS / HLO_FLOPS ratio.

Conventions: train = 6·N_active·tokens (fwd 2 + bwd 4) plus attention
quadratic terms; prefill = forward only (2·N·tokens + attention);
decode = 2·N_active·new_tokens + per-layer KV-cache reads (the dominant
attention term at long context); GNN/recsys from per-op counts × 3 for
training (bwd ≈ 2× fwd).
"""

from __future__ import annotations

from repro.configs import GNN_SHAPES, LM_SHAPES, RECSYS_SHAPES, get_arch
from repro.configs.common import sampled_block_dims


def _lm_flops(cfg, cell: str) -> float:
    s = LM_SHAPES[cell]
    n_act = cfg.active_param_count()
    bsz, seq = s["batch"], s["seq"]
    L, H, dh = cfg.n_layers, cfg.n_heads, cfg.d_head
    if cfg.attn == "mla":
        qk_dim = cfg.nope_head_dim + cfg.rope_head_dim
        attn_per_tok_train = 2 * L * H * (qk_dim + cfg.v_head_dim) * seq / 2
    else:
        attn_per_tok_train = 2 * L * H * dh * 2 * seq / 2  # causal half
    if s["kind"] == "train":
        tokens = bsz * seq
        return 6.0 * n_act * tokens + 3 * 2 * attn_per_tok_train * tokens
    if s["kind"] == "prefill":
        tokens = bsz * seq
        return 2.0 * n_act * tokens + 2 * attn_per_tok_train * tokens
    # decode: 1 token per sequence against a `seq`-long cache
    t = seq
    if cfg.attn == "mla":
        per_tok_attn = 2 * L * H * t * (2 * cfg.kv_lora + cfg.rope_head_dim)
    else:
        per_tok_attn = 2 * L * cfg.n_heads * t * dh * 2
    return bsz * (2.0 * n_act + per_tok_attn)


def _gnn_dims(cell: str) -> tuple[int, int, int]:
    s = GNN_SHAPES[cell]
    if s["regime"] == "sampled":
        n, e = sampled_block_dims(s["batch_nodes"], s["fanout"])
        return n, e, s["d_feat"]
    if s["regime"] == "batched":
        return s["n_per"] * s["batch"], s["e_per"] * s["batch"], s["d_feat"]
    return s["n"], s["e"], s["d_feat"]


def _gnn_flops(arch: str, cfg, cell: str) -> float:
    n, e, d_feat = _gnn_dims(cell)
    if arch == "gcn-cora":
        h = cfg.d_hidden
        dims = [d_feat] + [h] * (cfg.n_layers - 1) + [cfg.n_classes]
        fwd = sum(2.0 * n * dims[i] * dims[i + 1] + 2.0 * e * dims[i + 1]
                  for i in range(cfg.n_layers))
        return 3 * fwd
    if arch == "pna":
        h = cfg.d_hidden
        d_in = d_feat
        fwd = 0.0
        for _ in range(cfg.n_layers):
            fwd += 2.0 * e * (2 * d_in) * h  # pre-MLP on edges
            fwd += 4 * 2.0 * e * h  # 4 aggregators
            fwd += 2.0 * n * (d_in + 12 * h) * h + 2.0 * n * h * h  # post
            d_in = h
        fwd += 2.0 * n * h * cfg.n_classes
        return 3 * fwd
    if arch == "meshgraphnet":
        h = cfg.d_hidden
        fwd = 2.0 * n * d_feat * h + 2.0 * e * cfg.d_edge_in * h
        for _ in range(cfg.n_layers):
            fwd += 2.0 * e * (3 * h) * h + 2.0 * e * h * h  # edge MLP
            fwd += 2.0 * n * (2 * h) * h + 2.0 * n * h * h  # node MLP
            fwd += 2.0 * e * h  # aggregate
        fwd += 2.0 * n * h * cfg.d_out
        return 3 * fwd
    # dimenet
    h, b = cfg.d_hidden, cfg.n_bilinear
    t = 8 * e
    sr = cfg.n_spherical * cfg.n_radial
    fwd = 2.0 * e * (3 * h) * h
    for _ in range(cfg.n_blocks):
        fwd += 2.0 * e * h * h  # w_src
        fwd += 2.0 * t * sr * b  # sbf proj
        fwd += 2.0 * t * b * h * h  # bilinear einsum tb,bhg,th->tg
        fwd += 2.0 * t * h  # segment sum
        fwd += 2 * 2.0 * e * h * h  # update MLP
        fwd += 2.0 * n * h * h + 2.0 * n * h  # out block
    return 3 * fwd


def _recsys_flops(cfg, cell: str) -> float:
    s = RECSYS_SHAPES[cell]
    b = s["batch"]
    bot = [cfg.n_dense, *cfg.bot_mlp]
    top_in = cfg.n_interact + cfg.bot_mlp[-1]
    top = [top_in, *cfg.top_mlp]
    mlps = sum(2.0 * b * a * bb for a, bb in zip(bot, bot[1:]))
    mlps += sum(2.0 * b * a * bb for a, bb in zip(top, top[1:]))
    f = cfg.n_sparse + 1
    inter = 2.0 * b * f * f * cfg.embed_dim
    gather = b * cfg.n_sparse * cfg.hotness * cfg.embed_dim  # sum-reduce
    fwd = mlps + inter + gather
    if s["kind"] == "train":
        return 3 * fwd
    if s["kind"] == "retrieval":
        return 2.0 * s["n_candidates"] * cfg.embed_dim + mlps / b
    return fwd


def model_flops(arch_name: str, cell: str) -> float:
    arch = get_arch(arch_name)
    cfg = arch.config_for(cell) if arch.cell_config else arch.config
    if arch.family == "lm":
        return _lm_flops(cfg, cell)
    if arch.family == "gnn":
        return _gnn_flops(arch_name, cfg, cell)
    if arch.family == "recsys":
        return _recsys_flops(cfg, cell)
    raise ValueError(arch.family)
