import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512")

"""Roofline analysis from the compiled dry-run artifacts.

Three terms per (arch × cell), single-pod mesh (256 chips), TPU v5e
constants:

    compute_s    = HLO_FLOPs_per_chip / 197e12
    memory_s     = HLO_bytes_per_chip / 819e9
    collective_s = Σ_op factor(op) · collective_bytes_per_chip / 50e9
        factors: all-reduce 2 (ring send+recv of ~2(n−1)/n·s), all-gather 1
        (output ≈ wire), reduce-scatter 1 (underestimates by ~n·out ≈ in;
        noted), all-to-all 1, collective-permute 1.

``cost_analysis`` counts a lax.scan body ONCE (XLA HloCostAnalysis does not
multiply while-loop trip counts — verified in tests/test_roofline.py), so
LM stacks are corrected by *depth differencing*: compile the same cell at
small depths, per_layer = cost(L+1) − cost(L), total = fixed + depth ·
per_layer.  GNN/recsys/engine cells have python-unrolled stacks and need no
correction.

MODEL_FLOPS comes from analysis/model_flops.py (6·N_active·D etc.);
ratio = MODEL_FLOPS / (HLO_FLOPs_per_chip × chips) — remat and redundant
compute push it below the family's natural ceiling (≈0.33 for 6ND training
accounting with full remat ≈ 0.25).
"""

import argparse
import json
from pathlib import Path

import jax

from repro.analysis.model_flops import model_flops
from repro.configs import all_archs, get_arch

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s per chip
LINK_BW = 50e9  # B/s per ICI link
CHIPS_SINGLE = 256
COLL_FACTORS = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

# --------------------------------------------------------------------------
# Binding-table kernel cost models (current repro.kernels API)
#
# First-order traffic/flop models for the executor's step kernels, keyed by
# the names the dispatch layer (kernels/ops.py) actually exposes:
# ``expand_filter`` (the fused expand/filter/compact Pallas kernel),
# ``ragged_expand`` (the legacy expand + separate filter + scatter-compact
# path), ``delta_merge`` / ``delta_merge_labeled`` (live-store snapshot
# merge), and ``edge_exists`` (per-candidate binary-search join).  The
# executor's trace annotations evaluate these per step so measured wall
# time sits next to a roofline estimate in every span.
#
# Units: int32/float32 elements (4 B).  ``expanded`` = ragged expansion
# total for the step, ``rows`` = input binding-table rows, ``capacity`` =
# the step's capacity (table writes are capacity-shaped, not row-shaped),
# ``nq`` = binding-table width, ``bitmap_words`` = label-bitmap words per
# vertex, ``n_iters`` = binary-search iterations (≈ log2(max degree)).
# --------------------------------------------------------------------------

# (peak_flops/s, mem_bw B/s) used to turn a cost into model time; the TPU
# row matches the chip constants above, cpu/gpu are order-of-magnitude
# single-device defaults for annotation purposes.
BACKEND_PEAKS = {
    "tpu": (PEAK_FLOPS, HBM_BW),
    "gpu": (6.0e13, 1.0e12),
    "cpu": (2.0e11, 4.0e10),
}

KERNEL_MODELS = ("expand_filter", "ragged_expand", "delta_merge",
                 "delta_merge_labeled", "edge_exists")


def kernel_cost(kernel: str, *, expanded: float, rows: float = 0.0,
                capacity: float = 0.0, nq: int = 4, bitmap_words: int = 1,
                n_iters: int = 20) -> dict:
    """Cost tuple ({flops, bytes, coll}) for one executor step kernel —
    the same shape ``roofline_terms`` consumes."""
    expanded = max(0.0, float(expanded))
    rows = max(0.0, float(rows))
    capacity = max(0.0, float(capacity))
    w = max(1, int(bitmap_words))
    it = max(1, int(n_iters))
    table = capacity * (nq + 1) * 4.0  # one table image (B + pvar/org cols)
    if kernel == "expand_filter":
        # CSR degree/start reads, one neighbor gather + bitmap gather per
        # expansion, in-kernel prefix sum, one gather-built output table
        bytes_ = rows * 12.0 + expanded * (8.0 + 4.0 * w) + 2.0 * table
        flops = expanded * (2.0 + w) + 2.0 * capacity
    elif kernel == "ragged_expand":
        # unfused: expansion triple (row, j, valid) materialized, filters
        # re-read candidates, scatter-compact touches the padded table twice
        bytes_ = rows * 12.0 + expanded * (16.0 + 4.0 * w) + 3.0 * table
        flops = expanded * (4.0 + w) + 3.0 * capacity
    elif kernel in ("delta_merge", "delta_merge_labeled"):
        # base + delta CSR reads and a tombstone binary search per
        # expansion on top of the unfused path; the labeled variant also
        # reads/writes the edge-label column
        lab = 8.0 if kernel == "delta_merge_labeled" else 0.0
        bytes_ = (rows * 24.0 + expanded * (16.0 + lab + 4.0 * (w + it))
                  + 3.0 * table)
        flops = expanded * (6.0 + w + it) + 3.0 * capacity
    elif kernel == "edge_exists":
        # per-candidate binary search over the probe vertex's adjacency
        bytes_ = expanded * 4.0 * it
        flops = expanded * float(it)
    else:
        raise ValueError(f"unknown kernel {kernel!r}; "
                         f"known: {KERNEL_MODELS}")
    return {"flops": flops, "bytes": bytes_, "coll": {}}


def estimate_step_ms(kernel: str, backend: str = "cpu", **kw) -> dict:
    """Roofline time estimate for one executor step on one device.
    Returns ``{model_ms, dominant, flops, bytes}`` — what the executor
    attaches to kernel-level trace spans."""
    cost = kernel_cost(kernel, **kw)
    peak_f, bw = BACKEND_PEAKS.get(backend, BACKEND_PEAKS["cpu"])
    compute_s = cost["flops"] / peak_f
    memory_s = cost["bytes"] / bw
    return {"model_ms": max(compute_s, memory_s) * 1e3,
            "dominant": "compute" if compute_s >= memory_s else "memory",
            "flops": cost["flops"], "bytes": cost["bytes"]}


def _cost_tuple(rec: dict) -> dict:
    coll = rec.get("collective_bytes", {})
    return {
        "flops": rec.get("flops", 0.0),
        "bytes": rec.get("bytes_accessed", 0.0),
        "coll": {k: v for k, v in coll.items() if k != "total"},
    }


def _combine(fixed, per, n):
    out = {"flops": fixed["flops"] + n * per["flops"],
           "bytes": fixed["bytes"] + n * per["bytes"],
           "coll": {}}
    keys = set(fixed["coll"]) | set(per["coll"])
    for k in keys:
        out["coll"][k] = fixed["coll"].get(k, 0) + n * per["coll"].get(k, 0)
    return out


def _sub(a, b):
    return {"flops": a["flops"] - b["flops"], "bytes": a["bytes"] - b["bytes"],
            "coll": {k: a["coll"].get(k, 0) - b["coll"].get(k, 0)
                     for k in set(a["coll"]) | set(b["coll"])}}


def xla_cost(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized across jax versions: newer
    jax returns a per-computation list of dicts, older a single dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def _variant_cost(arch_name: str, cell: str, depth: tuple[int, int],
                  cache_dir: Path) -> dict:
    """Compile the cell at a small depth and return its cost tuple."""
    key = f"{arch_name}--{cell}--d{depth[0]}-{depth[1]}.json"
    path = cache_dir / key
    if path.exists():
        return _cost_tuple(json.loads(path.read_text()))
    from repro.launch.cells import build_cell
    from repro.launch.dryrun import collective_bytes
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=False)
    built = build_cell(arch_name, cell, mesh, lm_depth=depth)
    with jax.set_mesh(mesh):
        compiled = built["step"].lower(*built["args"]).compile()
    cost = xla_cost(compiled)
    rec = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": collective_bytes(compiled.as_text()),
    }
    cache_dir.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(rec))
    print(f"[roofline] variant {arch_name}/{cell} depth={depth}: "
          f"flops={rec['flops']:.3e}", flush=True)
    return _cost_tuple(rec)


def corrected_cost(arch_name: str, cell: str, dryrun_rec: dict,
                   cache_dir: Path) -> dict:
    """Per-chip cost with scan-body depth correction (LM cells only)."""
    arch = get_arch(arch_name)
    if arch.family != "lm":
        return _cost_tuple(dryrun_rec)
    cfg = arch.config
    if cfg.moe is None:
        nd_full, nm_full = cfg.n_layers, 0
        c1 = _variant_cost(arch_name, cell, (1, 0), cache_dir)
        c2 = _variant_cost(arch_name, cell, (2, 0), cache_dir)
        per_dense = _sub(c2, c1)
        fixed = _sub(c1, per_dense)
        return _combine(fixed, per_dense, nd_full)
    nd_full = cfg.moe.first_dense_layers
    nm_full = cfg.n_layers - nd_full
    c11 = _variant_cost(arch_name, cell, (1, 1), cache_dir)
    c12 = _variant_cost(arch_name, cell, (1, 2), cache_dir)
    per_moe = _sub(c12, c11)
    if nd_full:
        c01 = _variant_cost(arch_name, cell, (0, 1), cache_dir)
        per_dense = _sub(c11, c01)
        fixed = _sub(c01, per_moe)
        out = _combine(fixed, per_dense, nd_full)
        return _combine(out, per_moe, nm_full - 0)
    # nd_full == 0 (dbrx): all layers MoE; fixed from the (0,1) variant
    c01 = _variant_cost(arch_name, cell, (0, 1), cache_dir)
    fixed = _sub(c01, per_moe)
    return _combine(fixed, per_moe, nm_full)


def roofline_terms(cost: dict, chips: int = CHIPS_SINGLE) -> dict:
    compute_s = cost["flops"] / PEAK_FLOPS
    memory_s = cost["bytes"] / HBM_BW
    coll_s = sum(COLL_FACTORS.get(k, 1.0) * v
                 for k, v in cost["coll"].items()) / LINK_BW
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", coll_s)), key=lambda kv: kv[1])[0]
    return {"compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll_s, "dominant": dominant}


def analyze(dryrun_dir: Path, out_dir: Path, archs=None) -> list[dict]:
    cache_dir = out_dir / "variants"
    rows = []
    for arch_name in (archs or all_archs()):
        arch = get_arch(arch_name)
        for cell in sorted(arch.cells):
            rec_path = dryrun_dir / "single" / f"{arch_name}--{cell}.json"
            if not rec_path.exists():
                continue
            rec = json.loads(rec_path.read_text())
            if rec.get("status") != "ok":
                continue
            cost = corrected_cost(arch_name, cell, rec, cache_dir)
            terms = roofline_terms(cost)
            row = {"arch": arch_name, "cell": cell, **terms,
                   "hlo_flops_per_chip": cost["flops"],
                   "hlo_bytes_per_chip": cost["bytes"],
                   "coll_bytes_per_chip": sum(cost["coll"].values()),
                   "raw_flops_per_chip": rec.get("flops", 0.0)}
            if arch.family != "engine":
                mf = model_flops(arch_name, cell)
                row["model_flops"] = mf
                denom = cost["flops"] * CHIPS_SINGLE
                row["useful_ratio"] = mf / denom if denom else 0.0
                step_s = max(terms["compute_s"], terms["memory_s"],
                             terms["collective_s"])
                row["roofline_frac"] = (
                    mf / CHIPS_SINGLE / PEAK_FLOPS) / step_s if step_s else 0.0
            rows.append(row)
            print(f"[roofline] {arch_name:18s} {cell:14s} "
                  f"c={terms['compute_s']:.2e}s m={terms['memory_s']:.2e}s "
                  f"n={terms['collective_s']:.2e}s dom={terms['dominant']:10s}"
                  f" ratio={row.get('useful_ratio', float('nan')):.3f}",
                  flush=True)
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / "roofline.json").write_text(json.dumps(rows, indent=1))
    (out_dir / "roofline.md").write_text(to_markdown(rows))
    return rows


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | cell | compute_s | memory_s | collective_s | dominant | "
           "MODEL_FLOPS | useful ratio | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    body = "".join(
        f"| {r['arch']} | {r['cell']} | {r['compute_s']:.3e} | "
        f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | {r['dominant']} | "
        f"{r.get('model_flops', 0):.3e} | {r.get('useful_ratio', 0):.3f} | "
        f"{r.get('roofline_frac', 0):.3f} |\n"
        for r in rows)
    return hdr + body


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="runs/dryrun")
    ap.add_argument("--out", default="runs/roofline")
    ap.add_argument("--arch", default=None)
    args = ap.parse_args()
    analyze(Path(args.dryrun), Path(args.out),
            archs=[args.arch] if args.arch else None)


if __name__ == "__main__":
    main()
