"""SPARQL UPDATE subset parser: ``INSERT DATA`` / ``DELETE DATA``.

Grammar (reusing the SPARQL lexer from :mod:`repro.rdf.sparql`):

    update   := prologue (op)+
    prologue := (PREFIX name: <iri>)*
    op       := INSERT DATA '{' triples '}'
              | DELETE DATA '{' triples '}'
    triples  := (term term term '.'?)*

Terms are ground (no variables — DATA blocks are concrete triples).  IRIs
and prefixed names normalize exactly like query terms (``rdf:type`` /
``rdf:subClassOf`` short forms); literals keep their quoted lexical form so
they dictionary-encode the way the N-Triples loader does.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.rdf.sparql import (SparqlError, _lex, normalize_iri,
                              normalize_prefixed)


class UpdateError(ValueError):
    """Malformed SPARQL UPDATE text or an unsupported mutation."""


@dataclass
class UpdateOp:
    action: str  # "insert" | "delete"
    triples: list[tuple[str, str, str]] = field(default_factory=list)


class _UpdateParser:
    def __init__(self, src: str):
        try:
            self.toks = _lex(src)
        except SparqlError as e:
            raise UpdateError(str(e)) from e
        self.i = 0

    def peek(self):
        return self.toks[self.i]

    def next(self):
        t = self.toks[self.i]
        self.i += 1
        return t

    def expect(self, kind: str):
        t = self.next()
        if t.kind != kind:
            raise UpdateError(
                f"expected {kind}, got {t.kind} {t.text!r} at {t.pos}")
        return t

    def parse(self) -> list[UpdateOp]:
        while self.peek().kind == "PREFIX":
            self.next()
            self.expect("NAME")
            self.expect("IRI")  # prefixes fold into terms at lex level
        ops: list[UpdateOp] = []
        while self.peek().kind != "EOF":
            t = self.next()
            word = t.text.upper() if t.kind == "NAME" else ""
            if word not in ("INSERT", "DELETE"):
                raise UpdateError(
                    f"expected INSERT/DELETE DATA, got {t.text!r} at {t.pos}")
            data = self.next()
            if data.kind != "NAME" or data.text.upper() != "DATA":
                raise UpdateError(
                    "only INSERT DATA / DELETE DATA are supported "
                    f"(got {data.text!r} at {data.pos})")
            ops.append(UpdateOp(action=word.lower(),
                                triples=self._data_block()))
            if self.peek().kind == "DOT":  # tolerate ';'-less separators
                self.next()
        if not ops:
            raise UpdateError("empty update: no INSERT DATA / DELETE DATA op")
        return ops

    def _data_block(self) -> list[tuple[str, str, str]]:
        self.expect("LBRACE")
        triples: list[tuple[str, str, str]] = []
        while self.peek().kind != "RBRACE":
            if self.peek().kind == "EOF":
                raise UpdateError("unexpected EOF inside DATA block")
            s = self._term()
            p = self._term(pred=True)
            o = self._term()
            triples.append((s, p, o))
            if self.peek().kind == "DOT":
                self.next()
        self.next()  # RBRACE
        return triples

    def _term(self, pred: bool = False) -> str:
        t = self.next()
        if t.kind == "IRI":
            return normalize_iri(t.text[1:-1])
        if t.kind == "NAME":
            return normalize_prefixed(t.text)
        if t.kind == "A" and pred:
            return "rdf:type"
        if t.kind == "LITERAL" and not pred:
            end = t.text.rfind('"')
            return f'"{t.text[1:end]}"'
        if t.kind == "NUMBER" and not pred:
            return f'"{t.text}"'
        if t.kind == "VAR":
            raise UpdateError(
                f"variables are not allowed in DATA blocks ({t.text!r} at "
                f"{t.pos}); use ground triples")
        raise UpdateError(f"bad term {t.text!r} at {t.pos}")


def parse_update(src: str) -> list[UpdateOp]:
    """Parse SPARQL UPDATE text into a list of insert/delete operations."""
    return _UpdateParser(src).parse()
