"""Edge-delta buffers over a frozen base :class:`LabeledGraph`.

``EdgeDelta`` is the mutable write-side state: a set of inserted edges and
a set of tombstoned *base* edges, both keyed ``(src, elabel, dst)``.  The
two sets are kept disjoint from the base by construction:

- inserting an edge that exists in the base is a no-op (RDF set
  semantics), unless it was tombstoned — then the tombstone is removed;
- deleting an edge removes it from the insert buffer if it only ever
  lived there, tombstones it if it exists in the base, and is a no-op
  otherwise.

``materialize`` freezes the current buffers into the sorted COO arrays a
:class:`~repro.store.versioned.Snapshot` serves from: one ``(el, key,
nbr)``-sorted array per direction for inserts and tombstones, from which
per-edge-label CSR rows (and the plain all-labels CSR for predicate-
variable steps) are derived lazily.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.rdf.graph import LabeledGraph


def base_has_edge(base: LabeledGraph, s: int, el: int, o: int) -> bool:
    """Is (s, el, o) an edge of the base graph?  O(log deg) binary search."""
    if not (0 <= s < base.n_vertices and 0 <= el < base.n_elabels):
        return False
    row = base.out.indptr_el[el]
    lo, hi = int(row[s]), int(row[s + 1])
    seg = base.out.nbr_el[lo:hi]
    i = int(np.searchsorted(seg, o))
    return i < seg.shape[0] and int(seg[i]) == o


@dataclass
class DeltaCOO:
    """One direction's frozen delta: arrays sorted by (el, key, nbr).

    For the outgoing direction ``key`` is the subject and ``nbr`` the
    object; the incoming direction swaps them.  ``nbr`` runs within one
    (el, key) group are ascending, so the executor's binary-search
    membership probes work on the per-(el, key) slices directly.
    """

    el: np.ndarray  # int32 [k]
    key: np.ndarray  # int32 [k]
    nbr: np.ndarray  # int32 [k]

    @staticmethod
    def from_edges(edges, forward: bool) -> "DeltaCOO":
        if not edges:
            z = np.zeros(0, np.int32)
            return DeltaCOO(z, z, z)
        # (s, el, o) tuples; the lexsort below is a total order, so no
        # Python-level pre-sort is needed
        arr = np.fromiter((x for e in edges for x in e), dtype=np.int64,
                          count=3 * len(edges)).reshape(-1, 3)
        s, el, o = arr[:, 0], arr[:, 1], arr[:, 2]
        key, nbr = (s, o) if forward else (o, s)
        order = np.lexsort((nbr, key, el))
        return DeltaCOO(el[order].astype(np.int32),
                        key[order].astype(np.int32),
                        nbr[order].astype(np.int32))

    @property
    def size(self) -> int:
        return int(self.el.shape[0])

    def el_slice(self, el: int) -> tuple[np.ndarray, np.ndarray]:
        """(keys, nbrs) of this edge label, sorted by (key, nbr)."""
        lo = int(np.searchsorted(self.el, el, side="left"))
        hi = int(np.searchsorted(self.el, el, side="right"))
        return self.key[lo:hi], self.nbr[lo:hi]

    def el_rows(self, el: int, n_rows: int) -> tuple[np.ndarray, np.ndarray]:
        """CSR (indptr[n_rows+1], nbr) for one edge label over ``n_rows``
        source vertices.  Returns empty arrays when the label is absent."""
        key, nbr = self.el_slice(el)
        if key.size == 0:
            return np.zeros(n_rows + 1, np.int32), np.zeros(0, np.int32)
        counts = np.bincount(key, minlength=n_rows)
        indptr = np.zeros(n_rows + 1, dtype=np.int32)
        np.cumsum(counts, out=indptr[1:], dtype=np.int64)
        return indptr, nbr.copy()

    def plain_rows(self, n_rows: int) -> tuple[np.ndarray, np.ndarray,
                                               np.ndarray]:
        """All-labels CSR ``(indptr, nbr, lab)`` sorted by (key, nbr, el)
        — the predicate-variable expansion layout."""
        if self.size == 0:
            return (np.zeros(n_rows + 1, np.int32), np.zeros(0, np.int32),
                    np.zeros(0, np.int32))
        order = np.lexsort((self.el, self.nbr, self.key))
        key = self.key[order]
        counts = np.bincount(key, minlength=n_rows)
        indptr = np.zeros(n_rows + 1, dtype=np.int32)
        np.cumsum(counts, out=indptr[1:], dtype=np.int64)
        return indptr, self.nbr[order].copy(), self.el[order].copy()

    def composite_rows(self, n_rows: int,
                       n_elabels: int) -> tuple[np.ndarray, np.ndarray]:
        """All-labels CSR of composite keys ``nbr * n_elabels + el`` sorted
        ascending per source — the tombstone probe layout for predicate-
        variable steps (one binary search tests a specific (nbr, el) pair)."""
        if self.size == 0:
            return np.zeros(n_rows + 1, np.int32), np.zeros(0, np.int32)
        comp = self.nbr.astype(np.int64) * n_elabels + self.el.astype(np.int64)
        assert comp.size == 0 or int(comp.max()) < 2**31, \
            "composite (vertex, elabel) key exceeds int32"
        order = np.lexsort((comp, self.key))
        key = self.key[order]
        counts = np.bincount(key, minlength=n_rows)
        indptr = np.zeros(n_rows + 1, dtype=np.int32)
        np.cumsum(counts, out=indptr[1:], dtype=np.int64)
        return indptr, comp[order].astype(np.int32)

    def max_run(self) -> int:
        """Largest per-(el, key) adjacency run — the delta fanout bound."""
        if self.size == 0:
            return 0
        group = (np.r_[True, (np.diff(self.el) != 0) | (np.diff(self.key) != 0)]
                 .cumsum() - 1)
        return int(np.bincount(group).max())


class EdgeDelta:
    """Mutable insert/tombstone buffers over a frozen base graph."""

    def __init__(self, base: LabeledGraph):
        self.base = base
        self.inserts: set[tuple[int, int, int]] = set()  # (s, el, o)
        self.tombs: set[tuple[int, int, int]] = set()

    def __len__(self) -> int:
        return len(self.inserts) + len(self.tombs)

    def insert(self, s: int, el: int, o: int) -> bool:
        """Apply one edge insertion; True if visible state changed."""
        e = (int(s), int(el), int(o))
        if e in self.tombs:
            self.tombs.discard(e)
            return True
        if e in self.inserts or base_has_edge(self.base, *e):
            return False
        self.inserts.add(e)
        return True

    def delete(self, s: int, el: int, o: int) -> bool:
        """Apply one edge deletion; True if visible state changed."""
        e = (int(s), int(el), int(o))
        if e in self.inserts:
            self.inserts.discard(e)
            return True
        if e in self.tombs or not base_has_edge(self.base, *e):
            return False
        self.tombs.add(e)
        return True

    def materialize(self) -> dict[str, DeltaCOO]:
        """Freeze the buffers into per-direction sorted COO views."""
        return {
            "ins_out": DeltaCOO.from_edges(self.inserts, forward=True),
            "ins_in": DeltaCOO.from_edges(self.inserts, forward=False),
            "tomb_out": DeltaCOO.from_edges(self.tombs, forward=True),
            "tomb_in": DeltaCOO.from_edges(self.tombs, forward=False),
        }
