"""repro.store — versioned live RDF store with delta-aware snapshots.

The paper's engine (and everything downstream of :class:`LabeledGraph`)
assumes an immutable graph built once from a finalized triple store.  This
package makes the data *live*: a :class:`VersionedStore` keeps the frozen
base graph plus an in-memory delta overlay (COO insert buffers and
tombstones over base edges), and hands out cheap immutable
:class:`Snapshot` views that queries execute against while writers keep
appending.  The executor merges base-CSR adjacency with the snapshot's
small sorted delta adjacency per expansion step (``kernels/delta_merge``),
so no CSR rebuild happens on the write path; a threshold-triggered
compaction folds the delta into a fresh ``LabeledGraph`` and *patches* the
cached ``GraphStats`` incrementally instead of recomputing them.

SPARQL UPDATE (``INSERT DATA`` / ``DELETE DATA``) is parsed by
:mod:`repro.store.update_parser` and served by ``POST /update`` in
:mod:`repro.serve.server`.
"""

from repro.store.delta import EdgeDelta
from repro.store.update_parser import UpdateError, UpdateOp, parse_update
from repro.store.versioned import Snapshot, VersionedStore

__all__ = [
    "EdgeDelta",
    "Snapshot",
    "VersionedStore",
    "UpdateError",
    "UpdateOp",
    "parse_update",
]
