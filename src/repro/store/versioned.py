"""Versioned live store: frozen base graph + copy-on-write delta snapshots.

``VersionedStore`` owns the mutable state (an :class:`EdgeDelta`, label
patches, new-vertex metadata, dictionary growth through the shared
``TransformMaps``) behind a lock.  ``snapshot()`` freezes the current delta
into an immutable :class:`Snapshot` — the object queries plan and execute
against.  A snapshot is *cheap*: it sorts the (small) delta buffers and
shares every base array; per-edge-label CSR rows, merged label bitmaps and
device uploads are derived lazily and cached on the snapshot, while padded
base rows are cached on the store so consecutive snapshots share them.

A ``Snapshot`` quacks like a :class:`~repro.rdf.graph.LabeledGraph` for
everything the *planner* touches host-side (``candidates_with_labels``,
``predicate_index``, ``label_bitmap``, ``numeric_value``, ``freq``,
``out/inc.degree``) — all answers are exact for the merged graph.  The
*executor* recognizes ``is_snapshot`` and merges base CSR adjacency with
the snapshot's delta adjacency per step (see ``core.exec`` and
``kernels/delta_merge``).

``compact()`` folds the delta into a fresh ``LabeledGraph`` (vertex /
edge-label ids are preserved, so compiled plans and the dictionary stay
valid) and incrementally patches the cached ``GraphStats`` instead of
recomputing them from scratch.
"""

from __future__ import annotations

import math
import threading
from typing import Iterable, Sequence

import numpy as np

from repro.rdf.dictionary import RDF_TYPE, RDFS_SUBCLASSOF
from repro.rdf.graph import LabeledGraph, pack_bitmap
from repro.resilience import faults as _faults
from repro.store.delta import DeltaCOO, EdgeDelta
from repro.store.update_parser import UpdateError, parse_update
from repro.utils import get_logger

log = get_logger("store.versioned")


class _SnapDirection:
    """Host-side stand-in for ``LabeledGraph.out`` / ``.inc``: only the
    pieces the planner reads (merged per-vertex degree)."""

    def __init__(self, snap: "Snapshot", forward: bool):
        self._snap = snap
        self._forward = forward
        self._degree: np.ndarray | None = None

    @property
    def degree(self) -> np.ndarray:
        if self._degree is None:
            s = self._snap
            base_dir = s.base.out if self._forward else s.base.inc
            deg = np.zeros(s.n_vertices, dtype=np.int64)
            deg[: s.base.n_vertices] = base_dir.degree
            ins = s.coo["ins_out" if self._forward else "ins_in"]
            tomb = s.coo["tomb_out" if self._forward else "tomb_in"]
            if ins.size:
                deg += np.bincount(ins.key, minlength=s.n_vertices)
            if tomb.size:
                deg -= np.bincount(tomb.key, minlength=s.n_vertices)
            self._degree = deg.astype(np.int32)
        return self._degree


class Snapshot:
    """Immutable view of the store at one version (base + frozen delta)."""

    is_snapshot = True
    supports_sampled_order = False  # planner falls back to greedy order

    def __init__(self, store: "VersionedStore", base: LabeledGraph,
                 version: int, epoch: int, n_vertices: int, n_elabels: int,
                 coo: dict[str, DeltaCOO],
                 new_vlabel_sets: list[tuple[int, ...]],
                 label_patch: dict[int, tuple[int, ...]],
                 numeric_value: np.ndarray | None):
        self.store = store
        self.base = base
        self.version = version
        self.epoch = epoch
        self.n_vertices = n_vertices
        self.n_elabels = n_elabels
        self.coo = coo
        self.new_vlabel_sets = new_vlabel_sets
        self.label_patch = label_patch
        self.numeric_value = numeric_value
        self.out = _SnapDirection(self, True)
        self.inc = _SnapDirection(self, False)
        self._label_bitmap: np.ndarray | None = None
        self._pred_index: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        self._dev: dict = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------- metadata
    @property
    def n_vlabels(self) -> int:
        return self.base.n_vlabels

    @property
    def n_new_vertices(self) -> int:
        return self.n_vertices - self.base.n_vertices

    @property
    def n_edges(self) -> int:
        return (self.base.n_edges + self.coo["ins_out"].size
                - self.coo["tomb_out"].size)

    @property
    def has_delta(self) -> bool:
        return bool(self.coo["ins_out"].size or self.coo["tomb_out"].size
                    or self.n_new_vertices or self.label_patch)

    def token(self) -> tuple:
        """Identity for executor-side caches (epoch ties to the base)."""
        return (id(self.base), self.epoch, self.version)

    # ------------------------------------------------ host planner interface
    def _labels_of(self, v: int) -> tuple[int, ...]:
        if v >= self.base.n_vertices:
            return self.new_vlabel_sets[v - self.base.n_vertices]
        hit = self.label_patch.get(v)
        if hit is not None:
            return hit
        return self.base.vlabel_sets[v] if self.base.vlabel_sets else ()

    @property
    def label_bitmap(self) -> np.ndarray:
        if self._label_bitmap is None:
            base_bm = self.base.label_bitmap
            if not self.label_patch and not self.n_new_vertices:
                self._label_bitmap = base_bm
            else:
                n_bits = max(1, self.n_vlabels)
                new_rows = pack_bitmap(self.new_vlabel_sets, n_bits) \
                    if self.n_new_vertices else \
                    np.zeros((0, base_bm.shape[1]), np.uint32)
                merged = np.vstack([base_bm, new_rows])
                if self.label_patch:
                    vids = list(self.label_patch)
                    merged[vids] = pack_bitmap(
                        [self.label_patch[v] for v in vids], n_bits)
                self._label_bitmap = merged
        return self._label_bitmap

    def candidates_with_labels(self, labels: Sequence[int]) -> np.ndarray:
        if not labels:
            return np.arange(self.n_vertices, dtype=np.int32)
        cand = self.base.candidates_with_labels(labels)
        if not self.label_patch and not self.n_new_vertices:
            return cand
        req = set(labels)
        extra = [v for v, ls in self.label_patch.items() if req <= set(ls)]
        extra += [self.base.n_vertices + i
                  for i, ls in enumerate(self.new_vlabel_sets)
                  if req <= set(ls)]
        if self.label_patch:
            patched = np.fromiter(self.label_patch, dtype=np.int64,
                                  count=len(self.label_patch))
            cand = cand[~np.isin(cand, patched)]
        if extra:
            cand = np.union1d(cand, np.asarray(extra, dtype=np.int64))
        return np.sort(cand).astype(np.int32)

    def vertices_with_label(self, lbl: int) -> np.ndarray:
        return self.candidates_with_labels([lbl])

    def freq(self, labels: Sequence[int]) -> int:
        return int(self.candidates_with_labels(list(labels)).shape[0])

    def _merged_el_deg(self, el: int, keys: np.ndarray,
                       forward: bool) -> np.ndarray:
        """Exact merged (el, direction) degree for the given key vertices."""
        base_dir = self.base.out if forward else self.base.inc
        deg = np.zeros(keys.shape[0], dtype=np.int64)
        in_base = keys < self.base.n_vertices
        if el < self.base.n_elabels and in_base.any():
            row = base_dir.indptr_el[el]
            kb = keys[in_base]
            deg[in_base] = row[kb + 1] - row[kb]
        for name, sign in (("ins_out" if forward else "ins_in", 1),
                           ("tomb_out" if forward else "tomb_in", -1)):
            k_arr, _ = self.coo[name].el_slice(el)
            if k_arr.size:
                lo = np.searchsorted(k_arr, keys, side="left")
                hi = np.searchsorted(k_arr, keys, side="right")
                deg += sign * (hi - lo)
        return deg

    def predicate_index(self, el: int) -> tuple[np.ndarray, np.ndarray]:
        """(sorted distinct subjects, sorted distinct objects) of ``el`` in
        the merged graph — base index adjusted by the delta."""
        hit = self._pred_index.get(el)
        if hit is not None:
            return hit
        sides = []
        for forward in (True, False):
            if el < self.base.n_elabels:
                base_side = self.base.predicate_index(el)[0 if forward else 1]
            else:
                base_side = np.zeros(0, np.int32)
            ins_k, _ = self.coo["ins_out" if forward else "ins_in"].el_slice(el)
            tomb_k, _ = self.coo["tomb_out" if forward
                                 else "tomb_in"].el_slice(el)
            side = base_side
            if tomb_k.size:
                affected = np.unique(tomb_k).astype(np.int64)
                dead = affected[self._merged_el_deg(el, affected,
                                                    forward) <= 0]
                if dead.size:
                    side = side[~np.isin(side, dead)]
            if ins_k.size:
                side = np.union1d(side, np.unique(ins_k).astype(np.int64))
            sides.append(np.sort(side).astype(np.int32))
        self._pred_index[el] = (sides[0], sides[1])
        return self._pred_index[el]

    # ------------------------------------------------------- device arrays
    def el_clean(self, el: int, forward: bool) -> bool:
        """No delta inserts and no tombstones for (el, direction)."""
        ins = self.coo["ins_out" if forward else "ins_in"]
        tomb = self.coo["tomb_out" if forward else "tomb_in"]
        return (ins.el_slice(el)[0].size == 0
                and tomb.el_slice(el)[0].size == 0)

    def _dev_cached(self, key, build):
        with self._lock:
            hit = self._dev.get(key)
            if hit is None:
                hit = build()
                self._dev[key] = hit
            return hit

    @staticmethod
    def _pad_pow2(a: np.ndarray, fill: int = -1, to: int = 1) -> np.ndarray:
        """Pad a delta value array to the next pow2 length ≥ ``to``.  Every
        read is bounded by an indptr slice over the real prefix, so the
        fill is never observed — the point is shape stability: consecutive
        snapshots land in the same jit trace until a bucket overflows."""
        from repro.core.planner.ir import _next_pow2

        n = a.shape[0]
        target = _next_pow2(max(n, to))
        if n == target:
            return a
        return np.concatenate([a, np.full(target - n, fill, a.dtype)])

    def dev_el_step(self, el: int, forward: bool, n_pad: int) -> dict:
        """Delta device arrays for one tree-edge step: ``d_iptr``/``d_nbr``
        for inserts and ``t_iptr``/``t_nbr`` for tombstones.

        Presence is decided per *direction*, not per label: once a
        direction has any inserts (or tombstones), every label gets its
        (possibly all-zero) rows.  A per-label decision would flip the
        step-arrays pytree structure — and force a jit retrace of the
        whole chunk program — every time a batch first touches a label;
        direction granularity makes the structure stable from the first
        update on, at the cost of a no-op merge for still-clean labels."""
        import jax.numpy as jnp

        def build():
            d = {}
            for tag, name in (("d", "ins_out" if forward else "ins_in"),
                              ("t", "tomb_out" if forward else "tomb_in")):
                coo = self.coo[name]
                if not coo.size:
                    continue
                iptr, nbr = coo.el_rows(el, n_pad)
                # every label pads to the direction's LARGEST per-label
                # bucket, and buckets grow coarsely (floor 64, ×4 steps):
                # a bucket crossing retraces every compiled chunk program,
                # so crossings must be rare and happen for all labels at
                # once — not per label per batch
                bucket = 64
                need = int(np.bincount(coo.el).max(initial=1))
                while bucket < need:
                    bucket *= 4
                d[f"{tag}_iptr"] = jnp.asarray(iptr)
                d[f"{tag}_nbr"] = jnp.asarray(
                    self._pad_pow2(nbr, to=bucket))
            return d

        return self._dev_cached(("el", el, forward, n_pad), build)

    def dev_plain(self, forward: bool, n_pad: int) -> dict:
        """Delta device arrays for a predicate-variable step: the plain
        all-labels insert CSR (+ edge labels) and the composite-key
        tombstone CSR (key = nbr * n_elabels + el)."""
        import jax.numpy as jnp

        def build():
            d = {}
            ins = self.coo["ins_out" if forward else "ins_in"]
            if ins.size:
                iptr, nbr, lab = ins.plain_rows(n_pad)
                d["d_iptr"] = jnp.asarray(iptr)
                d["d_nbr"] = jnp.asarray(self._pad_pow2(nbr))
                d["d_lab"] = jnp.asarray(self._pad_pow2(lab))
            tomb = self.coo["tomb_out" if forward else "tomb_in"]
            if tomb.size:
                iptr, key = tomb.composite_rows(n_pad, self.n_elabels)
                d["t_iptr"] = jnp.asarray(iptr)
                d["t_key"] = jnp.asarray(self._pad_pow2(key))
            return d

        return self._dev_cached(("plain", forward, n_pad), build)

    def dev_flat(self, forward: bool, n_pad: int) -> dict:
        """Flattened per-(el, vertex) delta CSRs, layout ``el * (n_pad + 1)
        + v`` — the dynamic-edge-label non-tree probe tables."""
        import jax.numpy as jnp

        def build():
            d = {}
            for tag, name in (("d", "ins_out" if forward else "ins_in"),
                              ("t", "tomb_out" if forward else "tomb_in")):
                coo = self.coo[name]
                if not coo.size:
                    continue
                iptrs, nbrs, off = [], [], 0
                for el in range(self.n_elabels):
                    iptr, nbr = coo.el_rows(el, n_pad)
                    iptrs.append(iptr.astype(np.int64) + off)
                    nbrs.append(nbr)
                    off += nbr.size
                d[f"{tag}_flat_iptr"] = jnp.asarray(
                    np.concatenate(iptrs).astype(np.int32))
                flat_nbr = (np.concatenate(nbrs) if off
                            else np.zeros(1, np.int32))
                d[f"{tag}_flat_nbr"] = jnp.asarray(self._pad_pow2(flat_nbr))
            return d

        return self._dev_cached(("flat", forward, n_pad), build)

    def dev_bitmap(self, n_pad: int):
        import jax.numpy as jnp

        def build():
            bm = self.label_bitmap
            if bm.shape[0] < n_pad:
                bm = np.vstack([bm, np.zeros((n_pad - bm.shape[0],
                                              bm.shape[1]), np.uint32)])
            return jnp.asarray(bm)

        return self._dev_cached(("bitmap", n_pad), build)

    def dev_sig(self, n_pad: int):
        """Padded per-vertex neighborhood-signature rows (conservative
        overlay: insert bits OR-ed onto the base index, tombstones
        ignored — see :func:`repro.index.signature_rows`)."""
        import jax.numpy as jnp

        def build():
            from repro.index import signature_rows

            sig = signature_rows(self)
            if sig.shape[0] < n_pad:
                sig = np.vstack([sig, np.zeros((n_pad - sig.shape[0],
                                                sig.shape[1]), np.uint32)])
            return jnp.asarray(sig)

        return self._dev_cached(("sig", n_pad), build)

    def dev_filter_bitmap(self, n_pad: int):
        """Padded (labels ++ signature) rows for the fused kernel's
        combined superset probe."""
        import jax.numpy as jnp

        def build():
            from repro.index import signature_rows

            bm = self.label_bitmap
            sig = signature_rows(self)
            rows = max(bm.shape[0], sig.shape[0], n_pad)
            wide = np.zeros((rows, bm.shape[1] + sig.shape[1]), np.uint32)
            wide[:bm.shape[0], :bm.shape[1]] = bm
            wide[:sig.shape[0], bm.shape[1]:] = sig
            return jnp.asarray(wide)

        return self._dev_cached(("filter_bitmap", n_pad), build)

    def dev_numeric(self, n_pad: int):
        import jax.numpy as jnp

        if self.numeric_value is None:
            return None

        def build():
            nv = self.numeric_value.astype(np.float32)
            if nv.shape[0] < n_pad:
                nv = np.concatenate(
                    [nv, np.full(n_pad - nv.shape[0], np.nan, np.float32)])
            return jnp.asarray(nv)

        return self._dev_cached(("numeric", n_pad), build)

    def base_el_row_padded(self, el: int, forward: bool, n_pad: int):
        """Base per-label indptr row padded to ``n_pad + 1`` (cached on the
        store — shared by every snapshot of this epoch)."""
        return self.store._padded_base(("el", el, forward, n_pad), self.epoch,
                                       self._build_base_el_row, el, forward,
                                       n_pad)

    def _build_base_el_row(self, el: int, forward: bool, n_pad: int):
        import jax.numpy as jnp

        base_dir = self.base.out if forward else self.base.inc
        if 0 <= el < self.base.n_elabels:
            row = base_dir.indptr_el[el].astype(np.int64)
        else:  # label exists only in the delta
            row = np.zeros(self.base.n_vertices + 1, dtype=np.int64)
        if row.shape[0] < n_pad + 1:
            row = np.concatenate(
                [row, np.full(n_pad + 1 - row.shape[0], row[-1], np.int64)])
        return jnp.asarray(row.astype(np.int32))

    def base_plain_padded(self, forward: bool, n_pad: int):
        return self.store._padded_base(("plain", forward, n_pad), self.epoch,
                                       self._build_base_plain, forward, n_pad)

    def _build_base_plain(self, forward: bool, n_pad: int):
        import jax.numpy as jnp

        base_dir = self.base.out if forward else self.base.inc
        row = base_dir.indptr_all.astype(np.int64)
        if row.shape[0] < n_pad + 1:
            row = np.concatenate(
                [row, np.full(n_pad + 1 - row.shape[0], row[-1], np.int64)])
        return jnp.asarray(row.astype(np.int32))


class VersionedStore:
    """Mutable store: immutable base graph + delta overlay + versioning.

    All mutating entry points take the store lock; ``snapshot()`` returns a
    cached immutable view that is invalidated by the next write.  Vertex,
    edge-label and vertex-label id spaces are append-only — ids handed out
    once stay valid across updates *and* compactions, which is what lets
    compiled plans and the serving layer's plan cache survive data changes.
    """

    def __init__(self, graph: LabeledGraph, maps=None, *,
                 compact_threshold: float = 0.25, compact_min: int = 4096,
                 auto_compact: bool = True):
        self.base = graph
        self.maps = maps
        self.version = 0
        self.epoch = 0
        self.compact_threshold = compact_threshold
        self.compact_min = compact_min
        self.auto_compact = auto_compact
        self._delta = EdgeDelta(graph)
        self._n_vertices = graph.n_vertices
        self._n_elabels = graph.n_elabels
        self._new_vlabel_sets: list[tuple[int, ...]] = []
        self._new_numeric: list[float] = []
        if maps is not None:
            # a reused TransformMaps may already have grown past this graph
            # (a previous store interned terms/predicates into it) — resume
            # from its id space so stale ids are never reassigned; the gap
            # vertices exist, label-free and edge-free, in every snapshot
            n0 = len(maps.vertex_to_term)
            if n0 > self._n_vertices:
                gap = n0 - self._n_vertices
                self._new_vlabel_sets = [()] * gap
                self._new_numeric = [math.nan] * gap
                self._n_vertices = n0
            self._n_elabels = max(self._n_elabels, len(maps.elabel_to_pred))
        self._label_patch: dict[int, tuple[int, ...]] = {}
        self._snapshot: Snapshot | None = None
        self._pad_cache: dict = {}
        self._lock = threading.RLock()
        self.counters = {"inserted": 0, "deleted": 0, "compactions": 0}

    # ------------------------------------------------------------ plumbing
    def _padded_base(self, key, epoch, build, *args):
        with self._lock:
            hit = self._pad_cache.get((epoch,) + key)
            if hit is None:
                hit = build(*args)
                self._pad_cache[(epoch,) + key] = hit
            return hit

    def _dirty(self) -> None:
        self._snapshot = None
        self.version += 1

    def delta_size(self) -> int:
        return len(self._delta)

    def should_compact(self) -> bool:
        return len(self._delta) >= max(
            self.compact_min,
            int(self.compact_threshold * max(1, self.base.n_edges)))

    # ------------------------------------------------------ graph-level API
    def add_vertex(self, labels: Sequence[int] = (),
                   numeric: float = math.nan) -> int:
        with self._lock:
            for lbl in labels:
                if not 0 <= lbl < self.base.n_vlabels:
                    raise ValueError(f"vertex label {lbl} out of range "
                                     f"(new label spaces need a re-transform)")
            vid = self._n_vertices
            self._n_vertices += 1
            self._new_vlabel_sets.append(tuple(sorted(set(labels))))
            self._new_numeric.append(float(numeric))
            self._dirty()
            return vid

    def insert_edges(self,
                     edges: Iterable[tuple[int, int, int]]) -> int:
        """Insert (src, elabel, dst) edges; returns how many changed state.
        Edge labels ≥ n_elabels extend the label space; vertex ids must
        already exist (``add_vertex`` first)."""
        with self._lock:
            n = 0
            for s, el, o in edges:
                if not (0 <= s < self._n_vertices
                        and 0 <= o < self._n_vertices):
                    raise ValueError(f"edge ({s},{el},{o}) references an "
                                     f"unknown vertex (n={self._n_vertices})")
                if el < 0:
                    raise ValueError("edge label must be >= 0")
                self._n_elabels = max(self._n_elabels, int(el) + 1)
                n += self._delta.insert(s, el, o)
            if n:
                self.counters["inserted"] += n
                self._dirty()
            return n

    def delete_edges(self,
                     edges: Iterable[tuple[int, int, int]]) -> int:
        with self._lock:
            n = 0
            for s, el, o in edges:
                n += self._delta.delete(int(s), int(el), int(o))
            if n:
                self.counters["deleted"] += n
                self._dirty()
            return n

    def set_vertex_labels(self, vid: int, labels: Sequence[int]) -> bool:
        """Replace a vertex's label set (monotone growth is what the RDF
        layer uses; arbitrary replacement is allowed at graph level)."""
        with self._lock:
            for lbl in labels:
                if not 0 <= lbl < self.base.n_vlabels:
                    raise ValueError(f"vertex label {lbl} out of range")
            new = tuple(sorted(set(labels)))
            if vid >= self.base.n_vertices:
                i = vid - self.base.n_vertices
                if self._new_vlabel_sets[i] == new:
                    return False
                self._new_vlabel_sets[i] = new
            else:
                cur = self._label_patch.get(
                    vid, self.base.vlabel_sets[vid]
                    if self.base.vlabel_sets else ())
                if cur == new:
                    return False
                self._label_patch[vid] = new
            self._dirty()
            return True

    # -------------------------------------------------------- RDF-level API
    def _require_maps(self):
        if self.maps is None:
            raise UpdateError("store has no TransformMaps; RDF-level updates "
                              "need the transform's term mappings")
        return self.maps

    def _vertex_for_term(self, term: str, pending: list[int]) -> int:
        maps = self._require_maps()
        vid = maps.vertex_of(term)
        if vid is not None:
            return vid
        tid = maps.dict.encode_term(term)
        vid = self._n_vertices
        self._n_vertices += 1
        self._new_vlabel_sets.append(())
        self._new_numeric.append(_numeric_of(term))
        maps.term_to_vertex[tid] = vid
        pending.append(tid)
        return vid

    def _elabel_for_pred(self, pred: str, create: bool) -> int | None:
        maps = self._require_maps()
        el = maps.elabel_of(pred)
        if el is not None or not create:
            return el
        pid = maps.dict.encode_predicate(pred)
        el = self._n_elabels
        self._n_elabels += 1
        maps.pred_to_elabel[pid] = el
        maps.elabel_to_pred = np.append(maps.elabel_to_pred, pid)
        return el

    def _labels_of(self, vid: int) -> tuple[int, ...]:
        if vid >= self.base.n_vertices:
            return self._new_vlabel_sets[vid - self.base.n_vertices]
        hit = self._label_patch.get(vid)
        if hit is not None:
            return hit
        return self.base.vlabel_sets[vid] if self.base.vlabel_sets else ()

    def _validate_triples(self, action: str,
                          triples: list[tuple[str, str, str]]) -> None:
        """Raise for any triple this store cannot apply.  Every
        ``UpdateError`` source is checkable up front, which is what makes
        a batch (and a whole ``apply_update`` request) all-or-nothing."""
        maps = self._require_maps()
        if maps.kind != "type_aware":
            return
        for _s, p, o in triples:
            if p == RDFS_SUBCLASSOF:
                raise UpdateError(
                    "rdf:subClassOf updates change the class hierarchy; "
                    "re-transform the dataset instead")
            if p != RDF_TYPE:
                continue
            if action == "delete":
                raise UpdateError(
                    "deleting rdf:type triples under the type-aware "
                    "transform requires a re-transform (label closures "
                    "are not invertible)")
            if maps.vlabel_of(o) is None:
                raise UpdateError(
                    f"rdf:type object {o!r} is not a known class; "
                    "new classes require a re-transform")

    def insert_triples(self,
                       triples: Iterable[tuple[str, str, str]]) -> int:
        """Insert decoded (subject, predicate, object) string triples.
        Under the type-aware transform, ``rdf:type`` triples with a *known*
        class grow the subject's label set through the class closure; new
        classes or ``rdf:subClassOf`` assertions raise (they change the
        label space and need a re-transform)."""
        maps = self._require_maps()
        type_aware = maps.kind == "type_aware"
        with self._lock:
            triples = list(triples)
            # validate BEFORE touching any state: a failed batch applies
            # nothing (no half-applied prefix leaking into the next
            # successful update's version)
            self._validate_triples("insert", triples)
            n = 0
            pending: list[int] = []
            try:
                for s, p, o in triples:
                    if type_aware and p == RDF_TYPE:
                        lbl = maps.vlabel_of(o)
                        closure = (maps.hierarchy.expand_types({lbl})
                                   if maps.hierarchy is not None else {lbl})
                        vid = self._vertex_for_term(s, pending)
                        cur = self._labels_of(vid)
                        new = tuple(sorted({*cur, *closure}))
                        if new != cur:
                            if vid >= self.base.n_vertices:
                                self._new_vlabel_sets[
                                    vid - self.base.n_vertices] = new
                            else:
                                self._label_patch[vid] = new
                            n += 1
                        continue
                    el = self._elabel_for_pred(p, create=True)
                    sv = self._vertex_for_term(s, pending)
                    ov = self._vertex_for_term(o, pending)
                    n += self._delta.insert(sv, el, ov)
            finally:
                self._flush_terms(pending)
            if n:
                self.counters["inserted"] += n
                self._dirty()
            return n

    def delete_triples(self,
                       triples: Iterable[tuple[str, str, str]]) -> int:
        """Delete decoded string triples.  Unknown terms/predicates are
        no-ops (nothing to delete).  ``rdf:type`` retraction under the
        type-aware transform raises: label closures are not invertible
        without the direct type sets, so it needs a re-transform."""
        maps = self._require_maps()
        with self._lock:
            triples = list(triples)
            self._validate_triples("delete", triples)
            n = 0
            for s, p, o in triples:
                el = self._elabel_for_pred(p, create=False)
                sv = maps.vertex_of(s)
                ov = maps.vertex_of(o)
                if el is None or sv is None or ov is None:
                    continue
                n += self._delta.delete(sv, el, ov)
            if n:
                self.counters["deleted"] += n
                self._dirty()
            return n

    def _flush_terms(self, pending: list[int]) -> None:
        if pending:
            maps = self.maps
            maps.vertex_to_term = np.concatenate(
                [maps.vertex_to_term, np.asarray(pending, dtype=np.int64)])

    def apply_update(self, text: str) -> dict:
        """Parse and apply SPARQL UPDATE text atomically: every op is
        validated before any is applied, so a rejected request mutates
        nothing.  Auto-compacts past the threshold.  Returns counters for
        the serving layer."""
        ops = parse_update(text)
        with self._lock:
            for op in ops:
                self._validate_triples(op.action, op.triples)
            # fault-injection site: after validation, before any mutation —
            # an injected commit fault must leave the store untouched
            _faults.fire("store_commit")
            inserted = deleted = 0
            for op in ops:
                if op.action == "insert":
                    inserted += self.insert_triples(op.triples)
                else:
                    deleted += self.delete_triples(op.triples)
            compacted = False
            if self.auto_compact and self.should_compact():
                self.compact()
                compacted = True
            return {"inserted": inserted, "deleted": deleted,
                    "compacted": compacted, "version": self.version,
                    "delta": len(self._delta)}

    # ------------------------------------------------------------ snapshots
    def snapshot(self) -> Snapshot:
        with self._lock:
            if self._snapshot is None:
                self._snapshot = Snapshot(
                    store=self, base=self.base, version=self.version,
                    epoch=self.epoch, n_vertices=self._n_vertices,
                    n_elabels=self._n_elabels,
                    coo=self._delta.materialize(),
                    new_vlabel_sets=list(self._new_vlabel_sets),
                    label_patch=dict(self._label_patch),
                    numeric_value=self._merged_numeric())
            return self._snapshot

    def _merged_numeric(self) -> np.ndarray | None:
        base_nv = self.base.numeric_value
        if base_nv is None and not self._new_numeric:
            return None
        if base_nv is None:
            base_nv = np.full(self.base.n_vertices, np.nan, np.float64)
        if not self._new_numeric:
            return base_nv
        return np.concatenate(
            [base_nv, np.asarray(self._new_numeric, dtype=np.float64)])

    def _merged_vlabel_sets(self) -> list[tuple[int, ...]]:
        base_sets = self.base.vlabel_sets or \
            [()] * self.base.n_vertices
        merged = list(base_sets)
        for vid, ls in self._label_patch.items():
            merged[vid] = ls
        merged.extend(self._new_vlabel_sets)
        return merged

    # ----------------------------------------------------------- compaction
    def compact(self) -> Snapshot:
        """Fold the delta into a fresh ``LabeledGraph`` (ids preserved) and
        incrementally patch the base's cached ``GraphStats``."""
        from repro.stats import patch_stats

        with self._lock:
            base = self.base
            src = np.repeat(np.arange(base.n_vertices, dtype=np.int64),
                            np.diff(base.out.indptr_all))
            dst = base.out.nbr_all.astype(np.int64)
            el = base.out.lab_all.astype(np.int64)
            tombs = np.asarray(list(self._delta.tombs), dtype=np.int64) \
                if self._delta.tombs else np.zeros((0, 3), np.int64)
            ins = np.asarray(list(self._delta.inserts), dtype=np.int64) \
                if self._delta.inserts else np.zeros((0, 3), np.int64)
            if tombs.shape[0]:
                nv, nel = self._n_vertices, self._n_elabels
                assert nv * nel * nv < 2**62, "composite edge key overflow"
                key = (src * nel + el) * nv + dst
                tkey = (tombs[:, 0] * nel + tombs[:, 1]) * nv + tombs[:, 2]
                keep = ~np.isin(key, tkey)
                src, el, dst = src[keep], el[keep], dst[keep]
            if ins.shape[0]:
                src = np.concatenate([src, ins[:, 0]])
                el = np.concatenate([el, ins[:, 1]])
                dst = np.concatenate([dst, ins[:, 2]])
            label_changes = [
                (vid, base.vlabel_sets[vid] if base.vlabel_sets else (), ls)
                for vid, ls in self._label_patch.items()]
            label_changes += [
                (base.n_vertices + i, (), ls)
                for i, ls in enumerate(self._new_vlabel_sets)]
            new_g = LabeledGraph.build(
                n_vertices=self._n_vertices, src=src, el=el, dst=dst,
                n_elabels=self._n_elabels,
                vlabel_sets=self._merged_vlabel_sets(),
                n_vlabels=base.n_vlabels,
                numeric_value=self._merged_numeric())
            old_stats = getattr(base, "_graph_stats", None)
            if old_stats is not None:
                new_g._graph_stats = patch_stats(
                    old_stats, new_g, ins=ins, tombs=tombs,
                    label_changes=label_changes)
            # repro.index maintenance: snapshots ran on conservative
            # overlays; compaction restores *exact* structures by patching
            # only the touched rows / count cells (same contract as
            # GraphStats — asserted against a rebuild in tests)
            old_sig = getattr(base, "_sig_index", None)
            if old_sig is not None:
                from repro.index import patch_index

                new_g._sig_index = patch_index(old_sig, new_g,
                                               ins=ins, tombs=tombs)
            old_sum = getattr(base, "_summary_graph", None)
            if old_sum is not None:
                from repro.index import patch_summary

                new_g._summary_graph = patch_summary(
                    old_sum, new_g, ins=ins, tombs=tombs,
                    label_changes=label_changes)
            log.info("compacted store: %d vertices, %d edges (delta was %d)",
                     new_g.n_vertices, new_g.n_edges, len(self._delta))
            self.base = new_g
            self._delta = EdgeDelta(new_g)
            self._new_vlabel_sets = []
            self._new_numeric = []
            self._label_patch = {}
            self._pad_cache.clear()
            self.epoch += 1
            self.counters["compactions"] += 1
            self._dirty()
            return self.snapshot()


def _numeric_of(term: str) -> float:
    if term.startswith('"'):
        end = term.find('"', 1)
        lex = term[1:end] if end > 0 else term.strip('"')
        try:
            return float(lex)
        except ValueError:
            return math.nan
    return math.nan
