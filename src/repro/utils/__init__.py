import repro.utils.compat  # noqa: F401  (installs jax version shims)
from repro.utils.logging import get_logger, log_event, set_json_logging
from repro.utils.timing import Timer, timed

__all__ = ["get_logger", "log_event", "set_json_logging", "Timer", "timed"]
