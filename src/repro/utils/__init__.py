import repro.utils.compat  # noqa: F401  (installs jax version shims)
from repro.utils.logging import get_logger
from repro.utils.timing import Timer, timed

__all__ = ["get_logger", "Timer", "timed"]
