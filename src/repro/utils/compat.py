"""jax version-compatibility shims.

The codebase is written against the jax>=0.5 public names ``jax.shard_map``
and ``jax.set_mesh``.  On older jax (0.4.x) those live elsewhere:

- ``shard_map``: ``jax.experimental.shard_map.shard_map``;
- ``set_mesh``: no equivalent, but ``Mesh`` is itself a context manager
  with the same ambient-mesh effect, so ``with jax.set_mesh(mesh):``
  degrades to ``with mesh:``.

Importing this module (repro.utils does it on package import) installs the
missing names onto ``jax`` so every call site — including test subprocesses
that only import repro — runs on either version unchanged.
"""

from __future__ import annotations

import jax

if not hasattr(jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def _shard_map(f, *args, **kwargs):
        # jax>=0.5 calls it check_vma; 0.4.x cannot express unchecked P()
        # outputs (check_rep=False rejects them), so always run checked
        kwargs.pop("check_vma", None)
        return _exp_shard_map(f, *args, **kwargs)

    jax.shard_map = _shard_map

if not hasattr(jax, "set_mesh"):
    def _set_mesh(mesh):
        # new-jax set_mesh returns a context manager; a 0.4.x Mesh already
        # is one (enter = make ambient), so pass it straight through
        return mesh

    jax.set_mesh = _set_mesh
