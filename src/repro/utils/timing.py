"""Wall-clock timing helpers used by benchmarks and the straggler tracker."""

from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field
from typing import Callable, Iterator


@dataclass
class Timer:
    """Accumulating timer: ``with timer.span("phase"): ...``; per-phase totals."""

    totals: dict[str, float] = field(default_factory=dict)
    counts: dict[str, int] = field(default_factory=dict)

    @contextlib.contextmanager
    def span(self, name: str) -> Iterator[None]:
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            self.totals[name] = self.totals.get(name, 0.0) + dt
            self.counts[name] = self.counts.get(name, 0) + 1

    def mean(self, name: str) -> float:
        return self.totals.get(name, 0.0) / max(1, self.counts.get(name, 0))

    def report(self) -> str:
        rows = sorted(self.totals.items(), key=lambda kv: -kv[1])
        return "\n".join(
            f"{k:40s} total={v * 1e3:10.2f}ms n={self.counts[k]:5d} "
            f"mean={self.mean(k) * 1e3:8.3f}ms"
            for k, v in rows
        )


def timed(fn: Callable, *args, repeats: int = 3, warmup: int = 1, **kwargs):
    """Best-effort microbenchmark: returns (result, seconds_per_call).

    Mirrors the paper's protocol (5 runs, drop best/worst, average the rest)
    when ``repeats >= 3``; jax results are block_until_ready'd.
    """
    result = None
    for _ in range(warmup):
        result = fn(*args, **kwargs)
        _block(result)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn(*args, **kwargs)
        _block(result)
        times.append(time.perf_counter() - t0)
    times.sort()
    if len(times) >= 3:
        times = times[1:-1]  # drop best and worst, like the paper
    return result, sum(times) / len(times)


def _block(x) -> None:
    try:
        import jax

        jax.block_until_ready(x)
    except Exception:
        pass
