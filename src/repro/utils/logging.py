"""Structured logging for the repro framework.

One logger per subsystem; format carries the subsystem so multi-host logs
interleave legibly.  ``REPRO_LOG=debug`` raises verbosity globally.
"""

from __future__ import annotations

import logging
import os
import sys

_FORMAT = "%(asctime)s %(levelname).1s %(name)s: %(message)s"
_configured = False


def _configure_root() -> None:
    global _configured
    if _configured:
        return
    level = getattr(logging, os.environ.get("REPRO_LOG", "info").upper(), logging.INFO)
    handler = logging.StreamHandler(sys.stderr)
    handler.setFormatter(logging.Formatter(_FORMAT, datefmt="%H:%M:%S"))
    root = logging.getLogger("repro")
    root.setLevel(level)
    root.addHandler(handler)
    root.propagate = False
    _configured = True


def get_logger(name: str) -> logging.Logger:
    _configure_root()
    if not name.startswith("repro"):
        name = f"repro.{name}"
    return logging.getLogger(name)
