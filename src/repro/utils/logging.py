"""Structured logging for the repro framework.

One logger per subsystem; format carries the subsystem so multi-host logs
interleave legibly.  ``REPRO_LOG=debug`` raises verbosity globally.

``REPRO_LOG_FORMAT=json`` (or :func:`set_json_logging`) switches the
handler to one-JSON-object-per-line output; :func:`log_event` emits
machine-parseable key=value events (request logs carry the scheduler's
correlation ``query_id``) that serialize as flat JSON fields in that
mode and as readable ``event k=v ...`` lines otherwise.
"""

from __future__ import annotations

import json
import logging
import os
import sys

_FORMAT = "%(asctime)s %(levelname).1s %(name)s: %(message)s"
_configured = False
_handler: logging.StreamHandler | None = None


class JsonFormatter(logging.Formatter):
    """One JSON object per line: ts/level/logger/message plus any flat
    fields attached by :func:`log_event`."""

    def format(self, record: logging.LogRecord) -> str:
        out = {
            "ts": self.formatTime(record, "%Y-%m-%dT%H:%M:%S"),
            "level": record.levelname.lower(),
            "logger": record.name,
        }
        event = getattr(record, "event", None)
        fields = getattr(record, "event_fields", None)
        if event is not None:
            out["event"] = event
            for k, v in (fields or {}).items():
                if k not in out:
                    out[k] = v
        else:
            out["message"] = record.getMessage()
        if record.exc_info and record.exc_info[0] is not None:
            out["exc"] = self.formatException(record.exc_info)
        return json.dumps(out, default=str)


def _configure_root() -> None:
    global _configured, _handler
    if _configured:
        return
    level = getattr(logging, os.environ.get("REPRO_LOG", "info").upper(), logging.INFO)
    _handler = logging.StreamHandler(sys.stderr)
    if os.environ.get("REPRO_LOG_FORMAT", "").lower() == "json":
        _handler.setFormatter(JsonFormatter())
    else:
        _handler.setFormatter(logging.Formatter(_FORMAT, datefmt="%H:%M:%S"))
    root = logging.getLogger("repro")
    root.setLevel(level)
    root.addHandler(_handler)
    root.propagate = False
    _configured = True


def set_json_logging(enabled: bool = True) -> None:
    """Switch the repro handler to (or from) JSON-lines output at runtime
    — the programmatic equivalent of ``REPRO_LOG_FORMAT=json``."""
    _configure_root()
    assert _handler is not None
    if enabled:
        _handler.setFormatter(JsonFormatter())
    else:
        _handler.setFormatter(logging.Formatter(_FORMAT, datefmt="%H:%M:%S"))


def log_event(logger: logging.Logger, event: str,
              level: int = logging.INFO, **fields) -> None:
    """Emit a structured event: ``event k=v ...`` as text, flat JSON
    fields under ``REPRO_LOG_FORMAT=json``.  The serving layer routes
    request logs through this with the correlation ``query_id``."""
    if not logger.isEnabledFor(level):
        return
    msg = event
    if fields:
        msg += " " + " ".join(f"{k}={v}" for k, v in fields.items())
    logger.log(level, msg, extra={"event": event, "event_fields": fields})


def get_logger(name: str) -> logging.Logger:
    _configure_root()
    if not name.startswith("repro"):
        name = f"repro.{name}"
    return logging.getLogger(name)
