"""repro.serve — the concurrent SPARQL serving subsystem.

Layers (bottom-up):

- :mod:`repro.serve.fingerprint` — structural query canonicalization; the
  cache key that lets alpha-equivalent queries share one compiled plan;
- :mod:`repro.serve.cache` — bounded LRU plan/result caches with stats;
- :mod:`repro.serve.metrics` — counters/gauges/histograms + Prometheus text;
- :mod:`repro.serve.scheduler` — admission control, deadlines, and
  coalescing of identical in-flight queries over a worker pool;
- :mod:`repro.serve.server` — multi-dataset registry + stdlib
  ``ThreadingHTTPServer`` (``/sparql``, ``/healthz``, ``/metrics``).

Submodules are imported lazily so the low-level pieces (``cache``,
``fingerprint``) stay importable from ``repro.core`` without pulling the
HTTP stack (which itself imports ``repro.core``) into a cycle.
"""

from __future__ import annotations

_EXPORTS = {
    "CanonicalQuery": "repro.serve.fingerprint",
    "canonicalize_query": "repro.serve.fingerprint",
    "fingerprint_query": "repro.serve.fingerprint",
    "serialize_query": "repro.serve.fingerprint",
    "CacheStats": "repro.serve.cache",
    "LRUCache": "repro.serve.cache",
    "PlanCache": "repro.serve.cache",
    "ResultCache": "repro.serve.cache",
    "Counter": "repro.serve.metrics",
    "Gauge": "repro.serve.metrics",
    "Histogram": "repro.serve.metrics",
    "MetricsRegistry": "repro.serve.metrics",
    "ServeMetrics": "repro.serve.metrics",
    "DeadlineExceeded": "repro.serve.scheduler",
    "Overloaded": "repro.serve.scheduler",
    "Scheduler": "repro.serve.scheduler",
    "SchedulerError": "repro.serve.scheduler",
    "DatasetRegistry": "repro.serve.server",
    "HostedDataset": "repro.serve.server",
    "SparqlHTTPServer": "repro.serve.server",
    "UnknownDataset": "repro.serve.server",
    "UpdateNotSupported": "repro.serve.server",
    "make_server": "repro.serve.server",
    "serve_in_thread": "repro.serve.server",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.serve' has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod), name)


def __dir__() -> list[str]:
    return __all__
