"""Thread-based request scheduler with admission control and micro-batching.

Requests are canonicalized on the submitting thread (cheap, pure-Python)
and keyed ``(dataset, fingerprint, graph_version)``.  Concurrent requests
with the same key *coalesce*: one flight executes, every waiter gets the
shared result with its own variable names restored — the serving-layer
analogue of the engine's shared-plan compilation, applied to execution.

Distinct queries of the same *shape* (same structure, different constants)
additionally coalesce into one **batched dispatch**: the submitting thread
parameterizes the query (``fingerprint.parameterize_query``), flights are
grouped by ``(dataset, shape, graph_version)``, and the worker that picks
up the first such flight *claims* up to ``batch_max - 1`` same-shape
queued peers and answers the whole batch in one vmapped device launch via
``registry.execute_canonical_batch`` — splitting results back per request.
A ``batch_window_ms`` micro-deadline optionally holds a lone eligible
flight briefly to let peers arrive.  Forced-trace flights never coalesce
or batch (each requester wants *their* execution observed), but their
traces carry a ``batch_assemble`` span so batched and solo timelines stay
comparable.

Admission control bounds the number of queued flights (excess submissions
fail fast with :class:`Overloaded`) and every request carries a deadline:
waiters stop waiting when it passes, and a flight that is still queued past
its deadline is dropped without executing.
"""

from __future__ import annotations

import contextlib
import itertools
import queue
import threading
import time
import uuid
from dataclasses import dataclass, field

from repro.core.sparql_exec import QueryResult
from repro.rdf.sparql import SelectQuery, parse_sparql
from repro.resilience.cancel import CancelToken, QueryCancelled
from repro.serve.fingerprint import (CanonicalQuery, ParamQuery,
                                     canonicalize_query, parameterize_query)
from repro.serve.metrics import ServeMetrics
from repro.utils import get_logger

log = get_logger("serve.scheduler")


def _maybe_span(trace, name: str, **meta):
    return (trace.span(name, **meta) if trace is not None
            else contextlib.nullcontext())


# correlation ids: one per *flight* (coalesced waiters share their leader's
# id — the id names the execution, not the HTTP request).  A short random
# process prefix keeps ids from different server processes distinguishable
# in merged logs.
_qid_prefix = uuid.uuid4().hex[:6]
_qid_counter = itertools.count(1)


def next_query_id() -> str:
    """Process-unique correlation id for one scheduled flight."""
    return f"{_qid_prefix}-{next(_qid_counter):06d}"


class SchedulerError(RuntimeError):
    pass


class Overloaded(SchedulerError):
    """Admission control rejected the request (queue full).

    ``retry_after_s`` estimates when the queue should have drained enough
    to accept new work (surfaced as the HTTP ``Retry-After`` header)."""

    def __init__(self, message: str, retry_after_s: float = 1.0) -> None:
        super().__init__(message)
        self.retry_after_s = retry_after_s


class DeadlineExceeded(SchedulerError):
    """The request's deadline passed before a result was ready.

    ``queue_wait_ms`` / ``exec_ms`` split where the time went (queued vs.
    executing) so clients can tune their backoff."""

    def __init__(self, message: str, queue_wait_ms: float | None = None,
                 exec_ms: float | None = None) -> None:
        super().__init__(message)
        self.queue_wait_ms = queue_wait_ms
        self.exec_ms = exec_ms


class SchedulerStopped(SchedulerError):
    """submit() called on a scheduler that is not running."""


class SchedulerShutdown(SchedulerError):
    """The scheduler stopped while this flight was still unfinished."""


@dataclass
class _Flight:
    key: tuple
    dataset: str
    canonical: CanonicalQuery
    version: int
    deadline: float  # absolute monotonic; max over attached waiters
    done: threading.Event = field(default_factory=threading.Event)
    result: QueryResult | None = None
    error: Exception | None = None
    waiters: int = 1
    trace: object | None = None  # repro.obs.Trace for forced-trace requests
    query_id: str = ""  # correlation id, threaded through traces/logs/journal
    # same-shape batching: the parameterized form (None = batching-
    # ineligible), the batch key (dataset, shape, version), and whether a
    # batch leader already claimed this flight (its worker then skips it)
    param: ParamQuery | None = None
    bkey: tuple | None = None
    claimed: bool = False
    # cooperative cancellation: the token travels into the executor's chunk
    # loop; queue-wait vs. execution timing feeds 504 error bodies
    cancel: CancelToken = field(default_factory=CancelToken)
    t_submit: float = 0.0  # monotonic, set at enqueue
    t_start: float | None = None  # monotonic, set when a worker picks it up

    def timing_ms(self, now: float | None = None) -> tuple[float, float]:
        """(queue_wait_ms, exec_ms) as of ``now``."""
        now = time.monotonic() if now is None else now
        if self.t_start is None:
            return max(0.0, now - self.t_submit) * 1e3, 0.0
        return (max(0.0, self.t_start - self.t_submit) * 1e3,
                max(0.0, now - self.t_start) * 1e3)


_SENTINEL = object()


class Scheduler:
    """Request queue + worker pool in front of a dataset registry.

    ``registry`` needs two methods: ``version(dataset) -> int`` and
    ``execute_canonical(dataset, canonical, version) -> QueryResult`` (see
    :class:`repro.serve.server.DatasetRegistry`).
    """

    def __init__(self, registry, *, workers: int = 4, max_queue: int = 64,
                 default_timeout_s: float = 30.0,
                 metrics: ServeMetrics | None = None,
                 batch_max: int = 16, batch_window_ms: float = 0.0):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        self.registry = registry
        self.max_queue = max_queue
        self.default_timeout_s = default_timeout_s
        self.metrics = metrics or ServeMetrics()
        # same-shape batching: at most batch_max queries per dispatch;
        # batch_max <= 1 disables batching entirely.  batch_window_ms > 0
        # holds a lone eligible flight that long for peers to arrive
        # (trades a bounded latency bump for batching under light load).
        self.batch_max = batch_max
        self.batch_window_s = max(0.0, batch_window_ms) / 1e3
        self._can_batch = (batch_max > 1 and callable(
            getattr(registry, "execute_canonical_batch", None)))
        # duck-typed registries (tests, custom backends) may not know the
        # ``cancel`` / ``query_id`` kwargs — probe the signatures once
        def _accepts(fn, name: str) -> bool:
            try:
                import inspect

                return fn is not None and name in inspect.signature(
                    fn).parameters
            except (TypeError, ValueError):
                return False

        reg_exec = getattr(registry, "execute_canonical", None)
        reg_batch = getattr(registry, "execute_canonical_batch", None)
        self._reg_accepts_cancel = _accepts(reg_exec, "cancel")
        self._reg_accepts_qid = _accepts(reg_exec, "query_id")
        self._batch_accepts_cancel = _accepts(reg_batch, "cancel")
        self._batch_accepts_qids = _accepts(reg_batch, "query_ids")
        # EMA of execution time, for the Overloaded Retry-After estimate
        self._ema_exec_ms = 50.0
        self._queue: queue.Queue = queue.Queue()
        self._inflight: dict[tuple, _Flight] = {}
        self._pending: dict[tuple, list[_Flight]] = {}  # bkey -> queued
        self._lock = threading.Lock()
        self._threads: list[threading.Thread] = []
        self._running = False
        self._n_workers = workers

    # ---------------------------------------------------------- lifecycle
    def start(self) -> "Scheduler":
        with self._lock:
            if self._running:
                return self
            self._running = True
        self.metrics.bind_queue_depth(self._queue.qsize)
        for i in range(self._n_workers):
            t = threading.Thread(target=self._worker, daemon=True,
                                 name=f"serve-worker-{i}")
            t.start()
            self._threads.append(t)
        return self

    def stop(self, wait: bool = True) -> None:
        """Stop the worker pool.

        Every unfinished flight is failed with :class:`SchedulerShutdown`
        (waking all its waiters) and in-flight executions are cancelled via
        their tokens, so no waiter blocks past shutdown.  A worker thread
        that fails to join (stuck in a non-cooperative call) is *logged* as
        leaked rather than silently dropped — its flight has already been
        failed, so nothing waits on it."""
        with self._lock:
            if not self._running:
                return
            self._running = False
            inflight = list(self._inflight.values())
        # cancel running executions first so stuck workers get a chance to
        # exit at their next chunk boundary before the join deadline
        for f in inflight:
            f.cancel.cancel("scheduler shutdown")
        # fail every unfinished flight *now*: waiters wake immediately with
        # SchedulerShutdown instead of riding out the worker join below
        failed = 0
        with self._lock:
            for f in list(self._inflight.values()):
                if not f.done.is_set():
                    failed += 1
                self._finish_locked(f, error=SchedulerShutdown(
                    "scheduler stopped before this flight finished"))
            self._pending.clear()
        for _ in self._threads:
            self._queue.put(_SENTINEL)
        leaked: list[str] = []
        if wait:
            for t in self._threads:
                t.join(timeout=5.0)
                if t.is_alive():
                    leaked.append(t.name)
        self._threads.clear()
        # sweep flights a concurrent submit may have registered between the
        # _running flip and its queue put
        with self._lock:
            remaining = [f for f in self._inflight.values()
                         if not f.done.is_set()]
            self._inflight.clear()
            self._pending.clear()
        failed += len(remaining)
        for f in remaining:
            self._finish(f, error=SchedulerShutdown(
                "scheduler stopped before this flight finished"))
        if leaked:
            log.warning(
                "scheduler stop: %d worker thread(s) failed to join within "
                "5s and leaked: %s (their flights were failed with "
                "SchedulerShutdown)", len(leaked), ", ".join(leaked))
        if failed:
            log.info("scheduler stop: failed %d unfinished flight(s) with "
                     "SchedulerShutdown", failed)

    def __enter__(self) -> "Scheduler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------- submit
    def submit(self, dataset: str, query: str | SelectQuery | CanonicalQuery,
               timeout_s: float | None = None,
               trace: bool = False) -> QueryResult:
        """Execute (or join) a query; returns bindings with the caller's
        variable names.  Raises ``Overloaded`` / ``DeadlineExceeded`` /
        parse and plan errors from the engine.

        ``trace=True`` forces a profiled :class:`repro.obs.Trace` for this
        request: the result's ``stats["trace"]`` carries the span tree.
        Forced-trace flights never coalesce (each requester wants *their*
        execution observed), and parse/canonicalize happen inside the trace
        so the span sum accounts for the submitting thread's work too."""
        if not self._running:
            raise SchedulerStopped("scheduler is not running; call start()")
        t0 = time.perf_counter()
        t = None
        if trace:
            from repro.obs import Trace
            t = Trace(profile_steps=True)
        pq: ParamQuery | None = None
        if isinstance(query, CanonicalQuery):
            canon = query
        else:
            if isinstance(query, str):
                with _maybe_span(t, "parse"):
                    query = parse_sparql(query)
            with _maybe_span(t, "fingerprint"):
                if t is None and self._can_batch:
                    # shape + constants in one pass (canonicalization is a
                    # sub-step of parameterization, so no duplicate work)
                    pq = parameterize_query(query)
                    canon = pq.canon
                    if not pq.consts:
                        pq = None
                else:
                    canon = canonicalize_query(query)
        version = self.registry.version(dataset)
        timeout = self.default_timeout_s if timeout_s is None else timeout_s
        deadline = time.monotonic() + timeout
        key = (dataset, canon.fingerprint, version)
        if t is not None:
            # unique tail: a forced trace must execute, never coalesce
            key = key + (("trace", t.trace_id),)

        with self._lock:
            flight = self._inflight.get(key)
            if flight is not None and not flight.done.is_set():
                flight.waiters += 1
                flight.deadline = max(flight.deadline, deadline)
                flight.cancel.extend(deadline)
                self.metrics.coalesced.inc()
                coalesced = True
            else:
                if self._queue.qsize() >= self.max_queue:
                    self.metrics.record(dataset, "overloaded",
                                        (time.perf_counter() - t0) * 1e3)
                    raise Overloaded(
                        f"queue full ({self.max_queue} flights pending)",
                        retry_after_s=self.retry_after_s())
                flight = _Flight(key=key, dataset=dataset, canonical=canon,
                                 version=version, deadline=deadline, trace=t,
                                 query_id=next_query_id(),
                                 cancel=CancelToken(deadline),
                                 t_submit=time.monotonic())
                if t is not None:
                    t.query_id = flight.query_id
                    t.dataset = dataset
                if pq is not None:
                    flight.param = pq
                    flight.bkey = (dataset, pq.shape, version)
                    self._pending.setdefault(flight.bkey, []).append(flight)
                self._inflight[key] = flight
                self._queue.put(flight)
                coalesced = False
        self.metrics.inflight.inc()
        self.metrics.dataset_inflight.inc(dataset)
        self.metrics.queue_depth.set(self._queue.qsize())
        try:
            finished = flight.done.wait(max(0.0, deadline - time.monotonic()))
            ms = (time.perf_counter() - t0) * 1e3
            if not finished:
                self.metrics.record(dataset, "timeout", ms)
                qw, ex = flight.timing_ms()
                raise DeadlineExceeded(
                    f"no result within {timeout:.3f}s "
                    f"({'coalesced' if coalesced else 'leader'})",
                    queue_wait_ms=qw, exec_ms=ex)
            if flight.error is not None:
                status = ("timeout" if isinstance(flight.error,
                                                  DeadlineExceeded)
                          else "cancelled" if isinstance(flight.error,
                                                         QueryCancelled)
                          else "error")
                self.metrics.record(dataset, status, ms)
                raise flight.error
            self.metrics.record(dataset, "ok", ms)
            res = flight.result
            assert res is not None
            stats = dict(res.stats)
            stats["query_id"] = flight.query_id
            return QueryResult(canon.restore(res.variables), res.rows,
                               list(res.kinds), count=res.count,
                               stats=stats)
        finally:
            self.metrics.inflight.dec()
            self.metrics.dataset_inflight.dec(dataset)
            with self._lock:
                flight.waiters -= 1
                abandoned = flight.waiters <= 0 and not flight.done.is_set()
            if abandoned:
                # every waiter is gone (timed out or errored): cancel the
                # execution so it stops occupying the device
                flight.cancel.cancel("all waiters abandoned the flight")

    # ----------------------------------------------------------- finalize
    def _finish_locked(self, flight: _Flight,
                       result: QueryResult | None = None,
                       error: Exception | None = None) -> None:
        """Finalize a flight exactly once (caller holds the lock):
        de-register it, store the outcome, wake every waiter.  Idempotent —
        shutdown and a slow worker may race to finish the same flight."""
        if self._inflight.get(flight.key) is flight:
            del self._inflight[flight.key]
        self._unpend(flight)
        if flight.done.is_set():
            return
        flight.result, flight.error = result, error
        if result is not None and flight.t_start is not None:
            _, exec_ms = flight.timing_ms()
            self._ema_exec_ms = 0.8 * self._ema_exec_ms + 0.2 * exec_ms
        flight.done.set()

    def _finish(self, flight: _Flight, result: QueryResult | None = None,
                error: Exception | None = None) -> None:
        with self._lock:
            self._finish_locked(flight, result=result, error=error)

    def retry_after_s(self) -> float:
        """Seconds until the queue has likely drained enough to retry:
        per-worker backlog times the execution-time EMA, clamped to
        [0.5s, 30s].  Feeds the 503 ``Retry-After`` header."""
        backlog = self._queue.qsize() / max(1, self._n_workers)
        return min(30.0, max(0.5, backlog * self._ema_exec_ms / 1e3))

    # ------------------------------------------------------------- worker
    def _worker(self) -> None:
        while True:
            flight = self._queue.get()
            if flight is _SENTINEL:
                return
            self.metrics.queue_depth.set(self._queue.qsize())
            # expiry check and de-registration are atomic with submit's
            # attach/deadline-extend, so no request can coalesce onto a
            # flight that is about to be declared dead; a claimed flight
            # was (or is being) answered by a batch leader — skip it
            with self._lock:
                if flight.claimed:
                    continue
                dead = (time.monotonic() > flight.deadline
                        or flight.cancel.cancelled)
                if dead:
                    qw, ex = flight.timing_ms()
                    self._finish_locked(flight, error=DeadlineExceeded(
                        "expired while queued (admission backlog)",
                        queue_wait_ms=qw, exec_ms=ex))
            if dead:
                continue
            flight.t_start = time.monotonic()
            if flight.param is not None and flight.trace is None:
                self._run_batch(flight)
                continue
            if flight.trace is not None:
                flight.trace.thread = threading.current_thread().name
                # forced traces never batch; record the (empty) assembly
                # phase so batched and solo timelines stay comparable
                t_asm = time.perf_counter()
                flight.trace.add("batch_assemble",
                                 time.perf_counter() - t_asm, batch=1)
            err: Exception | None = None
            result = None
            try:
                # pass trace/cancel only when applicable so duck-typed
                # registries that don't know the kwargs (tests, custom
                # backends) keep working
                kwargs = {}
                if flight.trace is not None:
                    kwargs["trace"] = flight.trace
                if self._reg_accepts_cancel:
                    kwargs["cancel"] = flight.cancel
                if self._reg_accepts_qid:
                    kwargs["query_id"] = flight.query_id
                result = self.registry.execute_canonical(
                    flight.dataset, flight.canonical, flight.version,
                    **kwargs)
            except QueryCancelled as e:
                self.metrics.cancelled.inc()
                if e.queue_wait_ms is None:
                    e.queue_wait_ms, e.exec_ms = flight.timing_ms()
                err = e
            except Exception as e:  # noqa: BLE001 — fan the error out
                err = e
            self._finish(flight, result=result, error=err)

    # ----------------------------------------------------------- batching
    def _unpend(self, flight: _Flight) -> None:
        """Drop a flight from its batch-pending list (caller holds lock)."""
        if flight.bkey is None:
            return
        pend = self._pending.get(flight.bkey)
        if pend is not None:
            try:
                pend.remove(flight)
            except ValueError:
                pass
            if not pend:
                self._pending.pop(flight.bkey, None)

    def _claim_peers(self, leader: _Flight, n: int) -> list[_Flight]:
        """Claim up to ``n`` queued same-shape peers (caller holds lock).
        Expired peers found along the way are failed in place."""
        pend = self._pending.get(leader.bkey)
        if not pend or n <= 0:
            return []
        now = time.monotonic()
        taken: list[_Flight] = []
        kept: list[_Flight] = []
        # copy: _finish_locked on an expired peer unpends it from `pend`
        for f in list(pend):
            if f is leader or f.claimed:
                continue
            if now > f.deadline or f.cancel.cancelled:
                f.claimed = True
                qw, ex = f.timing_ms(now)
                self._finish_locked(f, error=DeadlineExceeded(
                    "expired while queued (admission backlog)",
                    queue_wait_ms=qw, exec_ms=ex))
            elif len(taken) < n:
                f.claimed = True
                taken.append(f)
            else:
                kept.append(f)
        if kept:
            self._pending[leader.bkey] = kept
        else:
            self._pending.pop(leader.bkey, None)
        return taken

    def _run_batch(self, leader: _Flight) -> None:
        """Lead a same-shape batch: claim queued peers, answer the whole
        batch via ``registry.execute_canonical_batch`` (one vmapped device
        launch when the shape parameterizes), fan results back out."""
        batch = [leader]
        with self._lock:
            self._unpend(leader)
            batch += self._claim_peers(leader, self.batch_max - 1)
        if len(batch) < self.batch_max and self.batch_window_s > 0:
            # micro-deadline: hold an under-full batch briefly so arrivals
            # still in the parse/fingerprint stage can join — batching
            # amortizes so steeply that a few ms of queueing is repaid
            # whenever there is any same-shape pressure at all
            time.sleep(min(self.batch_window_s,
                           max(0.0, leader.deadline - time.monotonic())))
            with self._lock:
                batch += self._claim_peers(leader,
                                           self.batch_max - len(batch))
        now = time.monotonic()
        for f in batch:
            if f.t_start is None:
                f.t_start = now
        # one token for the whole dispatch: live until the *latest* member
        # deadline, and cancelled only when every member's token is — a
        # batch keeps running as long as anyone still wants its answer
        group = CancelToken(max(f.deadline for f in batch))
        try:
            kwargs = {"cancel": group} if self._batch_accepts_cancel else {}
            if self._batch_accepts_qids:
                kwargs["query_ids"] = [f.query_id for f in batch]
            out = self.registry.execute_canonical_batch(
                leader.dataset, [f.param for f in batch], leader.version,
                **kwargs)
            if len(out) != len(batch):
                raise SchedulerError(
                    f"registry returned {len(out)} results for a batch "
                    f"of {len(batch)}")
        except QueryCancelled as e:
            self.metrics.cancelled.inc(len(batch))
            out = [e] * len(batch)
        except Exception as e:  # noqa: BLE001 — fan the error out
            out = [e] * len(batch)
        with self._lock:
            for f, r in zip(batch, out):
                if isinstance(r, Exception):
                    self._finish_locked(f, error=r)
                else:
                    self._finish_locked(f, result=r)

    # -------------------------------------------------------------- stats
    def snapshot(self) -> dict:
        with self._lock:
            inflight = len(self._inflight)
            alive = sum(1 for t in self._threads if t.is_alive())
        return {"inflight": inflight, "queued": self._queue.qsize(),
                "workers": self._n_workers, "workers_alive": alive,
                "running": self._running, "max_queue": self.max_queue,
                "retry_after_s": round(self.retry_after_s(), 3),
                "ema_exec_ms": round(self._ema_exec_ms, 3),
                **self.metrics.summary()}
