"""Thread-based request scheduler with admission control and micro-batching.

Requests are canonicalized on the submitting thread (cheap, pure-Python)
and keyed ``(dataset, fingerprint, graph_version)``.  Concurrent requests
with the same key *coalesce*: one flight executes, every waiter gets the
shared result with its own variable names restored — the serving-layer
analogue of the engine's shared-plan compilation, applied to execution.

Distinct queries of the same *shape* (same structure, different constants)
additionally coalesce into one **batched dispatch**: the submitting thread
parameterizes the query (``fingerprint.parameterize_query``), flights are
grouped by ``(dataset, shape, graph_version)``, and the worker that picks
up the first such flight *claims* up to ``batch_max - 1`` same-shape
queued peers and answers the whole batch in one vmapped device launch via
``registry.execute_canonical_batch`` — splitting results back per request.
A ``batch_window_ms`` micro-deadline optionally holds a lone eligible
flight briefly to let peers arrive.  Forced-trace flights never coalesce
or batch (each requester wants *their* execution observed), but their
traces carry a ``batch_assemble`` span so batched and solo timelines stay
comparable.

Admission control bounds the number of queued flights (excess submissions
fail fast with :class:`Overloaded`) and every request carries a deadline:
waiters stop waiting when it passes, and a flight that is still queued past
its deadline is dropped without executing.
"""

from __future__ import annotations

import contextlib
import queue
import threading
import time
from dataclasses import dataclass, field

from repro.core.sparql_exec import QueryResult
from repro.rdf.sparql import SelectQuery, parse_sparql
from repro.serve.fingerprint import (CanonicalQuery, ParamQuery,
                                     canonicalize_query, parameterize_query)
from repro.serve.metrics import ServeMetrics
from repro.utils import get_logger

log = get_logger("serve.scheduler")


def _maybe_span(trace, name: str, **meta):
    return (trace.span(name, **meta) if trace is not None
            else contextlib.nullcontext())


class SchedulerError(RuntimeError):
    pass


class Overloaded(SchedulerError):
    """Admission control rejected the request (queue full)."""


class DeadlineExceeded(SchedulerError):
    """The request's deadline passed before a result was ready."""


class SchedulerStopped(SchedulerError):
    """submit() called on a scheduler that is not running."""


@dataclass
class _Flight:
    key: tuple
    dataset: str
    canonical: CanonicalQuery
    version: int
    deadline: float  # absolute monotonic; max over attached waiters
    done: threading.Event = field(default_factory=threading.Event)
    result: QueryResult | None = None
    error: Exception | None = None
    waiters: int = 1
    trace: object | None = None  # repro.obs.Trace for forced-trace requests
    # same-shape batching: the parameterized form (None = batching-
    # ineligible), the batch key (dataset, shape, version), and whether a
    # batch leader already claimed this flight (its worker then skips it)
    param: ParamQuery | None = None
    bkey: tuple | None = None
    claimed: bool = False


_SENTINEL = object()


class Scheduler:
    """Request queue + worker pool in front of a dataset registry.

    ``registry`` needs two methods: ``version(dataset) -> int`` and
    ``execute_canonical(dataset, canonical, version) -> QueryResult`` (see
    :class:`repro.serve.server.DatasetRegistry`).
    """

    def __init__(self, registry, *, workers: int = 4, max_queue: int = 64,
                 default_timeout_s: float = 30.0,
                 metrics: ServeMetrics | None = None,
                 batch_max: int = 16, batch_window_ms: float = 0.0):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        self.registry = registry
        self.max_queue = max_queue
        self.default_timeout_s = default_timeout_s
        self.metrics = metrics or ServeMetrics()
        # same-shape batching: at most batch_max queries per dispatch;
        # batch_max <= 1 disables batching entirely.  batch_window_ms > 0
        # holds a lone eligible flight that long for peers to arrive
        # (trades a bounded latency bump for batching under light load).
        self.batch_max = batch_max
        self.batch_window_s = max(0.0, batch_window_ms) / 1e3
        self._can_batch = (batch_max > 1 and callable(
            getattr(registry, "execute_canonical_batch", None)))
        self._queue: queue.Queue = queue.Queue()
        self._inflight: dict[tuple, _Flight] = {}
        self._pending: dict[tuple, list[_Flight]] = {}  # bkey -> queued
        self._lock = threading.Lock()
        self._threads: list[threading.Thread] = []
        self._running = False
        self._n_workers = workers

    # ---------------------------------------------------------- lifecycle
    def start(self) -> "Scheduler":
        with self._lock:
            if self._running:
                return self
            self._running = True
        self.metrics.bind_queue_depth(self._queue.qsize)
        for i in range(self._n_workers):
            t = threading.Thread(target=self._worker, daemon=True,
                                 name=f"serve-worker-{i}")
            t.start()
            self._threads.append(t)
        return self

    def stop(self, wait: bool = True) -> None:
        with self._lock:
            if not self._running:
                return
            self._running = False
        for _ in self._threads:
            self._queue.put(_SENTINEL)
        if wait:
            for t in self._threads:
                t.join(timeout=5.0)
        self._threads.clear()

    def __enter__(self) -> "Scheduler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------- submit
    def submit(self, dataset: str, query: str | SelectQuery | CanonicalQuery,
               timeout_s: float | None = None,
               trace: bool = False) -> QueryResult:
        """Execute (or join) a query; returns bindings with the caller's
        variable names.  Raises ``Overloaded`` / ``DeadlineExceeded`` /
        parse and plan errors from the engine.

        ``trace=True`` forces a profiled :class:`repro.obs.Trace` for this
        request: the result's ``stats["trace"]`` carries the span tree.
        Forced-trace flights never coalesce (each requester wants *their*
        execution observed), and parse/canonicalize happen inside the trace
        so the span sum accounts for the submitting thread's work too."""
        if not self._running:
            raise SchedulerStopped("scheduler is not running; call start()")
        t0 = time.perf_counter()
        t = None
        if trace:
            from repro.obs import Trace
            t = Trace(profile_steps=True)
        pq: ParamQuery | None = None
        if isinstance(query, CanonicalQuery):
            canon = query
        else:
            if isinstance(query, str):
                with _maybe_span(t, "parse"):
                    query = parse_sparql(query)
            with _maybe_span(t, "fingerprint"):
                if t is None and self._can_batch:
                    # shape + constants in one pass (canonicalization is a
                    # sub-step of parameterization, so no duplicate work)
                    pq = parameterize_query(query)
                    canon = pq.canon
                    if not pq.consts:
                        pq = None
                else:
                    canon = canonicalize_query(query)
        version = self.registry.version(dataset)
        timeout = self.default_timeout_s if timeout_s is None else timeout_s
        deadline = time.monotonic() + timeout
        key = (dataset, canon.fingerprint, version)
        if t is not None:
            # unique tail: a forced trace must execute, never coalesce
            key = key + (("trace", t.trace_id),)

        with self._lock:
            flight = self._inflight.get(key)
            if flight is not None and not flight.done.is_set():
                flight.waiters += 1
                flight.deadline = max(flight.deadline, deadline)
                self.metrics.coalesced.inc()
                coalesced = True
            else:
                if self._queue.qsize() >= self.max_queue:
                    self.metrics.record(dataset, "overloaded",
                                        (time.perf_counter() - t0) * 1e3)
                    raise Overloaded(
                        f"queue full ({self.max_queue} flights pending)")
                flight = _Flight(key=key, dataset=dataset, canonical=canon,
                                 version=version, deadline=deadline, trace=t)
                if pq is not None:
                    flight.param = pq
                    flight.bkey = (dataset, pq.shape, version)
                    self._pending.setdefault(flight.bkey, []).append(flight)
                self._inflight[key] = flight
                self._queue.put(flight)
                coalesced = False
        self.metrics.inflight.inc()
        self.metrics.dataset_inflight.inc(dataset)
        self.metrics.queue_depth.set(self._queue.qsize())
        try:
            finished = flight.done.wait(max(0.0, deadline - time.monotonic()))
            ms = (time.perf_counter() - t0) * 1e3
            if not finished:
                self.metrics.record(dataset, "timeout", ms)
                raise DeadlineExceeded(
                    f"no result within {timeout:.3f}s "
                    f"({'coalesced' if coalesced else 'leader'})")
            if flight.error is not None:
                status = ("timeout" if isinstance(flight.error,
                                                  DeadlineExceeded) else "error")
                self.metrics.record(dataset, status, ms)
                raise flight.error
            self.metrics.record(dataset, "ok", ms)
            res = flight.result
            assert res is not None
            return QueryResult(canon.restore(res.variables), res.rows,
                               list(res.kinds), count=res.count,
                               stats=dict(res.stats))
        finally:
            self.metrics.inflight.dec()
            self.metrics.dataset_inflight.dec(dataset)

    # ------------------------------------------------------------- worker
    def _worker(self) -> None:
        while True:
            flight = self._queue.get()
            if flight is _SENTINEL:
                return
            self.metrics.queue_depth.set(self._queue.qsize())
            # expiry check and de-registration are atomic with submit's
            # attach/deadline-extend, so no request can coalesce onto a
            # flight that is about to be declared dead; a claimed flight
            # was (or is being) answered by a batch leader — skip it
            with self._lock:
                if flight.claimed:
                    continue
                expired = time.monotonic() > flight.deadline
                if expired:
                    self._inflight.pop(flight.key, None)
                    self._unpend(flight)
            if expired:
                flight.error = DeadlineExceeded(
                    "expired while queued (admission backlog)")
                flight.done.set()
                continue
            if flight.param is not None and flight.trace is None:
                self._run_batch(flight)
                continue
            if flight.trace is not None:
                # forced traces never batch; record the (empty) assembly
                # phase so traced and batched timelines stay comparable
                t_asm = time.perf_counter()
                flight.trace.add("batch_assemble",
                                 time.perf_counter() - t_asm, batch=1)
            err: Exception | None = None
            result = None
            try:
                # pass trace only when set so duck-typed registries that
                # don't know the kwarg (tests, custom backends) keep working
                if flight.trace is not None:
                    result = self.registry.execute_canonical(
                        flight.dataset, flight.canonical, flight.version,
                        trace=flight.trace)
                else:
                    result = self.registry.execute_canonical(
                        flight.dataset, flight.canonical, flight.version)
            except Exception as e:  # noqa: BLE001 — fan the error out
                err = e
            with self._lock:
                self._inflight.pop(flight.key, None)
            flight.result, flight.error = result, err
            flight.done.set()

    # ----------------------------------------------------------- batching
    def _unpend(self, flight: _Flight) -> None:
        """Drop a flight from its batch-pending list (caller holds lock)."""
        if flight.bkey is None:
            return
        pend = self._pending.get(flight.bkey)
        if pend is not None:
            try:
                pend.remove(flight)
            except ValueError:
                pass
            if not pend:
                self._pending.pop(flight.bkey, None)

    def _claim_peers(self, leader: _Flight, n: int) -> list[_Flight]:
        """Claim up to ``n`` queued same-shape peers (caller holds lock).
        Expired peers found along the way are failed in place."""
        pend = self._pending.get(leader.bkey)
        if not pend or n <= 0:
            return []
        now = time.monotonic()
        taken: list[_Flight] = []
        kept: list[_Flight] = []
        for f in pend:
            if f is leader or f.claimed:
                continue
            if now > f.deadline:
                f.claimed = True
                self._inflight.pop(f.key, None)
                f.error = DeadlineExceeded(
                    "expired while queued (admission backlog)")
                f.done.set()
            elif len(taken) < n:
                f.claimed = True
                taken.append(f)
            else:
                kept.append(f)
        if kept:
            self._pending[leader.bkey] = kept
        else:
            self._pending.pop(leader.bkey, None)
        return taken

    def _run_batch(self, leader: _Flight) -> None:
        """Lead a same-shape batch: claim queued peers, answer the whole
        batch via ``registry.execute_canonical_batch`` (one vmapped device
        launch when the shape parameterizes), fan results back out."""
        batch = [leader]
        with self._lock:
            self._unpend(leader)
            batch += self._claim_peers(leader, self.batch_max - 1)
        if len(batch) < self.batch_max and self.batch_window_s > 0:
            # micro-deadline: hold an under-full batch briefly so arrivals
            # still in the parse/fingerprint stage can join — batching
            # amortizes so steeply that a few ms of queueing is repaid
            # whenever there is any same-shape pressure at all
            time.sleep(min(self.batch_window_s,
                           max(0.0, leader.deadline - time.monotonic())))
            with self._lock:
                batch += self._claim_peers(leader,
                                           self.batch_max - len(batch))
        try:
            out = self.registry.execute_canonical_batch(
                leader.dataset, [f.param for f in batch], leader.version)
            if len(out) != len(batch):
                raise SchedulerError(
                    f"registry returned {len(out)} results for a batch "
                    f"of {len(batch)}")
        except Exception as e:  # noqa: BLE001 — fan the error out
            out = [e] * len(batch)
        with self._lock:
            for f in batch:
                self._inflight.pop(f.key, None)
        for f, r in zip(batch, out):
            if isinstance(r, Exception):
                f.error = r
            else:
                f.result = r
            f.done.set()

    # -------------------------------------------------------------- stats
    def snapshot(self) -> dict:
        with self._lock:
            inflight = len(self._inflight)
        return {"inflight": inflight, "queued": self._queue.qsize(),
                "workers": self._n_workers, "max_queue": self.max_queue,
                **self.metrics.summary()}
