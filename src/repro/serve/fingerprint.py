"""Structural fingerprints for SPARQL queries (plan-cache keys).

Two queries that differ only in variable names, triple order, filter order,
prefix declarations, or whitespace describe the same query graph and should
compile to the same execution plan.  This module canonicalizes a parsed
``SelectQuery`` into a normal form and hashes it:

1. every variable gets a *structural signature* via a few rounds of
   Weisfeiler–Leman-style refinement over the triple/filter occurrences
   (constants anchor the refinement, so ``?a ub:worksFor ub:Dept0`` and
   ``?b ub:worksFor ub:Dept1`` are distinguished);
2. variables are renamed ``v0, v1, ...`` in signature order (alpha-renaming);
3. the commutative parts — triples and filters within a group — are sorted
   by their canonical serialization (OPTIONAL groups and UNION blocks keep
   their written order: they are evaluated sequentially and are not
   commutative);
4. the fingerprint is the SHA-256 of the canonical serialization.

Because canonicalization only applies a bijective renaming plus reordering
of commutative parts, two queries with equal canonical forms are genuinely
alpha-equivalent: a collision can only merge queries with identical
semantics.  The converse is best-effort — WL-symmetric variables are
tie-broken on their original names, so a pathological automorphic query may
miss sharing, but never computes a wrong answer.

SELECT order is preserved (it fixes result-column order), and the renaming
map is returned so callers can restore the caller's variable names on the
way out of a shared plan or cached result.

``parameterize_query`` additionally produces a *shape* fingerprint: the same
canonicalization with hoistable constants blinded, so LUBM-style template
queries that differ only in which IRI they mention share one parameterized
plan.  The hoisted constants come back as a slot-ordered vector; slot order
is the occurrence order over the shape-canonical group (see
``iter_param_occurrences``), which the engine reuses verbatim to assign
parameter slots to query-graph vertices.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field

from repro.rdf.sparql import (Comparison, GroupPattern, Iri, Literal, Regex,
                              SelectQuery, TriplePattern, Var, parse_sparql)

_REFINE_ROUNDS = 3


@dataclass(frozen=True)
class CanonicalQuery:
    """A query in canonical form plus the renaming that produced it."""

    query: SelectQuery          # canonical AST (variables renamed v0, v1, ...)
    fingerprint: str            # hex digest of the canonical serialization
    rename: dict[str, str] = field(default_factory=dict)  # original -> canonical

    @property
    def inverse(self) -> dict[str, str]:
        return {c: o for o, c in self.rename.items()}

    def restore(self, variables: list[str]) -> list[str]:
        """Map canonical variable names back to this caller's names."""
        inv = self.inverse
        return [inv.get(v, v) for v in variables]


# ------------------------------------------------------------------ hashing
def _h(obj) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


def _term_struct(t, blind: frozenset[int] | None = None) -> tuple:
    """Structural key of a term with variables blinded.  ``blind`` holds
    ``id()``s of constant occurrences to blind too (shape canonicalization:
    every hoistable constant collapses to one placeholder key)."""
    if isinstance(t, Var):
        return ("v",)
    if blind is not None and id(t) in blind:
        return ("c?",)
    if isinstance(t, Iri):
        return ("i", t.value)
    return ("l", t.value, t.numeric)


def _term_sig(t, sig: dict[str, str],
              blind: frozenset[int] | None = None) -> tuple:
    """Structural key of a term with variables replaced by their signature."""
    if isinstance(t, Var):
        return ("v", sig[t.name])
    return _term_struct(t, blind)


def _walk(g: GroupPattern, ctx: str, triples: list, filters: list) -> None:
    """Flatten all triples/filters with a renaming-invariant context tag
    (nesting kind + depth, never a sibling index)."""
    for tp in g.triples:
        triples.append((ctx, tp))
    for f in g.filters:
        filters.append((ctx, f))
    for og in g.optionals:
        _walk(og, ctx + "o", triples, filters)
    for union in g.unions:
        for branch in union:
            _walk(branch, ctx + "u", triples, filters)


def _filter_occurrence(ctx: str, f, name: str) -> tuple:
    if isinstance(f, Regex):
        return ("r", ctx, f.pattern)
    lhs = f.lhs.name == name if isinstance(f.lhs, Var) else False
    rhs = f.rhs.name == name if isinstance(f.rhs, Var) else False
    side = "b" if (lhs and rhs) else ("l" if lhs else "r")
    return ("f", ctx, side, f.op, _term_struct(f.lhs), _term_struct(f.rhs))


def _variable_signatures(ast: SelectQuery,
                         blind: frozenset[int] | None = None) -> dict[str, str]:
    triples: list[tuple[str, TriplePattern]] = []
    filters: list[tuple] = []
    _walk(ast.where, "b", triples, filters)

    occ: dict[str, list] = {}

    def _note(name: str, entry) -> None:
        occ.setdefault(name, []).append(entry)

    for ctx, tp in triples:
        key = (ctx, _term_struct(tp.s, blind), _term_struct(tp.p, blind),
               _term_struct(tp.o, blind))
        for role, t in (("s", tp.s), ("p", tp.p), ("o", tp.o)):
            if isinstance(t, Var):
                _note(t.name, ("t", role, key))
    for ctx, f in filters:
        for t in ((f.var,) if isinstance(f, Regex) else (f.lhs, f.rhs)):
            if isinstance(t, Var):
                _note(t.name, _filter_occurrence(ctx, f, t.name))
    for idx, name in enumerate(ast.select):
        _note(name, ("sel", idx))

    sig = {name: _h(tuple(sorted(entries))) for name, entries in occ.items()}

    # WL refinement: fold in the signatures of co-occurring variables so
    # structurally distinct-but-locally-similar variables separate.
    for _ in range(_REFINE_ROUNDS):
        nxt: dict[str, str] = {}
        for name in sig:
            nbr = []
            for ctx, tp in triples:
                terms = (tp.s, tp.p, tp.o)
                if any(isinstance(t, Var) and t.name == name for t in terms):
                    role = "".join(
                        r for r, t in zip("spo", terms)
                        if isinstance(t, Var) and t.name == name)
                    nbr.append((ctx, role, tuple(_term_sig(t, sig, blind)
                                                 for t in terms)))
            nxt[name] = _h((sig[name], tuple(sorted(nbr))))
        sig = nxt
    return sig


# ------------------------------------------------------------ serialization
def _ser_term(t, blind: frozenset[int] | None = None) -> str:
    if isinstance(t, Var):
        return "?" + t.name
    if blind is not None and id(t) in blind:
        return "◆"  # hoisted constant placeholder (shape serialization)
    if isinstance(t, Iri):
        return f"<{t.value}>"
    num = "" if t.numeric is None else f"#{t.numeric!r}"
    return f'"{t.value}"{num}'


def _ser_filter(f) -> str:
    if isinstance(f, Regex):
        return f"(re {_ser_term(f.var)} {f.pattern!r})"
    return f"(cmp {f.op} {_ser_term(f.lhs)} {_ser_term(f.rhs)})"


def _ser_group(g: GroupPattern, blind: frozenset[int] | None = None) -> str:
    parts = ["T[" + " ".join(f"({_ser_term(tp.s, blind)} "
                             f"{_ser_term(tp.p, blind)} "
                             f"{_ser_term(tp.o, blind)})"
                             for tp in g.triples) + "]",
             "F[" + " ".join(_ser_filter(f) for f in g.filters) + "]",
             "O[" + " ".join(_ser_group(o, blind) for o in g.optionals) + "]",
             "U[" + " ".join("(" + "|".join(_ser_group(b, blind)
                                            for b in branches)
                             + ")" for branches in g.unions) + "]"]
    return "{" + "".join(parts) + "}"


def serialize_query(ast: SelectQuery,
                    blind: frozenset[int] | None = None) -> str:
    sel = "*" if not ast.select else ",".join("?" + v for v in ast.select)
    # solution modifiers are part of query identity: a cached result for
    # LIMIT 10 must not answer LIMIT 20 (plans could be shared, results not
    # — one fingerprint keys both caches, so modifiers split it)
    mods = ""
    if ast.distinct:
        mods += "|D"
    if ast.limit is not None:
        mods += f"|L{ast.limit}"
    if ast.offset:
        mods += f"|O{ast.offset}"
    return f"SELECT({sel})WHERE{_ser_group(ast.where, blind)}{mods}"


# ---------------------------------------------------------- canonical form
def _rename_term(t, rename: dict[str, str]):
    if isinstance(t, Var):
        return Var(rename[t.name])
    return t


def _canon_group(g: GroupPattern, rename: dict[str, str],
                 blind: frozenset[int] | None = None) -> GroupPattern:
    # Constants pass through _rename_term as the SAME objects, so id()-keyed
    # blinding survives into the canonical AST.  Shape canonicalization sorts
    # on the blinded key first (family members must agree on triple order)
    # with the real serialization as a deterministic tie-break — tied triples
    # are structurally interchangeable, so either resolution pairs slots with
    # consistent structural positions.
    triples = sorted(
        (TriplePattern(_rename_term(tp.s, rename), _rename_term(tp.p, rename),
                       _rename_term(tp.o, rename)) for tp in g.triples),
        key=lambda tp: ((_ser_term(tp.p, blind), _ser_term(tp.s, blind),
                         _ser_term(tp.o, blind)),
                        (_ser_term(tp.p), _ser_term(tp.s), _ser_term(tp.o))))
    filters: list = []
    for f in g.filters:
        if isinstance(f, Regex):
            filters.append(Regex(_rename_term(f.var, rename), f.pattern))
        else:
            filters.append(Comparison(_rename_term(f.lhs, rename), f.op,
                                      _rename_term(f.rhs, rename)))
    filters.sort(key=_ser_filter)
    # OPTIONAL groups and UNION blocks keep their written order: OPTIONAL
    # left-joins chain (a later group may join on variables bound by an
    # earlier one) and the first UNION branch fixes SELECT-* projection, so
    # neither is commutative — sorting them would merge non-equivalent
    # queries under one fingerprint
    optionals = [_canon_group(o, rename, blind) for o in g.optionals]
    unions = [[_canon_group(b, rename, blind) for b in branches]
              for branches in g.unions]
    return GroupPattern(triples, filters, optionals, unions)


def canonicalize_query(ast: SelectQuery) -> CanonicalQuery:
    sig = _variable_signatures(ast)
    # signature order; original name only breaks WL-symmetric ties
    order = sorted(sig, key=lambda name: (sig[name], name))
    rename = {name: f"v{i}" for i, name in enumerate(order)}
    canon = SelectQuery(
        select=[rename.get(v, v) for v in ast.select],
        where=_canon_group(ast.where, rename),
        prefixes={},  # already folded into terms by the parser
        distinct=ast.distinct,
        limit=ast.limit,
        offset=ast.offset,
    )
    text = serialize_query(canon)
    fp = hashlib.sha256(text.encode()).hexdigest()[:32]
    return CanonicalQuery(query=canon, fingerprint=fp, rename=rename)


def fingerprint_query(source: str | SelectQuery) -> str:
    """Fingerprint a query given as SPARQL text or a parsed AST."""
    ast = parse_sparql(source) if isinstance(source, str) else source
    return canonicalize_query(ast).fingerprint


# ------------------------------------------------------- parameterized shape
# Predicates whose constant terms anchor the *structure* of the query under
# the type-aware transformation (they fold into vertex labels, not bound
# vertices) — never hoisted into parameters.
_STRUCT_PREDS = frozenset({"rdf:type", "rdf:subClassOf"})


def const_key(t) -> str:
    """Dictionary-text form of a constant term — must match what
    ``core.query.build_query_graph`` feeds ``maps.vertex_of``."""
    return t.value if isinstance(t, Iri) else f'"{t.value}"'


def iter_param_occurrences(g: GroupPattern):
    """Yield hoistable constant term occurrences of a group in slot order.

    Slot order is definitional: the fingerprint layer extracts the constant
    vector with it and the engine assigns plan parameter slots with it, so
    both must call this one generator.  Each occurrence is its own slot even
    when two occurrences mention the same constant (mirroring
    ``build_query_graph``, which makes a fresh bound vertex per occurrence).
    """
    for tp in g.triples:
        if isinstance(tp.p, Iri) and tp.p.value in _STRUCT_PREDS:
            continue
        for t in (tp.s, tp.o):
            if not isinstance(t, Var):
                yield t
    for og in g.optionals:
        yield from iter_param_occurrences(og)
    for union in g.unions:
        for branch in union:
            yield from iter_param_occurrences(branch)


@dataclass(frozen=True)
class ParamQuery:
    """A query split into (shape, constant vector) plus its exact canonical
    form.  ``shape_query`` is the shape-canonical AST with this member's real
    constants still in place — the engine compiles the family plan from it
    (any member works as representative: parameter slots make the compiled
    program constant-independent)."""

    canon: CanonicalQuery       # exact canonicalization (result-cache key)
    shape: str                  # fingerprint with hoistable constants blinded
    consts: tuple[str, ...]     # hoisted constants (dictionary text), by slot
    shape_query: SelectQuery    # shape-canonical AST, slot order authoritative
    rename: dict[str, str] = field(default_factory=dict)  # original -> shape

    @property
    def inverse(self) -> dict[str, str]:
        return {c: o for o, c in self.rename.items()}

    def restore(self, variables: list[str]) -> list[str]:
        """Map shape-canonical variable names back to this caller's names."""
        inv = self.inverse
        return [inv.get(v, v) for v in variables]


def parameterize_query(source: str | SelectQuery) -> ParamQuery:
    """Canonicalize a query to a (shape fingerprint, constant vector) pair.

    Runs the exact canonicalization plus a second pass with hoistable
    constants blinded in the WL refinement, the triple sort, and the
    serialization.  Queries with no hoistable constants degrade to
    shape == exact fingerprint (a family of one).
    """
    ast = parse_sparql(source) if isinstance(source, str) else source
    canon = canonicalize_query(ast)
    blind = frozenset(id(t) for t in iter_param_occurrences(ast.where))
    if not blind:
        return ParamQuery(canon=canon, shape=canon.fingerprint, consts=(),
                          shape_query=canon.query, rename=canon.rename)
    sig = _variable_signatures(ast, blind)
    order = sorted(sig, key=lambda name: (sig[name], name))
    rename = {name: f"v{i}" for i, name in enumerate(order)}
    shape_ast = SelectQuery(
        select=[rename.get(v, v) for v in ast.select],
        where=_canon_group(ast.where, rename, blind),
        prefixes={},
        distinct=ast.distinct,
        limit=ast.limit,
        offset=ast.offset,
    )
    text = serialize_query(shape_ast, blind)
    shape = hashlib.sha256(text.encode()).hexdigest()[:32]
    consts = tuple(const_key(t)
                   for t in iter_param_occurrences(shape_ast.where))
    return ParamQuery(canon=canon, shape=shape, consts=consts,
                      shape_query=shape_ast, rename=rename)
