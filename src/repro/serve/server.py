"""Multi-dataset SPARQL HTTP service over the paper's engine.

``DatasetRegistry`` hosts several transformed graphs (lubm / bsbm / hetero
/ loaded N-Triples) behind one process: each dataset gets its own
``SparqlEngine`` with a fingerprint-keyed plan cache, an optional result
cache keyed ``(fingerprint, graph_version)``, and a version counter whose
bump is the explicit invalidation point for cached results.

``SparqlHTTPServer`` is a stdlib ``ThreadingHTTPServer`` exposing

- ``GET/POST /sparql`` — ``query`` + optional ``dataset``/``limit``/
  ``timeout_ms``/``explain`` parameters (query string, form body, JSON
  body, or raw ``application/sparql-query``), answering SPARQL-JSON-style
  bindings; ``explain=1`` returns the compiled plan (matching order,
  per-step cardinality estimates) without executing;
- ``GET /healthz`` — liveness + hosted datasets;
- ``GET /metrics`` — Prometheus text exposition;
- ``GET /debug/slow`` — per-dataset slow-query log digest (worst traced
  executions by fingerprint);
- ``GET /debug/trace?id=N`` — one logged trace in full: span tree +
  EXPLAIN-ANALYZE-style plan, or Chrome ``trace_event`` JSON with
  ``format=chrome`` (load in chrome://tracing / Perfetto);
- ``GET /debug/workload`` — per-(dataset, plan) workload profiles:
  q-error accounting, observed fanouts, kernel mix, prune ratios,
  batch-lane fill, plus each engine's applied-feedback versions;
- ``GET /debug/decisions`` — the decision journal (plan-cache hits,
  small-plan probes, batch coalescing, replans, cancellations), newest
  first; filter with ``?kind=`` / ``?limit=``.

``/sparql`` additionally accepts ``trace=1``: the request executes in
profiled mode with a forced :class:`repro.obs.Trace` and the response
carries the span tree under ``"trace"``.  A registry-level
``trace_sample`` rate traces that fraction of ordinary requests on the
fast path (zero-duration step spans) to feed the slow-query log and the
``repro_span_seconds`` histograms without the profiled path's overhead.

Requests flow through the :class:`~repro.serve.scheduler.Scheduler`, so
identical concurrent queries coalesce and overload returns 503 rather than
piling onto the engine.
"""

from __future__ import annotations

import hashlib
import json
import random
import threading
import time
from dataclasses import dataclass, field
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

from repro.core.exec import ExecOpts
from repro.core.planner import PlanError
from repro.core.query import QueryBuildError
from repro.core.sparql_exec import QueryResult, SparqlEngine
from repro.obs import (DecisionJournal, SlowQueryLog, Trace,
                       WorkloadProfiler)
from repro.rdf.sparql import SparqlError
from repro.resilience import faults
from repro.resilience.cancel import CancelToken, QueryCancelled
from repro.serve.cache import PlanCache, ResultCache
from repro.serve.fingerprint import CanonicalQuery
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import (DeadlineExceeded, Overloaded, Scheduler,
                                   SchedulerError, SchedulerShutdown,
                                   SchedulerStopped)
from repro.utils import get_logger, log_event

log = get_logger("serve.server")


class UnknownDataset(KeyError):
    pass


def _shape_key(shape: str) -> str:
    """Short stable digest of a parameterized shape (the serialized shape
    AST is too long for journal entries / workload profile keys)."""
    return hashlib.sha1(shape.encode()).hexdigest()[:12]


class UpdateNotSupported(ValueError):
    """Dataset registered without ``updatable=True``."""


@dataclass
class HostedDataset:
    name: str
    graph: object
    maps: object
    engine: SparqlEngine
    result_cache: ResultCache
    store: object = None  # VersionedStore when updatable
    version: int = 0
    lock: threading.Lock = field(default_factory=threading.Lock)
    slow_log: SlowQueryLog = field(default_factory=SlowQueryLog)

    def current_graph(self):
        return self.store.snapshot() if self.store is not None else self.graph


class DatasetRegistry:
    """Named graphs + engines, the unit the scheduler executes against."""

    def __init__(self, metrics: ServeMetrics | None = None, *,
                 plan_cache_size: int = 256, result_cache_size: int = 0,
                 slow_log_size: int = 32, trace_sample: float = 0.0,
                 feedback: bool = False, qerror_threshold: float = 8.0,
                 feedback_min_runs: int = 5, workload_size: int = 256,
                 journal_size: int = 512):
        self.metrics = metrics or ServeMetrics()
        self._default_plan_cache_size = plan_cache_size
        self._default_result_cache_size = result_cache_size
        self._slow_log_size = slow_log_size
        self.trace_sample = min(1.0, max(0.0, float(trace_sample)))
        # workload intelligence: every completed execution folds into a
        # bounded per-(dataset, plan) profile, every engine choice lands in
        # the journal.  ``feedback=True`` closes the loop — consistently
        # misestimated shapes get their cached plan marked stale and the
        # recompile re-runs order search with observed fanouts.  Off by
        # default: feedback changes plan-cache behaviour (replans evict
        # entries), which opt-in deployments should choose knowingly.
        self.journal = DecisionJournal(journal_size)
        self.workload = WorkloadProfiler(
            max_profiles=workload_size, feedback=feedback,
            qerror_threshold=qerror_threshold, min_runs=feedback_min_runs,
            journal=self.journal)
        self._datasets: dict[str, HostedDataset] = {}
        self._lock = threading.Lock()

    def _journal(self, kind: str, **fields) -> None:
        """Record one engine decision + bump its Prometheus counter."""
        self.journal.record(kind, **{k: v for k, v in fields.items()
                                     if v is not None})
        self.metrics.decisions.inc(kind=kind)

    # ------------------------------------------------------------- hosting
    def register(self, name: str, graph, maps, opts: ExecOpts | None = None,
                 *, plan_cache_size: int | None = None,
                 result_cache_size: int | None = None,
                 updatable: bool = False,
                 store=None) -> HostedDataset:
        """Host a dataset.  ``updatable=True`` wraps the graph in a
        :class:`~repro.store.versioned.VersionedStore` (or accepts a
        pre-built one via ``store=``): the engine then executes against
        live snapshots and ``POST /update`` mutates the data in place."""
        plan_cache = PlanCache(self._default_plan_cache_size
                               if plan_cache_size is None else plan_cache_size)
        result_cache = ResultCache(self._default_result_cache_size
                                   if result_cache_size is None
                                   else result_cache_size)
        if updatable and store is None:
            from repro.store import VersionedStore
            store = VersionedStore(graph, maps)
        engine_graph = store.snapshot() if store is not None else graph
        engine = SparqlEngine(engine_graph, maps, opts, plan_cache=plan_cache)
        ds = HostedDataset(name=name, graph=graph, maps=maps, engine=engine,
                           result_cache=result_cache, store=store,
                           version=store.version if store is not None else 0,
                           slow_log=SlowQueryLog(self._slow_log_size))
        with self._lock:
            self._datasets[name] = ds
        self.metrics.attach_cache_gauges(name, plan_cache, result_cache)
        self.metrics.attach_param_family_gauge(name, engine)
        self.metrics.attach_breaker_gauges(name, engine)
        return ds

    def get(self, name: str) -> HostedDataset:
        with self._lock:
            ds = self._datasets.get(name)
        if ds is None:
            raise UnknownDataset(name)
        return ds

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._datasets)

    def default_name(self) -> str:
        names = self.names()
        if not names:
            raise UnknownDataset("registry is empty")
        return names[0]

    def version(self, name: str) -> int:
        return self.get(name).version

    def invalidate(self, name: str) -> int:
        """Bump a dataset's graph version; retire its cached results.
        Call after mutating/reloading the graph in place.  The bump and
        the cache invalidation both happen under the dataset lock, and
        ``ResultCache.invalidate`` raises its version watermark — so an
        execution that captured the old version but finishes later cannot
        re-insert a stale result (the insertion race the old code had)."""
        ds = self.get(name)
        with ds.lock:
            stale = ds.version
            ds.version += 1
            return ds.result_cache.invalidate(stale)

    def update(self, name: str, update_text: str) -> dict:
        """Apply SPARQL UPDATE text to an updatable dataset: mutate the
        store, swap the engine to the fresh snapshot, bump the version and
        retire cached results — all under the dataset lock.  The plan
        cache deliberately survives (plans are structural; snapshot
        execution re-resolves their candidate sets)."""
        import time as _time

        ds = self.get(name)
        if ds.store is None:
            raise UpdateNotSupported(
                f"dataset {name!r} is not updatable; register it with "
                "updatable=True")
        t0 = _time.perf_counter()
        with ds.lock:
            before_compactions = ds.store.counters["compactions"]
            res = ds.store.apply_update(update_text)
            changed = bool(res["inserted"] or res["deleted"])
            if changed:
                ds.engine.set_graph(ds.store.snapshot())
                # ds.version can run ahead of the store's counter (the
                # public invalidate() bumps it independently) — always
                # move strictly forward so this update's invalidation
                # cannot be skipped
                ds.version = max(ds.version + 1, ds.store.version)
                res["invalidated"] = ds.result_cache.invalidate(
                    ds.version - 1)
                res["version"] = ds.version
            else:
                res["invalidated"] = 0
            compactions = ds.store.counters["compactions"] - before_compactions
        m = self.metrics
        m.updates.inc(dataset=name, status="ok")
        if res["inserted"]:
            m.update_triples.inc(res["inserted"], dataset=name, op="insert")
        if res["deleted"]:
            m.update_triples.inc(res["deleted"], dataset=name, op="delete")
        if compactions:
            m.compactions.inc(compactions)
        m.update_latency.observe((_time.perf_counter() - t0) * 1e3)
        res["dataset"] = name
        return res

    # ----------------------------------------------------------- execution
    def execute_canonical(self, name: str, canon: CanonicalQuery,
                          version: int, trace: Trace | None = None,
                          cancel: CancelToken | None = None,
                          query_id: str | None = None) -> QueryResult:
        """Execute over canonical variable names (scheduler entry point).

        ``trace`` is a live :class:`repro.obs.Trace` (forced request);
        when absent, ``trace_sample`` of executions get a sampled trace on
        the fast path.  Traced executions bypass the result cache (there is
        nothing to observe about returning a stored object) and feed the
        slow-query log + span histograms.  ``cancel`` is the flight's
        cooperative-cancellation token: the executor polls it at chunk
        boundaries, so expired/abandoned requests stop occupying the
        device."""
        ds = self.get(name)
        key = (canon.fingerprint, version)
        if trace is None and self.trace_sample > 0.0 \
                and random.random() < self.trace_sample:
            trace = Trace(sampled=True)
        if trace is not None:
            # correlation labels for the span tree / Chrome export
            if trace.query_id is None:
                trace.query_id = query_id
            if trace.dataset is None:
                trace.dataset = name
            if trace.thread is None:
                trace.thread = threading.current_thread().name
        if ds.result_cache.enabled and trace is None:
            hit = ds.result_cache.get(key)
            if hit is not None:
                self._journal("result_cache", dataset=name, hit=True,
                              query_id=query_id,
                              fingerprint=canon.fingerprint)
                return hit
        if trace is not None and trace.root.children:
            # scheduler-submitted trace: account the time between the
            # submitting thread's last span and this worker picking it up
            last = trace.root.children[-1]
            gap = trace._now() - (last.t0 + last.dur)
            if gap > 0:
                trace.add("queue_wait", gap)
        compiled, fresh = ds.engine.compile_canonical(canon, with_fresh=True,
                                                      trace=trace)
        if fresh:
            self.metrics.record_plan_search(compiled.plan_ms)
        self._journal("plan_cache", dataset=name, hit=not fresh,
                      query_id=query_id, fingerprint=canon.fingerprint,
                      search=(compiled.branches[0].plan.search
                              if compiled.branches else None))
        try:
            res = ds.engine.execute_compiled(
                compiled, trace=trace,
                profile=trace.profile_steps if trace is not None else False,
                cancel=cancel)
        except QueryCancelled:
            self._journal("cancel", dataset=name, query_id=query_id,
                          fingerprint=canon.fingerprint)
            self.workload.record_cancel(name, canon.fingerprint)
            raise
        est = res.stats.get("est_rows")
        if est is not None:
            self.metrics.record_cardinality(est, res.count)
        for step_est, step_actual in res.stats.get("step_card", ()):
            self.metrics.record_step_cardinality(step_est, step_actual)
        exec_stats = res.stats.get("exec") or {}
        parts = [part
                 for br in exec_stats.get("branches", ())
                 for part in ([br.get("base") or {}]
                              + list(br.get("optionals") or ()))]
        retries = sum(sum(part.get("step_retries", ())) for part in parts)
        if retries:
            self.metrics.exec_retries.inc(retries)
        prune_in = sum(sum(part.get("step_prune_in", ())) for part in parts)
        if prune_in:
            self.metrics.prune_candidates_in.inc(prune_in)
            self.metrics.prune_candidates_out.inc(
                sum(sum(part.get("step_prune_out", ())) for part in parts))
        compiles = sum(part.get("compiles", 0) for part in parts)
        if compiles:
            self.metrics.compile_events.inc(compiles)
        degraded = sum(1 for part in parts if part.get("degraded_level"))
        if degraded:
            self.metrics.degraded.inc(degraded)
        branches = exec_stats.get("branches") or ()
        base = (branches[0].get("base") or {}) if branches else {}
        probe = base.get("small_probe")
        if probe:
            self._journal("small_probe", dataset=name, query_id=query_id,
                          fingerprint=canon.fingerprint,
                          legacy_wins=bool(probe.get("legacy_wins")),
                          t_pipelined_ms=round(
                              probe.get("t_pipelined_ms", 0.0), 3),
                          t_legacy_ms=round(probe.get("t_legacy_ms", 0.0), 3))
        self._journal("execute", dataset=name, query_id=query_id,
                      fingerprint=canon.fingerprint, count=res.count,
                      wall_ms=round(base.get("wall_ms") or 0.0, 3),
                      small_mode=bool(base.get("small_mode")) or None,
                      degraded=int(base.get("degraded_level") or 0) or None,
                      prune=any(v >= 0 for v in
                                base.get("step_prune_in") or ()) or None)
        if base and compiled.branches:
            # fold the run into the workload profile; feedback hints are
            # only possible for single-branch queries (the profile tracks
            # the branch-0 base plan, which for UNIONs is just one member)
            hint = self.workload.observe(
                name, canon.fingerprint, compiled.branches[0].plan, base,
                count=res.count, wall_ms=base.get("wall_ms") or 0.0,
                fingerprint=(canon.fingerprint
                             if len(compiled.branches) == 1 else None))
            if hint is not None:
                fb_version = ds.engine.apply_feedback(hint["fingerprint"],
                                                      hint["fanouts"])
                self.metrics.feedback_replans.inc()
                self._journal("replan", dataset=name, query_id=query_id,
                              fingerprint=hint["fingerprint"],
                              q_error=round(hint["q_error_median"], 2),
                              version=fb_version)
                log_event(log, "feedback_replan", dataset=name,
                          query_id=query_id,
                          fingerprint=hint["fingerprint"],
                          q_error=round(hint["q_error_median"], 2),
                          version=fb_version)
        if trace is not None:
            trace.finish()
            self.metrics.record_trace(trace)
            explain = ds.engine.describe_compiled(compiled,
                                                  run_stats=res.stats,
                                                  inverse=canon.inverse)
            if ds.slow_log.record(canon.fingerprint, trace.dur_ms, trace,
                                  dataset=name, count=res.count,
                                  explain=explain):
                self.metrics.slow_queries.inc(dataset=name)
            res.stats["trace"] = trace.to_dict()
        elif ds.result_cache.enabled and version == ds.version:
            ds.result_cache.put(key, res)
        return res

    def execute_canonical_batch(self, name: str, pqs, version: int,
                                cancel: CancelToken | None = None,
                                query_ids: list[str] | None = None) -> list:
        """Answer a same-shape batch in one parameterized dispatch
        (scheduler batch-leader entry point).

        ``pqs`` is a list of :class:`~repro.serve.fingerprint.ParamQuery`
        sharing one shape; the shape compiles once
        (:meth:`~repro.core.sparql_exec.SparqlEngine.compile_param`) and
        the members execute as one vmapped launch.  Returns one
        ``QueryResult | Exception`` per member, in order, with canonical
        variable names (the scheduler restores each caller's).  Each
        member still probes the result cache under its own exact
        ``(fingerprint, version)`` key — the canonical fingerprint covers
        shape *and* constants, so this is the per-(shape, constants,
        graph_version) keying the batch path needs.  Shapes that cannot
        be parameterized fall back to per-member
        :meth:`execute_canonical`."""
        ds = self.get(name)
        self.metrics.batch_size.observe(len(pqs))
        if len(pqs) >= 2:
            self.metrics.coalesced_queries.inc(len(pqs))
        qids = query_ids or [None] * len(pqs)
        out: list = [None] * len(pqs)
        family = ds.engine.compile_param(pqs[0])
        if family is None:
            self._journal("batch", dataset=name, size=len(pqs),
                          query_id=qids[0], parameterized=False)
            for i, pq in enumerate(pqs):
                try:
                    out[i] = self.execute_canonical(name, pq.canon, version,
                                                    cancel=cancel,
                                                    query_id=qids[i])
                except Exception as e:  # noqa: BLE001 — per-member fan-out
                    out[i] = e
            return out
        self._journal("batch", dataset=name, size=len(pqs),
                      query_id=qids[0], parameterized=True,
                      shape=_shape_key(family.shape))
        todo: list[int] = []
        for i, pq in enumerate(pqs):
            if ds.result_cache.enabled:
                hit = ds.result_cache.get((pq.canon.fingerprint, version))
                if hit is not None:
                    out[i] = hit
                    continue
            todo.append(i)
        if not todo:
            return out
        try:
            results = ds.engine.execute_param_batch(
                family, [pqs[i].consts for i in todo], cancel=cancel)
        except Exception as e:  # noqa: BLE001 — fail the executed members
            for i in todo:
                out[i] = e
            return out
        plan_key = f"shape:{_shape_key(family.shape)}"
        for i, res in zip(todo, results):
            pq = pqs[i]
            # shape-canonical -> caller-original -> exact-canonical names
            names = [pq.canon.rename.get(v, v)
                     for v in pq.restore(res.variables)]
            r = QueryResult(names, res.rows, list(res.kinds),
                            count=res.count, stats=dict(res.stats))
            out[i] = r
            # cardinality accounting on the batch path too: the member
            # stats carry est_rows/step_card like the solo path does
            est = res.stats.get("est_rows")
            if est is not None:
                self.metrics.record_cardinality(est, res.count)
            for step_est, step_actual in res.stats.get("step_card", ()):
                self.metrics.record_step_cardinality(step_est, step_actual)
            mstats = (res.stats.get("exec") or {}).get("branches") or ()
            mbase = (mstats[0].get("base") or {}) if mstats else {}
            if mbase:
                # profile per shape (the unit the parameterized plan is
                # shared at); no feedback from here — the param family has
                # no single fingerprint to mark stale
                self.workload.observe(name, plan_key, family.plan, mbase,
                                      count=res.count,
                                      wall_ms=mbase.get("wall_ms") or 0.0)
            if ds.result_cache.enabled and version == ds.version:
                ds.result_cache.put((pq.canon.fingerprint, version), r)
        return out

    def execute(self, name: str, sparql: str) -> QueryResult:
        """Scheduler-less convenience path (tests, CLIs)."""
        from repro.serve.fingerprint import canonicalize_query
        from repro.rdf.sparql import parse_sparql

        canon = canonicalize_query(parse_sparql(sparql))
        res = self.execute_canonical(name, canon, self.version(name))
        return QueryResult(canon.restore(res.variables), res.rows,
                           list(res.kinds), count=res.count)

    def decode(self, name: str, res: QueryResult,
               limit: int | None = None) -> list[dict]:
        return res.decode(self.get(name).maps, limit=limit)

    def explain(self, name: str, sparql: str, analyze: bool = False) -> dict:
        """Describe the plan (order, start vertex, per-step estimates)
        without executing; compiles through the shared plan cache.
        ``analyze=True`` executes in profiled mode and adds per-step
        actual rows / retries / wall times (``explain=analyze``)."""
        return self.get(name).engine.explain(sparql, analyze=analyze)

    # -------------------------------------------------------- observability
    def workload_snapshot(self, limit: int | None = 50) -> dict:
        """Workload profiles (worst q-error first) plus each engine's
        applied-feedback versions — the ``/debug/workload`` payload."""
        return {
            "profiles": self.workload.snapshot(limit),
            "feedback_enabled": self.workload.feedback,
            "qerror_threshold": self.workload.qerror_threshold,
            "feedback": {n: self.get(n).engine.feedback_snapshot()
                         for n in self.names()},
            "decisions": dict(self.journal.counts),
        }

    def slow_summaries(self, name: str | None = None) -> dict:
        """Slow-query-log digests, per dataset (no span trees)."""
        names = [name] if name is not None else self.names()
        return {n: self.get(n).slow_log.summaries() for n in names}

    def find_trace(self, trace_id: int) -> dict | None:
        """Locate one logged trace entry by id across all datasets."""
        for n in self.names():
            entry = self.get(n).slow_log.get(trace_id)
            if entry is not None:
                return entry
        return None

    def stats(self) -> dict:
        out = {}
        for name in self.names():
            ds = self.get(name)
            g = ds.current_graph()
            rec = {
                "vertices": int(g.n_vertices),
                "edges": int(g.n_edges),
                "version": ds.version,
                "plan_cache": ds.engine.plan_cache.snapshot(),
                "result_cache": ds.result_cache.snapshot(),
                "resilience": ds.engine.executor.resilience_snapshot(),
            }
            if ds.store is not None:
                rec["store"] = {
                    "delta": ds.store.delta_size(),
                    "epoch": ds.store.epoch,
                    **ds.store.counters,
                }
            out[name] = rec
        return out


# ------------------------------------------------------------------- HTTP
def _bindings_json(registry: DatasetRegistry, dataset: str, res: QueryResult,
                   limit: int | None) -> dict:
    rows = registry.decode(dataset, res, limit=limit)
    bindings = []
    for rec in rows:
        b = {}
        for var, term in rec.items():
            if term is None:
                continue
            kind = "literal" if term.startswith('"') else "uri"
            b[var] = {"type": kind, "value": term.strip('"')}
        bindings.append(b)
    return {"head": {"vars": list(res.variables)},
            "results": {"bindings": bindings},
            "stats": {"count": res.count, "returned": len(bindings)}}


class _Handler(BaseHTTPRequestHandler):
    server: "SparqlHTTPServer"  # type: ignore[assignment]
    protocol_version = "HTTP/1.1"

    # ------------------------------------------------------------- plumbing
    def log_message(self, fmt: str, *args) -> None:  # route to our logger
        log.debug("%s %s", self.address_string(), fmt % args)

    def _send(self, code: int, body: bytes, ctype: str,
              headers: dict[str, str] | None = None) -> None:
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    def _send_json(self, code: int, obj: dict,
                   headers: dict[str, str] | None = None) -> None:
        self._send(code, json.dumps(obj).encode(),
                   "application/json; charset=utf-8", headers)

    def _error(self, code: int, message: str,
               headers: dict[str, str] | None = None, **extra) -> None:
        self._send_json(code, {"error": message, **extra}, headers)

    # ------------------------------------------------------------ endpoints
    def do_GET(self) -> None:  # noqa: N802 (http.server naming)
        url = urlparse(self.path)
        if url.path == "/healthz":
            self._send_json(200, {"status": "ok",
                                  "datasets": self.server.registry.stats(),
                                  "scheduler": self.server.scheduler.snapshot(),
                                  "faults": faults.describe()})
        elif url.path == "/metrics":
            text = self.server.metrics.registry.render()
            self._send(200, text.encode(), "text/plain; version=0.0.4")
        elif url.path == "/sparql":
            params = {k: v[-1] for k, v in parse_qs(url.query).items()}
            self._handle_sparql(params)
        elif url.path == "/debug/slow":
            params = {k: v[-1] for k, v in parse_qs(url.query).items()}
            try:
                out = self.server.registry.slow_summaries(
                    params.get("dataset"))
            except UnknownDataset as e:
                self._error(404, f"unknown dataset: {e}")
            else:
                self._send_json(200, {"slow": out})
        elif url.path == "/debug/workload":
            params = {k: v[-1] for k, v in parse_qs(url.query).items()}
            try:
                limit = int(params.get("limit", 50))
            except ValueError:
                self._error(400, "non-integer 'limit' parameter")
                return
            self._send_json(200,
                            self.server.registry.workload_snapshot(limit))
        elif url.path == "/debug/decisions":
            params = {k: v[-1] for k, v in parse_qs(url.query).items()}
            try:
                limit = int(params.get("limit", 100))
            except ValueError:
                self._error(400, "non-integer 'limit' parameter")
                return
            journal = self.server.registry.journal
            self._send_json(200, {
                "decisions": journal.snapshot(limit=limit,
                                              kind=params.get("kind")),
                "counts": dict(journal.counts)})
        elif url.path == "/debug/trace":
            params = {k: v[-1] for k, v in parse_qs(url.query).items()}
            try:
                trace_id = int(params["id"])
            except (KeyError, ValueError):
                self._error(400, "missing or non-integer 'id' parameter")
                return
            entry = self.server.registry.find_trace(trace_id)
            if entry is None:
                self._error(404, f"no logged trace with id {trace_id} "
                                 "(evicted, or never recorded)")
                return
            fmt = "chrome" if params.get("format") == "chrome" else "json"
            self._send_json(200, SlowQueryLog.render_entry(entry, fmt))
        else:
            self._error(404, f"no such endpoint: {url.path}")

    def do_POST(self) -> None:  # noqa: N802
        url = urlparse(self.path)
        if url.path not in ("/sparql", "/update"):
            self._error(404, f"no such endpoint: {url.path}")
            return
        body_key = "query" if url.path == "/sparql" else "update"
        length = int(self.headers.get("Content-Length") or 0)
        raw = self.rfile.read(length) if length else b""
        ctype = (self.headers.get("Content-Type") or "").split(";")[0].strip()
        params = {k: v[-1] for k, v in parse_qs(url.query).items()}
        try:
            if ctype == "application/json":
                obj = json.loads(raw.decode() or "{}")
                if not isinstance(obj, dict):
                    self._error(400, "JSON body must be an object")
                    return
                params.update(obj)
            elif ctype == "application/x-www-form-urlencoded":
                params.update({k: v[-1]
                               for k, v in parse_qs(raw.decode()).items()})
            elif raw.strip():  # sparql-query / -update / text/plain: raw body
                params[body_key] = raw.decode()
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            self._error(400, f"bad request body: {e}")
            return
        if (url.path == "/update" and "update" not in params and raw.strip()
                and ctype != "application/json"):
            # curl --data-binary defaults to form-encoding; a raw SPARQL
            # UPDATE body form-parses to garbage keys — fall back to it
            params["update"] = raw.decode()
        if url.path == "/update":
            self._handle_update(params)
        else:
            self._handle_sparql(params)

    def _handle_update(self, params: dict) -> None:
        from repro.store import UpdateError

        update = params.get("update")
        if not update:
            self._error(400, "missing 'update' parameter "
                             "(SPARQL INSERT DATA / DELETE DATA)")
            return
        registry = self.server.registry
        try:
            dataset = params.get("dataset") or registry.default_name()
            res = registry.update(dataset, update)
        except UnknownDataset as e:
            self._error(404, f"unknown dataset: {e}")
        except UpdateNotSupported as e:
            self._error(409, str(e))
        except UpdateError as e:
            self.server.metrics.updates.inc(
                dataset=params.get("dataset") or "?", status="error")
            self._error(400, str(e))
        except Exception as e:  # noqa: BLE001 — keep the handler alive
            log.exception("internal error applying update")
            self._error(500, f"internal error: {e}")
        else:
            self._send_json(200, res)

    def _handle_sparql(self, params: dict) -> None:
        query = params.get("query")
        if not query:
            self._error(400, "missing 'query' parameter")
            return
        registry = self.server.registry
        try:
            dataset = params.get("dataset") or registry.default_name()
            limit = int(params["limit"]) if "limit" in params else None
            timeout_s = (float(params["timeout_ms"]) / 1e3
                         if "timeout_ms" in params else None)
            explain_param = str(params.get("explain", "")).lower()
            explain = explain_param in ("1", "true", "yes", "analyze")
            analyze = explain_param == "analyze"
            trace = (str(params.get("trace", "")).lower()
                     in ("1", "true", "yes"))
        except (ValueError, UnknownDataset) as e:
            self._error(400, str(e))
            return
        if explain:
            # plan description only — no scheduler round-trip.  analyze mode
            # executes the query once, in profiled mode (deliberately slow:
            # per-step host syncs), on this handler thread; it bypasses the
            # scheduler, so a dedicated semaphore bounds how many profiled
            # runs may be in flight — excess analyze requests get 503.
            gate = self.server.analyze_gate if analyze else None
            if gate is not None and not gate.acquire(blocking=False):
                self._error(503, "too many explain=analyze runs in flight")
                return
            try:
                plan = registry.explain(dataset, query, analyze=analyze)
            except UnknownDataset as e:
                self._error(404, f"unknown dataset: {e}")
            except (SparqlError, QueryBuildError, PlanError) as e:
                self._error(400, str(e))
            except Exception as e:  # noqa: BLE001 — keep the handler alive
                log.exception("internal error explaining query")
                self._error(500, f"internal error: {e}")
            else:
                self._send_json(200, {"dataset": dataset, "explain": plan})
            finally:
                if gate is not None:
                    gate.release()
            return
        t0 = time.perf_counter()
        try:
            res = self.server.scheduler.submit(dataset, query,
                                               timeout_s=timeout_s,
                                               trace=trace)
        except UnknownDataset as e:
            self._error(404, f"unknown dataset: {e}")
        except (SparqlError, QueryBuildError, PlanError) as e:
            self._error(400, str(e))
        except Overloaded as e:
            # admission control: tell clients when to come back
            log_event(log, "sparql", dataset=dataset, status="overloaded",
                      ms=round((time.perf_counter() - t0) * 1e3, 3))
            self._error(503, str(e),
                        headers={"Retry-After":
                                 str(max(1, round(e.retry_after_s)))},
                        retry_after_s=round(e.retry_after_s, 3))
        except DeadlineExceeded as e:
            extra = {}
            if e.queue_wait_ms is not None:
                extra["queue_wait_ms"] = round(e.queue_wait_ms, 3)
            if e.exec_ms is not None:
                extra["exec_ms"] = round(e.exec_ms, 3)
            log_event(log, "sparql", dataset=dataset, status="timeout",
                      ms=round((time.perf_counter() - t0) * 1e3, 3), **extra)
            self._error(504, str(e), **extra)
        except QueryCancelled as e:
            # distinct from 500: the engine stopped *cooperatively* at a
            # chunk boundary; surface how far it got before the deadline
            extra = {}
            if e.queue_wait_ms is not None:
                extra["queue_wait_ms"] = round(e.queue_wait_ms, 3)
            if e.exec_ms is not None:
                extra["exec_ms"] = round(e.exec_ms, 3)
            if e.partial_stats:
                parts = [part
                         for br in (e.partial_stats.get("exec") or {})
                         .get("branches", ())
                         for part in [br.get("base") or {}]]
                extra["partial"] = {
                    "branches": len(parts),
                    "chunks": sum(p.get("chunks", 0) for p in parts),
                    "wall_ms": round(sum(p.get("wall_ms", 0.0)
                                         for p in parts), 3),
                }
            log_event(log, "sparql", dataset=dataset, status="cancelled",
                      ms=round((time.perf_counter() - t0) * 1e3, 3))
            self._error(504, f"cancelled: {e}", **extra)
        except (SchedulerShutdown, SchedulerStopped) as e:
            self._error(503, str(e),
                        headers={"Retry-After": "1"})
        except SchedulerError as e:
            self._error(500, str(e))
        except Exception as e:  # noqa: BLE001 — never kill the handler thread
            log.exception("internal error serving query")
            self._error(500, f"internal error: {e}")
        else:
            qid = res.stats.get("query_id")
            log_event(log, "sparql", query_id=qid, dataset=dataset,
                      status="ok", count=res.count,
                      ms=round((time.perf_counter() - t0) * 1e3, 3))
            out = _bindings_json(registry, dataset, res, limit)
            if qid:
                out["query_id"] = qid
            if trace and res.stats.get("trace") is not None:
                out["trace"] = res.stats["trace"]
            self._send_json(200, out,
                            headers={"X-Repro-Query-Id": qid} if qid
                            else None)


class SparqlHTTPServer(ThreadingHTTPServer):
    """ThreadingHTTPServer bound to a registry + scheduler."""

    daemon_threads = True

    def __init__(self, address: tuple[str, int], registry: DatasetRegistry,
                 scheduler: Scheduler):
        super().__init__(address, _Handler)
        self.registry = registry
        self.scheduler = scheduler
        self.metrics = scheduler.metrics
        # at most this many profiled explain=analyze executions at once
        self.analyze_gate = threading.BoundedSemaphore(2)


def make_server(registry: DatasetRegistry, host: str = "127.0.0.1",
                port: int = 0, *, workers: int = 4, max_queue: int = 64,
                default_timeout_s: float = 30.0,
                scheduler: Scheduler | None = None) -> SparqlHTTPServer:
    """Build (and start the scheduler of) a ready-to-serve HTTP server.
    ``port=0`` binds an ephemeral port (see ``server.server_address``)."""
    if scheduler is None:
        scheduler = Scheduler(registry, workers=workers, max_queue=max_queue,
                              default_timeout_s=default_timeout_s,
                              metrics=registry.metrics)
    scheduler.start()
    server = SparqlHTTPServer((host, port), registry, scheduler)
    log.info("sparql service on http://%s:%d/sparql (datasets: %s)",
             *server.server_address[:2], ",".join(registry.names()) or "-")
    return server


def serve_in_thread(server: SparqlHTTPServer) -> threading.Thread:
    t = threading.Thread(target=server.serve_forever, daemon=True,
                         name="sparql-http")
    t.start()
    return t
