"""Serving metrics: counters, gauges, latency histograms, and a
Prometheus-style text exposition for the ``/metrics`` endpoint.

Stdlib-only and thread-safe.  Histograms keep fixed cumulative buckets for
exposition plus a bounded reservoir of recent samples so the CLI can print
exact p50/p95/p99 over the recent window.
"""

from __future__ import annotations

import math
import threading
import time
from collections import deque


def _fmt_labels(labels: dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class Counter:
    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._values: dict[tuple, float] = {}
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0, **labels: str) -> None:
        key = tuple(sorted(labels.items()))
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + n

    def value(self, **labels: str) -> float:
        key = tuple(sorted(labels.items()))
        with self._lock:
            return self._values.get(key, 0.0)

    def total(self) -> float:
        with self._lock:
            return sum(self._values.values())

    def render(self) -> list[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} counter"]
        with self._lock:
            items = sorted(self._values.items()) or [((), 0.0)]
        for key, val in items:
            lines.append(f"{self.name}{_fmt_labels(dict(key))} {val:g}")
        return lines


class Gauge:
    def __init__(self, name: str, help: str = "", fn=None):
        self.name, self.help = name, help
        self._value = 0.0
        self._fn = fn  # optional callable sampled at render time
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)

    def value(self) -> float:
        if self._fn is not None:
            return float(self._fn())
        with self._lock:
            return self._value

    def render(self) -> list[str]:
        return [f"# HELP {self.name} {self.help}",
                f"# TYPE {self.name} gauge",
                f"{self.name} {self.value():g}"]


DEFAULT_BUCKETS_MS = (0.5, 1, 2.5, 5, 10, 25, 50, 100, 250, 500,
                      1000, 2500, 5000, 10000, math.inf)

# Log-spaced 1µs .. 10s ladder (seconds) for trace-span histograms: spans
# range from sub-ms cache probes to multi-second first-dispatch compiles,
# so the default ms ladder would dump everything in its two edge buckets.
FINE_BUCKETS_S = tuple(m * 10.0 ** e
                       for e in range(-6, 1) for m in (1, 2.5, 5)) + \
                 (10.0, math.inf)


class Histogram:
    """Latency histogram in milliseconds."""

    def __init__(self, name: str, help: str = "",
                 buckets=DEFAULT_BUCKETS_MS, reservoir: int = 8192):
        self.name, self.help = name, help
        self.buckets = tuple(buckets)
        self._counts = [0] * len(self.buckets)
        self._sum = 0.0
        self._count = 0
        self._recent: deque[float] = deque(maxlen=reservoir)
        self._lock = threading.Lock()

    def observe(self, ms: float) -> None:
        with self._lock:
            self._sum += ms
            self._count += 1
            self._recent.append(ms)
            for i, b in enumerate(self.buckets):
                if ms <= b:
                    self._counts[i] += 1
                    break

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    def percentile(self, p: float) -> float:
        """Exact percentile over the recent-sample reservoir."""
        with self._lock:
            data = sorted(self._recent)
        if not data:
            return float("nan")
        idx = min(len(data) - 1, max(0, int(round(p / 100.0 * (len(data) - 1)))))
        return data[idx]

    def summary(self) -> dict:
        with self._lock:
            count, total = self._count, self._sum
        return {"count": count,
                "mean_ms": (total / count) if count else float("nan"),
                "p50_ms": self.percentile(50),
                "p95_ms": self.percentile(95),
                "p99_ms": self.percentile(99)}

    def render(self) -> list[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} histogram"]
        with self._lock:
            counts, total, count = list(self._counts), self._sum, self._count
        cum = 0
        for b, c in zip(self.buckets, counts):
            cum += c
            le = "+Inf" if math.isinf(b) else f"{b:g}"
            lines.append(f'{self.name}_bucket{{le="{le}"}} {cum}')
        lines.append(f"{self.name}_sum {total:g}")
        lines.append(f"{self.name}_count {count}")
        return lines


class LabeledHistogram:
    """A family of histograms sharing one metric name, split by a single
    label (e.g. ``repro_span_seconds{span="compile"}``).  Children are
    created on first observation; unit is whatever the bucket ladder is in
    (`FINE_BUCKETS_S` = seconds)."""

    def __init__(self, name: str, help: str = "", label: str = "label",
                 buckets=DEFAULT_BUCKETS_MS, reservoir: int = 1024):
        self.name, self.help, self.label = name, help, label
        self.buckets = tuple(buckets)
        self._reservoir = reservoir
        self._children: dict[str, Histogram] = {}
        self._lock = threading.Lock()

    def child(self, value: str) -> Histogram:
        with self._lock:
            h = self._children.get(value)
            if h is None:
                h = Histogram(self.name, buckets=self.buckets,
                              reservoir=self._reservoir)
                self._children[value] = h
            return h

    def observe(self, value: str, x: float) -> None:
        self.child(value).observe(x)

    def render(self) -> list[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} histogram"]
        with self._lock:
            children = sorted(self._children.items())
        for lv, h in children:
            with h._lock:
                counts, total, count = list(h._counts), h._sum, h._count
            cum = 0
            for b, c in zip(self.buckets, counts):
                cum += c
                le = "+Inf" if math.isinf(b) else f"{b:g}"
                lines.append(f'{self.name}_bucket{{{self.label}="{lv}",'
                             f'le="{le}"}} {cum}')
            lines.append(f'{self.name}_sum{{{self.label}="{lv}"}} {total:g}')
            lines.append(f'{self.name}_count{{{self.label}="{lv}"}} {count}')
        return lines


class LabeledGauge:
    """A gauge family split by a single label (e.g. per-dataset in-flight
    query counts)."""

    def __init__(self, name: str, help: str = "", label: str = "label"):
        self.name, self.help, self.label = name, help, label
        self._values: dict[str, float] = {}
        self._lock = threading.Lock()

    def set(self, value: str, v: float) -> None:
        with self._lock:
            self._values[value] = float(v)

    def inc(self, value: str, n: float = 1.0) -> None:
        with self._lock:
            self._values[value] = self._values.get(value, 0.0) + n

    def dec(self, value: str, n: float = 1.0) -> None:
        self.inc(value, -n)

    def value(self, value: str) -> float:
        with self._lock:
            return self._values.get(value, 0.0)

    def render(self) -> list[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} gauge"]
        with self._lock:
            items = sorted(self._values.items())
        for lv, v in items:
            lines.append(f'{self.name}{{{self.label}="{lv}"}} {v:g}')
        return lines


class MetricsRegistry:
    """Holds metrics and renders the Prometheus text exposition."""

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def _register(self, metric):
        with self._lock:
            if metric.name in self._metrics:
                raise ValueError(f"duplicate metric {metric.name}")
            self._metrics[metric.name] = metric
        return metric

    def counter(self, name: str, help: str = "") -> Counter:
        with self._lock:
            m = self._metrics.get(name)
        return m if m is not None else self._register(Counter(name, help))

    def gauge(self, name: str, help: str = "", fn=None) -> Gauge:
        with self._lock:
            m = self._metrics.get(name)
        return m if m is not None else self._register(Gauge(name, help, fn))

    def histogram(self, name: str, help: str = "", **kw) -> Histogram:
        with self._lock:
            m = self._metrics.get(name)
        return m if m is not None else self._register(Histogram(name, help, **kw))

    def labeled_histogram(self, name: str, help: str = "",
                          **kw) -> LabeledHistogram:
        with self._lock:
            m = self._metrics.get(name)
        return m if m is not None else self._register(
            LabeledHistogram(name, help, **kw))

    def labeled_gauge(self, name: str, help: str = "",
                      label: str = "label") -> LabeledGauge:
        with self._lock:
            m = self._metrics.get(name)
        return m if m is not None else self._register(
            LabeledGauge(name, help, label))

    def render(self) -> str:
        with self._lock:
            metrics = list(self._metrics.values())
        lines: list[str] = []
        for m in metrics:
            lines.extend(m.render())
        return "\n".join(lines) + "\n"


class ServeMetrics:
    """The serving subsystem's metric bundle (QPS window, latency, caches)."""

    QPS_WINDOW_S = 60.0

    def __init__(self, registry: MetricsRegistry | None = None):
        self.registry = registry or MetricsRegistry()
        r = self.registry
        self.requests = r.counter(
            "repro_requests_total", "SPARQL requests by dataset and status")
        self.coalesced = r.counter(
            "repro_coalesced_total",
            "requests served by attaching to an identical in-flight query")
        self.latency = r.histogram(
            "repro_request_latency_ms", "end-to-end request latency (ms)")
        self.inflight = r.gauge(
            "repro_inflight_requests", "requests admitted and not yet done")
        self.queue_depth = r.gauge(
            "repro_queue_depth", "flights waiting for a worker")
        self.qps = r.gauge("repro_qps",
                           f"completions / s over the last "
                           f"{int(self.QPS_WINDOW_S)}s", fn=self._qps)
        self.plan_search = r.histogram(
            "repro_plan_search_ms",
            "planner order-search + compile time per fresh plan (ms)")
        self.card_error = r.histogram(
            "repro_cardinality_error_log10",
            "abs log10 ratio of planner-estimated to actual result rows",
            buckets=(0.1, 0.25, 0.5, 1.0, 2.0, 3.0, math.inf))
        self.step_card_error = r.histogram(
            "repro_step_cardinality_error_log10",
            "abs log10 ratio of per-step estimated to actual binding-table "
            "rows (feeds the executor capacity schedule)",
            buckets=(0.1, 0.25, 0.5, 1.0, 2.0, 3.0, math.inf))
        self.qerror = r.labeled_histogram(
            "repro_qerror_log10",
            "log10 q-error (max(est/actual, actual/est), +1-smoothed) of "
            "cardinality estimates, by scope: whole-query vs per-step",
            label="scope", buckets=(0.1, 0.25, 0.5, 1.0, 2.0, 3.0, math.inf),
            reservoir=1024)
        self.feedback_replans = r.counter(
            "repro_feedback_replans_total",
            "cached plans marked stale by workload q-error feedback "
            "(next compile re-runs order search with observed fanouts)")
        self.decisions = r.counter(
            "repro_decisions_total",
            "decision-journal entries recorded, by decision kind")
        self.exec_retries = r.counter(
            "repro_exec_step_retries_total",
            "executor capacity overflows (suffix-resume re-entries)")
        self.prune_candidates_in = r.counter(
            "repro_prune_candidates_in_total",
            "expansion candidates entering neighborhood-signature probes")
        self.prune_candidates_out = r.counter(
            "repro_prune_candidates_out_total",
            "expansion candidates surviving neighborhood-signature probes")
        self.updates = r.counter(
            "repro_updates_total", "SPARQL UPDATE requests by dataset/status")
        self.update_triples = r.counter(
            "repro_update_triples_total",
            "triples applied via SPARQL UPDATE, by dataset and op")
        self.update_latency = r.histogram(
            "repro_update_latency_ms",
            "end-to-end /update latency incl. snapshot + cache invalidation")
        self.compactions = r.counter(
            "repro_store_compactions_total",
            "live-store delta compactions (base graph rebuilds)")
        self.span_seconds = r.labeled_histogram(
            "repro_span_seconds",
            "top-level trace span duration in seconds, by span name",
            label="span", buckets=FINE_BUCKETS_S, reservoir=1024)
        self.compile_events = r.counter(
            "repro_compile_events_total",
            "fresh XLA chunk-program compiles observed on the query path")
        self.traces = r.counter(
            "repro_traces_total", "traces recorded, by mode (forced/sampled)")
        self.slow_queries = r.counter(
            "repro_slow_log_inserts_total",
            "executions admitted to a dataset's slow-query log")
        self.dataset_inflight = r.labeled_gauge(
            "repro_dataset_inflight_queries",
            "queries submitted and not yet completed, per dataset",
            label="dataset")
        self.batch_size = r.histogram(
            "repro_batch_size",
            "queries answered per batched device dispatch (1 = unbatched)",
            buckets=(1, 2, 4, 8, 16, 32, 64, math.inf))
        self.coalesced_queries = r.counter(
            "repro_coalesced_queries_total",
            "queries answered via same-shape batched dispatch (lanes of "
            "batches with size >= 2)")
        self.cancelled = r.counter(
            "repro_cancelled_total",
            "executions stopped cooperatively (deadline expiry, waiter "
            "abandonment, or shutdown) after starting on the device")
        self.degraded = r.counter(
            "repro_degraded_dispatch_total",
            "query executions that completed at a degraded ladder level "
            "after transient faults (OOM/compile failure)")
        self._completions: deque[float] = deque(maxlen=65536)
        self._started = time.monotonic()
        self._lock = threading.Lock()

    def record(self, dataset: str, status: str, ms: float) -> None:
        self.requests.inc(dataset=dataset, status=status)
        self.latency.observe(ms)
        with self._lock:
            self._completions.append(time.monotonic())

    def record_plan_search(self, ms: float) -> None:
        """Planner wall time for a freshly compiled (cache-miss) query."""
        self.plan_search.observe(ms)

    def bind_queue_depth(self, fn) -> None:
        """Make the queue-depth gauge sample ``fn()`` at render time (the
        scheduler binds its live queue size here at start())."""
        self.queue_depth._fn = fn

    def record_trace(self, trace) -> None:
        """Fold one finished trace into the span histograms: every span in
        the tree lands in ``repro_span_seconds{span=...}``.  (Compile
        events are counted from ``Result.stats`` on *every* execution, not
        here, so traced runs are not double-counted.)"""
        self.traces.inc(mode="forced" if trace.profile_steps else "sampled")

        def walk(span):
            self.span_seconds.observe(span.name, span.dur)
            for c in span.children:
                walk(c)

        for child in trace.root.children:
            walk(child)

    def record_cardinality(self, estimated: float, actual: int) -> None:
        """Estimate-vs-actual error as |log10((est+1)/(actual+1))| — 0 is a
        perfect estimate, 1 is an order of magnitude off either way.  The
        same value is log10 of the (+1-smoothed) q-error, so it also lands
        in ``repro_qerror_log10{scope="query"}``."""
        err = abs(math.log10((max(0.0, estimated) + 1.0) / (actual + 1.0)))
        self.card_error.observe(err)
        self.qerror.observe("query", err)

    def record_step_cardinality(self, estimated: float, actual: int) -> None:
        """Per-plan-step estimate-vs-actual row error (same log10 scale).
        Large values here mean the executor's capacity schedule starts from
        bad guesses and leans on suffix-resume doublings."""
        err = abs(math.log10((max(0.0, estimated) + 1.0) / (actual + 1.0)))
        self.step_card_error.observe(err)
        self.qerror.observe("step", err)

    def _qps(self) -> float:
        now = time.monotonic()
        with self._lock:
            n = sum(1 for t in self._completions
                    if now - t <= self.QPS_WINDOW_S)
        window = min(self.QPS_WINDOW_S, max(now - self._started, 1e-9))
        return n / window

    def attach_cache_gauges(self, dataset: str, plan_cache, result_cache) -> None:
        """Expose a dataset's cache counters as render-time gauges."""
        r = self.registry
        for kind, cache in (("plan", plan_cache), ("result", result_cache)):
            if cache is None:
                continue
            for stat in ("hits", "misses", "evictions"):
                r.gauge(f"repro_{kind}_cache_{stat}_{dataset}",
                        f"{kind} cache {stat} for dataset {dataset}",
                        fn=lambda c=cache, s=stat: getattr(c.stats, s))
            r.gauge(f"repro_{kind}_cache_hit_ratio_{dataset}",
                    f"{kind} cache hit ratio for dataset {dataset}",
                    fn=lambda c=cache: c.stats.hit_rate)

    def attach_param_family_gauge(self, dataset: str, engine) -> None:
        """Expose an engine's parameterized-family plan-cache hit ratio
        (hits = queries answered by an already-compiled shape plan) as
        render-time gauges, like :meth:`attach_cache_gauges`."""
        r = self.registry
        for stat in ("hits", "misses"):
            r.gauge(f"repro_param_family_{stat}_{dataset}",
                    f"param-family plan-cache {stat} for dataset {dataset}",
                    fn=lambda e=engine, s=stat: getattr(e.param_stats, s))
        r.gauge(f"repro_param_family_hit_ratio_{dataset}",
                f"param-family plan-cache hit ratio for dataset {dataset}",
                fn=lambda e=engine: e.param_stats.hit_rate)

    def attach_breaker_gauges(self, dataset: str, engine) -> None:
        """Expose an engine executor's degradation-breaker state (plans
        currently pinned to a degraded ladder level) as render-time gauges,
        like :meth:`attach_cache_gauges`."""
        r = self.registry

        def snap(e=engine):
            try:
                return e.executor.resilience_snapshot()
            except Exception:  # noqa: BLE001 — gauges must never raise
                return {}

        r.gauge(f"repro_degraded_plans_{dataset}",
                f"plans running at a degraded ladder level for {dataset}",
                fn=lambda: snap().get("degraded_plans", 0))
        r.gauge(f"repro_degraded_max_level_{dataset}",
                f"highest active degradation ladder level for {dataset}",
                fn=lambda: snap().get("max_level", 0))

    def summary(self) -> dict:
        out = {"requests": self.requests.total(),
               "coalesced": self.coalesced.total(),
               "qps": round(self._qps(), 2),
               **self.latency.summary()}
        if self.cancelled.total():
            out["cancelled"] = self.cancelled.total()
        if self.degraded.total():
            out["degraded"] = self.degraded.total()
        if self.plan_search.count:
            out["plan_search_p50_ms"] = self.plan_search.percentile(50)
        if self.card_error.count:
            out["card_error_p50_log10"] = self.card_error.percentile(50)
        return out
