"""Bounded, thread-safe LRU caches for the serving layer.

``PlanCache`` maps query fingerprints to compiled plans (one per engine, so
keys never cross graphs).  ``ResultCache`` maps ``(fingerprint,
graph_version)`` to finished ``QueryResult``s; bumping the graph version on
a dataset (or calling :meth:`ResultCache.invalidate`) retires stale entries
without touching the plan cache — plans stay valid across data updates that
preserve the schema, results do not.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Hashable


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    inserts: int = 0
    evictions: int = 0
    invalidations: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def snapshot(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "inserts": self.inserts, "evictions": self.evictions,
                "invalidations": self.invalidations,
                "hit_rate": round(self.hit_rate, 4)}


class LRUCache:
    """Thread-safe LRU with hit/miss/eviction accounting."""

    def __init__(self, capacity: int = 128):
        if capacity < 0:
            raise ValueError("capacity must be >= 0")
        self.capacity = capacity
        self._data: OrderedDict[Hashable, Any] = OrderedDict()
        self._lock = threading.RLock()
        self.stats = CacheStats()

    @property
    def enabled(self) -> bool:
        return self.capacity > 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def get(self, key: Hashable, default: Any = None) -> Any:
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.stats.hits += 1
                return self._data[key]
            self.stats.misses += 1
            return default

    def peek(self, key: Hashable, default: Any = None) -> Any:
        """Read without touching recency or stats."""
        with self._lock:
            return self._data.get(key, default)

    def put(self, key: Hashable, value: Any) -> None:
        if not self.enabled:
            return
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self._data[key] = value
                return
            self._data[key] = value
            self.stats.inserts += 1
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
                self.stats.evictions += 1

    def pop(self, key: Hashable, default: Any = None) -> Any:
        with self._lock:
            return self._data.pop(key, default)

    def clear(self) -> None:
        with self._lock:
            self.stats.invalidations += len(self._data)
            self._data.clear()

    def keys(self) -> list:
        with self._lock:
            return list(self._data.keys())

    def snapshot(self) -> dict:
        with self._lock:
            return {"size": len(self._data), "capacity": self.capacity,
                    **self.stats.snapshot()}


class PlanCache(LRUCache):
    """Fingerprint -> CompiledQuery (per engine/graph)."""

    def __init__(self, capacity: int = 256):
        super().__init__(capacity)


class ResultCache(LRUCache):
    """(fingerprint, graph_version) -> QueryResult, with explicit
    invalidation and a row cap so one huge result can't pin the cache.

    ``invalidate(v)`` retires every generation ``<= v`` and raises a
    *watermark*: later ``put`` calls for retired generations are refused.
    Without the watermark there is a lost-invalidation race — a query that
    captured version ``v`` before an update finishes executing after
    ``invalidate(v)`` ran and re-inserts a stale result under a key no
    future invalidation will ever visit."""

    def __init__(self, capacity: int = 512, max_result_rows: int = 200_000):
        super().__init__(capacity)
        self.max_result_rows = max_result_rows
        self._min_version = 0  # smallest graph version still cacheable

    def put(self, key: Hashable, value: Any) -> None:
        rows = getattr(value, "rows", None)
        if rows is not None and rows.shape[0] > self.max_result_rows:
            return
        if (isinstance(key, tuple) and len(key) == 2
                and isinstance(key[1], int)):
            with self._lock:
                if key[1] < self._min_version:
                    return  # a concurrent invalidation already retired it
        super().put(key, value)

    def invalidate(self, graph_version: int | None = None) -> int:
        """Drop entries up to and including ``graph_version`` (or
        everything), and refuse late inserts for retired generations."""
        with self._lock:
            if graph_version is None:
                n = len(self._data)
                self._data.clear()
            else:
                self._min_version = max(self._min_version, graph_version + 1)
                stale = [k for k in self._data
                         if isinstance(k, tuple) and len(k) == 2
                         and isinstance(k[1], int)
                         and k[1] <= graph_version]
                for k in stale:
                    del self._data[k]
                n = len(stale)
            self.stats.invalidations += n
            return n
