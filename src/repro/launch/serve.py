"""RDF query serving driver — thin CLI over :mod:`repro.serve`.

Workload mode (default) builds the requested dataset(s), hosts them in a
:class:`~repro.serve.server.DatasetRegistry`, and drives the query mix
through the concurrent :class:`~repro.serve.scheduler.Scheduler` with N
closed-loop client threads, printing per-query cold/warm latency, cache
hit-rates, and service percentiles:

    python -m repro.launch.serve --dataset lubm --scale 1 --clients 4

HTTP mode exposes the same registry over ``GET/POST /sparql`` (+
``/healthz``, ``/metrics``) and blocks until interrupted:

    python -m repro.launch.serve --dataset lubm,bsbm --http --port 8080
"""

from __future__ import annotations

import argparse
import json
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.core import ExecOpts, SparqlEngine
from repro.rdf.generator import generate_bsbm, generate_hetero, generate_lubm
from repro.rdf.transform import type_aware_transform
from repro.rdf.workloads import BSBM_QUERIES, HETERO_QUERIES, LUBM_QUERIES
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import Scheduler
from repro.serve.server import DatasetRegistry, make_server
from repro.utils import get_logger

log = get_logger("launch.serve")

WORKLOADS = {"lubm": LUBM_QUERIES, "hetero": HETERO_QUERIES,
             "bsbm": BSBM_QUERIES}


class QueryService:
    """Compiled-plan-cached engine wrapper with latency accounting.

    Kept as the minimal single-dataset embedding of the serving stack (the
    full registry/scheduler/HTTP path lives in :mod:`repro.serve`)."""

    def __init__(self, graph, maps, opts: ExecOpts | None = None):
        self.engine = SparqlEngine(graph, maps, opts or ExecOpts())
        self.latencies_ms: list[float] = []

    def execute(self, sparql: str):
        t0 = time.perf_counter()
        res = self.engine.query(sparql)
        dt = (time.perf_counter() - t0) * 1e3
        self.latencies_ms.append(dt)
        return res, dt

    def stats(self) -> dict:
        arr = np.asarray(self.latencies_ms)
        if arr.size == 0:
            return {}
        return {"n": int(arr.size), "mean_ms": float(arr.mean()),
                "p50_ms": float(np.percentile(arr, 50)),
                "p95_ms": float(np.percentile(arr, 95)),
                "p99_ms": float(np.percentile(arr, 99)),
                "max_ms": float(arr.max()),
                "plan_cache": self.engine.plan_cache.snapshot()}


def build_dataset(name: str, scale: int, density: float):
    if name == "lubm":
        st = generate_lubm(scale=scale, density=density)
    elif name == "hetero":
        st = generate_hetero(n_entities=scale * 10000)
    elif name == "bsbm":
        st = generate_bsbm(n_products=scale * 500)
    else:
        raise SystemExit(f"unknown dataset {name}")
    st.finalize()
    g, maps = type_aware_transform(st)
    return g, maps, WORKLOADS[name]


def _build_registry(args) -> tuple[DatasetRegistry, dict[str, dict[str, str]]]:
    metrics = ServeMetrics()
    registry = DatasetRegistry(metrics,
                               result_cache_size=args.result_cache_size,
                               slow_log_size=args.slow_log,
                               trace_sample=args.trace_sample,
                               feedback=not getattr(args, "no_feedback",
                                                    False),
                               qerror_threshold=getattr(
                                   args, "feedback_threshold", 8.0),
                               feedback_min_runs=getattr(
                                   args, "feedback_min_runs", 5),
                               journal_size=getattr(args, "journal_size",
                                                    512))
    workloads: dict[str, dict[str, str]] = {}
    for name in args.dataset.split(","):
        name = name.strip()
        t0 = time.time()
        g, maps, queries = build_dataset(name, args.scale, args.density)
        registry.register(name, g, maps,
                          updatable=getattr(args, "updatable", False))
        workloads[name] = queries
        log.info("dataset %s built: %s in %.1fs", name, g.stats(),
                 time.time() - t0)
    return registry, workloads


def _run_workload(args, registry: DatasetRegistry,
                  workloads: dict[str, dict[str, str]]) -> dict:
    if args.queries:
        known = {n for queries in workloads.values() for n in queries}
        unknown = [n for n in args.queries.split(",") if n not in known]
        if unknown:
            raise SystemExit(f"unknown queries {unknown}; known: "
                             f"{sorted(known)}")
    scheduler = Scheduler(registry, workers=args.workers,
                          max_queue=args.max_queue,
                          default_timeout_s=args.timeout_s,
                          metrics=registry.metrics).start()
    results: dict[str, dict] = {}
    try:
        with ThreadPoolExecutor(max_workers=args.clients) as pool:
            for r in range(args.repeat):
                futs = {}
                for ds, queries in workloads.items():
                    names = (args.queries.split(",") if args.queries
                             else sorted(queries))
                    for name in (n for n in names if n in queries):
                        key = f"{ds}.{name}"
                        futs[key] = pool.submit(
                            _timed_submit, scheduler, ds, queries[name])
                for key, fut in futs.items():
                    res, dt = fut.result()
                    rec = results.setdefault(
                        key, {"count": res.count, "first_ms": dt,
                              "warm_ms": []})
                    if r > 0:
                        rec["warm_ms"].append(dt)
    finally:
        scheduler.stop()

    for key, rec in sorted(results.items()):
        warm = rec.pop("warm_ms")
        # all warm rounds count — a single surviving round under-reports
        rec["warm_mean_ms"] = float(np.mean(warm)) if warm else float("nan")
        rec["warm_min_ms"] = float(np.min(warm)) if warm else float("nan")
        print(f"{key:14s} count={rec['count']:8d} "
              f"cold={rec['first_ms']:9.2f}ms "
              f"warm_mean={rec['warm_mean_ms']:9.2f}ms "
              f"warm_min={rec['warm_min_ms']:9.2f}ms")

    summary = {"service": registry.metrics.summary(),
               "scheduler": {"coalesced": registry.metrics.coalesced.total()},
               "datasets": registry.stats()}
    for ds, st in summary["datasets"].items():
        pc, rc = st["plan_cache"], st["result_cache"]
        print(f"{ds}: plan-cache hit-rate={pc['hit_rate']:.2%} "
              f"({pc['hits']}/{pc['hits'] + pc['misses']}), "
              f"result-cache hit-rate={rc['hit_rate']:.2%}" +
              ("" if rc["capacity"] else " (disabled)"))
    svc = summary["service"]
    print(f"service: qps={svc['qps']:.1f} p50={svc['p50_ms']:.2f}ms "
          f"p95={svc['p95_ms']:.2f}ms p99={svc['p99_ms']:.2f}ms "
          f"coalesced={summary['scheduler']['coalesced']:.0f}")
    wl = registry.workload_snapshot(limit=5)
    replans = sum(v for ds in wl["feedback"].values() for v in ds.values())
    print(f"workload: {len(registry.workload)} profiles, "
          f"decisions={sum(wl['decisions'].values()):.0f} "
          f"{dict(wl['decisions'])}, feedback_replans={replans}")
    for prof in wl["profiles"]:
        if prof["q_error_median"] > 2.0:
            print(f"  misestimated {prof['dataset']}/"
                  f"{prof['plan_key'][:16]}: q-error median="
                  f"{prof['q_error_median']:.1f} over {prof['runs']} runs"
                  + (f" (replanned x{prof['replans']})"
                     if prof["replans"] else ""))
    summary["workload"] = wl
    if args.json:
        print(json.dumps({"queries": results, **summary}, indent=None))
    return results


def _timed_submit(scheduler: Scheduler, dataset: str, sparql: str):
    t0 = time.perf_counter()
    res = scheduler.submit(dataset, sparql)
    return res, (time.perf_counter() - t0) * 1e3


def _run_http(args, registry: DatasetRegistry) -> None:
    server = make_server(registry, host=args.host, port=args.port,
                         workers=args.workers, max_queue=args.max_queue,
                         default_timeout_s=args.timeout_s)
    host, port = server.server_address[:2]
    print(f"serving http://{host}:{port}/sparql "
          f"(datasets: {','.join(registry.names())}; "
          f"also /healthz, /metrics) — Ctrl-C to stop", flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.shutdown()
        server.scheduler.stop()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="lubm",
                    help="comma list of lubm/hetero/bsbm (all hosted at once)")
    ap.add_argument("--scale", type=int, default=2)
    ap.add_argument("--density", type=float, default=0.6)
    ap.add_argument("--queries", default=None, help="comma list of names")
    ap.add_argument("--repeat", type=int, default=3)
    ap.add_argument("--clients", type=int, default=4,
                    help="closed-loop client threads (workload mode)")
    ap.add_argument("--workers", type=int, default=4,
                    help="scheduler worker threads")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="admission control: max queued flights")
    ap.add_argument("--timeout-s", type=float, default=60.0,
                    help="per-request deadline")
    ap.add_argument("--result-cache-size", type=int, default=0,
                    help="entries per dataset (0 disables result caching)")
    ap.add_argument("--trace-sample", type=float, default=0.0,
                    help="fraction of requests traced on the fast path to "
                         "feed /debug/slow and span histograms (0 disables)")
    ap.add_argument("--slow-log", type=int, default=32,
                    help="worst traced executions kept per dataset "
                         "(0 disables the slow-query log)")
    obs = ap.add_argument_group(
        "workload intelligence", "q-error accounting, decision journal, "
        "observed-cardinality feedback (see README 'Observability')")
    obs.add_argument("--no-feedback", action="store_true",
                     help="disable observed-cardinality feedback into the "
                          "planner (profiles and the journal stay on)")
    obs.add_argument("--feedback-threshold", type=float, default=8.0,
                     help="median worst-step q-error above which a cached "
                          "plan is marked stale for re-planning")
    obs.add_argument("--feedback-min-runs", type=int, default=5,
                     help="runs a shape must accumulate before feedback "
                          "can trigger")
    obs.add_argument("--journal-size", type=int, default=512,
                     help="decision-journal ring buffer entries")
    obs.add_argument("--log-json", action="store_true",
                     help="one-JSON-object-per-line logs (same as "
                          "REPRO_LOG_FORMAT=json)")
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--http", action="store_true",
                    help="serve HTTP instead of running the workload")
    ap.add_argument("--updatable", action="store_true",
                    help="host datasets behind a VersionedStore so POST "
                         "/update (SPARQL INSERT DATA / DELETE DATA) works")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    res = ap.add_argument_group(
        "resilience", "fault injection + degraded-mode execution knobs "
        "(see README 'Resilience')")
    res.add_argument("--fault-spec", default=None, metavar="SPEC",
                     help="deterministic fault injection, e.g. "
                          "'dispatch:oom:0.05;compile:latency:0.1:20' — "
                          "site:kind[:rate[:latency_ms]] entries joined "
                          "with ';' (sites: compile, dispatch, delta_merge, "
                          "store_commit; kinds: oom, compile_error, latency, "
                          "poison)")
    res.add_argument("--fault-seed", type=int, default=0,
                     help="seed for the per-spec fault RNG streams (same "
                          "seed + spec + request order => same faults)")
    res.add_argument("--retry-max", type=int, default=None,
                     help="transient-fault retries per degradation level "
                          "before escalating (default 2)")
    res.add_argument("--retry-backoff-ms", type=float, default=None,
                     help="base backoff between transient-fault retries, "
                          "doubled per attempt (default 5ms)")
    res.add_argument("--breaker-cooldown-s", type=float, default=None,
                     help="how long a plan stays at its degraded level "
                          "before re-probing one level lower (default 30s)")
    args = ap.parse_args(argv)

    if args.log_json:
        from repro.utils import set_json_logging
        set_json_logging(True)

    # retry/breaker knobs travel via env so every engine the registry
    # builds (RetryPolicy.from_env) picks them up without plumbing
    import os

    if args.retry_max is not None:
        os.environ["REPRO_RETRY_MAX"] = str(args.retry_max)
    if args.retry_backoff_ms is not None:
        os.environ["REPRO_RETRY_BACKOFF_MS"] = str(args.retry_backoff_ms)
    if args.breaker_cooldown_s is not None:
        os.environ["REPRO_BREAKER_COOLDOWN_S"] = str(args.breaker_cooldown_s)
    if args.fault_spec:
        from repro.resilience import faults
        faults.install(faults.FaultInjector(
            faults.parse_fault_spec(args.fault_spec), seed=args.fault_seed))
        log.warning("fault injection active: %s (seed=%d)",
                    args.fault_spec, args.fault_seed)

    for ds in args.dataset.split(","):
        if ds.strip() not in WORKLOADS:
            raise SystemExit(f"unknown dataset {ds.strip()}")
    registry, workloads = _build_registry(args)
    if args.http:
        _run_http(args, registry)
    else:
        _run_workload(args, registry, workloads)


if __name__ == "__main__":
    main()
