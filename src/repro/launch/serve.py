"""RDF query serving driver (the paper's engine as a service).

``python -m repro.launch.serve --dataset lubm --scale 2`` builds the graph,
starts a compiled-plan-cached engine and executes a query workload with
latency statistics — the end-to-end example deployment of the paper's
system.  ``--queries`` selects named workload queries; default runs the
full LUBM mix.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import ExecOpts, SparqlEngine
from repro.rdf.generator import generate_bsbm, generate_hetero, generate_lubm
from repro.rdf.transform import type_aware_transform
from repro.rdf.workloads import BSBM_QUERIES, HETERO_QUERIES, LUBM_QUERIES
from repro.utils import get_logger

log = get_logger("launch.serve")


class QueryService:
    """Compiled-plan-cached engine wrapper with latency accounting."""

    def __init__(self, graph, maps, opts: ExecOpts | None = None):
        self.engine = SparqlEngine(graph, maps, opts or ExecOpts())
        self.latencies_ms: list[float] = []

    def execute(self, sparql: str):
        t0 = time.perf_counter()
        res = self.engine.query(sparql)
        dt = (time.perf_counter() - t0) * 1e3
        self.latencies_ms.append(dt)
        return res, dt

    def stats(self) -> dict:
        arr = np.asarray(self.latencies_ms)
        if arr.size == 0:
            return {}
        return {"n": int(arr.size), "mean_ms": float(arr.mean()),
                "p50_ms": float(np.percentile(arr, 50)),
                "p95_ms": float(np.percentile(arr, 95)),
                "p99_ms": float(np.percentile(arr, 99)),
                "max_ms": float(arr.max())}


def build_dataset(name: str, scale: int, density: float):
    if name == "lubm":
        st = generate_lubm(scale=scale, density=density)
        queries = LUBM_QUERIES
    elif name == "hetero":
        st = generate_hetero(n_entities=scale * 10000)
        queries = HETERO_QUERIES
    elif name == "bsbm":
        st = generate_bsbm(n_products=scale * 500)
        queries = BSBM_QUERIES
    else:
        raise SystemExit(f"unknown dataset {name}")
    st.finalize()
    g, maps = type_aware_transform(st)
    return g, maps, queries


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="lubm",
                    choices=["lubm", "hetero", "bsbm"])
    ap.add_argument("--scale", type=int, default=2)
    ap.add_argument("--density", type=float, default=0.6)
    ap.add_argument("--queries", default=None, help="comma list of names")
    ap.add_argument("--repeat", type=int, default=3)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)

    t0 = time.time()
    g, maps, queries = build_dataset(args.dataset, args.scale, args.density)
    log.info("dataset built: %s in %.1fs", g.stats(), time.time() - t0)
    svc = QueryService(g, maps)
    names = args.queries.split(",") if args.queries else sorted(queries)
    results = {}
    for r in range(args.repeat):
        for name in names:
            res, dt = svc.execute(queries[name])
            if r == 0:
                results[name] = {"count": res.count, "first_ms": dt}
            else:
                results[name]["warm_ms"] = dt
    for name, rec in results.items():
        print(f"{name:6s} count={rec['count']:8d} "
              f"cold={rec['first_ms']:9.2f}ms "
              f"warm={rec.get('warm_ms', float('nan')):9.2f}ms")
    print("service:", json.dumps(svc.stats(), indent=None))


if __name__ == "__main__":
    main()
