"""Cell builders: (arch × shape × mesh) → jittable step + abstract args +
shardings.  Shared by the dry-run launcher, the roofline analyzer, and the
benchmarks."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.sharding.specs import batch_specs, opt_state_specs, param_specs
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.trainstep import make_train_step
from repro.utils import get_logger

log = get_logger("launch.cells")


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


def build_cell(arch_name: str, cell_name: str, mesh,
               opt_cfg: OptConfig | None = None,
               lm_depth: tuple[int, int] | None = None,
               profile: str = "baseline") -> dict[str, Any]:
    """Returns dict(step=jitted fn, args=abstract arg pytree).

    ``jax.jit(step, in_shardings=...)`` is already applied; call
    ``out["step"].lower(*out["args"])`` to lower.

    ``lm_depth=(n_dense_layers, n_moe_layers)``: depth override used by the
    roofline analyzer to undo XLA's count-scan-body-once cost accounting
    via depth differencing (see analysis/roofline.py).

    ``profile``: sharding/optimization profile (the §Perf hillclimb knobs):
      LM:  "baseline"       activations model-sharded between blocks
           "act_replicated" Megatron-style: activations replicated across
                            `model`, one all-reduce per row-parallel matmul
           "act_seq"        sequence-parallel flavor: activations sharded on
                            the sequence dim between blocks
      GNN: "baseline"       GSPMD auto-partitioning of the edge scatter
           "shard_map"      explicit SPMD: local segment_sum + psum
    """
    arch = get_arch(arch_name)
    opt_cfg = opt_cfg or OptConfig()

    if arch.family == "engine":
        from repro.core.distributed import lower_engine_cell

        meta = arch.cells[cell_name].meta
        return {
            "lower": lambda: lower_engine_cell(
                mesh, arch.config, meta, multi_pod="pod" in mesh.axis_names),
            "family": "engine",
        }

    cfg = arch.config_for(cell_name)
    cell = arch.cells[cell_name]
    batch_abs = arch.input_specs(cell_name)

    if arch.family == "lm":
        from repro.configs.common import lm_input_specs
        from repro.models import transformer

        # activation sharding hints follow the mesh + profile
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        dp = dp if len(dp) > 1 else (dp[0] if dp else None)
        # profile grammar: <act_mode>[+bf16logits][+dots]
        parts = profile.split("+")
        act_specs = {
            "baseline": P(dp, None, "model"),
            "act_replicated": P(dp, None, None),
            "act_seq": P(dp, "model", None),
        }
        cfg = dataclasses.replace(
            cfg, act_spec=act_specs[parts[0]],
            logits_spec=P(dp, None, "model"),
            attn_fp32_logits="bf16logits" not in parts,
            remat="noremat" not in parts,
            remat_policy="dots" if "dots" in parts else "full")
        if lm_depth is not None:
            nd, nm = lm_depth
            moe = cfg.moe
            if moe is not None:
                moe = dataclasses.replace(moe, first_dense_layers=nd)
            # unroll_layers: scan trip count is invisible to HloCostAnalysis,
            # so the analyzer's depth variants must be python-unrolled
            cfg = dataclasses.replace(cfg, n_layers=nd + nm, moe=moe,
                                      unroll_layers=True)
            batch_abs = lm_input_specs(cfg, cell_name)
        params_abs = jax.eval_shape(
            lambda k: transformer.init_params(k, cfg),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        pspecs = param_specs(params_abs, "lm", mesh)
        psh = _named(mesh, pspecs)
        bspec = batch_specs("lm", cell.kind, batch_abs, mesh)
        bsh = _named(mesh, bspec)

        if cell.kind == "train":
            opt_abs = jax.eval_shape(lambda p: adamw_init(p, opt_cfg),
                                     params_abs)
            osh = _named(mesh, opt_state_specs(pspecs, opt_abs))
            raw = make_train_step(transformer.loss_fn, cfg, opt_cfg)
            step = jax.jit(raw, in_shardings=(psh, osh, bsh),
                           out_shardings=(psh, osh, None), donate_argnums=(0, 1))
            return {"step": step, "args": (params_abs, opt_abs, batch_abs),
                    "family": "lm", "cfg": cfg}
        if cell.kind == "prefill":
            def prefill(params, batch):
                logits, _ = transformer.forward(params, batch["tokens"], cfg)
                return logits

            step = jax.jit(prefill, in_shardings=(psh, bsh))
            return {"step": step, "args": (params_abs, batch_abs),
                    "family": "lm", "cfg": cfg}
        # decode
        cache_abs = batch_abs.pop("cache")
        csh = _named(mesh, batch_specs("lm", "decode", cache_abs, mesh))
        tsh = _named(mesh, batch_specs("lm", "decode", batch_abs, mesh))

        def decode(params, cache, batch):
            return transformer.decode_step(params, cache, batch["tokens"], cfg)

        step = jax.jit(decode, in_shardings=(psh, csh, tsh),
                       out_shardings=(None, csh), donate_argnums=(1,))
        return {"step": step, "args": (params_abs, cache_abs, batch_abs),
                "family": "lm", "cfg": cfg}

    if arch.family == "gnn":
        from repro.models.gnn import dimenet, gcn, meshgraphnet, pna

        mod = {"dimenet": dimenet, "gcn-cora": gcn,
               "meshgraphnet": meshgraphnet, "pna": pna}[arch_name]
        if profile in ("shard_map", "shard_map_v2"):
            from repro.sharding.gnn_spmd import (make_spmd_train_step,
                                                 n_shards_of,
                                                 pad_gnn_batch_abstract)

            ns = n_shards_of(mesh)
            n_seg = batch_abs["edge_src"].shape[0] if arch_name == "dimenet" \
                else (batch_abs["x"].shape[0] if "x" in batch_abs
                      else batch_abs["pos"].shape[0])
            v2 = profile == "shard_map_v2"
            fields = ["t_kj", "t_ji", "edge_src", "edge_dst"] if v2 else None
            batch_abs = pad_gnn_batch_abstract(arch_name, batch_abs, ns, n_seg)
            if v2:
                # edge arrays must also divide the shard count
                for f in ("edge_src", "edge_dst"):
                    x = batch_abs[f]
                    pad = (-x.shape[0]) % ns
                    if pad:
                        batch_abs[f] = jax.ShapeDtypeStruct(
                            (x.shape[0] + pad,), x.dtype)
            wrap, cfg2 = make_spmd_train_step(arch_name, mod, cfg, opt_cfg,
                                              mesh, edge_sharded=v2)
            params_abs = jax.eval_shape(
                lambda k: mod.init_params(k, cfg2),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
            opt_abs = jax.eval_shape(lambda p: adamw_init(p, opt_cfg),
                                     params_abs)
            step = wrap(params_abs, opt_abs, batch_abs)
            return {"step": step, "args": (params_abs, opt_abs, batch_abs),
                    "family": "gnn", "cfg": cfg2}
        params_abs = jax.eval_shape(
            lambda k: mod.init_params(k, cfg),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        pspecs = param_specs(params_abs, "gnn", mesh)
        psh = _named(mesh, pspecs)
        bsh = _named(mesh, batch_specs("gnn", cell.kind, batch_abs, mesh))
        opt_abs = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_abs)
        osh = _named(mesh, opt_state_specs(pspecs, opt_abs))
        raw = make_train_step(mod.loss_fn, cfg, opt_cfg)
        step = jax.jit(raw, in_shardings=(psh, osh, bsh),
                       out_shardings=(psh, osh, None), donate_argnums=(0, 1))
        return {"step": step, "args": (params_abs, opt_abs, batch_abs),
                "family": "gnn", "cfg": cfg}

    # recsys
    from repro.models.recsys import dlrm

    params_abs = jax.eval_shape(
        lambda k: dlrm.init_params(k, cfg), jax.ShapeDtypeStruct((2,),
                                                                 jnp.uint32))
    pspecs = param_specs(params_abs, "recsys", mesh)
    psh = _named(mesh, pspecs)
    bsh = _named(mesh, batch_specs("recsys", cell.kind, batch_abs, mesh))
    if cell.kind == "train":
        opt_abs = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_abs)
        osh = _named(mesh, opt_state_specs(pspecs, opt_abs))
        raw = make_train_step(dlrm.loss_fn, cfg, opt_cfg)
        step = jax.jit(raw, in_shardings=(psh, osh, bsh),
                       out_shardings=(psh, osh, None), donate_argnums=(0, 1))
        return {"step": step, "args": (params_abs, opt_abs, batch_abs),
                "family": "recsys", "cfg": cfg}
    if cell.kind == "retrieval":
        step = jax.jit(lambda p, b: dlrm.retrieval_score(p, b, cfg),
                       in_shardings=(psh, bsh))
    else:  # serve
        step = jax.jit(lambda p, b: dlrm.forward(p, b, cfg),
                       in_shardings=(psh, bsh))
    return {"step": step, "args": (params_abs, batch_abs),
            "family": "recsys", "cfg": cfg}
