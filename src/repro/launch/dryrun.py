import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile EVERY (architecture × input shape)
over the production meshes, record memory/cost analysis + collective bytes.

THE TWO LINES ABOVE MUST STAY FIRST — jax locks the device count at first
initialization, and smoke tests / benches must NOT inherit 512 devices
(hence no global conftest/env setting).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                      # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b      # one arch
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single        # one mesh
  PYTHONPATH=src python -m repro.launch.dryrun --cell train_4k
Results: runs/dryrun/<mesh>/<arch>--<cell>.json (existing cells skipped,
so interrupted sweeps resume).
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.configs import all_archs, get_arch
from repro.launch.cells import build_cell
from repro.launch.mesh import make_production_mesh
from repro.utils import get_logger

log = get_logger("launch.dryrun")

COLLECTIVE_RE = re.compile(
    r"^\s*%?\S*\s*=\s*(\S+)\s+(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)", re.MULTILINE)
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(type_str: str) -> int:
    """Bytes of one HLO type string like 'bf16[16,4096]' or a tuple."""
    total = 0
    for m in SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in (post-SPMD) HLO."""
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(
            r"^%?\S+\s*=\s*((?:\([^)]*\)|\S+))\s+(all-gather|all-reduce|"
            r"reduce-scatter|all-to-all|collective-permute)", s)
        if not m:
            continue
        ty, op = m.group(1), m.group(2)
        out[op] = out.get(op, 0) + _shape_bytes(ty)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def run_cell(arch_name: str, cell_name: str, mesh_name: str, out_dir: Path,
             force: bool = False) -> dict:
    out_path = out_dir / f"{arch_name}--{cell_name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    rec = {"arch": arch_name, "cell": cell_name, "mesh": mesh_name,
           "mesh_shape": dict(zip(mesh.axis_names,
                                  [int(mesh.shape[a]) for a in mesh.axis_names])),
           "status": "error"}
    t0 = time.time()
    try:
        built = build_cell(arch_name, cell_name, mesh)
        with jax.set_mesh(mesh):
            if built.get("family") == "engine":
                lowered = built["lower"]()
            else:
                lowered = built["step"].lower(*built["args"])
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "flops": float(cost.get("flops", -1)) if cost else -1,
            "bytes_accessed": float(cost.get("bytes accessed", -1))
            if cost else -1,
            "cost_raw": {k: float(v) for k, v in (cost or {}).items()
                         if isinstance(v, (int, float))},
            "collective_bytes": coll,
            "memory": _mem_dict(mem),
            "hlo_bytes": len(hlo),
        })
        print(f"[dryrun] {mesh_name}/{arch_name}/{cell_name}: OK  "
              f"flops={rec['flops']:.3e} coll={coll.get('total', 0):.3e}B "
              f"compile={t_compile:.1f}s", flush=True)
        print(f"  memory_analysis: {rec['memory']}", flush=True)
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[dryrun] {mesh_name}/{arch_name}/{cell_name}: FAIL {e}",
              flush=True)
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for attr in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "temp_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = int(v)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--cell", default=None, help="one cell (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, (
        "dryrun needs 512 forced host devices; do not import jax before "
        "this module sets XLA_FLAGS")

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = [args.arch] if args.arch else all_archs()
    n_ok = n_fail = 0
    for mesh_name in meshes:
        for arch_name in archs:
            arch = get_arch(arch_name)
            cells = [args.cell] if args.cell else sorted(arch.cells)
            for cell_name in cells:
                rec = run_cell(arch_name, cell_name, mesh_name,
                               Path(args.out) / mesh_name, force=args.force)
                if rec["status"] == "ok":
                    n_ok += 1
                else:
                    n_fail += 1
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
